//! Stub of the `xla` crate surface used by `bpdq::runtime`.
//!
//! The real PJRT bindings (xla_extension + the PJRT C API shared library)
//! are not available in the offline build environment. This stub keeps
//! every PJRT-touching module compiling; at runtime, [`PjRtClient::cpu`]
//! returns an error, so all PJRT-dependent code paths (the `pjrt` engine,
//! `selfcheck`, artifact-gated tests) detect the missing plugin and skip
//! or degrade gracefully. Swap the `xla` path dependency for the real
//! bindings to enable AOT artifact execution — no call sites change.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error("PJRT plugin not available (bpdq built against the offline xla stub)".to_string()))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    U8,
    S32,
    F32,
}

/// Host-side literal handle. The stub carries no data: literals are only
/// ever consumed by `execute`, which is unreachable without a client.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn scalar(_v: i32) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Ok(HloModuleProto)
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn literals_construct_but_do_not_execute() {
        let lit = Literal::vec1(&[1.0, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[Literal]).is_err());
    }
}
