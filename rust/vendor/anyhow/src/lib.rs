//! Minimal, dependency-free replacement for the `anyhow` crate.
//!
//! The offline build environment has no crates.io access, so this vendored
//! crate provides the API subset the workspace actually uses:
//!
//! * [`Error`] — an opaque error value carrying a message and a context
//!   chain (outermost first);
//! * [`Result<T>`] — `std::result::Result<T, Error>` with a default
//!   error type;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * `anyhow!`, `bail!`, `ensure!` macros;
//! * a blanket `From<E: std::error::Error + Send + Sync + 'static>` so
//!   `?` converts library errors, preserving their source chains.
//!
//! `{:#}` formatting prints the full `outer: inner: …` chain, matching
//! the real crate's alternate Display.

use std::error::Error as StdError;
use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message plus a chain of causes, outermost first.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Construct from anything displayable (no source chain).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), cause: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: c.to_string(), cause: Some(Box::new(self)) }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> + '_ {
        let mut items = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            items.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        items.into_iter()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.cause.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.cause.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.cause.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.cause.as_deref();
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`: that is
// what makes this blanket conversion coherent (same trick as the real
// anyhow crate).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msgs = Vec::new();
        let mut cur: Option<&(dyn StdError + 'static)> = Some(&e);
        while let Some(s) = cur {
            msgs.push(s.to_string());
            cur = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, cause: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// `.context(..)` / `.with_context(..)` for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

// The format string must be matched as `literal` (not `expr`): an expr
// fragment forwarded to `format!` is rejected by the compiler ("format
// argument must be a string literal").
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($fmt:literal, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)+) => { return ::std::result::Result::Err($crate::anyhow!($($t)+)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Error = Err::<(), _>(io_err()).context("loading config").unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing file");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing here").unwrap_err();
        assert_eq!(format!("{e}"), "nothing here");
    }

    #[test]
    fn macros_build_errors() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(inner(true).unwrap(), 7);
        let e = inner(false).unwrap_err();
        assert_eq!(format!("{e}"), "flag was false");
        let m = anyhow!("x = {}", 3);
        assert_eq!(format!("{m}"), "x = 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        assert!(parse("nope").is_err());
    }
}
