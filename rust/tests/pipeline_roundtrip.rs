//! Artifact-free integration tests: the whole quantize → pack → serve
//! pipeline on synthetic models (always runnable, no `make artifacts`).

use bpdq::model::pipeline::quantize_model;
use bpdq::model::{synthetic_model, ModelConfig};
use bpdq::serving::KvFormat;
use bpdq::quant::{BcqConfig, BpdqConfig, QuantMethod, UniformConfig, VqConfig};
use bpdq::serving::{EngineKind, LutModel, Router, RouterConfig, Strategy};
use std::collections::HashMap;
use std::sync::Arc;

fn model() -> bpdq::model::Model {
    synthetic_model(
        &ModelConfig {
            vocab_size: 32,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 48,
            max_seq: 48,
            kv_format: KvFormat::F32,
        },
        0xAB,
    )
}

fn calib() -> Vec<Vec<u32>> {
    (0..8).map(|i| (0..32).map(|t| ((t * 5 + i * 7) % 32) as u32).collect()).collect()
}

#[test]
fn every_method_survives_the_pipeline() {
    let m = model();
    let methods = vec![
        QuantMethod::Rtn(UniformConfig { bits: 3, group_size: 16, act_order: false }),
        QuantMethod::Gptq(UniformConfig { bits: 3, group_size: 16, act_order: true }),
        QuantMethod::Awq(UniformConfig { bits: 3, group_size: 16, act_order: false }),
        QuantMethod::AnyBcq(BcqConfig { bits: 2, group_size: 16, alt_iters: 3 }),
        QuantMethod::Vptq(VqConfig { bits: 2, vdim: 2, kmeans_iters: 8, outlier_frac: 0.01 }),
        QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 16, iters: 3, ..Default::default() }),
    ];
    for method in methods {
        let qm = quantize_model(&m, &calib(), &method)
            .unwrap_or_else(|e| panic!("{} failed: {e:#}", method.name()));
        assert_eq!(qm.reports.len(), 14, "{}", method.name());
        assert!(qm.bits_per_weight() > 1.0 && qm.bits_per_weight() < 16.0);
        // forward still works and is finite
        let logits = qm.model.forward_full(&[1, 2, 3, 4]);
        assert!(logits.data().iter().all(|v| v.is_finite()), "{}", method.name());
    }
}

#[test]
fn output_error_ordering_holds_on_full_model() {
    // Sum of per-linear output errors: BPDQ < GPTQ < AWQ at 2-bit.
    let m = model();
    let err_of = |method: QuantMethod| -> f64 {
        quantize_model(&m, &calib(), &method)
            .unwrap()
            .reports
            .iter()
            .map(|r| r.output_err)
            .sum()
    };
    let e_bpdq =
        err_of(QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 16, iters: 6, ..Default::default() }));
    let e_gptq = err_of(QuantMethod::Gptq(UniformConfig { bits: 2, group_size: 16, act_order: true }));
    let e_awq = err_of(QuantMethod::Awq(UniformConfig { bits: 2, group_size: 16, act_order: false }));
    eprintln!("sum output err: bpdq={e_bpdq:.4} gptq={e_gptq:.4} awq={e_awq:.4}");
    assert!(e_bpdq < e_gptq, "bpdq {e_bpdq} !< gptq {e_gptq}");
    assert!(e_gptq < e_awq, "gptq {e_gptq} !< awq {e_awq}");
}

#[test]
fn lut_serving_end_to_end_matches_native() {
    let m = model();
    let qm = quantize_model(
        &m,
        &calib(),
        &QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 16, iters: 2, ..Default::default() }),
    )
    .unwrap();
    let packed: HashMap<_, _> = qm
        .packed
        .iter()
        .map(|(k, v)| (k.clone(), v.as_bit_planes().unwrap().clone()))
        .collect();
    let qmodel = Arc::new(qm.model.clone());

    let run = |kind: EngineKind| -> Vec<Vec<u32>> {
        let router = Router::start(
            RouterConfig {
                n_workers: 2,
                max_batch: 3,
                strategy: Strategy::RoundRobin,
                prefix_cache: false,
            },
            |_| Ok(kind.clone()),
        )
        .unwrap();
        let streams: Vec<_> = (0..6u64)
            .map(|i| router.submit(vec![(i % 32) as u32, 3, 7], 5))
            .collect();
        let out = streams.into_iter().map(|s| s.collect().unwrap().tokens).collect();
        router.shutdown();
        out
    };
    let native = run(EngineKind::Native(qmodel.clone()));
    let lut = run(EngineKind::Lut(LutModel::new(qmodel, packed).unwrap()));
    assert_eq!(native, lut, "LUT serving must reproduce native decode exactly");
}

#[test]
fn quantized_model_size_accounting() {
    let m = model();
    let qm = quantize_model(
        &m,
        &calib(),
        &QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 16, iters: 1, ..Default::default() }),
    )
    .unwrap();
    // packed model strictly smaller than fp16 but nonzero
    assert!(qm.size_bytes() > 0);
    assert!(qm.size_bytes() < m.fp16_bytes());
    // BPW at g=16: 2 + 3·16/16 = 5
    assert!((qm.bits_per_weight() - 5.0).abs() < 1e-6);
}
