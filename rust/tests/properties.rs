//! Property-based integration tests (proptest-lite): the paper's
//! theoretical claims and the system invariants, over randomized shapes
//! and data.

use bpdq::model::{synthetic_model, ModelConfig};
use bpdq::proptest_lite::{check, run_prop, Config};
use bpdq::quant::bpdq::{quantize_full, BpdqConfig};
use bpdq::quant::gar::{gar_perm, preserves_groups};
use bpdq::quant::gptq::invert_perm;
use bpdq::quant::packing::PackedPlane;
use bpdq::quant::{quantize_linear, HessianState, QuantMethod, UniformConfig};
use bpdq::rng::Rng;
use bpdq::serving::prefix::register_reclaimer;
use bpdq::serving::{KvFormat, PrefixCache};
use bpdq::tensor::{matmul_f64, Matrix};
use std::collections::HashSet;
use std::sync::Arc;

fn rand_wx(rng: &mut Rng, d_out: usize, d_in: usize, n: usize) -> (Matrix, Matrix) {
    let w = Matrix::from_vec(
        d_out,
        d_in,
        (0..d_out * d_in).map(|_| 0.1 * rng.student_t(5.0) as f32).collect(),
    );
    let x = Matrix::from_vec(
        n,
        d_in,
        (0..n * d_in)
            .map(|i| ((1.0 / (1.0 + (i % d_in) as f64)).sqrt() * 2.0 + 0.1) as f32 * rng.normal() as f32)
            .collect(),
    );
    (w, x)
}

/// Appendix B.3: after every group (including delta corrections), the
/// global propagation invariant `(W_perm − Ŵ_perm) = E·U` holds.
#[test]
fn prop_bpdq_propagation_invariant() {
    run_prop(
        "bpdq_propagation_invariant",
        Config { cases: 10, ..Default::default() },
        |rng| {
            let d_out = 2 + rng.below_usize(6);
            let g = [8usize, 16][rng.below_usize(2)];
            let ngroups = 1 + rng.below_usize(3);
            let d_in = g * ngroups;
            let n = d_in + 8 + rng.below_usize(16);
            let (w, x) = rand_wx(rng, d_out, d_in, n);
            let h = HessianState::from_activations(&x);
            let cfg = BpdqConfig {
                k: 1 + rng.below_usize(3) as u8,
                group_size: g,
                iters: 1 + rng.below_usize(4),
                ..Default::default()
            };
            let out = quantize_full(&w, &h, cfg).map_err(|e| e.to_string())?;
            let u = h.factor(cfg.hessian_damp, Some(&out.perm)).map_err(|e| e.to_string())?;
            let w_perm = w.permute_cols(&out.perm).to_f64();
            let what_perm = out.dequant.permute_cols(&out.perm).to_f64();
            let eu = matmul_f64(&out.e_coords.to_f64(), &u);
            for r in 0..d_out {
                for j in 0..d_in {
                    let resid = w_perm.get(r, j) - what_perm.get(r, j);
                    let diff = (resid - eu.get(r, j)).abs();
                    if diff > 5e-3 * (1.0 + resid.abs()) {
                        return Err(format!(
                            "invariant violated at ({r},{j}): resid={resid:.5} EU={:.5}",
                            eu.get(r, j)
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// §3.3 best-iterate retention ⇒ propagation error is non-increasing in
/// the iteration budget.
#[test]
fn prop_bpdq_iters_monotone() {
    run_prop("bpdq_iters_monotone", Config { cases: 8, ..Default::default() }, |rng| {
        let d_out = 2 + rng.below_usize(8);
        let d_in = 32;
        let (w, x) = rand_wx(rng, d_out, d_in, 48);
        let h = HessianState::from_activations(&x);
        let mut last = f64::INFINITY;
        for iters in [1usize, 4, 10] {
            let cfg = BpdqConfig { k: 2, group_size: 16, iters, ..Default::default() };
            let out = quantize_full(&w, &h, cfg).map_err(|e| e.to_string())?;
            let err = out.e_coords.fro_norm().powi(2);
            if err > last * 1.0001 {
                return Err(format!("iters={iters}: {err} > {last}"));
            }
            last = err;
        }
        Ok(())
    });
}

/// Proposition 1 corollary, behavioral form: with enough planes (k=8 ≈
/// the full 8-bit RTN init), BPDQ's weight error is far below 2-plane
/// BPDQ — the feasible set grows with k.
#[test]
fn prop_feasible_set_grows_with_k() {
    run_prop("feasible_set_grows_with_k", Config { cases: 6, ..Default::default() }, |rng| {
        let (w, x) = rand_wx(rng, 8, 64, 96);
        let mut errs = Vec::new();
        for k in [1u8, 2, 4] {
            let q = quantize_linear(
                &w,
                &x,
                QuantMethod::Bpdq(BpdqConfig { k, group_size: 32, iters: 4, ..Default::default() }),
            )
            .map_err(|e| e.to_string())?;
            errs.push(q.stats.output_err);
        }
        if !(errs[2] < errs[1] && errs[1] < errs[0]) {
            return Err(format!("errors not decreasing in k: {errs:?}"));
        }
        Ok(())
    });
}

/// GAR permutations are valid and group-preserving for any diag/size.
#[test]
fn prop_gar_valid() {
    check("gar_valid", |rng| {
        let g = [8usize, 16, 32][rng.below_usize(3)];
        let ngroups = 1 + rng.below_usize(6);
        let d_in = g * ngroups;
        let diag: Vec<f64> = (0..d_in).map(|_| rng.f64() * 100.0).collect();
        let perm = gar_perm(&diag, g);
        let mut sorted = perm.clone();
        sorted.sort_unstable();
        if sorted != (0..d_in).collect::<Vec<_>>() {
            return Err("not a permutation".into());
        }
        if !preserves_groups(&perm, g) {
            return Err("group integrity broken".into());
        }
        // inverse round-trips
        let inv = invert_perm(&perm);
        for (j, &p) in perm.iter().enumerate() {
            if inv[p] != j {
                return Err("inverse wrong".into());
            }
        }
        Ok(())
    });
}

/// Bit-plane packing round-trips for arbitrary shapes.
#[test]
fn prop_plane_pack_roundtrip() {
    check("plane_pack_roundtrip", |rng| {
        let d_out = 1 + rng.below_usize(20);
        let d_in = 1 + rng.below_usize(200);
        let m = Matrix::from_vec(
            d_out,
            d_in,
            (0..d_out * d_in).map(|_| if rng.coin(0.4) { 1.0 } else { 0.0 }).collect(),
        );
        let p = PackedPlane::pack(&m);
        if p.unpack() != m {
            return Err(format!("roundtrip failed for {d_out}x{d_in}"));
        }
        Ok(())
    });
}

/// LUT-GEMV equals dequant-GEMV on random packed records (the serving
/// hot path's correctness).
#[test]
fn prop_lut_matches_dequant() {
    check("lut_matches_dequant", |rng| {
        let d_out = 1 + rng.below_usize(12);
        let g = [8usize, 16, 32][rng.below_usize(3)];
        let d_in = g * (1 + rng.below_usize(4));
        let k = 1 + rng.below_usize(4);
        let (w, x) = rand_wx(rng, d_out, d_in, d_in + 8);
        let h = HessianState::from_activations(&x);
        let out = quantize_full(
            &w,
            &h,
            BpdqConfig { k: k as u8, group_size: g, iters: 2, ..Default::default() },
        )
        .map_err(|e| e.to_string())?;
        let xv: Vec<f32> = (0..d_in).map(|_| rng.normal() as f32).collect();
        let want = bpdq::lut::dequant_gemv(&out.packed, &xv);
        let mut got = vec![0.0f32; d_out];
        bpdq::lut::lut_gemv(&out.packed, &xv, &mut got, &mut bpdq::lut::LutScratch::default());
        bpdq::proptest_lite::assert_close(&got, &want, 1e-3, 1e-3)
    });
}

/// GPTQ packed records dequantize to exactly the dense dequant matrix
/// for random shapes, including act-order permutations.
#[test]
fn prop_gptq_pack_consistency() {
    run_prop("gptq_pack_consistency", Config { cases: 12, ..Default::default() }, |rng| {
        let d_out = 1 + rng.below_usize(10);
        let g = [8usize, 16][rng.below_usize(2)];
        let d_in = g * (1 + rng.below_usize(4));
        let (w, x) = rand_wx(rng, d_out, d_in, d_in + 8);
        let bits = [2u8, 3, 4][rng.below_usize(3)];
        let act_order = rng.coin(0.5);
        let q = quantize_linear(
            &w,
            &x,
            QuantMethod::Gptq(UniformConfig { bits, group_size: g, act_order }),
        )
        .map_err(|e| e.to_string())?;
        if let bpdq::quant::PackedWeights::Uniform(p) = &q.packed {
            let deq = p.dequant();
            if q.dequant.fro_dist(&deq) > 1e-4 {
                return Err(format!("pack/dense mismatch: {}", q.dequant.fro_dist(&deq)));
            }
            Ok(())
        } else {
            Err("wrong packing variant".into())
        }
    });
}

/// Arena-backed decode invariants over random tiny models: a fork
/// continues identically to its parent, and a session decoding on a
/// reused (dirty) arena slot matches its fresh-slot twin exactly.
#[test]
fn prop_arena_fork_and_slot_reuse_identical() {
    run_prop(
        "arena_fork_and_slot_reuse_identical",
        Config { cases: 6, ..Default::default() },
        |rng| {
            let nh = 1 << rng.below_usize(3);
            let divisors: Vec<usize> = (1..=nh).filter(|d| nh % d == 0).collect();
            let nkv = divisors[rng.below_usize(divisors.len())];
            let cfg = ModelConfig {
                vocab_size: 10 + rng.below_usize(20),
                d_model: nh * 8,
                n_layers: 1 + rng.below_usize(2),
                n_heads: nh,
                n_kv_heads: nkv,
                d_ff: 16 + rng.below_usize(16),
                max_seq: 32,
                kv_format: KvFormat::F32,
            };
            let m = synthetic_model(&cfg, rng.next_u64());
            let len = 2 + rng.below_usize(6);
            let toks: Vec<u32> =
                (0..len).map(|_| rng.below(cfg.vocab_size as u64) as u32).collect();
            let cont = rng.below(cfg.vocab_size as u64) as u32;

            // Decode once, recording the final logits; fork and check the
            // fork continues exactly like the parent.
            let mut st = m.decode_state();
            let mut last = Vec::new();
            for &t in &toks {
                last = st.step(&m, t);
            }
            let mut f = st.fork();
            let a = f.step(&m, cont);
            let b = st.step(&m, cont);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                if (x - y).abs() > 1e-6 {
                    return Err(format!("fork diverged at vocab {i}: {x} vs {y}"));
                }
            }
            drop(f);
            drop(st); // both slots back to the free list, dirty

            // A fresh session now reuses a dirty slot; it must replay the
            // original decode bit-for-bit.
            let mut st2 = m.decode_state();
            let mut last2 = Vec::new();
            for &t in &toks {
                last2 = st2.step(&m, t);
            }
            for (i, (x, y)) in last.iter().zip(&last2).enumerate() {
                if (x - y).abs() > 1e-6 {
                    return Err(format!("dirty-slot replay diverged at vocab {i}: {x} vs {y}"));
                }
            }
            Ok(())
        },
    );
}

/// Prefix-cache lifecycle invariants under random interleavings of
/// admit-with-shared-prefix / greedy-decode / cancel / evict, on both
/// f32 and packed-W2 arenas with tiny pages (1–3 positions, so every
/// prompt spans page transitions):
///
/// * **parity** — a session that borrowed cached prefix pages emits
///   greedy tokens identical to its cold (cache-less) twin, no matter
///   what the other sessions / the evictor did around it;
/// * **no resurrection** — once a page generation `(id, gen)` has been
///   observed dead, it never answers live again and never reappears in
///   any live session's page table (frees recycle the id under a new
///   generation, so a stale import would be visible here);
/// * **no leaks** — after dropping every session and evicting the whole
///   tree, the arena is back to zero pages and zero slots.
#[test]
fn prop_prefix_cache_interleavings_parity_no_resurrection() {
    use bpdq::model::{argmax, DecodeState};
    run_prop(
        "prefix_cache_interleavings_parity_no_resurrection",
        Config { cases: 4, ..Default::default() },
        |rng| {
            for bits in [0usize, 2] {
                let nh = 1 << rng.below_usize(2);
                let divisors: Vec<usize> = (1..=nh).filter(|d| nh % d == 0).collect();
                let nkv = divisors[rng.below_usize(divisors.len())];
                let cfg = ModelConfig {
                    vocab_size: 10 + rng.below_usize(20),
                    d_model: nh * 8,
                    n_layers: 1 + rng.below_usize(2),
                    n_heads: nh,
                    n_kv_heads: nkv,
                    d_ff: 16 + rng.below_usize(16),
                    max_seq: 32,
                    kv_format: if bits == 0 { KvFormat::F32 } else { KvFormat::bit_plane(bits) },
                };
                let m = synthetic_model(&cfg, rng.next_u64()).with_kv_page(1 + rng.below_usize(3));
                let arena = m.kv_arena();
                let cache = Arc::new(PrefixCache::new(arena.clone()));
                register_reclaimer(&arena, &cache);

                // Prompt pool: a shared stem plus short divergent suffixes.
                let stem: Vec<u32> = (0..3 + rng.below_usize(3))
                    .map(|_| rng.below(cfg.vocab_size as u64) as u32)
                    .collect();
                let pool: Vec<Vec<u32>> = (0..3)
                    .map(|_| {
                        let mut p = stem.clone();
                        for _ in 0..1 + rng.below_usize(2) {
                            p.push(rng.below(cfg.vocab_size as u64) as u32);
                        }
                        p
                    })
                    .collect();
                let decode_n = 3 + rng.below_usize(3);

                // Cold oracle: greedy continuation per prompt, no cache.
                let oracle: Vec<Vec<u32>> = pool
                    .iter()
                    .map(|p| {
                        let mut st = m.decode_state();
                        let mut logits = Vec::new();
                        for &t in p {
                            logits = st.step(&m, t);
                        }
                        let mut toks = Vec::new();
                        for _ in 0..decode_n {
                            let tok = argmax(&logits) as u32;
                            toks.push(tok);
                            logits = st.step(&m, tok);
                        }
                        toks
                    })
                    .collect();

                let mut live: Vec<(DecodeState, usize, usize, Vec<f32>)> = Vec::new();
                let mut seen: HashSet<(u32, u64)> = HashSet::new();
                let mut ghosts: HashSet<(u32, u64)> = HashSet::new();
                for _ in 0..16 {
                    match rng.below(4) {
                        0 if live.len() < 3 => {
                            // Admit: borrow whatever prefix is cached,
                            // prefill the rest, publish.
                            let pi = rng.below_usize(pool.len());
                            let p = &pool[pi];
                            let mut st = m.decode_state();
                            let matched = st.prefix_attach(&cache, p);
                            if matched >= p.len() {
                                return Err(format!(
                                    "match_and_borrow returned {matched} for a \
                                     {}-token prompt (must leave one to feed)",
                                    p.len()
                                ));
                            }
                            let mut logits = Vec::new();
                            for &t in &p[matched..] {
                                logits = st.step(&m, t);
                            }
                            st.prefix_publish(&cache, p);
                            live.push((st, pi, 0, logits));
                        }
                        1 if !live.is_empty() => {
                            // One greedy decode step on a random live
                            // session; its token must match the oracle.
                            let i = rng.below_usize(live.len());
                            let (st, pi, emitted, logits) = &mut live[i];
                            if *emitted < decode_n {
                                let tok = argmax(logits) as u32;
                                if tok != oracle[*pi][*emitted] {
                                    return Err(format!(
                                        "bits {bits} prompt {pi} token {emitted}: cached \
                                         session emitted {tok}, cold twin {}",
                                        oracle[*pi][*emitted]
                                    ));
                                }
                                *logits = st.step(&m, tok);
                                *emitted += 1;
                            }
                        }
                        2 if !live.is_empty() => {
                            // Cancel a session mid-decode.
                            let i = rng.below_usize(live.len());
                            drop(live.swap_remove(i));
                        }
                        _ => {
                            // Pressure the cache's reclaimer.
                            cache.evict(1 + rng.below_usize(3));
                        }
                    }
                    // Invariant sweep: no live session references a dead
                    // generation, and dead generations stay dead.
                    for (st, ..) in &live {
                        for p in st.page_ids() {
                            if ghosts.contains(&p) {
                                return Err(format!(
                                    "bits {bits}: freed page {p:?} resurrected into a \
                                     live session's table"
                                ));
                            }
                            seen.insert(p);
                        }
                    }
                    for &(id, gen) in &seen {
                        let alive = arena.page_is_live(id, gen);
                        if !alive {
                            ghosts.insert((id, gen));
                        } else if ghosts.contains(&(id, gen)) {
                            return Err(format!(
                                "bits {bits}: page ({id}, {gen}) answered live after being \
                                 observed dead"
                            ));
                        }
                    }
                }
                drop(live);
                cache.evict(usize::MAX / 2);
                let st = arena.stats();
                if st.slots_in_use != 0 || st.pages_in_use != 0 {
                    return Err(format!(
                        "bits {bits}: leak at drain — {} slots, {} pages still in use",
                        st.slots_in_use, st.pages_in_use
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Chunked-prefill lifecycle invariants under random interleavings of
/// admit / advance-prefill-by-a-chunk / greedy-decode / cancel / evict,
/// on f32 and packed-W2 arenas with tiny pages (1–3 positions, so
/// chunks straddle page transitions):
///
/// * **parity** — a session prefilled in random-sized chunks (over
///   whatever prefix pages the cache lent it) emits greedy tokens
///   identical to its one-token-per-step cold twin;
/// * **mid-prefill cancel safety** — a session dropped with its prompt
///   only partially fed releases its slot and every borrowed page;
/// * **no leaks** — after dropping every session and evicting the whole
///   tree, the arena is back to zero pages and zero slots.
#[test]
fn prop_chunked_prefill_interleavings_parity_no_leaks() {
    use bpdq::model::{argmax, DecodeState};
    run_prop(
        "chunked_prefill_interleavings_parity_no_leaks",
        Config { cases: 4, ..Default::default() },
        |rng| {
            for bits in [0usize, 2] {
                let nh = 1 << rng.below_usize(2);
                let divisors: Vec<usize> = (1..=nh).filter(|d| nh % d == 0).collect();
                let nkv = divisors[rng.below_usize(divisors.len())];
                let cfg = ModelConfig {
                    vocab_size: 10 + rng.below_usize(20),
                    d_model: nh * 8,
                    n_layers: 1 + rng.below_usize(2),
                    n_heads: nh,
                    n_kv_heads: nkv,
                    d_ff: 16 + rng.below_usize(16),
                    max_seq: 32,
                    kv_format: if bits == 0 { KvFormat::F32 } else { KvFormat::bit_plane(bits) },
                };
                let m = synthetic_model(&cfg, rng.next_u64()).with_kv_page(1 + rng.below_usize(3));
                let arena = m.kv_arena();
                let cache = Arc::new(PrefixCache::new(arena.clone()));
                register_reclaimer(&arena, &cache);

                let stem: Vec<u32> = (0..3 + rng.below_usize(3))
                    .map(|_| rng.below(cfg.vocab_size as u64) as u32)
                    .collect();
                let pool: Vec<Vec<u32>> = (0..3)
                    .map(|_| {
                        let mut p = stem.clone();
                        for _ in 0..2 + rng.below_usize(4) {
                            p.push(rng.below(cfg.vocab_size as u64) as u32);
                        }
                        p
                    })
                    .collect();
                let decode_n = 3 + rng.below_usize(3);

                // Cold oracle: one token per step, no cache, no chunks.
                let oracle: Vec<Vec<u32>> = pool
                    .iter()
                    .map(|p| {
                        let mut st = m.decode_state();
                        let mut logits = Vec::new();
                        for &t in p {
                            logits = st.step(&m, t);
                        }
                        let mut toks = Vec::new();
                        for _ in 0..decode_n {
                            let tok = argmax(&logits) as u32;
                            toks.push(tok);
                            logits = st.step(&m, tok);
                        }
                        toks
                    })
                    .collect();

                // (state, prompt idx, prompt tokens fed, emitted, logits)
                let mut live: Vec<(DecodeState, usize, usize, usize, Vec<f32>)> = Vec::new();
                for _ in 0..24 {
                    match rng.below(5) {
                        0 if live.len() < 3 => {
                            // Admit: borrow whatever prefix is cached;
                            // the suffix is fed in chunks later.
                            let pi = rng.below_usize(pool.len());
                            let mut st = m.decode_state();
                            let matched = st.prefix_attach(&cache, &pool[pi]);
                            if matched >= pool[pi].len() {
                                return Err(format!(
                                    "match_and_borrow returned {matched} for a \
                                     {}-token prompt (must leave one to feed)",
                                    pool[pi].len()
                                ));
                            }
                            live.push((st, pi, matched, 0, Vec::new()));
                        }
                        1 if !live.is_empty() => {
                            // Advance a random session's prefill by a
                            // ragged chunk (1..=3 tokens); publish when
                            // the prompt completes.
                            let i = rng.below_usize(live.len());
                            let (st, pi, fed, _, logits) = &mut live[i];
                            let p = &pool[*pi];
                            if *fed < p.len() {
                                let n = (1 + rng.below_usize(3)).min(p.len() - *fed);
                                let out = st.prefill_chunk(&m, &p[*fed..*fed + n]);
                                *fed += n;
                                if *fed == p.len() {
                                    st.prefix_publish(&cache, p);
                                    *logits = out;
                                }
                            }
                        }
                        2 if !live.is_empty() => {
                            // One greedy decode step on a prefilled
                            // session; its token must match the oracle.
                            let i = rng.below_usize(live.len());
                            let (st, pi, fed, emitted, logits) = &mut live[i];
                            if *fed == pool[*pi].len() && *emitted < decode_n {
                                let tok = argmax(logits) as u32;
                                if tok != oracle[*pi][*emitted] {
                                    return Err(format!(
                                        "bits {bits} prompt {pi} token {emitted}: chunked \
                                         session emitted {tok}, cold twin {}",
                                        oracle[*pi][*emitted]
                                    ));
                                }
                                *logits = st.step(&m, tok);
                                *emitted += 1;
                            }
                        }
                        3 if !live.is_empty() => {
                            // Cancel a session — possibly mid-prefill,
                            // which must release its slot and every
                            // borrowed page.
                            let i = rng.below_usize(live.len());
                            drop(live.swap_remove(i));
                        }
                        _ => {
                            // Pressure the cache's reclaimer.
                            cache.evict(1 + rng.below_usize(3));
                        }
                    }
                }
                drop(live);
                cache.evict(usize::MAX / 2);
                let st = arena.stats();
                if st.slots_in_use != 0 || st.pages_in_use != 0 {
                    return Err(format!(
                        "bits {bits}: leak at drain — {} slots, {} pages still in use",
                        st.slots_in_use, st.pages_in_use
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Model decode path (KV cache) matches the batch forward for random
/// tiny models and token streams.
#[test]
fn prop_decode_matches_forward() {
    run_prop("decode_matches_forward", Config { cases: 6, ..Default::default() }, |rng| {
        // 1, 2, or 4 query heads with a random divisor as kv-head count
        // (exercises MQA / GQA / MHA in the same property).
        let nh = 1 << rng.below_usize(3);
        let divisors: Vec<usize> = (1..=nh).filter(|d| nh % d == 0).collect();
        let nkv = divisors[rng.below_usize(divisors.len())];
        let cfg = ModelConfig {
            vocab_size: 10 + rng.below_usize(20),
            d_model: nh * 8,
            n_layers: 1 + rng.below_usize(2),
            n_heads: nh,
            n_kv_heads: nkv,
            d_ff: 16 + rng.below_usize(16),
            max_seq: 32,
            kv_format: KvFormat::F32,
        };
        let m = synthetic_model(&cfg, rng.next_u64());
        let len = 2 + rng.below_usize(8);
        let toks: Vec<u32> = (0..len).map(|_| rng.below(cfg.vocab_size as u64) as u32).collect();
        let full = m.forward_full(&toks);
        let mut st = m.decode_state();
        for (t, &tok) in toks.iter().enumerate() {
            let logits = st.step(&m, tok);
            for v in 0..cfg.vocab_size {
                let a = full.get(t, v);
                if (a - logits[v]).abs() > 2e-3 * (1.0 + a.abs()) {
                    return Err(format!("pos {t} vocab {v}: {a} vs {}", logits[v]));
                }
            }
        }
        Ok(())
    });
}

/// The KV bit-plane encoder's grid-step guarantee, over random strips:
/// pack -> unpack of any stored row errs by at most one grid step per
/// coefficient group (plus f16 coefficient rounding), at every
/// supported bit-width and for ragged channel groups.
#[test]
fn prop_kv_bitplane_roundtrip_bounded_by_grid_step() {
    use bpdq::tensor::{PackedGeom, PackedStripMut};
    run_prop(
        "kv_bitplane_roundtrip_bounded_by_grid_step",
        Config { cases: 12, ..Default::default() },
        |rng| {
            let bits = [2usize, 3, 4][rng.below_usize(3)];
            let hd = [4usize, 8, 32, 48][rng.below_usize(4)];
            let group = [4usize, 8, 16, 32][rng.below_usize(4)];
            let cap = 2 + rng.below_usize(14);
            let len = 1 + rng.below_usize(cap);
            let geom = PackedGeom::new(cap, hd, bits, group);
            let mut words = vec![0u32; geom.strip_words()];
            let mut strip = PackedStripMut::new(geom, &mut words);
            let rows: Vec<Vec<f32>> = (0..len)
                .map(|_| (0..hd).map(|_| rng.normal() as f32 * 2.0).collect())
                .collect();
            for (u, row) in rows.iter().enumerate() {
                strip.store_row(u, row);
            }
            let view = strip.as_strip();
            let levels = ((1usize << bits) - 1) as f32;
            let mut out = vec![0.0f32; hd];
            for (u, row) in rows.iter().enumerate() {
                view.dequant_row(u, &mut out);
                for grp in 0..geom.n_groups() {
                    let lo = grp * geom.group;
                    let hi = (lo + geom.group).min(hd);
                    let mn = row[lo..hi].iter().cloned().fold(f32::INFINITY, f32::min);
                    let mx = row[lo..hi].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let step = (mx - mn) / levels;
                    let maxabs = mx.abs().max(mn.abs());
                    for j in lo..hi {
                        let err = (row[j] - out[j]).abs();
                        if err > step * 1.001 + 2e-3 * (maxabs + 1.0) {
                            return Err(format!(
                                "bits {bits} hd {hd} g {group} u {u} j {j}: err {err} > step {step}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Packed-KV arena decode invariants over random tiny models: forks
/// continue bit-identically to their parent (bytewise prefix copy + a
/// deterministic store-time encoder) and dirty-slot reuse replays a
/// decode exactly — the quantized-KV twin of
/// `prop_arena_fork_and_slot_reuse_identical`.
#[test]
fn prop_packed_arena_fork_and_slot_reuse_identical() {
    run_prop(
        "packed_arena_fork_and_slot_reuse_identical",
        Config { cases: 4, ..Default::default() },
        |rng| {
            let nh = 1 << rng.below_usize(3);
            let divisors: Vec<usize> = (1..=nh).filter(|d| nh % d == 0).collect();
            let nkv = divisors[rng.below_usize(divisors.len())];
            let bits = [2usize, 3, 4][rng.below_usize(3)];
            let cfg = ModelConfig {
                vocab_size: 10 + rng.below_usize(20),
                d_model: nh * 8,
                n_layers: 1 + rng.below_usize(2),
                n_heads: nh,
                n_kv_heads: nkv,
                d_ff: 16 + rng.below_usize(16),
                max_seq: 32,
                kv_format: KvFormat::bit_plane(bits),
            };
            let m = synthetic_model(&cfg, rng.next_u64());
            let len = 2 + rng.below_usize(6);
            let toks: Vec<u32> =
                (0..len).map(|_| rng.below(cfg.vocab_size as u64) as u32).collect();
            let cont = rng.below(cfg.vocab_size as u64) as u32;

            let mut st = m.decode_state();
            let mut last = Vec::new();
            for &t in &toks {
                last = st.step(&m, t);
            }
            if last.iter().any(|v| !v.is_finite()) {
                return Err("packed decode produced non-finite logits".into());
            }
            let mut f = st.fork();
            let a = f.step(&m, cont);
            let b = st.step(&m, cont);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                if (x - y).abs() > 1e-6 {
                    return Err(format!("packed fork diverged at vocab {i}: {x} vs {y}"));
                }
            }
            drop(f);
            drop(st); // both slots back to the free list, dirty

            let mut st2 = m.decode_state();
            let mut last2 = Vec::new();
            for &t in &toks {
                last2 = st2.step(&m, t);
            }
            for (i, (x, y)) in last.iter().zip(&last2).enumerate() {
                if (x - y).abs() > 1e-6 {
                    return Err(format!(
                        "dirty packed-slot replay diverged at vocab {i}: {x} vs {y}"
                    ));
                }
            }
            Ok(())
        },
    );
}
