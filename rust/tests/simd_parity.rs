//! SIMD-vs-scalar parity per kernel family, tier forced explicitly.
//!
//! Every test drives the `_t` entry points (`dot_t`, `strip_dots_packed_t`,
//! …) so each tier is exercised regardless of what `BPDQ_SIMD` or the
//! process-wide dispatch latch says. Hosts without a SIMD tier skip with
//! a note (`SimdTier::detect() == Scalar`) instead of silently passing —
//! the CI ubuntu fleet always has AVX2, so the skips only fire on exotic
//! local hosts.
//!
//! Parity contract (see `tensor/mod.rs` "SIMD dispatch & numerics
//! policy"):
//! * bit-exact (`assert_eq!`): packed strip dots/axpys (table-driven
//!   subset-sum chunks reproduce the scalar ascending-bit fold
//!   exactly), axpy / f32 strip axpys (per-element mul+add, no FMA),
//!   the LUT-GEMM byte gather, and softmax (its max is associative and
//!   the exp/sum/scale epilogue is the scalar code verbatim).
//! * tolerance-bounded: dot / f32 strip dots (the reduction
//!   reassociates in lanes) and rmsnorm (f64 sum of squares
//!   reassociates; the f32 epilogue is per-element identical).
//!
//! Shapes deliberately ragged: head dims off the vector width
//! (13, 80), odd lengths straddling the packed-table cutoff
//! (`PACKED_TABLE_MIN_LEN = 16`), channel groups that don't divide the
//! head dim, and batch sizes 1/3/8.

use bpdq::rng::Rng;
use bpdq::tensor::simd::{
    axpy_t, dot_t, rmsnorm_t, softmax_t, strip_axpys_packed_t, strip_axpys_t,
    strip_dots_packed_t, strip_dots_t,
};
use bpdq::tensor::{PackedGeom, PackedStrip, PackedStripMut, SimdScratch, SimdTier};

const HDS: [usize; 4] = [8, 13, 32, 80];
const LENS: [usize; 4] = [5, 17, 33, 129]; // 5 < table cutoff < the rest
const BATCHES: [usize; 3] = [1, 3, 8];
const BITS: [usize; 3] = [2, 3, 4];

/// The SIMD tier to test against scalar, or `None` (with a note) when
/// the host only has the scalar tier.
fn simd_tier() -> Option<SimdTier> {
    let t = SimdTier::detect();
    if t == SimdTier::Scalar {
        eprintln!("note: host has no SIMD tier — parity test skipped");
        None
    } else {
        Some(t)
    }
}

fn normals(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn rel_close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[test]
fn tier_parse_and_support_are_loud() {
    assert!(SimdTier::parse("bogus").is_err());
    assert!(SimdTier::parse("").is_err());
    // `auto` always resolves to something the host supports.
    let auto = SimdTier::parse("auto").unwrap();
    assert!(auto.is_supported());
    assert!(SimdTier::Scalar.is_supported());
    // At most one of avx2/neon is supported on any real host; the
    // unsupported one must be reported as such, not silently accepted.
    assert!(!(SimdTier::Avx2.is_supported() && SimdTier::Neon.is_supported()));
}

#[test]
fn dot_parity_tolerance() {
    let Some(tier) = simd_tier() else { return };
    let mut rng = Rng::new(101);
    for &n in &[0usize, 1, 7, 8, 15, 16, 31, 33, 100, 257] {
        let a = normals(&mut rng, n);
        let b = normals(&mut rng, n);
        let scalar = dot_t(SimdTier::Scalar, &a, &b);
        let simd = dot_t(tier, &a, &b);
        assert!(rel_close(scalar, simd, 1e-5), "n {n}: {scalar} vs {simd}");
    }
}

#[test]
fn axpy_parity_bit_exact() {
    let mut rng = Rng::new(102);
    let Some(tier) = simd_tier() else { return };
    for &n in &[1usize, 7, 8, 15, 33, 129] {
        let x = normals(&mut rng, n);
        let y0 = normals(&mut rng, n);
        let alpha = rng.normal() as f32;
        let mut ys = y0.clone();
        axpy_t(SimdTier::Scalar, alpha, &x, &mut ys);
        let mut yv = y0.clone();
        axpy_t(tier, alpha, &x, &mut yv);
        assert_eq!(ys, yv, "n {n}");
    }
}

#[test]
fn f32_strip_dots_parity_tolerance() {
    let Some(tier) = simd_tier() else { return };
    let mut rng = Rng::new(103);
    for &hd in &HDS {
        for &nb in &BATCHES {
            let len = 21usize;
            let qs_data: Vec<Vec<f32>> = (0..nb).map(|_| normals(&mut rng, hd)).collect();
            let strips_data: Vec<Vec<f32>> =
                (0..nb).map(|_| normals(&mut rng, len * hd)).collect();
            let qs: Vec<&[f32]> = qs_data.iter().map(|v| v.as_slice()).collect();
            let strips: Vec<&[f32]> = strips_data.iter().map(|v| v.as_slice()).collect();
            let mut ss = vec![0.0f32; nb * len];
            strip_dots_t(SimdTier::Scalar, &qs, &strips, hd, 0.5, &mut ss);
            let mut sv = vec![0.0f32; nb * len];
            strip_dots_t(tier, &qs, &strips, hd, 0.5, &mut sv);
            for (i, (&a, &b)) in ss.iter().zip(&sv).enumerate() {
                assert!(rel_close(a, b, 1e-5), "hd {hd} nb {nb} i {i}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn f32_strip_axpys_parity_bit_exact() {
    let Some(tier) = simd_tier() else { return };
    let mut rng = Rng::new(104);
    for &hd in &HDS {
        for &nb in &BATCHES {
            let len = 19usize;
            let strips_data: Vec<Vec<f32>> =
                (0..nb).map(|_| normals(&mut rng, len * hd)).collect();
            let strips: Vec<&[f32]> = strips_data.iter().map(|v| v.as_slice()).collect();
            // Mix sub-threshold weights in so the `w < 1e-9` skip mask
            // is exercised on both sides.
            let ws: Vec<f32> = (0..nb * len)
                .map(|i| if i % 4 == 0 { 0.0 } else { 0.01 + (i % 11) as f32 * 0.02 })
                .collect();
            let mut fs = vec![0.0f32; nb * hd];
            {
                let mut outs: Vec<&mut [f32]> = fs.chunks_exact_mut(hd).collect();
                strip_axpys_t(SimdTier::Scalar, &ws, &strips, hd, &mut outs);
            }
            let mut fv = vec![0.0f32; nb * hd];
            {
                let mut outs: Vec<&mut [f32]> = fv.chunks_exact_mut(hd).collect();
                strip_axpys_t(tier, &ws, &strips, hd, &mut outs);
            }
            assert_eq!(fs, fv, "hd {hd} nb {nb}");
        }
    }
}

/// Build `nb` packed strips of `len` random rows (same recipe as the
/// ops unit fixture).
fn packed_fixture(rng: &mut Rng, nb: usize, len: usize, geom: PackedGeom) -> Vec<Vec<u32>> {
    let mut words = vec![vec![0u32; geom.strip_words()]; nb];
    for w in words.iter_mut() {
        let mut strip = PackedStripMut::new(geom, w);
        for u in 0..len {
            let row = normals(rng, geom.hd);
            strip.store_row(u, &row);
        }
    }
    words
}

#[test]
fn packed_strip_dots_parity_bit_exact() {
    let Some(tier) = simd_tier() else { return };
    let mut rng = Rng::new(105);
    for &hd in &HDS {
        for &bits in &BITS {
            // Ragged and aligned channel groups (7 never divides the
            // head dims above; `hd` makes one whole-row group).
            for &group in &[7usize, 8, 32, 64] {
                for &len in &LENS {
                    for &nb in &BATCHES {
                        let geom = PackedGeom::new(len, hd, bits, group);
                        let words = packed_fixture(&mut rng, nb, len, geom);
                        let strips: Vec<PackedStrip> =
                            words.iter().map(|w| PackedStrip::new(geom, w)).collect();
                        let qs_data: Vec<Vec<f32>> =
                            (0..nb).map(|_| normals(&mut rng, hd)).collect();
                        let qs: Vec<&[f32]> = qs_data.iter().map(|v| v.as_slice()).collect();
                        let mut ss = vec![0.0f32; nb * len];
                        let mut scr = SimdScratch::default();
                        strip_dots_packed_t(
                            SimdTier::Scalar,
                            &qs,
                            &strips,
                            len,
                            0.25,
                            &mut ss,
                            &mut scr,
                        );
                        let mut sv = vec![0.0f32; nb * len];
                        strip_dots_packed_t(tier, &qs, &strips, len, 0.25, &mut sv, &mut scr);
                        assert_eq!(ss, sv, "hd {hd} bits {bits} group {group} len {len} nb {nb}");
                    }
                }
            }
        }
    }
}

#[test]
fn packed_strip_axpys_parity_bit_exact() {
    let Some(tier) = simd_tier() else { return };
    let mut rng = Rng::new(106);
    for &hd in &HDS {
        for &bits in &BITS {
            for &group in &[7usize, 32] {
                for &len in &LENS {
                    for &nb in &BATCHES {
                        let geom = PackedGeom::new(len, hd, bits, group);
                        let words = packed_fixture(&mut rng, nb, len, geom);
                        let strips: Vec<PackedStrip> =
                            words.iter().map(|w| PackedStrip::new(geom, w)).collect();
                        let ws: Vec<f32> = (0..nb * len)
                            .map(|i| if i % 5 == 0 { 0.0 } else { 0.01 + (i % 9) as f32 * 0.03 })
                            .collect();
                        let mut fs = vec![0.0f32; nb * hd];
                        {
                            let mut outs: Vec<&mut [f32]> = fs.chunks_exact_mut(hd).collect();
                            strip_axpys_packed_t(SimdTier::Scalar, &ws, &strips, len, &mut outs);
                        }
                        let mut fv = vec![0.0f32; nb * hd];
                        {
                            let mut outs: Vec<&mut [f32]> = fv.chunks_exact_mut(hd).collect();
                            strip_axpys_packed_t(tier, &ws, &strips, len, &mut outs);
                        }
                        assert_eq!(fs, fv, "hd {hd} bits {bits} group {group} len {len} nb {nb}");
                    }
                }
            }
        }
    }
}

#[test]
fn rmsnorm_parity_tolerance() {
    let Some(tier) = simd_tier() else { return };
    let mut rng = Rng::new(107);
    for &d in &[8usize, 13, 80, 257] {
        let x = normals(&mut rng, d);
        let gain: Vec<f32> = (0..d).map(|_| 1.0 + 0.05 * rng.normal() as f32).collect();
        let mut os = vec![0.0f32; d];
        rmsnorm_t(SimdTier::Scalar, &x, &gain, 1e-5, &mut os);
        let mut ov = vec![0.0f32; d];
        rmsnorm_t(tier, &x, &gain, 1e-5, &mut ov);
        for (i, (&a, &b)) in os.iter().zip(&ov).enumerate() {
            assert!(rel_close(a, b, 1e-6), "d {d} i {i}: {a} vs {b}");
        }
    }
}

#[test]
fn softmax_parity_value_exact() {
    let Some(tier) = simd_tier() else { return };
    let mut rng = Rng::new(108);
    for &d in &[1usize, 7, 8, 33, 129] {
        let logits: Vec<f32> = (0..d).map(|_| 6.0 * rng.normal() as f32).collect();
        let mut xs = logits.clone();
        softmax_t(SimdTier::Scalar, &mut xs);
        let mut xv = logits.clone();
        softmax_t(tier, &mut xv);
        // The max reduction is associative (same value whatever the
        // lane order) and the exp/sum/scale epilogue is the scalar
        // code verbatim, so the tiers agree exactly.
        assert_eq!(xs, xv, "d {d}");
    }
}

#[test]
fn lut_gemm_parity_bit_exact() {
    use bpdq::lut::{lut_gemm_with_tier, LutScratch};
    use bpdq::quant::packing::{BitPlanePacked, PackedPlane};
    use bpdq::tensor::Matrix;
    let Some(tier) = simd_tier() else { return };
    let mut rng = Rng::new(109);
    // 68×52: ragged against both the 8-wide chunk grid and the
    // batch-gather width; group 24 splits chunks mid-byte.
    let (d_out, d_in, g, k) = (68usize, 52usize, 24usize, 3usize);
    let planes: Vec<PackedPlane> = (0..k)
        .map(|_| {
            let dense = Matrix::from_vec(
                d_out,
                d_in,
                (0..d_out * d_in).map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 }).collect(),
            );
            PackedPlane::pack(&dense)
        })
        .collect();
    let ng = d_in.div_ceil(g);
    let coeffs: Vec<Matrix> = (0..=k)
        .map(|_| Matrix::from_vec(d_out, ng, normals(&mut rng, d_out * ng)))
        .collect();
    let packed = BitPlanePacked { d_out, d_in, group_size: g, planes, coeffs, coeff_bits: 16 };
    for &nb in &BATCHES {
        let xs_data: Vec<Vec<f32>> = (0..nb).map(|_| normals(&mut rng, d_in)).collect();
        let xs: Vec<&[f32]> = xs_data.iter().map(|v| v.as_slice()).collect();
        let mut scratch = LutScratch::default();
        let mut ys_s = vec![vec![0.0f32; d_out]; nb];
        {
            let mut yrefs: Vec<&mut [f32]> = ys_s.iter_mut().map(|y| y.as_mut_slice()).collect();
            lut_gemm_with_tier(SimdTier::Scalar, &packed, &xs, &mut yrefs, &mut scratch);
        }
        let mut ys_v = vec![vec![0.0f32; d_out]; nb];
        {
            let mut yrefs: Vec<&mut [f32]> = ys_v.iter_mut().map(|y| y.as_mut_slice()).collect();
            lut_gemm_with_tier(tier, &packed, &xs, &mut yrefs, &mut scratch);
        }
        // The gather reads table entries per lane in the same order and
        // adds them into per-lane accumulators — no reassociation.
        assert_eq!(ys_s, ys_v, "nb {nb}");
    }
}
