//! Artifact-gated integration tests: exercise the real `make artifacts`
//! outputs (trained checkpoint, HLO kernels, vocab) end to end. Each
//! test skips cleanly when the artifact it needs is missing so that
//! `cargo test` is green both before and after `make artifacts`.

use bpdq::data::{CorpusConfig, CorpusGen, Split, Tokenizer};
use bpdq::eval::perplexity;
use bpdq::io::tlm::TlmFile;
use bpdq::model::pipeline::quantize_model;
use bpdq::model::Model;
use bpdq::quant::{BpdqConfig, QuantMethod, UniformConfig};
use bpdq::runtime::{self, Runtime};
use std::path::Path;

fn artifact(name: &str) -> Option<std::path::PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(name);
    if p.exists() {
        Some(p)
    } else {
        eprintln!("[skip] artifact {name} missing (run `make artifacts`)");
        None
    }
}

#[test]
fn vocab_artifact_in_sync() {
    let Some(p) = artifact("vocab.txt") else { return };
    Tokenizer::new().verify_artifact(&p).expect("vocab drift between rust and artifact");
}

#[test]
fn trained_checkpoint_loads_and_is_trained() {
    let Some(p) = artifact("tiny_small.tlm") else { return };
    let model = Model::from_tlm(&TlmFile::load(&p).unwrap()).unwrap();
    let tok = Tokenizer::new();
    assert_eq!(model.cfg.vocab_size, tok.vocab_size());
    // A trained model must beat the uniform baseline by a wide margin:
    // uniform ppl = vocab_size (68); trained should be < 5.
    let gen = CorpusGen::new(CorpusConfig::default());
    let docs = gen.token_docs(Split::Eval, 12, &tok);
    let ppl = perplexity(&model, &docs);
    assert!(ppl < 5.0, "checkpoint does not look trained: ppl={ppl}");
}

#[test]
fn kernel_artifacts_compile_and_match_native_lut() {
    let Some(bpdq_hlo) = artifact("bpdq_gemv.hlo.txt") else { return };
    let Some(dequant_hlo) = artifact("dequant_gemv.hlo.txt") else { return };

    // Random packed weights at the artifact's fixed shape.
    let (k, d_out, d_in, g) = (2usize, 128usize, 128usize, 64usize);
    use bpdq::quant::packing::{BitPlanePacked, PackedPlane};
    use bpdq::rng::Rng;
    use bpdq::tensor::Matrix;
    let mut rng = Rng::new(99);
    let planes: Vec<PackedPlane> = (0..k)
        .map(|_| {
            PackedPlane::pack(&Matrix::from_vec(
                d_out,
                d_in,
                (0..d_out * d_in).map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 }).collect(),
            ))
        })
        .collect();
    let ng = d_in / g;
    let coeffs: Vec<Matrix> = (0..=k)
        .map(|_| Matrix::from_vec(d_out, ng, (0..d_out * ng).map(|_| rng.normal() as f32).collect()))
        .collect();
    let packed = BitPlanePacked { d_out, d_in, group_size: g, planes, coeffs, coeff_bits: 16 };
    let x: Vec<f32> = (0..d_in).map(|_| rng.normal() as f32).collect();

    let mut y_native = vec![0.0f32; d_out];
    bpdq::lut::lut_gemv(&packed, &x, &mut y_native, &mut bpdq::lut::LutScratch::default());

    // byte layout conversion (same as selfcheck)
    let mut bytes = Vec::new();
    for plane in &packed.planes {
        for r in 0..d_out {
            let words = plane.row_words(r);
            for c in 0..d_in / 8 {
                bytes.push(((words[c / 4] >> (8 * (c % 4))) & 0xFF) as u8);
            }
        }
    }
    let mut coeff_flat = Vec::new();
    for c in &packed.coeffs {
        coeff_flat.extend_from_slice(c.data());
    }

    let mut rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("[skip] PJRT plugin unavailable: {e:#}");
            return;
        }
    };
    for hlo in [&bpdq_hlo, &dequant_hlo] {
        let exe = rt.load(hlo).unwrap();
        let out = exe
            .run(&[
                runtime::literal_f32(&x, &[d_in as i64]).unwrap(),
                runtime::literal_u8(&bytes, &[k, d_out, d_in / 8]).unwrap(),
                runtime::literal_f32(&coeff_flat, &[(k + 1) as i64, d_out as i64, ng as i64])
                    .unwrap(),
            ])
            .unwrap();
        let y = runtime::to_f32_vec(&out[0]).unwrap();
        for r in 0..d_out {
            assert!(
                (y[r] - y_native[r]).abs() < 1e-3 * (1.0 + y_native[r].abs()),
                "{}: row {r}: {} vs {}",
                hlo.display(),
                y[r],
                y_native[r]
            );
        }
    }
}

#[test]
fn quantization_quality_ordering_on_trained_model() {
    // The paper's central ordinal claim, on the real trained model:
    // at 2-bit, BPDQ ppl < GPTQ ppl, and both beat AWQ.
    let Some(p) = artifact("tiny_small.tlm") else { return };
    let model = Model::from_tlm(&TlmFile::load(&p).unwrap()).unwrap();
    let gen = CorpusGen::new(CorpusConfig::default());
    let tok = Tokenizer::new();
    let calib: Vec<Vec<u32>> = gen
        .token_docs(Split::Calib, 32, &tok)
        .into_iter()
        .map(|mut d| {
            d.truncate(model.cfg.max_seq);
            d
        })
        .filter(|d| d.len() >= 8)
        .collect();
    let docs = gen.token_docs(Split::Eval, 16, &tok);

    let ppl_of = |method: QuantMethod| {
        let qm = quantize_model(&model, &calib, &method).unwrap();
        perplexity(&qm.model, &docs)
    };
    let bpdq2 = ppl_of(QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 64, ..Default::default() }));
    let gptq2 =
        ppl_of(QuantMethod::Gptq(UniformConfig { bits: 2, group_size: 32, act_order: true }));
    let awq2 = ppl_of(QuantMethod::Awq(UniformConfig { bits: 2, group_size: 32, act_order: false }));
    eprintln!("2-bit ppl: bpdq={bpdq2:.3} gptq={gptq2:.3} awq={awq2:.3}");
    assert!(bpdq2 < gptq2, "BPDQ {bpdq2} !< GPTQ {gptq2}");
    assert!(gptq2 < awq2, "GPTQ {gptq2} !< AWQ {awq2}");
}
