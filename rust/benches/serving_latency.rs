//! End-to-end serving bench: router + batcher + engines — decode
//! latency and throughput per engine kind (the system half of Table 3),
//! including the batched-LUT scaling axis (max_batch 1/4/8 so the
//! fused-sweep amortization is visible in tok/s) and the GQA axis
//! (n_kv_heads 4 → 1 on the same tiny-LM: KV bytes shrink by exactly
//! n_heads / n_kv_heads while the fused attention sweep keeps parity)
//! and the quantized-KV axis (`kvq2` rows: W2 bit-plane KV strips with
//! fused-dequant attention — ~9× fewer KV bytes per session/token,
//! reported as real packed bytes in `kv_bytes_per_session` /
//! `kv_bytes_per_token`; the perf gate matches these rows separately
//! from the f32 rows via their `kv_bits` field).
//! Requests stream through the persistent iteration-level scheduler, so
//! TTFT here is the real first-token-event latency and inter-token
//! latency (ITL) is the event-to-event gap. Emits `BENCH_decode.json`
//! (tokens/sec, TTFT p50/p95, ITL p50, sweep occupancy, KV bytes) for
//! the CI perf-trajectory artifact — the perf gate watches both
//! tokens/sec drops and TTFT p95 growth.
//!
//! A final Zipf prompt-popularity section replays the same request
//! sequence — prompts drawn Zipf(s=1.1) from a pool sharing a
//! page-aligned system stem — against a cold router and a
//! `--prefix-cache` router, asserts token parity, and emits
//! `zipf prefix …` rows (cache hit rate, shared-page ratio, borrowed
//! KV bytes, hit-vs-cold TTFT) for the perf gate's cache-hit axis.
use bpdq::benchkit::JsonReport;
use bpdq::io::tlm::TlmFile;
use bpdq::model::pipeline::quantize_model;
use bpdq::model::{synthetic_model, Model, ModelConfig};
use bpdq::quant::{BpdqConfig, QuantMethod};
use bpdq::rng::{Rng, Zipf};
use bpdq::serving::{EngineKind, KvFormat, LutModel, Router, RouterConfig, Strategy};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

/// BPDQ-quantize `model` and return (dequantized model, LUT engine
/// kind, packed records — reusable for format-variant LutModels).
fn quantize_for_lut(
    model: &Arc<Model>,
) -> (Arc<Model>, EngineKind, HashMap<String, bpdq::quant::packing::BitPlanePacked>) {
    let vocab = model.cfg.vocab_size;
    let calib: Vec<Vec<u32>> =
        (0..24).map(|i| (0..64).map(|t| ((t * 7 + i * 3) % vocab) as u32).collect()).collect();
    let qm = quantize_model(
        model,
        &calib,
        &QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 64, ..Default::default() }),
    )
    .unwrap();
    let qmodel = Arc::new(qm.model.clone());
    let packed: HashMap<_, _> = qm
        .packed
        .iter()
        .map(|(k, v)| (k.clone(), v.as_bit_planes().unwrap().clone()))
        .collect();
    let kind = EngineKind::Lut(LutModel::new(qmodel.clone(), packed.clone()).unwrap());
    (qmodel, kind, packed)
}

fn main() {
    let quick = std::env::var("BPDQ_BENCH_QUICK").is_ok();
    // Use the trained checkpoint when present, else synthetic weights.
    let model = match TlmFile::load(Path::new("artifacts/tiny_small.tlm")) {
        Ok(f) => Model::from_tlm(&f).unwrap(),
        Err(_) => synthetic_model(&ModelConfig::tiny_small(68), 7),
    };
    let model = Arc::new(model);
    // GQA variant of the same size: 4 query heads sharing 1 kv head — the
    // KV cache (and its bandwidth) is exactly 4× smaller.
    let gqa_model =
        Arc::new(synthetic_model(&ModelConfig::tiny_small(68).with_kv_heads(1), 7));
    let (qmodel, lut_kind, packed) = quantize_for_lut(&model);
    let (_gqa_q, gqa_lut_kind, _) = quantize_for_lut(&gqa_model);
    // Quantized-KV variant: same W2 weights (reuse the packed records —
    // the KV format does not affect weight quantization), but the arena
    // stores W2 bit-plane strips and attention runs the fused-dequant
    // kernels — the KV-bandwidth axis of the bench.
    let kvq_qmodel = Arc::new(qmodel.with_kv_format(KvFormat::bit_plane(2)));
    let kvq_lut_kind = EngineKind::Lut(LutModel::new(kvq_qmodel.clone(), packed).unwrap());

    let n_requests = if quick { 8 } else { 32 };
    let max_new = if quick { 4 } else { 12 };
    println!("\n================================================================");
    println!("BENCH serving_latency — {n_requests} requests × {max_new} new tokens");
    println!("================================================================");
    let runs: Vec<(&str, EngineKind, usize, &Arc<Model>)> = vec![
        ("native fp32 (fp16 role)", EngineKind::Native(model.clone()), 4, &model),
        ("native dequantized W2", EngineKind::Native(qmodel.clone()), 4, &qmodel),
        ("LUT bit-plane W2  B=1", lut_kind.clone(), 1, &qmodel),
        ("LUT bit-plane W2  B=4", lut_kind.clone(), 4, &qmodel),
        ("LUT bit-plane W2  B=8", lut_kind.clone(), 8, &qmodel),
        ("LUT W2 GQA kv=1   B=4", gqa_lut_kind.clone(), 4, &gqa_model),
        ("LUT W2 GQA kv=1   B=8", gqa_lut_kind.clone(), 8, &gqa_model),
        ("LUT W2 kvq2      B=4", kvq_lut_kind.clone(), 4, &kvq_qmodel),
        ("LUT W2 kvq2      B=8", kvq_lut_kind.clone(), 8, &kvq_qmodel),
    ];
    let mut report = JsonReport::new("serving_latency", "BENCH_decode.json");
    for (name, kind, max_batch, m) in runs {
        let router = Router::start(
            RouterConfig {
                n_workers: 1,
                max_batch,
                strategy: Strategy::LeastLoaded,
                ..Default::default()
            },
            |_| Ok(kind.clone()),
        )
        .unwrap();
        let streams: Vec<_> = (0..n_requests)
            .map(|i| router.submit((0..12).map(|t| ((t + i) % 68) as u32).collect(), max_new))
            .collect();
        for s in streams {
            s.collect().unwrap();
        }
        let s = router.metrics.summary();
        let kv_bytes = m.kv_bytes_per_session();
        let kv_bits = match m.cfg.kv_format {
            KvFormat::F32 => 0usize,
            KvFormat::BitPlane { bits, .. } => bits,
        };
        println!(
            "{name:<26} TTFT p50 {:>7.2} ms p95 {:>7.2} ms   ITL p50 {:>6.2} ms   \
             decode {:>8.1} µs/tok   {:>7.1} tok/s   decode sweeps {:>5} (mean B {:.1}, max {})   \
             KV {:>8} B/session   arena high-water {} ({:.2} MiB slab)",
            s.p50_first_us as f64 / 1e3,
            s.p95_first_us as f64 / 1e3,
            s.p50_itl_us as f64 / 1e3,
            s.us_per_token,
            s.tokens_per_sec,
            s.decode_sweeps,
            s.mean_decode_batch,
            s.max_decode_batch,
            kv_bytes,
            s.arena_high_water,
            s.arena_bytes_resident as f64 / (1 << 20) as f64
        );
        let cfg = m.cfg;
        report.row(|w| {
            w.begin_object()
                .key("name")
                .string(name)
                .key("max_batch")
                .int(max_batch as i64)
                .key("n_heads")
                .int(cfg.n_heads as i64)
                .key("n_kv_heads")
                .int(cfg.n_kv_heads as i64)
                .key("kv_bits")
                .int(kv_bits as i64)
                .key("tokens_per_sec")
                .number(s.tokens_per_sec)
                .key("us_per_token")
                .number(s.us_per_token)
                .key("ttft_p50_us")
                .int(s.p50_first_us as i64)
                .key("ttft_p95_us")
                .int(s.p95_first_us as i64)
                .key("itl_p50_us")
                .int(s.p50_itl_us as i64)
                .key("itl_p95_us")
                .int(s.p95_itl_us as i64)
                .key("decode_sweeps")
                .int(s.decode_sweeps as i64)
                .key("mean_decode_batch")
                .number(s.mean_decode_batch)
                .key("max_decode_batch")
                .int(s.max_decode_batch as i64)
                .key("kv_bytes_per_session")
                .int(kv_bytes as i64)
                .key("kv_bytes_per_token")
                .int(m.kv_bytes_per_token() as i64)
                .key("arena_high_water")
                .int(s.arena_high_water as i64)
                .key("arena_bytes_resident")
                .int(s.arena_bytes_resident as i64)
                .key("arena_fork_copies")
                .int(s.arena_fork_copies as i64)
                .key("simd_tier")
                .string(s.simd_tier)
                .end_object();
        });
        router.shutdown();
    }

    // ------------------------------------------------------------------
    // Zipf prompt-popularity section — the prefix-cache axis. A pool of
    // prompts shares a 32-token system stem (exactly one default KV
    // page, so the cache shares it without a copy-on-write split) and
    // request popularity follows Zipf(s = 1.1): a few prompts dominate,
    // which is the regime where a radix prefix cache pays. The same
    // sampled sequence runs against a cold router (cache off) and a
    // warm router (cache on, stem published once up front); warm must
    // decode token-identically while prefilling only the un-cached
    // suffix. Rows carry cache hit rate, borrowed prompt tokens/bytes,
    // the mid-flight shared-page ratio, and TTFT — the perf gate reads
    // the warm rows' TTFT against these cold baselines.
    let zipf_reqs = if quick { 12 } else { 24 };
    let stem: Vec<u32> = (0..32).map(|t| ((t * 5 + 3) % 68) as u32).collect();
    let pool: Vec<Vec<u32>> = (0..6)
        .map(|i| {
            let mut p = stem.clone();
            p.extend([((20 + i * 7) % 68) as u32, ((11 + i * 13) % 68) as u32]);
            p
        })
        .collect();
    let zipf = Zipf::new(pool.len(), 1.1);
    let mut rng = Rng::new(0xB0D4);
    let picks: Vec<usize> = (0..zipf_reqs).map(|_| zipf.sample(&mut rng)).collect();
    println!(
        "\n---- Zipf prefix-cache section: {zipf_reqs} requests over {} prompts, \
         stem {} tokens ----",
        pool.len(),
        stem.len()
    );
    let variants: [(&str, &EngineKind, &Arc<Model>); 2] =
        [("zipf prefix f32", &lut_kind, &qmodel), ("zipf prefix kvq2", &kvq_lut_kind, &kvq_qmodel)];
    for (vname, vkind, vm) in variants {
        let mut cold_tokens: Vec<Vec<u32>> = Vec::new();
        for warm in [false, true] {
            let kind = vkind.clone();
            let router = Router::start(
                RouterConfig {
                    n_workers: 1,
                    max_batch: 4,
                    strategy: Strategy::LeastLoaded,
                    prefix_cache: warm,
                    ..Default::default()
                },
                move |_| Ok(kind.clone()),
            )
            .unwrap();
            if warm {
                // Publish the shared stem as its own radix node first:
                // lookups follow whole edges only, so every pool prompt
                // full-edge matches the stem instead of diverging
                // inside a longer first-request edge.
                router.submit(stem.clone(), 1).collect().unwrap();
            }
            let streams: Vec<_> =
                picks.iter().map(|&p| router.submit(pool[p].clone(), max_new)).collect();
            let mut tokens = Vec::with_capacity(streams.len());
            let mut mid = None;
            for (i, s) in streams.into_iter().enumerate() {
                tokens.push(s.collect().unwrap().tokens);
                if i == zipf_reqs / 2 {
                    // Mid-flight snapshot: later sessions are still
                    // borrowing stem pages, so shared-page counts are
                    // visible here (at drain every refcount is 1).
                    mid = Some(router.metrics.summary());
                }
            }
            let s = router.metrics.summary();
            router.shutdown();
            if warm {
                assert_eq!(
                    tokens, cold_tokens,
                    "{vname}: warm decode must be token-identical to cold"
                );
            } else {
                cold_tokens = tokens;
            }
            let mid = mid.unwrap_or_else(|| s.clone());
            let hit_rate = if s.prefix_lookups > 0 {
                s.prefix_hits as f64 / s.prefix_lookups as f64
            } else {
                0.0
            };
            let shared_ratio = if mid.arena_pages_in_use > 0 {
                mid.arena_pages_shared as f64 / mid.arena_pages_in_use as f64
            } else {
                0.0
            };
            let borrowed_tokens_per_session = s.prefix_hit_tokens as f64 / zipf_reqs as f64;
            let borrowed_bytes_per_session =
                (borrowed_tokens_per_session * vm.kv_bytes_per_token() as f64) as i64;
            let name = if warm { format!("{vname} warm") } else { format!("{vname} cold") };
            println!(
                "{name:<26} TTFT p50 {:>7.2} ms p95 {:>7.2} ms   hit rate {:>4.2} \
                 ({} tokens borrowed)   shared pages {}/{} mid-flight   COW copies {}",
                s.p50_first_us as f64 / 1e3,
                s.p95_first_us as f64 / 1e3,
                hit_rate,
                s.prefix_hit_tokens,
                mid.arena_pages_shared,
                mid.arena_pages_in_use,
                s.arena_cow_copies,
            );
            let cfg = vm.cfg;
            report.row(|w| {
                w.begin_object()
                    .key("name")
                    .string(&name)
                    .key("max_batch")
                    .int(4)
                    .key("n_heads")
                    .int(cfg.n_heads as i64)
                    .key("n_kv_heads")
                    .int(cfg.n_kv_heads as i64)
                    .key("kv_bits")
                    .int(match cfg.kv_format {
                        KvFormat::F32 => 0,
                        KvFormat::BitPlane { bits, .. } => bits as i64,
                    })
                    .key("tokens_per_sec")
                    .number(s.tokens_per_sec)
                    .key("us_per_token")
                    .number(s.us_per_token)
                    .key("ttft_p50_us")
                    .int(s.p50_first_us as i64)
                    .key("ttft_p95_us")
                    .int(s.p95_first_us as i64)
                    .key("itl_p50_us")
                    .int(s.p50_itl_us as i64)
                    .key("itl_p95_us")
                    .int(s.p95_itl_us as i64)
                    .key("cache_hit_rate")
                    .number(hit_rate)
                    .key("prefix_hit_tokens")
                    .int(s.prefix_hit_tokens as i64)
                    .key("shared_page_ratio")
                    .number(shared_ratio)
                    .key("arena_pages_shared")
                    .int(mid.arena_pages_shared as i64)
                    .key("arena_pages_in_use")
                    .int(mid.arena_pages_in_use as i64)
                    .key("arena_cow_copies")
                    .int(s.arena_cow_copies as i64)
                    .key("kv_bytes_per_session")
                    .int(vm.kv_bytes_per_session() as i64)
                    .key("kv_bytes_borrowed_per_session")
                    .int(borrowed_bytes_per_session)
                    .key("simd_tier")
                    .string(s.simd_tier)
                    .end_object();
            });
        }
    }
    // ------------------------------------------------------------------
    // Mixed long/short chunked-prefill section — the TTFT axis of
    // Sarathi-style scheduling. A bimodal workload (every 4th request
    // carries a 64-token prompt, the rest 8-token prompts) runs once
    // through a chunk-1 router and once through a chunked router
    // (chunk 8 under a 16-token sweep budget, so decodes claim their
    // tokens first and the long prefills fill the remainder). Both runs
    // must be token-identical; the rows carry short-request TTFT
    // percentiles (classified per stream, measured at the first token
    // event) so the perf gate can require that chunking keeps short
    // requests stall-free while long prompts prefill.
    let mixed_reqs = if quick { 12 } else { 24 };
    let mixed_new = if quick { 4 } else { 8 };
    let long_prompt: Vec<u32> = (0..64).map(|t| ((t * 3 + 5) % 68) as u32).collect();
    println!(
        "\n---- mixed prefill section: {mixed_reqs} requests (every 4th a {}-token prompt, \
         shorts 8 tokens) ----",
        long_prompt.len()
    );
    let pctl = |sorted: &[u64], q: f64| -> u64 {
        if sorted.is_empty() {
            return 0;
        }
        let i = ((sorted.len() - 1) as f64 * q).round() as usize;
        sorted[i.min(sorted.len() - 1)]
    };
    let mut unchunked_tokens: Vec<Vec<u32>> = Vec::new();
    for chunked in [false, true] {
        let kind = lut_kind.clone();
        let router = Router::start(
            RouterConfig {
                n_workers: 1,
                max_batch: 8,
                strategy: Strategy::LeastLoaded,
                prefill_chunk: if chunked { 8 } else { 1 },
                sweep_token_budget: if chunked { Some(16) } else { None },
                ..Default::default()
            },
            move |_| Ok(kind.clone()),
        )
        .unwrap();
        let prompts: Vec<Vec<u32>> = (0..mixed_reqs)
            .map(|i| {
                if i % 4 == 0 {
                    long_prompt.clone()
                } else {
                    (0..8).map(|t| ((t * 7 + i * 5 + 2) % 68) as u32).collect()
                }
            })
            .collect();
        let streams: Vec<_> =
            prompts.iter().map(|p| router.submit(p.clone(), mixed_new)).collect();
        let mut tokens = Vec::with_capacity(mixed_reqs);
        let mut short_ttft_us: Vec<u64> = Vec::new();
        for (i, s) in streams.into_iter().enumerate() {
            let r = s.collect().unwrap();
            if i % 4 != 0 {
                short_ttft_us.push(r.first_token_us);
            }
            tokens.push(r.tokens);
        }
        short_ttft_us.sort_unstable();
        let s = router.metrics.summary();
        router.shutdown();
        if chunked {
            assert_eq!(
                tokens, unchunked_tokens,
                "mixed prefill: chunked run must be token-identical to chunk 1"
            );
        } else {
            unchunked_tokens = tokens;
        }
        let name =
            if chunked { "mixed prefill chunked" } else { "mixed prefill unchunked" };
        println!(
            "{name:<26} TTFT p50 {:>7.2} ms p95 {:>7.2} ms   short TTFT p50 {:>7.2} ms \
             p95 {:>7.2} ms   prefill {:>7.1} tok/s   {:>7.1} tok/s",
            s.p50_first_us as f64 / 1e3,
            s.p95_first_us as f64 / 1e3,
            pctl(&short_ttft_us, 0.5) as f64 / 1e3,
            pctl(&short_ttft_us, 0.95) as f64 / 1e3,
            s.prefill_tokens_per_sec,
            s.tokens_per_sec,
        );
        report.row(|w| {
            w.begin_object()
                .key("name")
                .string(name)
                .key("max_batch")
                .int(8)
                .key("n_heads")
                .int(qmodel.cfg.n_heads as i64)
                .key("n_kv_heads")
                .int(qmodel.cfg.n_kv_heads as i64)
                .key("kv_bits")
                .int(0)
                .key("prefill_chunk")
                .int(if chunked { 8 } else { 1 })
                .key("tokens_per_sec")
                .number(s.tokens_per_sec)
                .key("us_per_token")
                .number(s.us_per_token)
                .key("ttft_p50_us")
                .int(s.p50_first_us as i64)
                .key("ttft_p95_us")
                .int(s.p95_first_us as i64)
                .key("itl_p50_us")
                .int(s.p50_itl_us as i64)
                .key("itl_p95_us")
                .int(s.p95_itl_us as i64)
                .key("short_ttft_p50_us")
                .int(pctl(&short_ttft_us, 0.5) as i64)
                .key("short_ttft_p95_us")
                .int(pctl(&short_ttft_us, 0.95) as i64)
                .key("prefill_p50_us")
                .int(s.p50_prefill_us as i64)
                .key("prefill_p95_us")
                .int(s.p95_prefill_us as i64)
                .key("prefill_tokens_per_sec")
                .number(s.prefill_tokens_per_sec)
                .key("simd_tier")
                .string(s.simd_tier)
                .end_object();
        });
    }
    report.finish();
    println!("\nBENCH serving_latency done");
}
