//! End-to-end serving bench: router + batcher + engines — decode
//! latency and throughput per engine kind (the system half of Table 3),
//! including the batched-LUT scaling axis: the LUT engine is run at
//! max_batch 1 vs 8 so the fused-sweep amortization (mean decode batch,
//! reported from the engine metrics) is visible in tok/s.
use bpdq::io::tlm::TlmFile;
use bpdq::model::pipeline::quantize_model;
use bpdq::model::{synthetic_model, Model, ModelConfig};
use bpdq::quant::{BpdqConfig, QuantMethod};
use bpdq::serving::{EngineKind, LutModel, Router, RouterConfig, Strategy};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let quick = std::env::var("BPDQ_BENCH_QUICK").is_ok();
    // Use the trained checkpoint when present, else synthetic weights.
    let model = match TlmFile::load(Path::new("artifacts/tiny_small.tlm")) {
        Ok(f) => Model::from_tlm(&f).unwrap(),
        Err(_) => synthetic_model(&ModelConfig::tiny_small(68), 7),
    };
    let model = Arc::new(model);
    let calib: Vec<Vec<u32>> =
        (0..24).map(|i| (0..64).map(|t| ((t * 7 + i * 3) % 68) as u32).collect()).collect();
    let qm = quantize_model(
        &model,
        &calib,
        &QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 64, ..Default::default() }),
    )
    .unwrap();
    let qmodel = Arc::new(qm.model.clone());
    let packed: HashMap<_, _> = qm
        .packed
        .iter()
        .map(|(k, v)| (k.clone(), v.as_bit_planes().unwrap().clone()))
        .collect();
    let lut_kind =
        || EngineKind::Lut(LutModel::new(qmodel.clone(), packed.clone()).unwrap());

    let n_requests = if quick { 8 } else { 32 };
    let max_new = if quick { 4 } else { 12 };
    println!("\n================================================================");
    println!("BENCH serving_latency — {n_requests} requests × {max_new} new tokens");
    println!("================================================================");
    let runs: Vec<(&str, EngineKind, usize)> = vec![
        ("native fp32 (fp16 role)", EngineKind::Native(model.clone()), 4),
        ("native dequantized W2", EngineKind::Native(qmodel.clone()), 4),
        ("LUT bit-plane W2  B=1", lut_kind(), 1),
        ("LUT bit-plane W2  B=4", lut_kind(), 4),
        ("LUT bit-plane W2  B=8", lut_kind(), 8),
    ];
    for (name, kind, max_batch) in runs {
        let router = Router::start(
            RouterConfig {
                n_workers: 1,
                max_batch,
                batch_window: Duration::from_millis(1),
                strategy: Strategy::LeastLoaded,
            },
            |_| kind.clone(),
        )
        .unwrap();
        let rxs: Vec<_> = (0..n_requests)
            .map(|i| router.submit((0..12).map(|t| ((t + i) % 68) as u32).collect(), max_new))
            .collect();
        for (_, rx) in rxs {
            rx.recv().unwrap();
        }
        let s = router.metrics.summary();
        println!(
            "{name:<26} p50 first {:>8.2} ms   decode {:>8.1} µs/tok   {:>7.1} tok/s   \
             mean batch {:.1}   decode sweeps {:>5} (mean B {:.1}, max {})",
            s.p50_first_us as f64 / 1e3,
            s.us_per_token,
            s.tokens_per_sec,
            s.mean_batch,
            s.decode_sweeps,
            s.mean_decode_batch,
            s.max_decode_batch
        );
        router.shutdown();
    }
    println!("\nBENCH serving_latency done");
}
