//! `cargo bench --bench table3_efficiency` — regenerates paper Table 3
//! (quantization cost, model size, decode latency per engine, and
//! activation outlier statistics).
use bpdq::report::harness::{table3, HarnessCfg};

fn main() {
    // Default QUICK: the full sweep is the CLI path (`bpdq table*`, outputs
    // recorded in EXPERIMENTS.md); set BPDQ_BENCH_FULL=1 for the full run.
    let quick = std::env::var("BPDQ_BENCH_FULL").is_err();
    let cfg = HarnessCfg::new("artifacts/tiny_small.tlm", quick);
    if let Err(e) = table3(&cfg) {
        eprintln!("table3 bench failed: {e:#}");
        std::process::exit(1);
    }
}
