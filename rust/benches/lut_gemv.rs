//! Micro-bench: LUT-GEMV vs dequant-GEMV vs dense fp32 GEMV across
//! bit-widths — the kernel-level basis of Table 3's latency column.
//! Paper shape to verify: LUT latency ≈ flat in k; dequant grows with
//! k; LUT beats dequant at every k on memory-bound shapes.
use bpdq::benchkit::{bench, black_box, Bench};
use bpdq::lut::{dequant_gemv, lut_gemv, LutScratch};
use bpdq::quant::packing::{BitPlanePacked, PackedPlane};
use bpdq::rng::Rng;
use bpdq::tensor::{matvec, Matrix};

fn random_packed(seed: u64, d_out: usize, d_in: usize, g: usize, k: usize) -> BitPlanePacked {
    let mut rng = Rng::new(seed);
    let planes = (0..k)
        .map(|_| {
            let dense = Matrix::from_vec(
                d_out,
                d_in,
                (0..d_out * d_in).map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 }).collect(),
            );
            PackedPlane::pack(&dense)
        })
        .collect();
    let ng = d_in.div_ceil(g);
    let coeffs = (0..=k)
        .map(|_| Matrix::from_vec(d_out, ng, (0..d_out * ng).map(|_| rng.normal() as f32).collect()))
        .collect();
    BitPlanePacked { d_out, d_in, group_size: g, planes, coeffs, coeff_bits: 16 }
}

fn main() {
    let b = Bench::new("lut_gemv — kernel latency vs bit-width");
    for &(d_out, d_in) in &[(512usize, 512usize), (1024, 1024), (2048, 2048)] {
        b.section(&format!("shape {d_out}×{d_in}, g=64"));
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..d_in).map(|_| rng.normal() as f32).collect();
        let w = Matrix::from_vec(
            d_out,
            d_in,
            (0..d_out * d_in).map(|_| rng.normal() as f32).collect(),
        );
        let s = bench(|| {
            black_box(matvec(black_box(&w), black_box(&x)));
        });
        b.row_time("dense fp32 GEMV (fp16-role baseline)", &s);
        for k in [2usize, 3, 4] {
            let packed = random_packed(k as u64, d_out, d_in, 64, k);
            let mut scratch = LutScratch::default();
            let mut y = vec![0.0f32; d_out];
            let s = bench(|| {
                lut_gemv(black_box(&packed), black_box(&x), &mut y, &mut scratch);
                black_box(&y);
            });
            b.row_time(&format!("LUT-GEMV      k={k}"), &s);
            let s = bench(|| {
                black_box(dequant_gemv(black_box(&packed), black_box(&x)));
            });
            b.row_time(&format!("dequant-GEMV  k={k}"), &s);
        }
    }
    b.finish();
}
