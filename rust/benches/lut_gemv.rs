//! Micro-bench: LUT-GEMV vs dequant-GEMV vs dense fp32 GEMV across
//! bit-widths — the kernel-level basis of Table 3's latency column —
//! plus the batched-decode comparison: one `lut_gemm` over B activation
//! vectors vs B independent `lut_gemv` calls on the tiny-LM shapes.
//! Paper shape to verify: LUT latency ≈ flat in k; dequant grows with
//! k; LUT beats dequant at every k on memory-bound shapes; and batched
//! GEMM amortizes the weight fetch so per-token cost falls as B grows
//! (target: ≥2× over independent GEMVs at B=8).
use bpdq::benchkit::{bench, black_box, Bench, JsonReport};
use bpdq::lut::{dequant_gemv, lut_gemm, lut_gemv, LutScratch};
use bpdq::model::{attend_head, softmax};
use bpdq::quant::packing::{BitPlanePacked, PackedPlane};
use bpdq::rng::Rng;
use bpdq::tensor::{
    matvec, strip_axpys, strip_axpys_packed, strip_dots, strip_dots_packed, Matrix, PackedGeom,
    PackedStrip, PackedStripMut, SimdScratch,
};

fn random_packed(seed: u64, d_out: usize, d_in: usize, g: usize, k: usize) -> BitPlanePacked {
    let mut rng = Rng::new(seed);
    let planes = (0..k)
        .map(|_| {
            let dense = Matrix::from_vec(
                d_out,
                d_in,
                (0..d_out * d_in).map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 }).collect(),
            );
            PackedPlane::pack(&dense)
        })
        .collect();
    let ng = d_in.div_ceil(g);
    let coeffs = (0..=k)
        .map(|_| Matrix::from_vec(d_out, ng, (0..d_out * ng).map(|_| rng.normal() as f32).collect()))
        .collect();
    BitPlanePacked { d_out, d_in, group_size: g, planes, coeffs, coeff_bits: 16 }
}

fn main() {
    let b = Bench::new("lut_gemv — kernel latency vs bit-width, GEMV vs batched GEMM");
    for &(d_out, d_in) in &[(512usize, 512usize), (1024, 1024), (2048, 2048)] {
        b.section(&format!("shape {d_out}×{d_in}, g=64"));
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..d_in).map(|_| rng.normal() as f32).collect();
        let w = Matrix::from_vec(
            d_out,
            d_in,
            (0..d_out * d_in).map(|_| rng.normal() as f32).collect(),
        );
        let s = bench(|| {
            black_box(matvec(black_box(&w), black_box(&x)));
        });
        b.row_time("dense fp32 GEMV (fp16-role baseline)", &s);
        for k in [2usize, 3, 4] {
            let packed = random_packed(k as u64, d_out, d_in, 64, k);
            let mut scratch = LutScratch::default();
            let mut y = vec![0.0f32; d_out];
            let s = bench(|| {
                lut_gemv(black_box(&packed), black_box(&x), &mut y, &mut scratch);
                black_box(&y);
            });
            b.row_time(&format!("LUT-GEMV      k={k}"), &s);
            let s = bench(|| {
                black_box(dequant_gemv(black_box(&packed), black_box(&x)));
            });
            b.row_time(&format!("dequant-GEMV  k={k}"), &s);
        }
    }

    // Batched decode: one fused lut_gemm over B activation vectors vs B
    // independent lut_gemv calls. Shapes are the tiny-LM block linears
    // (d_model=128, d_ff=344) plus one larger square; the fused kernel
    // gathers each row's plane words once per step instead of B times.
    b.section("batched decode — lut_gemm vs B × lut_gemv (tiny-LM shapes, k=2, g=64)");
    let simd_tier = bpdq::tensor::simd::active().label();
    let mut report = JsonReport::new("lut_gemv", "BENCH_lut_gemv.json");
    for &(d_out, d_in) in &[(128usize, 128usize), (344, 128), (128, 344), (512, 512)] {
        let packed = random_packed(7 + d_out as u64, d_out, d_in, 64, 2);
        let mut rng = Rng::new(11);
        for &bsz in &[1usize, 2, 4, 8, 16] {
            let xs: Vec<Vec<f32>> = (0..bsz)
                .map(|_| (0..d_in).map(|_| rng.normal() as f32).collect())
                .collect();
            let xrefs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let mut ys: Vec<Vec<f32>> = vec![vec![0.0f32; d_out]; bsz];
            let mut scratch = LutScratch::default();
            let s_gemm = bench(|| {
                let mut yrefs: Vec<&mut [f32]> =
                    ys.iter_mut().map(|y| y.as_mut_slice()).collect();
                lut_gemm(black_box(&packed), black_box(&xrefs), &mut yrefs, &mut scratch);
                black_box(&ys);
            });
            let mut y1 = vec![0.0f32; d_out];
            let mut scratch1 = LutScratch::default();
            let s_gemv = bench(|| {
                for x in &xrefs {
                    lut_gemv(black_box(&packed), black_box(x), &mut y1, &mut scratch1);
                }
                black_box(&y1);
            });
            let gemm_tok = s_gemm.per_iter_us() / bsz as f64;
            let gemv_tok = s_gemv.per_iter_us() / bsz as f64;
            b.row_metric(
                &format!("{d_out}×{d_in}  B={bsz:<2} lut_gemm"),
                &format!(
                    "{gemm_tok:>8.2} µs/tok   B×lut_gemv {gemv_tok:>8.2} µs/tok   speedup ×{:.2}",
                    gemv_tok / gemm_tok
                ),
            );
            report.row(|w| {
                w.begin_object()
                    .key("d_out")
                    .int(d_out as i64)
                    .key("d_in")
                    .int(d_in as i64)
                    .key("batch")
                    .int(bsz as i64)
                    .key("gemm_us_per_tok")
                    .number(gemm_tok)
                    .key("gemv_us_per_tok")
                    .number(gemv_tok)
                    .key("speedup")
                    .number(gemv_tok / gemm_tok)
                    .key("simd_tier")
                    .string(simd_tier)
                    .end_object();
            });
        }
    }
    report.finish();

    // Batched attention: the fused sweep's score/softmax/AV phase as one
    // multi-session pass over B *adjacent* strips of one slab
    // (strip_dots/strip_axpys — the KV-arena access pattern) vs B
    // independent attend_head walks over B scattered allocations (the
    // pre-arena per-session path).
    b.section("batched attention — strip kernels (one slab) vs B walks (hd=64, 256 pos)");
    let (hd, live) = (64usize, 256usize);
    let scale = 1.0 / (hd as f32).sqrt();
    let mut rng = Rng::new(23);
    for &bsz in &[2usize, 4, 8] {
        // arena-style: B strips adjacent in one slab
        let kslab: Vec<f32> = (0..bsz * live * hd).map(|_| rng.normal() as f32).collect();
        let vslab: Vec<f32> = (0..bsz * live * hd).map(|_| rng.normal() as f32).collect();
        // per-session-style: B scattered heap allocations of the same data
        let kseps: Vec<Vec<f32>> =
            kslab.chunks_exact(live * hd).map(|c| c.to_vec()).collect();
        let vseps: Vec<Vec<f32>> =
            vslab.chunks_exact(live * hd).map(|c| c.to_vec()).collect();
        let qflat: Vec<f32> = (0..bsz * hd).map(|_| rng.normal() as f32).collect();
        let mut scores = vec![0.0f32; bsz * live];
        let mut outs_flat = vec![0.0f32; bsz * hd];
        let s_batched = bench(|| {
            let kstrips: Vec<&[f32]> = kslab.chunks_exact(live * hd).collect();
            let vstrips: Vec<&[f32]> = vslab.chunks_exact(live * hd).collect();
            let qs: Vec<&[f32]> = qflat.chunks_exact(hd).collect();
            strip_dots(&qs, &kstrips, hd, scale, &mut scores);
            for sc in scores.chunks_exact_mut(live) {
                softmax(sc);
            }
            outs_flat.iter_mut().for_each(|o| *o = 0.0);
            let mut outs: Vec<&mut [f32]> = outs_flat.chunks_exact_mut(hd).collect();
            strip_axpys(&scores, &vstrips, hd, &mut outs);
            black_box(&outs_flat);
        });
        let mut score1 = vec![0.0f32; live];
        let s_per_session = bench(|| {
            outs_flat.iter_mut().for_each(|o| *o = 0.0);
            for (bb, (ks, vs)) in kseps.iter().zip(&vseps).enumerate() {
                attend_head(
                    black_box(&qflat[bb * hd..(bb + 1) * hd]),
                    ks,
                    vs,
                    scale,
                    &mut score1,
                    &mut outs_flat[bb * hd..(bb + 1) * hd],
                );
            }
            black_box(&outs_flat);
        });
        let bt = s_batched.per_iter_us() / bsz as f64;
        let pt = s_per_session.per_iter_us() / bsz as f64;
        b.row_metric(
            &format!("B={bsz:<2} batched strips"),
            &format!(
                "{bt:>8.2} µs/session   per-session walks {pt:>8.2} µs/session   ratio ×{:.2}",
                pt / bt
            ),
        );
    }
    // Packed-KV strip attention: the same score/softmax/AV phase over
    // bit-plane KV strips (fused dequant — strip_dots_packed /
    // strip_axpys_packed) vs f32 strips. The packed walk does more ALU
    // work per position but streams ~9× fewer bytes (W2) — on the
    // memory-bound serving shapes the bytes are what saturate first.
    b.section("packed-KV attention — bit-plane strips vs f32 strips (hd=64, 256 pos, B=4)");
    let bsz = 4usize;
    let f32_strip_bytes = live * hd * 4;
    for &bits in &[2usize, 3, 4] {
        let geom = PackedGeom::new(live, hd, bits, 32);
        let mut words: Vec<Vec<u32>> = vec![vec![0u32; geom.strip_words()]; 2 * bsz];
        let rows: Vec<Vec<f32>> =
            (0..live).map(|_| (0..hd).map(|_| rng.normal() as f32).collect()).collect();
        for w in words.iter_mut() {
            let mut strip = PackedStripMut::new(geom, w);
            for (u, row) in rows.iter().enumerate() {
                strip.store_row(u, row);
            }
        }
        let (kwords, vwords) = words.split_at(bsz);
        let qflat: Vec<f32> = (0..bsz * hd).map(|_| rng.normal() as f32).collect();
        let mut scores = vec![0.0f32; bsz * live];
        let mut outs_flat = vec![0.0f32; bsz * hd];
        let mut simd = SimdScratch::default();
        let s_packed = bench(|| {
            let kstrips: Vec<PackedStrip> =
                kwords.iter().map(|w| PackedStrip::new(geom, w)).collect();
            let vstrips: Vec<PackedStrip> =
                vwords.iter().map(|w| PackedStrip::new(geom, w)).collect();
            let qs: Vec<&[f32]> = qflat.chunks_exact(hd).collect();
            strip_dots_packed(&qs, &kstrips, live, scale, &mut scores, &mut simd);
            for sc in scores.chunks_exact_mut(live) {
                softmax(sc);
            }
            outs_flat.iter_mut().for_each(|o| *o = 0.0);
            let mut outs: Vec<&mut [f32]> = outs_flat.chunks_exact_mut(hd).collect();
            strip_axpys_packed(&scores, &vstrips, live, &mut outs);
            black_box(&outs_flat);
        });
        // f32 baseline over the same shape (built once above for B=4 is
        // a different buffer; rebuild here so both sides are warm).
        let kslab: Vec<f32> = (0..bsz * live * hd).map(|_| rng.normal() as f32).collect();
        let vslab: Vec<f32> = (0..bsz * live * hd).map(|_| rng.normal() as f32).collect();
        let s_f32 = bench(|| {
            let kstrips: Vec<&[f32]> = kslab.chunks_exact(live * hd).collect();
            let vstrips: Vec<&[f32]> = vslab.chunks_exact(live * hd).collect();
            let qs: Vec<&[f32]> = qflat.chunks_exact(hd).collect();
            strip_dots(&qs, &kstrips, hd, scale, &mut scores);
            for sc in scores.chunks_exact_mut(live) {
                softmax(sc);
            }
            outs_flat.iter_mut().for_each(|o| *o = 0.0);
            let mut outs: Vec<&mut [f32]> = outs_flat.chunks_exact_mut(hd).collect();
            strip_axpys(&scores, &vstrips, hd, &mut outs);
            black_box(&outs_flat);
        });
        let pk = s_packed.per_iter_us() / bsz as f64;
        let f3 = s_f32.per_iter_us() / bsz as f64;
        let packed_bytes = geom.strip_words() * 4;
        b.row_metric(
            &format!("W{bits} packed strips"),
            &format!(
                "{pk:>8.2} µs/session   f32 strips {f3:>8.2} µs/session   time ×{:.2}   bytes/strip {packed_bytes} vs {f32_strip_bytes} (×{:.1} smaller)",
                pk / f3,
                f32_strip_bytes as f64 / packed_bytes as f64
            ),
        );
    }
    b.finish();
}
