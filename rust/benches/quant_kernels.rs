//! Micro-bench: quantizer throughput per method on one linear layer —
//! the basis of Table 2/3's quantization-cost columns (GPTQ 1×,
//! BPDQ ≈3×, VPTQ ≫).
use bpdq::benchkit::{bench_with, Bench, Options};
use bpdq::quant::{
    quantize_linear, BcqConfig, BpdqConfig, QuantMethod, UniformConfig, VqConfig,
};
use bpdq::rng::Rng;
use bpdq::tensor::Matrix;
use std::time::Duration;

fn main() {
    let b = Bench::new("quant_kernels — per-layer quantization cost");
    let (d_out, d_in, n) = (128usize, 128usize, 256usize);
    let mut rng = Rng::new(3);
    let w = Matrix::from_vec(
        d_out,
        d_in,
        (0..d_out * d_in).map(|_| 0.1 * rng.student_t(5.0) as f32).collect(),
    );
    let x = Matrix::from_vec(n, d_in, (0..n * d_in).map(|_| rng.normal() as f32).collect());

    let opts = Options {
        warmup: Duration::from_millis(50),
        target_time: Duration::from_millis(400),
        max_iters: 50,
        min_iters: 3,
    };
    let methods = vec![
        QuantMethod::Rtn(UniformConfig { bits: 2, group_size: 64, act_order: false }),
        QuantMethod::Gptq(UniformConfig { bits: 2, group_size: 64, act_order: true }),
        QuantMethod::Awq(UniformConfig { bits: 2, group_size: 64, act_order: false }),
        QuantMethod::AnyBcq(BcqConfig { bits: 2, group_size: 64, alt_iters: 6 }),
        QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 64, ..Default::default() }),
        QuantMethod::Vptq(VqConfig { bits: 2, ..Default::default() }),
    ];
    b.section(&format!("layer {d_out}×{d_in}, {n} calib rows"));
    let mut gptq_us = None;
    for m in methods {
        let mut keep = None;
        let s = bench_with(opts, &mut || {
            keep = Some(quantize_linear(&w, &x, m.clone()).unwrap());
        });
        if m.name().starts_with("GPTQ") {
            gptq_us = Some(s.per_iter_us());
        }
        let ratio = gptq_us.map(|g| s.per_iter_us() / g).unwrap_or(f64::NAN);
        b.row_time(&format!("{:<16} ({ratio:.1}× GPTQ)", m.name()), &s);
    }
    b.finish();
}
