//! Per-kernel-family SIMD dispatch bench: every decode-hot kernel at
//! the scalar tier vs the detected SIMD tier, same inputs, same shapes.
//! Emits `BENCH_kernels.json` — one row per (family, kv_bits, tier) —
//! the artifact the CI perf gate tracks for per-family regressions.
//!
//! Families:
//!   - `lut_gemm`           batched LUT-GEMM weight kernel (k = kv_bits)
//!   - `packed_strip_dots`  table-driven bit-plane QK^T scores
//!   - `packed_strip_axpys` masked-blend bit-plane AV accumulate
//!   - `packed_attn`        dots + softmax + axpys fused phase
//!   - `f32_strip_dots` / `f32_strip_axpys` the f32 KV twins (kv_bits 0)
//!   - `rmsnorm` / `softmax` the per-step epilogues (kv_bits 0)
//!
//! The headline acceptance shape is `packed_attn` at len=512: the
//! table-driven path replaces the serial per-bit `m &= m-1` walk with
//! eight independent 256-entry lookups per plane row.
use bpdq::benchkit::{bench, black_box, Bench, JsonReport};
use bpdq::lut::{lut_gemm_with_tier, LutScratch};
use bpdq::quant::packing::{BitPlanePacked, PackedPlane};
use bpdq::rng::Rng;
use bpdq::tensor::simd::{
    rmsnorm_t, softmax_t, strip_axpys_packed_t, strip_axpys_t, strip_dots_packed_t, strip_dots_t,
};
use bpdq::tensor::{Matrix, PackedGeom, PackedStrip, PackedStripMut, SimdScratch, SimdTier};

const LEN: usize = 512; // KV positions — the acceptance shape (len ≥ 512)
const HD: usize = 64; // head dim
const B: usize = 4; // batch lanes
const D: usize = 512; // epilogue vector width

fn random_packed(seed: u64, d_out: usize, d_in: usize, g: usize, k: usize) -> BitPlanePacked {
    let mut rng = Rng::new(seed);
    let planes = (0..k)
        .map(|_| {
            let dense = Matrix::from_vec(
                d_out,
                d_in,
                (0..d_out * d_in).map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 }).collect(),
            );
            PackedPlane::pack(&dense)
        })
        .collect();
    let ng = d_in.div_ceil(g);
    let coeffs = (0..=k)
        .map(|_| Matrix::from_vec(d_out, ng, (0..d_out * ng).map(|_| rng.normal() as f32).collect()))
        .collect();
    BitPlanePacked { d_out, d_in, group_size: g, planes, coeffs, coeff_bits: 16 }
}

/// One benchmarked row: runs `f` at `tier`, prints it, records it in
/// the report, and returns µs/iter so callers can compute speedups.
#[allow(clippy::too_many_arguments)]
fn run_row(
    b: &Bench,
    report: &mut JsonReport,
    family: &str,
    kv_bits: usize,
    tier: SimdTier,
    scalar_us: Option<f64>,
    mut f: impl FnMut(),
) -> f64 {
    let s = bench(&mut f);
    let us = s.per_iter_us();
    let speedup = scalar_us.map_or(1.0, |base| base / us);
    b.row_metric(
        &format!("{family:<18} kv_bits={kv_bits} {:<6}", tier.label()),
        &format!("{us:>9.2} µs/iter   ×{speedup:.2} vs scalar"),
    );
    report.row(|w| {
        w.begin_object()
            .key("family")
            .string(family)
            .key("kv_bits")
            .int(kv_bits as i64)
            .key("tier")
            .string(tier.label())
            .key("us_per_iter")
            .number(us)
            .key("speedup_vs_scalar")
            .number(speedup)
            .end_object();
    });
    us
}

fn main() {
    let detected = SimdTier::detect();
    let tiers: Vec<SimdTier> = if detected == SimdTier::Scalar {
        vec![SimdTier::Scalar]
    } else {
        vec![SimdTier::Scalar, detected]
    };
    let b = Bench::new(&format!(
        "kernels — per-family scalar vs SIMD dispatch (detected tier: {})",
        detected.label()
    ));
    let mut report = JsonReport::new("kernels", "BENCH_kernels.json");
    let mut rng = Rng::new(41);

    // --- packed KV families, per bit-width -----------------------------
    for &bits in &[2usize, 3, 4] {
        b.section(&format!("packed KV strips — W{bits}, len={LEN}, hd={HD}, B={B}"));
        let geom = PackedGeom::new(LEN, HD, bits, 32);
        let mut words: Vec<Vec<u32>> = vec![vec![0u32; geom.strip_words()]; 2 * B];
        let rows: Vec<Vec<f32>> =
            (0..LEN).map(|_| (0..HD).map(|_| rng.normal() as f32).collect()).collect();
        for w in words.iter_mut() {
            let mut strip = PackedStripMut::new(geom, w);
            for (u, row) in rows.iter().enumerate() {
                strip.store_row(u, row);
            }
        }
        let (kwords, vwords) = words.split_at(B);
        let kstrips: Vec<PackedStrip> = kwords.iter().map(|w| PackedStrip::new(geom, w)).collect();
        let vstrips: Vec<PackedStrip> = vwords.iter().map(|w| PackedStrip::new(geom, w)).collect();
        let qflat: Vec<f32> = (0..B * HD).map(|_| rng.normal() as f32).collect();
        let scale = 1.0 / (HD as f32).sqrt();
        let mut scores = vec![0.0f32; B * LEN];
        let mut outs_flat = vec![0.0f32; B * HD];
        let mut simd = SimdScratch::default();

        // scores for the axpys family: realistic softmax weights
        let mut ws = vec![0.0f32; B * LEN];
        {
            let qs: Vec<&[f32]> = qflat.chunks_exact(HD).collect();
            strip_dots_packed_t(SimdTier::Scalar, &qs, &kstrips, LEN, scale, &mut ws, &mut simd);
            for sc in ws.chunks_exact_mut(LEN) {
                softmax_t(SimdTier::Scalar, sc);
            }
        }

        let mut base = [0.0f64; 3]; // per-family scalar µs
        for &tier in &tiers {
            let sc = if tier == SimdTier::Scalar { None } else { Some(base[0]) };
            base[0] = run_row(&b, &mut report, "packed_strip_dots", bits, tier, sc, || {
                let qs: Vec<&[f32]> = qflat.chunks_exact(HD).collect();
                strip_dots_packed_t(tier, &qs, &kstrips, LEN, scale, &mut scores, &mut simd);
                black_box(&scores);
            });
            let sc = if tier == SimdTier::Scalar { None } else { Some(base[1]) };
            base[1] = run_row(&b, &mut report, "packed_strip_axpys", bits, tier, sc, || {
                outs_flat.iter_mut().for_each(|o| *o = 0.0);
                let mut outs: Vec<&mut [f32]> = outs_flat.chunks_exact_mut(HD).collect();
                strip_axpys_packed_t(tier, &ws, &vstrips, LEN, &mut outs);
                black_box(&outs_flat);
            });
            let sc = if tier == SimdTier::Scalar { None } else { Some(base[2]) };
            base[2] = run_row(&b, &mut report, "packed_attn", bits, tier, sc, || {
                let qs: Vec<&[f32]> = qflat.chunks_exact(HD).collect();
                strip_dots_packed_t(tier, &qs, &kstrips, LEN, scale, &mut scores, &mut simd);
                for sc in scores.chunks_exact_mut(LEN) {
                    softmax_t(tier, sc);
                }
                outs_flat.iter_mut().for_each(|o| *o = 0.0);
                let mut outs: Vec<&mut [f32]> = outs_flat.chunks_exact_mut(HD).collect();
                strip_axpys_packed_t(tier, &scores, &vstrips, LEN, &mut outs);
                black_box(&outs_flat);
            });
        }
    }

    // --- LUT-GEMM weight kernel, per bit-width -------------------------
    for &k in &[2usize, 3, 4] {
        b.section(&format!("lut_gemm — 512×512, k={k}, g=64, B={B}"));
        let packed = random_packed(17 + k as u64, 512, 512, 64, k);
        let xs: Vec<Vec<f32>> =
            (0..B).map(|_| (0..512).map(|_| rng.normal() as f32).collect()).collect();
        let xrefs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let mut ys: Vec<Vec<f32>> = vec![vec![0.0f32; 512]; B];
        let mut scratch = LutScratch::default();
        let mut scalar_us = None;
        for &tier in &tiers {
            let us = run_row(&b, &mut report, "lut_gemm", k, tier, scalar_us, || {
                let mut yrefs: Vec<&mut [f32]> = ys.iter_mut().map(|y| y.as_mut_slice()).collect();
                lut_gemm_with_tier(tier, black_box(&packed), &xrefs, &mut yrefs, &mut scratch);
                black_box(&ys);
            });
            scalar_us.get_or_insert(us);
        }
    }

    // --- f32 KV strip twins --------------------------------------------
    b.section(&format!("f32 KV strips — len={LEN}, hd={HD}, B={B}"));
    let kslab: Vec<f32> = (0..B * LEN * HD).map(|_| rng.normal() as f32).collect();
    let vslab: Vec<f32> = (0..B * LEN * HD).map(|_| rng.normal() as f32).collect();
    let qflat: Vec<f32> = (0..B * HD).map(|_| rng.normal() as f32).collect();
    let scale = 1.0 / (HD as f32).sqrt();
    let mut scores = vec![0.0f32; B * LEN];
    let mut outs_flat = vec![0.0f32; B * HD];
    let mut ws = vec![0.0f32; B * LEN];
    {
        let kstrips: Vec<&[f32]> = kslab.chunks_exact(LEN * HD).collect();
        let qs: Vec<&[f32]> = qflat.chunks_exact(HD).collect();
        strip_dots_t(SimdTier::Scalar, &qs, &kstrips, HD, scale, &mut ws);
        for sc in ws.chunks_exact_mut(LEN) {
            softmax_t(SimdTier::Scalar, sc);
        }
    }
    let mut base = [0.0f64; 2];
    for &tier in &tiers {
        let sc = if tier == SimdTier::Scalar { None } else { Some(base[0]) };
        base[0] = run_row(&b, &mut report, "f32_strip_dots", 0, tier, sc, || {
            let kstrips: Vec<&[f32]> = kslab.chunks_exact(LEN * HD).collect();
            let qs: Vec<&[f32]> = qflat.chunks_exact(HD).collect();
            strip_dots_t(tier, &qs, &kstrips, HD, scale, &mut scores);
            black_box(&scores);
        });
        let sc = if tier == SimdTier::Scalar { None } else { Some(base[1]) };
        base[1] = run_row(&b, &mut report, "f32_strip_axpys", 0, tier, sc, || {
            let vstrips: Vec<&[f32]> = vslab.chunks_exact(LEN * HD).collect();
            outs_flat.iter_mut().for_each(|o| *o = 0.0);
            let mut outs: Vec<&mut [f32]> = outs_flat.chunks_exact_mut(HD).collect();
            strip_axpys_t(tier, &ws, &vstrips, HD, &mut outs);
            black_box(&outs_flat);
        });
    }

    // --- per-step epilogues --------------------------------------------
    b.section(&format!("epilogues — rmsnorm/softmax, d={D}"));
    let x: Vec<f32> = (0..D).map(|_| rng.normal() as f32).collect();
    let gain: Vec<f32> = (0..D).map(|_| 1.0 + 0.01 * rng.normal() as f32).collect();
    let mut out = vec![0.0f32; D];
    let logits: Vec<f32> = (0..D).map(|_| 4.0 * rng.normal() as f32).collect();
    let mut buf = vec![0.0f32; D];
    let mut base = [0.0f64; 2];
    for &tier in &tiers {
        let sc = if tier == SimdTier::Scalar { None } else { Some(base[0]) };
        base[0] = run_row(&b, &mut report, "rmsnorm", 0, tier, sc, || {
            rmsnorm_t(tier, black_box(&x), &gain, 1e-5, &mut out);
            black_box(&out);
        });
        let sc = if tier == SimdTier::Scalar { None } else { Some(base[1]) };
        base[1] = run_row(&b, &mut report, "softmax", 0, tier, sc, || {
            buf.copy_from_slice(&logits);
            softmax_t(tier, &mut buf);
            black_box(&buf);
        });
    }

    report.finish();
    b.finish();
}
