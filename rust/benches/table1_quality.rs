//! `cargo bench --bench table1_quality` — regenerates paper Table 1
//! (main quality sweep) on the synthetic substrate. Honors
//! BPDQ_BENCH_QUICK=1 for a fast smoke run.
use bpdq::report::harness::{table1, HarnessCfg};

fn main() {
    // Default QUICK: the full sweep is the CLI path (`bpdq table*`, outputs
    // recorded in EXPERIMENTS.md); set BPDQ_BENCH_FULL=1 for the full run.
    let quick = std::env::var("BPDQ_BENCH_FULL").is_err();
    let cfg = HarnessCfg::new("artifacts/tiny_small.tlm", quick);
    if let Err(e) = table1(&cfg) {
        eprintln!("table1 bench failed: {e:#}");
        std::process::exit(1);
    }
}
