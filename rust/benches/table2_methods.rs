//! `cargo bench --bench table2_methods` — regenerates paper Table 2
//! (bit-plane + VQ comparison incl. AnyBCQ and VPTQ, with SIZE and
//! quantization-cost ratios).
use bpdq::report::harness::{table2, HarnessCfg};

fn main() {
    // Default QUICK: the full sweep is the CLI path (`bpdq table*`, outputs
    // recorded in EXPERIMENTS.md); set BPDQ_BENCH_FULL=1 for the full run.
    let quick = std::env::var("BPDQ_BENCH_FULL").is_err();
    let cfg = HarnessCfg::new("artifacts/tiny_small.tlm", quick);
    if let Err(e) = table2(&cfg) {
        eprintln!("table2 bench failed: {e:#}");
        std::process::exit(1);
    }
}
