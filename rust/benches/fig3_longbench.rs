//! `cargo bench --bench fig3_longbench` — regenerates paper Figure 3
//! (long-context suite: passkey retrieval, summary, classification).
use bpdq::report::harness::{fig3, HarnessCfg};

fn main() {
    // Default QUICK: the full sweep is the CLI path (`bpdq table*`, outputs
    // recorded in EXPERIMENTS.md); set BPDQ_BENCH_FULL=1 for the full run.
    let quick = std::env::var("BPDQ_BENCH_FULL").is_err();
    let cfg = HarnessCfg::new("artifacts/tiny_small.tlm", quick);
    if let Err(e) = fig3(&cfg) {
        eprintln!("fig3 bench failed: {e:#}");
        std::process::exit(1);
    }
}
