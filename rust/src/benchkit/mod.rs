//! benchkit — the micro-benchmark harness behind `cargo bench`.
//!
//! criterion is not in the offline vendor set; this provides the subset we
//! rely on: warmup, repeated timed runs, and median / p95 / mean stats,
//! with black-box protection against the optimizer. Quality-table benches
//! (`table1_quality` etc.) use [`Bench::section`] for structured output
//! that mirrors the paper's tables row-for-row.

use crate::io::json::JsonWriter;
use std::hint::black_box as bb;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Re-export so benches don't import std::hint directly.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn per_iter_us(&self) -> f64 {
        self.median.as_secs_f64() * 1e6
    }
}

/// Time `f` with warmup; adaptive iteration count targeting `target_time`
/// total measurement.
pub fn bench<F: FnMut()>(mut f: F) -> Stats {
    bench_with(Options::default(), &mut f)
}

#[derive(Clone, Copy, Debug)]
pub struct Options {
    pub warmup: Duration,
    pub target_time: Duration,
    pub max_iters: usize,
    pub min_iters: usize,
}

impl Default for Options {
    fn default() -> Self {
        let quick = std::env::var("BPDQ_BENCH_QUICK").is_ok();
        Self {
            warmup: Duration::from_millis(if quick { 10 } else { 100 }),
            target_time: Duration::from_millis(if quick { 50 } else { 500 }),
            max_iters: 10_000,
            min_iters: 5,
        }
    }
}

pub fn bench_with<F: FnMut()>(opts: Options, f: &mut F) -> Stats {
    // Warmup + estimate per-iter cost.
    let warm_start = Instant::now();
    let mut warm_iters = 0usize;
    while warm_start.elapsed() < opts.warmup || warm_iters == 0 {
        f();
        warm_iters += 1;
        if warm_iters > opts.max_iters {
            break;
        }
    }
    let est = warm_start.elapsed() / warm_iters.max(1) as u32;
    let iters = ((opts.target_time.as_secs_f64() / est.as_secs_f64().max(1e-9)) as usize)
        .clamp(opts.min_iters, opts.max_iters);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    Stats {
        iters,
        mean,
        median: samples[samples.len() / 2],
        p95: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        min: samples[0],
    }
}

/// Structured bench output: named sections with rows, formatted as an
/// aligned text table (the cargo-bench stdout is the artifact).
pub struct Bench {
    name: String,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        println!("\n================================================================");
        println!("BENCH {name}");
        println!("================================================================");
        Self { name: name.to_string() }
    }

    pub fn section(&self, title: &str) {
        println!("\n--- {title} ---");
    }

    /// Print a timing row.
    pub fn row_time(&self, label: &str, s: &Stats) {
        println!(
            "{label:<44} median {:>10.2} µs   p95 {:>10.2} µs   ({} iters)",
            s.median.as_secs_f64() * 1e6,
            s.p95.as_secs_f64() * 1e6,
            s.iters
        );
    }

    /// Print a free-form metric row.
    pub fn row_metric(&self, label: &str, value: &str) {
        println!("{label:<44} {value}");
    }

    pub fn finish(self) {
        println!("\nBENCH {} done", self.name);
    }
}

/// Machine-readable bench artifact (`BENCH_*.json`): one object
/// `{"bench": <name>, "rows": [ ... ]}` written at [`JsonReport::finish`].
/// CI uploads these so the decode perf trajectory (tokens/sec, sweep
/// occupancy, KV bytes) is tracked per commit instead of scraped from
/// bench stdout.
pub struct JsonReport {
    w: JsonWriter,
    path: PathBuf,
}

impl JsonReport {
    pub fn new(bench: &str, path: &str) -> Self {
        let mut w = JsonWriter::new();
        w.begin_object().key("bench").string(bench).key("rows").begin_array();
        Self { w, path: PathBuf::from(path) }
    }

    /// Append one row: the closure writes a full JSON value (typically
    /// `begin_object() … end_object()`) into the open `rows` array.
    pub fn row<F: FnOnce(&mut JsonWriter)>(&mut self, f: F) -> &mut Self {
        f(&mut self.w);
        self
    }

    /// Close the document and write it; prints the path so the artifact
    /// is discoverable from bench stdout. Panics if the write fails —
    /// the file is the bench's contract with CI, and a silent miss would
    /// only surface one step later as a confusing upload-artifact error.
    pub fn finish(mut self) {
        self.w.end_array().end_object();
        let json = self.w.finish();
        std::fs::write(&self.path, &json)
            .unwrap_or_else(|e| panic!("failed to write {}: {e}", self.path.display()));
        println!("\nwrote {}", self.path.display());
    }
}

/// Format a duration human-readably.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        std::env::set_var("BPDQ_BENCH_QUICK", "1");
        let mut x = 0u64;
        let s = bench(|| {
            for i in 0..1000 {
                x = x.wrapping_add(black_box(i));
            }
        });
        assert!(s.iters >= 5);
        assert!(s.median <= s.p95);
        assert!(s.min <= s.median);
    }

    #[test]
    fn json_report_writes_valid_document() {
        let path = std::env::temp_dir().join("bpdq_bench_report_test.json");
        let mut rep = JsonReport::new("unit", path.to_str().unwrap());
        rep.row(|w| {
            w.begin_object().key("name").string("a").key("tok_s").number(12.5).end_object();
        });
        rep.row(|w| {
            w.begin_object().key("name").string("b").key("tok_s").number(0.0).end_object();
        });
        rep.finish();
        let got = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            got,
            r#"{"bench":"unit","rows":[{"name":"a","tok_s":12.5},{"name":"b","tok_s":0}]}"#
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fmt_duration_scales() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with(" ms"));
        assert!(fmt_duration(Duration::from_micros(7)).ends_with(" µs"));
    }
}
