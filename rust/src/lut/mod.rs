//! Native bit-plane LUT-GEMV/GEMM — the serving hot path (paper §4.3,
//! LUT-GEMM adapted to CPU lanes).
//!
//! For a BPDQ/BCQ-packed layer `Ŵ = REP(C₀) + Σᵢ REP(Cᵢ)⊙Bᵢ`:
//!
//! ```text
//! y_r = Σ_groups [ C₀[r,g]·S_g  +  Σᵢ Cᵢ[r,g] · (Bᵢ[r, g-cols] · x_g) ]
//! ```
//!
//! The binary dot products are evaluated through a subset-sum **LUT over
//! 8-wide activation chunks** (256 entries each, built in O(256) by Gray-
//! style incremental sums), so decode cost is independent of the weight
//! bit-width beyond the per-plane gather — the property that gives the
//! paper's flat W2/W3/W4 decode latency (Table 3).
//!
//! # Batched decode: why `lut_gemm`
//!
//! At batch size B the per-vector [`lut_gemv`] re-gathers every packed
//! plane word (and re-reads every coefficient) B times per decode step,
//! so batched decode is memory-bandwidth-bound on the *same weight bytes*
//! B times over. [`lut_gemm`] instead builds one subset-sum table per
//! activation vector (B tables, interleaved by chunk so the B entries for
//! one gathered byte sit in adjacent cache lines) and then walks each
//! row's plane words **once**, applying the gathered byte to all B LUTs
//! in the inner loop. The weight fetch — the dominant term for the
//! paper's memory-bound shapes, and exactly the term ABQ-LLM/SqueezeLLM
//! amortize on GPU — is thus paid once per step instead of B times,
//! driving per-token cost toward `1/B` of the weight-fetch bound.
//!
//! Groups need not be multiples of the 8-wide chunk: boundary chunks are
//! masked so each group only sums its own columns (this also fixes the
//! historical mis-stepping of the zero-coefficient skip for
//! `group_size % 8 != 0`).

use crate::quant::packing::BitPlanePacked;
use crate::tensor::Matrix;

/// Per-call workspace (reused across layers/tokens/batches to keep the
/// decode loop allocation-free).
#[derive(Default)]
pub struct LutScratch {
    lut: Vec<f32>,
    group_sums: Vec<f32>,
    acc: Vec<f32>,
    dot: Vec<f32>,
}

/// Build subset-sum tables for a batch of activation vectors, chunk-major
/// and batch-interleaved:
/// `lut[(c*B + b)*256 + p] = Σ_i xs[b][8c+i]·bit(p,i)`.
///
/// All vectors must share one length; entries past the end of a vector
/// contribute 0 (ragged final chunk).
// lint: hot
pub fn build_luts(xs: &[&[f32]], scratch: &mut LutScratch) {
    let nb = xs.len();
    let d = xs.first().map_or(0, |x| x.len());
    assert!(xs.iter().all(|x| x.len() == d), "batch vectors must share one length");
    let n_chunks = d.div_ceil(8);
    scratch.lut.resize(n_chunks * nb * 256, 0.0);
    for c in 0..n_chunks {
        for (b, x) in xs.iter().enumerate() {
            let base = (c * nb + b) * 256;
            let lut = &mut scratch.lut[base..base + 256];
            lut[0] = 0.0;
            // incremental: lut[p] = lut[p without lowest set bit] + x[bit]
            for p in 1usize..256 {
                let lsb = p & p.wrapping_neg();
                let bit = lsb.trailing_zeros() as usize;
                let xi = x.get(c * 8 + bit).copied().unwrap_or(0.0);
                lut[p] = lut[p ^ lsb] + xi;
            }
        }
    }
}

/// Build the subset-sum table for a single `x`:
/// `lut[c*256+p] = Σ_i x[8c+i]·bit(p,i)`.
pub fn build_lut(x: &[f32], scratch: &mut LutScratch) {
    build_luts(&[x], scratch);
}

/// Batched LUT-GEMM: `ys[b] = Ŵ xs[b]` for all `b` in one pass over the
/// packed record. Each row's plane words are gathered once and applied to
/// every activation's LUT — decode cost per token approaches `1/B` of the
/// weight-fetch bound as B grows.
// lint: hot
pub fn lut_gemm(
    packed: &BitPlanePacked,
    xs: &[&[f32]],
    ys: &mut [&mut [f32]],
    scratch: &mut LutScratch,
) {
    lut_gemm_with_tier(crate::tensor::simd::active(), packed, xs, ys, scratch);
}

/// [`lut_gemm`] with an explicit SIMD tier, for parity tests and benches
/// that need to force a tier regardless of the process-wide dispatch latch.
/// The tier only affects the per-chunk LUT gather; every accumulation is
/// per-lane and order-preserving, so all tiers are bit-identical.
// lint: hot
pub fn lut_gemm_with_tier(
    tier: crate::tensor::SimdTier,
    packed: &BitPlanePacked,
    xs: &[&[f32]],
    ys: &mut [&mut [f32]],
    scratch: &mut LutScratch,
) {
    let nb = xs.len();
    assert_eq!(ys.len(), nb, "xs/ys batch size mismatch");
    if nb == 0 {
        return;
    }
    for (x, y) in xs.iter().zip(ys.iter()) {
        assert_eq!(x.len(), packed.d_in);
        assert_eq!(y.len(), packed.d_out);
    }
    let g = packed.group_size;
    let ng = packed.n_groups();
    let k = packed.k();
    // Total byte-chunks is bounded by d_in (the packed words round up to
    // 32-bit granularity, so `words.len()*4` can overshoot by up to 3).
    let n_chunks = packed.d_in.div_ceil(8);

    build_luts(xs, scratch);
    let LutScratch { lut, group_sums, acc, dot } = scratch;

    // Group activation sums (bias term), batch-interleaved per group.
    group_sums.resize(ng * nb, 0.0);
    for grp in 0..ng {
        let c0 = grp * g;
        let c1 = (c0 + g).min(packed.d_in);
        for (b, x) in xs.iter().enumerate() {
            group_sums[grp * nb + b] = x[c0..c1].iter().sum();
        }
    }

    acc.resize(nb, 0.0);
    dot.resize(nb, 0.0);
    for r in 0..packed.d_out {
        acc.iter_mut().for_each(|a| *a = 0.0);
        // bias term: Σ_g c0[r,g] · S_g, for every batch lane
        let c0row = packed.coeffs[0].row(r);
        for (grp, &cv) in c0row.iter().enumerate() {
            if cv == 0.0 {
                continue;
            }
            let gs = &group_sums[grp * nb..(grp + 1) * nb];
            for (a, &s) in acc.iter_mut().zip(gs) {
                *a += cv * s;
            }
        }
        // plane terms via the LUTs
        for i in 0..k {
            let words = packed.planes[i].row_words(r);
            let crow = packed.coeffs[i + 1].row(r);
            for (grp, &cv) in crow.iter().enumerate() {
                if cv == 0.0 {
                    // Nothing to add; the chunk range below is derived
                    // from `grp`, so skipping is free (no running cursor
                    // to mis-step — the historical g%8≠0 bug).
                    continue;
                }
                let bit0 = grp * g;
                let bit1 = ((grp + 1) * g).min(packed.d_in);
                let c_start = bit0 / 8;
                let c_end = bit1.div_ceil(8).min(n_chunks);
                dot.iter_mut().for_each(|d| *d = 0.0);
                for chunk in c_start..c_end {
                    let mut byte = ((words[chunk / 4] >> (8 * (chunk % 4))) & 0xFF) as usize;
                    // Mask off columns belonging to neighbouring groups
                    // when a group boundary falls inside this chunk.
                    let lo = bit0.saturating_sub(chunk * 8);
                    let hi = (bit1 - chunk * 8).min(8);
                    if lo > 0 || hi < 8 {
                        byte &= ((1usize << hi) - 1) & !((1usize << lo) - 1);
                    }
                    let base = chunk * nb * 256;
                    let luts = &lut[base..base + nb * 256];
                    crate::tensor::simd::lut_gather_add(tier, luts, byte, dot);
                }
                for (a, &d) in acc.iter_mut().zip(dot.iter()) {
                    *a += cv * d;
                }
            }
        }
        for (y, &a) in ys.iter_mut().zip(acc.iter()) {
            y[r] = a;
        }
    }
}

/// y = Ŵ x for a packed record, using the LUT algorithm (batch-1 case of
/// [`lut_gemm`]; bit-identical to the batched path).
// lint: hot
pub fn lut_gemv(packed: &BitPlanePacked, x: &[f32], y: &mut [f32], scratch: &mut LutScratch) {
    lut_gemm(packed, &[x], &mut [y], scratch);
}

/// Reference: dequantize then dense matvec (the "Torch/Triton dequant"
/// baseline of Table 3).
pub fn dequant_gemv(packed: &BitPlanePacked, x: &[f32]) -> Vec<f32> {
    let w = packed.dequant();
    crate::tensor::matvec(&w, x)
}

/// fp32 dense matvec baseline (the fp16 row of Table 3; we compute in
/// f32 — CPU has no fp16 ALU — but charge fp16 bytes in size columns).
pub fn dense_gemv(w: &Matrix, x: &[f32]) -> Vec<f32> {
    crate::tensor::matvec(w, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::packing::PackedPlane;
    use crate::rng::Rng;

    fn random_packed(seed: u64, d_out: usize, d_in: usize, g: usize, k: usize) -> BitPlanePacked {
        let mut rng = Rng::new(seed);
        let planes = (0..k)
            .map(|_| {
                let dense = Matrix::from_vec(
                    d_out,
                    d_in,
                    (0..d_out * d_in).map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 }).collect(),
                );
                PackedPlane::pack(&dense)
            })
            .collect();
        let ng = d_in.div_ceil(g);
        let coeffs = (0..=k)
            .map(|_| {
                Matrix::from_vec(d_out, ng, (0..d_out * ng).map(|_| rng.normal() as f32).collect())
            })
            .collect();
        BitPlanePacked { d_out, d_in, group_size: g, planes, coeffs, coeff_bits: 16 }
    }

    fn assert_rows_close(got: &[f32], want: &[f32], tag: &str) {
        for (r, (&a, &b)) in got.iter().zip(want).enumerate() {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{tag} row {r}: {a} vs {b}");
        }
    }

    #[test]
    fn build_lut_subset_sums() {
        let x: Vec<f32> = (1..=8).map(|i| i as f32).collect();
        let mut s = LutScratch::default();
        build_lut(&x, &mut s);
        assert_eq!(s.lut[0], 0.0);
        assert_eq!(s.lut[0b1], 1.0);
        assert_eq!(s.lut[0b11], 3.0);
        assert_eq!(s.lut[0b10000000], 8.0);
        assert_eq!(s.lut[0xFF], 36.0);
        // random spot-check
        let p = 0b1010_0110usize;
        let want: f32 = (0..8).filter(|i| (p >> i) & 1 == 1).map(|i| x[i]).sum();
        assert_eq!(s.lut[p], want);
    }

    #[test]
    fn build_luts_interleaves_batches() {
        // Two vectors: chunk-major, batch-interleaved layout.
        let x0: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let x1: Vec<f32> = (0..16).map(|i| -(i as f32)).collect();
        let mut s = LutScratch::default();
        build_luts(&[&x0, &x1], &mut s);
        // chunk 0, batch 0, pattern 0b1 → x0[0] = 0; batch 1 → -0
        assert_eq!(s.lut[0b10], x0[1]);
        assert_eq!(s.lut[256 + 0b10], x1[1]);
        // chunk 1, batch 0 starts at (1*2+0)*256
        assert_eq!(s.lut[2 * 256 + 0b1], x0[8]);
        assert_eq!(s.lut[3 * 256 + 0b1], x1[8]);
    }

    #[test]
    fn lut_gemv_matches_dequant_gemv() {
        let mut rng = Rng::new(7);
        for &(d_out, d_in, g, k) in
            &[(4usize, 32usize, 8usize, 1usize), (8, 64, 16, 2), (16, 128, 64, 3), (5, 96, 32, 4)]
        {
            let packed = random_packed(d_out as u64, d_out, d_in, g, k);
            let x: Vec<f32> = (0..d_in).map(|_| rng.normal() as f32).collect();
            let want = dequant_gemv(&packed, &x);
            let mut got = vec![0.0f32; d_out];
            let mut scratch = LutScratch::default();
            lut_gemv(&packed, &x, &mut got, &mut scratch);
            assert_rows_close(&got, &want, &format!("({d_out},{d_in},{g},{k})"));
        }
    }

    #[test]
    fn group_size_not_multiple_of_8() {
        // Regression: the old zero-coefficient fast path advanced the
        // chunk cursor by g/8 (0 for g=4, 1 for g=12), corrupting every
        // later group; and even the nonzero path summed whole chunks that
        // straddle group boundaries. Both must now agree with dequant.
        let mut rng = Rng::new(21);
        for &(d_in, g) in &[(24usize, 4usize), (48, 12), (44, 12), (30, 4), (10, 3)] {
            let packed = random_packed(300 + d_in as u64 + g as u64, 5, d_in, g, 2);
            let x: Vec<f32> = (0..d_in).map(|_| rng.normal() as f32).collect();
            let want = dequant_gemv(&packed, &x);
            let mut got = vec![0.0f32; 5];
            lut_gemv(&packed, &x, &mut got, &mut LutScratch::default());
            assert_rows_close(&got, &want, &format!("d_in={d_in} g={g}"));
        }
    }

    #[test]
    fn zero_coeff_in_middle_group_with_small_groups() {
        // The exact shape of the historical bug: g ∈ {4, 12}, a zero
        // coefficient in a *middle* group followed by nonzero groups.
        for &(d_in, g) in &[(24usize, 4usize), (48, 12)] {
            let mut p = random_packed(77 + g as u64, 4, d_in, g, 2);
            let ng = p.n_groups();
            assert!(ng >= 3, "test needs a middle group");
            for r in 0..4 {
                p.coeffs[1].set(r, 1, 0.0); // zero plane-0 coeff, group 1
                p.coeffs[2].set(r, ng / 2, 0.0); // and a middle group of plane 1
            }
            let x: Vec<f32> = (0..d_in).map(|i| (i as f32 * 0.37).sin()).collect();
            let want = dequant_gemv(&p, &x);
            let mut got = vec![0.0f32; 4];
            lut_gemv(&p, &x, &mut got, &mut LutScratch::default());
            assert_rows_close(&got, &want, &format!("d_in={d_in} g={g}"));
        }
    }

    #[test]
    fn scratch_reuse_is_clean() {
        // Using the same scratch across shapes must not leak state.
        let mut scratch = LutScratch::default();
        let p1 = random_packed(1, 8, 64, 16, 2);
        let p2 = random_packed(2, 4, 32, 8, 1);
        let mut rng = Rng::new(8);
        let x1: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let x2: Vec<f32> = (0..32).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0.0; 8];
        lut_gemv(&p1, &x1, &mut y, &mut scratch);
        let mut y2 = vec![0.0; 4];
        lut_gemv(&p2, &x2, &mut y2, &mut scratch);
        let want = dequant_gemv(&p2, &x2);
        assert_rows_close(&y2, &want, "scratch reuse");
    }

    #[test]
    fn non_multiple_of_32_d_in() {
        // d_in=344 (the tiny-LM d_ff): 43 byte-chunks but 11 u32 words —
        // the gather must stop at the true chunk count (regression test
        // for an out-of-bounds on the w2 projection).
        let mut rng = Rng::new(12);
        for &(d_in, g) in &[(344usize, 344usize), (344, 8), (40, 8), (24, 24)] {
            let packed = random_packed(100 + d_in as u64, 3, d_in, g, 2);
            let x: Vec<f32> = (0..d_in).map(|_| rng.normal() as f32).collect();
            let want = dequant_gemv(&packed, &x);
            let mut got = vec![0.0f32; 3];
            lut_gemv(&packed, &x, &mut got, &mut LutScratch::default());
            assert_rows_close(&got, &want, &format!("d_in={d_in} g={g}"));
        }
    }

    #[test]
    fn group_larger_than_d_in() {
        // W2-G256 on a 128-wide layer: a single (short) group.
        let packed = random_packed(55, 4, 128, 256, 2);
        let mut rng = Rng::new(56);
        let x: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        let want = dequant_gemv(&packed, &x);
        let mut got = vec![0.0f32; 4];
        lut_gemv(&packed, &x, &mut got, &mut LutScratch::default());
        assert_rows_close(&got, &want, "g>d_in");
    }

    #[test]
    fn zero_coefficient_fast_path() {
        let mut p = random_packed(3, 4, 64, 16, 2);
        // zero out plane-1 coefficients entirely
        for v in p.coeffs[1].data_mut() {
            *v = 0.0;
        }
        let x: Vec<f32> = (0..64).map(|i| i as f32 * 0.01).collect();
        let want = dequant_gemv(&p, &x);
        let mut got = vec![0.0; 4];
        lut_gemv(&p, &x, &mut got, &mut LutScratch::default());
        assert_rows_close(&got, &want, "zero plane");
    }

    #[test]
    fn lut_gemm_matches_per_column_dequant() {
        // Batched GEMM agrees with per-column dequant-GEMV for every
        // batch lane, across B, ragged d_in, and every k.
        let mut rng = Rng::new(41);
        for &nb in &[1usize, 3, 8] {
            for &(d_out, d_in, g) in
                &[(6usize, 44usize, 12usize), (5, 100, 12), (8, 64, 16), (3, 344, 64)]
            {
                for k in 1..=4usize {
                    let packed =
                        random_packed((nb * 1000 + d_in + k) as u64, d_out, d_in, g, k);
                    let xs: Vec<Vec<f32>> = (0..nb)
                        .map(|_| (0..d_in).map(|_| rng.normal() as f32).collect())
                        .collect();
                    let xrefs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
                    let mut ys: Vec<Vec<f32>> = vec![vec![0.0; d_out]; nb];
                    {
                        let mut yrefs: Vec<&mut [f32]> =
                            ys.iter_mut().map(|y| y.as_mut_slice()).collect();
                        lut_gemm(&packed, &xrefs, &mut yrefs, &mut LutScratch::default());
                    }
                    for (b, x) in xs.iter().enumerate() {
                        let want = dequant_gemv(&packed, x);
                        assert_rows_close(
                            &ys[b],
                            &want,
                            &format!("B={nb} b={b} ({d_out},{d_in},{g},{k})"),
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lut_gemm_batch_invariant() {
        // The batched path must be bit-identical to B independent GEMVs
        // (same operations in the same order per lane) — the engine
        // relies on this for token-identical batched decode.
        let packed = random_packed(91, 7, 96, 16, 3);
        let mut rng = Rng::new(92);
        let xs: Vec<Vec<f32>> =
            (0..5).map(|_| (0..96).map(|_| rng.normal() as f32).collect()).collect();
        let xrefs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
        let mut ys: Vec<Vec<f32>> = vec![vec![0.0; 7]; 5];
        {
            let mut yrefs: Vec<&mut [f32]> = ys.iter_mut().map(|y| y.as_mut_slice()).collect();
            lut_gemm(&packed, &xrefs, &mut yrefs, &mut LutScratch::default());
        }
        let mut scratch = LutScratch::default();
        for (b, x) in xs.iter().enumerate() {
            let mut y = vec![0.0f32; 7];
            lut_gemv(&packed, x, &mut y, &mut scratch);
            assert_eq!(y, ys[b], "lane {b} not bit-identical");
        }
    }

    #[test]
    fn lut_gemm_scratch_reuse_across_mixed_shapes() {
        // One scratch, interleaved shapes and batch sizes: no stale state.
        let mut scratch = LutScratch::default();
        let pa = random_packed(61, 6, 72, 24, 2);
        let pb = random_packed(62, 3, 40, 8, 1);
        let mut rng = Rng::new(63);
        let mk = |rng: &mut Rng, n: usize, d: usize| -> Vec<Vec<f32>> {
            (0..n).map(|_| (0..d).map(|_| rng.normal() as f32).collect()).collect()
        };
        for &(p, nb) in &[(&pa, 3usize), (&pb, 1), (&pa, 8), (&pb, 4)] {
            let xs = mk(&mut rng, nb, p.d_in);
            let xrefs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
            let mut ys: Vec<Vec<f32>> = vec![vec![0.0; p.d_out]; nb];
            {
                let mut yrefs: Vec<&mut [f32]> =
                    ys.iter_mut().map(|y| y.as_mut_slice()).collect();
                lut_gemm(p, &xrefs, &mut yrefs, &mut scratch);
            }
            for (b, x) in xs.iter().enumerate() {
                let want = dequant_gemv(p, x);
                assert_rows_close(&ys[b], &want, &format!("mixed B={nb} b={b}"));
            }
        }
    }
}
