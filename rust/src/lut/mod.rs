//! Native bit-plane LUT-GEMV — the serving hot path (paper §4.3,
//! LUT-GEMM adapted to CPU lanes).
//!
//! For a BPDQ/BCQ-packed layer `Ŵ = REP(C₀) + Σᵢ REP(Cᵢ)⊙Bᵢ`:
//!
//! ```text
//! y_r = Σ_groups [ C₀[r,g]·S_g  +  Σᵢ Cᵢ[r,g] · (Bᵢ[r, g-cols] · x_g) ]
//! ```
//!
//! The binary dot products are evaluated through a subset-sum **LUT over
//! 8-wide activation chunks** (256 entries each, built in O(256) by Gray-
//! style incremental sums), so decode cost is independent of the weight
//! bit-width beyond the per-plane gather — the property that gives the
//! paper's flat W2/W3/W4 decode latency (Table 3).

use crate::quant::packing::BitPlanePacked;
use crate::tensor::Matrix;

/// Per-call workspace (reused across layers/tokens to keep the decode
/// loop allocation-free).
#[derive(Default)]
pub struct LutScratch {
    lut: Vec<f32>,
    group_sums: Vec<f32>,
}

/// Build the subset-sum table for `x`: `lut[c*256+p] = Σ_i x[8c+i]·bit(p,i)`.
pub fn build_lut(x: &[f32], scratch: &mut LutScratch) {
    let n_chunks = x.len().div_ceil(8);
    scratch.lut.resize(n_chunks * 256, 0.0);
    for c in 0..n_chunks {
        let base = c * 256;
        let lut = &mut scratch.lut[base..base + 256];
        lut[0] = 0.0;
        // incremental: lut[p] = lut[p without lowest set bit] + x[bit]
        for p in 1usize..256 {
            let lsb = p & p.wrapping_neg();
            let bit = lsb.trailing_zeros() as usize;
            let xi = x.get(c * 8 + bit).copied().unwrap_or(0.0);
            lut[p] = lut[p ^ lsb] + xi;
        }
    }
}

/// y = Ŵ x for a packed record, using the LUT algorithm.
pub fn lut_gemv(packed: &BitPlanePacked, x: &[f32], y: &mut [f32], scratch: &mut LutScratch) {
    assert_eq!(x.len(), packed.d_in);
    assert_eq!(y.len(), packed.d_out);
    let g = packed.group_size;
    let ng = packed.n_groups();
    let k = packed.k();

    build_lut(x, scratch);

    // Group activation sums (bias term).
    scratch.group_sums.resize(ng, 0.0);
    for grp in 0..ng {
        let c0 = grp * g;
        let c1 = (c0 + g).min(packed.d_in);
        scratch.group_sums[grp] = x[c0..c1].iter().sum();
    }

    let chunks_per_group = g / 8;
    // Total byte-chunks is bounded by d_in (the packed words round up to
    // 32-bit granularity, so `words.len()*4` can overshoot by up to 3).
    let n_chunks = packed.d_in.div_ceil(8);
    let lut = &scratch.lut;
    for (r, yr) in y.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        // bias term: Σ_g c0[r,g] · S_g
        let c0row = packed.coeffs[0].row(r);
        for grp in 0..ng {
            acc += c0row[grp] * scratch.group_sums[grp];
        }
        // plane terms via the LUT
        for i in 0..k {
            let words = packed.planes[i].row_words(r);
            let crow = packed.coeffs[i + 1].row(r);
            let mut chunk = 0usize;
            for (grp, &cv) in crow.iter().enumerate() {
                if cv == 0.0 {
                    chunk += chunks_per_group;
                    continue;
                }
                let mut dot = 0.0f32;
                let chunk_end = (((grp + 1) * g).div_ceil(8)).min(n_chunks);
                while chunk < chunk_end {
                    let byte = (words[chunk / 4] >> (8 * (chunk % 4))) & 0xFF;
                    dot += lut[chunk * 256 + byte as usize];
                    chunk += 1;
                }
                acc += cv * dot;
            }
        }
        *yr = acc;
    }
}

/// Reference: dequantize then dense matvec (the "Torch/Triton dequant"
/// baseline of Table 3).
pub fn dequant_gemv(packed: &BitPlanePacked, x: &[f32]) -> Vec<f32> {
    let w = packed.dequant();
    crate::tensor::matvec(&w, x)
}

/// fp32 dense matvec baseline (the fp16 row of Table 3; we compute in
/// f32 — CPU has no fp16 ALU — but charge fp16 bytes in size columns).
pub fn dense_gemv(w: &Matrix, x: &[f32]) -> Vec<f32> {
    crate::tensor::matvec(w, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::packing::PackedPlane;
    use crate::rng::Rng;

    fn random_packed(seed: u64, d_out: usize, d_in: usize, g: usize, k: usize) -> BitPlanePacked {
        let mut rng = Rng::new(seed);
        let planes = (0..k)
            .map(|_| {
                let dense = Matrix::from_vec(
                    d_out,
                    d_in,
                    (0..d_out * d_in).map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 }).collect(),
                );
                PackedPlane::pack(&dense)
            })
            .collect();
        let ng = d_in.div_ceil(g);
        let coeffs = (0..=k)
            .map(|_| {
                Matrix::from_vec(d_out, ng, (0..d_out * ng).map(|_| rng.normal() as f32).collect())
            })
            .collect();
        BitPlanePacked { d_out, d_in, group_size: g, planes, coeffs, coeff_bits: 16 }
    }

    #[test]
    fn build_lut_subset_sums() {
        let x: Vec<f32> = (1..=8).map(|i| i as f32).collect();
        let mut s = LutScratch::default();
        build_lut(&x, &mut s);
        assert_eq!(s.lut[0], 0.0);
        assert_eq!(s.lut[0b1], 1.0);
        assert_eq!(s.lut[0b11], 3.0);
        assert_eq!(s.lut[0b10000000], 8.0);
        assert_eq!(s.lut[0xFF], 36.0);
        // random spot-check
        let p = 0b1010_0110usize;
        let want: f32 = (0..8).filter(|i| (p >> i) & 1 == 1).map(|i| x[i]).sum();
        assert_eq!(s.lut[p], want);
    }

    #[test]
    fn lut_gemv_matches_dequant_gemv() {
        let mut rng = Rng::new(7);
        for &(d_out, d_in, g, k) in
            &[(4usize, 32usize, 8usize, 1usize), (8, 64, 16, 2), (16, 128, 64, 3), (5, 96, 32, 4)]
        {
            let packed = random_packed(d_out as u64, d_out, d_in, g, k);
            let x: Vec<f32> = (0..d_in).map(|_| rng.normal() as f32).collect();
            let want = dequant_gemv(&packed, &x);
            let mut got = vec![0.0f32; d_out];
            let mut scratch = LutScratch::default();
            lut_gemv(&packed, &x, &mut got, &mut scratch);
            for r in 0..d_out {
                assert!(
                    (got[r] - want[r]).abs() < 1e-3 * (1.0 + want[r].abs()),
                    "({d_out},{d_in},{g},{k}) row {r}: {} vs {}",
                    got[r],
                    want[r]
                );
            }
        }
    }

    #[test]
    fn scratch_reuse_is_clean() {
        // Using the same scratch across shapes must not leak state.
        let mut scratch = LutScratch::default();
        let p1 = random_packed(1, 8, 64, 16, 2);
        let p2 = random_packed(2, 4, 32, 8, 1);
        let mut rng = Rng::new(8);
        let x1: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let x2: Vec<f32> = (0..32).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0.0; 8];
        lut_gemv(&p1, &x1, &mut y, &mut scratch);
        let mut y2 = vec![0.0; 4];
        lut_gemv(&p2, &x2, &mut y2, &mut scratch);
        let want = dequant_gemv(&p2, &x2);
        for r in 0..4 {
            assert!((y2[r] - want[r]).abs() < 1e-3 * (1.0 + want[r].abs()));
        }
    }

    #[test]
    fn non_multiple_of_32_d_in() {
        // d_in=344 (the tiny-LM d_ff): 43 byte-chunks but 11 u32 words —
        // the gather must stop at the true chunk count (regression test
        // for an out-of-bounds on the w2 projection).
        let mut rng = Rng::new(12);
        for &(d_in, g) in &[(344usize, 344usize), (344, 8), (40, 8), (24, 24)] {
            let packed = random_packed(100 + d_in as u64, 3, d_in, g, 2);
            let x: Vec<f32> = (0..d_in).map(|_| rng.normal() as f32).collect();
            let want = dequant_gemv(&packed, &x);
            let mut got = vec![0.0f32; 3];
            lut_gemv(&packed, &x, &mut got, &mut LutScratch::default());
            for r in 0..3 {
                assert!(
                    (got[r] - want[r]).abs() < 1e-3 * (1.0 + want[r].abs()),
                    "d_in={d_in} g={g} row {r}"
                );
            }
        }
    }

    #[test]
    fn group_larger_than_d_in() {
        // W2-G256 on a 128-wide layer: a single (short) group.
        let packed = random_packed(55, 4, 128, 256, 2);
        let mut rng = Rng::new(56);
        let x: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        let want = dequant_gemv(&packed, &x);
        let mut got = vec![0.0f32; 4];
        lut_gemv(&packed, &x, &mut got, &mut LutScratch::default());
        for r in 0..4 {
            assert!((got[r] - want[r]).abs() < 1e-3 * (1.0 + want[r].abs()));
        }
    }

    #[test]
    fn zero_coefficient_fast_path() {
        let mut p = random_packed(3, 4, 64, 16, 2);
        // zero out plane-1 coefficients entirely
        for v in p.coeffs[1].data_mut() {
            *v = 0.0;
        }
        let x: Vec<f32> = (0..64).map(|i| i as f32 * 0.01).collect();
        let want = dequant_gemv(&p, &x);
        let mut got = vec![0.0; 4];
        lut_gemv(&p, &x, &mut got, &mut LutScratch::default());
        for r in 0..4 {
            assert!((got[r] - want[r]).abs() < 1e-3 * (1.0 + want[r].abs()));
        }
    }
}
