//! `bpdq` — the L3 coordinator binary.
//!
//! Subcommands:
//! * `gen-data`   — write the deterministic synthetic corpus + vocab into
//!   `artifacts/` (consumed by the python trainer; rust is the data
//!   source of truth);
//! * `quantize`   — quantize a `.tlm` checkpoint with any method and save
//!   the result + report;
//! * `eval`       — run the benchmark battery on a checkpoint;
//! * `table1` / `table2` / `table3` / `fig1b` / `fig3` — regenerate the
//!   paper's tables/figures on the synthetic substrate;
//! * `serve`      — start the serving engine on a quantized checkpoint
//!   and run a request trace through it, or (`--listen`) expose it over
//!   HTTP/SSE with admission control and graceful drain;
//! * `loadgen`    — wire-level Zipf load generator against a
//!   `serve --listen` process, emitting `BENCH_serve_load.json`;
//! * `selfcheck`  — verify artifacts (vocab sync, HLO loads, kernel
//!   parity) end to end;
//! * `lint`       — project-native static analysis: hot-path and
//!   unsafe-aliasing invariants (rules L1–L5, see `bpdq::analysis`).

use bpdq::cli::Args;

mod commands {
    pub mod bench_tables;
    pub mod gen_data;
    pub mod lint;
    pub mod loadgen;
    pub mod quantize;
    pub mod selfcheck;
    pub mod serve;
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_str() {
        "gen-data" => commands::gen_data::run(&args),
        "quantize" => commands::quantize::run_quantize(&args),
        "eval" => commands::quantize::run_eval(&args),
        "table1" => commands::bench_tables::table1(&args),
        "table2" => commands::bench_tables::table2(&args),
        "table3" => commands::bench_tables::table3(&args),
        "fig1b" => commands::bench_tables::fig1b(&args),
        "fig3" => commands::bench_tables::fig3(&args),
        "serve" => commands::serve::run(&args),
        "loadgen" => commands::loadgen::run(&args),
        "selfcheck" => commands::selfcheck::run(&args),
        "lint" => commands::lint::run(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand `{other}`\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        r#"bpdq — Bit-Plane Decomposition Quantization (paper reproduction)

USAGE: bpdq <SUBCOMMAND> [--flag value]...

SUBCOMMANDS
  gen-data   --out artifacts [--train-docs N] [--eval-docs N] [--calib-docs N]
  quantize   --model <.tlm> --method <fp16|rtn|gptq|awq|anybcq|vptq|bpdq>
             [--bits K] [--group G] [--iters N] [--out <.tlm>]
  eval       --model <.tlm> [--n-arith N] [--n-choice N] [--ppl-docs N]
  table1     [--model small|large] [--quick]     main quality table
  table2     [--quick]                           + AnyBCQ/VPTQ comparison
  table3     [--quick]                           efficiency + outlier stats
  fig1b      [--quick]                           2-bit comparison series
  fig3       [--quick]                           long-context suite
  serve      --model <.tlm> [--engine native|pjrt|lut] [--requests N]
             [--workers N] [--max-batch B] [--max-new N] [--stream]
             [--kv-bits 0|2|3|4] (0 = f32 KV; 2..4 = packed bit-plane KV)
             [--simd auto|scalar|avx2|neon] (kernel tier; also BPDQ_SIMD)
             [--temperature T] [--top-k K] [--top-p P] [--seed S]
             [--stop id,id,...]                streaming scheduler smoke
                                               via --stream (cancels one
                                               request mid-decode)
             [--prefix-cache] [--kv-page N]   radix prefix cache + paging
             [--prefill-chunk N] [--sweep-token-budget N]
                                               chunked prefill: N prompt
                                               tokens per sweep per session
                                               under a shared token budget
                                               (default max_batch × chunk)
             [--listen host:port] [--addr-file p] [--max-conns N]
             [--deadline-budget-us N] [--tenant-priority gold=9,free=0]
             [--keepalive-ms N] [--io-timeout-ms N]
                                               HTTP/SSE front door: POST
                                               /v1/generate, GET /healthz,
                                               GET /metrics, POST
                                               /admin/drain (+ raw BPQ1
                                               protocol on the same port)
  loadgen    --addr host:port | --addr-file p   wire-level Zipf load client
             [--requests N] [--concurrency C] [--pool P] [--zipf-s S]
             [--prompt-len-dist uniform|bimodal] (bimodal: every 4th
                                               request is a 96-token
                                               prompt; short TTFT is
                                               reported separately)
             [--max-new N] [--seed S] [--raw] [--drain] [--name NAME]
             [--out BENCH_serve_load.json] [--verify-inprocess]
             [--require-all] [--expect-rejections]
                                               + the serve model/engine
                                               flags when verifying
  selfcheck                                       artifact + kernel parity
  lint       [--root rust/src] [--config rust/lint.toml] [--list-rules]
                                                  static analysis (L1..L5):
                                                  SAFETY comments, alloc/
                                                  panic/lock-free hot paths,
                                                  unsafe aliasing protocol
"#
    );
}
