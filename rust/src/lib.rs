//! # BPDQ — Bit-Plane Decomposition Quantization on a Variable Grid
//!
//! Full-stack reproduction of the BPDQ paper (Chen et al., ICML 2026):
//! a post-training quantizer that replaces the fixed (shape-invariant)
//! quantization grid with a per-group *variable grid* built from bit-planes
//! and scalar coefficients, optimized under the Hessian-induced geometry.
//!
//! Layering (see DESIGN.md):
//! * **L3 (this crate)** — quantization pipeline, evaluation harness,
//!   serving stack (router / batcher / KV manager / decode engine),
//!   PJRT runtime for AOT artifacts.
//! * **L2/L1 (python/, build-time only)** — JAX model + Pallas kernels,
//!   lowered once to HLO text under `artifacts/`.
//!
//! The hot-path and unsafe-aliasing invariants the serving stack relies
//! on are machine-checked by `bpdq lint` (the [`analysis`] module); see
//! `serving`'s "Static analysis" docs for the marker contract.

// The numeric kernels intentionally use index loops (parallel indexing
// into several buffers at matching offsets); the iterator rewrites
// clippy suggests obscure the stride arithmetic.
#![allow(clippy::needless_range_loop)]
// Every unsafe operation inside an unsafe fn must still sit in its own
// `unsafe { }` block so lint rule L1 sees (and demands a SAFETY comment
// on) each one.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod benchkit;
pub mod cli;
pub mod data;
pub mod eval;
pub mod io;
pub mod linalg;
pub mod lut;
pub mod model;
pub mod proptest_lite;
pub mod quant;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod serving;
pub mod tensor;
