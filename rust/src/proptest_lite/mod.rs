//! proptest-lite: a minimal property-testing harness.
//!
//! The real `proptest` crate is not in the offline vendor set, so this
//! module provides the 10% we need: run a property over many seeded
//! random inputs, and on failure report the seed + case index so the
//! exact case can be replayed by construction (all our generators are
//! deterministic functions of the [`Rng`]).

use crate::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Env knobs mirror proptest's: BPDQ_PROPTEST_CASES / _SEED.
        let cases = std::env::var("BPDQ_PROPTEST_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(48);
        let seed = std::env::var("BPDQ_PROPTEST_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(DEFAULT_SEED);
        Self { cases, seed }
    }
}

const DEFAULT_SEED: u64 = 0x50FA_CE5;

/// Run `prop` over `cfg.cases` independently seeded RNGs. `prop` returns
/// `Err(msg)` to fail the case. Panics with seed + case on first failure
/// (no shrinking — cases are reconstructable from the seed).
pub fn run_prop<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property `{name}` failed at case {case}/{} (seed={:#x}): {msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Convenience: run with the default config.
pub fn check<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    run_prop(name, Config::default(), prop);
}

/// Assert two slices are element-wise close; returns a property error
/// with the first offending index otherwise.
pub fn assert_close(a: &[f32], b: &[f32], atol: f64, rtol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let diff = (x as f64 - y as f64).abs();
        let bound = atol + rtol * (y as f64).abs();
        if !(diff <= bound) {
            return Err(format!("idx {i}: {x} vs {y} (|Δ|={diff:.3e} > {bound:.3e})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop("trivial", Config { cases: 17, seed: 1 }, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_name() {
        run_prop("fails", Config { cases: 5, seed: 2 }, |rng| {
            if rng.f64() >= 0.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn assert_close_reports_index() {
        let e = assert_close(&[1.0, 2.0], &[1.0, 3.0], 1e-6, 0.0).unwrap_err();
        assert!(e.contains("idx 1"), "{e}");
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-8], 1e-6, 0.0).is_ok());
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        run_prop("collect", Config { cases: 4, seed: 3 }, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        run_prop("collect", Config { cases: 4, seed: 3 }, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
