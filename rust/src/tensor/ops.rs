//! Matmul / matvec kernels.
//!
//! All kernels are written so the inner loop is a contiguous
//! multiply-accumulate over the K dimension that LLVM auto-vectorizes.
//! `matmul` packs nothing (matrices here are at most a few thousand wide);
//! instead it uses an i-k-j loop order with a 4-row unroll, which is the
//! standard cache-friendly order for row-major data.

use super::kvpack::PackedStrip;
use super::{Mat, Matrix};

/// `C = A @ B` (A: m×k, B: k×n).
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul shape mismatch: {:?} @ {:?}",
        a.shape(),
        b.shape()
    );
    let (m, _k) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    // i-k-j order: C[i, :] += A[i, kk] * B[kk, :] — unit-stride over both
    // C and B rows, auto-vectorizes well.
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(kk);
            for (cj, &bj) in crow.iter_mut().zip(brow) {
                *cj += aik * bj;
            }
        }
    }
    c
}

/// `C = A @ Bᵀ` (A: m×k, B: n×k). This is the natural layout for linear
/// layers stored as (d_out × d_in): `y = x @ Wᵀ`.
pub fn matmul_transb(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(
        a.cols(),
        b.cols(),
        "matmul_transb shape mismatch: {:?} @ {:?}ᵀ",
        a.shape(),
        b.shape()
    );
    let (m, k) = a.shape();
    let n = b.rows();
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        // 2-wide j unroll: two independent dot products share the A row
        // stream.
        let mut j = 0;
        while j + 2 <= n {
            let (b0, b1) = (b.row(j), b.row(j + 1));
            let (mut s0, mut s1) = (0.0f32, 0.0f32);
            for kk in 0..k {
                let av = arow[kk];
                s0 += av * b0[kk];
                s1 += av * b1[kk];
            }
            crow[j] = s0;
            crow[j + 1] = s1;
            j += 2;
        }
        if j < n {
            crow[j] = dot(arow, b.row(j));
        }
    }
    c
}

/// `y = A @ x` (A: m×k, x: k).
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols(), x.len());
    (0..a.rows()).map(|i| dot(a.row(i), x)).collect()
}

/// `y = Aᵀ @ x` (A: m×k, x: m, y: k).
pub fn matvec_transa(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.rows(), x.len());
    let mut y = vec![0.0f32; a.cols()];
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        for (yj, &aij) in y.iter_mut().zip(a.row(i)) {
            *yj += xi * aij;
        }
    }
    y
}

/// Contiguous dot product — the single hottest scalar loop in the stack.
// lint: hot
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4 independent accumulators break the FP dependency chain so LLVM
    // vectorizes + pipelines.
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x` over contiguous slices — the AV-accumulation
/// primitive of the decode attention sweep (head-major KV strips make
/// every V row contiguous, so this auto-vectorizes).
// lint: hot
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Batched strided-dot over B head-major KV strips: for every live
/// position `u` and batch lane `b`,
///
/// `scores[b * len + u] = scale * dot(qs[b], strips[b][u*hd .. (u+1)*hd])`
///
/// where `len = scores.len() / qs.len()` is the shared live length. The
/// position loop is *outer* so all B strips are walked together — when
/// the strips are adjacent slots of one KV arena slab, each step of the
/// walk touches B rows a fixed stride apart, the batched-matvec access
/// pattern the per-session loop could never produce. Per-lane numerics
/// are identical to B independent [`dot`] sweeps (same slices, same
/// order), so the batched serving path stays token-identical to B=1.
// lint: hot
pub fn strip_dots(qs: &[&[f32]], strips: &[&[f32]], hd: usize, scale: f32, scores: &mut [f32]) {
    let nb = qs.len();
    debug_assert_eq!(strips.len(), nb);
    if nb == 0 {
        return;
    }
    debug_assert_eq!(scores.len() % nb, 0);
    let len = scores.len() / nb;
    for u in 0..len {
        let o = u * hd;
        for b in 0..nb {
            scores[b * len + u] = dot(qs[b], &strips[b][o..o + hd]) * scale;
        }
    }
}

/// Batched AV accumulation over B head-major V strips:
///
/// `outs[b] += Σ_u ws[b * len + u] · strips[b][u*hd .. (u+1)*hd]`
///
/// with `len = ws.len() / outs.len()`. Position-major walk like
/// [`strip_dots`]; weights below 1e-9 are skipped exactly as in the
/// per-session `attend_head` path so both orders accumulate the same
/// f32 sums in the same order (token-identical parity).
///
/// The `w < 1e-9` skip assumes weights are **softmax outputs** (always
/// `>= 0`): it is a "contributes nothing at f32 precision" cutoff, not
/// a magnitude test, and a negative weight would be silently dropped.
/// That contract is asserted in debug builds, and the SIMD twin in
/// `tensor::simd` replicates this exact comparison so the skip mask is
/// bit-identical across tiers.
// lint: hot
pub fn strip_axpys(ws: &[f32], strips: &[&[f32]], hd: usize, outs: &mut [&mut [f32]]) {
    let nb = outs.len();
    debug_assert_eq!(strips.len(), nb);
    if nb == 0 {
        return;
    }
    debug_assert_eq!(ws.len() % nb, 0);
    let len = ws.len() / nb;
    for u in 0..len {
        let o = u * hd;
        for b in 0..nb {
            let w = ws[b * len + u];
            debug_assert!(w >= 0.0, "strip_axpys weights must be softmax outputs (got {w})");
            if w < 1e-9 {
                continue;
            }
            axpy(w, &strips[b][o..o + hd], &mut *outs[b]);
        }
    }
}

/// `Σ q[j]` over the set bits of channels `[lo, hi)` of the plane row
/// starting at bit `row0` (`q` is the full `hd`-wide activation row;
/// `q[j]` pairs with plane bit `row0 + j`) — the popcount-style partial
/// dot of the fused-dequant score kernel.
///
/// Accumulation is *chunked at absolute channel multiples of 8*: each
/// 8-channel chunk folds its set bits ascending into a fresh
/// sub-accumulator (starting from 0.0), and the chunk sums are added in
/// chunk order. This is exactly the chain shape of the table-driven
/// SIMD path (`tensor::simd`), whose 256-entry subset-sum tables store
/// ascending-order chains per byte — so the scalar reference and the
/// table kernels are bit-exact twins, not merely close (see the
/// "SIMD dispatch & numerics policy" notes in `tensor/mod.rs`).
// lint: hot
#[inline]
fn fold_set_bits(plane: &[u32], row0: usize, lo: usize, hi: usize, q: &[f32]) -> f32 {
    debug_assert!(q.len() >= hi);
    let mut acc = 0.0f32;
    let mut j = lo;
    while j < hi {
        let c = j >> 3;
        let take = ((c + 1) * 8).min(hi) - j;
        let mut m = super::kvpack::plane_byte(plane, row0 + j) & ((1usize << take) - 1);
        let mut sub = 0.0f32;
        while m != 0 {
            let t = m.trailing_zeros() as usize;
            sub += q[j + t];
            m &= m - 1;
        }
        acc += sub;
        j += take;
    }
    acc
}

/// `out[j] += add` over the set bits of a plane bit-span — the AV-side
/// twin of [`fold_set_bits`].
// lint: hot
#[inline]
fn scatter_set_bits(plane: &[u32], start: usize, n: usize, add: f32, out: &mut [f32]) {
    debug_assert!(out.len() >= n);
    let mut j = 0;
    while j < n {
        let bp = start + j;
        let off = bp % 32;
        let take = (32 - off).min(n - j);
        let mask = if take == 32 { u32::MAX } else { (1u32 << take) - 1 };
        let mut m = (plane[bp / 32] >> off) & mask;
        while m != 0 {
            let t = m.trailing_zeros() as usize;
            out[j + t] += add;
            m &= m - 1;
        }
        j += take;
    }
}

/// Fused-dequant variant of [`strip_dots`] over **packed** bit-plane KV
/// strips: for every live position `u < len` and batch lane `b`,
///
/// `scores[b*len + u] = scale * dot(qs[b], dequant(strips[b], u))`
///
/// evaluated without materializing the dequantized row — per channel
/// group the bias term is `c₀ · Σ q` (group q-sums precomputed once per
/// call) and each plane contributes `cᵢ ×` a popcount-style partial dot
/// over its set bits. The position loop stays *outer* exactly like the
/// f32 kernel, so lanes of one group are walked together and the f32
/// path's token-identity guarantees are untouched (this kernel only
/// runs when the arena stores packed strips).
// lint: hot
pub fn strip_dots_packed(
    qs: &[&[f32]],
    strips: &[PackedStrip],
    len: usize,
    scale: f32,
    scores: &mut [f32],
) {
    let nb = qs.len();
    debug_assert_eq!(strips.len(), nb);
    if nb == 0 {
        return;
    }
    debug_assert_eq!(scores.len(), nb * len);
    let geom = strips[0].geom;
    let (hd, bits, group, ng) = (geom.hd, geom.bits, geom.group, geom.n_groups());
    // Per-(lane, group) activation sums — the c₀ bias partner, computed
    // once and reused at every position. Stack-allocated in the common
    // case so the packed score kernel stays as allocation-free as its
    // f32 twin inside the decode hot loop (heap fallback only for huge
    // batch × group-count products).
    let mut qsums_stack = [0.0f32; 64];
    let mut qsums_heap: Vec<f32>;
    let qsums: &mut [f32] = if nb * ng <= qsums_stack.len() {
        &mut qsums_stack[..nb * ng]
    } else {
        qsums_heap = vec![0.0f32; nb * ng];
        &mut qsums_heap
    };
    for (b, q) in qs.iter().enumerate() {
        debug_assert_eq!(q.len(), hd);
        for g in 0..ng {
            let lo = g * group;
            let hi = (lo + group).min(hd);
            qsums[b * ng + g] = q[lo..hi].iter().sum();
        }
    }
    for u in 0..len {
        for b in 0..nb {
            let st = &strips[b];
            debug_assert_eq!(st.geom, geom);
            let mut s = 0.0f32;
            for g in 0..ng {
                let lo = g * group;
                let hi = (lo + group).min(hd);
                s += st.coeff(u, g, 0) * qsums[b * ng + g];
                for i in 0..bits {
                    let pd = fold_set_bits(st.plane(i), u * hd, lo, hi, qs[b]);
                    s += st.coeff(u, g, 1 + i) * pd;
                }
            }
            scores[b * len + u] = s * scale;
        }
    }
}

/// Fused-dequant variant of [`strip_axpys`] over packed V strips:
///
/// `outs[b] += Σ_u ws[b*len + u] · dequant(strips[b], u)`
///
/// — per group the bias adds `w·c₀` to every channel and each plane
/// scatters `w·cᵢ` onto its set bits. Position-major walk and the same
/// `< 1e-9` weight skip as the f32 kernel (softmax outputs only — see
/// [`strip_axpys`]), so the packed single-session and batched paths
/// accumulate identically to each other.
// lint: hot
pub fn strip_axpys_packed(ws: &[f32], strips: &[PackedStrip], len: usize, outs: &mut [&mut [f32]]) {
    let nb = outs.len();
    debug_assert_eq!(strips.len(), nb);
    if nb == 0 {
        return;
    }
    debug_assert_eq!(ws.len(), nb * len);
    for u in 0..len {
        for b in 0..nb {
            let w = ws[b * len + u];
            debug_assert!(w >= 0.0, "strip_axpys_packed weights must be softmax outputs (got {w})");
            if w < 1e-9 {
                continue;
            }
            let st = &strips[b];
            let geom = st.geom;
            let (hd, bits, group) = (geom.hd, geom.bits, geom.group);
            let out = &mut *outs[b];
            debug_assert_eq!(out.len(), hd);
            for g in 0..geom.n_groups() {
                let lo = g * group;
                let hi = (lo + group).min(hd);
                let base = w * st.coeff(u, g, 0);
                for v in out[lo..hi].iter_mut() {
                    *v += base;
                }
                for i in 0..bits {
                    let add = w * st.coeff(u, g, 1 + i);
                    scatter_set_bits(st.plane(i), u * hd + lo, hi - lo, add, &mut out[lo..hi]);
                }
            }
        }
    }
}

/// RMSNorm scalar reference: `out = x * gain / rms(x)`, with the mean
/// square accumulated in f64 (conditioning) and the epilogue entirely
/// per-element in f32. The SIMD tiers reassociate only the f64 sum of
/// squares; the epilogue is copied verbatim, so the tier difference is
/// bounded by the f64 reduction's reassociation error alone.
// lint: hot
pub fn rmsnorm(x: &[f32], gain: &[f32], eps: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), gain.len());
    let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64;
    let inv = 1.0 / (ms + eps as f64).sqrt() as f32;
    for ((o, &xv), &g) in out.iter_mut().zip(x).zip(gain) {
        *o = xv * inv * g;
    }
}

/// In-place softmax scalar reference. The max pass is an associative
/// reduction (vectorizing it is value-exact); the exp + sum pass stays
/// scalar in every tier so softmax outputs are identical across tiers.
// lint: hot
pub fn softmax(xs: &mut [f32]) {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

/// f64 matmul for conditioning-sensitive paths (Hessian ops).
pub fn matmul_f64(a: &Mat<f64>, b: &Mat<f64>) -> Mat<f64> {
    assert_eq!(a.cols(), b.rows());
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Mat::<f64>::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for kk in 0..k {
            let aik = arow[kk];
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(kk);
            for (cj, &bj) in crow.iter_mut().zip(brow) {
                *cj += aik * bj;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for kk in 0..k {
                    s += a.get(i, kk) as f64 * b.get(kk, j) as f64;
                }
                c.set(i, j, s as f32);
            }
        }
        c
    }

    fn rand_mat(rng: &mut Rng, m: usize, n: usize) -> Matrix {
        Matrix::from_vec(m, n, (0..m * n).map(|_| rng.normal() as f32).collect())
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 4), (17, 33, 9), (64, 64, 64)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let c = matmul(&a, &b);
            let r = naive_matmul(&a, &b);
            assert!(c.fro_dist(&r) < 1e-3 * (1.0 + r.fro_norm()), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_transb_matches_matmul() {
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(2, 3, 5), (16, 31, 7), (33, 64, 65)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, n, k);
            let c1 = matmul_transb(&a, &b);
            let c2 = matmul(&a, &b.transpose());
            assert!(c1.fro_dist(&c2) < 1e-4 * (1.0 + c2.fro_norm()));
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(3);
        let a = rand_mat(&mut rng, 9, 14);
        let x: Vec<f32> = (0..14).map(|_| rng.normal() as f32).collect();
        let y = matvec(&a, &x);
        let xm = Matrix::from_vec(14, 1, x.clone());
        let ym = matmul(&a, &xm);
        for i in 0..9 {
            assert!((y[i] - ym.get(i, 0)).abs() < 1e-4);
        }
    }

    #[test]
    fn matvec_transa_matches() {
        let mut rng = Rng::new(4);
        let a = rand_mat(&mut rng, 9, 14);
        let x: Vec<f32> = (0..9).map(|_| rng.normal() as f32).collect();
        let y = matvec_transa(&a, &x);
        let yt = matvec(&a.transpose(), &x);
        for i in 0..14 {
            assert!((y[i] - yt[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn axpy_slices() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn dot_empty_and_odd() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[1., 2., 3.], &[4., 5., 6.]), 32.0);
        let a = vec![1.0f32; 7];
        assert_eq!(dot(&a, &a), 7.0);
    }

    #[test]
    fn strip_dots_matches_per_lane_dot() {
        let mut rng = Rng::new(6);
        let (nb, len, hd) = (3usize, 5usize, 8usize);
        let qs_data: Vec<Vec<f32>> =
            (0..nb).map(|_| (0..hd).map(|_| rng.normal() as f32).collect()).collect();
        let strips_data: Vec<Vec<f32>> =
            (0..nb).map(|_| (0..len * hd).map(|_| rng.normal() as f32).collect()).collect();
        let qs: Vec<&[f32]> = qs_data.iter().map(|v| v.as_slice()).collect();
        let strips: Vec<&[f32]> = strips_data.iter().map(|v| v.as_slice()).collect();
        let mut scores = vec![0.0f32; nb * len];
        strip_dots(&qs, &strips, hd, 0.5, &mut scores);
        for b in 0..nb {
            for u in 0..len {
                let want = dot(&qs_data[b], &strips_data[b][u * hd..(u + 1) * hd]) * 0.5;
                // bit-identical: same slices, same dot, same order
                assert_eq!(scores[b * len + u], want, "b {b} u {u}");
            }
        }
    }

    #[test]
    fn strip_axpys_matches_per_lane_axpy() {
        let mut rng = Rng::new(7);
        let (nb, len, hd) = (2usize, 4usize, 8usize);
        let strips_data: Vec<Vec<f32>> =
            (0..nb).map(|_| (0..len * hd).map(|_| rng.normal() as f32).collect()).collect();
        let ws: Vec<f32> =
            (0..nb * len).map(|i| if i % 3 == 0 { 0.0 } else { 0.1 + i as f32 * 0.01 }).collect();
        let strips: Vec<&[f32]> = strips_data.iter().map(|v| v.as_slice()).collect();
        let mut flat = vec![0.0f32; nb * hd];
        {
            let mut outs: Vec<&mut [f32]> = flat.chunks_exact_mut(hd).collect();
            strip_axpys(&ws, &strips, hd, &mut outs);
        }
        for b in 0..nb {
            let mut want = vec![0.0f32; hd];
            for u in 0..len {
                let w = ws[b * len + u];
                if w < 1e-9 {
                    continue;
                }
                axpy(w, &strips_data[b][u * hd..(u + 1) * hd], &mut want);
            }
            assert_eq!(&flat[b * hd..(b + 1) * hd], want.as_slice(), "b {b}");
        }
    }

    #[test]
    fn strip_kernels_empty_batch() {
        strip_dots(&[], &[], 8, 1.0, &mut []);
        strip_axpys(&[], &[], 8, &mut []);
        strip_dots_packed(&[], &[], 4, 1.0, &mut []);
        strip_axpys_packed(&[], &[], 4, &mut []);
    }

    /// Build `nb` packed strips of `len` random rows each; returns the
    /// strips' backing words (the tests read back via `dequant_row`).
    fn packed_fixture(
        rng: &mut Rng,
        nb: usize,
        len: usize,
        geom: crate::tensor::kvpack::PackedGeom,
    ) -> Vec<Vec<u32>> {
        use crate::tensor::kvpack::PackedStripMut;
        let mut words = vec![vec![0u32; geom.strip_words()]; nb];
        for w in words.iter_mut() {
            let mut strip = PackedStripMut::new(geom, w);
            for u in 0..len {
                let row: Vec<f32> = (0..geom.hd).map(|_| rng.normal() as f32).collect();
                strip.store_row(u, &row);
            }
        }
        words
    }

    #[test]
    fn strip_dots_packed_matches_dequant_reference() {
        use crate::tensor::kvpack::{PackedGeom, PackedStrip};
        let mut rng = Rng::new(8);
        let geom = PackedGeom::new(10, 8, 2, 4);
        let (nb, len) = (3usize, 7usize);
        let words = packed_fixture(&mut rng, nb, 10, geom);
        let strips: Vec<PackedStrip> =
            words.iter().map(|w| PackedStrip::new(geom, w)).collect();
        let qs_data: Vec<Vec<f32>> =
            (0..nb).map(|_| (0..geom.hd).map(|_| rng.normal() as f32).collect()).collect();
        let qs: Vec<&[f32]> = qs_data.iter().map(|v| v.as_slice()).collect();
        let mut scores = vec![0.0f32; nb * len];
        strip_dots_packed(&qs, &strips, len, 0.5, &mut scores);
        let mut row = vec![0.0f32; geom.hd];
        for b in 0..nb {
            for u in 0..len {
                strips[b].dequant_row(u, &mut row);
                let want = dot(&qs_data[b], &row) * 0.5;
                let got = scores[b * len + u];
                assert!(
                    (got - want).abs() < 1e-4 * (1.0 + want.abs()),
                    "b {b} u {u}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn strip_axpys_packed_matches_dequant_reference() {
        use crate::tensor::kvpack::{PackedGeom, PackedStrip};
        let mut rng = Rng::new(9);
        let geom = PackedGeom::new(10, 8, 3, 8);
        let (nb, len) = (2usize, 6usize);
        let words = packed_fixture(&mut rng, nb, 10, geom);
        let strips: Vec<PackedStrip> =
            words.iter().map(|w| PackedStrip::new(geom, w)).collect();
        let ws: Vec<f32> =
            (0..nb * len).map(|i| if i % 3 == 0 { 0.0 } else { 0.05 + i as f32 * 0.01 }).collect();
        let mut flat = vec![0.0f32; nb * geom.hd];
        {
            let mut outs: Vec<&mut [f32]> = flat.chunks_exact_mut(geom.hd).collect();
            strip_axpys_packed(&ws, &strips, len, &mut outs);
        }
        let mut row = vec![0.0f32; geom.hd];
        for b in 0..nb {
            let mut want = vec![0.0f32; geom.hd];
            for u in 0..len {
                let w = ws[b * len + u];
                if w < 1e-9 {
                    continue;
                }
                strips[b].dequant_row(u, &mut row);
                axpy(w, &row, &mut want);
            }
            for (j, (&got, &wv)) in flat[b * geom.hd..(b + 1) * geom.hd]
                .iter()
                .zip(&want)
                .enumerate()
            {
                assert!((got - wv).abs() < 1e-4 * (1.0 + wv.abs()), "b {b} j {j}");
            }
        }
    }

    #[test]
    fn packed_kernels_batched_match_single_lane() {
        // The batched packed kernels must agree bit-for-bit with nb=1
        // calls per lane — the packed analogue of the f32 token-identity
        // guarantee (same walk order, same accumulators).
        use crate::tensor::kvpack::{PackedGeom, PackedStrip};
        let mut rng = Rng::new(10);
        let geom = PackedGeom::new(8, 8, 2, 8);
        let (nb, len) = (3usize, 5usize);
        let words = packed_fixture(&mut rng, nb, 8, geom);
        let strips: Vec<PackedStrip> =
            words.iter().map(|w| PackedStrip::new(geom, w)).collect();
        let qs_data: Vec<Vec<f32>> =
            (0..nb).map(|_| (0..geom.hd).map(|_| rng.normal() as f32).collect()).collect();
        let qs: Vec<&[f32]> = qs_data.iter().map(|v| v.as_slice()).collect();
        let mut scores = vec![0.0f32; nb * len];
        strip_dots_packed(&qs, &strips, len, 0.25, &mut scores);
        let ws: Vec<f32> = (0..nb * len).map(|i| 0.01 + (i % 7) as f32 * 0.03).collect();
        let mut flat = vec![0.0f32; nb * geom.hd];
        {
            let mut outs: Vec<&mut [f32]> = flat.chunks_exact_mut(geom.hd).collect();
            strip_axpys_packed(&ws, &strips, len, &mut outs);
        }
        for b in 0..nb {
            let mut solo_scores = vec![0.0f32; len];
            strip_dots_packed(&[qs_data[b].as_slice()], &[strips[b]], len, 0.25, &mut solo_scores);
            assert_eq!(&scores[b * len..(b + 1) * len], solo_scores.as_slice(), "b {b}");
            let mut solo_out = vec![0.0f32; geom.hd];
            {
                let mut outs: Vec<&mut [f32]> = vec![solo_out.as_mut_slice()];
                strip_axpys_packed(&ws[b * len..(b + 1) * len], &[strips[b]], len, &mut outs);
            }
            assert_eq!(&flat[b * geom.hd..(b + 1) * geom.hd], solo_out.as_slice(), "b {b}");
        }
    }

    #[test]
    fn matmul_f64_identity() {
        let n = 8;
        let mut eye = Mat::<f64>::zeros(n, n);
        for i in 0..n {
            eye.set(i, i, 1.0);
        }
        let mut rng = Rng::new(5);
        let a = Mat::<f64>::from_vec(n, n, (0..n * n).map(|_| rng.normal()).collect());
        let c = matmul_f64(&a, &eye);
        for i in 0..n {
            for j in 0..n {
                assert!((c.get(i, j) - a.get(i, j)).abs() < 1e-12);
            }
        }
    }
}
