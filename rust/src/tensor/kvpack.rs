//! Bit-plane packed KV strips — the BPDQ variable grid applied to the
//! KV cache.
//!
//! A **strip** is one (layer, K/V, kv-head) region of a KV arena slot:
//! `cap` positions × `hd` channels. The f32 format stores it as
//! `cap × hd` floats; this module defines the packed alternative the
//! format-generic arena ([`crate::serving::kv::KvFormat::BitPlane`])
//! stores instead:
//!
//! ```text
//! strip = [ plane 0 | plane 1 | … | plane bits-1 | coefficients ]
//!
//! plane i   : ceil(cap·hd / 32) u32 words, bit (u·hd + j) = i-th code
//!             bit of channel j at position u — positions are packed
//!             back-to-back at *bit* granularity, so when hd < 32 a
//!             single word holds a whole group of positions (the
//!             "position-group" sharing that makes small-head test
//!             models cheap too);
//! coeffs    : cap × n_groups × (bits+1) f16 values, position-major,
//!             two per u32 word: for position u and channel group g,
//!             [c₀, c₁, …, c_bits] — the per-plane scalars of the
//!             BPDQ grid  x̂ⱼ = c₀ + Σᵢ cᵢ·Bᵢ[j]   (paper Eq. 1).
//! ```
//!
//! The row encoder quantizes one freshly-computed K/V head-row at store
//! time (uniform `2^bits`-level grid per channel group, then a
//! mean-residual refit of `c₀` — the cheapest point on the paper's
//! variable-grid axis, chosen so the max-abs error stays provably
//! bounded by one grid step). Because every coefficient is a free
//! per-plane scalar in the *format*, richer encoders (alternating
//! refits, salience-split planes à la BiLLM) can drop in without a
//! layout change.
//!
//! Writes are masked read-modify-writes touching exactly the stored
//! row's bits, so strips tolerate dirty (reused / forked) memory: bits
//! of a position are never read before that position was stored, and
//! storing clears them first. That is what lets
//! [`crate::serving::kv::KvArena::fork`] copy a live prefix *bytewise*
//! — including a partial word shared with not-yet-written positions —
//! with no re-quantization.
//!
//! **Pages compose with packing.** The paged arena stores each
//! (layer, K/V, kv-head) strip as fixed-size *pages* of `pp` positions,
//! and a packed page is simply a self-contained strip with `cap = pp`
//! ([`PackedGeom::for_page`]): its planes and coefficients are
//! page-local, so page boundaries land on plane-word *and*
//! coefficient-span boundaries by construction. A page dequantizes in
//! isolation and can be shared or copied bytewise between sessions —
//! the variable-grid encoding travels with the page, never re-quantized.

/// Round an f32 to IEEE 754 binary16 bits (round-to-nearest-even).
// lint: hot
pub fn f16_encode(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = bits >> 31;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let frac = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // inf / nan map to f16 inf / nan
        let payload: u16 = if frac == 0 { 0 } else { 0x200 | (((frac >> 13) as u16) & 0x3FF) };
        return ((sign << 15) as u16) | 0x7C00 | payload;
    }
    let e16 = exp - 127 + 15;
    if e16 >= 0x1F {
        // overflow → inf
        ((sign << 15) | 0x7C00) as u16
    } else if e16 <= 0 {
        // subnormal or zero
        if e16 < -10 {
            (sign << 15) as u16
        } else {
            let m = frac | 0x80_0000;
            let shift = (14 - e16) as u32;
            let halfway = 1u32 << (shift - 1);
            let mut m16 = m >> shift;
            // round-to-nearest-even
            let rem = m & ((1 << shift) - 1);
            if rem > halfway || (rem == halfway && (m16 & 1) == 1) {
                m16 += 1;
            }
            ((sign << 15) as u16) | (m16 as u16)
        }
    } else {
        let mut m16 = (frac >> 13) as u32;
        let rem = frac & 0x1FFF;
        let mut e = e16 as u32;
        if rem > 0x1000 || (rem == 0x1000 && (m16 & 1) == 1) {
            m16 += 1;
            if m16 == 0x400 {
                m16 = 0;
                e += 1;
                if e >= 0x1F {
                    return ((sign << 15) | 0x7C00) as u16; // inf
                }
            }
        }
        ((sign << 15) | (e << 10) | m16) as u16
    }
}

/// Decode IEEE 754 binary16 bits to f32.
// lint: hot
pub fn f16_decode(h: u16) -> f32 {
    let hs = (h >> 15) as u32;
    let he = ((h >> 10) & 0x1F) as u32;
    let hf = (h & 0x3FF) as u32;
    let f32_bits = if he == 0 {
        if hf == 0 {
            hs << 31
        } else {
            // subnormal
            let mut e = -1i32;
            let mut m = hf;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3FF;
            (hs << 31) | (((127 - 15 + e + 1) as u32) << 23) | (m << 13)
        }
    } else if he == 0x1F {
        (hs << 31) | 0x7F80_0000 | (hf << 13)
    } else {
        (hs << 31) | ((he + 127 - 15) << 23) | (hf << 13)
    };
    f32::from_bits(f32_bits)
}

/// Geometry of one packed strip: `cap` positions × `hd` channels at
/// `bits` planes, with `group` channels per coefficient group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PackedGeom {
    pub cap: usize,
    pub hd: usize,
    pub bits: usize,
    /// channels per coefficient group, clamped to `min(hd, 64)` at
    /// construction (64 bounds the encoder's stack scratch)
    pub group: usize,
}

impl PackedGeom {
    pub fn new(cap: usize, hd: usize, bits: usize, group: usize) -> Self {
        assert!(hd > 0 && cap > 0, "empty strip geometry");
        assert!((1..=8).contains(&bits), "KV bit-plane count {bits} out of range 1..=8");
        assert!(group > 0, "coefficient group must be positive");
        Self { cap, hd, bits, group: group.min(hd).min(64) }
    }

    /// Geometry of one packed KV **page**: a self-contained mini-strip
    /// of `pp` positions. Identical math to [`PackedGeom::new`] with
    /// `cap = pp` — the named constructor documents the composition
    /// contract (module docs): because every page carries its own
    /// planes and coefficient region, page-granular addressing needs no
    /// cross-page bit arithmetic, and [`PackedGeom::prefix_spans`] of a
    /// *page* stays entirely inside that page's words.
    pub fn for_page(pp: usize, hd: usize, bits: usize, group: usize) -> Self {
        assert!(pp > 0, "empty KV page");
        Self::new(pp, hd, bits, group)
    }

    /// Coefficient groups per position (`hd / group`, last one ragged).
    #[inline]
    pub fn n_groups(&self) -> usize {
        self.hd.div_ceil(self.group)
    }

    /// u32 words per plane sub-region (`cap · hd` bits, rounded up).
    #[inline]
    pub fn plane_words(&self) -> usize {
        (self.cap * self.hd).div_ceil(32)
    }

    /// f16 coefficients per position: `(bits + 1)` per group.
    #[inline]
    pub fn coeffs_per_pos(&self) -> usize {
        self.n_groups() * (self.bits + 1)
    }

    /// u32 words of the coefficient region (two f16 per word).
    #[inline]
    pub fn coeff_words(&self) -> usize {
        (self.cap * self.coeffs_per_pos()).div_ceil(2)
    }

    /// Word offset of the coefficient region within the strip.
    #[inline]
    pub fn coeff_base(&self) -> usize {
        self.bits * self.plane_words()
    }

    /// Total u32 words of one packed strip.
    #[inline]
    pub fn strip_words(&self) -> usize {
        self.coeff_base() + self.coeff_words()
    }

    /// Word spans `(offset, len)` of the live prefix of `pos` positions
    /// — the bytewise copy list for `fork`. Spans may include trailing
    /// bits/halves of position `pos` itself when it shares a word; the
    /// masked store discipline makes those stale bits harmless.
    pub fn prefix_spans(&self, pos: usize) -> Vec<(usize, usize)> {
        assert!(pos <= self.cap, "prefix beyond strip capacity");
        let mut spans = Vec::with_capacity(self.bits + 1);
        let pw = self.plane_words();
        let plane_prefix = (pos * self.hd).div_ceil(32);
        if plane_prefix > 0 {
            for i in 0..self.bits {
                spans.push((i * pw, plane_prefix));
            }
        }
        let coeff_prefix = (pos * self.coeffs_per_pos()).div_ceil(2);
        if coeff_prefix > 0 {
            spans.push((self.coeff_base(), coeff_prefix));
        }
        spans
    }

    #[inline]
    fn coeff_index(&self, u: usize, g: usize, c: usize) -> usize {
        debug_assert!(u < self.cap && g < self.n_groups() && c <= self.bits);
        (u * self.n_groups() + g) * (self.bits + 1) + c
    }
}

/// Read one f16 (index `idx` in the half-word stream) out of packed
/// coefficient words.
// lint: hot
#[inline]
fn get_half(words: &[u32], idx: usize) -> f32 {
    let w = words[idx / 2];
    let h = if idx % 2 == 0 { (w & 0xFFFF) as u16 } else { (w >> 16) as u16 };
    f16_decode(h)
}

/// Write one f16 into the half-word stream (read-modify-write of the
/// containing u32, so neighbours survive).
// lint: hot
#[inline]
fn set_half(words: &mut [u32], idx: usize, v: f32) {
    let h = f16_encode(v) as u32;
    let w = &mut words[idx / 2];
    if idx % 2 == 0 {
        *w = (*w & 0xFFFF_0000) | h;
    } else {
        *w = (*w & 0x0000_FFFF) | (h << 16);
    }
}

/// Byte-granular plane view: the 8 plane bits starting at bit position
/// `bp` (bit `t` of the result = plane bit `bp + t`). This is the unit
/// the table-driven SIMD kernels consume — one subset-sum table lookup
/// per extracted byte instead of a per-bit `trailing_zeros` walk. Plane
/// rows start at `u·hd`, which is not byte-aligned for odd `hd`, so the
/// straddling case reads two words; bits past the end of the plane read
/// as zero (callers mask to their span anyway).
// lint: hot
#[inline]
pub fn plane_byte(plane: &[u32], bp: usize) -> usize {
    let w = bp >> 5;
    let off = bp & 31;
    if off <= 24 {
        ((plane[w] >> off) & 0xFF) as usize
    } else {
        let w0 = plane[w] as u64;
        let w1 = plane.get(w + 1).copied().unwrap_or(0) as u64;
        (((w0 | (w1 << 32)) >> off) & 0xFF) as usize
    }
}

/// Shared read view of one packed strip (`strip_words` u32s).
#[derive(Clone, Copy)]
pub struct PackedStrip<'a> {
    pub geom: PackedGeom,
    pub words: &'a [u32],
}

impl<'a> PackedStrip<'a> {
    pub fn new(geom: PackedGeom, words: &'a [u32]) -> Self {
        assert_eq!(words.len(), geom.strip_words(), "packed strip length mismatch");
        Self { geom, words }
    }

    /// Words of plane `i` (bit `u·hd + j` = code bit of channel `j` at
    /// position `u`).
    // lint: hot
    #[inline]
    pub fn plane(&self, i: usize) -> &'a [u32] {
        let pw = self.geom.plane_words();
        let words: &'a [u32] = self.words;
        &words[i * pw..(i + 1) * pw]
    }

    /// Coefficient `c` (0 = bias c₀, `1..=bits` = plane scalars) of
    /// channel group `g` at position `u`.
    // lint: hot
    #[inline]
    pub fn coeff(&self, u: usize, g: usize, c: usize) -> f32 {
        get_half(&self.words[self.geom.coeff_base()..], self.geom.coeff_index(u, g, c))
    }

    /// Dequantize position `u` into `out` (`hd` wide):
    /// `x̂ⱼ = c₀ + Σᵢ cᵢ·Bᵢ[j]` per group.
    // lint: hot
    pub fn dequant_row(&self, u: usize, out: &mut [f32]) {
        let g = &self.geom;
        // Width mismatches still fault loudly via the bounds-checked
        // slice indexing below; no hard assert in the per-token path.
        debug_assert_eq!(out.len(), g.hd);
        for grp in 0..g.n_groups() {
            let lo = grp * g.group;
            let hi = (lo + g.group).min(g.hd);
            let c0 = self.coeff(u, grp, 0);
            for v in out[lo..hi].iter_mut() {
                *v = c0;
            }
            for i in 0..g.bits {
                let ci = self.coeff(u, grp, 1 + i);
                let plane = self.plane(i);
                for (j, v) in out[lo..hi].iter_mut().enumerate() {
                    let bp = u * g.hd + lo + j;
                    if (plane[bp / 32] >> (bp % 32)) & 1 == 1 {
                        *v += ci;
                    }
                }
            }
        }
    }
}

/// Exclusive write view of one packed strip.
pub struct PackedStripMut<'a> {
    pub geom: PackedGeom,
    pub words: &'a mut [u32],
}

impl<'a> PackedStripMut<'a> {
    pub fn new(geom: PackedGeom, words: &'a mut [u32]) -> Self {
        assert_eq!(words.len(), geom.strip_words(), "packed strip length mismatch");
        Self { geom, words }
    }

    #[inline]
    pub fn as_strip(&self) -> PackedStrip<'_> {
        PackedStrip { geom: self.geom, words: &*self.words }
    }

    /// Quantize and store one `hd`-wide row at position `u`. Per channel
    /// group: a uniform `2^bits`-level grid over `[min, max]`, decomposed
    /// into bit-planes (`cᵢ = step·2ⁱ`), then `c₀` refit by the mean
    /// residual — max abs error ≤ one grid `step` before f16 rounding of
    /// the coefficients. Writes are masked to exactly this row's bits.
    // lint: hot
    pub fn store_row(&mut self, u: usize, x: &[f32]) {
        let g = self.geom;
        // Shape violations still fault loudly via bounds-checked plane/
        // coeff indexing; the arena's store() keeps the hard protocol
        // asserts at the slot boundary.
        debug_assert_eq!(x.len(), g.hd, "row width != head_dim");
        debug_assert!(u < g.cap, "store position beyond strip capacity");
        let levels = ((1u32 << g.bits) - 1) as f32;
        let pw = g.plane_words();
        let cb = g.coeff_base();
        for grp in 0..g.n_groups() {
            let lo = grp * g.group;
            let hi = (lo + g.group).min(g.hd);
            let xs = &x[lo..hi];
            let mut mn = f32::INFINITY;
            let mut mx = f32::NEG_INFINITY;
            for &v in xs {
                mn = mn.min(v);
                mx = mx.max(v);
            }
            let step = if mx > mn { (mx - mn) / levels } else { 0.0 };
            let inv_step = if step > 0.0 { 1.0 / step } else { 0.0 };
            // Codes + mean residual (the c₀ refit that makes the grid
            // "variable": it centres the error instead of flooring it).
            let mut resid_sum = 0.0f32;
            let mut codes = [0u32; 64];
            debug_assert!(xs.len() <= 64, "coefficient group wider than 64 channels");
            for (j, &v) in xs.iter().enumerate() {
                let q = ((v - mn) * inv_step).round().clamp(0.0, levels) as u32;
                codes[j] = q;
                resid_sum += v - (mn + step * q as f32);
            }
            let c0 = mn + resid_sum / xs.len() as f32;
            set_half(&mut self.words[cb..], g.coeff_index(u, grp, 0), c0);
            for i in 0..g.bits {
                set_half(
                    &mut self.words[cb..],
                    g.coeff_index(u, grp, 1 + i),
                    step * (1u32 << i) as f32,
                );
            }
            // Masked plane writes: clear-then-set exactly this row's bits.
            for i in 0..g.bits {
                let plane = &mut self.words[i * pw..(i + 1) * pw];
                for (j, &q) in codes[..xs.len()].iter().enumerate() {
                    let bp = u * g.hd + lo + j;
                    let mask = 1u32 << (bp % 32);
                    if (q >> i) & 1 == 1 {
                        plane[bp / 32] |= mask;
                    } else {
                        plane[bp / 32] &= !mask;
                    }
                }
            }
        }
    }

    /// Quantize and store consecutive rows starting at position `u0` —
    /// the chunked-prefill bulk store. Each row goes through exactly
    /// [`PackedStripMut::store_row`] (which keeps no cross-position
    /// state), so the resulting strip bytes are identical to storing
    /// the rows one call at a time; the caller amortizes what *is*
    /// per-call — page ownership resolution and view construction —
    /// over the whole run.
    // lint: hot
    pub fn store_rows<'r>(&mut self, u0: usize, rows: impl IntoIterator<Item = &'r [f32]>) {
        for (j, row) in rows.into_iter().enumerate() {
            self.store_row(u0 + j, row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn f16_helpers_roundtrip_and_bound() {
        for v in [0.0f32, 1.0, -2.5, 0.333, 65504.0, -65504.0, 1e-4] {
            let r = f16_decode(f16_encode(v));
            assert!((r - v).abs() <= v.abs() * 1e-3 + 1e-7, "{v} -> {r}");
        }
        assert!(f16_decode(f16_encode(1e6)).is_infinite());
        assert!(f16_decode(f16_encode(f32::NAN)).is_nan());
        // idempotent
        let once = f16_decode(f16_encode(0.1));
        assert_eq!(f16_decode(f16_encode(once)), once);
    }

    #[test]
    fn geometry_word_counts() {
        // hd=32: one word per (position, plane); coeffs 3 per pos → 2 words/pos… padded once.
        let g = PackedGeom::new(4, 32, 2, 32);
        assert_eq!(g.n_groups(), 1);
        assert_eq!(g.plane_words(), 4);
        assert_eq!(g.coeffs_per_pos(), 3);
        assert_eq!(g.coeff_words(), 6);
        assert_eq!(g.strip_words(), 2 * 4 + 6);
        // hd=4: 8 positions share one plane word (the position-group).
        let g = PackedGeom::new(16, 4, 3, 8);
        assert_eq!(g.group, 4, "group clamps to hd");
        assert_eq!(g.plane_words(), 2);
        assert_eq!(g.strip_words(), 3 * 2 + (16 * 4).div_ceil(2));
    }

    #[test]
    fn roundtrip_error_bounded_by_grid_step() {
        // Property: pack→unpack max abs error ≤ one grid step per group
        // (plus f16 coefficient rounding) at bits ∈ {2, 3, 4}.
        let mut rng = Rng::new(42);
        for &bits in &[2usize, 3, 4] {
            for &(hd, group) in &[(32usize, 32usize), (8, 8), (48, 16)] {
                let geom = PackedGeom::new(6, hd, bits, group);
                let mut words = vec![0u32; geom.strip_words()];
                let mut strip = PackedStripMut::new(geom, &mut words);
                let rows: Vec<Vec<f32>> = (0..6)
                    .map(|_| (0..hd).map(|_| rng.normal() as f32).collect())
                    .collect();
                for (u, row) in rows.iter().enumerate() {
                    strip.store_row(u, row);
                }
                let view = strip.as_strip();
                let levels = ((1usize << bits) - 1) as f32;
                let mut out = vec![0.0f32; hd];
                for (u, row) in rows.iter().enumerate() {
                    view.dequant_row(u, &mut out);
                    for grp in 0..geom.n_groups() {
                        let lo = grp * geom.group;
                        let hi = (lo + geom.group).min(hd);
                        let mn = row[lo..hi].iter().cloned().fold(f32::INFINITY, f32::min);
                        let mx = row[lo..hi].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                        let step = (mx - mn) / levels;
                        let maxabs = mx.abs().max(mn.abs());
                        for j in lo..hi {
                            let err = (row[j] - out[j]).abs();
                            assert!(
                                err <= step * 1.001 + 2e-3 * (maxabs + 1.0),
                                "bits {bits} hd {hd} u {u} j {j}: err {err} step {step}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn flat_group_is_exact() {
        let geom = PackedGeom::new(2, 8, 2, 8);
        let mut words = vec![0u32; geom.strip_words()];
        let mut strip = PackedStripMut::new(geom, &mut words);
        strip.store_row(0, &[1.5f32; 8]);
        let mut out = vec![0.0f32; 8];
        strip.as_strip().dequant_row(0, &mut out);
        for v in out {
            assert_eq!(v, 1.5, "constant rows survive exactly (1.5 is f16-exact)");
        }
    }

    #[test]
    fn store_rows_is_byte_identical_to_sequential_store_row() {
        // The bulk store must leave *exactly* the words a sequential
        // per-row store leaves — the property the chunked-prefill
        // token-identity bar rests on. Dirty slabs, ragged group, odd
        // hd (position rows straddle plane words).
        let mut rng = Rng::new(9);
        for &(hd, group, bits) in &[(8usize, 8usize, 2usize), (5, 4, 3), (32, 16, 4)] {
            let geom = PackedGeom::new(8, hd, bits, group);
            let rows: Vec<Vec<f32>> =
                (0..5).map(|_| (0..hd).map(|_| rng.normal() as f32).collect()).collect();
            let mut seq_words = vec![0xDEAD_BEEFu32; geom.strip_words()];
            let mut bulk_words = seq_words.clone();
            let mut seq = PackedStripMut::new(geom, &mut seq_words);
            for (j, row) in rows.iter().enumerate() {
                seq.store_row(2 + j, row);
            }
            let mut bulk = PackedStripMut::new(geom, &mut bulk_words);
            bulk.store_rows(2, rows.iter().map(|r| r.as_slice()));
            assert_eq!(seq_words, bulk_words, "hd {hd} bits {bits}");
        }
    }

    #[test]
    fn masked_store_leaves_neighbours_intact() {
        // hd=4 → 8 positions per plane word: storing position 3 must not
        // disturb already-stored position 2 sharing the same word.
        let geom = PackedGeom::new(16, 4, 2, 4);
        let mut words = vec![0xFFFF_FFFFu32; geom.strip_words()]; // dirty slab
        let mut strip = PackedStripMut::new(geom, &mut words);
        let a = [0.5f32, -1.0, 2.0, 0.0];
        let b = [3.0f32, 3.0, -3.0, 1.0];
        strip.store_row(2, &a);
        let mut before = vec![0.0f32; 4];
        strip.as_strip().dequant_row(2, &mut before);
        strip.store_row(3, &b);
        let mut after = vec![0.0f32; 4];
        strip.as_strip().dequant_row(2, &mut after);
        assert_eq!(before, after, "neighbour position changed by a masked store");
    }

    #[test]
    fn page_geometry_composes_with_strip_geometry() {
        // A page is a strip with cap = pp; with pp | cap and pp·hd a
        // word multiple (the serving default: pp 32, hd ≥ 32 even), the
        // paged plane region is word-for-word the monolithic one.
        let mono = PackedGeom::new(1024, 32, 2, 32);
        let page = PackedGeom::for_page(32, 32, 2, 32);
        assert_eq!(page, PackedGeom::new(32, 32, 2, 32));
        let n_pages = 1024 / 32;
        assert_eq!(n_pages * page.strip_words(), mono.strip_words());
        // Ragged case (pp·hd not a word multiple): pages still
        // self-contain — per-page spans never cross a page boundary.
        let small = PackedGeom::for_page(4, 4, 3, 4);
        for pos in 0..=4 {
            for (off, len) in small.prefix_spans(pos) {
                assert!(off + len <= small.strip_words());
            }
        }
    }

    #[test]
    fn prefix_spans_cover_exactly_the_prefix() {
        let geom = PackedGeom::new(16, 4, 2, 4);
        // pos 3 of hd=4: 12 bits → 1 word per plane; 3×3 coeffs → 5 words.
        let spans = geom.prefix_spans(3);
        assert_eq!(spans, vec![(0, 1), (geom.plane_words(), 1), (geom.coeff_base(), 5)]);
        assert!(geom.prefix_spans(0).is_empty());
        let full = geom.prefix_spans(16);
        let covered: usize = full.iter().map(|&(_, n)| n).sum();
        assert_eq!(covered, geom.strip_words(), "full prefix covers the whole strip");
    }
}
