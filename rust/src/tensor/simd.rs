//! Runtime-dispatched SIMD kernel layer — the facade in front of the
//! scalar reference kernels in [`super::ops`].
//!
//! A [`SimdTier`] is selected **once** per process (cached in a
//! `OnceLock`): by default via CPU feature probes
//! (`is_x86_feature_detected!("avx2")` on x86_64, baseline NEON on
//! aarch64), overridable with the `BPDQ_SIMD={auto|scalar|avx2|neon}`
//! env var or `serve --simd`. Requesting a tier the host cannot run is
//! a **loud failure** (panic for the env var, `Err` for the flag) —
//! never a silent fallback — so bench artifacts and parity tests always
//! know which kernels actually ran.
//!
//! Every dispatched kernel has a `*_t` twin taking an explicit tier so
//! tests and benches can force each tier on one host. The scalar
//! reference in `ops` is the semantic ground truth; the parity contract
//! per kernel family is spelled out in `tensor/mod.rs` ("SIMD dispatch
//! & numerics policy").
//!
//! The packed-KV kernels do not use per-bit intrinsics at all: they
//! apply the LUT-GEMM subset-sum trick to plane bytes — one 256-entry
//! partial-dot table per 8-channel chunk, built once per call, then one
//! table lookup per (plane, chunk) instead of a `trailing_zeros` walk.
//! Tables store ascending-bit-order f32 chains, which makes them
//! bit-exact against the chunked scalar fold (see `ops::fold_set_bits`).

use super::kvpack::{plane_byte, PackedStrip};
use super::{ops, Matrix};
use std::sync::OnceLock;

/// Kernel dispatch tier. `Scalar` is always supported; `Avx2`/`Neon`
/// are only constructible (via [`SimdTier::parse`] / [`set_tier`] /
/// [`SimdTier::detect`]) on hosts that can actually execute them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdTier {
    Scalar,
    Avx2,
    Neon,
}

impl SimdTier {
    /// Stable lowercase name — used in bench JSON rows, the serve
    /// banner, and `LatencySummary`.
    pub fn label(self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Avx2 => "avx2",
            SimdTier::Neon => "neon",
        }
    }

    /// Parse a tier spec (`auto|scalar|avx2|neon`). `auto` resolves to
    /// [`SimdTier::detect`]. Unknown names and tiers the host cannot
    /// execute are errors — an unsupported tier must fail loudly here,
    /// not fall back at dispatch time.
    pub fn parse(spec: &str) -> Result<SimdTier, String> {
        let tier = match spec {
            "auto" => return Ok(SimdTier::detect()),
            "scalar" => SimdTier::Scalar,
            "avx2" => SimdTier::Avx2,
            "neon" => SimdTier::Neon,
            _ => {
                return Err(format!(
                    "unknown SIMD tier `{spec}` (expected auto|scalar|avx2|neon)"
                ))
            }
        };
        if !tier.is_supported() {
            return Err(format!("SIMD tier `{spec}` is not supported on this host"));
        }
        Ok(tier)
    }

    /// Can this host execute the tier's kernels?
    pub fn is_supported(self) -> bool {
        match self {
            SimdTier::Scalar => true,
            SimdTier::Avx2 => x86_has_avx2(),
            SimdTier::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// Probe the host: AVX2 if detected, NEON on aarch64 (baseline
    /// feature), scalar otherwise.
    pub fn detect() -> SimdTier {
        if SimdTier::Avx2.is_supported() {
            SimdTier::Avx2
        } else if SimdTier::Neon.is_supported() {
            SimdTier::Neon
        } else {
            SimdTier::Scalar
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn x86_has_avx2() -> bool {
    is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn x86_has_avx2() -> bool {
    false
}

static ACTIVE: OnceLock<SimdTier> = OnceLock::new();

/// The process-wide tier, resolved once on first use: `BPDQ_SIMD` if
/// set (an invalid or unsupported value panics — requesting a specific
/// tier and silently getting another would invalidate every artifact
/// that records it), else [`SimdTier::detect`].
pub fn active() -> SimdTier {
    *ACTIVE.get_or_init(|| match std::env::var("BPDQ_SIMD") {
        Ok(spec) => match SimdTier::parse(&spec) {
            Ok(tier) => tier,
            Err(e) => panic!("BPDQ_SIMD: {e}"),
        },
        Err(_) => SimdTier::detect(),
    })
}

/// Pin the process-wide tier (the `serve --simd` path; takes precedence
/// over `BPDQ_SIMD` because it runs before any kernel dispatches).
/// Errors if the tier is unsupported on this host or if dispatch
/// already latched a different tier.
pub fn set_tier(tier: SimdTier) -> Result<(), String> {
    if !tier.is_supported() {
        return Err(format!(
            "SIMD tier `{}` is not supported on this host",
            tier.label()
        ));
    }
    let got = *ACTIVE.get_or_init(|| tier);
    if got == tier {
        Ok(())
    } else {
        Err(format!(
            "SIMD tier already pinned to `{}` — set it before any kernel runs",
            got.label()
        ))
    }
}

/// Reusable workspace for the table-driven packed kernels: the
/// per-lane subset-sum tables (`ceil(hd/8) × 256` entries) and the
/// per-group activation sums. Owned by whoever drives a decode loop
/// (`DecodeState`, `BatchedLutStep`, benches) so the hot path stays
/// allocation-free after warmup (`resize` reuses capacity).
#[derive(Debug, Default)]
pub struct SimdScratch {
    lut: Vec<f32>,
    qsums: Vec<f32>,
}

/// Positions below this length skip the table path even on SIMD tiers:
/// building the subset-sum tables costs `ceil(hd/8) × 256` adds per
/// lane, which only amortizes once enough positions reuse them. Values
/// are bit-identical either way (the chunked scalar fold is the table
/// path's twin), so this threshold is purely a cost model.
const PACKED_TABLE_MIN_LEN: usize = 16;

// ---------------------------------------------------------------------------
// Dispatched kernels (active-tier wrappers + explicit-tier `_t` twins)
// ---------------------------------------------------------------------------

/// Dispatched contiguous dot product.
// lint: hot
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_t(active(), a, b)
}

/// [`dot`] at an explicit tier. Tolerance-bounded vs the scalar
/// reference (SIMD tiers reassociate the reduction).
// lint: hot
#[inline]
pub fn dot_t(tier: SimdTier, a: &[f32], b: &[f32]) -> f32 {
    debug_assert!(tier.is_supported());
    match tier {
        SimdTier::Scalar => ops::dot(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx2 tier is only constructible on hosts where
        // `is_x86_feature_detected!("avx2")` reported support, so the
        // target feature is present at every dispatch site.
        SimdTier::Avx2 => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is a baseline aarch64 target feature; the fn is
        // unsafe only for uniformity with the avx2 twin.
        SimdTier::Neon => unsafe { neon::dot(a, b) },
        // Tiers foreign to this ISA are rejected by `is_supported`
        // before they can reach dispatch; keep the scalar reference as
        // the statically-complete arm.
        _ => ops::dot(a, b),
    }
}

/// Dispatched `y += alpha * x`.
// lint: hot
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    axpy_t(active(), alpha, x, y)
}

/// [`axpy`] at an explicit tier. Bit-exact across tiers: every element
/// is one mul + one add with no reassociation, so the vector lanes
/// perform the identical IEEE ops.
// lint: hot
#[inline]
pub fn axpy_t(tier: SimdTier, alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert!(tier.is_supported());
    match tier {
        SimdTier::Scalar => ops::axpy(alpha, x, y),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only constructible when the host supports it.
        SimdTier::Avx2 => unsafe { avx2::axpy(alpha, x, y) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is a baseline aarch64 feature.
        SimdTier::Neon => unsafe { neon::axpy(alpha, x, y) },
        _ => ops::axpy(alpha, x, y),
    }
}

/// Dispatched batched f32 strip dots (see [`ops::strip_dots`]).
// lint: hot
pub fn strip_dots(qs: &[&[f32]], strips: &[&[f32]], hd: usize, scale: f32, scores: &mut [f32]) {
    strip_dots_t(active(), qs, strips, hd, scale, scores)
}

/// [`strip_dots`] at an explicit tier: the scalar loop structure with
/// every row dot dispatched. Tolerance-bounded like [`dot_t`].
// lint: hot
pub fn strip_dots_t(
    tier: SimdTier,
    qs: &[&[f32]],
    strips: &[&[f32]],
    hd: usize,
    scale: f32,
    scores: &mut [f32],
) {
    if tier == SimdTier::Scalar {
        ops::strip_dots(qs, strips, hd, scale, scores);
        return;
    }
    let nb = qs.len();
    debug_assert_eq!(strips.len(), nb);
    if nb == 0 {
        return;
    }
    debug_assert_eq!(scores.len() % nb, 0);
    let len = scores.len() / nb;
    for u in 0..len {
        let o = u * hd;
        for b in 0..nb {
            scores[b * len + u] = dot_t(tier, qs[b], &strips[b][o..o + hd]) * scale;
        }
    }
}

/// Dispatched batched f32 strip axpys (see [`ops::strip_axpys`]).
// lint: hot
pub fn strip_axpys(ws: &[f32], strips: &[&[f32]], hd: usize, outs: &mut [&mut [f32]]) {
    strip_axpys_t(active(), ws, strips, hd, outs)
}

/// [`strip_axpys`] at an explicit tier. Bit-exact across tiers: the
/// `w < 1e-9` softmax-weight skip is replicated verbatim (same
/// comparison, same walk order) and [`axpy_t`] is per-element exact.
// lint: hot
pub fn strip_axpys_t(
    tier: SimdTier,
    ws: &[f32],
    strips: &[&[f32]],
    hd: usize,
    outs: &mut [&mut [f32]],
) {
    if tier == SimdTier::Scalar {
        ops::strip_axpys(ws, strips, hd, outs);
        return;
    }
    let nb = outs.len();
    debug_assert_eq!(strips.len(), nb);
    if nb == 0 {
        return;
    }
    debug_assert_eq!(ws.len() % nb, 0);
    let len = ws.len() / nb;
    for u in 0..len {
        let o = u * hd;
        for b in 0..nb {
            let w = ws[b * len + u];
            debug_assert!(w >= 0.0, "strip_axpys weights must be softmax outputs (got {w})");
            if w < 1e-9 {
                continue;
            }
            axpy_t(tier, w, &strips[b][o..o + hd], &mut *outs[b]);
        }
    }
}

/// Dispatched fused-dequant packed strip dots (see
/// [`ops::strip_dots_packed`]). `scratch` holds the subset-sum tables;
/// callers that loop (engines, decode states) should reuse one.
// lint: hot
pub fn strip_dots_packed(
    qs: &[&[f32]],
    strips: &[PackedStrip],
    len: usize,
    scale: f32,
    scores: &mut [f32],
    scratch: &mut SimdScratch,
) {
    strip_dots_packed_t(active(), qs, strips, len, scale, scores, scratch)
}

/// [`strip_dots_packed`] at an explicit tier. **Bit-exact** across
/// tiers: on SIMD tiers each plane's partial dot is one table lookup
/// per 8-channel chunk, and the tables store the same ascending-order
/// f32 chains the chunked scalar fold accumulates.
// lint: hot
pub fn strip_dots_packed_t(
    tier: SimdTier,
    qs: &[&[f32]],
    strips: &[PackedStrip],
    len: usize,
    scale: f32,
    scores: &mut [f32],
    scratch: &mut SimdScratch,
) {
    debug_assert!(tier.is_supported());
    if tier == SimdTier::Scalar || len < PACKED_TABLE_MIN_LEN {
        ops::strip_dots_packed(qs, strips, len, scale, scores);
        return;
    }
    let nb = qs.len();
    debug_assert_eq!(strips.len(), nb);
    if nb == 0 {
        return;
    }
    debug_assert_eq!(scores.len(), nb * len);
    let geom = strips[0].geom;
    let (hd, bits, group, ng) = (geom.hd, geom.bits, geom.group, geom.n_groups());
    let n_chunks = hd.div_ceil(8);
    scratch.lut.resize(n_chunks * 256, 0.0);
    scratch.qsums.resize(ng, 0.0);
    // Lane-outer (unlike the position-outer scalar walk) so one lane's
    // tables stay hot; per-(b, u) scores are independent, so the loop
    // order cannot change any value.
    for b in 0..nb {
        let st = &strips[b];
        debug_assert_eq!(st.geom, geom);
        let q = qs[b];
        debug_assert_eq!(q.len(), hd);
        build_chunk_tables(q, n_chunks, &mut scratch.lut);
        for g in 0..ng {
            let lo = g * group;
            let hi = (lo + group).min(hd);
            scratch.qsums[g] = q[lo..hi].iter().sum();
        }
        for u in 0..len {
            let row0 = u * hd;
            let mut s = 0.0f32;
            for g in 0..ng {
                let lo = g * group;
                let hi = (lo + group).min(hd);
                s += st.coeff(u, g, 0) * scratch.qsums[g];
                for i in 0..bits {
                    let plane = st.plane(i);
                    let mut pd = 0.0f32;
                    let mut j = lo;
                    while j < hi {
                        let c = j >> 3;
                        let take = ((c + 1) * 8).min(hi) - j;
                        let byte = plane_byte(plane, row0 + j) & ((1usize << take) - 1);
                        // Shift maps extracted bit t (channel j + t) to
                        // table bit (j - 8c) + t, pairing it with
                        // q[8c + (j - 8c) + t] = q[j + t].
                        pd += scratch.lut[c * 256 + (byte << (j - c * 8))];
                        j += take;
                    }
                    s += st.coeff(u, g, 1 + i) * pd;
                }
            }
            scores[b * len + u] = s * scale;
        }
    }
}

/// Dispatched fused-dequant packed strip axpys (see
/// [`ops::strip_axpys_packed`]).
// lint: hot
pub fn strip_axpys_packed(
    ws: &[f32],
    strips: &[PackedStrip],
    len: usize,
    outs: &mut [&mut [f32]],
) {
    strip_axpys_packed_t(active(), ws, strips, len, outs)
}

/// [`strip_axpys_packed`] at an explicit tier. **Bit-exact** across
/// tiers: channels are updated independently (blend-masked vector adds
/// on full 8-channel chunks, a bit walk on ragged edges), the per-lane
/// position order is unchanged, and the `w < 1e-9` softmax-weight skip
/// is replicated verbatim.
// lint: hot
pub fn strip_axpys_packed_t(
    tier: SimdTier,
    ws: &[f32],
    strips: &[PackedStrip],
    len: usize,
    outs: &mut [&mut [f32]],
) {
    debug_assert!(tier.is_supported());
    if tier == SimdTier::Scalar {
        ops::strip_axpys_packed(ws, strips, len, outs);
        return;
    }
    let nb = outs.len();
    debug_assert_eq!(strips.len(), nb);
    if nb == 0 {
        return;
    }
    debug_assert_eq!(ws.len(), nb * len);
    // Lane-outer like the packed dots: each out row still sees
    // positions in ascending order, so its f32 accumulation sequence is
    // identical to the position-outer scalar walk.
    for b in 0..nb {
        let st = &strips[b];
        let geom = st.geom;
        let (hd, bits, group) = (geom.hd, geom.bits, geom.group);
        let out = &mut *outs[b];
        debug_assert_eq!(out.len(), hd);
        for u in 0..len {
            let w = ws[b * len + u];
            debug_assert!(w >= 0.0, "strip_axpys_packed weights must be softmax (got {w})");
            if w < 1e-9 {
                continue;
            }
            let row0 = u * hd;
            for g in 0..geom.n_groups() {
                let lo = g * group;
                let hi = (lo + group).min(hd);
                let base = w * st.coeff(u, g, 0);
                for v in out[lo..hi].iter_mut() {
                    *v += base;
                }
                for i in 0..bits {
                    let add = w * st.coeff(u, g, 1 + i);
                    let plane = st.plane(i);
                    let mut j = lo;
                    while j < hi {
                        let c = j >> 3;
                        let take = ((c + 1) * 8).min(hi) - j;
                        let byte = plane_byte(plane, row0 + j) & ((1usize << take) - 1);
                        if take == 8 {
                            scatter_add8_t(tier, &mut out[j..j + 8], byte, add);
                        } else {
                            let mut m = byte;
                            while m != 0 {
                                let t = m.trailing_zeros() as usize;
                                out[j + t] += add;
                                m &= m - 1;
                            }
                        }
                        j += take;
                    }
                }
            }
        }
    }
}

/// Dispatched RMSNorm (see [`ops::rmsnorm`]).
// lint: hot
pub fn rmsnorm(x: &[f32], gain: &[f32], eps: f32, out: &mut [f32]) {
    rmsnorm_t(active(), x, gain, eps, out)
}

/// [`rmsnorm`] at an explicit tier. Tolerance-bounded: only the f64
/// sum of squares reassociates; the f32 epilogue is per-element
/// identical to the scalar reference.
// lint: hot
pub fn rmsnorm_t(tier: SimdTier, x: &[f32], gain: &[f32], eps: f32, out: &mut [f32]) {
    if tier == SimdTier::Scalar {
        ops::rmsnorm(x, gain, eps, out);
        return;
    }
    debug_assert_eq!(x.len(), gain.len());
    let ms = sumsq_t(tier, x) / x.len() as f64;
    let inv = 1.0 / (ms + eps as f64).sqrt() as f32;
    for ((o, &xv), &g) in out.iter_mut().zip(x).zip(gain) {
        *o = xv * inv * g;
    }
}

/// Dispatched in-place softmax (see [`ops::softmax`]).
// lint: hot
pub fn softmax(xs: &mut [f32]) {
    softmax_t(active(), xs)
}

/// [`softmax`] at an explicit tier. **Value-exact** across tiers: the
/// vectorized max is an associative reduction (any association yields
/// the same maximum) and the exp + sum + scale passes are the scalar
/// reference verbatim.
// lint: hot
pub fn softmax_t(tier: SimdTier, xs: &mut [f32]) {
    if tier == SimdTier::Scalar {
        ops::softmax(xs);
        return;
    }
    let max = max_t(tier, xs);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

/// Dispatched matvec: every row through [`dot_t`] with the tier
/// hoisted out of the row loop (decode-path linears and the lm_head).
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    let tier = active();
    debug_assert_eq!(a.cols(), x.len());
    (0..a.rows()).map(|i| dot_t(tier, a.row(i), x)).collect()
}

/// `dot[b] += luts[b*256 + byte]` for every LUT lane — the gather +
/// accumulate inner step of `lut_gemm`. **Bit-exact** across tiers:
/// lanes are independent and the vector add performs the identical
/// per-lane IEEE op. AVX2 uses a hardware gather for blocks of 8
/// lanes; NEON has no gather, so it shares the scalar loop.
// lint: hot
#[inline]
pub fn lut_gather_add(tier: SimdTier, luts: &[f32], byte: usize, dot: &mut [f32]) {
    debug_assert!(byte < 256);
    debug_assert!(luts.len() >= dot.len() * 256);
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only constructible when the host supports it;
        // the gather indices are bounded by the debug-asserted
        // `luts.len() >= dot.len() * 256` contract (checked again
        // inside via slice indexing on the scalar tail).
        SimdTier::Avx2 if dot.len() >= 8 => unsafe { avx2::lut_gather_add(luts, byte, dot) },
        _ => {
            for (d, l) in dot.iter_mut().zip(luts.chunks_exact(256)) {
                *d += l[byte];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Internal helpers
// ---------------------------------------------------------------------------

/// Build the per-chunk subset-sum tables over one activation row:
/// `lut[c*256 + p] = Σ_{t ∈ bits(p)} q[8c + t]`, accumulated in
/// **ascending bit order from 0.0** (remove-highest-bit recursion), so
/// every entry is the exact chain the chunked scalar fold would
/// compute for the same byte.
// lint: hot
fn build_chunk_tables(q: &[f32], n_chunks: usize, lut: &mut [f32]) {
    debug_assert_eq!(lut.len(), n_chunks * 256);
    for c in 0..n_chunks {
        let t = &mut lut[c * 256..(c + 1) * 256];
        t[0] = 0.0;
        for hi_bit in 0..8usize {
            let qv = q.get(c * 8 + hi_bit).copied().unwrap_or(0.0);
            let w = 1usize << hi_bit;
            for p in 0..w {
                t[w + p] = t[p] + qv;
            }
        }
    }
}

/// `out[t] += add` for every set bit `t` of `byte` over one aligned
/// 8-channel chunk (`out.len() >= 8`). Vector tiers blend-mask the add
/// so untouched lanes keep their exact bit patterns.
// lint: hot
#[inline]
fn scatter_add8_t(tier: SimdTier, out: &mut [f32], byte: usize, add: f32) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only constructible when the host supports it;
        // callers pass `out.len() >= 8` (debug-asserted inside).
        SimdTier::Avx2 => unsafe { avx2::scatter_add8(out, byte, add) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is a baseline aarch64 feature.
        SimdTier::Neon => unsafe { neon::scatter_add8(out, byte, add) },
        _ => {
            let mut m = byte;
            while m != 0 {
                let t = m.trailing_zeros() as usize;
                out[t] += add;
                m &= m - 1;
            }
        }
    }
}

/// f64 sum of squares of an f32 slice (the rmsnorm reduction).
// lint: hot
#[inline]
fn sumsq_t(tier: SimdTier, x: &[f32]) -> f64 {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only constructible when the host supports it.
        SimdTier::Avx2 => unsafe { avx2::sumsq_f64(x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is a baseline aarch64 feature.
        SimdTier::Neon => unsafe { neon::sumsq_f64(x) },
        _ => x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>(),
    }
}

/// Maximum element (softmax max pass; `NEG_INFINITY` identity).
// lint: hot
#[inline]
fn max_t(tier: SimdTier, xs: &[f32]) -> f32 {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only constructible when the host supports it.
        SimdTier::Avx2 => unsafe { avx2::max(xs) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is a baseline aarch64 feature.
        SimdTier::Neon => unsafe { neon::max(xs) },
        _ => xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
    }
}

// ---------------------------------------------------------------------------
// AVX2 intrinsics
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    // Every fn here is `unsafe fn` with one whole-body `unsafe` block:
    // the pointer loads/stores are genuinely unsafe on every toolchain,
    // while the register-only intrinsics flipped to safe-in-context
    // when `#[target_feature]` calls did — `allow(unused_unsafe)` keeps
    // both compiler generations warning-free under `-D warnings`.

    /// 8-lane dot product, single accumulator + scalar tail.
    // lint: hot
    // SAFETY: callers must guarantee the host supports AVX2 (dispatch
    // only constructs the Avx2 tier after feature detection). All
    // memory access is unaligned loads fully inside the two slices.
    #[allow(unused_unsafe)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        // SAFETY: every load reads 8 in-bounds f32s from a
        // `chunks_exact(8)` subslice; the tail is safe indexing.
        unsafe {
            let mut acc = _mm256_setzero_ps();
            let mut ia = a.chunks_exact(8);
            let mut ib = b.chunks_exact(8);
            for (ca, cb) in (&mut ia).zip(&mut ib) {
                let va = _mm256_loadu_ps(ca.as_ptr());
                let vb = _mm256_loadu_ps(cb.as_ptr());
                // mul + add (not FMA) so the per-lane ops match the
                // scalar reference's rounding.
                acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
            }
            let lo = _mm256_castps256_ps128(acc);
            let hi = _mm256_extractf128_ps::<1>(acc);
            let s4 = _mm_add_ps(lo, hi);
            let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
            let s1 = _mm_add_ss(s2, _mm_shuffle_ps::<0b01>(s2, s2));
            let mut s = _mm_cvtss_f32(s1);
            for (&xa, &xb) in ia.remainder().iter().zip(ib.remainder()) {
                s += xa * xb;
            }
            s
        }
    }

    /// 8-lane `y += alpha * x` (bit-exact: per-element mul + add).
    // lint: hot
    // SAFETY: callers must guarantee AVX2 (dispatch-gated); all loads
    // and stores stay inside the two slices.
    #[allow(unused_unsafe)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        // SAFETY: loads/stores cover 8 in-bounds f32s per
        // `chunks_exact(_mut)(8)` subslice; the tail is safe indexing.
        unsafe {
            let va = _mm256_set1_ps(alpha);
            let mut ix = x.chunks_exact(8);
            let mut iy = y.chunks_exact_mut(8);
            for (cx, cy) in (&mut ix).zip(&mut iy) {
                let vy = _mm256_loadu_ps(cy.as_ptr());
                let vx = _mm256_loadu_ps(cx.as_ptr());
                _mm256_storeu_ps(cy.as_mut_ptr(), _mm256_add_ps(vy, _mm256_mul_ps(va, vx)));
            }
            for (&xv, yv) in ix.remainder().iter().zip(iy.into_remainder()) {
                *yv += alpha * xv;
            }
        }
    }

    /// f64 sum of squares of an f32 slice, 4 lanes at a time.
    // lint: hot
    // SAFETY: callers must guarantee AVX2 (dispatch-gated); loads stay
    // inside `x`.
    #[allow(unused_unsafe)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn sumsq_f64(x: &[f32]) -> f64 {
        // SAFETY: each load reads 4 in-bounds f32s from a
        // `chunks_exact(4)` subslice; the tail is safe iteration.
        unsafe {
            let mut acc = _mm256_setzero_pd();
            let mut it = x.chunks_exact(4);
            for c in &mut it {
                let v = _mm256_cvtps_pd(_mm_loadu_ps(c.as_ptr()));
                acc = _mm256_add_pd(acc, _mm256_mul_pd(v, v));
            }
            let lo = _mm256_castpd256_pd128(acc);
            let hi = _mm256_extractf128_pd::<1>(acc);
            let s2 = _mm_add_pd(lo, hi);
            let s1 = _mm_add_sd(s2, _mm_unpackhi_pd(s2, s2));
            let mut s = _mm_cvtsd_f64(s1);
            for &v in it.remainder() {
                s += (v as f64) * (v as f64);
            }
            s
        }
    }

    /// Maximum element (associative reduction — value-exact).
    // lint: hot
    // SAFETY: callers must guarantee AVX2 (dispatch-gated); loads stay
    // inside `xs`.
    #[allow(unused_unsafe)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn max(xs: &[f32]) -> f32 {
        // SAFETY: each load reads 8 in-bounds f32s from a
        // `chunks_exact(8)` subslice; the tail is safe iteration.
        unsafe {
            let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
            let mut it = xs.chunks_exact(8);
            for c in &mut it {
                acc = _mm256_max_ps(acc, _mm256_loadu_ps(c.as_ptr()));
            }
            let lo = _mm256_castps256_ps128(acc);
            let hi = _mm256_extractf128_ps::<1>(acc);
            let m4 = _mm_max_ps(lo, hi);
            let m2 = _mm_max_ps(m4, _mm_movehl_ps(m4, m4));
            let m1 = _mm_max_ss(m2, _mm_shuffle_ps::<0b01>(m2, m2));
            let mut m = _mm_cvtss_f32(m1);
            for &v in it.remainder() {
                m = m.max(v);
            }
            m
        }
    }

    /// Masked `out[t] += add` over the 8 bits of `byte`. The blend
    /// keeps unselected lanes' original bit patterns, so channels with
    /// a clear bit are untouched exactly as in the scalar walk.
    // lint: hot
    // SAFETY: callers must guarantee AVX2 (dispatch-gated) and
    // `out.len() >= 8` (debug-asserted).
    #[allow(unused_unsafe)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn scatter_add8(out: &mut [f32], byte: usize, add: f32) {
        debug_assert!(out.len() >= 8);
        // SAFETY: the load and store touch the first 8 f32s of `out`,
        // in bounds per the length contract above.
        unsafe {
            let bits = _mm256_setr_epi32(1, 2, 4, 8, 16, 32, 64, 128);
            let sel = _mm256_set1_epi32(byte as i32);
            let mask = _mm256_castsi256_ps(_mm256_cmpeq_epi32(_mm256_and_si256(sel, bits), bits));
            let cur = _mm256_loadu_ps(out.as_ptr());
            let upd = _mm256_add_ps(cur, _mm256_set1_ps(add));
            _mm256_storeu_ps(out.as_mut_ptr(), _mm256_blendv_ps(cur, upd, mask));
        }
    }

    /// `dot[b] += luts[b*256 + byte]` via hardware gather over blocks
    /// of 8 LUT lanes, scalar remainder.
    // lint: hot
    // SAFETY: callers must guarantee AVX2 (dispatch-gated) and
    // `luts.len() >= dot.len() * 256` with `byte < 256`, so every
    // gathered index is in bounds.
    #[allow(unused_unsafe)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn lut_gather_add(luts: &[f32], byte: usize, dot: &mut [f32]) {
        debug_assert!(byte < 256);
        debug_assert!(luts.len() >= dot.len() * 256);
        // SAFETY: gather indices are `blk*256 + byte + 256*lane` with
        // `blk + 8 <= dot.len()`, all below `luts.len()` per the
        // contract above; `dot` loads/stores are in-bounds subslices.
        unsafe {
            let strides = _mm256_setr_epi32(0, 256, 512, 768, 1024, 1280, 1536, 1792);
            let nb = dot.len();
            let mut blk = 0usize;
            while blk + 8 <= nb {
                let base = _mm256_set1_epi32((blk * 256 + byte) as i32);
                let idx = _mm256_add_epi32(base, strides);
                let vals = _mm256_i32gather_ps::<4>(luts.as_ptr(), idx);
                let cur = _mm256_loadu_ps(dot[blk..].as_ptr());
                _mm256_storeu_ps(dot[blk..].as_mut_ptr(), _mm256_add_ps(cur, vals));
                blk += 8;
            }
            for b in blk..nb {
                dot[b] += luts[b * 256 + byte];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// NEON intrinsics (aarch64 — NEON is a baseline target feature there)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    // Mirrors of the avx2 module at 4-lane width; `unsafe fn` for
    // uniformity with the avx2 twins (NEON itself is baseline on
    // aarch64), same whole-body-unsafe + `allow(unused_unsafe)` shape
    // for toolchain-generation robustness.

    /// 4-lane dot product.
    // lint: hot
    // SAFETY: loads stay inside the two slices; NEON is baseline.
    #[allow(unused_unsafe)]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        // SAFETY: each load reads 4 in-bounds f32s from a
        // `chunks_exact(4)` subslice.
        unsafe {
            let mut acc = vdupq_n_f32(0.0);
            let mut ia = a.chunks_exact(4);
            let mut ib = b.chunks_exact(4);
            for (ca, cb) in (&mut ia).zip(&mut ib) {
                let va = vld1q_f32(ca.as_ptr());
                let vb = vld1q_f32(cb.as_ptr());
                // mul + add (not vfmaq) to match scalar rounding.
                acc = vaddq_f32(acc, vmulq_f32(va, vb));
            }
            let mut s = vaddvq_f32(acc);
            for (&xa, &xb) in ia.remainder().iter().zip(ib.remainder()) {
                s += xa * xb;
            }
            s
        }
    }

    /// 4-lane `y += alpha * x` (bit-exact: per-element mul + add).
    // lint: hot
    // SAFETY: loads/stores stay inside the two slices; NEON is baseline.
    #[allow(unused_unsafe)]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        // SAFETY: each load/store covers 4 in-bounds f32s from a
        // `chunks_exact(_mut)(4)` subslice.
        unsafe {
            let va = vdupq_n_f32(alpha);
            let mut ix = x.chunks_exact(4);
            let mut iy = y.chunks_exact_mut(4);
            for (cx, cy) in (&mut ix).zip(&mut iy) {
                let vy = vld1q_f32(cy.as_ptr());
                let vx = vld1q_f32(cx.as_ptr());
                vst1q_f32(cy.as_mut_ptr(), vaddq_f32(vy, vmulq_f32(va, vx)));
            }
            for (&xv, yv) in ix.remainder().iter().zip(iy.into_remainder()) {
                *yv += alpha * xv;
            }
        }
    }

    /// f64 sum of squares, 2 lanes at a time.
    // lint: hot
    // SAFETY: loads stay inside `x`; NEON is baseline.
    #[allow(unused_unsafe)]
    pub unsafe fn sumsq_f64(x: &[f32]) -> f64 {
        // SAFETY: each load reads 2 in-bounds f32s from a
        // `chunks_exact(2)` subslice.
        unsafe {
            let mut acc = vdupq_n_f64(0.0);
            let mut it = x.chunks_exact(2);
            for c in &mut it {
                let v = vcvt_f64_f32(vld1_f32(c.as_ptr()));
                acc = vaddq_f64(acc, vmulq_f64(v, v));
            }
            let mut s = vaddvq_f64(acc);
            for &v in it.remainder() {
                s += (v as f64) * (v as f64);
            }
            s
        }
    }

    /// Maximum element (associative reduction — value-exact).
    // lint: hot
    // SAFETY: loads stay inside `xs`; NEON is baseline.
    #[allow(unused_unsafe)]
    pub unsafe fn max(xs: &[f32]) -> f32 {
        // SAFETY: each load reads 4 in-bounds f32s from a
        // `chunks_exact(4)` subslice.
        unsafe {
            let mut acc = vdupq_n_f32(f32::NEG_INFINITY);
            let mut it = xs.chunks_exact(4);
            for c in &mut it {
                acc = vmaxq_f32(acc, vld1q_f32(c.as_ptr()));
            }
            let mut m = vmaxvq_f32(acc);
            for &v in it.remainder() {
                m = m.max(v);
            }
            m
        }
    }

    /// Masked `out[t] += add` over the 8 bits of `byte`, two 4-lane
    /// halves; `vbslq` keeps unselected lanes' exact bit patterns.
    // lint: hot
    // SAFETY: callers pass `out.len() >= 8` (debug-asserted); NEON is
    // baseline.
    #[allow(unused_unsafe)]
    pub unsafe fn scatter_add8(out: &mut [f32], byte: usize, add: f32) {
        debug_assert!(out.len() >= 8);
        // SAFETY: loads/stores touch out[0..4] and out[4..8], in
        // bounds per the length contract above.
        unsafe {
            let bits_lo: [u32; 4] = [1, 2, 4, 8];
            let bits_hi: [u32; 4] = [16, 32, 64, 128];
            let sel = vdupq_n_u32(byte as u32);
            let va = vdupq_n_f32(add);
            let m_lo = vtstq_u32(sel, vld1q_u32(bits_lo.as_ptr()));
            let cur_lo = vld1q_f32(out.as_ptr());
            vst1q_f32(out.as_mut_ptr(), vbslq_f32(m_lo, vaddq_f32(cur_lo, va), cur_lo));
            let m_hi = vtstq_u32(sel, vld1q_u32(bits_hi.as_ptr()));
            let cur_hi = vld1q_f32(out[4..].as_ptr());
            vst1q_f32(out[4..].as_mut_ptr(), vbslq_f32(m_hi, vaddq_f32(cur_hi, va), cur_hi));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_rejects_unknown_and_unsupported() {
        assert!(SimdTier::parse("bogus").is_err());
        assert!(SimdTier::parse("").is_err());
        for tier in [SimdTier::Avx2, SimdTier::Neon] {
            if !tier.is_supported() {
                assert!(SimdTier::parse(tier.label()).is_err());
                assert!(set_tier(tier).is_err());
            }
        }
    }

    #[test]
    fn parse_auto_resolves_to_supported() {
        let t = SimdTier::parse("auto").unwrap();
        assert!(t.is_supported());
        assert_eq!(t, SimdTier::detect());
        assert_eq!(SimdTier::parse("scalar").unwrap(), SimdTier::Scalar);
    }

    #[test]
    fn active_is_supported_and_stable() {
        let t = active();
        assert!(t.is_supported());
        assert_eq!(active(), t);
        // Re-pinning the already-active tier is fine; it only errors on
        // a conflicting tier.
        assert!(set_tier(t).is_ok());
    }

    #[test]
    fn labels_roundtrip() {
        for tier in [SimdTier::Scalar, SimdTier::Avx2, SimdTier::Neon] {
            if tier.is_supported() {
                assert_eq!(SimdTier::parse(tier.label()).unwrap(), tier);
            }
        }
    }

    #[test]
    fn detected_tier_dot_close_to_scalar() {
        let tier = SimdTier::detect();
        let a: Vec<f32> = (0..137).map(|i| ((i * 37 % 101) as f32 - 50.0) / 25.0).collect();
        let b: Vec<f32> = (0..137).map(|i| ((i * 53 % 97) as f32 - 48.0) / 24.0).collect();
        let s = dot_t(SimdTier::Scalar, &a, &b);
        let v = dot_t(tier, &a, &b);
        assert!((s - v).abs() <= 1e-4 * s.abs().max(1.0), "{s} vs {v}");
    }

    #[test]
    fn detected_tier_axpy_bit_exact() {
        let tier = SimdTier::detect();
        let x: Vec<f32> = (0..61).map(|i| ((i * 29 % 83) as f32 - 41.0) / 17.0).collect();
        let mut y0: Vec<f32> = (0..61).map(|i| ((i * 31 % 89) as f32 - 44.0) / 19.0).collect();
        let mut y1 = y0.clone();
        axpy_t(SimdTier::Scalar, 0.37, &x, &mut y0);
        axpy_t(tier, 0.37, &x, &mut y1);
        assert_eq!(y0, y1);
    }

    #[test]
    fn detected_tier_softmax_value_exact() {
        let tier = SimdTier::detect();
        let mut a: Vec<f32> = (0..45).map(|i| ((i * 7 % 23) as f32 - 11.0) / 3.0).collect();
        let mut b = a.clone();
        softmax_t(SimdTier::Scalar, &mut a);
        softmax_t(tier, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn lut_gather_add_matches_scalar() {
        let tier = SimdTier::detect();
        let nb = 11;
        let luts: Vec<f32> = (0..nb * 256).map(|i| ((i * 13 % 47) as f32 - 23.0) / 7.0).collect();
        for byte in [0usize, 1, 5, 127, 200, 255] {
            let mut d0: Vec<f32> = (0..nb).map(|i| i as f32 * 0.25).collect();
            let mut d1 = d0.clone();
            lut_gather_add(SimdTier::Scalar, &luts, byte, &mut d0);
            lut_gather_add(tier, &luts, byte, &mut d1);
            assert_eq!(d0, d1, "byte {byte}");
        }
    }
}
