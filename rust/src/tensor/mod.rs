//! Dense row-major matrices and the blocked matmul micro-kernels.
//!
//! The offline vendor set has no BLAS / ndarray, so this module is the
//! numeric substrate for the whole stack: the transformer forward, the
//! Hessian accumulation, and every quantizer operate on [`Mat`].
//!
//! Layout is row-major; the generic [`Mat<T>`] covers f32 (models) and
//! f64 (conditioning-sensitive linear algebra). The f32 matmul uses
//! register-tiled kernels over the K dimension (see [`matmul`]).
//!
//! # SIMD dispatch & numerics policy
//!
//! Decode-path kernels (`dot`/`axpy`, the f32 and packed strip
//! dots/axpys, `rmsnorm`/`softmax`, the `lut_gemm` gather) are
//! re-exported from [`simd`], which selects a dispatch tier
//! (`scalar`/`avx2`/`neon`) **once per process**: CPU feature probes by
//! default, overridable via `BPDQ_SIMD={auto|scalar|avx2|neon}` or
//! `serve --simd`. An invalid or unsupported tier fails loudly (env →
//! panic, flag → error) — never a silent fallback. The scalar kernels
//! in `ops` remain the semantic reference; every dispatched kernel has
//! a `*_t` twin taking an explicit tier so parity tests and benches can
//! force each tier on one host.
//!
//! Parity contract per kernel family (asserted in
//! `tests/simd_parity.rs`):
//!
//! * **Bit-exact** — packed strip dots/axpys (the subset-sum tables
//!   store the same ascending-order f32 chains as the chunked scalar
//!   fold; scatters update channels independently with identical IEEE
//!   ops), `axpy` / f32 strip axpys (per-element mul + add, no
//!   reassociation, skip mask replicated verbatim), and the LUT-GEMM
//!   gather (per-lane adds).
//! * **Value-exact** — `softmax` (the vectorized max is an associative
//!   reduction; exp + sum + scale stay scalar verbatim).
//! * **Tolerance-bounded** — `dot` / f32 strip dots (reassociated f32
//!   reduction) and `rmsnorm` (reassociated f64 sum of squares only;
//!   the f32 epilogue is per-element identical).

pub mod kvpack;
pub mod ops;
pub mod simd;

pub use kvpack::{f16_decode, f16_encode, plane_byte, PackedGeom, PackedStrip, PackedStripMut};
pub use ops::{matmul, matmul_f64, matmul_transb, matvec_transa};
pub use simd::{
    axpy, dot, matvec, rmsnorm, softmax, strip_axpys, strip_axpys_packed, strip_dots,
    strip_dots_packed, SimdScratch, SimdTier,
};

use std::fmt;

/// Dense row-major matrix.
#[derive(Clone, PartialEq)]
pub struct Mat<T> {
    rows: usize,
    cols: usize,
    data: Vec<T>,
}

pub type Matrix = Mat<f32>;
pub type MatrixF64 = Mat<f64>;

impl<T: Copy + Default> Mat<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![T::default(); rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { rows, cols, data }
    }

    pub fn full(rows: usize, cols: usize, v: T) -> Self {
        Self { rows, cols, data: vec![v; rows * cols] }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> T {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: T) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[T] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<T> {
        self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Self {
        let mut out = Self::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Copy of the column block `[c0, c1)`.
    pub fn col_block(&self, c0: usize, c1: usize) -> Self {
        assert!(c0 <= c1 && c1 <= self.cols);
        let w = c1 - c0;
        let mut out = Self::zeros(self.rows, w);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Overwrite the column block `[c0, c0+src.cols)` with `src`.
    pub fn set_col_block(&mut self, c0: usize, src: &Self) {
        assert_eq!(src.rows, self.rows);
        assert!(c0 + src.cols <= self.cols);
        for r in 0..self.rows {
            let dst = &mut self.data[r * self.cols + c0..r * self.cols + c0 + src.cols];
            dst.copy_from_slice(src.row(r));
        }
    }

    /// Copy column `c` into a vector.
    pub fn col(&self, c: usize) -> Vec<T> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    /// Map every element.
    pub fn map<F: Fn(T) -> T>(&self, f: F) -> Self {
        Self {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Reorder columns: `out[:, j] = self[:, perm[j]]`.
    pub fn permute_cols(&self, perm: &[usize]) -> Self {
        assert_eq!(perm.len(), self.cols);
        let mut out = Self::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let src = self.row(r);
            let dst = out.row_mut(r);
            for (j, &p) in perm.iter().enumerate() {
                dst[j] = src[p];
            }
        }
        out
    }

    /// Reorder rows: `out[i, :] = self[perm[i], :]`.
    pub fn permute_rows(&self, perm: &[usize]) -> Self {
        assert_eq!(perm.len(), self.rows);
        let mut out = Self::zeros(self.rows, self.cols);
        for (i, &p) in perm.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(p));
        }
        out
    }
}

impl Matrix {
    /// Frobenius norm (f32 matrix, f64 accumulation).
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// `‖self − other‖_F`.
    pub fn fro_dist(&self, other: &Self) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Self) {
        assert_eq!(self.shape(), other.shape());
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// In-place scale.
    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// Widen to f64.
    pub fn to_f64(&self) -> MatrixF64 {
        MatrixF64 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| x as f64).collect(),
        }
    }
}

impl MatrixF64 {
    /// Narrow to f32.
    pub fn to_f32(&self) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| x as f32).collect(),
        }
    }

    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| x * x).sum::<f64>().sqrt()
    }
}

impl<T: fmt::Debug + Copy + Default> fmt::Debug for Mat<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat[{}x{}]", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for r in 0..show_r {
            write!(f, "  ")?;
            for c in 0..show_c {
                write!(f, "{:?} ", self.get(r, c))?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let mut m = Matrix::zeros(3, 4);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.shape(), (3, 4));
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0, 0.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.shape(), (3, 2));
        assert_eq!(t.get(2, 1), 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn transpose_blocked_large() {
        let n = 70;
        let mut m = Matrix::zeros(n, n + 13);
        for r in 0..n {
            for c in 0..n + 13 {
                m.set(r, c, (r * 1000 + c) as f32);
            }
        }
        let t = m.transpose();
        for r in 0..n {
            for c in 0..n + 13 {
                assert_eq!(t.get(c, r), m.get(r, c));
            }
        }
    }

    #[test]
    fn col_block_roundtrip() {
        let m = Matrix::from_vec(2, 4, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let b = m.col_block(1, 3);
        assert_eq!(b.row(0), &[2., 3.]);
        assert_eq!(b.row(1), &[6., 7.]);
        let mut m2 = Matrix::zeros(2, 4);
        m2.set_col_block(1, &b);
        assert_eq!(m2.get(0, 1), 2.0);
        assert_eq!(m2.get(1, 2), 7.0);
        assert_eq!(m2.get(0, 0), 0.0);
    }

    #[test]
    fn permute_cols_inverse() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let perm = vec![2, 0, 1];
        let p = m.permute_cols(&perm);
        assert_eq!(p.row(0), &[3., 1., 2.]);
        // invert
        let mut inv = vec![0usize; 3];
        for (j, &pj) in perm.iter().enumerate() {
            inv[pj] = j;
        }
        assert_eq!(p.permute_cols(&inv), m);
    }

    #[test]
    fn fro_norms() {
        let m = Matrix::from_vec(1, 2, vec![3., 4.]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-9);
        let z = Matrix::zeros(1, 2);
        assert!((m.fro_dist(&z) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn axpy_scale() {
        let mut a = Matrix::from_vec(1, 3, vec![1., 2., 3.]);
        let b = Matrix::from_vec(1, 3, vec![1., 1., 1.]);
        a.axpy(2.0, &b);
        assert_eq!(a.row(0), &[3., 4., 5.]);
        a.scale(0.5);
        assert_eq!(a.row(0), &[1.5, 2., 2.5]);
    }
}
