//! PJRT runtime — loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1 / PJRT C API):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. Executables are compiled once and
//! cached by artifact path; python never runs at serving time.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact plus its source path.
pub struct LoadedExecutable {
    pub path: PathBuf,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedExecutable {
    /// Execute with pre-built literals; returns the decomposed output
    /// tuple (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .context("pjrt execute")?;
        let out = result[0][0].to_literal_sync().context("fetch result")?;
        out.to_tuple().context("decompose output tuple")
    }
}

/// PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, LoadedExecutable>,
    compiles: usize,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client, cache: HashMap::new(), compiles: 0 })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached). Repeat loads of one
    /// path return the cached executable without recompiling — callers on
    /// the request path should still hoist the load out of per-request
    /// loops to avoid the per-call hash + borrow round-trip.
    pub fn load(&mut self, path: &Path) -> Result<&LoadedExecutable> {
        if !self.cache.contains_key(path) {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {}", path.display()))?;
            self.compiles += 1;
            self.cache
                .insert(path.to_path_buf(), LoadedExecutable { path: path.to_path_buf(), exe });
        }
        Ok(&self.cache[path])
    }

    pub fn is_loaded(&self, path: &Path) -> bool {
        self.cache.contains_key(path)
    }

    /// Number of artifact compilations performed (cache misses) — used by
    /// tests to assert the request path never recompiles per request.
    pub fn compile_count(&self) -> usize {
        self.compiles
    }
}

/// f32 slice → literal with the given dims.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// u8 slice → literal with the given dims (u8 is not a `NativeType` in
/// the crate; go through the untyped-data constructor).
pub fn literal_u8(data: &[u8], dims: &[usize]) -> Result<xla::Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::U8,
        dims,
        data,
    )?)
}

/// i32 scalar literal.
pub fn literal_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// literal → Vec<f32>.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests require the PJRT shared library; they are cheap and
    // hermetic (no artifacts needed — we synthesize HLO text inline).
    // When bpdq is built against the offline xla stub, client creation
    // fails and the tests skip.
    const ADD_HLO: &str = r#"
HloModule add1, entry_computation_layout={(f32[4]{0})->(f32[4]{0})}

ENTRY main {
  p0 = f32[4]{0} parameter(0)
  one = f32[] constant(1)
  ones = f32[4]{0} broadcast(one), dimensions={}
  sum = f32[4]{0} add(p0, ones)
  ROOT out = (f32[4]{0}) tuple(sum)
}
"#;

    #[test]
    fn runtime_compiles_and_runs_inline_hlo() {
        let dir = std::env::temp_dir().join("bpdq_runtime_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("add1.hlo.txt");
        std::fs::write(&path, ADD_HLO).unwrap();

        let mut rt = match Runtime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("[skip] PJRT plugin unavailable: {e:#}");
                return;
            }
        };
        assert!(!rt.is_loaded(&path));
        let out = {
            let exe = rt.load(&path).unwrap();
            let x = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[4]).unwrap();
            exe.run(&[x]).unwrap()
        };
        assert!(rt.is_loaded(&path));
        let y = to_f32_vec(&out[0]).unwrap();
        assert_eq!(y, vec![2.0, 3.0, 4.0, 5.0]);

        // Regression for the per-request reload bug: repeat loads of the
        // same artifact must hit the cache, never recompile.
        assert_eq!(rt.compile_count(), 1);
        let _again = rt.load(&path).unwrap();
        assert_eq!(rt.compile_count(), 1, "second load recompiled");
        std::fs::remove_file(&path).ok();
    }
}
