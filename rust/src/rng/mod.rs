//! Deterministic pseudo-random number generation and sampling.
//!
//! The offline vendor set does not include the `rand` crate, so this module
//! provides the generators and distributions the rest of the stack needs:
//! a SplitMix64 seeder, a xoshiro256** core generator, and samplers for
//! uniform/normal/Student-t/Zipf distributions. Everything is fully
//! deterministic given a seed — experiment tables are reproducible
//! bit-for-bit across runs.

mod zipf;

pub use zipf::Zipf;

/// SplitMix64 — used to expand a single `u64` seed into generator state.
/// Reference: Steele, Lea, Flood — "Fast splittable pseudorandom number
/// generators" (OOPSLA 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the workhorse generator (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    /// Derive an independent child stream (for per-layer / per-request
    /// determinism regardless of call order).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1). 53-bit mantissa resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached second variate omitted for
    /// simplicity; statistics code is not on the hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with the given mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Student-t with `nu` degrees of freedom — heavy-tailed weight noise
    /// used by the synthetic-LLM-statistics generator (LLM weights are
    /// well-modelled by t-distributions with nu≈4–6; see e.g. BiLLM).
    pub fn student_t(&mut self, nu: f64) -> f64 {
        // t = Z / sqrt(ChiSq(nu)/nu); ChiSq via sum of squared normals for
        // integral nu, via Gamma(nu/2, 2) Marsaglia-Tsang otherwise.
        let z = self.normal();
        let chi2 = self.gamma(nu / 2.0, 2.0);
        z / (chi2 / nu).sqrt()
    }

    /// Gamma(shape k, scale theta), Marsaglia–Tsang squeeze method.
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        if k < 1.0 {
            // boost: Gamma(k) = Gamma(k+1) * U^{1/k}
            let g = self.gamma(k + 1.0, theta);
            let u = self.f64().max(1e-300);
            return g * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v * theta;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below_usize(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn student_t_heavier_tails_than_normal() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let thresh = 4.0;
        let t_tail = (0..n).filter(|_| r.student_t(4.0).abs() > thresh).count();
        let z_tail = (0..n).filter(|_| r.normal().abs() > thresh).count();
        assert!(t_tail > z_tail * 3, "t_tail={t_tail} z_tail={z_tail}");
    }

    #[test]
    fn gamma_mean() {
        let mut r = Rng::new(17);
        let n = 30_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.gamma(3.0, 2.0);
        }
        let mean = sum / n as f64; // expect k*theta = 6
        assert!((mean - 6.0).abs() < 0.15, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(23);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(31);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
