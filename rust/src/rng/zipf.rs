//! Zipf-distributed sampling for the synthetic-corpus vocabulary.
//!
//! Natural-language token frequencies follow a Zipf law; sampling the
//! synthetic corpus vocabulary from Zipf(s) reproduces the rank-frequency
//! skew that makes calibration activations (and hence the Hessian
//! `H = XXᵀ`) realistically ill-conditioned — the regime where the
//! paper's variable grid matters.

use super::Rng;

/// Zipf distribution over ranks `0..n` with exponent `s`, sampled by
/// inverse-CDF over a precomputed cumulative table (n is small — vocab
/// sized — so O(log n) binary search per sample is fine).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in &mut cdf {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Sample a rank in `[0, n)`; rank 0 is the most frequent.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_zero_most_frequent() {
        let z = Zipf::new(100, 1.1);
        let mut rng = Rng::new(5);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
    }

    #[test]
    fn frequency_ratio_tracks_exponent() {
        let z = Zipf::new(50, 1.0);
        let mut rng = Rng::new(6);
        let mut counts = vec![0usize; 50];
        for _ in 0..200_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // P(rank 0)/P(rank 9) should be ~10 for s=1.
        let ratio = counts[0] as f64 / counts[9] as f64;
        assert!((ratio - 10.0).abs() < 2.5, "ratio={ratio}");
    }

    #[test]
    fn all_ranks_in_range() {
        let z = Zipf::new(7, 1.3);
        let mut rng = Rng::new(8);
        for _ in 0..10_000 {
            assert!(z.sample(&mut rng) < 7);
        }
    }
}
