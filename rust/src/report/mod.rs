//! Paper-table regeneration harness + formatting.
//!
//! Each `table*`/`fig*` function reproduces one table or figure of the
//! paper on the synthetic substrate (DESIGN.md §5 maps them). They are
//! called both by the `bpdq` CLI subcommands and by the `cargo bench`
//! wrappers, and print rows in the paper's column order so outputs can
//! be diffed against the paper's shape claims.

pub mod harness;

use crate::eval::BenchScores;

/// One row of a quality table (Tables 1/2/4–7 share this shape).
#[derive(Clone, Debug)]
pub struct QualityRow {
    pub method: String,
    pub bpw: f64,
    pub size_mib: f64,
    pub quant_secs: f64,
    pub scores: BenchScores,
}

/// Print a paper-shaped quality table.
pub fn print_quality_table(title: &str, rows: &[QualityRow]) {
    println!("\n=== {title} ===");
    println!(
        "{:<18} {:>6} {:>9} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "Method", "BPW", "SIZE(MiB)", "Cost(s)", "Wiki2*↓", "GSM8K*↑", "ARC*↑", "BoolQ*↑", "HellaS*↑", "TREC*↑"
    );
    for r in rows {
        println!(
            "{:<18} {:>6.2} {:>9.2} {:>8.1} {:>8} {:>7.2}% {:>7.2}% {:>7.2}% {:>7.2}% {:>7.2}%",
            r.method,
            r.bpw,
            r.size_mib,
            r.quant_secs,
            fmt_ppl(r.scores.ppl),
            r.scores.arith * 100.0,
            r.scores.fact_choice * 100.0,
            r.scores.bool_fact * 100.0,
            r.scores.continuation * 100.0,
            r.scores.classify * 100.0,
        );
    }
}

/// Perplexities can explode (AWQ-W2 in the paper hits 10⁵–10⁷); print
/// them the way the paper does.
pub fn fmt_ppl(ppl: f64) -> String {
    if !ppl.is_finite() {
        "N/A".to_string()
    } else if ppl >= 1e4 {
        format!("{ppl:.1e}")
    } else {
        format!("{ppl:.2}")
    }
}

/// Simple horizontal bar chart for figure-style output (Fig. 1b / Fig 3).
pub fn print_bar(label: &str, value: f64, max: f64, width: usize) {
    let frac = if max > 0.0 { (value / max).clamp(0.0, 1.0) } else { 0.0 };
    let filled = (frac * width as f64).round() as usize;
    println!(
        "{label:<22} {:>6.2}% |{}{}|",
        value * 100.0,
        "█".repeat(filled),
        " ".repeat(width - filled)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppl_formatting_matches_paper_style() {
        assert_eq!(fmt_ppl(8.35), "8.35");
        assert_eq!(fmt_ppl(1.5e6), "1.5e6");
        assert_eq!(fmt_ppl(f64::INFINITY), "N/A");
        assert_eq!(fmt_ppl(f64::NAN), "N/A");
    }

    #[test]
    fn bar_does_not_panic_on_edges() {
        print_bar("x", 0.0, 1.0, 20);
        print_bar("y", 1.0, 1.0, 20);
        print_bar("z", 0.5, 0.0, 20);
    }
}
