//! The experiment harness behind every table/figure reproduction.
//!
//! Maps paper experiments → synthetic-substrate runs (DESIGN.md §5):
//!
//! * [`table1`] — main quality sweep (GPTQ/AWQ/BPDQ × W4/W3/W2 × group
//!   pairings, 7 metrics). Paper Tables 1/4/5 shape.
//! * [`table2`] — + AnyBCQ/VPTQ/RTN and SIZE column. Paper Table 2/6/7.
//! * [`table3`] — efficiency profile (quant cost, size, decode µs/token
//!   per engine) + activation outlier stats. Paper Table 3.
//! * [`fig1b`]  — 2-bit bar comparison. Paper Figure 1(b).
//! * [`fig3`]   — long-context suite. Paper Figure 3.

use super::{print_bar, print_quality_table, QualityRow};
use crate::data::{tasks, CorpusConfig, CorpusGen, Split, Tokenizer};
use crate::eval::{self, outliers, EvalConfig};
use crate::io::tlm::TlmFile;
use crate::model::pipeline::{quantize_model, QuantizedModel};
use crate::model::Model;
use crate::quant::{BcqConfig, BpdqConfig, QuantMethod, UniformConfig, VqConfig};
use crate::serving::{Engine, EngineKind, LutModel, Request};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct HarnessCfg {
    pub model_path: PathBuf,
    pub quick: bool,
}

impl HarnessCfg {
    pub fn new(model_path: &str, quick: bool) -> Self {
        Self { model_path: PathBuf::from(model_path), quick }
    }

    fn eval_cfg(&self) -> EvalConfig {
        if self.quick {
            EvalConfig { n_ppl_docs: 12, n_arith: 12, n_choice: 16, ..Default::default() }
        } else {
            EvalConfig { n_ppl_docs: 48, n_arith: 48, n_choice: 48, ..Default::default() }
        }
    }

    fn n_calib(&self) -> usize {
        if self.quick {
            24
        } else {
            96
        }
    }
}

/// Load the trained checkpoint + shared data context.
pub fn load(cfg: &HarnessCfg) -> Result<(Model, CorpusGen, Tokenizer)> {
    let tlm = TlmFile::load(&cfg.model_path)
        .with_context(|| format!("load {} (run `make artifacts` first)", cfg.model_path.display()))?;
    let model = Model::from_tlm(&tlm)?;
    Ok((model, CorpusGen::new(CorpusConfig::default()), Tokenizer::new()))
}

fn calib_seqs(gen: &CorpusGen, tok: &Tokenizer, n: usize, max_len: usize) -> Vec<Vec<u32>> {
    gen.token_docs(Split::Calib, n, tok)
        .into_iter()
        .map(|mut d| {
            d.truncate(max_len);
            d
        })
        .filter(|d| d.len() >= 8)
        .collect()
}

/// Quantize + evaluate one method; returns the table row and the
/// quantized model for reuse.
pub fn run_method(
    cfg: &HarnessCfg,
    model: &Model,
    gen: &CorpusGen,
    tok: &Tokenizer,
    method: &QuantMethod,
) -> Result<(QualityRow, Option<QuantizedModel>)> {
    let ecfg = cfg.eval_cfg();
    if matches!(method, QuantMethod::Fp16) {
        let scores = eval::run_battery(model, gen, tok, &ecfg);
        return Ok((
            QualityRow {
                method: "FP16 (baseline)".into(),
                bpw: 16.0,
                size_mib: model.fp16_bytes() as f64 / (1 << 20) as f64,
                quant_secs: 0.0,
                scores,
            },
            None,
        ));
    }
    let calib = calib_seqs(gen, tok, cfg.n_calib(), model.cfg.max_seq);
    let qm = quantize_model(model, &calib, method)?;
    let scores = eval::run_battery(&qm.model, gen, tok, &ecfg);
    let row = QualityRow {
        method: method.name(),
        bpw: qm.bits_per_weight(),
        size_mib: qm.size_bytes() as f64 / (1 << 20) as f64,
        quant_secs: qm.quant_secs,
        scores,
    };
    Ok((row, Some(qm)))
}

fn uc(bits: u8, g: usize) -> UniformConfig {
    UniformConfig { bits, group_size: g, act_order: true }
}

fn bp(k: u8, g: usize) -> BpdqConfig {
    BpdqConfig { k, group_size: g, ..Default::default() }
}

/// Paper Table 1 method grid: GPTQ/AWQ at group g, BPDQ at 2g (the
/// paper's BPW-fairness pairing).
fn table1_methods(quick: bool) -> Vec<QuantMethod> {
    use QuantMethod::*;
    if quick {
        return vec![Fp16, Gptq(uc(2, 32)), Awq(uc(2, 32)), Bpdq(bp(2, 64))];
    }
    vec![
        Fp16,
        // W4 tier
        Gptq(uc(4, 64)),
        Awq(uc(4, 64)),
        Bpdq(bp(4, 128)),
        // W3 tiers
        Gptq(uc(3, 32)),
        Awq(uc(3, 32)),
        Bpdq(bp(3, 64)),
        Gptq(uc(3, 64)),
        Awq(uc(3, 64)),
        Bpdq(bp(3, 128)),
        // W2 tiers — the paper's headline regime
        Gptq(uc(2, 32)),
        Awq(uc(2, 32)),
        Bpdq(bp(2, 64)),
        Gptq(uc(2, 64)),
        Awq(uc(2, 64)),
        Bpdq(bp(2, 128)),
        // extreme compression row
        Bpdq(bp(2, 256)),
    ]
}

pub fn table1(cfg: &HarnessCfg) -> Result<Vec<QualityRow>> {
    let (model, gen, tok) = load(cfg)?;
    let mut rows = Vec::new();
    for m in table1_methods(cfg.quick) {
        eprintln!("[table1] {} …", m.name());
        let (row, _) = run_method(cfg, &model, &gen, &tok, &m)?;
        rows.push(row);
    }
    print_quality_table(
        "Table 1 — main quality results (synthetic tiny-LM substrate)",
        &rows,
    );
    print_shape_checks(&rows);
    Ok(rows)
}

/// The paper's qualitative claims, checked on our rows and reported.
fn print_shape_checks(rows: &[QualityRow]) {
    let find = |prefix: &str| rows.iter().find(|r| r.method.starts_with(prefix));
    println!("\n-- shape checks vs paper claims --");
    if let (Some(g), Some(a), Some(b)) =
        (find("GPTQ-W2-G32"), find("AWQ-W2-G32"), find("BPDQ-W2-G64"))
    {
        println!(
            "W2: BPDQ ppl {} < GPTQ ppl {}: {}   AWQ collapses (ppl {}): {}",
            super::fmt_ppl(b.scores.ppl),
            super::fmt_ppl(g.scores.ppl),
            b.scores.ppl < g.scores.ppl,
            super::fmt_ppl(a.scores.ppl),
            a.scores.ppl > g.scores.ppl,
        );
        println!(
            "W2 reasoning: BPDQ {:.1}% vs GPTQ {:.1}% vs AWQ {:.1}%",
            b.scores.arith * 100.0,
            g.scores.arith * 100.0,
            a.scores.arith * 100.0
        );
    }
    if let (Some(g), Some(b)) = (find("GPTQ-W4"), find("BPDQ-W4")) {
        println!(
            "W4: all methods ≈ fp16 (GPTQ ppl {}, BPDQ ppl {})",
            super::fmt_ppl(g.scores.ppl),
            super::fmt_ppl(b.scores.ppl)
        );
    }
}

/// Paper Table 2 grid: + AnyBCQ, VPTQ, RTN at the same tiers.
pub fn table2(cfg: &HarnessCfg) -> Result<Vec<QualityRow>> {
    use QuantMethod::*;
    let (model, gen, tok) = load(cfg)?;
    let grid: Vec<QuantMethod> = if cfg.quick {
        vec![
            Fp16,
            Gptq(uc(2, 64)),
            AnyBcq(BcqConfig { bits: 2, group_size: 64, alt_iters: 6 }),
            Vptq(VqConfig { bits: 2, ..Default::default() }),
            Bpdq(bp(2, 128)),
        ]
    } else {
        vec![
            Fp16,
            Rtn(uc(4, 64)),
            Gptq(uc(4, 64)),
            Awq(uc(4, 64)),
            AnyBcq(BcqConfig { bits: 4, group_size: 128, alt_iters: 6 }),
            Vptq(VqConfig { bits: 4, ..Default::default() }),
            Bpdq(bp(4, 128)),
            Rtn(uc(3, 64)),
            Gptq(uc(3, 64)),
            Awq(uc(3, 64)),
            AnyBcq(BcqConfig { bits: 3, group_size: 128, alt_iters: 6 }),
            Vptq(VqConfig { bits: 3, ..Default::default() }),
            Bpdq(bp(3, 128)),
            Rtn(uc(2, 64)),
            Gptq(uc(2, 64)),
            Awq(uc(2, 64)),
            AnyBcq(BcqConfig { bits: 2, group_size: 64, alt_iters: 6 }),
            Vptq(VqConfig { bits: 2, ..Default::default() }),
            Bpdq(bp(2, 64)),
            Bpdq(bp(2, 128)),
        ]
    };
    let mut rows = Vec::new();
    for m in grid {
        eprintln!("[table2] {} …", m.name());
        let (row, _) = run_method(cfg, &model, &gen, &tok, &m)?;
        rows.push(row);
    }
    print_quality_table(
        "Table 2 — bit-plane & VQ method comparison (synthetic substrate)",
        &rows,
    );
    // cost-ratio claims (paper: BPDQ ≈3× GPTQ, VPTQ ≈40×)
    let t = |p: &str| rows.iter().find(|r| r.method.starts_with(p)).map(|r| r.quant_secs);
    if let (Some(tg), Some(tb), Some(tv)) = (t("GPTQ-W2"), t("BPDQ-W2"), t("VPTQ-W2")) {
        println!(
            "\nquant-cost ratios vs GPTQ: BPDQ {:.1}× (paper ~3×), VPTQ {:.1}× (paper ~40×)",
            tb / tg,
            tv / tg
        );
    }
    Ok(rows)
}

/// Decode latency of one engine over `n_tokens`, µs/token.
fn decode_latency_us(kind: EngineKind, prompt: &[u32], n_tokens: usize) -> Result<f64> {
    let mut engine = Engine::new(kind)?;
    // warmup
    let _ = engine.generate_batch(&[Request { id: 0, prompt: prompt.to_vec(), max_new: 2 }])?;
    let t0 = std::time::Instant::now();
    let r = engine.generate_batch(&[Request {
        id: 1,
        prompt: prompt.to_vec(),
        max_new: n_tokens,
    }])?;
    let total = t0.elapsed().as_secs_f64() * 1e6;
    Ok(total / (r[0].tokens.len() + prompt.len()) as f64)
}

/// Paper Table 3: efficiency profile + activation outlier statistics.
pub fn table3(cfg: &HarnessCfg) -> Result<()> {
    let (model, gen, tok) = load(cfg)?;
    let model = Arc::new(model);
    let calib = calib_seqs(&gen, &tok, cfg.n_calib(), model.cfg.max_seq);
    let probes: Vec<Vec<u32>> = gen
        .token_docs(Split::Eval, if cfg.quick { 8 } else { 32 }, &tok)
        .into_iter()
        .map(|mut d| {
            d.truncate(model.cfg.max_seq);
            d
        })
        .collect();
    let n_tokens = if cfg.quick { 16 } else { 64 };
    let prompt = tok.encode("q: 3+4=? a:");

    println!("\n=== Table 3 — efficiency profile & outlier statistics ===");
    println!(
        "{:<22} {:>9} {:>10} {:>12} {:>12} {:>9} {:>9} {:>8} {:>8}",
        "Model", "Cost(s)", "SIZE(MiB)", "Engine", "µs/token", "DiagR", "ΔDiagR", "Cnt10", "ΔCnt10"
    );

    let base_stats = outliers::activation_outliers(&model, &probes);
    let fp_lat = decode_latency_us(EngineKind::Native(model.clone()), &prompt, n_tokens)?;
    println!(
        "{:<22} {:>9} {:>10.2} {:>12} {:>12.1} {:>9.2} {:>9} {:>8} {:>8}",
        "FP16",
        "-",
        model.fp16_bytes() as f64 / (1 << 20) as f64,
        "dense",
        fp_lat,
        base_stats.diag_r_p95,
        "-",
        base_stats.cnt10,
        "-"
    );

    let entries: Vec<(QuantMethod, &str)> = vec![
        (QuantMethod::Gptq(uc(2, 32)), "dequant"),
        (QuantMethod::Vptq(VqConfig { bits: 2, ..Default::default() }), "dequant"),
        (QuantMethod::Bpdq(bp(2, 64)), "LUT"),
    ];
    for (m, engine_name) in entries {
        eprintln!("[table3] {} …", m.name());
        let qm = quantize_model(&model, &calib, &m)?;
        let stats = outliers::activation_outliers(&qm.model, &probes);
        let (dr, dc) = stats.delta_vs(&base_stats);
        let qmodel = Arc::new(qm.model.clone());
        let lat = if engine_name == "LUT" {
            let packed: HashMap<_, _> = qm
                .packed
                .iter()
                .map(|(k, v)| (k.clone(), v.as_bit_planes().unwrap().clone()))
                .collect();
            decode_latency_us(
                EngineKind::Lut(LutModel::new(qmodel.clone(), packed)?),
                &prompt,
                n_tokens,
            )?
        } else {
            decode_latency_us(EngineKind::Native(qmodel.clone()), &prompt, n_tokens)?
        };
        println!(
            "{:<22} {:>9.1} {:>10.2} {:>12} {:>12.1} {:>9.2} {:>+8.1}% {:>8} {:>+7.1}%",
            m.name(),
            qm.quant_secs,
            qm.size_bytes() as f64 / (1 << 20) as f64,
            engine_name,
            lat,
            stats.diag_r_p95,
            dr * 100.0,
            stats.cnt10,
            dc * 100.0
        );
    }
    println!("\n(paper shape: GPTQ-W2 suppresses outliers strongly, BPDQ ≈ preserves;");
    println!(" LUT decode latency ≈ flat across bit-widths and beats dequant at W2/W3)");
    Ok(())
}

/// Paper Fig. 1(b): 2-bit method comparison, printed as bars.
pub fn fig1b(cfg: &HarnessCfg) -> Result<Vec<QualityRow>> {
    use QuantMethod::*;
    let (model, gen, tok) = load(cfg)?;
    let grid = vec![
        Fp16,
        Gptq(uc(2, 32)),
        Awq(uc(2, 32)),
        AnyBcq(BcqConfig { bits: 2, group_size: 64, alt_iters: 6 }),
        Vptq(VqConfig { bits: 2, ..Default::default() }),
        Bpdq(bp(2, 64)),
    ];
    let mut rows = Vec::new();
    for m in grid {
        eprintln!("[fig1b] {} …", m.name());
        let (row, _) = run_method(cfg, &model, &gen, &tok, &m)?;
        rows.push(row);
    }
    println!("\n=== Figure 1(b) — 2-bit quantization comparison (GSM8K* EM) ===");
    let max = rows.iter().map(|r| r.scores.arith).fold(0.0, f64::max);
    for r in &rows {
        print_bar(&r.method, r.scores.arith, max, 40);
    }
    println!("\n(ppl column for the same rows)");
    for r in &rows {
        println!("{:<22} ppl {}", r.method, super::fmt_ppl(r.scores.ppl));
    }
    Ok(rows)
}

/// Paper Fig. 3: LongBench-proxy suite.
pub fn fig3(cfg: &HarnessCfg) -> Result<()> {
    use QuantMethod::*;
    let (model, gen, tok) = load(cfg)?;
    let n = if cfg.quick { 12 } else { 32 };
    // Retrieval = keyword-classification at increasing distance (the
    // retrieval proxy the tiny-LM can perform; the verbatim passkey task
    // is beyond its 96-char training window — see EXPERIMENTS.md).
    let suites = |m: &Model, label: &str| -> (f64, f64, f64, f64) {
        let r0 = eval::choice_accuracy(m, &tok, &tasks::gen_classify_at_distance(&gen, 11, n, 0));
        let r1 = eval::choice_accuracy(m, &tok, &tasks::gen_classify_at_distance(&gen, 12, n, 1));
        let r2 = eval::choice_accuracy(m, &tok, &tasks::gen_classify_at_distance(&gen, 13, n, 2));
        let class = eval::choice_accuracy(m, &tok, &tasks::gen_classify(&gen, 14, n));
        println!(
            "{label:<18} retrieve@0 {:>6.1}%  retrieve@1 {:>6.1}%  retrieve@2 {:>6.1}%  classify {:>6.1}%",
            r0 * 100.0,
            r1 * 100.0,
            r2 * 100.0,
            class * 100.0
        );
        (r0, r1, r2, class)
    };

    println!("\n=== Figure 3 — long-context suite (LongBench proxies) ===");
    suites(&model, "FP16");
    let calib = calib_seqs(&gen, &tok, cfg.n_calib(), model.cfg.max_seq);
    let grid: Vec<QuantMethod> = if cfg.quick {
        vec![Gptq(uc(2, 32)), Awq(uc(2, 32)), Bpdq(bp(2, 64))]
    } else {
        vec![
            Gptq(uc(4, 64)),
            Bpdq(bp(4, 128)),
            Gptq(uc(3, 64)),
            Bpdq(bp(3, 128)),
            Gptq(uc(2, 32)),
            Awq(uc(2, 32)),
            Vptq(VqConfig { bits: 2, ..Default::default() }),
            Bpdq(bp(2, 64)),
        ]
    };
    for m in grid {
        eprintln!("[fig3] {} …", m.name());
        let qm = quantize_model(&model, &calib, &m)?;
        suites(&qm.model, &m.name());
    }
    println!("\n(paper shape: at 3–4 bit all ≈ baseline; at 2-bit retrieval collapses for");
    println!(" GPTQ/AWQ while BPDQ retains most of it; VPTQ best but costliest)");
    Ok(())
}
