//! Hand-rolled CLI argument parsing (clap is not in the offline vendor
//! set). Supports `bpdq <subcommand> [--flag value]... [--switch]...`.

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut it = args.into_iter().peekable();
        let subcommand = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = HashMap::new();
        let mut switches = Vec::new();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--key value` or bare `--switch`
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        flags.insert(name.to_string(), it.next().unwrap());
                    }
                    _ => switches.push(name.to_string()),
                }
            } else {
                return Err(format!("unexpected positional argument `{a}`"));
            }
        }
        Ok(Args { subcommand, flags, switches })
    }

    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected integer, got `{v}`")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected number, got `{v}`")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch) || self.flags.contains_key(switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("quantize --method bpdq --bits 2 --verbose");
        assert_eq!(a.subcommand, "quantize");
        assert_eq!(a.get("method"), Some("bpdq"));
        assert_eq!(a.get_usize("bits", 4).unwrap(), 2);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("eval");
        assert_eq!(a.get_or("model", "artifacts/tiny_small.tlm"), "artifacts/tiny_small.tlm");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n", 1).is_err());
    }

    #[test]
    fn positional_rejected() {
        assert!(Args::parse(["x".into(), "oops".into()]).is_err());
    }

    #[test]
    fn empty_is_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.subcommand, "help");
    }
}
