//! Project-native static analysis — the `bpdq lint` subcommand.
//!
//! The container this crate grows in has no rustc/clippy/miri, so the
//! invariants the serving stack's performance rests on (alloc-free
//! decode kernels, lock-free sweep loop, disciplined `unsafe` strip
//! carving) are enforced by a self-contained pass in the crate itself —
//! the same vendoring-free philosophy as [`crate::proptest_lite`].
//!
//! * [`lexer`] — hand-rolled Rust lexer: a "blanked" source view
//!   (comments + literal contents → spaces), fn items with brace-matched
//!   body spans, `unsafe` sites.
//! * [`rules`] — the five rules L1–L5 and the `// lint: hot` /
//!   `// lint: sweep` marker contract.
//! * this module — the plain-text allowlist (`rust/lint.toml`) so every
//!   intentional exception is explicit, justified, and reviewed, plus
//!   the source-tree walk the CLI drives.
//!
//! Allowlist format, parsed by hand (no toml dep):
//!
//! ```text
//! # comment lines and blanks are skipped
//! L2 tensor/ops.rs strip_dots_packed   # cold heap fallback above 64 groups
//! L3 lut/mod.rs *                      # entry asserts guard silent corruption
//! ```
//!
//! Three whitespace-separated fields — rule ID, path *suffix*, fn name
//! (`*` matches any, and module-scope findings) — then a mandatory
//! `# reason`. An entry suppresses a finding when the rule matches, the
//! finding's path ends with the path field, and the fn matches. Unused
//! entries are reported as warnings so the file cannot rot.

pub mod lexer;
pub mod rules;

pub use lexer::SourceModel;
pub use rules::{lint_source, Finding, Rule, REGISTRY};

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One parsed allowlist entry.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub rule: String,
    /// Path suffix, matched against `Finding::path` with `ends_with`.
    pub path: String,
    /// Fn name, or `*` for any (including module scope `-`).
    pub func: String,
    pub reason: String,
    /// 1-based line in the allowlist file, for diagnostics.
    pub line: usize,
}

impl AllowEntry {
    pub fn matches(&self, f: &Finding) -> bool {
        self.rule == f.rule
            && f.path.ends_with(&self.path)
            && (self.func == "*" || self.func == f.func)
    }
}

/// Parse the plain-text allowlist. Every entry must carry a `# reason`.
pub fn parse_allowlist(text: &str) -> Result<Vec<AllowEntry>, String> {
    let known: Vec<&str> = REGISTRY.iter().map(|r| r.id).collect();
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line_no = i + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (spec, reason) = match line.split_once('#') {
            Some((s, r)) => (s.trim(), r.trim()),
            None => {
                return Err(format!(
                    "allowlist line {line_no}: entry without a `# reason` justification"
                ))
            }
        };
        if reason.is_empty() {
            return Err(format!("allowlist line {line_no}: empty `# reason`"));
        }
        let mut parts = spec.split_whitespace();
        let (rule, path, func) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(r), Some(p), Some(f), None) => (r, p, f),
            _ => {
                return Err(format!(
                    "allowlist line {line_no}: expected `RULE path-suffix fn  # reason`, got `{line}`"
                ))
            }
        };
        if !known.contains(&rule) {
            return Err(format!(
                "allowlist line {line_no}: unknown rule `{rule}` (known: {})",
                known.join(", ")
            ));
        }
        out.push(AllowEntry {
            rule: rule.to_string(),
            path: path.to_string(),
            func: func.to_string(),
            reason: reason.to_string(),
            line: line_no,
        });
    }
    Ok(out)
}

/// Split findings into (kept, suppressed); the bool vec marks which
/// allowlist entries matched at least one finding.
pub fn apply_allowlist(
    findings: Vec<Finding>,
    entries: &[AllowEntry],
) -> (Vec<Finding>, Vec<Finding>, Vec<bool>) {
    let mut used = vec![false; entries.len()];
    let mut kept = Vec::new();
    let mut suppressed = Vec::new();
    for f in findings {
        match entries.iter().position(|e| e.matches(&f)) {
            Some(i) => {
                used[i] = true;
                suppressed.push(f);
            }
            None => kept.push(f),
        }
    }
    (kept, suppressed, used)
}

/// Recursively collect every `.rs` file under `root`, sorted for
/// deterministic reports.
pub fn walk_rs_files(root: &Path) -> io::Result<Vec<PathBuf>> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
        for entry in fs::read_dir(dir)? {
            let path = entry?.path();
            if path.is_dir() {
                walk(&path, out)?;
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    walk(root, &mut out)?;
    out.sort();
    Ok(out)
}

/// Lint every `.rs` file under `root`; findings are pre-allowlist.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    for path in walk_rs_files(root)? {
        let src = fs::read_to_string(&path)?;
        let label = path.to_string_lossy().to_string();
        findings.extend(lint_source(&label, &src));
    }
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, path: &str, func: &str) -> Finding {
        Finding {
            rule,
            path: path.to_string(),
            line: 1,
            func: func.to_string(),
            msg: String::new(),
            excerpt: String::new(),
        }
    }

    #[test]
    fn allowlist_parses_entries_comments_and_blanks() {
        let text = "# header comment\n\nL2 tensor/ops.rs strip_dots_packed  # cold fallback\nL3 lut/mod.rs *  # entry asserts\n";
        let entries = parse_allowlist(text).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].rule, "L2");
        assert_eq!(entries[0].func, "strip_dots_packed");
        assert_eq!(entries[0].reason, "cold fallback");
        assert_eq!(entries[1].func, "*");
        assert_eq!(entries[1].line, 4);
    }

    #[test]
    fn allowlist_rejects_missing_or_empty_reason() {
        assert!(parse_allowlist("L2 a.rs f\n").is_err());
        assert!(parse_allowlist("L2 a.rs f #   \n").is_err());
    }

    #[test]
    fn allowlist_rejects_unknown_rule_and_bad_arity() {
        assert!(parse_allowlist("L9 a.rs f  # nope\n").is_err());
        assert!(parse_allowlist("L2 a.rs  # missing fn field\n").is_err());
        assert!(parse_allowlist("L2 a.rs f extra  # too many\n").is_err());
    }

    #[test]
    fn apply_allowlist_matches_suffix_and_wildcard() {
        let entries = parse_allowlist(
            "L2 tensor/ops.rs strip_dots_packed  # cold fallback\nL3 lut/mod.rs *  # asserts\n",
        )
        .unwrap();
        let findings = vec![
            finding("L2", "rust/src/tensor/ops.rs", "strip_dots_packed"),
            finding("L2", "rust/src/tensor/ops.rs", "other_fn"),
            finding("L3", "rust/src/lut/mod.rs", "anything"),
        ];
        let (kept, suppressed, used) = apply_allowlist(findings, &entries);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].func, "other_fn");
        assert_eq!(suppressed.len(), 2);
        assert_eq!(used, vec![true, true]);
    }

    #[test]
    fn apply_allowlist_reports_unused_entries() {
        let entries = parse_allowlist("L4 nowhere.rs *  # stale\n").unwrap();
        let (kept, suppressed, used) = apply_allowlist(vec![], &entries);
        assert!(kept.is_empty() && suppressed.is_empty());
        assert_eq!(used, vec![false]);
    }

    #[test]
    fn rule_must_match_exactly() {
        let entries = parse_allowlist("L2 ops.rs f  # reason\n").unwrap();
        let (kept, _, _) = apply_allowlist(vec![finding("L3", "x/ops.rs", "f")], &entries);
        assert_eq!(kept.len(), 1);
    }

    /// The crate's own tree must lint clean modulo the checked-in
    /// allowlist — the same gate `bpdq lint` and the CI lint job
    /// enforce, run under tier-1 so a hot-path or SAFETY regression
    /// fails `cargo test` before it ever reaches CI.
    #[test]
    fn own_source_tree_is_lint_clean() {
        let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
        let findings = lint_tree(&manifest.join("src")).expect("walk crate sources");
        let text = fs::read_to_string(manifest.join("lint.toml")).expect("read rust/lint.toml");
        let entries = parse_allowlist(&text).expect("allowlist parses");
        let (kept, _suppressed, used) = apply_allowlist(findings, &entries);
        assert!(
            kept.is_empty(),
            "lint violations in the tree:\n{}",
            kept.iter()
                .map(|f| format!("{}:{}: [{}] ({}) {}", f.path, f.line, f.rule, f.func, f.msg))
                .collect::<Vec<_>>()
                .join("\n")
        );
        for (e, u) in entries.iter().zip(&used) {
            assert!(
                *u,
                "unused allowlist entry at lint.toml:{} ({} {} {})",
                e.line, e.rule, e.path, e.func
            );
        }
    }
}
