//! Hand-rolled, dependency-free Rust lexer for the `lint` pass.
//!
//! This is deliberately *not* a full parser: the lint rules only need a
//! faithful answer to "is this byte code, comment, or literal?", plus
//! item-level structure (function boundaries, `unsafe` spans). The
//! lexer produces a **blanked** copy of the source — same byte length,
//! same newlines, but with every comment and every string/char-literal
//! *content* replaced by spaces — so downstream pattern scans can never
//! false-positive on text inside a doc comment or a format string.
//!
//! Handled correctly (and covered by self-tests below):
//! * line comments `//`, doc comments `///` / `//!`
//! * nested block comments `/* /* */ */`
//! * string literals with escapes (`"a\"b"`), byte strings `b"…"`
//! * raw strings `r"…"`, `r#"…"#` (any `#` count), `br#"…"#`
//! * char literals (`'x'`, `'\''`, `'\u{1F600}'`, `b'x'`) vs lifetimes
//!   (`'a`, `'static`, `'_`)
//! * function items: name, signature offset, brace-matched body span
//!   (a `;` inside `-> [u8; 4]` does not terminate the signature)
//! * `unsafe` blocks / `unsafe impl` sites with brace-matched spans

/// One comment, line-accurate. `text` is everything after the `//`
/// (so doc comments keep their leading `/` or `!`), or the interior of
/// a `/* … */` block.
#[derive(Debug, Clone)]
pub struct Comment {
    /// 1-based line of the comment's first character.
    pub line: usize,
    pub text: String,
    /// True for `/* … */` comments (which may span lines).
    pub block: bool,
}

/// A `fn` item found in the blanked source.
#[derive(Debug, Clone)]
pub struct FnItem {
    pub name: String,
    /// Byte offset of the `fn` keyword.
    pub sig_start: usize,
    /// 1-based line of the `fn` keyword.
    pub sig_line: usize,
    /// Byte span `[start, end]` of the body braces (inclusive of both
    /// braces), or `None` for a bodiless trait-method signature.
    pub body: Option<(usize, usize)>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnsafeKind {
    /// `unsafe { … }` expression/block.
    Block,
    /// `unsafe impl … for … { … }`.
    Impl,
    /// `unsafe fn` / `unsafe trait` / anything else keyword-adjacent.
    Other,
}

/// One occurrence of the `unsafe` keyword in real code.
#[derive(Debug, Clone)]
pub struct UnsafeSite {
    pub kind: UnsafeKind,
    /// Byte offset of the `unsafe` keyword.
    pub at: usize,
    /// 1-based line of the keyword.
    pub line: usize,
    /// Brace-matched span of the block/impl body, when present.
    pub span: Option<(usize, usize)>,
}

/// Lexed view of one source file.
pub struct SourceModel {
    pub path: String,
    pub src: String,
    /// Same length as `src`; comments and literal contents are spaces.
    pub blanked: String,
    pub comments: Vec<Comment>,
    pub fns: Vec<FnItem>,
    pub unsafe_sites: Vec<UnsafeSite>,
    line_starts: Vec<usize>,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Index of the `}` matching the `{` at `open` (depth-counted), or the
/// last byte if unbalanced.
fn match_brace(b: &[u8], open: usize) -> usize {
    debug_assert!(b[open] == b'{');
    let mut depth = 0usize;
    let mut i = open;
    while i < b.len() {
        match b[i] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return i;
                }
            }
            _ => {}
        }
        i += 1;
    }
    b.len().saturating_sub(1)
}

impl SourceModel {
    pub fn parse(path: &str, src: &str) -> SourceModel {
        let (blanked, comments) = blank(src);
        let line_starts = {
            let mut ls = vec![0usize];
            for (i, byte) in src.bytes().enumerate() {
                if byte == b'\n' {
                    ls.push(i + 1);
                }
            }
            ls
        };
        let mut m = SourceModel {
            path: path.to_string(),
            src: src.to_string(),
            blanked,
            comments,
            fns: Vec::new(),
            unsafe_sites: Vec::new(),
            line_starts,
        };
        m.fns = scan_fns(&m.blanked, &m);
        m.unsafe_sites = scan_unsafe(&m.blanked, &m);
        m
    }

    /// 1-based line number of a byte offset.
    pub fn line_of(&self, byte: usize) -> usize {
        match self.line_starts.binary_search(&byte) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// Byte offset where `line` (1-based) starts.
    pub fn line_start(&self, line: usize) -> usize {
        self.line_starts[(line - 1).min(self.line_starts.len() - 1)]
    }

    /// Original source text of `line` (1-based), without the newline.
    pub fn line_text(&self, line: usize) -> &str {
        let s = self.line_start(line);
        let e = self
            .line_starts
            .get(line)
            .map(|&x| x.saturating_sub(1))
            .unwrap_or(self.src.len());
        &self.src[s..e.max(s)]
    }

    /// Blanked text of `line` (1-based) — comments already spaces.
    pub fn blanked_line(&self, line: usize) -> &str {
        let s = self.line_start(line);
        let e = self
            .line_starts
            .get(line)
            .map(|&x| x.saturating_sub(1))
            .unwrap_or(self.blanked.len());
        &self.blanked[s..e.max(s)]
    }

    /// The line comment (or block comment) starting on `line`, if any.
    pub fn comment_on(&self, line: usize) -> Option<&Comment> {
        self.comments.iter().find(|c| c.line == line)
    }

    /// All `//!` inner-doc text, joined — the module doc header.
    pub fn module_doc(&self) -> String {
        let mut out = String::new();
        for c in &self.comments {
            if !c.block && c.text.starts_with('!') {
                out.push_str(&c.text[1..]);
                out.push('\n');
            }
        }
        out
    }

    /// Innermost fn whose body span contains `byte`.
    pub fn enclosing_fn(&self, byte: usize) -> Option<&FnItem> {
        self.fns
            .iter()
            .filter(|f| matches!(f.body, Some((s, e)) if s <= byte && byte <= e))
            .max_by_key(|f| f.body.unwrap().0)
    }

    /// True if `byte` falls inside any `unsafe { … }` block span.
    pub fn in_unsafe_block(&self, byte: usize) -> bool {
        self.unsafe_sites
            .iter()
            .any(|u| matches!(u.span, Some((s, e)) if u.kind == UnsafeKind::Block && s <= byte && byte <= e))
    }
}

/// Produce the blanked copy and the comment list.
fn blank(src: &str) -> (String, Vec<Comment>) {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let mut comments = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        // Line comment.
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let start = i + 2;
            let mut j = start;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            comments.push(Comment { line, text: src[start..j].to_string(), block: false });
            for slot in out.iter_mut().take(j).skip(i) {
                *slot = b' ';
            }
            i = j;
            continue;
        }
        // Block comment, possibly nested.
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start_line = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'\n' {
                    line += 1;
                    j += 1;
                } else if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let inner_end = if j >= i + 4 { j - 2 } else { i + 2 };
            comments.push(Comment {
                line: start_line,
                text: src[i + 2..inner_end].to_string(),
                block: true,
            });
            for k in i..j {
                if out[k] != b'\n' {
                    out[k] = b' ';
                }
            }
            i = j;
            continue;
        }
        // Raw string (r"…", r#"…"#, br#"…"#).
        if (c == b'r' || c == b'b') && (i == 0 || !is_ident(b[i - 1])) {
            let mut j = i + 1;
            if c == b'b' && j < n && b[j] == b'r' {
                j += 1;
            }
            let mut hashes = 0usize;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            let is_raw_prefix = j > i + 1 || c == b'r';
            if is_raw_prefix && j < n && b[j] == b'"' {
                // Scan for `"` followed by `hashes` hash marks.
                let mut k = j + 1;
                'raw: while k < n {
                    if b[k] == b'\n' {
                        line += 1;
                        k += 1;
                        continue;
                    }
                    if b[k] == b'"' {
                        let mut h = 0usize;
                        while h < hashes && k + 1 + h < n && b[k + 1 + h] == b'#' {
                            h += 1;
                        }
                        if h == hashes {
                            k += 1 + hashes;
                            break 'raw;
                        }
                    }
                    out[k] = b' ';
                    k += 1;
                }
                i = k;
                continue;
            }
            // Not a raw string — fall through to the default advance so
            // identifiers starting with r/b are walked normally.
        }
        // Plain or byte string literal with escapes.
        if c == b'"' {
            let mut j = i + 1;
            while j < n && b[j] != b'"' {
                if b[j] == b'\\' && j + 1 < n {
                    out[j] = b' ';
                    j += 1; // the escaped byte
                    if b[j] == b'\n' {
                        line += 1; // line-continuation escape
                    } else {
                        out[j] = b' ';
                    }
                    j += 1;
                    continue;
                }
                if b[j] == b'\n' {
                    line += 1;
                } else {
                    out[j] = b' ';
                }
                j += 1;
            }
            i = if j < n { j + 1 } else { j };
            continue;
        }
        // Char literal vs lifetime.
        if c == b'\'' {
            if i + 1 < n && b[i + 1] == b'\\' {
                // Escaped char literal: '\n', '\'', '\u{…}'.
                let mut j = i + 2;
                if j < n && b[j] == b'u' {
                    j += 1;
                    if j < n && b[j] == b'{' {
                        while j < n && b[j] != b'}' {
                            j += 1;
                        }
                        j += 1;
                    }
                } else {
                    j += 1;
                }
                if j < n && b[j] == b'\'' {
                    for slot in out.iter_mut().take(j).skip(i + 1) {
                        *slot = b' ';
                    }
                    i = j + 1;
                    continue;
                }
                i += 1;
                continue;
            }
            // 'X' where X is one (possibly multi-byte) char and the
            // next char is the closing quote → char literal; otherwise
            // it is a lifetime and we leave it alone.
            if let Some(ch) = src[i + 1..].chars().next() {
                let w = ch.len_utf8();
                if ch != '\'' && i + 1 + w < n && b[i + 1 + w] == b'\'' {
                    for slot in out.iter_mut().take(i + 1 + w).skip(i + 1) {
                        *slot = b' ';
                    }
                    i = i + 2 + w;
                    continue;
                }
            }
            i += 1;
            continue;
        }
        i += 1;
    }
    // out was built from valid UTF-8 and every replacement is ASCII
    // space applied to whole multi-byte sequences, so this cannot fail.
    (String::from_utf8(out).expect("blanked source is valid UTF-8"), comments)
}

/// Find every `fn` item in the blanked source. Scanning resumes just
/// past each opening brace, so nested fns are recorded too (innermost
/// resolution happens in [`SourceModel::enclosing_fn`]).
fn scan_fns(blanked: &str, m: &SourceModel) -> Vec<FnItem> {
    let b = blanked.as_bytes();
    let n = b.len();
    let mut fns = Vec::new();
    let mut i = 0usize;
    while i + 2 <= n {
        let word_ok = b[i] == b'f'
            && b[i + 1] == b'n'
            && (i == 0 || !is_ident(b[i - 1]))
            && (i + 2 == n || !is_ident(b[i + 2]));
        if !word_ok {
            i += 1;
            continue;
        }
        let sig_start = i;
        let mut j = i + 2;
        while j < n && (b[j] as char).is_ascii_whitespace() {
            j += 1;
        }
        let name_start = j;
        while j < n && is_ident(b[j]) {
            j += 1;
        }
        if j == name_start {
            // `fn(` — a fn-pointer type, not an item.
            i += 2;
            continue;
        }
        let name = blanked[name_start..j].to_string();
        // Scan the signature for the body `{` or a terminating `;`,
        // tracking paren/bracket depth so `-> [u8; 4]` and default
        // const-generic args never end the signature early.
        let mut depth = 0i32;
        let mut k = j;
        let mut body = None;
        while k < n {
            match b[k] {
                b'(' | b'[' => depth += 1,
                b')' | b']' => depth -= 1,
                b';' if depth == 0 => break,
                b'{' if depth == 0 => {
                    body = Some((k, match_brace(b, k)));
                    break;
                }
                _ => {}
            }
            k += 1;
        }
        fns.push(FnItem { name, sig_start, sig_line: m.line_of(sig_start), body });
        // Resume just inside the body (nested items get their own
        // entries) or after the signature terminator.
        i = match body {
            Some((open, _)) => open + 1,
            None => k.max(j),
        };
    }
    fns
}

/// Find every `unsafe` keyword in the blanked source.
fn scan_unsafe(blanked: &str, m: &SourceModel) -> Vec<UnsafeSite> {
    let b = blanked.as_bytes();
    let n = b.len();
    let pat = b"unsafe";
    let mut sites = Vec::new();
    let mut i = 0usize;
    while i + pat.len() <= n {
        if &b[i..i + pat.len()] != pat
            || (i > 0 && is_ident(b[i - 1]))
            || (i + pat.len() < n && is_ident(b[i + pat.len()]))
        {
            i += 1;
            continue;
        }
        let mut j = i + pat.len();
        while j < n && (b[j] as char).is_ascii_whitespace() {
            j += 1;
        }
        let (kind, span) = if j < n && b[j] == b'{' {
            (UnsafeKind::Block, Some((j, match_brace(b, j))))
        } else if blanked[j..].starts_with("impl") {
            // The impl body braces, for completeness.
            let open = blanked[j..].find('{').map(|o| j + o);
            (UnsafeKind::Impl, open.map(|o| (o, match_brace(b, o))))
        } else {
            (UnsafeKind::Other, None)
        };
        sites.push(UnsafeSite { kind, at: i, line: m.line_of(i), span });
        i += pat.len();
    }
    sites
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_comments_are_blanked_and_recorded() {
        let m = SourceModel::parse("t.rs", "let x = 1; // unsafe trailing\nlet y = 2;\n");
        assert!(!m.blanked.contains("unsafe"));
        assert_eq!(m.comments.len(), 1);
        assert_eq!(m.comments[0].line, 1);
        assert!(m.comments[0].text.contains("unsafe trailing"));
        assert!(m.unsafe_sites.is_empty());
        assert_eq!(m.blanked.len(), m.src.len());
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ fn after() {}\n";
        let m = SourceModel::parse("t.rs", src);
        assert!(!m.blanked.contains("outer"));
        assert!(!m.blanked.contains("still"));
        assert_eq!(m.fns.len(), 1);
        assert_eq!(m.fns[0].name, "after");
        assert!(m.comments[0].block);
    }

    #[test]
    fn raw_strings_hide_their_contents() {
        let src = "let s = r#\"unsafe { fn fake() {} } \"# ;\nlet t = r\"also unsafe\";\nlet u = br##\"double \"# hash\"##;\n";
        let m = SourceModel::parse("t.rs", src);
        assert!(!m.blanked.contains("unsafe"));
        assert!(!m.blanked.contains("fake"));
        assert!(!m.blanked.contains("hash"));
        assert!(m.fns.is_empty());
        assert!(m.unsafe_sites.is_empty());
    }

    #[test]
    fn string_escapes_do_not_end_the_literal() {
        let src = "let s = \"a\\\" // not a comment\"; let live = 1;\n";
        let m = SourceModel::parse("t.rs", src);
        assert!(!m.blanked.contains("not a comment"));
        assert!(m.blanked.contains("let live"));
        assert!(m.comments.is_empty());
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let src = "fn f<'a>(x: &'a str) -> char { let q = '\\''; let b = b'{'; let u = '\\u{41}'; 'x' }\n";
        let m = SourceModel::parse("t.rs", src);
        // The '{' char literal must not look like an open brace: the fn
        // body must still brace-match to the real closing brace.
        assert_eq!(m.fns.len(), 1);
        let (s, e) = m.fns[0].body.unwrap();
        assert_eq!(&m.src[s..=s], "{");
        assert_eq!(&m.src[e..=e], "}");
        assert_eq!(e, src.trim_end().len() - 1);
        // Lifetimes survive blanking (harmless), literal contents do not.
        assert!(m.blanked.contains("'a"));
        assert!(!m.blanked.contains("u{41}"));
    }

    #[test]
    fn fn_signature_scan_ignores_array_semicolons() {
        let src = "fn id(x: [u8; 4]) -> [u8; 4] { x }\nfn trait_sig(y: usize) -> [u8; 2];\nfn last() {}\n";
        let m = SourceModel::parse("t.rs", src);
        let names: Vec<&str> = m.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, ["id", "trait_sig", "last"]);
        assert!(m.fns[0].body.is_some());
        assert!(m.fns[1].body.is_none());
        assert!(m.fns[2].body.is_some());
    }

    #[test]
    fn fn_pointer_types_are_not_items() {
        let src = "type Hook = fn(usize) -> usize;\nfn real(h: fn(usize) -> usize) -> usize { h(1) }\n";
        let m = SourceModel::parse("t.rs", src);
        let names: Vec<&str> = m.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, ["real"]);
    }

    #[test]
    fn nested_fns_resolve_innermost() {
        let src = "fn outer() {\n    fn inner(v: usize) -> usize { v + 1 }\n    inner(2);\n}\n";
        let m = SourceModel::parse("t.rs", src);
        assert_eq!(m.fns.len(), 2);
        let at = src.find("v + 1").unwrap();
        assert_eq!(m.enclosing_fn(at).unwrap().name, "inner");
        let at2 = src.find("inner(2)").unwrap();
        assert_eq!(m.enclosing_fn(at2).unwrap().name, "outer");
    }

    #[test]
    fn unsafe_sites_and_spans() {
        let src = "unsafe impl Send for T {}\nfn f(p: *const f32) -> f32 {\n    unsafe { *p.add(1) }\n}\n";
        let m = SourceModel::parse("t.rs", src);
        assert_eq!(m.unsafe_sites.len(), 2);
        assert_eq!(m.unsafe_sites[0].kind, UnsafeKind::Impl);
        assert_eq!(m.unsafe_sites[0].line, 1);
        assert_eq!(m.unsafe_sites[1].kind, UnsafeKind::Block);
        assert_eq!(m.unsafe_sites[1].line, 3);
        let at = src.find(".add(").unwrap();
        assert!(m.in_unsafe_block(at));
        assert!(!m.in_unsafe_block(src.find("Send").unwrap()));
    }

    #[test]
    fn unsafe_word_boundaries() {
        let src = "#![deny(unsafe_op_in_unsafe_fn)]\nlet not_unsafe_here = 1;\n";
        let m = SourceModel::parse("t.rs", src);
        assert!(m.unsafe_sites.is_empty());
    }

    #[test]
    fn module_doc_collects_inner_doc_lines() {
        let src = "//! Top docs.\n//! aliasing: one handle per slot.\nfn f() {}\n";
        let m = SourceModel::parse("t.rs", src);
        assert!(m.module_doc().contains("aliasing: one handle"));
    }

    #[test]
    fn line_of_and_line_text() {
        let src = "alpha\nbeta\ngamma\n";
        let m = SourceModel::parse("t.rs", src);
        assert_eq!(m.line_of(0), 1);
        assert_eq!(m.line_of(6), 2);
        assert_eq!(m.line_text(2), "beta");
        assert_eq!(m.line_text(3), "gamma");
    }
}
