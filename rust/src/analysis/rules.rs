//! The lint-rule registry: five project-native invariants, machine-checked.
//!
//! | Rule | Invariant |
//! |------|-----------|
//! | `L1` | every `unsafe` block / `unsafe impl` is preceded by a `// SAFETY:` comment |
//! | `L2` | no heap allocation in functions marked `// lint: hot` |
//! | `L3` | no `.unwrap()` / `.expect(` / `panic!` / non-debug asserts in hot- or sweep-marked functions |
//! | `L4` | no `.lock()` / `Mutex` / `RwLock` in hot- or sweep-marked functions |
//! | `L5` | every `from_raw_parts` / pointer `.add(` sits inside an `unsafe` block, in a file with an `//! aliasing:` protocol header |
//!
//! Markers are plain comments attached to the **next** `fn` item:
//! `// lint: hot` opts a function into L2+L3+L4 (the per-token decode
//! path: zero allocation, zero panics, zero locks); `// lint: sweep`
//! opts into L3+L4 only (the scheduler sweep loop may size buffers but
//! must never panic or take a shared lock per iteration).
//!
//! The analysis is textual and per-function — it does not chase calls,
//! so a hot function calling an allocating helper is not caught unless
//! the helper is itself marked. That is the deliberate trade for a
//! dependency-free pass that runs with no toolchain; reviews still own
//! the call graph.

use super::lexer::{SourceModel, UnsafeKind};

/// One rule violation (pre-allowlist).
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule ID: "L1".."L5".
    pub rule: &'static str,
    /// Path label the file was lexed under.
    pub path: String,
    /// 1-based line.
    pub line: usize,
    /// Enclosing fn name, or "-" at module scope.
    pub func: String,
    /// What went wrong, human-oriented.
    pub msg: String,
    /// The trimmed source line.
    pub excerpt: String,
}

/// A registered rule.
pub struct Rule {
    pub id: &'static str,
    pub summary: &'static str,
    pub run: fn(&SourceModel, &mut Vec<Finding>),
}

pub const REGISTRY: &[Rule] = &[
    Rule { id: "L1", summary: "unsafe block/impl requires a // SAFETY: comment", run: rule_l1 },
    Rule { id: "L2", summary: "no heap allocation in `// lint: hot` functions", run: rule_l2 },
    Rule { id: "L3", summary: "no unwrap/expect/panic/assert in hot or sweep functions", run: rule_l3 },
    Rule { id: "L4", summary: "no lock acquisition in hot or sweep functions", run: rule_l4 },
    Rule { id: "L5", summary: "raw-pointer calls need an unsafe block and an //! aliasing: header", run: rule_l5 },
];

/// Lex `src` and run every registered rule over it.
pub fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let m = SourceModel::parse(path, src);
    let mut out = Vec::new();
    for rule in REGISTRY {
        (rule.run)(&m, &mut out);
    }
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

// ---------------------------------------------------------------- markers

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Marker {
    Hot,
    Sweep,
}

/// `(fn index, marker)` for every `// lint: hot|sweep` comment. A
/// marker attaches to the first fn whose signature starts after it.
fn marked_fns(m: &SourceModel) -> Vec<(usize, Marker)> {
    let mut out = Vec::new();
    for c in &m.comments {
        let marker = match c.text.trim() {
            "lint: hot" => Marker::Hot,
            "lint: sweep" => Marker::Sweep,
            _ => continue,
        };
        let at = m.line_start(c.line);
        if let Some(idx) = m
            .fns
            .iter()
            .enumerate()
            .filter(|(_, f)| f.sig_start >= at)
            .min_by_key(|(_, f)| f.sig_start)
            .map(|(i, _)| i)
        {
            out.push((idx, marker));
        }
    }
    out
}

// --------------------------------------------------------- pattern scans

/// `(pattern, needs_nonident_prev)`. Patterns starting with `.` or `:`
/// are self-delimiting; identifier-led patterns additionally require a
/// non-identifier byte before them, which is what lets `debug_assert!`
/// pass an `assert!(` scan.
type Pat = (&'static str, bool);

const L2_PATTERNS: &[Pat] = &[
    ("vec!", true),
    ("Vec::new", true),
    (".to_vec(", false),
    (".collect(", false),
    (".collect::", false),
    ("Box::new", true),
    ("String::from", true),
    ("format!", true),
];

const L3_PATTERNS: &[Pat] = &[
    (".unwrap()", false),
    (".expect(", false),
    ("panic!", true),
    ("assert!(", true),
    ("assert_eq!(", true),
    ("assert_ne!(", true),
    ("unreachable!", true),
    ("todo!", true),
];

const L4_PATTERNS: &[Pat] = &[(".lock(", false), ("Mutex", true), ("RwLock", true)];

const L5_PATTERNS: &[Pat] = &[("from_raw_parts", true), (".add(", false)];

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// All `(byte offset, pattern)` hits in `blanked[lo..hi]`.
fn scan_range(blanked: &str, lo: usize, hi: usize, pats: &[Pat]) -> Vec<(usize, &'static str)> {
    let hay = blanked.as_bytes();
    let mut hits = Vec::new();
    for &(pat, ident_led) in pats {
        let p = pat.as_bytes();
        if hi < lo + p.len() {
            continue;
        }
        for off in lo..=hi - p.len() {
            if &hay[off..off + p.len()] != p {
                continue;
            }
            if ident_led && off > 0 && is_ident(hay[off - 1]) {
                continue;
            }
            hits.push((off, pat));
        }
    }
    hits
}

fn excerpt(m: &SourceModel, line: usize) -> String {
    m.line_text(line).trim().chars().take(96).collect()
}

fn func_at(m: &SourceModel, byte: usize) -> String {
    m.enclosing_fn(byte).map(|f| f.name.clone()).unwrap_or_else(|| "-".to_string())
}

// ------------------------------------------------------------------ L1

/// Is the `unsafe` on `line` covered by a `// SAFETY:` comment — on the
/// same line, or in the contiguous run of comment / blank / attribute
/// lines directly above? Any code line breaks the run, so consecutive
/// `unsafe impl`s each need their own comment.
fn has_safety_comment(m: &SourceModel, line: usize) -> bool {
    if let Some(c) = m.comment_on(line) {
        if c.text.contains("SAFETY:") {
            return true;
        }
    }
    let mut l = line;
    while l > 1 {
        l -= 1;
        if let Some(c) = m.comment_on(l) {
            if c.text.contains("SAFETY:") {
                return true;
            }
            if m.blanked_line(l).trim().is_empty() {
                continue; // pure comment line — keep walking up
            }
            return false; // trailing comment on a code line
        }
        let code = m.blanked_line(l).trim();
        if code.is_empty() || code.starts_with("#[") || code.starts_with("#![") {
            continue; // blank line or attribute
        }
        return false;
    }
    false
}

fn rule_l1(m: &SourceModel, out: &mut Vec<Finding>) {
    for site in &m.unsafe_sites {
        if has_safety_comment(m, site.line) {
            continue;
        }
        let what = match site.kind {
            UnsafeKind::Block => "unsafe block",
            UnsafeKind::Impl => "unsafe impl",
            UnsafeKind::Other => "unsafe item",
        };
        out.push(Finding {
            rule: "L1",
            path: m.path.clone(),
            line: site.line,
            func: func_at(m, site.at),
            msg: format!("{what} without a preceding // SAFETY: comment"),
            excerpt: excerpt(m, site.line),
        });
    }
}

// --------------------------------------------------------------- L2–L4

fn body_scan_rule(
    m: &SourceModel,
    out: &mut Vec<Finding>,
    rule: &'static str,
    pats: &[Pat],
    include_sweep: bool,
    msg: &str,
) {
    let mut seen = std::collections::HashSet::new();
    for (idx, marker) in marked_fns(m) {
        if marker == Marker::Sweep && !include_sweep {
            continue;
        }
        if !seen.insert(idx) {
            continue;
        }
        let f = &m.fns[idx];
        let (lo, hi) = match f.body {
            Some(span) => span,
            None => continue,
        };
        for (off, pat) in scan_range(&m.blanked, lo, hi, pats) {
            let line = m.line_of(off);
            out.push(Finding {
                rule,
                path: m.path.clone(),
                line,
                func: func_at(m, off),
                msg: format!("{msg}: `{pat}` in `{}` (marked `// lint: {}`)", f.name, match marker {
                    Marker::Hot => "hot",
                    Marker::Sweep => "sweep",
                }),
                excerpt: excerpt(m, line),
            });
        }
    }
}

fn rule_l2(m: &SourceModel, out: &mut Vec<Finding>) {
    // Hot only: the sweep loop may size its admission buffers.
    body_scan_rule(m, out, "L2", L2_PATTERNS, false, "heap allocation");
}

fn rule_l3(m: &SourceModel, out: &mut Vec<Finding>) {
    body_scan_rule(m, out, "L3", L3_PATTERNS, true, "panic path");
}

fn rule_l4(m: &SourceModel, out: &mut Vec<Finding>) {
    body_scan_rule(m, out, "L4", L4_PATTERNS, true, "lock acquisition");
}

// ------------------------------------------------------------------ L5

fn rule_l5(m: &SourceModel, out: &mut Vec<Finding>) {
    let hits = scan_range(&m.blanked, 0, m.blanked.len(), L5_PATTERNS);
    for &(off, pat) in &hits {
        if m.in_unsafe_block(off) {
            continue;
        }
        let line = m.line_of(off);
        out.push(Finding {
            rule: "L5",
            path: m.path.clone(),
            line,
            func: func_at(m, off),
            msg: format!("raw-pointer call `{pat}` outside an unsafe block"),
            excerpt: excerpt(m, line),
        });
    }
    if !hits.is_empty() && !m.module_doc().contains("aliasing:") {
        out.push(Finding {
            rule: "L5",
            path: m.path.clone(),
            line: 1,
            func: "-".to_string(),
            msg: "file uses raw-pointer strip carving but declares no `//! aliasing:` protocol header".to_string(),
            excerpt: excerpt(m, 1),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // ---- L1

    #[test]
    fn l1_fires_on_uncommented_unsafe_block() {
        let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        let f = lint_source("t.rs", src);
        assert!(rules_of(&f).contains(&"L1"), "{f:?}");
        let hit = f.iter().find(|x| x.rule == "L1").unwrap();
        assert_eq!(hit.line, 2);
        assert_eq!(hit.func, "f");
    }

    #[test]
    fn l1_clean_with_safety_comment_and_attributes() {
        let src = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller upholds validity.\n    #[allow(clippy::all)]\n    unsafe { *p }\n}\n";
        let f = lint_source("t.rs", src);
        assert!(!rules_of(&f).contains(&"L1"), "{f:?}");
    }

    #[test]
    fn l1_same_line_comment_counts() {
        let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p } // SAFETY: valid by construction\n}\n";
        assert!(!rules_of(&lint_source("t.rs", src)).contains(&"L1"));
    }

    #[test]
    fn l1_consecutive_unsafe_impls_each_need_a_comment() {
        let src = "// SAFETY: T owns its data.\nunsafe impl Send for T {}\nunsafe impl Sync for T {}\n";
        let f = lint_source("t.rs", src);
        let l1: Vec<_> = f.iter().filter(|x| x.rule == "L1").collect();
        assert_eq!(l1.len(), 1, "{f:?}");
        assert_eq!(l1[0].line, 3); // the Sync impl is uncovered
    }

    #[test]
    fn l1_safety_in_string_or_doc_mention_does_not_count() {
        // The word SAFETY inside a *string literal* above the unsafe
        // block is blanked and is not a comment — must still fire.
        let src = "fn f(p: *const u8) -> u8 {\n    let _s = \"SAFETY: not a comment\";\n    unsafe { *p }\n}\n";
        assert!(rules_of(&lint_source("t.rs", src)).contains(&"L1"));
    }

    // ---- L2

    #[test]
    fn l2_fires_on_alloc_in_hot_fn() {
        let src = "// lint: hot\nfn kernel(n: usize) -> usize {\n    let v = vec![0u8; n];\n    let w: Vec<usize> = (0..n).collect();\n    v.len() + w.len()\n}\n";
        let f = lint_source("t.rs", src);
        let l2: Vec<_> = f.iter().filter(|x| x.rule == "L2").collect();
        assert_eq!(l2.len(), 2, "{f:?}");
        assert!(l2.iter().all(|x| x.func == "kernel"));
    }

    #[test]
    fn l2_clean_unmarked_fn_and_clean_hot_fn() {
        let src = "fn cold(n: usize) -> Vec<u8> {\n    vec![0u8; n]\n}\n// lint: hot\nfn hot(acc: &mut [f32], x: &[f32]) {\n    for (a, &b) in acc.iter_mut().zip(x) { *a += b; }\n}\n";
        let f = lint_source("t.rs", src);
        assert!(!rules_of(&f).contains(&"L2"), "{f:?}");
    }

    #[test]
    fn l2_marker_attaches_to_next_fn_only() {
        let src = "// lint: hot\nfn first(x: &mut [f32]) { x[0] = 1.0; }\nfn second(n: usize) -> Vec<u8> { vec![0; n] }\n";
        let f = lint_source("t.rs", src);
        assert!(!rules_of(&f).contains(&"L2"), "{f:?}");
    }

    #[test]
    fn l2_sweep_marker_allows_allocation() {
        let src = "// lint: sweep\nfn sweep_loop(n: usize) -> Vec<u8> { Vec::with_capacity(n) }\n";
        assert!(!rules_of(&lint_source("t.rs", src)).contains(&"L2"));
    }

    // ---- L3

    #[test]
    fn l3_fires_on_unwrap_and_assert_in_hot_fn() {
        let src = "// lint: hot\nfn kernel(x: Option<usize>, n: usize) -> usize {\n    assert!(n > 0, \"n\");\n    x.unwrap()\n}\n";
        let f = lint_source("t.rs", src);
        let l3: Vec<_> = f.iter().filter(|x| x.rule == "L3").collect();
        assert_eq!(l3.len(), 2, "{f:?}");
    }

    #[test]
    fn l3_debug_assert_is_allowed() {
        let src = "// lint: hot\nfn kernel(a: &[f32], b: &[f32]) -> f32 {\n    debug_assert_eq!(a.len(), b.len());\n    debug_assert!(!a.is_empty());\n    a[0] + b[0]\n}\n";
        let f = lint_source("t.rs", src);
        assert!(!rules_of(&f).contains(&"L3"), "{f:?}");
    }

    #[test]
    fn l3_applies_to_sweep_marker_too() {
        let src = "// lint: sweep\nfn sweep_loop(x: Option<usize>) -> usize { x.expect(\"x\") }\n";
        let f = lint_source("t.rs", src);
        assert!(rules_of(&f).contains(&"L3"), "{f:?}");
    }

    // ---- L4

    #[test]
    fn l4_fires_on_lock_in_hot_fn() {
        let src = "// lint: hot\nfn kernel(m: &std::sync::Mutex<usize>) -> usize {\n    *m.lock().unwrap()\n}\n";
        let f = lint_source("t.rs", src);
        // Mutex in the signature is outside the body; `.lock(` inside fires.
        assert!(f.iter().any(|x| x.rule == "L4" && x.line == 3), "{f:?}");
    }

    #[test]
    fn l4_clean_unmarked_fn_may_lock() {
        let src = "fn cold(m: &std::sync::Mutex<usize>) -> usize { *m.lock().unwrap() }\n";
        assert!(!rules_of(&lint_source("t.rs", src)).contains(&"L4"));
    }

    // ---- L5

    #[test]
    fn l5_fires_outside_unsafe_block() {
        let src = "//! aliasing: one handle per slot.\nfn f(p: *const f32) -> *const f32 {\n    p.add(1)\n}\n";
        let f = lint_source("t.rs", src);
        assert!(f.iter().any(|x| x.rule == "L5" && x.line == 3), "{f:?}");
    }

    #[test]
    fn l5_fires_on_missing_aliasing_header() {
        let src = "fn f(p: *const f32) -> f32 {\n    // SAFETY: in-bounds by construction.\n    unsafe { *p.add(1) }\n}\n";
        let f = lint_source("t.rs", src);
        assert!(
            f.iter().any(|x| x.rule == "L5" && x.msg.contains("aliasing")),
            "{f:?}"
        );
    }

    #[test]
    fn l5_clean_with_header_and_unsafe() {
        let src = "//! aliasing: one handle per slot; see kv.rs.\nfn f(p: *const f32) -> f32 {\n    // SAFETY: in-bounds by construction.\n    unsafe { *p.add(1) }\n}\n";
        let f = lint_source("t.rs", src);
        assert!(!rules_of(&f).contains(&"L5"), "{f:?}");
    }

    #[test]
    fn l5_fetch_add_is_not_a_pointer_add() {
        let src = "fn f(c: &std::sync::atomic::AtomicUsize) -> usize {\n    c.fetch_add(1, std::sync::atomic::Ordering::Relaxed)\n}\n";
        let f = lint_source("t.rs", src);
        assert!(!rules_of(&f).contains(&"L5"), "{f:?}");
    }

    // ---- registry

    #[test]
    fn registry_ids_are_unique_and_complete() {
        let ids: Vec<&str> = REGISTRY.iter().map(|r| r.id).collect();
        assert_eq!(ids, ["L1", "L2", "L3", "L4", "L5"]);
    }
}
