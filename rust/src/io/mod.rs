//! Minimal I/O codecs (serde is not in the offline vendor set).
//!
//! * [`json`] — a small JSON *writer* for reports/metrics (we never need
//!   to parse arbitrary JSON; the artifact metadata we do read uses the
//!   line-oriented formats below).
//! * binary helpers — little-endian readers/writers for the `.tlm`
//!   weight format exchanged with the python trainer (see
//!   `python/compile/export_weights.py` for the mirrored writer).

pub mod json;
pub mod tlm;

use std::io::{self, Read, Write};

pub fn write_u32<W: Write>(w: &mut W, v: u32) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

pub fn write_u64<W: Write>(w: &mut W, v: u64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

pub fn write_f32<W: Write>(w: &mut W, v: f32) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

pub fn write_f32s<W: Write>(w: &mut W, vs: &[f32]) -> io::Result<()> {
    // Bulk byte conversion: one write syscall per slice.
    let mut buf = Vec::with_capacity(vs.len() * 4);
    for v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(&buf)
}

pub fn read_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn read_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub fn read_f32<R: Read>(r: &mut R) -> io::Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

pub fn read_f32s<R: Read>(r: &mut R, n: usize) -> io::Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a length-prefixed UTF-8 string.
pub fn write_str<W: Write>(w: &mut W, s: &str) -> io::Result<()> {
    write_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())
}

pub fn read_str<R: Read>(r: &mut R) -> io::Result<String> {
    let n = read_u32(r)? as usize;
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf).map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrips() {
        let mut buf = Vec::new();
        write_u32(&mut buf, 0xDEAD_BEEF).unwrap();
        write_u64(&mut buf, u64::MAX - 7).unwrap();
        write_f32(&mut buf, -1.5e-3).unwrap();
        write_str(&mut buf, "héllo wörld").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_u32(&mut r).unwrap(), 0xDEAD_BEEF);
        assert_eq!(read_u64(&mut r).unwrap(), u64::MAX - 7);
        assert_eq!(read_f32(&mut r).unwrap(), -1.5e-3);
        assert_eq!(read_str(&mut r).unwrap(), "héllo wörld");
    }

    #[test]
    fn f32_slice_roundtrip() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        let mut buf = Vec::new();
        write_f32s(&mut buf, &xs).unwrap();
        let got = read_f32s(&mut &buf[..], xs.len()).unwrap();
        assert_eq!(xs, got);
    }

    #[test]
    fn short_read_errors() {
        let buf = [1u8, 2];
        assert!(read_u32(&mut &buf[..]).is_err());
    }
}
