//! Tiny JSON writer (reports & metrics only — we never parse JSON).

use std::fmt::Write;

/// Incremental JSON object/array builder producing compact valid JSON.
#[derive(Default)]
pub struct JsonWriter {
    buf: String,
    needs_comma: Vec<bool>,
}

impl JsonWriter {
    pub fn new() -> Self {
        Self::default()
    }

    fn pre_value(&mut self) {
        if let Some(top) = self.needs_comma.last_mut() {
            if *top {
                self.buf.push(',');
            }
            *top = true;
        }
    }

    pub fn begin_object(&mut self) -> &mut Self {
        self.pre_value();
        self.buf.push('{');
        self.needs_comma.push(false);
        self
    }

    pub fn end_object(&mut self) -> &mut Self {
        self.needs_comma.pop();
        self.buf.push('}');
        self
    }

    pub fn begin_array(&mut self) -> &mut Self {
        self.pre_value();
        self.buf.push('[');
        self.needs_comma.push(false);
        self
    }

    pub fn end_array(&mut self) -> &mut Self {
        self.needs_comma.pop();
        self.buf.push(']');
        self
    }

    pub fn key(&mut self, k: &str) -> &mut Self {
        self.pre_value();
        Self::push_escaped(&mut self.buf, k);
        self.buf.push(':');
        // The following value must not emit a comma.
        if let Some(top) = self.needs_comma.last_mut() {
            *top = false;
        }
        self
    }

    pub fn string(&mut self, v: &str) -> &mut Self {
        self.pre_value();
        Self::push_escaped(&mut self.buf, v);
        self
    }

    pub fn number(&mut self, v: f64) -> &mut Self {
        self.pre_value();
        if v.is_finite() {
            let _ = write!(self.buf, "{v}");
        } else {
            // JSON has no inf/nan; stringify (ppl can overflow for AWQ-W2!)
            Self::push_escaped(&mut self.buf, &v.to_string());
        }
        self
    }

    pub fn int(&mut self, v: i64) -> &mut Self {
        self.pre_value();
        let _ = write!(self.buf, "{v}");
        self
    }

    pub fn bool(&mut self, v: bool) -> &mut Self {
        self.pre_value();
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    fn push_escaped(buf: &mut String, s: &str) {
        buf.push('"');
        for c in s.chars() {
            match c {
                '"' => buf.push_str("\\\""),
                '\\' => buf.push_str("\\\\"),
                '\n' => buf.push_str("\\n"),
                '\t' => buf.push_str("\\t"),
                '\r' => buf.push_str("\\r"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(buf, "\\u{:04x}", c as u32);
                }
                c => buf.push(c),
            }
        }
        buf.push('"');
    }

    pub fn finish(self) -> String {
        debug_assert!(self.needs_comma.is_empty(), "unbalanced json");
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_with_values() {
        let mut w = JsonWriter::new();
        w.begin_object()
            .key("name")
            .string("bpdq")
            .key("bits")
            .int(2)
            .key("ppl")
            .number(8.35)
            .key("ok")
            .bool(true)
            .end_object();
        assert_eq!(w.finish(), r#"{"name":"bpdq","bits":2,"ppl":8.35,"ok":true}"#);
    }

    #[test]
    fn nested_arrays() {
        let mut w = JsonWriter::new();
        w.begin_object().key("rows").begin_array();
        for i in 0..3 {
            w.begin_array().int(i).int(i * 2).end_array();
        }
        w.end_array().end_object();
        assert_eq!(w.finish(), r#"{"rows":[[0,0],[1,2],[2,4]]}"#);
    }

    #[test]
    fn escaping() {
        let mut w = JsonWriter::new();
        w.begin_object().key("s").string("a\"b\\c\nd").end_object();
        assert_eq!(w.finish(), "{\"s\":\"a\\\"b\\\\c\\nd\"}");
    }

    #[test]
    fn nonfinite_number_stringified() {
        let mut w = JsonWriter::new();
        w.begin_array().number(f64::INFINITY).end_array();
        assert_eq!(w.finish(), r#"["inf"]"#);
    }
}
