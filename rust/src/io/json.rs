//! Tiny JSON writer + parser.
//!
//! The writer produces compact reports and metrics; the parser exists
//! for the serving front door (`serve --listen` request bodies and the
//! `loadgen` client's SSE/metrics frames). Both are dependency-free.
//! The parser is defensive by construction: it never panics on
//! arbitrary input (malformed documents are `Err`), and recursion depth
//! is capped so adversarial `[[[[…` bodies cannot blow the stack.

use std::fmt::Write;

/// Incremental JSON object/array builder producing compact valid JSON.
#[derive(Default)]
pub struct JsonWriter {
    buf: String,
    needs_comma: Vec<bool>,
}

impl JsonWriter {
    pub fn new() -> Self {
        Self::default()
    }

    fn pre_value(&mut self) {
        if let Some(top) = self.needs_comma.last_mut() {
            if *top {
                self.buf.push(',');
            }
            *top = true;
        }
    }

    pub fn begin_object(&mut self) -> &mut Self {
        self.pre_value();
        self.buf.push('{');
        self.needs_comma.push(false);
        self
    }

    pub fn end_object(&mut self) -> &mut Self {
        self.needs_comma.pop();
        self.buf.push('}');
        self
    }

    pub fn begin_array(&mut self) -> &mut Self {
        self.pre_value();
        self.buf.push('[');
        self.needs_comma.push(false);
        self
    }

    pub fn end_array(&mut self) -> &mut Self {
        self.needs_comma.pop();
        self.buf.push(']');
        self
    }

    pub fn key(&mut self, k: &str) -> &mut Self {
        self.pre_value();
        Self::push_escaped(&mut self.buf, k);
        self.buf.push(':');
        // The following value must not emit a comma.
        if let Some(top) = self.needs_comma.last_mut() {
            *top = false;
        }
        self
    }

    pub fn string(&mut self, v: &str) -> &mut Self {
        self.pre_value();
        Self::push_escaped(&mut self.buf, v);
        self
    }

    pub fn number(&mut self, v: f64) -> &mut Self {
        self.pre_value();
        if v.is_finite() {
            let _ = write!(self.buf, "{v}");
        } else {
            // JSON has no inf/nan; stringify (ppl can overflow for AWQ-W2!)
            Self::push_escaped(&mut self.buf, &v.to_string());
        }
        self
    }

    pub fn int(&mut self, v: i64) -> &mut Self {
        self.pre_value();
        let _ = write!(self.buf, "{v}");
        self
    }

    pub fn bool(&mut self, v: bool) -> &mut Self {
        self.pre_value();
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    pub fn null(&mut self) -> &mut Self {
        self.pre_value();
        self.buf.push_str("null");
        self
    }

    fn push_escaped(buf: &mut String, s: &str) {
        buf.push('"');
        for c in s.chars() {
            match c {
                '"' => buf.push_str("\\\""),
                '\\' => buf.push_str("\\\\"),
                '\n' => buf.push_str("\\n"),
                '\t' => buf.push_str("\\t"),
                '\r' => buf.push_str("\\r"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(buf, "\\u{:04x}", c as u32);
                }
                c => buf.push(c),
            }
        }
        buf.push('"');
    }

    pub fn finish(self) -> String {
        debug_assert!(self.needs_comma.is_empty(), "unbalanced json");
        self.buf
    }
}

/// Maximum nesting depth [`JsonValue::parse`] accepts. Request bodies
/// on the wire are flat objects; anything deeper is hostile input.
const MAX_JSON_DEPTH: usize = 64;

/// A parsed JSON document. Object members keep their source order
/// (duplicate keys: first wins via [`JsonValue::get`]).
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Parse a complete JSON document. Errors carry a byte offset and a
    /// short reason; the parser never panics, whatever the input.
    pub fn parse(text: &str) -> Result<JsonValue, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object member lookup (`None` for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value that is a non-negative integer (fractional or
    /// out-of-range numbers are `None`, not truncated).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(items) => Some(items.as_slice()),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, JsonValue::Null)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.i) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn err(&self, msg: &str) -> String {
        format!("{msg} at byte {}", self.i)
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.b.get(self.i) == Some(&c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<JsonValue, String> {
        if depth > MAX_JSON_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.b.get(self.i) {
            None => Err(self.err("unexpected end of input")),
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(c) if *c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected byte 0x{c:02x}"))),
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self, depth: usize) -> Result<JsonValue, String> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(JsonValue::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value(depth + 1)?;
            members.push((key, v));
            self.skip_ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(JsonValue::Obj(members));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<JsonValue, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(JsonValue::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.i;
        while let Some(&c) = self.b.get(self.i) {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        // The token alphabet above cannot spell `inf`/`nan`, so a
        // successful parse that is still non-finite means overflow
        // (`1e999`) — rejected: JSON has no such value.
        let tok = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad utf8"))?;
        match tok.parse::<f64>() {
            Ok(v) if v.is_finite() => Ok(JsonValue::Num(v)),
            _ => Err(format!("bad number `{tok}` at byte {start}")),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.i;
            // Bulk-copy the unescaped span. `"` and `\` are ASCII, so
            // the span boundary can never split a multi-byte char.
            while let Some(&c) = self.b.get(self.i) {
                if c == b'"' || c == b'\\' || c < 0x20 {
                    break;
                }
                self.i += 1;
            }
            out.push_str(
                std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad utf8"))?,
            );
            match self.b.get(self.i) {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    self.escape(&mut out)?;
                }
                Some(_) => return Err(self.err("raw control byte in string")),
            }
        }
    }

    fn escape(&mut self, out: &mut String) -> Result<(), String> {
        let c = *self.b.get(self.i).ok_or_else(|| self.err("truncated escape"))?;
        self.i += 1;
        match c {
            b'"' => out.push('"'),
            b'\\' => out.push('\\'),
            b'/' => out.push('/'),
            b'b' => out.push('\u{8}'),
            b'f' => out.push('\u{c}'),
            b'n' => out.push('\n'),
            b'r' => out.push('\r'),
            b't' => out.push('\t'),
            b'u' => {
                let hi = self.hex4()?;
                let code = if (0xD800..0xDC00).contains(&hi) {
                    // Surrogate pair: a low surrogate must follow.
                    if self.b.get(self.i) != Some(&b'\\') || self.b.get(self.i + 1) != Some(&b'u')
                    {
                        return Err(self.err("lone high surrogate"));
                    }
                    self.i += 2;
                    let lo = self.hex4()?;
                    if !(0xDC00..0xE000).contains(&lo) {
                        return Err(self.err("bad low surrogate"));
                    }
                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                } else {
                    hi
                };
                out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
            }
            _ => return Err(self.err("bad escape")),
        }
        Ok(())
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d;
            self.i += 1;
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_with_values() {
        let mut w = JsonWriter::new();
        w.begin_object()
            .key("name")
            .string("bpdq")
            .key("bits")
            .int(2)
            .key("ppl")
            .number(8.35)
            .key("ok")
            .bool(true)
            .end_object();
        assert_eq!(w.finish(), r#"{"name":"bpdq","bits":2,"ppl":8.35,"ok":true}"#);
    }

    #[test]
    fn nested_arrays() {
        let mut w = JsonWriter::new();
        w.begin_object().key("rows").begin_array();
        for i in 0..3 {
            w.begin_array().int(i).int(i * 2).end_array();
        }
        w.end_array().end_object();
        assert_eq!(w.finish(), r#"{"rows":[[0,0],[1,2],[2,4]]}"#);
    }

    #[test]
    fn escaping() {
        let mut w = JsonWriter::new();
        w.begin_object().key("s").string("a\"b\\c\nd").end_object();
        assert_eq!(w.finish(), "{\"s\":\"a\\\"b\\\\c\\nd\"}");
    }

    #[test]
    fn nonfinite_number_stringified() {
        let mut w = JsonWriter::new();
        w.begin_array().number(f64::INFINITY).end_array();
        assert_eq!(w.finish(), r#"["inf"]"#);
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(JsonValue::parse("null").unwrap(), JsonValue::Null);
        assert_eq!(JsonValue::parse("true").unwrap(), JsonValue::Bool(true));
        assert_eq!(JsonValue::parse(" false ").unwrap(), JsonValue::Bool(false));
        assert_eq!(JsonValue::parse("-1.5e2").unwrap(), JsonValue::Num(-150.0));
        assert_eq!(JsonValue::parse(r#""a\nb""#).unwrap(), JsonValue::Str("a\nb".into()));
    }

    #[test]
    fn parse_request_shaped_object() {
        let v = JsonValue::parse(
            r#"{"prompt":"2+2=","max_new":8,"temperature":0.5,"tokens":[1,2,3],"tenant":"a"}"#,
        )
        .unwrap();
        assert_eq!(v.get("prompt").and_then(JsonValue::as_str), Some("2+2="));
        assert_eq!(v.get("max_new").and_then(JsonValue::as_u64), Some(8));
        assert_eq!(v.get("temperature").and_then(JsonValue::as_f64), Some(0.5));
        let arr = v.get("tokens").unwrap().as_array().unwrap();
        let toks: Vec<u64> = arr.iter().filter_map(|t| t.as_u64()).collect();
        assert_eq!(toks, vec![1, 2, 3]);
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "", "{", "}", "[1,", "{\"a\":}", "{\"a\" 1}", "tru", "01x", "1e999", "nan",
            "\"unterminated", "\"bad \\q escape\"", "\"\\ud800 lone\"", "{}extra", "\u{7}",
        ] {
            assert!(JsonValue::parse(bad).is_err(), "accepted malformed: {bad:?}");
        }
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(JsonValue::parse(r#""Aé""#).unwrap(), JsonValue::Str("Aé".into()));
        // Surrogate pair → one astral char.
        assert_eq!(JsonValue::parse(r#""😀""#).unwrap(), JsonValue::Str("😀".into()));
    }

    #[test]
    fn parse_depth_is_capped_not_stack_overflowed() {
        let deep = "[".repeat(100_000);
        assert!(JsonValue::parse(&deep).is_err());
        let nested = format!("{}1{}", "[".repeat(10), "]".repeat(10));
        assert!(JsonValue::parse(&nested).is_ok());
    }

    #[test]
    fn writer_output_roundtrips_through_parser() {
        let mut w = JsonWriter::new();
        w.begin_object()
            .key("name")
            .string("zipf \"wire\"\n")
            .key("rows")
            .begin_array()
            .int(-3)
            .number(1.25)
            .bool(false)
            .end_array()
            .key("null_like")
            .string("null")
            .end_object();
        let v = JsonValue::parse(&w.finish()).unwrap();
        assert_eq!(v.get("name").and_then(JsonValue::as_str), Some("zipf \"wire\"\n"));
        let rows = v.get("rows").unwrap().as_array().unwrap();
        assert_eq!(rows[0].as_f64(), Some(-3.0));
        assert_eq!(rows[1].as_f64(), Some(1.25));
        assert_eq!(rows[2].as_bool(), Some(false));
    }

    #[test]
    fn prop_parser_never_panics_on_arbitrary_bytes() {
        // The front door feeds attacker-controlled bodies straight into
        // the parser: any input must produce Ok or Err, never a panic.
        crate::proptest_lite::check("json_parse_total", |rng| {
            let len = rng.below(257) as usize;
            let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            if let Ok(text) = std::str::from_utf8(&bytes) {
                let _ = JsonValue::parse(text);
            }
            Ok(())
        });
    }

    #[test]
    fn prop_mutated_valid_documents_never_panic() {
        // Take a valid request body, flip a few bytes, and parse: the
        // result may be Ok or Err but must never panic. Mutants that
        // stay valid UTF-8 exercise deep parser states.
        let base = br#"{"prompt":"2+2=","max_new":8,"tokens":[1,2,3],"t":{"a":[true,null,"x"]}}"#;
        crate::proptest_lite::check("json_parse_mutated", |rng| {
            let mut doc = base.to_vec();
            for _ in 0..(1 + rng.below(4)) {
                let i = rng.below(doc.len() as u64) as usize;
                doc[i] = rng.below(256) as u8;
            }
            let cut = rng.below(doc.len() as u64 + 1) as usize;
            if let Ok(text) = std::str::from_utf8(&doc[..cut]) {
                let _ = JsonValue::parse(text);
            }
            Ok(())
        });
    }
}
