//! `.tlm` — the tiny-LM weight interchange format.
//!
//! Written by `python/compile/export_weights.py` after training, read by
//! [`crate::model`]. Two header revisions (all little-endian):
//!
//! ```text
//! magic   b"TLM1"                                        (legacy, MHA)
//! u32 ×6  vocab_size, d_model, n_layers, n_heads, d_ff, max_seq
//!
//! magic   b"TLM2"                                        (GQA-aware)
//! u32 ×7  vocab_size, d_model, n_layers, n_heads, n_kv_heads, d_ff, max_seq
//!
//! then, for either revision:
//! u32     n_tensors
//! repeat n_tensors:
//!   str   name          (u32 length + utf-8)
//!   u32   rows, cols    (cols == 1 for vectors)
//!   f32[] rows*cols     (row-major)
//! ```
//!
//! Reading a `TLM1` file defaults `n_kv_heads = n_heads` (every pre-GQA
//! checkpoint is plain multi-head attention). Writing emits `TLM1` when
//! `n_kv_heads == n_heads` — byte-identical to the legacy format — and
//! `TLM2` only when the model actually uses grouped-query attention.

use super::{read_f32s, read_str, read_u32, write_f32s, write_str, write_u32};
use crate::tensor::Matrix;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

pub const MAGIC: &[u8; 4] = b"TLM1";
pub const MAGIC_V2: &[u8; 4] = b"TLM2";

/// Model hyper-parameters carried in the header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TlmHeader {
    pub vocab_size: u32,
    pub d_model: u32,
    pub n_layers: u32,
    pub n_heads: u32,
    /// Number of K/V heads (grouped-query attention). Equal to `n_heads`
    /// for MHA; a proper divisor of it shrinks the KV cache by
    /// `n_heads / n_kv_heads`.
    pub n_kv_heads: u32,
    pub d_ff: u32,
    pub max_seq: u32,
}

/// A parsed checkpoint: header + named tensors.
#[derive(Clone, Debug)]
pub struct TlmFile {
    pub header: TlmHeader,
    pub tensors: BTreeMap<String, Matrix>,
}

impl TlmFile {
    pub fn new(header: TlmHeader) -> Self {
        Self { header, tensors: BTreeMap::new() }
    }

    pub fn insert(&mut self, name: &str, m: Matrix) {
        self.tensors.insert(name.to_string(), m);
    }

    pub fn get(&self, name: &str) -> Result<&Matrix> {
        self.tensors
            .get(name)
            .with_context(|| format!("tensor `{name}` missing from checkpoint"))
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        let h = &self.header;
        if h.n_kv_heads == h.n_heads {
            // MHA models stay byte-identical to the legacy format.
            w.write_all(MAGIC)?;
            for v in [h.vocab_size, h.d_model, h.n_layers, h.n_heads, h.d_ff, h.max_seq] {
                write_u32(w, v)?;
            }
        } else {
            w.write_all(MAGIC_V2)?;
            for v in [
                h.vocab_size,
                h.d_model,
                h.n_layers,
                h.n_heads,
                h.n_kv_heads,
                h.d_ff,
                h.max_seq,
            ] {
                write_u32(w, v)?;
            }
        }
        write_u32(w, self.tensors.len() as u32)?;
        for (name, m) in &self.tensors {
            write_str(w, name)?;
            write_u32(w, m.rows() as u32)?;
            write_u32(w, m.cols() as u32)?;
            write_f32s(w, m.data())?;
        }
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        let v2 = if &magic == MAGIC {
            false
        } else if &magic == MAGIC_V2 {
            true
        } else {
            bail!("bad magic {magic:?}: not a .tlm file")
        };
        let vocab_size = read_u32(r)?;
        let d_model = read_u32(r)?;
        let n_layers = read_u32(r)?;
        let n_heads = read_u32(r)?;
        // Legacy TLM1 headers predate GQA: every head is a KV head.
        let n_kv_heads = if v2 { read_u32(r)? } else { n_heads };
        let header = TlmHeader {
            vocab_size,
            d_model,
            n_layers,
            n_heads,
            n_kv_heads,
            d_ff: read_u32(r)?,
            max_seq: read_u32(r)?,
        };
        let n = read_u32(r)? as usize;
        if n > 100_000 {
            bail!("implausible tensor count {n}");
        }
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            let name = read_str(r)?;
            let rows = read_u32(r)? as usize;
            let cols = read_u32(r)? as usize;
            if rows.saturating_mul(cols) > 1 << 28 {
                bail!("implausible tensor size {rows}x{cols} for `{name}`");
            }
            let data = read_f32s(r, rows * cols)?;
            tensors.insert(name, Matrix::from_vec(rows, cols, data));
        }
        Ok(Self { header, tensors })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        self.write_to(&mut w)?;
        w.flush()?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        Self::read_from(&mut BufReader::new(f))
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.tensors.values().map(|m| m.rows() * m.cols()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TlmFile {
        let header = TlmHeader {
            vocab_size: 68,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 32,
            max_seq: 64,
        };
        let mut f = TlmFile::new(header);
        f.insert("embed", Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]));
        f.insert("l0.wq", Matrix::full(4, 4, 0.5));
        f
    }

    #[test]
    fn roundtrip() {
        let f = sample();
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        // MHA (n_kv_heads == n_heads) serializes as legacy TLM1.
        assert_eq!(&buf[..4], MAGIC);
        let g = TlmFile::read_from(&mut &buf[..]).unwrap();
        assert_eq!(g.header, f.header);
        assert_eq!(g.tensors.len(), 2);
        assert_eq!(g.get("embed").unwrap().row(1), &[4., 5., 6.]);
        assert_eq!(g.n_params(), 6 + 16);
    }

    #[test]
    fn gqa_header_roundtrip_uses_v2() {
        let mut f = sample();
        f.header.n_heads = 4;
        f.header.n_kv_heads = 2;
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        assert_eq!(&buf[..4], MAGIC_V2);
        let g = TlmFile::read_from(&mut &buf[..]).unwrap();
        assert_eq!(g.header, f.header);
        assert_eq!(g.header.n_kv_heads, 2);
        assert_eq!(g.get("embed").unwrap().row(0), &[1., 2., 3.]);
    }

    #[test]
    fn legacy_header_defaults_kv_heads() {
        // Hand-build a TLM1 byte stream (no n_kv_heads field): reading it
        // must default n_kv_heads = n_heads.
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        for v in [68u32, 16, 2, 4, 32, 64] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf.extend_from_slice(&0u32.to_le_bytes()); // n_tensors
        let g = TlmFile::read_from(&mut &buf[..]).unwrap();
        assert_eq!(g.header.n_heads, 4);
        assert_eq!(g.header.n_kv_heads, 4);
        assert_eq!(g.header.d_ff, 32);
        assert_eq!(g.header.max_seq, 64);
    }

    #[test]
    fn bad_magic_rejected() {
        let buf = b"NOPE\x00\x00\x00\x00".to_vec();
        assert!(TlmFile::read_from(&mut &buf[..]).is_err());
    }

    #[test]
    fn missing_tensor_error_names_it() {
        let f = sample();
        let err = f.get("nonexistent").unwrap_err().to_string();
        assert!(err.contains("nonexistent"));
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("bpdq_tlm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.tlm");
        let f = sample();
        f.save(&path).unwrap();
        let g = TlmFile::load(&path).unwrap();
        assert_eq!(g.header, f.header);
        std::fs::remove_file(&path).ok();
    }
}
