//! Character-level tokenizer shared with the python trainer.
//!
//! The vocabulary is a *fixed* ASCII subset (defined here and mirrored in
//! `python/compile/data_gen.py`); `artifacts/vocab.txt` is written by the
//! python side at artifact-build time and [`Tokenizer::verify_artifact`]
//! cross-checks the two definitions so rust and python can never drift.
//!
//! A char tokenizer (rather than BPE) keeps the tiny LM's embedding small
//! and makes exact-match generation tasks trivially checkable; the
//! quantization study is about weight statistics, not tokenization.

use std::collections::HashMap;
use std::path::Path;

/// Characters the synthetic corpus can emit. Index in this string = token
/// id. Keep in sync with `python/compile/data_gen.py::VOCAB`.
pub const VOCAB: &str =
    "\n abcdefghijklmnopqrstuvwxyz0123456789.,:;?!'\"()+-*/=<>[]{}@#$%&_^|~";

/// Token id of the padding token (newline doubles as BOS/pad — the corpus
/// is newline-delimited documents).
pub const PAD_ID: u32 = 0;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    id_of: HashMap<char, u32>,
    char_of: Vec<char>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Self {
        let char_of: Vec<char> = VOCAB.chars().collect();
        let id_of = char_of
            .iter()
            .enumerate()
            .map(|(i, &c)| (c, i as u32))
            .collect();
        Self { id_of, char_of }
    }

    pub fn vocab_size(&self) -> usize {
        self.char_of.len()
    }

    /// Encode a string; unknown characters map to space (never panics so
    /// the serving path is total).
    pub fn encode(&self, s: &str) -> Vec<u32> {
        s.chars()
            .map(|c| {
                self.id_of
                    .get(&c)
                    .copied()
                    .unwrap_or_else(|| self.id_of[&' '])
            })
            .collect()
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| {
                self.char_of
                    .get(i as usize)
                    .copied()
                    .unwrap_or('\u{FFFD}')
            })
            .collect()
    }

    /// Check the artifact vocab file written by python matches this
    /// definition exactly.
    pub fn verify_artifact(&self, path: &Path) -> anyhow::Result<()> {
        let contents = std::fs::read_to_string(path)?;
        // File format: one char per line, escaped \n as literal "\\n".
        let chars: Vec<char> = contents
            .lines()
            .map(|l| if l == "\\n" { '\n' } else { l.chars().next().unwrap_or(' ') })
            .collect();
        if chars != self.char_of {
            anyhow::bail!(
                "vocab mismatch: artifact has {} chars, tokenizer has {}",
                chars.len(),
                self.char_of.len()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = Tokenizer::new();
        let s = "the answer is 42.\nnext line";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn unknown_maps_to_space() {
        let t = Tokenizer::new();
        let ids = t.encode("héllo");
        assert_eq!(t.decode(&ids), "h llo");
    }

    #[test]
    fn ids_dense_and_stable() {
        let t = Tokenizer::new();
        assert_eq!(t.encode("\n")[0], PAD_ID);
        assert_eq!(t.vocab_size(), VOCAB.chars().count());
        // every id decodes to exactly the vocab char
        for (i, c) in VOCAB.chars().enumerate() {
            assert_eq!(t.decode(&[i as u32]), c.to_string());
        }
    }

    #[test]
    fn out_of_range_decode_is_total() {
        let t = Tokenizer::new();
        assert_eq!(t.decode(&[9999]), "\u{FFFD}");
    }
}
