//! Evaluation task generators (proxies for the paper's benchmarks).
//!
//! Every generator is deterministic given a seed and consistent with the
//! [`super::corpus::World`] the model was trained on. See DESIGN.md §3 for
//! the paper-benchmark ↔ proxy mapping.

use super::corpus::{arith_problem, CorpusGen, COLORS, HOMES, LABELS, SIZES};
use crate::rng::Rng;
use std::fmt::Write as _;

/// Which paper benchmark a task proxies (used by the report tables).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// GSM8K / MATH500 proxy: few-shot exact-match generation.
    Arith,
    /// ARC-C / MMLU proxy: 4-way multiple choice over world facts.
    FactChoice,
    /// BoolQ proxy: yes/no over world facts.
    BoolFact,
    /// HellaSwag proxy: pick the consistent continuation.
    Continuation,
    /// LongBench retrieval proxy.
    Passkey,
    /// LongBench classification proxy.
    Classify,
    /// LongBench summarization proxy (keyword recovery).
    Summary,
}

impl TaskKind {
    pub fn paper_name(self) -> &'static str {
        match self {
            TaskKind::Arith => "GSM8K*",
            TaskKind::FactChoice => "ARC-C*/MMLU*",
            TaskKind::BoolFact => "BoolQ*",
            TaskKind::Continuation => "HellaS*",
            TaskKind::Passkey => "PassageRetrieval*",
            TaskKind::Classify => "TREC*",
            TaskKind::Summary => "SAMSum*",
        }
    }
}

/// A generation task: feed `prompt`, greedy-decode, and check the decoded
/// text starts with `answer`.
#[derive(Clone, Debug)]
pub struct ArithTask {
    pub prompt: String,
    pub answer: String,
}

/// A likelihood-scored multiple-choice task (lm-eval convention): the
/// choice with the highest total log-likelihood continuation wins.
#[derive(Clone, Debug)]
pub struct ChoiceTask {
    pub prompt: String,
    pub choices: Vec<String>,
    pub correct: usize,
}

/// A long-context generation task.
#[derive(Clone, Debug)]
pub struct LongCtxTask {
    pub kind: TaskKind,
    pub prompt: String,
    pub answer: String,
}

/// Few-shot arithmetic exact-match (GSM8K proxy). `shots` in-context
/// examples followed by the question.
pub fn gen_arith(seed: u64, n: usize, shots: usize) -> Vec<ArithTask> {
    let mut rng = Rng::new(seed ^ 0xA717);
    (0..n)
        .map(|_| {
            let mut prompt = String::new();
            for _ in 0..shots {
                let (e, a) = arith_problem(&mut rng);
                let _ = write!(prompt, "q: {e}=? a: {a}.\n");
            }
            let (e, a) = arith_problem(&mut rng);
            let _ = write!(prompt, "q: {e}=? a:");
            ArithTask { prompt, answer: format!(" {a}.") }
        })
        .collect()
}

/// 4-way multiple choice over world facts (ARC-C/MMLU proxy).
pub fn gen_fact_choice(gen: &CorpusGen, seed: u64, n: usize) -> Vec<ChoiceTask> {
    let mut rng = Rng::new(seed ^ 0xFC01);
    let w = &gen.world;
    (0..n)
        .map(|_| {
            let e = rng.below_usize(w.entities.len());
            let ent = &w.entities[e];
            let (prompt, opts, correct): (String, &[&str], usize) = match rng.below_usize(3) {
                0 => (format!("the color of {ent} is"), COLORS, w.color[e]),
                1 => (format!("the size of {ent} is"), SIZES, w.size[e]),
                _ => (format!("the home of {ent} is the"), HOMES, w.home[e]),
            };
            ChoiceTask {
                prompt,
                choices: opts.iter().map(|o| format!(" {o}.")).collect(),
                correct,
            }
        })
        .collect()
}

/// Yes/no fact verification (BoolQ proxy): statement is true half the time.
/// Scored as 2-way choice between the true attribute and a distractor.
pub fn gen_bool_fact(gen: &CorpusGen, seed: u64, n: usize) -> Vec<ChoiceTask> {
    let mut rng = Rng::new(seed ^ 0xB001);
    let w = &gen.world;
    (0..n)
        .map(|_| {
            let e = rng.below_usize(w.entities.len());
            let ent = &w.entities[e];
            let true_color = COLORS[w.color[e]];
            let mut wrong = rng.below_usize(COLORS.len());
            while wrong == w.color[e] {
                wrong = rng.below_usize(COLORS.len());
            }
            // Order is randomized; `correct` tracks the true statement.
            let truth_first = rng.coin(0.5);
            let (c0, c1, correct) = if truth_first {
                (true_color, COLORS[wrong], 0)
            } else {
                (COLORS[wrong], true_color, 1)
            };
            ChoiceTask {
                prompt: format!("the color of {ent} is"),
                choices: vec![format!(" {c0}."), format!(" {c1}.")],
                correct,
            }
        })
        .collect()
}

/// Continuation consistency (HellaSwag proxy): given a fact prefix about
/// an entity, pick the continuation consistent with the world over ones
/// consistent with *other* entities.
pub fn gen_continuation(gen: &CorpusGen, seed: u64, n: usize) -> Vec<ChoiceTask> {
    let mut rng = Rng::new(seed ^ 0xCE11);
    let w = &gen.world;
    (0..n)
        .map(|_| {
            let e = rng.below_usize(w.entities.len());
            let ent = &w.entities[e];
            let prompt =
                format!("the color of {ent} is {}. the home of {ent} is the", COLORS[w.color[e]]);
            let mut choices = vec![format!(" {}.", HOMES[w.home[e]])];
            let mut used = vec![w.home[e]];
            while choices.len() < 4 {
                let h = rng.below_usize(HOMES.len());
                if !used.contains(&h) {
                    used.push(h);
                    choices.push(format!(" {}.", HOMES[h]));
                }
            }
            // Shuffle, tracking the correct index.
            let mut order: Vec<usize> = (0..choices.len()).collect();
            rng.shuffle(&mut order);
            let correct = order.iter().position(|&i| i == 0).unwrap();
            let choices = order.iter().map(|&i| choices[i].clone()).collect();
            ChoiceTask { prompt, choices, correct }
        })
        .collect()
}

/// Passkey retrieval at a given filler distance (LongBench retrieval
/// proxy). Distance is measured in filler clauses between statement and
/// recall.
pub fn gen_passkey(gen: &CorpusGen, seed: u64, n: usize, n_filler: usize) -> Vec<LongCtxTask> {
    let mut rng = Rng::new(seed ^ 0x9A55);
    (0..n)
        .map(|_| {
            let doc = gen.passkey_doc(&mut rng, n_filler);
            // Split at the final "recall: the passkey is " — prompt ends
            // right before the digits.
            let cut = doc.rfind(" recall: the passkey is").unwrap();
            let prompt = doc[..cut + " recall: the passkey is".len()].to_string();
            let answer = doc[cut + " recall: the passkey is".len()..].to_string();
            LongCtxTask { kind: TaskKind::Passkey, prompt, answer }
        })
        .collect()
}

/// Keyword-label classification (TREC proxy).
pub fn gen_classify(gen: &CorpusGen, seed: u64, n: usize) -> Vec<ChoiceTask> {
    let mut rng = Rng::new(seed ^ 0xC1A5);
    (0..n)
        .map(|_| {
            let doc = gen.classify_doc(&mut rng);
            let cut = doc.rfind(" label:").unwrap();
            let prompt = doc[..cut + " label:".len()].to_string();
            let correct_label = doc[cut + " label: ".len()..].trim_end_matches('.');
            let correct = LABELS.iter().position(|&l| l == correct_label).unwrap();
            ChoiceTask {
                prompt,
                choices: LABELS.iter().map(|l| format!(" {l}.")).collect(),
                correct,
            }
        })
        .collect()
}

/// Contextual keyword retrieval at distance (LongBench retrieval proxy
/// that the build-budget tiny-LM can actually perform): the label is
/// determined by a keyword planted `n_filler` clauses before the "label:"
/// cue, so accuracy measures retrieval across context. (The passkey task
/// requires verbatim 4-digit copying, which the 0.8M model trained on a
/// 96-char window never acquires — see EXPERIMENTS.md.)
pub fn gen_classify_at_distance(
    gen: &CorpusGen,
    seed: u64,
    n: usize,
    n_filler: usize,
) -> Vec<ChoiceTask> {
    let mut rng = Rng::new(seed ^ 0xCD15);
    (0..n)
        .map(|_| {
            let doc = gen.classify_doc(&mut rng);
            let cut = doc.rfind(" label:").unwrap();
            let mut prompt = doc[..cut].to_string();
            for _ in 0..n_filler {
                prompt.push(' ');
                prompt.push_str(&gen.filler_doc(&mut rng));
            }
            prompt.push_str(" label:");
            let correct_label = doc[cut + " label: ".len()..].trim_end_matches('.');
            let correct = LABELS.iter().position(|&l| l == correct_label).unwrap();
            ChoiceTask {
                prompt,
                choices: LABELS.iter().map(|l| format!(" {l}.")).collect(),
                correct,
            }
        })
        .collect()
}

/// Summary proxy: after a passkey-style doc, ask for the planted keyword.
/// ("summarize" = recover the salient token from a long document.)
pub fn gen_summary(gen: &CorpusGen, seed: u64, n: usize, n_filler: usize) -> Vec<LongCtxTask> {
    let mut rng = Rng::new(seed ^ 0x5CC5);
    (0..n)
        .map(|_| {
            let doc = gen.passkey_doc(&mut rng, n_filler);
            let first = doc.find("passkey is ").unwrap() + "passkey is ".len();
            let key = doc[first..first + 4].to_string();
            let cut = doc.rfind(" recall:").unwrap();
            let prompt = format!("{} recall: the passkey is", &doc[..cut]);
            LongCtxTask { kind: TaskKind::Summary, prompt, answer: format!(" {key}") }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusConfig;

    fn gen() -> CorpusGen {
        CorpusGen::new(CorpusConfig::default())
    }

    #[test]
    fn arith_tasks_deterministic_and_formatted() {
        let a = gen_arith(1, 10, 3);
        let b = gen_arith(1, 10, 3);
        assert_eq!(a.len(), 10);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
            assert!(x.prompt.ends_with("a:"), "{}", x.prompt);
            assert!(x.answer.ends_with('.'));
            assert_eq!(x.prompt.matches("q:").count(), 4); // 3 shots + 1
        }
    }

    #[test]
    fn fact_choice_correct_is_world_truth() {
        let g = gen();
        for t in gen_fact_choice(&g, 2, 50) {
            assert!(t.correct < t.choices.len());
            // the correct choice must appear in the training corpus as a
            // fact statement
            let full = format!("{}{}", t.prompt, t.choices[t.correct]);
            assert!(
                full.starts_with("the color of")
                    || full.starts_with("the size of")
                    || full.starts_with("the home of")
            );
        }
    }

    #[test]
    fn bool_fact_two_choices() {
        let g = gen();
        let tasks = gen_bool_fact(&g, 3, 40);
        let firsts = tasks.iter().filter(|t| t.correct == 0).count();
        assert!(firsts > 5 && firsts < 35, "order should be randomized: {firsts}");
        for t in &tasks {
            assert_eq!(t.choices.len(), 2);
            assert_ne!(t.choices[0], t.choices[1]);
        }
    }

    #[test]
    fn continuation_has_unique_correct() {
        let g = gen();
        for t in gen_continuation(&g, 4, 30) {
            assert_eq!(t.choices.len(), 4);
            let mut c = t.choices.clone();
            c.sort();
            c.dedup();
            assert_eq!(c.len(), 4, "choices must be distinct");
        }
    }

    #[test]
    fn passkey_answer_is_digits() {
        let g = gen();
        for t in gen_passkey(&g, 5, 20, 4) {
            let trimmed = t.answer.trim_start().trim_end_matches('.');
            assert_eq!(trimmed.len(), 4);
            assert!(trimmed.chars().all(|c| c.is_ascii_digit()), "{t:?}");
            // and the key appears in the prompt (stated earlier)
            assert!(t.prompt.contains(trimmed));
        }
    }

    #[test]
    fn classify_correct_matches_keyword() {
        let g = gen();
        for t in gen_classify(&g, 6, 30) {
            let kw = ["sun", "moon", "star"][t.correct];
            assert!(t.prompt.contains(kw), "{:?}", t);
        }
    }

    #[test]
    fn summary_recovers_first_key() {
        let g = gen();
        for t in gen_summary(&g, 7, 10, 6) {
            assert!(t.prompt.contains(t.answer.trim()));
        }
    }
}
