//! Synthetic data substrate.
//!
//! The paper calibrates on C4 and evaluates on WikiText-2 / GSM8K /
//! MATH500 / ARC-C / BoolQ / HellaSwag / MMLU / LongBench. None of those
//! are available offline, so this module builds the closest synthetic
//! equivalents that exercise the same code paths (DESIGN.md §3):
//!
//! * [`tokenizer`] — a fixed char-level tokenizer shared (byte-for-byte)
//!   with the python trainer via `artifacts/vocab.txt`;
//! * [`corpus`]    — a deterministic template-grammar + Zipf-vocabulary
//!   corpus generator, with an arithmetic sub-corpus (the "reasoning"
//!   slice) and held-out splits;
//! * [`tasks`]     — evaluation task generators: few-shot arithmetic
//!   exact-match (GSM8K proxy), likelihood-scored multiple choice
//!   (ARC/BoolQ/HellaSwag/MMLU proxy), passkey retrieval + keyword
//!   summary + classification (LongBench proxy).

pub mod corpus;
pub mod tasks;
pub mod tokenizer;

pub use corpus::{CorpusConfig, CorpusGen, Split};
pub use tasks::{ArithTask, ChoiceTask, LongCtxTask, TaskKind};
pub use tokenizer::Tokenizer;
