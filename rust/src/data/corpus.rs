//! Deterministic synthetic corpus generator.
//!
//! Rust is the source of truth for data (DESIGN.md §3): `bpdq gen-data`
//! writes `artifacts/{vocab.txt, corpus_train.txt, corpus_eval.txt,
//! corpus_calib.txt}` and the python trainer consumes them, so the two
//! languages can never disagree about the data distribution.
//!
//! The corpus is a mixture of five document kinds chosen so that (a) a
//! ~1M-parameter char-LM can learn them to near-determinism, and (b) each
//! paper benchmark has a faithful proxy:
//!
//! * **facts**     — a consistent entity→attribute world ("the color of
//!   kapu is red.") → multiple-choice likelihood tasks (ARC/BoolQ/MMLU
//!   proxies);
//! * **arith**     — "q: 3+5=? a: 8." → few-shot exact-match generation
//!   (GSM8K/MATH500 proxy, the quantization-sensitive regime);
//! * **filler**    — template grammar over a Zipf-ranked pseudo-word
//!   vocabulary → realistic rank-frequency skew in the activations (and
//!   hence a realistically ill-conditioned Hessian);
//! * **passkey**   — state-then-recall passkey documents → long-context
//!   retrieval (LongBench proxy);
//! * **classify**  — "text: <words>. label: <A|B|C>" documents whose label
//!   is determined by a keyword → classification proxy.

use super::tokenizer::Tokenizer;
use crate::rng::{Rng, Zipf};
use std::fmt::Write as _;

/// Which slice of the corpus to generate. Different splits use disjoint
/// RNG streams but the *same* fact world, so eval questions are about
/// facts the model saw in training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Calib,
    Eval,
}

impl Split {
    fn stream(self) -> u64 {
        match self {
            Split::Train => 0x1111,
            Split::Calib => 0x2222,
            Split::Eval => 0x3333,
        }
    }
}

#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub seed: u64,
    /// Number of entities in the fact world.
    pub n_entities: usize,
    /// Pseudo-word vocabulary size for filler text.
    pub n_words: usize,
    /// Zipf exponent for filler word frequencies.
    pub zipf_s: f64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        Self { seed: 0xB9D9, n_entities: 24, n_words: 160, zipf_s: 1.05 }
    }
}

/// Attribute kinds in the fact world.
pub const COLORS: &[&str] = &["red", "blue", "green", "gold"];
pub const SIZES: &[&str] = &["big", "small", "tiny", "huge"];
pub const HOMES: &[&str] = &["cave", "lake", "tree", "hill"];
pub const LABELS: &[&str] = &["alpha", "beta", "gamma"];

/// The consistent entity→attribute assignment shared by all splits.
#[derive(Clone, Debug)]
pub struct World {
    pub entities: Vec<String>,
    pub color: Vec<usize>,
    pub size: Vec<usize>,
    pub home: Vec<usize>,
}

impl World {
    fn build(cfg: &CorpusConfig) -> Self {
        let mut rng = Rng::new(cfg.seed ^ 0xFAC7);
        let entities = (0..cfg.n_entities).map(|i| pseudo_word(&mut rng, i)).collect::<Vec<_>>();
        let color = (0..cfg.n_entities).map(|_| rng.below_usize(COLORS.len())).collect();
        let size = (0..cfg.n_entities).map(|_| rng.below_usize(SIZES.len())).collect();
        let home = (0..cfg.n_entities).map(|_| rng.below_usize(HOMES.len())).collect();
        Self { entities, color, size, home }
    }
}

/// Deterministic CV-syllable pseudo-word ("kapu", "mirona", …).
fn pseudo_word(rng: &mut Rng, salt: usize) -> String {
    const C: &[u8] = b"bcdfgklmnprstvz";
    const V: &[u8] = b"aeiou";
    let mut local = rng.fork(salt as u64 + 17);
    let syllables = 2 + local.below_usize(2);
    let mut w = String::new();
    for _ in 0..syllables {
        w.push(C[local.below_usize(C.len())] as char);
        w.push(V[local.below_usize(V.len())] as char);
    }
    w
}

/// Corpus generator. Documents are newline-terminated single lines.
pub struct CorpusGen {
    pub cfg: CorpusConfig,
    pub world: World,
    words: Vec<String>,
    zipf: Zipf,
}

impl CorpusGen {
    pub fn new(cfg: CorpusConfig) -> Self {
        let world = World::build(&cfg);
        let mut rng = Rng::new(cfg.seed ^ 0x0D0D); // word-stream seed
        let words = (0..cfg.n_words).map(|i| pseudo_word(&mut rng, i + 1000)).collect();
        let zipf = Zipf::new(cfg.n_words, cfg.zipf_s);
        Self { cfg, world, words, zipf }
    }

    /// Generate `n_docs` documents for a split, concatenated with newlines.
    pub fn generate(&self, split: Split, n_docs: usize) -> String {
        let mut rng = Rng::new(self.cfg.seed ^ split.stream());
        let mut out = String::with_capacity(n_docs * 48);
        for _ in 0..n_docs {
            let roll = rng.f64();
            // Mixture weights: arithmetic gets the largest share — the
            // exact-match reasoning proxy is the hardest skill for a
            // ~1M-param char LM and the paper's most quantization-
            // sensitive benchmark (GSM8K), so the fp16 baseline must be
            // strong there.
            let doc = if roll < 0.22 {
                self.fact_doc(&mut rng)
            } else if roll < 0.62 {
                self.arith_doc(&mut rng)
            } else if roll < 0.78 {
                self.filler_doc(&mut rng)
            } else if roll < 0.90 {
                let n_filler = 2 + rng.below_usize(4);
                self.passkey_doc(&mut rng, n_filler)
            } else {
                self.classify_doc(&mut rng)
            };
            out.push_str(&doc);
            out.push('\n');
        }
        out
    }

    /// "the color of kapu is red."
    pub fn fact_doc(&self, rng: &mut Rng) -> String {
        let e = rng.below_usize(self.world.entities.len());
        let ent = &self.world.entities[e];
        match rng.below_usize(3) {
            0 => format!("the color of {} is {}.", ent, COLORS[self.world.color[e]]),
            1 => format!("the size of {} is {}.", ent, SIZES[self.world.size[e]]),
            _ => format!("the home of {} is the {}.", ent, HOMES[self.world.home[e]]),
        }
    }

    /// "q: 23+45=? a: 68."
    pub fn arith_doc(&self, rng: &mut Rng) -> String {
        let (expr, ans) = arith_problem(rng);
        format!("q: {expr}=? a: {ans}.")
    }

    /// Zipf filler: "the ADJ WORD VERB the WORD ."
    pub fn filler_doc(&self, rng: &mut Rng) -> String {
        const VERBS: &[&str] = &["sees", "finds", "makes", "takes", "keeps"];
        let n_clauses = 1 + rng.below_usize(3);
        let mut s = String::new();
        for i in 0..n_clauses {
            if i > 0 {
                s.push(' ');
            }
            let w1 = &self.words[self.zipf.sample(rng)];
            let w2 = &self.words[self.zipf.sample(rng)];
            let v = VERBS[rng.below_usize(VERBS.len())];
            let _ = write!(s, "the {w1} {v} the {w2}.");
        }
        s
    }

    /// Passkey doc: state, filler, recall. `n_filler` filler clauses set
    /// the retrieval distance.
    pub fn passkey_doc(&self, rng: &mut Rng, n_filler: usize) -> String {
        let key = 1000 + rng.below(9000);
        let mut s = format!("note: the passkey is {key}.");
        for _ in 0..n_filler {
            s.push(' ');
            s.push_str(&self.filler_doc(rng));
        }
        let _ = write!(s, " recall: the passkey is {key}.");
        s
    }

    /// Classification doc: label = keyword-determined.
    pub fn classify_doc(&self, rng: &mut Rng) -> String {
        let li = rng.below_usize(LABELS.len());
        // The label's keyword is planted among filler words.
        let keyword = ["sun", "moon", "star"][li];
        let w1 = &self.words[self.zipf.sample(rng)];
        let w2 = &self.words[self.zipf.sample(rng)];
        format!("text: the {w1} and the {keyword} and the {w2}. label: {}.", LABELS[li])
    }

    /// Tokenized documents for a split, each truncated/padded handling
    /// left to the caller.
    pub fn token_docs(&self, split: Split, n_docs: usize, tok: &Tokenizer) -> Vec<Vec<u32>> {
        self.generate(split, n_docs)
            .lines()
            .map(|l| {
                let mut ids = tok.encode(l);
                ids.push(0); // newline terminator = doc boundary
                ids
            })
            .collect()
    }
}

/// Sample an arithmetic problem. Mixture of single-digit add/sub/mul and
/// two-digit addition — hard enough that 2-bit damage shows, easy enough
/// that the fp32 tiny-LM nails it.
pub fn arith_problem(rng: &mut Rng) -> (String, i64) {
    match rng.below_usize(4) {
        0 => {
            let a = rng.below(10) as i64;
            let b = rng.below(10) as i64;
            (format!("{a}+{b}"), a + b)
        }
        1 => {
            let a = rng.below(10) as i64;
            let b = rng.below(a as u64 + 1) as i64;
            (format!("{a}-{b}"), a - b)
        }
        2 => {
            let a = rng.below(10) as i64;
            let b = rng.below(10) as i64;
            (format!("{a}*{b}"), a * b)
        }
        _ => {
            let a = 10 + rng.below(90) as i64;
            let b = 10 + rng.below(90) as i64;
            (format!("{a}+{b}"), a + b)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let g1 = CorpusGen::new(CorpusConfig::default());
        let g2 = CorpusGen::new(CorpusConfig::default());
        assert_eq!(g1.generate(Split::Train, 50), g2.generate(Split::Train, 50));
    }

    #[test]
    fn splits_differ_but_world_shared() {
        let g = CorpusGen::new(CorpusConfig::default());
        assert_ne!(g.generate(Split::Train, 50), g.generate(Split::Eval, 50));
        // Same entity list regardless of split.
        let g2 = CorpusGen::new(CorpusConfig::default());
        assert_eq!(g.world.entities, g2.world.entities);
    }

    #[test]
    fn all_chars_in_vocab() {
        let g = CorpusGen::new(CorpusConfig::default());
        let tok = Tokenizer::new();
        let text = g.generate(Split::Train, 300);
        // encode→decode must be lossless iff every char is in-vocab
        assert_eq!(tok.decode(&tok.encode(&text)), text);
    }

    #[test]
    fn arith_answers_correct() {
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let (expr, ans) = arith_problem(&mut rng);
            // parse and re-evaluate
            let op_pos = expr[1..].find(['+', '-', '*']).unwrap() + 1;
            let a: i64 = expr[..op_pos].parse().unwrap();
            let b: i64 = expr[op_pos + 1..].parse().unwrap();
            let want = match &expr[op_pos..op_pos + 1] {
                "+" => a + b,
                "-" => a - b,
                "*" => a * b,
                _ => unreachable!(),
            };
            assert_eq!(ans, want, "{expr}");
        }
    }

    #[test]
    fn passkey_doc_recalls_same_key() {
        let g = CorpusGen::new(CorpusConfig::default());
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let d = g.passkey_doc(&mut rng, 3);
            let first = d.find("passkey is ").unwrap() + 11;
            let key1 = &d[first..first + 4];
            let last = d.rfind("passkey is ").unwrap() + 11;
            let key2 = &d[last..last + 4];
            assert_eq!(key1, key2, "{d}");
        }
    }

    #[test]
    fn fact_docs_consistent_across_calls() {
        let g = CorpusGen::new(CorpusConfig::default());
        // Collect fact statements from two big samples; assert no entity
        // is claimed to have two different colors.
        let text = g.generate(Split::Train, 2000) + &g.generate(Split::Eval, 2000);
        let mut seen: std::collections::HashMap<String, String> = Default::default();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("the color of ") {
                let mut it = rest.splitn(2, " is ");
                let ent = it.next().unwrap().to_string();
                let col = it.next().unwrap().trim_end_matches('.').to_string();
                if let Some(prev) = seen.get(&ent) {
                    assert_eq!(prev, &col, "entity {ent} has two colors");
                } else {
                    seen.insert(ent, col);
                }
            }
        }
        assert!(!seen.is_empty());
    }

    #[test]
    fn token_docs_terminated() {
        let g = CorpusGen::new(CorpusConfig::default());
        let tok = Tokenizer::new();
        let docs = g.token_docs(Split::Calib, 20, &tok);
        assert_eq!(docs.len(), 20);
        for d in &docs {
            assert_eq!(*d.last().unwrap(), 0);
        }
    }
}
