//! Decoder-only transformer LM (the evaluation substrate).
//!
//! Architecture — mirrored **exactly** by `python/compile/model.py` (the
//! trainer) so `.tlm` checkpoints are interchangeable:
//!
//! * token embedding (no scale), learned absolute none — positions come
//!   from RoPE (half-rotation / "rotate_half" convention, base 10000);
//! * per block: RMSNorm(eps 1e-5) → attention (wq,wk,wv,wo; causal;
//!   grouped-query when `n_kv_heads < n_heads` — wk/wv project to
//!   `kv_dim = n_kv_heads × head_dim` and each group of
//!   `n_heads / n_kv_heads` query heads shares one K/V head) →
//!   residual → RMSNorm → SwiGLU MLP (w1=up, w3=gate, w2=down) → residual;
//! * final RMSNorm → lm_head (untied).
//!
//! The seven per-block linears (wq,wk,wv,wo,w1,w2,w3) are the
//! quantization targets; embeddings/lm_head stay fp16 as in the paper's
//! weight-only setting.
//!
//! Two forward paths:
//! * [`Model::forward_full`] — full-sequence logits (perplexity and
//!   likelihood-scored choice tasks), with optional per-linear activation
//!   capture for Hessian accumulation;
//! * [`DecodeState`] — incremental KV-cache decode used by the serving
//!   engine and exact-match generation tasks.

mod forward;
pub mod pipeline;
mod synth;

pub use forward::{
    argmax, attend_head, attend_head_packed, greedy_generate, sample, Capture, DecodeState, Rope,
};
pub use synth::{synthetic_checkpoint, synthetic_model};

use crate::io::tlm::{TlmFile, TlmHeader};
use crate::serving::kv::{KvArena, KvFormat, KvGeom};
use crate::tensor::Matrix;
use anyhow::{ensure, Result};
use std::sync::{Arc, OnceLock};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// Number of K/V heads (grouped-query attention). `n_kv_heads ==
    /// n_heads` is plain MHA; a proper divisor shrinks wk/wv and every KV
    /// cache by `n_heads / n_kv_heads`.
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    /// How this model's KV arena stores strips (f32 or packed
    /// bit-planes). Runtime serving policy, **not** part of the `.tlm`
    /// checkpoint format — loaders default to [`KvFormat::F32`] and
    /// callers opt in via [`ModelConfig::with_kv_format`] /
    /// [`Model::with_kv_format`] (e.g. `serve --kv-bits`).
    pub kv_format: KvFormat,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Width of the K/V projections and of one cached KV row:
    /// `n_kv_heads × head_dim`.
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// Query heads sharing each K/V head.
    pub fn kv_group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    /// Same config with `n_kv_heads` overridden (GQA variants of the
    /// stock tiny-LM sizes for tests and benches).
    pub fn with_kv_heads(mut self, n_kv_heads: usize) -> Self {
        self.n_kv_heads = n_kv_heads;
        self
    }

    /// Same config with the KV storage format overridden (quantized-KV
    /// variants for tests and benches).
    pub fn with_kv_format(mut self, kv_format: KvFormat) -> Self {
        self.kv_format = kv_format;
        self
    }

    pub fn from_header(h: &TlmHeader) -> Self {
        Self {
            vocab_size: h.vocab_size as usize,
            d_model: h.d_model as usize,
            n_layers: h.n_layers as usize,
            n_heads: h.n_heads as usize,
            n_kv_heads: h.n_kv_heads as usize,
            d_ff: h.d_ff as usize,
            max_seq: h.max_seq as usize,
            kv_format: KvFormat::F32,
        }
    }

    /// The two tiny-LM sizes used by the experiment tables ("small" ≈
    /// 0.8M params, "large" ≈ 3.4M params) — stand-ins for the paper's
    /// model-size axis (DESIGN.md §3).
    pub fn tiny_small(vocab_size: usize) -> Self {
        Self {
            vocab_size,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            n_kv_heads: 4,
            d_ff: 344,
            max_seq: 256,
            kv_format: KvFormat::F32,
        }
    }

    pub fn tiny_large(vocab_size: usize) -> Self {
        Self {
            vocab_size,
            d_model: 256,
            n_layers: 6,
            n_heads: 8,
            n_kv_heads: 8,
            d_ff: 688,
            max_seq: 256,
            kv_format: KvFormat::F32,
        }
    }
}

/// Names of the quantizable linears within a block, in pipeline order.
pub const BLOCK_LINEARS: [&str; 7] = ["wq", "wk", "wv", "wo", "w1", "w3", "w2"];

#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub norm1: Vec<f32>,
    /// query projection (d_model × d_model)
    pub wq: Matrix,
    /// key projection (kv_dim × d_model)
    pub wk: Matrix,
    /// value projection (kv_dim × d_model)
    pub wv: Matrix,
    /// output projection (d_model × d_model)
    pub wo: Matrix,
    pub norm2: Vec<f32>,
    /// up projection (d_ff × d_model)
    pub w1: Matrix,
    /// down projection (d_model × d_ff)
    pub w2: Matrix,
    /// gate projection (d_ff × d_model)
    pub w3: Matrix,
}

impl LayerWeights {
    pub fn linear(&self, name: &str) -> &Matrix {
        match name {
            "wq" => &self.wq,
            "wk" => &self.wk,
            "wv" => &self.wv,
            "wo" => &self.wo,
            "w1" => &self.w1,
            "w2" => &self.w2,
            "w3" => &self.w3,
            _ => panic!("unknown linear {name}"),
        }
    }

    pub fn linear_mut(&mut self, name: &str) -> &mut Matrix {
        match name {
            "wq" => &mut self.wq,
            "wk" => &mut self.wk,
            "wv" => &mut self.wv,
            "wo" => &mut self.wo,
            "w1" => &mut self.w1,
            "w2" => &mut self.w2,
            "w3" => &mut self.w3,
            _ => panic!("unknown linear {name}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Model {
    pub cfg: ModelConfig,
    /// vocab × d_model
    pub embed: Matrix,
    pub layers: Vec<LayerWeights>,
    pub norm_f: Vec<f32>,
    /// vocab × d_model
    pub lm_head: Matrix,
    /// Lazily-built decode RoPE table, shared by every [`DecodeState`]
    /// and LUT session of this model (built once per model, not per
    /// session / fork).
    rope: OnceLock<Arc<Rope>>,
    /// Lazily-built pooled KV arena ([`KvArena`]): one slab per model,
    /// every decode session (native and LUT) addresses its KV through a
    /// slot of this arena.
    arena: OnceLock<Arc<KvArena>>,
    /// Positions per KV arena page (`serve --kv-page`). Runtime serving
    /// policy like `cfg.kv_format` — not part of the `.tlm` format.
    /// Clamped to `1..=decode_capacity()` by [`KvGeom::of`]; the
    /// default [`Model::DEFAULT_KV_PAGE`] divides every `max_seq × 4`
    /// capacity, keeping slots byte-identical to the pre-paging layout.
    pub kv_page: usize,
}

pub const RMS_EPS: f32 = 1e-5;
pub const ROPE_BASE: f32 = 10_000.0;

impl Model {
    /// Load from a `.tlm` checkpoint written by the python trainer.
    pub fn from_tlm(f: &TlmFile) -> Result<Self> {
        let cfg = ModelConfig::from_header(&f.header);
        ensure!(cfg.d_model % cfg.n_heads == 0, "d_model must divide n_heads");
        ensure!(cfg.n_kv_heads > 0, "n_kv_heads must be positive");
        ensure!(
            cfg.n_heads % cfg.n_kv_heads == 0,
            "n_kv_heads ({}) must divide n_heads ({})",
            cfg.n_kv_heads,
            cfg.n_heads
        );
        let mat = |name: &str, rows: usize, cols: usize| -> Result<Matrix> {
            let m = f.get(name)?;
            ensure!(
                m.shape() == (rows, cols),
                "tensor {name}: expected {rows}x{cols}, got {:?}",
                m.shape()
            );
            Ok(m.clone())
        };
        let vecr = |name: &str, len: usize| -> Result<Vec<f32>> {
            let m = f.get(name)?;
            ensure!(m.rows() * m.cols() == len, "tensor {name}: expected len {len}");
            Ok(m.data().to_vec())
        };
        let (v, d, ff) = (cfg.vocab_size, cfg.d_model, cfg.d_ff);
        let kd = cfg.kv_dim();
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            layers.push(LayerWeights {
                norm1: vecr(&format!("l{l}.norm1"), d)?,
                wq: mat(&format!("l{l}.wq"), d, d)?,
                wk: mat(&format!("l{l}.wk"), kd, d)?,
                wv: mat(&format!("l{l}.wv"), kd, d)?,
                wo: mat(&format!("l{l}.wo"), d, d)?,
                norm2: vecr(&format!("l{l}.norm2"), d)?,
                w1: mat(&format!("l{l}.w1"), ff, d)?,
                w2: mat(&format!("l{l}.w2"), d, ff)?,
                w3: mat(&format!("l{l}.w3"), ff, d)?,
            });
        }
        Ok(Self {
            cfg,
            embed: mat("embed", v, d)?,
            layers,
            norm_f: vecr("norm_f", d)?,
            lm_head: mat("lm_head", v, d)?,
            rope: OnceLock::new(),
            arena: OnceLock::new(),
            kv_page: Self::DEFAULT_KV_PAGE,
        })
    }

    /// Serialize back to `.tlm` (used to persist quantized models).
    pub fn to_tlm(&self) -> TlmFile {
        let c = &self.cfg;
        let header = TlmHeader {
            vocab_size: c.vocab_size as u32,
            d_model: c.d_model as u32,
            n_layers: c.n_layers as u32,
            n_heads: c.n_heads as u32,
            n_kv_heads: c.n_kv_heads as u32,
            d_ff: c.d_ff as u32,
            max_seq: c.max_seq as u32,
        };
        let mut f = TlmFile::new(header);
        f.insert("embed", self.embed.clone());
        f.insert("norm_f", Matrix::from_vec(1, c.d_model, self.norm_f.clone()));
        f.insert("lm_head", self.lm_head.clone());
        for (l, lw) in self.layers.iter().enumerate() {
            f.insert(&format!("l{l}.norm1"), Matrix::from_vec(1, c.d_model, lw.norm1.clone()));
            f.insert(&format!("l{l}.norm2"), Matrix::from_vec(1, c.d_model, lw.norm2.clone()));
            f.insert(&format!("l{l}.wq"), lw.wq.clone());
            f.insert(&format!("l{l}.wk"), lw.wk.clone());
            f.insert(&format!("l{l}.wv"), lw.wv.clone());
            f.insert(&format!("l{l}.wo"), lw.wo.clone());
            f.insert(&format!("l{l}.w1"), lw.w1.clone());
            f.insert(&format!("l{l}.w2"), lw.w2.clone());
            f.insert(&format!("l{l}.w3"), lw.w3.clone());
        }
        f
    }

    pub fn n_params(&self) -> usize {
        let c = &self.cfg;
        // wq + wo are d×d; wk + wv shrink to kv_dim×d under GQA.
        let attn = 2 * c.d_model * c.d_model + 2 * c.kv_dim() * c.d_model;
        let per_layer = 2 * c.d_model + attn + 3 * c.d_model * c.d_ff;
        c.vocab_size * c.d_model * 2 + c.d_model + c.n_layers * per_layer
    }

    /// Bytes of the fp16 model (the "16-bit" SIZE column).
    pub fn fp16_bytes(&self) -> usize {
        self.n_params() * 2
    }

    /// KV-cache capacity every decode session allocates: 4× the training
    /// context, because long-context evals (Fig. 3) run beyond `max_seq`
    /// on purpose. Single source of truth shared by [`DecodeState`] and
    /// the serving engines' LUT sessions, so the engines cannot diverge
    /// on truncation points or KV memory.
    pub fn decode_capacity(&self) -> usize {
        self.cfg.max_seq * 4
    }

    /// **Real packed** KV bytes one decode session occupies — one
    /// [`KvArena`] slot under the model's [`KvFormat`]. For
    /// [`KvFormat::F32`] this is the historical
    /// `n_layers × cap × 2 × kv_dim × 4` bytes (K and V, f32); for
    /// [`KvFormat::BitPlane`] it is the plane words plus f16
    /// coefficients actually resident (see
    /// [`crate::serving::kv::KvGeom::slot_bytes`]). Under GQA either
    /// format is exactly `n_heads / n_kv_heads` smaller than its MHA
    /// counterpart.
    pub fn kv_bytes_per_session(&self) -> usize {
        KvGeom::of(self).slot_bytes()
    }

    /// Per-token KV traffic of one session: bytes of freshly stored
    /// K/V per decoded token (`slot_bytes / cap`) — the bandwidth
    /// number `BENCH_decode.json` reports per row.
    pub fn kv_bytes_per_token(&self) -> usize {
        self.kv_bytes_per_session() / self.decode_capacity()
    }

    /// A copy of this model serving under a different KV format: same
    /// weights, same rope table, but a **fresh, uninitialized** arena
    /// slot (the existing arena's geometry would not match). Use this —
    /// never mutate `cfg.kv_format` on a clone — once any decode or
    /// engine has touched the original.
    pub fn with_kv_format(&self, kv_format: KvFormat) -> Model {
        let mut m = self.clone();
        m.cfg.kv_format = kv_format;
        m.arena = OnceLock::new();
        m
    }

    /// A copy of this model with a different KV page size (positions
    /// per arena page, `serve --kv-page`). Same fresh-arena contract as
    /// [`Model::with_kv_format`].
    pub fn with_kv_page(&self, kv_page: usize) -> Model {
        assert!(kv_page > 0, "KV page must hold at least one position");
        let mut m = self.clone();
        m.kv_page = kv_page;
        m.arena = OnceLock::new();
        m
    }

    /// The decode RoPE table for this model, built once on first use and
    /// shared (`Arc`) by every decode session and fork.
    pub fn rope(&self) -> Arc<Rope> {
        self.rope
            .get_or_init(|| Arc::new(Rope::new(self.decode_capacity(), self.cfg.head_dim())))
            .clone()
    }

    /// Default first-segment size of the per-model KV arena (the arena
    /// doubles from there as sessions oversubscribe it).
    pub const DEFAULT_KV_SLOTS: usize = 4;

    /// Default positions per KV page. Divides every `max_seq × 4`
    /// decode capacity (max_seq is a power-of-two multiple of 8
    /// everywhere), so the default paged slot is byte-identical to the
    /// historical monolithic slot.
    pub const DEFAULT_KV_PAGE: usize = 32;

    /// The pooled KV arena for this model: one slab whose slots back
    /// every decode session (built once per model, shared by clones;
    /// unbounded doubling growth unless [`Model::init_kv_arena`] ran
    /// first). See [`crate::serving::kv::KvArena`] for layout.
    pub fn kv_arena(&self) -> Arc<KvArena> {
        self.arena
            .get_or_init(|| {
                Arc::new(KvArena::with_limit(
                    KvGeom::of(self),
                    Self::DEFAULT_KV_SLOTS,
                    usize::MAX,
                ))
            })
            .clone()
    }

    /// Initialize the model's KV arena with an explicit first-segment
    /// size and slot cap — must run **before** anything touches
    /// [`Model::kv_arena`] (a decode, an engine, a metrics hook).
    /// Panics if an arena with a *different* cap already exists, so a
    /// requested memory bound can never be silently dropped. Tests use
    /// the cap to exercise exhaustion; servers use it to bound KV
    /// memory.
    pub fn init_kv_arena(&self, initial_slots: usize, max_slots: usize) -> Arc<KvArena> {
        let mut created = false;
        let arena = self
            .arena
            .get_or_init(|| {
                created = true;
                Arc::new(KvArena::with_limit(KvGeom::of(self), initial_slots, max_slots))
            })
            .clone();
        assert!(
            created || arena.max_slots() == max_slots,
            "KV arena already initialized with a different slot cap ({} vs requested {}) — \
             call init_kv_arena before any decode/engine touches the model",
            arena.max_slots(),
            max_slots
        );
        arena
    }
}

/// RMSNorm: x * g / rms(x). Dispatches through `tensor::simd` (scalar
/// reference: `tensor::ops::rmsnorm`).
#[inline]
pub fn rmsnorm(x: &[f32], gain: &[f32], out: &mut [f32]) {
    crate::tensor::rmsnorm(x, gain, RMS_EPS, out);
}

/// In-place softmax over a slice. Dispatches through `tensor::simd`
/// (value-exact across tiers; scalar reference: `tensor::ops::softmax`).
#[inline]
pub fn softmax(xs: &mut [f32]) {
    crate::tensor::softmax(xs);
}

/// SiLU activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0f32, -4.0]; // rms = sqrt(12.5)
        let g = vec![1.0f32, 1.0];
        let mut out = vec![0.0; 2];
        rmsnorm(&x, &g, &mut out);
        let rms = (12.5f64).sqrt() as f32;
        assert!((out[0] - 3.0 / rms).abs() < 1e-5);
        assert!((out[1] + 4.0 / rms).abs() < 1e-5);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0f32, 2.0, 3.0, -1000.0];
        softmax(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(xs[3] < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn softmax_handles_large_logits() {
        let mut xs = vec![1e10f32, 1e10];
        softmax(&mut xs);
        assert!((xs[0] - 0.5).abs() < 1e-5);
    }

    #[test]
    fn silu_shape() {
        assert!(silu(0.0).abs() < 1e-9);
        assert!((silu(10.0) - 10.0).abs() < 1e-3);
        assert!(silu(-10.0).abs() < 1e-3);
    }

    #[test]
    fn tlm_roundtrip_preserves_model() {
        let ckpt = synthetic_checkpoint(&ModelConfig::tiny_small(68), 7);
        let m = Model::from_tlm(&ckpt).unwrap();
        let back = m.to_tlm();
        let m2 = Model::from_tlm(&back).unwrap();
        assert_eq!(m.embed, m2.embed);
        assert_eq!(m.layers[0].wq, m2.layers[0].wq);
        assert_eq!(m.norm_f, m2.norm_f);
    }

    #[test]
    fn n_params_matches_tensors() {
        let ckpt = synthetic_checkpoint(&ModelConfig::tiny_small(68), 8);
        let m = Model::from_tlm(&ckpt).unwrap();
        assert_eq!(m.n_params(), ckpt.n_params());
    }

    #[test]
    fn gqa_roundtrip_and_param_count() {
        let cfg = ModelConfig::tiny_small(68).with_kv_heads(2);
        let ckpt = synthetic_checkpoint(&cfg, 11);
        let m = Model::from_tlm(&ckpt).unwrap();
        assert_eq!(m.cfg.kv_dim(), 64); // 2 kv heads × head_dim 32
        assert_eq!(m.layers[0].wk.shape(), (64, 128));
        assert_eq!(m.layers[0].wv.shape(), (64, 128));
        assert_eq!(m.layers[0].wq.shape(), (128, 128));
        assert_eq!(m.n_params(), ckpt.n_params());
        let back = m.to_tlm();
        let m2 = Model::from_tlm(&back).unwrap();
        assert_eq!(m2.cfg, m.cfg);
        assert_eq!(m.layers[1].wk, m2.layers[1].wk);
    }

    #[test]
    fn kv_heads_must_divide_heads() {
        let cfg = ModelConfig::tiny_small(68).with_kv_heads(3); // 3 ∤ 4
        let ckpt = synthetic_checkpoint(&cfg, 1);
        assert!(Model::from_tlm(&ckpt).is_err());
    }

    #[test]
    fn kv_bytes_shrink_by_group_factor() {
        let mha = synthetic_model(&ModelConfig::tiny_small(68), 3);
        let gqa = synthetic_model(&ModelConfig::tiny_small(68).with_kv_heads(1), 3);
        assert_eq!(mha.kv_bytes_per_session(), 4 * gqa.kv_bytes_per_session());
    }

    #[test]
    fn kv_bytes_are_format_aware() {
        let f32_model = synthetic_model(&ModelConfig::tiny_small(68), 3);
        let q2 = f32_model.with_kv_format(KvFormat::bit_plane(2));
        assert!(
            f32_model.kv_bytes_per_session() >= 8 * q2.kv_bytes_per_session(),
            "W2 KV must be ≥8× smaller: {} vs {}",
            f32_model.kv_bytes_per_session(),
            q2.kv_bytes_per_session()
        );
        assert_eq!(
            q2.kv_bytes_per_token(),
            q2.kv_bytes_per_session() / q2.decode_capacity()
        );
        // The format copy starts with a fresh arena of matching geometry.
        let _ = f32_model.kv_arena();
        let q2b = f32_model.with_kv_format(KvFormat::bit_plane(2));
        assert_eq!(q2b.kv_arena().geom(), KvGeom::of(&q2b));
        assert!(!Arc::ptr_eq(&f32_model.kv_arena(), &q2b.kv_arena()));
    }

    #[test]
    fn rope_is_shared_across_sessions() {
        let m = synthetic_model(&ModelConfig::tiny_small(68), 3);
        let a = m.rope();
        let b = m.rope();
        assert!(Arc::ptr_eq(&a, &b));
    }
}
