//! Sequential model quantization pipeline (GPTQModel-style).
//!
//! Blocks are processed front-to-back; block *l*'s linears are calibrated
//! on activations produced by the **already-quantized** blocks 0..l — the
//! error-compounding-aware ordering every serious PTQ implementation uses.
//! Within a block the four distinct activation streams (attn_in, attn_out,
//! mlp_in, mlp_mid) each get one Hessian shared by the linears they feed
//! (wq/wk/wv ← attn_in, wo ← attn_out, w1/w3 ← mlp_in, w2 ← mlp_mid).

use super::forward::{Capture, Rope};
use super::{Model, BLOCK_LINEARS};
use crate::quant::{quantize_linear_h, HessianState, PackedWeights, QuantMethod, QuantizedLinear};
use crate::tensor::Matrix;
use anyhow::Result;
use std::collections::HashMap;
use std::time::Instant;

/// Per-linear record kept for reporting and LUT serving.
#[derive(Clone, Debug)]
pub struct LinearReport {
    pub layer: usize,
    pub name: &'static str,
    pub output_err: f64,
    pub weight_err: f64,
    pub bits_per_weight: f64,
    pub packed_bits: usize,
}

/// A fully quantized model plus its accounting.
pub struct QuantizedModel {
    /// Weights replaced by their dequantized values.
    pub model: Model,
    pub reports: Vec<LinearReport>,
    /// Packed records, keyed "l{layer}.{name}" (feeds the LUT engine).
    pub packed: HashMap<String, PackedWeights>,
    pub quant_secs: f64,
    pub method: String,
}

impl QuantizedModel {
    /// Exact serialized model size in bits: packed linears + fp16
    /// everything else (embed, lm_head, norms).
    pub fn total_bits(&self) -> usize {
        let c = &self.model.cfg;
        let fp16_rest =
            (2 * c.vocab_size * c.d_model + c.d_model + 2 * c.n_layers * c.d_model) * 16;
        let packed: usize = self.packed.values().map(|p| p.total_bits()).sum();
        fp16_rest + packed
    }

    pub fn size_bytes(&self) -> usize {
        self.total_bits().div_ceil(8)
    }

    /// Weighted-average bits per weight over the quantized linears.
    pub fn bits_per_weight(&self) -> f64 {
        let mut bits = 0usize;
        let mut n = 0usize;
        for (key, p) in &self.packed {
            bits += p.total_bits();
            let m = lookup_linear(&self.model, key);
            n += m.rows() * m.cols();
        }
        bits as f64 / n as f64
    }
}

fn lookup_linear<'m>(model: &'m Model, key: &str) -> &'m Matrix {
    // key = "l{layer}.{name}"
    let rest = key.strip_prefix('l').expect("key format");
    let (layer, name) = rest.split_once('.').expect("key format");
    model.layers[layer.parse::<usize>().unwrap()].linear(name)
}

/// Quantize every block linear of `model` with `method`, calibrating on
/// the token sequences `calib`.
pub fn quantize_model(
    model: &Model,
    calib: &[Vec<u32>],
    method: &QuantMethod,
) -> Result<QuantizedModel> {
    let t0 = Instant::now();
    let mut qm = model.clone();
    let max_len = calib.iter().map(|c| c.len()).max().unwrap_or(1);
    let rope = Rope::new(max_len, model.cfg.head_dim());

    // Current hidden states per calibration sequence (updated block by
    // block with the quantized weights).
    let mut hiddens: Vec<Matrix> = calib.iter().map(|seq| qm.embed_tokens(seq)).collect();

    let mut reports = Vec::new();
    let mut packed = HashMap::new();

    for l in 0..model.cfg.n_layers {
        // 1. capture activations with blocks 0..l already quantized
        let mut captures: Vec<Capture> = Vec::with_capacity(hiddens.len());
        for h in &hiddens {
            let mut cap = Capture::default();
            let _ = qm.block_forward(l, h, &rope, Some(&mut cap));
            captures.push(cap);
        }

        // 2. per activation stream: stack + Hessian
        let mut stream_x: HashMap<&'static str, Matrix> = HashMap::new();
        let mut stream_h: HashMap<&'static str, HessianState> = HashMap::new();
        for key in ["attn_in", "attn_out", "mlp_in", "mlp_mid"] {
            let total_rows: usize = captures.iter().map(|c| c.inputs[key].rows()).sum();
            let dim = captures[0].inputs[key].cols();
            let mut x = Matrix::zeros(total_rows, dim);
            let mut r0 = 0;
            for c in &captures {
                let m = &c.inputs[key];
                for r in 0..m.rows() {
                    x.row_mut(r0 + r).copy_from_slice(m.row(r));
                }
                r0 += m.rows();
            }
            stream_h.insert(key, HessianState::from_activations(&x));
            stream_x.insert(key, x);
        }

        // 3. quantize the seven linears
        for name in BLOCK_LINEARS {
            let key = Capture::key_for(name);
            let w = qm.layers[l].linear(name).clone();
            let q: QuantizedLinear =
                quantize_linear_h(&w, &stream_h[key], &stream_x[key], method.clone())?;
            reports.push(LinearReport {
                layer: l,
                name,
                output_err: q.stats.output_err,
                weight_err: q.stats.weight_err,
                bits_per_weight: q.bits_per_weight(),
                packed_bits: q.packed.total_bits(),
            });
            packed.insert(format!("l{l}.{name}"), q.packed);
            *qm.layers[l].linear_mut(name) = q.dequant;
        }

        // 4. recompute hidden states through the quantized block
        for h in &mut hiddens {
            *h = qm.block_forward(l, h, &rope, None);
        }
    }

    Ok(QuantizedModel {
        model: qm,
        reports,
        packed,
        quant_secs: t0.elapsed().as_secs_f64(),
        method: method.name(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic_model, ModelConfig};
    use crate::quant::{BpdqConfig, UniformConfig};
    use crate::serving::KvFormat;

    fn tiny_model() -> Model {
        synthetic_model(
            &ModelConfig {
                vocab_size: 20,
                d_model: 32,
                n_layers: 2,
                n_heads: 2,
                n_kv_heads: 2,
                d_ff: 48,
                max_seq: 32,
                kv_format: KvFormat::F32,
            },
            7,
        )
    }

    fn calib() -> Vec<Vec<u32>> {
        (0..6).map(|i| (0..24).map(|t| ((t * 7 + i * 3) % 20) as u32).collect()).collect()
    }

    #[test]
    fn pipeline_quantizes_all_linears() {
        let m = tiny_model();
        let method = QuantMethod::Gptq(UniformConfig { bits: 4, group_size: 16, act_order: true });
        let qm = quantize_model(&m, &calib(), &method).unwrap();
        assert_eq!(qm.reports.len(), 2 * 7);
        assert_eq!(qm.packed.len(), 2 * 7);
        // weights actually changed
        assert!(qm.model.layers[0].wq.fro_dist(&m.layers[0].wq) > 0.0);
        // but embeddings untouched
        assert_eq!(qm.model.embed, m.embed);
    }

    #[test]
    fn pipeline_quantizes_gqa_model() {
        // Non-square wk/wv (kv_dim × d_model) must flow through the
        // calibrated pipeline unchanged: same Hessian stream (attn_in is
        // still d_model-wide), narrower output rows.
        let cfg = ModelConfig {
            vocab_size: 20,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 48,
            max_seq: 32,
            kv_format: KvFormat::F32,
        };
        let m = synthetic_model(&cfg, 7);
        let method = QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 16, iters: 2, ..Default::default() });
        let qm = quantize_model(&m, &calib(), &method).unwrap();
        assert_eq!(qm.reports.len(), 2 * 7);
        assert_eq!(qm.model.layers[0].wk.shape(), (16, 32));
        assert_eq!(qm.model.layers[0].wv.shape(), (16, 32));
        let toks: Vec<u32> = (0..16).map(|t| (t % 20) as u32).collect();
        let out = qm.model.forward_full(&toks);
        assert!(out.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn four_bit_output_close_to_fp() {
        let m = tiny_model();
        let method = QuantMethod::Gptq(UniformConfig { bits: 8, group_size: 16, act_order: false });
        let qm = quantize_model(&m, &calib(), &method).unwrap();
        let toks: Vec<u32> = (0..16).map(|t| (t % 20) as u32).collect();
        let a = m.forward_full(&toks);
        let b = qm.model.forward_full(&toks);
        let rel = a.fro_dist(&b) / a.fro_norm();
        assert!(rel < 0.05, "rel err {rel}");
    }

    #[test]
    fn bpdq_pipeline_runs_and_accounts_bits() {
        let m = tiny_model();
        let method = QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 16, iters: 3, ..Default::default() });
        let qm = quantize_model(&m, &calib(), &method).unwrap();
        let bpw = qm.bits_per_weight();
        // k + 16(k+1)/16 = 2 + 3 = 5 bits per weight at g=16
        assert!((bpw - 5.0).abs() < 1e-6, "bpw={bpw}");
        assert!(qm.total_bits() > 0);
        assert!(qm.size_bytes() < m.fp16_bytes());
    }

    #[test]
    fn quantized_blocks_feed_next_layer() {
        // With a destructive method (2-bit RTN), layer-1 Hessians must be
        // computed from the damaged stream, not the fp stream. We verify
        // indirectly: the pipeline's layer-1 output error under RTN-2
        // differs from what quantizing layer 1 alone (fp activations)
        // would give.
        let m = tiny_model();
        let method = QuantMethod::Rtn(UniformConfig { bits: 2, group_size: 16, act_order: false });
        let qm = quantize_model(&m, &calib(), &method).unwrap();
        // independent quantization of layer 1 on fp activations
        let rope = Rope::new(24, m.cfg.head_dim());
        let mut h0: Vec<Matrix> = calib().iter().map(|s| m.embed_tokens(s)).collect();
        for h in &mut h0 {
            *h = m.block_forward(0, h, &rope, None);
        }
        let mut cap = Capture::default();
        let _ = m.block_forward(1, &h0[0], &rope, Some(&mut cap));
        let x_fp = &cap.inputs["attn_in"];
        let x_q_differs = {
            let mut cap2 = Capture::default();
            let mut hq = qm.model.embed_tokens(&calib()[0]);
            hq = qm.model.block_forward(0, &hq, &rope, None);
            let _ = qm.model.block_forward(1, &hq, &rope, Some(&mut cap2));
            cap2.inputs["attn_in"].fro_dist(x_fp) > 1e-6
        };
        assert!(x_q_differs, "2-bit RTN should visibly damage the stream");
    }
}
