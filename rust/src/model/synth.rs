//! Synthetic checkpoints.
//!
//! Two uses:
//! * unit/integration tests that need a structurally-valid model without
//!   the python training artifact;
//! * the "synthetic-LLM-statistics" weight generator for quantizer-only
//!   studies (Tables 4–7 model-size sweeps): heavy-tailed (Student-t)
//!   weights with a small set of high-magnitude **outlier channels**,
//!   matching published LLM weight statistics (see BiLLM/AWQ analyses).

use super::{Model, ModelConfig};
use crate::io::tlm::{TlmFile, TlmHeader};
use crate::rng::Rng;
use crate::tensor::Matrix;

/// Build a random-but-realistic checkpoint for `cfg`.
pub fn synthetic_checkpoint(cfg: &ModelConfig, seed: u64) -> TlmFile {
    let mut rng = Rng::new(seed ^ 0x517E);
    let header = TlmHeader {
        vocab_size: cfg.vocab_size as u32,
        d_model: cfg.d_model as u32,
        n_layers: cfg.n_layers as u32,
        n_heads: cfg.n_heads as u32,
        n_kv_heads: cfg.n_kv_heads as u32,
        d_ff: cfg.d_ff as u32,
        max_seq: cfg.max_seq as u32,
    };
    let mut f = TlmFile::new(header);
    let (v, d, ff) = (cfg.vocab_size, cfg.d_model, cfg.d_ff);
    let kd = cfg.kv_dim();

    f.insert("embed", heavy_tailed(&mut rng, v, d, 0.02, 0));
    f.insert("norm_f", ones_vec(d));
    f.insert("lm_head", heavy_tailed(&mut rng, v, d, 0.02, 0));
    for l in 0..cfg.n_layers {
        // A few outlier input channels per layer (attention-sink-like).
        let n_outlier = (d / 32).max(1);
        f.insert(&format!("l{l}.norm1"), ones_vec(d));
        f.insert(&format!("l{l}.norm2"), ones_vec(d));
        let s = (1.0 / d as f64).sqrt();
        f.insert(&format!("l{l}.wq"), heavy_tailed(&mut rng, d, d, s, n_outlier));
        f.insert(&format!("l{l}.wk"), heavy_tailed(&mut rng, kd, d, s, n_outlier));
        f.insert(&format!("l{l}.wv"), heavy_tailed(&mut rng, kd, d, s, 0));
        f.insert(&format!("l{l}.wo"), heavy_tailed(&mut rng, d, d, s, 0));
        f.insert(&format!("l{l}.w1"), heavy_tailed(&mut rng, ff, d, s, n_outlier));
        f.insert(&format!("l{l}.w3"), heavy_tailed(&mut rng, ff, d, s, n_outlier));
        let s2 = (1.0 / ff as f64).sqrt();
        f.insert(&format!("l{l}.w2"), heavy_tailed(&mut rng, d, ff, s2, 0));
    }
    f
}

/// Convenience: a loaded synthetic model.
pub fn synthetic_model(cfg: &ModelConfig, seed: u64) -> Model {
    Model::from_tlm(&synthetic_checkpoint(cfg, seed)).expect("synthetic checkpoint is valid")
}

/// Student-t(5) weights scaled by `std`, with `n_outlier_cols` columns
/// magnified ×8 (the salient-channel structure AWQ/BPDQ care about).
fn heavy_tailed(rng: &mut Rng, rows: usize, cols: usize, std: f64, n_outlier_cols: usize) -> Matrix {
    let mut m = Matrix::from_vec(
        rows,
        cols,
        (0..rows * cols).map(|_| (std * rng.student_t(5.0) * 0.76) as f32).collect(),
        // 0.76 ≈ 1/std(t₅) keeps the realized std equal to `std`
    );
    for _ in 0..n_outlier_cols {
        let c = rng.below_usize(cols);
        for r in 0..rows {
            let v = m.get(r, c) * 8.0;
            m.set(r, c, v);
        }
    }
    m
}

fn ones_vec(d: usize) -> Matrix {
    Matrix::full(1, d, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_as_model() {
        let cfg = ModelConfig::tiny_small(68);
        let m = synthetic_model(&cfg, 1);
        assert_eq!(m.layers.len(), cfg.n_layers);
        assert_eq!(m.embed.shape(), (68, cfg.d_model));
    }

    #[test]
    fn deterministic() {
        let cfg = ModelConfig::tiny_small(68);
        let a = synthetic_checkpoint(&cfg, 9);
        let b = synthetic_checkpoint(&cfg, 9);
        assert_eq!(a.get("l0.wq").unwrap(), b.get("l0.wq").unwrap());
        let c = synthetic_checkpoint(&cfg, 10);
        assert_ne!(a.get("l0.wq").unwrap(), c.get("l0.wq").unwrap());
    }

    #[test]
    fn gqa_checkpoint_has_narrow_kv() {
        let cfg = ModelConfig::tiny_small(68).with_kv_heads(2);
        let f = synthetic_checkpoint(&cfg, 4);
        assert_eq!(f.get("l0.wk").unwrap().shape(), (64, 128));
        assert_eq!(f.get("l0.wv").unwrap().shape(), (64, 128));
        assert_eq!(f.get("l0.wq").unwrap().shape(), (128, 128));
        let m = synthetic_model(&cfg, 4);
        assert_eq!(m.cfg.n_kv_heads, 2);
    }

    #[test]
    fn weights_heavy_tailed_with_outliers() {
        let cfg = ModelConfig::tiny_small(68);
        let m = synthetic_model(&cfg, 2);
        let w = &m.layers[0].wq;
        // column max-to-median ratio should show outlier columns
        let col_norms: Vec<f64> = (0..w.cols())
            .map(|c| (0..w.rows()).map(|r| (w.get(r, c) as f64).powi(2)).sum::<f64>().sqrt())
            .collect();
        let mut sorted = col_norms.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        let max = sorted[sorted.len() - 1];
        assert!(max / median > 3.0, "max/median = {}", max / median);
    }
}
