//! Transformer forward paths: full-sequence (with activation capture) and
//! incremental KV-cache decode.

use super::{rmsnorm, silu, softmax, Model, ROPE_BASE};
use crate::rng::Rng;
use crate::serving::kv::{KvArena, KvFormat, KvHandle};
use crate::serving::prefix::PrefixCache;
use crate::tensor::{
    axpy, dot, matmul_transb, matvec, strip_axpys_packed, strip_dots_packed, Matrix, PackedStrip,
    SimdScratch,
};
use std::collections::HashMap;
use std::sync::Arc;

/// Captured per-linear input activations for one block (rows = positions).
/// Keyed by the linear name ("wq", "wo", "w1", …). Note wq/wk/wv share
/// their input and w1/w3 share theirs; the capture stores one matrix per
/// distinct input and the pipeline maps linears onto them.
#[derive(Debug, Default)]
pub struct Capture {
    pub inputs: HashMap<&'static str, Matrix>,
}

impl Capture {
    /// The capture key whose activations feed `linear`.
    pub fn key_for(linear: &str) -> &'static str {
        match linear {
            "wq" | "wk" | "wv" => "attn_in",
            "wo" => "attn_out",
            "w1" | "w3" => "mlp_in",
            "w2" => "mlp_mid",
            _ => panic!("unknown linear {linear}"),
        }
    }

    pub fn input_for(&self, linear: &str) -> &Matrix {
        &self.inputs[Self::key_for(linear)]
    }
}

/// Precomputed RoPE tables for a range of positions.
#[derive(Clone, Debug)]
pub struct Rope {
    cos: Matrix, // seq × hd/2
    sin: Matrix,
}

impl Rope {
    pub fn new(max_pos: usize, head_dim: usize) -> Self {
        let half = head_dim / 2;
        let mut cos = Matrix::zeros(max_pos, half);
        let mut sin = Matrix::zeros(max_pos, half);
        for p in 0..max_pos {
            for i in 0..half {
                let theta = p as f64 / (ROPE_BASE as f64).powf(2.0 * i as f64 / head_dim as f64);
                cos.set(p, i, theta.cos() as f32);
                sin.set(p, i, theta.sin() as f32);
            }
        }
        Self { cos, sin }
    }

    /// Apply rotate-half RoPE in place to one head vector at position p.
    #[inline]
    pub fn apply(&self, v: &mut [f32], p: usize) {
        let half = v.len() / 2;
        let (c, s) = (self.cos.row(p), self.sin.row(p));
        for i in 0..half {
            let a = v[i];
            let b = v[i + half];
            v[i] = a * c[i] - b * s[i];
            v[i + half] = b * c[i] + a * s[i];
        }
    }
}

impl Model {
    /// Token embedding lookup → (seq × d_model).
    pub fn embed_tokens(&self, tokens: &[u32]) -> Matrix {
        let d = self.cfg.d_model;
        let mut h = Matrix::zeros(tokens.len(), d);
        for (t, &id) in tokens.iter().enumerate() {
            let id = (id as usize).min(self.cfg.vocab_size - 1);
            h.row_mut(t).copy_from_slice(self.embed.row(id));
        }
        h
    }

    /// Run one transformer block over the whole sequence. `capture`
    /// collects the linear inputs for Hessian accumulation.
    pub fn block_forward(
        &self,
        layer: usize,
        hidden: &Matrix,
        rope: &Rope,
        mut capture: Option<&mut Capture>,
    ) -> Matrix {
        let lw = &self.layers[layer];
        let seq = hidden.rows();
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let nkv = self.cfg.n_kv_heads;
        let hd = self.cfg.head_dim();
        let group = self.cfg.kv_group();
        let scale = 1.0 / (hd as f32).sqrt();

        // ---- attention (grouped-query: `group` q heads per kv head) ----
        let mut normed = Matrix::zeros(seq, d);
        for t in 0..seq {
            rmsnorm(hidden.row(t), &lw.norm1, normed.row_mut(t));
        }
        if let Some(c) = capture.as_deref_mut() {
            c.inputs.insert("attn_in", normed.clone());
        }
        let mut q = matmul_transb(&normed, &lw.wq); // seq × d_model
        let mut k = matmul_transb(&normed, &lw.wk); // seq × kv_dim
        let v = matmul_transb(&normed, &lw.wv); // seq × kv_dim
        for t in 0..seq {
            for h in 0..nh {
                rope.apply(&mut q.row_mut(t)[h * hd..(h + 1) * hd], t);
            }
            for h in 0..nkv {
                rope.apply(&mut k.row_mut(t)[h * hd..(h + 1) * hd], t);
            }
        }
        // causal attention, head-by-head
        let mut attn_out = Matrix::zeros(seq, d);
        let mut scores = vec![0.0f32; seq];
        for h in 0..nh {
            let o0 = h * hd;
            let k0 = (h / group) * hd;
            for t in 0..seq {
                let qrow = &q.row(t)[o0..o0 + hd];
                for (u, sc) in scores[..=t].iter_mut().enumerate() {
                    let krow = &k.row(u)[k0..k0 + hd];
                    *sc = dot(qrow, krow) * scale;
                }
                softmax(&mut scores[..=t]);
                let orow = &mut attn_out.row_mut(t)[o0..o0 + hd];
                for u in 0..=t {
                    let w = scores[u];
                    if w < 1e-9 {
                        continue;
                    }
                    axpy(w, &v.row(u)[k0..k0 + hd], orow);
                }
            }
        }
        if let Some(c) = capture.as_deref_mut() {
            c.inputs.insert("attn_out", attn_out.clone());
        }
        let proj = matmul_transb(&attn_out, &lw.wo);
        let mut hidden2 = hidden.clone();
        hidden2.axpy(1.0, &proj);

        // ---- MLP (SwiGLU) ----
        let mut normed2 = Matrix::zeros(seq, d);
        for t in 0..seq {
            rmsnorm(hidden2.row(t), &lw.norm2, normed2.row_mut(t));
        }
        if let Some(c) = capture.as_deref_mut() {
            c.inputs.insert("mlp_in", normed2.clone());
        }
        let up = matmul_transb(&normed2, &lw.w1);
        let gate = matmul_transb(&normed2, &lw.w3);
        let mut mid = up;
        for (m, g) in mid.data_mut().iter_mut().zip(gate.data()) {
            *m *= silu(*g);
        }
        if let Some(c) = capture.as_deref_mut() {
            c.inputs.insert("mlp_mid", mid.clone());
        }
        let down = matmul_transb(&mid, &lw.w2);
        hidden2.axpy(1.0, &down);
        hidden2
    }

    /// Final RMSNorm + lm_head → (seq × vocab) logits.
    pub fn final_logits(&self, hidden: &Matrix) -> Matrix {
        let seq = hidden.rows();
        let d = self.cfg.d_model;
        let mut normed = Matrix::zeros(seq, d);
        for t in 0..seq {
            rmsnorm(hidden.row(t), &self.norm_f, normed.row_mut(t));
        }
        matmul_transb(&normed, &self.lm_head)
    }

    /// Full forward: tokens → logits (seq × vocab).
    pub fn forward_full(&self, tokens: &[u32]) -> Matrix {
        let rope = Rope::new(tokens.len(), self.cfg.head_dim());
        let mut h = self.embed_tokens(tokens);
        for l in 0..self.cfg.n_layers {
            h = self.block_forward(l, &h, &rope, None);
        }
        self.final_logits(&h)
    }

    /// Start an incremental decode session.
    pub fn decode_state(&self) -> DecodeState {
        DecodeState::new(self)
    }
}

/// Score/softmax/AV for one query head over head-major K/V strips of
/// `t + 1 = scores.len()` live positions: `out += softmax(K q · scale) V`.
/// Used by [`DecodeState::step`]; the serving engines' fused sweep runs
/// the same computation batched across sessions
/// ([`crate::tensor::strip_dots`] / [`crate::tensor::strip_axpys`]),
/// with identical per-lane accumulation order so the two paths stay
/// token-identical.
#[inline]
pub fn attend_head(
    q_h: &[f32],
    kstrip: &[f32],
    vstrip: &[f32],
    scale: f32,
    scores: &mut [f32],
    out: &mut [f32],
) {
    let hd = q_h.len();
    for (u, sc) in scores.iter_mut().enumerate() {
        *sc = dot(q_h, &kstrip[u * hd..(u + 1) * hd]) * scale;
    }
    softmax(scores);
    for (u, &w) in scores.iter().enumerate() {
        if w < 1e-9 {
            continue;
        }
        axpy(w, &vstrip[u * hd..(u + 1) * hd], out);
    }
}

/// [`attend_head`] over **packed** bit-plane K/V strips: identical
/// score/softmax/AV structure, but dequantization is fused into the
/// strip walks ([`crate::tensor::strip_dots_packed`] /
/// [`crate::tensor::strip_axpys_packed`]) — no f32 row is ever
/// materialized. Implemented as the batched kernels at lane count 1, so
/// the single-session and fused multi-session packed paths accumulate
/// bit-identically (the packed analogue of the f32 token-identity
/// pairing between [`attend_head`] and `strip_dots`/`strip_axpys`).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn attend_head_packed(
    q_h: &[f32],
    kstrip: PackedStrip,
    vstrip: PackedStrip,
    len: usize,
    scale: f32,
    scores: &mut [f32],
    out: &mut [f32],
    simd: &mut SimdScratch,
) {
    debug_assert_eq!(scores.len(), len);
    strip_dots_packed(&[q_h], &[kstrip], len, scale, scores, simd);
    softmax(scores);
    let mut outs: [&mut [f32]; 1] = [out];
    strip_axpys_packed(scores, &[vstrip], len, &mut outs);
}

/// Incremental KV-cache decode (one token at a time). KV lives in a
/// slot of the model's pooled [`KvArena`] — the state owns only the
/// slot handle (released back to the arena on drop), position
/// bookkeeping, and a shared rope table.
pub struct DecodeState {
    arena: Arc<KvArena>,
    /// `Some` for the whole life of the state; taken only in `drop`.
    handle: Option<KvHandle>,
    pos: usize,
    rope: Arc<Rope>,
    max_seq: usize,
    /// Subset-sum table workspace for the packed attention kernels
    /// (unused for f32 KV; never cloned on fork — tables are per-call).
    simd: SimdScratch,
}

impl Drop for DecodeState {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            self.arena.release(h);
        }
    }
}

impl DecodeState {
    /// Claim an arena slot. Panics with "KV arena exhausted" when the
    /// model's arena is at its slot cap — the session-level analogue of
    /// the per-session "KV cache exhausted" capacity assert.
    pub fn new(model: &Model) -> Self {
        let arena = model.kv_arena();
        debug_assert_eq!(
            arena.geom(),
            crate::serving::kv::KvGeom::of(model),
            "arena geometry must match the model (clones share arenas only at equal geometry)"
        );
        let handle = arena.acquire().expect("KV arena exhausted");
        Self {
            arena,
            handle: Some(handle),
            pos: 0,
            rope: model.rope(),
            max_seq: model.decode_capacity(),
            simd: SimdScratch::default(),
        }
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn capacity(&self) -> usize {
        self.max_seq
    }

    /// `(id, generation)` of every arena page this session references
    /// ([`KvHandle::page_ids`]) — the observable the resurrection and
    /// leak property tests key on.
    pub fn page_ids(&self) -> Vec<(u32, u64)> {
        self.handle.as_ref().expect("live decode state").page_ids()
    }

    /// Rewind to position 0 for slot reuse. Stale K/V rows beyond `pos`
    /// are never read, so no zeroing is needed.
    pub fn reset(&mut self) {
        self.pos = 0;
    }

    /// Cheap branch point: claims a sibling session whose page table
    /// *shares* this one's live-prefix pages — [`KvArena::fork`] is a
    /// pure refcount bump, no byte copy; the first divergent store on
    /// either side copy-on-writes only its own page. Shares the rope
    /// table (the prefix-cache trick behind fast multiple-choice
    /// scoring — score N continuations against one shared prompt
    /// prefix). `&mut` because sharing marks this session's prefix
    /// pages copy-on-write in its own table.
    pub fn fork(&mut self) -> DecodeState {
        let pos = self.pos;
        let src = self.handle.as_mut().expect("live decode state");
        let handle = self.arena.fork(src, pos).expect("KV arena exhausted");
        DecodeState {
            arena: self.arena.clone(),
            handle: Some(handle),
            pos,
            rope: self.rope.clone(),
            max_seq: self.max_seq,
            simd: SimdScratch::default(),
        }
    }

    /// Borrow a cached token prefix ([`PrefixCache::match_and_borrow`]):
    /// imports the matched pages read-only and fast-forwards this
    /// session to the matched position. Returns how many prompt tokens
    /// are now resident — the caller feeds only `prompt[matched..]`.
    /// Must run before any token is fed.
    pub fn prefix_attach(&mut self, cache: &PrefixCache, prompt: &[u32]) -> usize {
        assert_eq!(self.pos, 0, "prefix_attach on a session that already decoded");
        let h = self.handle.as_mut().expect("live decode state");
        let matched = cache.match_and_borrow(prompt, h);
        self.pos = matched;
        matched
    }

    /// Publish this session's prompt pages into `cache` (refcount
    /// bumps, never byte copies). Call once the full prompt has been
    /// fed; idempotent for already-cached prompts.
    pub fn prefix_publish(&mut self, cache: &PrefixCache, prompt: &[u32]) {
        assert!(self.pos >= prompt.len(), "prefix_publish before the prompt was fully fed");
        let h = self.handle.as_mut().expect("live decode state");
        cache.insert(prompt, h);
    }

    /// Feed one token; returns the logits for the next-token distribution.
    pub fn step(&mut self, model: &Model, token: u32) -> Vec<f32> {
        assert!(self.pos < self.max_seq, "KV cache exhausted");
        let cfg = &model.cfg;
        let (d, nh, nkv, hd) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());
        let group = cfg.kv_group();
        let scale = 1.0 / (hd as f32).sqrt();
        let t = self.pos;

        let id = (token as usize).min(cfg.vocab_size - 1);
        let mut h: Vec<f32> = model.embed.row(id).to_vec();
        let mut normed = vec![0.0f32; d];
        let mut scores = vec![0.0f32; t + 1];
        let pp = self.arena.geom().page_positions;
        let mut kv = self.arena.view_mut(self.handle.as_mut().expect("live decode state"));

        for (l, lw) in model.layers.iter().enumerate() {
            rmsnorm(&h, &lw.norm1, &mut normed);
            let mut q = matvec(&lw.wq, &normed); // d_model
            let mut kx = matvec(&lw.wk, &normed); // kv_dim
            let vx = matvec(&lw.wv, &normed); // kv_dim
            for hh in 0..nh {
                self.rope.apply(&mut q[hh * hd..(hh + 1) * hd], t);
            }
            for hh in 0..nkv {
                self.rope.apply(&mut kx[hh * hd..(hh + 1) * hd], t);
            }
            // Quantization (if any) happens HERE, once per token, as the
            // freshly-computed row is stored; the attention walk below
            // consumes whatever the arena's format physically holds.
            kv.store_k(l, t, &kx);
            kv.store_v(l, t, &vx);

            // Attention walks the session's *page runs*: a strip is a
            // page table, not one contiguous region. Per-position order
            // is identical to the monolithic walk (scores page by page,
            // one softmax over all live positions, AV page by page), so
            // paging never changes logits.
            let mut attn = vec![0.0f32; d];
            let len = t + 1;
            for hh in 0..nh {
                let o0 = hh * hd;
                let kvh = hh / group;
                let q_h = &q[o0..o0 + hd];
                let (mut p0, mut pg) = (0usize, 0usize);
                while p0 < len {
                    let plen = (len - p0).min(pp);
                    let sc = &mut scores[p0..p0 + plen];
                    match kv.format() {
                        KvFormat::F32 => {
                            let kpage = kv.k_page(l, kvh, pg);
                            for (u, s) in sc.iter_mut().enumerate() {
                                *s = dot(q_h, &kpage[u * hd..(u + 1) * hd]) * scale;
                            }
                        }
                        KvFormat::BitPlane { .. } => strip_dots_packed(
                            &[q_h],
                            &[kv.k_page_packed(l, kvh, pg)],
                            plen,
                            scale,
                            sc,
                            &mut self.simd,
                        ),
                    }
                    p0 += plen;
                    pg += 1;
                }
                softmax(&mut scores[..len]);
                let out = &mut attn[o0..o0 + hd];
                let (mut p0, mut pg) = (0usize, 0usize);
                while p0 < len {
                    let plen = (len - p0).min(pp);
                    let sc = &scores[p0..p0 + plen];
                    match kv.format() {
                        KvFormat::F32 => {
                            let vpage = kv.v_page(l, kvh, pg);
                            for (u, &w) in sc.iter().enumerate() {
                                if w < 1e-9 {
                                    continue;
                                }
                                axpy(w, &vpage[u * hd..(u + 1) * hd], out);
                            }
                        }
                        KvFormat::BitPlane { .. } => {
                            let mut outs: [&mut [f32]; 1] = [&mut *out];
                            strip_axpys_packed(
                                sc,
                                &[kv.v_page_packed(l, kvh, pg)],
                                plen,
                                &mut outs,
                            );
                        }
                    }
                    p0 += plen;
                    pg += 1;
                }
            }
            let proj = matvec(&lw.wo, &attn);
            for (hi, p) in h.iter_mut().zip(&proj) {
                *hi += p;
            }

            rmsnorm(&h, &lw.norm2, &mut normed);
            let up = matvec(&lw.w1, &normed);
            let gate = matvec(&lw.w3, &normed);
            let mid: Vec<f32> = up.iter().zip(&gate).map(|(&u, &g)| u * silu(g)).collect();
            let down = matvec(&lw.w2, &mid);
            for (hi, dn) in h.iter_mut().zip(&down) {
                *hi += dn;
            }
        }
        self.pos += 1;
        // Final norm into the scratch buffer — no defensive h.clone().
        rmsnorm(&h, &model.norm_f, &mut normed);
        matvec(&model.lm_head, &normed)
    }

    /// Feed a chunk of prompt tokens at consecutive positions and
    /// return only the **final** position's next-token logits — the
    /// native multi-token prefill path behind the scheduler's chunked
    /// prefill. Token-identical to feeding the chunk through
    /// [`DecodeState::step`] one token at a time: every position runs
    /// the exact per-position kernels of `step` in the same order; what
    /// changes is the K/V store, which lands per layer as one bulk run
    /// ([`crate::serving::kv::KvViewMut::store_k_run`] — byte-identical
    /// end state, one page-ownership resolution per touched page).
    /// Storing the whole chunk *before* any in-chunk attention is safe
    /// because position `t`'s walk caps at `len = t + 1`: the later
    /// rows exist but are never read — causality by length, not masks.
    pub fn prefill_chunk(&mut self, model: &Model, tokens: &[u32]) -> Vec<f32> {
        let n = tokens.len();
        if n == 0 {
            return Vec::new();
        }
        if n == 1 {
            return self.step(model, tokens[0]);
        }
        assert!(self.pos + n <= self.max_seq, "KV cache exhausted");
        let cfg = &model.cfg;
        let (d, nh, nkv, hd) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());
        let kvd = cfg.kv_dim();
        let group = cfg.kv_group();
        let scale = 1.0 / (hd as f32).sqrt();
        let t0 = self.pos;

        // Lane-major flat buffers: lane j = position t0 + j.
        let mut hbuf = vec![0.0f32; n * d];
        for (j, &tok) in tokens.iter().enumerate() {
            let id = (tok as usize).min(cfg.vocab_size - 1);
            hbuf[j * d..(j + 1) * d].copy_from_slice(model.embed.row(id));
        }
        let mut normed = vec![0.0f32; n * d];
        let mut qbuf = vec![0.0f32; n * d];
        let mut kbuf = vec![0.0f32; n * kvd];
        let mut vbuf = vec![0.0f32; n * kvd];
        let mut scores = vec![0.0f32; t0 + n];
        let pp = self.arena.geom().page_positions;
        let mut kv = self.arena.view_mut(self.handle.as_mut().expect("live decode state"));

        for (l, lw) in model.layers.iter().enumerate() {
            for j in 0..n {
                let (h0, h1) = (j * d, (j + 1) * d);
                rmsnorm(&hbuf[h0..h1], &lw.norm1, &mut normed[h0..h1]);
                let mut q = matvec(&lw.wq, &normed[h0..h1]);
                let mut kx = matvec(&lw.wk, &normed[h0..h1]);
                let vx = matvec(&lw.wv, &normed[h0..h1]);
                let t = t0 + j;
                for hh in 0..nh {
                    self.rope.apply(&mut q[hh * hd..(hh + 1) * hd], t);
                }
                for hh in 0..nkv {
                    self.rope.apply(&mut kx[hh * hd..(hh + 1) * hd], t);
                }
                qbuf[h0..h1].copy_from_slice(&q);
                kbuf[j * kvd..(j + 1) * kvd].copy_from_slice(&kx);
                vbuf[j * kvd..(j + 1) * kvd].copy_from_slice(&vx);
            }
            // Whole-chunk store first (quantization, if any, happens
            // here exactly as in `step` — same rows, same encoder),
            // then per-position attention over the arena-resident
            // prefix plus the in-chunk causal block.
            kv.store_k_run(l, t0, &kbuf);
            kv.store_v_run(l, t0, &vbuf);

            for j in 0..n {
                let len = t0 + j + 1;
                let mut attn = vec![0.0f32; d];
                for hh in 0..nh {
                    let o0 = hh * hd;
                    let kvh = hh / group;
                    let q_h = &qbuf[j * d + o0..j * d + o0 + hd];
                    let (mut p0, mut pg) = (0usize, 0usize);
                    while p0 < len {
                        let plen = (len - p0).min(pp);
                        let sc = &mut scores[p0..p0 + plen];
                        match kv.format() {
                            KvFormat::F32 => {
                                let kpage = kv.k_page(l, kvh, pg);
                                for (u, s) in sc.iter_mut().enumerate() {
                                    *s = dot(q_h, &kpage[u * hd..(u + 1) * hd]) * scale;
                                }
                            }
                            KvFormat::BitPlane { .. } => strip_dots_packed(
                                &[q_h],
                                &[kv.k_page_packed(l, kvh, pg)],
                                plen,
                                scale,
                                sc,
                                &mut self.simd,
                            ),
                        }
                        p0 += plen;
                        pg += 1;
                    }
                    softmax(&mut scores[..len]);
                    let out = &mut attn[o0..o0 + hd];
                    let (mut p0, mut pg) = (0usize, 0usize);
                    while p0 < len {
                        let plen = (len - p0).min(pp);
                        let sc = &scores[p0..p0 + plen];
                        match kv.format() {
                            KvFormat::F32 => {
                                let vpage = kv.v_page(l, kvh, pg);
                                for (u, &w) in sc.iter().enumerate() {
                                    if w < 1e-9 {
                                        continue;
                                    }
                                    axpy(w, &vpage[u * hd..(u + 1) * hd], out);
                                }
                            }
                            KvFormat::BitPlane { .. } => {
                                let mut outs: [&mut [f32]; 1] = [&mut *out];
                                strip_axpys_packed(
                                    sc,
                                    &[kv.v_page_packed(l, kvh, pg)],
                                    plen,
                                    &mut outs,
                                );
                            }
                        }
                        p0 += plen;
                        pg += 1;
                    }
                }
                let (h0, h1) = (j * d, (j + 1) * d);
                let proj = matvec(&lw.wo, &attn);
                for (hi, p) in hbuf[h0..h1].iter_mut().zip(&proj) {
                    *hi += p;
                }

                rmsnorm(&hbuf[h0..h1], &lw.norm2, &mut normed[h0..h1]);
                let up = matvec(&lw.w1, &normed[h0..h1]);
                let gate = matvec(&lw.w3, &normed[h0..h1]);
                let mid: Vec<f32> = up.iter().zip(&gate).map(|(&u, &g)| u * silu(g)).collect();
                let down = matvec(&lw.w2, &mid);
                for (hi, dn) in hbuf[h0..h1].iter_mut().zip(&down) {
                    *hi += dn;
                }
            }
        }
        self.pos += n;
        let last = &hbuf[(n - 1) * d..];
        rmsnorm(last, &model.norm_f, &mut normed[..d]);
        matvec(&model.lm_head, &normed[..d])
    }
}

/// Greedy-decode `max_new` tokens after feeding `prompt`.
pub fn greedy_generate(model: &Model, prompt: &[u32], max_new: usize) -> Vec<u32> {
    let mut st = model.decode_state();
    let mut logits = vec![0.0f32; model.cfg.vocab_size];
    let budget = st.capacity().saturating_sub(2);
    for &t in prompt.iter().take(budget) {
        logits = st.step(model, t);
    }
    let mut out = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        if st.pos() >= st.capacity() {
            break;
        }
        let next = argmax(&logits) as u32;
        out.push(next);
        logits = st.step(model, next);
    }
    out
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            bi = i;
        }
    }
    bi
}

/// Seeded token sampling over next-token logits.
///
/// `temperature == 0` is **exactly** [`argmax`] (same first-max
/// tie-break) — the greedy path every token-identity parity test pins.
/// Otherwise the logits are scaled by `1/temperature`, truncated to the
/// `top_k` highest (`0` = off) and then to the smallest nucleus whose
/// cumulative tempered probability reaches `top_p` (`1.0` = off), and a
/// token is drawn from the renormalized distribution using `rng` — the
/// caller seeds one [`Rng`] per request, so identical (seed, prompt,
/// params) streams are token-identical regardless of batching.
///
/// The returned logprob is of the chosen token under the **raw**
/// (untempered, untruncated) softmax — the quantity serving APIs
/// report. Candidate sorting is O(V log V); V is the tiny-LM vocab
/// here, and the sort only runs on the sampled (non-greedy) path.
pub fn sample(
    logits: &[f32],
    temperature: f32,
    top_k: usize,
    top_p: f32,
    rng: &mut Rng,
) -> (usize, f32) {
    debug_assert!(!logits.is_empty());
    let raw_max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f64 = logits.iter().map(|&l| ((l - raw_max) as f64).exp()).sum::<f64>().ln();
    let logprob_of = |i: usize| ((logits[i] - raw_max) as f64 - lse) as f32;

    if temperature <= 0.0 {
        let i = argmax(logits);
        return (i, logprob_of(i));
    }

    // Candidates sorted by logit descending, index ascending on ties —
    // deterministic truncation order.
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]).then(a.cmp(&b)));
    if top_k > 0 && top_k < idx.len() {
        idx.truncate(top_k);
    }
    // Tempered weights over the kept candidates (max-shifted for
    // stability; f64 so tiny temperatures don't underflow to all-zero).
    let t_max = logits[idx[0]];
    let mut weights: Vec<f64> =
        idx.iter().map(|&i| (((logits[i] - t_max) / temperature) as f64).exp()).collect();
    if top_p < 1.0 {
        let total: f64 = weights.iter().sum();
        let mut cum = 0.0;
        let mut keep = weights.len();
        for (j, w) in weights.iter().enumerate() {
            cum += w;
            if cum >= top_p as f64 * total {
                keep = j + 1;
                break;
            }
        }
        idx.truncate(keep);
        weights.truncate(keep);
    }
    let total: f64 = weights.iter().sum();
    let mut u = rng.f64() * total;
    // Walk the kept candidates; the last one absorbs any float residue.
    let mut chosen = *idx.last().unwrap();
    for (j, &i) in idx.iter().enumerate() {
        u -= weights[j];
        if u <= 0.0 {
            chosen = i;
            break;
        }
    }
    (chosen, logprob_of(chosen))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic_model, ModelConfig};

    fn tiny() -> Model {
        tiny_gqa(2)
    }

    /// 4-head tiny model with `n_kv_heads` kv heads (4 = MHA, 2 = GQA,
    /// 1 = MQA).
    fn tiny_gqa(n_kv_heads: usize) -> Model {
        synthetic_model(
            &ModelConfig {
                vocab_size: 20,
                d_model: 16,
                n_layers: 2,
                n_heads: 4,
                n_kv_heads,
                d_ff: 24,
                max_seq: 32,
                kv_format: KvFormat::F32,
            },
            42,
        )
    }

    #[test]
    fn full_forward_shapes() {
        let m = tiny();
        let logits = m.forward_full(&[1, 2, 3, 4, 5]);
        assert_eq!(logits.shape(), (5, 20));
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn decode_matches_full_forward() {
        // The KV-cache path must agree with the batch path exactly
        // (up to f32 accumulation order) — for MHA, GQA, and MQA.
        for n_kv in [1usize, 2, 4] {
            let m = tiny_gqa(n_kv);
            let tokens = [3u32, 7, 1, 12, 5, 9];
            let full = m.forward_full(&tokens);
            let mut st = m.decode_state();
            for (t, &tok) in tokens.iter().enumerate() {
                let logits = st.step(&m, tok);
                for v in 0..m.cfg.vocab_size {
                    let a = full.get(t, v);
                    let b = logits[v];
                    assert!(
                        (a - b).abs() < 1e-3 * (1.0 + a.abs()),
                        "n_kv {n_kv} pos {t} vocab {v}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn gqa_changes_attention_but_stays_finite() {
        // Fewer kv heads is a different function (shared K/V), not a
        // reparameterization — outputs must differ from MHA yet be finite.
        let toks = [3u32, 7, 1, 12];
        let mha = tiny_gqa(4).forward_full(&toks);
        let mqa = tiny_gqa(1).forward_full(&toks);
        assert!(mqa.data().iter().all(|v| v.is_finite()));
        assert!(mha.fro_dist(&mqa) > 1e-6);
    }

    #[test]
    fn fork_preserves_live_prefix() {
        for n_kv in [1usize, 2, 4] {
            let m = tiny_gqa(n_kv);
            let prompt = [3u32, 7, 1];
            let mut st = m.decode_state();
            for &t in &prompt {
                let _ = st.step(&m, t);
            }
            // continue on a fork vs. on the original: identical logits
            let mut f = st.fork();
            assert_eq!(f.pos(), st.pos());
            let a = f.step(&m, 9);
            let b = st.step(&m, 9);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-6, "n_kv {n_kv}");
            }
        }
    }

    #[test]
    fn packed_kv_decode_is_finite_and_faithful() {
        // Decoding with a bit-plane KV arena must stay finite, actually
        // take the packed path (≠ f32 logits), and at W4 remain close
        // to the f32-KV decode (the grid step is range/15 per row).
        let f32_model = tiny_gqa(2);
        let toks = [3u32, 7, 1, 12, 5];
        let mut st = f32_model.decode_state();
        let mut f32_logits = Vec::new();
        for &t in &toks {
            f32_logits = st.step(&f32_model, t);
        }
        for bits in [2usize, 3, 4] {
            let qm = f32_model.with_kv_format(KvFormat::bit_plane(bits));
            let mut st = qm.decode_state();
            let mut logits = Vec::new();
            for &t in &toks {
                logits = st.step(&qm, t);
            }
            assert!(logits.iter().all(|v| v.is_finite()), "bits {bits}");
            let dist: f64 = logits
                .iter()
                .zip(&f32_logits)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            let norm: f64 =
                f32_logits.iter().map(|&b| (b as f64).powi(2)).sum::<f64>().sqrt();
            assert!(dist > 1e-9, "bits {bits}: packed path was never taken");
            if bits == 4 {
                assert!(
                    dist < 1.5 * (norm + 1.0),
                    "bits {bits}: quantized-KV logits diverged wildly ({dist} vs norm {norm})"
                );
            }
        }
    }

    #[test]
    fn packed_kv_fork_and_dirty_replay_are_deterministic() {
        // The packed encoder is deterministic and fork is a bytewise
        // prefix copy, so (a) a fork continues bit-identically to its
        // parent and (b) a dirty reused slot replays a decode exactly.
        let m = tiny_gqa(2).with_kv_format(KvFormat::bit_plane(2));
        let prompt = [3u32, 7, 1];
        let mut st = m.decode_state();
        let mut first = Vec::new();
        for &t in &prompt {
            first = st.step(&m, t);
        }
        let mut f = st.fork();
        let a = f.step(&m, 9);
        let b = st.step(&m, 9);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6, "packed fork diverged");
        }
        drop(f);
        drop(st); // slots back to the free list, dirty
        let mut st2 = m.decode_state();
        let mut replay = Vec::new();
        for &t in &prompt {
            replay = st2.step(&m, t);
        }
        for (x, y) in first.iter().zip(&replay) {
            assert!((x - y).abs() < 1e-6, "dirty packed slot replay diverged");
        }
    }

    #[test]
    fn page_size_never_changes_logits() {
        // Only the addressing changes with `kv_page` — same math, same
        // per-position order — so a 2-position-page decode must be
        // bit-identical to the default page size, f32 and packed.
        for fmt in [KvFormat::F32, KvFormat::bit_plane(2)] {
            let m = tiny_gqa(2).with_kv_format(fmt);
            let mp = m.with_kv_page(2);
            let toks = [3u32, 7, 1, 12, 5, 9, 2];
            let mut a = m.decode_state();
            let mut b = mp.decode_state();
            for &tk in &toks {
                assert_eq!(a.step(&m, tk), b.step(&mp, tk), "{fmt:?}");
            }
        }
    }

    #[test]
    fn prefix_cache_hit_decodes_token_identical_to_cold() {
        // The ISSUE parity bar: a cache-hit session continues
        // token-identically to a cold one at every kv_bits — shared
        // pages travel bytewise, never re-quantized.
        for bits in [0usize, 2, 3, 4] {
            let m = if bits == 0 {
                tiny_gqa(2)
            } else {
                tiny_gqa(2).with_kv_format(KvFormat::bit_plane(bits))
            }
            .with_kv_page(2); // small pages exercise page boundaries
            let cache = PrefixCache::new(m.kv_arena());
            let prompt = [3u32, 7, 1, 12, 5];

            // Cold: full prefill, publish, greedy continuation.
            let mut cold = m.decode_state();
            let mut logits = Vec::new();
            for &tk in &prompt {
                logits = cold.step(&m, tk);
            }
            cold.prefix_publish(&cache, &prompt);
            let mut cold_tokens = Vec::new();
            for _ in 0..6 {
                let next = argmax(&logits) as u32;
                cold_tokens.push(next);
                logits = cold.step(&m, next);
            }
            drop(cold); // cache refs alone keep the prefix alive

            // Warm: borrow the cached prefix, feed only the suffix.
            let mut warm = m.decode_state();
            let matched = warm.prefix_attach(&cache, &prompt);
            assert_eq!(matched, prompt.len() - 1, "bits {bits}");
            let mut logits = Vec::new();
            for &tk in &prompt[matched..] {
                logits = warm.step(&m, tk);
            }
            let mut warm_tokens = Vec::new();
            for _ in 0..6 {
                let next = argmax(&logits) as u32;
                warm_tokens.push(next);
                logits = warm.step(&m, next);
            }
            assert_eq!(warm_tokens, cold_tokens, "bits {bits}: cache hit diverged from cold");
        }
    }

    #[test]
    fn prefill_chunk_matches_stepwise() {
        // The chunked native prefill must be BIT-identical to stepping
        // the same tokens one at a time — every kv_bits, small pages
        // (chunks cross page boundaries), ragged chunk splits, and a
        // chunk fed mid-stream (non-zero starting position).
        for bits in [0usize, 2, 3, 4] {
            let m = if bits == 0 {
                tiny_gqa(2)
            } else {
                tiny_gqa(2).with_kv_format(KvFormat::bit_plane(bits))
            }
            .with_kv_page(2);
            let toks = [3u32, 7, 1, 12, 5, 9, 2, 11, 4, 6];
            let mut seq = m.decode_state();
            let mut seq_logits = Vec::new();
            for &tk in &toks {
                seq_logits = seq.step(&m, tk);
            }
            for splits in [vec![10usize], vec![3, 4, 3], vec![1, 5, 2, 2]] {
                let mut ch = m.decode_state();
                let mut logits = Vec::new();
                let mut at = 0usize;
                for &len in splits.iter() {
                    logits = ch.prefill_chunk(&m, &toks[at..at + len]);
                    at += len;
                }
                assert_eq!(ch.pos(), seq.pos(), "bits {bits} {splits:?}");
                assert_eq!(logits, seq_logits, "bits {bits} {splits:?}: chunked ≠ stepwise");
                // …and the decodes that follow stay identical too (the
                // stored KV bytes, not just the logits, must match).
                let mut a = seq.fork();
                let next = argmax(&seq_logits) as u32;
                assert_eq!(ch.step(&m, next), a.step(&m, next), "bits {bits} {splits:?}");
            }
        }
    }

    #[test]
    fn causality() {
        // Changing a future token must not change past logits.
        let m = tiny();
        let a = m.forward_full(&[1, 2, 3, 4]);
        let b = m.forward_full(&[1, 2, 3, 15]);
        for t in 0..3 {
            for v in 0..20 {
                assert!((a.get(t, v) - b.get(t, v)).abs() < 1e-5, "t={t}");
            }
        }
        // …but it must change the last position (model is not degenerate).
        let mut differs = false;
        for v in 0..20 {
            if (a.get(3, v) - b.get(3, v)).abs() > 1e-6 {
                differs = true;
            }
        }
        assert!(differs);
    }

    #[test]
    fn rope_position_dependence() {
        // Same token at different positions → different K → different
        // attention pattern. Check RoPE itself rotates.
        let rope = Rope::new(8, 8);
        let mut v0 = vec![1.0f32; 8];
        let mut v1 = vec![1.0f32; 8];
        rope.apply(&mut v0, 0);
        rope.apply(&mut v1, 5);
        assert_ne!(v0, v1);
        // position 0 is identity
        assert_eq!(v0, vec![1.0f32; 8]);
        // norm preserved (rotation)
        let n: f32 = v1.iter().map(|x| x * x).sum();
        assert!((n - 8.0).abs() < 1e-4);
    }

    #[test]
    fn capture_collects_all_inputs() {
        let m = tiny();
        let rope = Rope::new(4, m.cfg.head_dim());
        let h = m.embed_tokens(&[1, 2, 3, 4]);
        let mut cap = Capture::default();
        let _ = m.block_forward(0, &h, &rope, Some(&mut cap));
        for key in ["attn_in", "attn_out", "mlp_in", "mlp_mid"] {
            assert!(cap.inputs.contains_key(key), "{key}");
        }
        assert_eq!(cap.inputs["attn_in"].shape(), (4, 16));
        assert_eq!(cap.inputs["mlp_mid"].shape(), (4, 24));
        // key mapping
        assert_eq!(Capture::key_for("wk"), "attn_in");
        assert_eq!(Capture::key_for("w2"), "mlp_mid");
    }

    #[test]
    fn greedy_generate_deterministic() {
        let m = tiny();
        let a = greedy_generate(&m, &[1, 2, 3], 8);
        let b = greedy_generate(&m, &[1, 2, 3], 8);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn sample_temp_zero_is_argmax() {
        let logits = vec![0.1f32, 2.0, -1.0, 1.9];
        let mut rng = Rng::new(7);
        for _ in 0..10 {
            let (i, lp) = sample(&logits, 0.0, 0, 1.0, &mut rng);
            assert_eq!(i, argmax(&logits));
            assert!(lp <= 0.0 && lp.is_finite());
        }
    }

    #[test]
    fn sample_logprob_is_log_softmax() {
        let logits = vec![1.0f32, 2.0, 3.0];
        let mut rng = Rng::new(1);
        let (i, lp) = sample(&logits, 0.0, 0, 1.0, &mut rng);
        assert_eq!(i, 2);
        // log softmax of 3 over [1,2,3]
        let expect =
            (3.0f64 - ((1.0f64).exp() + (2.0f64).exp() + (3.0f64).exp()).ln()) as f32;
        assert!((lp - expect).abs() < 1e-5, "{lp} vs {expect}");
    }

    #[test]
    fn sample_top_k_one_and_tiny_top_p_are_greedy() {
        let logits = vec![0.5f32, -0.2, 3.1, 1.0, 2.9];
        let mut rng = Rng::new(11);
        for _ in 0..20 {
            assert_eq!(sample(&logits, 1.5, 1, 1.0, &mut rng).0, 2, "top_k=1");
            assert_eq!(sample(&logits, 1.5, 0, 1e-6, &mut rng).0, 2, "tiny nucleus");
        }
    }

    #[test]
    fn sample_top_k_restricts_support() {
        let logits = vec![0.0f32, 5.0, 4.8, -2.0, 1.0];
        let mut rng = Rng::new(3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            let (i, _) = sample(&logits, 1.0, 2, 1.0, &mut rng);
            seen.insert(i);
            assert!(i == 1 || i == 2, "top_k=2 must only emit the two largest, got {i}");
        }
        assert_eq!(seen.len(), 2, "high temperature should reach both kept tokens");
    }

    #[test]
    fn sample_seeded_streams_reproduce() {
        let logits: Vec<f32> = (0..16).map(|i| ((i * 7) % 5) as f32 * 0.3).collect();
        let draw = |seed: u64| -> Vec<usize> {
            let mut rng = Rng::new(seed);
            (0..32).map(|_| sample(&logits, 0.9, 0, 0.95, &mut rng).0).collect()
        };
        assert_eq!(draw(42), draw(42), "same seed ⇒ same stream");
        assert_ne!(draw(42), draw(43), "different seed ⇒ different stream");
    }

    #[test]
    fn sample_uniform_logits_spread() {
        let logits = vec![0.0f32; 8];
        let mut rng = Rng::new(5);
        let mut counts = [0usize; 8];
        for _ in 0..4000 {
            counts[sample(&logits, 1.0, 0, 1.0, &mut rng).0] += 1;
        }
        for &c in &counts {
            assert!(c > 300 && c < 700, "uniform logits should sample uniformly: {counts:?}");
        }
    }
}
