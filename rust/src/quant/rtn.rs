//! RTN — per-group asymmetric round-to-nearest (the fixed uniform grid
//! of paper Fig. 1a, no optimization). Also hosts the affine-grid helpers
//! shared by GPTQ and AWQ.

use super::packing::{PackedWeights, UniformPacked};
use super::UniformConfig;
use crate::tensor::Matrix;

/// Affine grid parameters for one (row, group).
#[derive(Clone, Copy, Debug)]
pub struct AffineParams {
    pub scale: f32,
    pub zero: u8,
}

/// Fit asymmetric min/max affine params over a slice of weights.
pub fn fit_affine(ws: &[f32], bits: u8) -> AffineParams {
    let qmax = ((1u32 << bits) - 1) as f32;
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &w in ws {
        lo = lo.min(w);
        hi = hi.max(w);
    }
    if !lo.is_finite() || !hi.is_finite() {
        return AffineParams { scale: 1.0, zero: 0 };
    }
    // grid must contain 0 for asymmetric quant of signed weights
    lo = lo.min(0.0);
    hi = hi.max(0.0);
    let scale = ((hi - lo) / qmax).max(1e-8);
    let zero = (-lo / scale).round().clamp(0.0, qmax) as u8;
    AffineParams { scale, zero }
}

/// Quantize one value to its code on the affine grid.
#[inline]
pub fn quant_code(w: f32, p: AffineParams, bits: u8) -> u8 {
    let qmax = ((1u32 << bits) - 1) as f32;
    (w / p.scale + p.zero as f32).round().clamp(0.0, qmax) as u8
}

/// Dequantize a code.
#[inline]
pub fn dequant_code(q: u8, p: AffineParams) -> f32 {
    p.scale * (q as f32 - p.zero as f32)
}

/// Plain RTN quantization of a weight matrix.
pub fn quantize(w: &Matrix, cfg: UniformConfig) -> (Matrix, PackedWeights) {
    let (d_out, d_in) = w.shape();
    let g = cfg.group_size;
    let ng = d_in.div_ceil(g);
    let mut codes = vec![0u8; d_out * d_in];
    let mut scales = Matrix::zeros(d_out, ng);
    let mut zeros = vec![0u8; d_out * ng];
    let mut deq = Matrix::zeros(d_out, d_in);

    for r in 0..d_out {
        for grp in 0..ng {
            let c0 = grp * g;
            let c1 = (c0 + g).min(d_in);
            let p = fit_affine(&w.row(r)[c0..c1], cfg.bits);
            scales.set(r, grp, p.scale);
            zeros[r * ng + grp] = p.zero;
            for j in c0..c1 {
                let q = quant_code(w.get(r, j), p, cfg.bits);
                codes[r * d_in + j] = q;
                deq.set(r, j, dequant_code(q, p));
            }
        }
    }

    let packed = UniformPacked {
        d_out,
        d_in,
        group_size: g,
        bits: cfg.bits,
        codes,
        scales,
        zeros,
        inv_perm: None,
    };
    (deq, PackedWeights::Uniform(packed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::test_util::rand_wx;

    #[test]
    fn affine_covers_range() {
        let ws = [-1.0f32, -0.2, 0.3, 0.9];
        let p = fit_affine(&ws, 4);
        // extremes must round-trip within one step
        for &w in &ws {
            let q = quant_code(w, p, 4);
            let d = dequant_code(q, p);
            assert!((d - w).abs() <= p.scale * 0.5 + 1e-6, "{w} -> {d}");
        }
    }

    #[test]
    fn grid_contains_zero() {
        let ws = [0.5f32, 0.7, 0.9]; // all positive
        let p = fit_affine(&ws, 2);
        // zero must be representable: code == zero gives exactly 0
        assert_eq!(dequant_code(p.zero, p), 0.0);
    }

    #[test]
    fn two_bit_grid_has_four_levels() {
        let ws = [-1.0f32, -0.3, 0.4, 1.0];
        let p = fit_affine(&ws, 2);
        let mut levels: Vec<i32> = ws
            .iter()
            .map(|&w| quant_code(w, p, 2) as i32)
            .collect();
        levels.sort_unstable();
        levels.dedup();
        assert!(levels.len() <= 4);
        for &l in &levels {
            assert!((0..=3).contains(&l));
        }
    }

    #[test]
    fn rtn_error_shrinks_with_bits() {
        let (w, _x) = rand_wx(5, 16, 128, 4);
        let errs: Vec<f64> = [2u8, 3, 4, 8]
            .iter()
            .map(|&bits| {
                let (deq, _) =
                    quantize(&w, UniformConfig { bits, group_size: 32, act_order: false });
                deq.fro_dist(&w)
            })
            .collect();
        assert!(errs[0] > errs[1] && errs[1] > errs[2] && errs[2] > errs[3], "{errs:?}");
        // 8-bit RTN is near-lossless
        assert!(errs[3] < 0.01 * w.fro_norm());
    }

    #[test]
    fn rtn_dequant_matches_packed_dequant() {
        let (w, _x) = rand_wx(6, 8, 96, 4);
        let cfg = UniformConfig { bits: 3, group_size: 32, act_order: false };
        let (deq, packed) = quantize(&w, cfg);
        if let PackedWeights::Uniform(p) = packed {
            assert!(deq.fro_dist(&p.dequant()) < 1e-6);
        } else {
            panic!("wrong packing variant");
        }
    }

    #[test]
    fn ragged_last_group() {
        let (w, _x) = rand_wx(7, 4, 70, 4); // 70 = 2*32 + 6
        let cfg = UniformConfig { bits: 4, group_size: 32, act_order: false };
        let (deq, packed) = quantize(&w, cfg);
        assert_eq!(deq.shape(), (4, 70));
        if let PackedWeights::Uniform(p) = &packed {
            assert_eq!(p.n_groups(), 3);
            assert!(deq.fro_dist(&p.dequant()) < 1e-6);
        }
    }
}
