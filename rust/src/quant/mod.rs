//! Post-training quantizers.
//!
//! The paper's contribution ([`bpdq`]) plus every baseline its evaluation
//! compares against, all behind one entry point ([`quantize_linear`]):
//!
//! | method | grid | objective | paper role |
//! |---|---|---|---|
//! | [`rtn`]    | fixed uniform   | none (round-to-nearest)        | floor |
//! | [`gptq`]   | fixed uniform   | Hessian-aware, per-column      | main baseline |
//! | [`awq`]    | fixed uniform   | activation-aware scaling       | main baseline |
//! | [`anybcq`] | binary-coded    | alternating LS, no Hessian     | bit-plane baseline |
//! | [`vptq`]   | vector codebook | Hessian-weighted k-means       | VQ baseline |
//! | [`bpdq`]   | **variable**    | Hessian-induced, iterative     | **the paper** |
//!
//! All of them consume the same [`hessian::HessianState`] built from
//! calibration activations and produce a [`QuantizedLinear`] carrying both
//! the dequantized weights (for evaluation forwards) and the
//! storage-accurate [`packing`] record (for BPW / model-size accounting
//! that mirrors the paper's tables: e.g. GPTQ-W2-G64 → 2.28 BPW,
//! BPDQ-W2-G64 → 2.75 BPW).

pub mod anybcq;
pub mod awq;
pub mod bpdq;
pub mod gar;
pub mod gptq;
pub mod hessian;
pub mod packing;
pub mod rtn;
pub mod vptq;

pub use bpdq::BpdqConfig;
pub use hessian::HessianState;
pub use packing::{BitPlanePacked, PackedWeights, UniformPacked, VqPacked};

use crate::tensor::{matmul_transb, Matrix};
use anyhow::Result;
use std::time::Instant;

/// Uniform-grid config shared by RTN / GPTQ / AWQ.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UniformConfig {
    pub bits: u8,
    pub group_size: usize,
    /// GPTQ `desc_act`: reorder channels by descending Hessian diagonal.
    pub act_order: bool,
}

impl Default for UniformConfig {
    fn default() -> Self {
        Self { bits: 4, group_size: 64, act_order: true }
    }
}

/// Binary-coded config (AnyBCQ-like baseline).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BcqConfig {
    pub bits: u8,
    pub group_size: usize,
    pub alt_iters: usize,
}

impl Default for BcqConfig {
    fn default() -> Self {
        Self { bits: 2, group_size: 64, alt_iters: 6 }
    }
}

/// Vector-quantization config (VPTQ-like baseline).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VqConfig {
    pub bits: u8,
    /// sub-vector dimension
    pub vdim: usize,
    pub kmeans_iters: usize,
    /// fraction of columns kept in fp16 (outlier protection)
    pub outlier_frac: f64,
}

impl Default for VqConfig {
    fn default() -> Self {
        Self { bits: 2, vdim: 2, kmeans_iters: 30, outlier_frac: 0.005 }
    }
}

/// Which quantizer to run.
#[derive(Clone, Debug, PartialEq)]
pub enum QuantMethod {
    Fp16,
    Rtn(UniformConfig),
    Gptq(UniformConfig),
    Awq(UniformConfig),
    AnyBcq(BcqConfig),
    Vptq(VqConfig),
    Bpdq(BpdqConfig),
}

impl QuantMethod {
    pub fn name(&self) -> String {
        match self {
            QuantMethod::Fp16 => "FP16".into(),
            QuantMethod::Rtn(c) => format!("RTN-W{}-G{}", c.bits, c.group_size),
            QuantMethod::Gptq(c) => format!("GPTQ-W{}-G{}", c.bits, c.group_size),
            QuantMethod::Awq(c) => format!("AWQ-W{}-G{}", c.bits, c.group_size),
            QuantMethod::AnyBcq(c) => format!("AnyBCQ-W{}-G{}", c.bits, c.group_size),
            QuantMethod::Vptq(c) => format!("VPTQ-W{}", c.bits),
            QuantMethod::Bpdq(c) => format!("BPDQ-W{}-G{}", c.k, c.group_size),
        }
    }
}

/// Per-layer quantization diagnostics.
#[derive(Clone, Debug, Default)]
pub struct QuantStats {
    /// `‖(W−Ŵ)X‖²_F` — the paper's optimization objective (Eq. 2).
    pub output_err: f64,
    /// `‖W−Ŵ‖²_F` — plain weight error, for reference.
    pub weight_err: f64,
    /// Wall-clock quantization time.
    pub secs: f64,
}

/// A quantized linear layer: dequantized weights for evaluation plus the
/// storage-exact packed record.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    pub method: String,
    pub dequant: Matrix,
    pub packed: PackedWeights,
    pub stats: QuantStats,
}

impl QuantizedLinear {
    pub fn bits_per_weight(&self) -> f64 {
        let n = (self.dequant.rows() * self.dequant.cols()) as f64;
        self.packed.total_bits() as f64 / n
    }

    pub fn size_bytes(&self) -> usize {
        self.packed.total_bits().div_ceil(8)
    }
}

/// Quantize one linear layer's weight `w` (d_out × d_in) given calibration
/// activations `x` (n_samples × d_in, rows are samples).
pub fn quantize_linear(w: &Matrix, x: &Matrix, method: QuantMethod) -> Result<QuantizedLinear> {
    let h = HessianState::from_activations(x);
    quantize_linear_h(w, &h, x, method)
}

/// Same but with a pre-computed Hessian (shared across layers reading the
/// same input activations).
pub fn quantize_linear_h(
    w: &Matrix,
    h: &HessianState,
    x: &Matrix,
    method: QuantMethod,
) -> Result<QuantizedLinear> {
    anyhow::ensure!(
        w.cols() == h.dim(),
        "weight d_in {} != hessian dim {}",
        w.cols(),
        h.dim()
    );
    let t0 = Instant::now();
    let (dequant, packed) = match &method {
        QuantMethod::Fp16 => {
            let bits = w.rows() * w.cols() * 16;
            (quantize_fp16(w), PackedWeights::Fp16 { total_bits: bits })
        }
        QuantMethod::Rtn(c) => rtn::quantize(w, *c),
        QuantMethod::Gptq(c) => gptq::quantize(w, h, *c)?,
        QuantMethod::Awq(c) => awq::quantize(w, h, *c),
        QuantMethod::AnyBcq(c) => anybcq::quantize(w, *c),
        QuantMethod::Vptq(c) => vptq::quantize(w, h, *c)?,
        QuantMethod::Bpdq(c) => bpdq::quantize(w, h, *c)?,
    };
    let secs = t0.elapsed().as_secs_f64();

    // Output-aligned error ‖(W−Ŵ)X‖²_F, computed exactly on the
    // calibration set.
    let mut diff = w.clone();
    diff.axpy(-1.0, &dequant);
    let dx = matmul_transb(x, &diff); // (n × d_out)
    let output_err = dx.fro_norm().powi(2);
    let weight_err = diff.fro_norm().powi(2);

    Ok(QuantizedLinear {
        method: method.name(),
        dequant,
        packed,
        stats: QuantStats { output_err, weight_err, secs },
    })
}

/// fp16 round-trip (the "16-bit baseline" row of every table).
pub fn quantize_fp16(w: &Matrix) -> Matrix {
    w.map(f32_to_f16_roundtrip)
}

/// Round an f32 to the nearest f16 and back (software emulation; the
/// vendor set has no `half` crate). The bit-level encode/decode pair
/// lives in [`crate::tensor::kvpack`], shared with the packed-KV
/// coefficient storage; NaN passes through unchanged.
pub fn f32_to_f16_roundtrip(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    crate::tensor::f16_decode(crate::tensor::f16_encode(x))
}

/// Number of column groups for `d_in` and `g` (last group may be ragged).
pub fn n_groups(d_in: usize, g: usize) -> usize {
    d_in.div_ceil(g)
}

#[cfg(test)]
pub(crate) mod test_util {
    use crate::rng::Rng;
    use crate::tensor::Matrix;

    /// Random (W, X) pair with heavy-tailed weights and Zipf-skewed
    /// per-channel activation scales — the statistics the quantizers are
    /// designed for.
    pub fn rand_wx(seed: u64, d_out: usize, d_in: usize, n: usize) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::from_vec(
            d_out,
            d_in,
            (0..d_out * d_in).map(|_| 0.1 * rng.student_t(5.0) as f32).collect(),
        );
        let scales: Vec<f32> =
            (0..d_in).map(|j| (1.0 / (1.0 + j as f32)).sqrt() * 3.0 + 0.05).collect();
        let x = Matrix::from_vec(
            n,
            d_in,
            (0..n * d_in)
                .map(|i| scales[i % d_in] * rng.normal() as f32)
                .collect(),
        );
        (w, x)
    }
}

#[cfg(test)]
mod tests {
    use super::test_util::rand_wx;
    use super::*;

    #[test]
    fn f16_roundtrip_exactness() {
        // Values exactly representable in f16 survive.
        for v in [0.0f32, 1.0, -2.5, 0.09375, 65504.0, -0.000061035156] {
            assert_eq!(f32_to_f16_roundtrip(v), v, "{v}");
        }
        // Values beyond f16 range overflow to inf.
        assert!(f32_to_f16_roundtrip(1e6).is_infinite());
        // Rounding error bounded by 2^-11 relative.
        for v in [0.1f32, 3.14159, -777.77, 1e-4] {
            let r = f32_to_f16_roundtrip(v);
            assert!(((r - v) / v).abs() < 1e-3, "{v} -> {r}");
        }
    }

    #[test]
    fn f16_idempotent() {
        let mut rng = crate::rng::Rng::new(3);
        for _ in 0..1000 {
            let v = (rng.normal() * 100.0) as f32;
            let once = f32_to_f16_roundtrip(v);
            assert_eq!(f32_to_f16_roundtrip(once), once, "{v}");
        }
    }

    #[test]
    fn fp16_method_bpw_is_16() {
        let (w, x) = rand_wx(1, 8, 32, 16);
        let q = quantize_linear(&w, &x, QuantMethod::Fp16).unwrap();
        assert!((q.bits_per_weight() - 16.0).abs() < 1e-9);
        assert!(q.stats.weight_err < 1e-4);
    }

    #[test]
    fn method_names() {
        assert_eq!(
            QuantMethod::Gptq(UniformConfig { bits: 2, group_size: 64, act_order: true }).name(),
            "GPTQ-W2-G64"
        );
        assert_eq!(
            QuantMethod::Bpdq(BpdqConfig { k: 3, group_size: 128, ..Default::default() }).name(),
            "BPDQ-W3-G128"
        );
    }

    #[test]
    fn n_groups_ragged() {
        assert_eq!(n_groups(128, 64), 2);
        assert_eq!(n_groups(130, 64), 3);
        assert_eq!(n_groups(1, 64), 1);
    }
}
