//! Storage-exact packed weight formats and BPW accounting.
//!
//! The paper's BPW column is reproduced bit-for-bit from these records:
//!
//! * uniform (GPTQ/AWQ/RTN): `b`-bit codes + per-group fp16 scale +
//!   `b`-bit zero-point → `BPW = b + (16 + b)/g`
//!   (GPTQ-W2-G64 → 2 + 18/64 = **2.28**, W4-G64 → **4.31**, W3-G32 →
//!   **3.59** — exactly the table values);
//! * bit-plane (BPDQ): `k` planes + `(k+1)` fp16 coefficients per group →
//!   `BPW = k + 16(k+1)/g`
//!   (BPDQ-W2-G64 → **2.75**, W2-G128 → **2.38**, W2-G256 → **2.19**,
//!   W4-G128 → **4.63**, W3-G64 → **4.00** — exactly the table values);
//! * binary-coded (AnyBCQ): `k` planes + `k` fp16 scales per group;
//! * vector-quantized (VPTQ): `b·vdim`-bit codes per sub-vector + shared
//!   codebook + fp16 outlier columns.
//!
//! Bit-planes are packed 32 columns per `u32` word — the layout the
//! [`crate::lut`] GEMV kernel consumes directly.

use crate::tensor::Matrix;

/// One packed bit-plane: `d_out × ceil(d_in/32)` u32 words, bit `j%32` of
/// word `j/32` = plane value at column `j`.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedPlane {
    pub d_out: usize,
    pub d_in: usize,
    pub words: Vec<u32>,
}

impl PackedPlane {
    pub fn words_per_row(&self) -> usize {
        self.d_in.div_ceil(32)
    }

    /// Pack from a dense 0/1 matrix.
    pub fn pack(plane: &Matrix) -> Self {
        let (d_out, d_in) = plane.shape();
        let wpr = d_in.div_ceil(32);
        let mut words = vec![0u32; d_out * wpr];
        for r in 0..d_out {
            let row = plane.row(r);
            for (j, &v) in row.iter().enumerate() {
                debug_assert!(v == 0.0 || v == 1.0, "plane value {v} not binary");
                if v != 0.0 {
                    words[r * wpr + j / 32] |= 1 << (j % 32);
                }
            }
        }
        Self { d_out, d_in, words }
    }

    /// Unpack to a dense 0/1 matrix.
    pub fn unpack(&self) -> Matrix {
        let wpr = self.words_per_row();
        let mut m = Matrix::zeros(self.d_out, self.d_in);
        for r in 0..self.d_out {
            let row = m.row_mut(r);
            for (j, v) in row.iter_mut().enumerate() {
                let w = self.words[r * wpr + j / 32];
                *v = ((w >> (j % 32)) & 1) as f32;
            }
        }
        m
    }

    #[inline]
    pub fn bit(&self, r: usize, j: usize) -> bool {
        let wpr = self.words_per_row();
        (self.words[r * wpr + j / 32] >> (j % 32)) & 1 == 1
    }

    /// Row slice of packed words (for the LUT kernel).
    #[inline]
    pub fn row_words(&self, r: usize) -> &[u32] {
        let wpr = self.words_per_row();
        &self.words[r * wpr..(r + 1) * wpr]
    }
}

/// BPDQ packed record: Ŵ = REP(C₀) + Σᵢ REP(Cᵢ) ⊙ Bᵢ  (paper Eq. 1).
#[derive(Clone, Debug)]
pub struct BitPlanePacked {
    pub d_out: usize,
    pub d_in: usize,
    pub group_size: usize,
    /// k packed planes, most-significant first.
    pub planes: Vec<PackedPlane>,
    /// (k+1) coefficient matrices, each d_out × n_groups; index 0 is the
    /// bias C₀.
    pub coeffs: Vec<Matrix>,
    /// bits charged per stored coefficient (16 = fp16, the paper's format)
    pub coeff_bits: usize,
}

impl BitPlanePacked {
    pub fn k(&self) -> usize {
        self.planes.len()
    }

    pub fn n_groups(&self) -> usize {
        self.d_in.div_ceil(self.group_size)
    }

    /// Dequantize to dense f32.
    pub fn dequant(&self) -> Matrix {
        let mut w = Matrix::zeros(self.d_out, self.d_in);
        let g = self.group_size;
        for r in 0..self.d_out {
            let row = w.row_mut(r);
            for (j, v) in row.iter_mut().enumerate() {
                let grp = j / g;
                let mut acc = self.coeffs[0].get(r, grp);
                for (i, plane) in self.planes.iter().enumerate() {
                    if plane.bit(r, j) {
                        acc += self.coeffs[i + 1].get(r, grp);
                    }
                }
                *v = acc;
            }
        }
        w
    }

    pub fn total_bits(&self) -> usize {
        let plane_bits = self.k() * self.d_out * self.d_in;
        let coeff_bits = (self.k() + 1) * self.d_out * self.n_groups() * self.coeff_bits;
        plane_bits + coeff_bits
    }
}

/// Uniform packed record (RTN/GPTQ/AWQ): per group-row fp16 scale +
/// b-bit zero point; codes b bits each.
#[derive(Clone, Debug)]
pub struct UniformPacked {
    pub d_out: usize,
    pub d_in: usize,
    pub group_size: usize,
    pub bits: u8,
    /// codes, row-major, one u8 per weight (stored widened; the *charged*
    /// size is `bits` per code)
    pub codes: Vec<u8>,
    /// d_out × n_groups fp16 scales (stored widened)
    pub scales: Matrix,
    /// d_out × n_groups integer zero-points
    pub zeros: Vec<u8>,
    /// If the channels were permuted before quantization (desc_act), the
    /// inverse permutation needed at inference time.
    pub inv_perm: Option<Vec<usize>>,
}

impl UniformPacked {
    pub fn n_groups(&self) -> usize {
        self.d_in.div_ceil(self.group_size)
    }

    pub fn dequant(&self) -> Matrix {
        let mut w = Matrix::zeros(self.d_out, self.d_in);
        let g = self.group_size;
        let ng = self.n_groups();
        for r in 0..self.d_out {
            let row = w.row_mut(r);
            for (j, v) in row.iter_mut().enumerate() {
                let grp = j / g;
                let s = self.scales.get(r, grp);
                let z = self.zeros[r * ng + grp] as f32;
                let q = self.codes[r * self.d_in + j] as f32;
                *v = s * (q - z);
            }
        }
        match &self.inv_perm {
            Some(p) => w.permute_cols(p),
            None => w,
        }
    }

    pub fn total_bits(&self) -> usize {
        let code_bits = self.d_out * self.d_in * self.bits as usize;
        let meta_bits = self.d_out * self.n_groups() * (16 + self.bits as usize);
        code_bits + meta_bits
    }
}

/// VPTQ packed record: codebook indices + shared codebook + fp16 outlier
/// columns.
#[derive(Clone, Debug)]
pub struct VqPacked {
    pub d_out: usize,
    pub d_in: usize,
    pub vdim: usize,
    pub bits: u8,
    /// codebook: (2^(bits·vdim)) × vdim entries, fp16-charged
    pub codebook: Matrix,
    /// per sub-vector codebook index
    pub codes: Vec<u16>,
    /// columns stored in fp16 (outlier protection), ascending
    pub outlier_cols: Vec<usize>,
    /// d_out × outlier_cols.len() fp16 values
    pub outliers: Matrix,
}

impl VqPacked {
    pub fn index_bits(&self) -> usize {
        (self.bits as usize) * self.vdim
    }

    pub fn total_bits(&self) -> usize {
        let n_sub = self.d_out * (self.d_in - self.outlier_cols.len()).div_ceil(self.vdim);
        let code_bits = n_sub * self.index_bits();
        let book_bits = self.codebook.rows() * self.codebook.cols() * 16;
        let outlier_bits = self.d_out * self.outlier_cols.len() * 16
            + self.outlier_cols.len() * 32; // column indices
        code_bits + book_bits + outlier_bits
    }
}

/// The tagged union every quantizer returns.
#[derive(Clone, Debug)]
pub enum PackedWeights {
    Fp16 { total_bits: usize },
    Uniform(UniformPacked),
    BitPlanes(BitPlanePacked),
    Vq(VqPacked),
}

impl PackedWeights {
    pub fn total_bits(&self) -> usize {
        match self {
            PackedWeights::Fp16 { total_bits } => *total_bits,
            PackedWeights::Uniform(p) => p.total_bits(),
            PackedWeights::BitPlanes(p) => p.total_bits(),
            PackedWeights::Vq(p) => p.total_bits(),
        }
    }

    /// The bit-plane record, if this is one (LUT serving path).
    pub fn as_bit_planes(&self) -> Option<&BitPlanePacked> {
        match self {
            PackedWeights::BitPlanes(p) => Some(p),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn plane_pack_roundtrip() {
        let mut rng = Rng::new(1);
        for &(r, c) in &[(3, 7), (4, 32), (5, 33), (2, 100)] {
            let m = Matrix::from_vec(
                r,
                c,
                (0..r * c).map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 }).collect(),
            );
            let p = PackedPlane::pack(&m);
            assert_eq!(p.unpack(), m, "{r}x{c}");
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(p.bit(i, j), m.get(i, j) == 1.0);
                }
            }
        }
    }

    #[test]
    fn bpw_matches_paper_table() {
        // Helper constructing an empty record of the right shape.
        let rec = |k: usize, g: usize, d_out: usize, d_in: usize| BitPlanePacked {
            d_out,
            d_in,
            group_size: g,
            planes: (0..k).map(|_| PackedPlane::pack(&Matrix::zeros(d_out, d_in))).collect(),
            coeffs: (0..k + 1).map(|_| Matrix::zeros(d_out, d_in.div_ceil(g))).collect(),
            coeff_bits: 16,
        };
        let bpw = |k: usize, g: usize| {
            let r = rec(k, g, 4, 1024);
            r.total_bits() as f64 / (4.0 * 1024.0)
        };
        assert!((bpw(2, 64) - 2.75).abs() < 1e-9); // paper BPDQ-W2-G64
        assert!((bpw(2, 128) - 2.375).abs() < 1e-9); // paper 2.38
        assert!((bpw(2, 256) - 2.1875).abs() < 1e-9); // paper 2.19
        assert!((bpw(3, 64) - 4.0).abs() < 1e-9); // paper 4.00
        assert!((bpw(3, 128) - 3.5).abs() < 1e-9); // paper 3.50
        assert!((bpw(4, 128) - 4.625).abs() < 1e-9); // paper 4.63
    }

    #[test]
    fn uniform_bpw_matches_paper_table() {
        let rec = |bits: u8, g: usize, d_out: usize, d_in: usize| UniformPacked {
            d_out,
            d_in,
            group_size: g,
            bits,
            codes: vec![0; d_out * d_in],
            scales: Matrix::zeros(d_out, d_in.div_ceil(g)),
            zeros: vec![0; d_out * d_in.div_ceil(g)],
            inv_perm: None,
        };
        let bpw = |bits: u8, g: usize| {
            let r = rec(bits, g, 4, 1024);
            r.total_bits() as f64 / (4.0 * 1024.0)
        };
        assert!((bpw(2, 64) - 2.28125).abs() < 1e-9); // paper 2.28
        assert!((bpw(2, 32) - 2.5625).abs() < 1e-9); // paper 2.56
        assert!((bpw(3, 32) - 3.59375).abs() < 1e-9); // paper 3.59
        assert!((bpw(3, 64) - 3.296875).abs() < 1e-9); // paper 3.30
        assert!((bpw(4, 64) - 4.3125).abs() < 1e-9); // paper 4.31
    }

    #[test]
    fn bitplane_dequant_formula() {
        // 1 row, 4 cols, g=2, k=2: Ŵ = c0 + c1·B1 + c2·B2 per group.
        let b1 = Matrix::from_vec(1, 4, vec![1., 0., 1., 1.]);
        let b2 = Matrix::from_vec(1, 4, vec![0., 1., 1., 0.]);
        let rec = BitPlanePacked {
            d_out: 1,
            d_in: 4,
            group_size: 2,
            planes: vec![PackedPlane::pack(&b1), PackedPlane::pack(&b2)],
            coeffs: vec![
                Matrix::from_vec(1, 2, vec![0.5, -1.0]), // c0 per group
                Matrix::from_vec(1, 2, vec![2.0, 3.0]),  // c1
                Matrix::from_vec(1, 2, vec![10.0, 20.0]), // c2
            ],
            coeff_bits: 16,
        };
        let w = rec.dequant();
        // col0: g0, b1=1,b2=0 → 0.5+2 = 2.5
        // col1: g0, b1=0,b2=1 → 0.5+10 = 10.5
        // col2: g1, b1=1,b2=1 → -1+3+20 = 22
        // col3: g1, b1=1,b2=0 → -1+3 = 2
        assert_eq!(w.row(0), &[2.5, 10.5, 22.0, 2.0]);
    }

    #[test]
    fn uniform_dequant_with_perm() {
        // 1 row, 4 cols, g=4, scale 2, zero 1, codes [0,1,2,3],
        // quantized in permuted order [2,0,3,1].
        let packed = UniformPacked {
            d_out: 1,
            d_in: 4,
            group_size: 4,
            bits: 2,
            codes: vec![0, 1, 2, 3],
            scales: Matrix::from_vec(1, 1, vec![2.0]),
            zeros: vec![1],
            inv_perm: Some(vec![1, 3, 0, 2]), // inverse of [2,0,3,1]
        };
        let w = packed.dequant();
        // dequant codes → [-2, 0, 2, 4] in permuted space; unpermute
        assert_eq!(w.row(0), &[0.0, 4.0, -2.0, 2.0]);
    }
}
