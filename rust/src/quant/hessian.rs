//! Hessian estimation and the propagation factor `U = chol(H⁻¹)`.
//!
//! The optimization objective (paper Eq. 2) is
//! `argmin ‖(W−Ŵ)X‖²_F = argmin tr((W−Ŵ) H (W−Ŵ)ᵀ)` with `H = XXᵀ`.
//! GPTQ and BPDQ both work in the geometry of the upper-triangular
//! Cholesky factor of the *inverse* Hessian (`H⁻¹ = UᵀU`), propagating
//! per-column quantization error into not-yet-quantized columns via
//! triangular updates (Eqs. 3–4).

use crate::linalg::{damp_in_place, inv_upper_factor};
use crate::tensor::{Matrix, MatrixF64};
use anyhow::{Context, Result};

/// GPTQ "percdamp" convention: damping added to H is `alpha * mean(diag)`.
pub const DEFAULT_HESSIAN_DAMP: f64 = 1e-2;

/// Accumulated second-order statistics for one linear layer's input.
#[derive(Clone, Debug)]
pub struct HessianState {
    h: MatrixF64,
    n_samples: usize,
}

impl HessianState {
    pub fn new(dim: usize) -> Self {
        Self { h: MatrixF64::zeros(dim, dim), n_samples: 0 }
    }

    /// Build directly from an activation matrix (n_samples × d_in).
    pub fn from_activations(x: &Matrix) -> Self {
        let mut s = Self::new(x.cols());
        s.accumulate(x);
        s
    }

    /// Accumulate `H += XᵀX` over a batch of rows (streaming, so
    /// calibration never materializes all activations at once).
    pub fn accumulate(&mut self, x: &Matrix) {
        assert_eq!(x.cols(), self.h.rows(), "activation dim mismatch");
        let d = x.cols();
        for r in 0..x.rows() {
            let row = x.row(r);
            for i in 0..d {
                let xi = row[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                let hrow = self.h.row_mut(i);
                for j in 0..d {
                    hrow[j] += xi * row[j] as f64;
                }
            }
        }
        self.n_samples += x.rows();
    }

    pub fn dim(&self) -> usize {
        self.h.rows()
    }

    pub fn n_samples(&self) -> usize {
        self.n_samples
    }

    /// The raw (undamped) Hessian.
    pub fn matrix(&self) -> &MatrixF64 {
        &self.h
    }

    /// Hessian diagonal — the per-channel saliency used by `desc_act`,
    /// GAR, AWQ scaling, and VPTQ's weighted k-means.
    pub fn diag(&self) -> Vec<f64> {
        (0..self.dim()).map(|i| self.h.get(i, i)).collect()
    }

    /// Damped copy of H (symmetrized, `alpha·mean(diag)` added).
    pub fn damped(&self, alpha: f64) -> MatrixF64 {
        let mut h = self.h.clone();
        damp_in_place(&mut h, alpha);
        h
    }

    /// The propagation factor: upper-triangular `U` with `H⁻¹ = UᵀU`,
    /// after applying the column permutation `perm` (channel reordering
    /// must permute H *before* factoring — the factor is order-dependent).
    pub fn factor(&self, alpha: f64, perm: Option<&[usize]>) -> Result<MatrixF64> {
        let mut h = match perm {
            Some(p) => {
                assert_eq!(p.len(), self.dim());
                self.h.permute_rows(p).permute_cols(p)
            }
            None => self.h.clone(),
        };
        damp_in_place(&mut h, alpha);
        inv_upper_factor(&h).context("factor damped hessian")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::matmul_f64;

    #[test]
    fn accumulate_matches_xtx() {
        let mut rng = Rng::new(1);
        let (n, d) = (20, 6);
        let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.normal() as f32).collect());
        let hs = HessianState::from_activations(&x);
        let xf = x.to_f64();
        let want = matmul_f64(&xf.transpose(), &xf);
        for i in 0..d {
            for j in 0..d {
                assert!(
                    (hs.matrix().get(i, j) - want.get(i, j)).abs() < 1e-6,
                    "({i},{j})"
                );
            }
        }
        assert_eq!(hs.n_samples(), n);
    }

    #[test]
    fn streaming_equals_batch() {
        let mut rng = Rng::new(2);
        let (n, d) = (24, 5);
        let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.normal() as f32).collect());
        let whole = HessianState::from_activations(&x);
        let mut streamed = HessianState::new(d);
        streamed.accumulate(&x.col_block(0, d).permute_rows(&(0..n).collect::<Vec<_>>()));
        // chunked
        let mut chunked = HessianState::new(d);
        let rows: Vec<Vec<f32>> = (0..n).map(|r| x.row(r).to_vec()).collect();
        for chunk in rows.chunks(7) {
            let flat: Vec<f32> = chunk.iter().flatten().copied().collect();
            chunked.accumulate(&Matrix::from_vec(chunk.len(), d, flat));
        }
        for i in 0..d {
            for j in 0..d {
                assert!((whole.matrix().get(i, j) - chunked.matrix().get(i, j)).abs() < 1e-6);
                assert!((whole.matrix().get(i, j) - streamed.matrix().get(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn factor_is_upper_and_valid() {
        let mut rng = Rng::new(3);
        let (n, d) = (40, 8);
        let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.normal() as f32).collect());
        let hs = HessianState::from_activations(&x);
        let u = hs.factor(1e-2, None).unwrap();
        for i in 0..d {
            assert!(u.get(i, i) > 0.0);
            for j in 0..i {
                assert_eq!(u.get(i, j), 0.0);
            }
        }
        // UᵀU ≈ H_damped⁻¹  ⇔  UᵀU H_damped ≈ I
        let hd = hs.damped(1e-2);
        let uu = matmul_f64(&u.transpose(), &u);
        let prod = matmul_f64(&uu, &hd);
        for i in 0..d {
            for j in 0..d {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.get(i, j) - want).abs() < 1e-6, "({i},{j})");
            }
        }
    }

    #[test]
    fn factor_with_permutation_consistent() {
        let mut rng = Rng::new(4);
        let (n, d) = (30, 6);
        let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.normal() as f32).collect());
        let hs = HessianState::from_activations(&x);
        let perm: Vec<usize> = vec![3, 1, 5, 0, 2, 4];
        let u = hs.factor(1e-2, Some(&perm)).unwrap();
        // should equal factoring the permuted activations directly
        let xp = x.permute_cols(&perm);
        let hsp = HessianState::from_activations(&xp);
        let up = hsp.factor(1e-2, None).unwrap();
        for i in 0..d {
            for j in 0..d {
                assert!((u.get(i, j) - up.get(i, j)).abs() < 1e-8, "({i},{j})");
            }
        }
    }

    #[test]
    fn dead_channels_survive_damping() {
        // A channel that is always zero ⇒ zero row/col in H; damping must
        // still produce a factorable matrix.
        let x = Matrix::from_vec(4, 3, vec![1., 0., 2., -1., 0., 1., 2., 0., 0., 1., 0., 1.]);
        let hs = HessianState::from_activations(&x);
        assert!(hs.factor(1e-2, None).is_ok());
    }
}
