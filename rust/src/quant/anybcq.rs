//! AnyBCQ-style binary-coded quantization (Park et al., 2025) — the
//! paper's fellow bit-plane baseline.
//!
//! `Ŵ_r ≈ Σᵢ aᵢ bᵢ` with `bᵢ ∈ {−1,+1}^g` and per-(row,group) scales
//! `aᵢ`: greedy residual binarization init, then round-robin alternating
//! refinement (codes ⇄ scales). Crucially — and this is what the paper
//! contrasts BPDQ against — there is **no Hessian / output-aligned
//! objective and no cross-column error propagation**; the fit is plain
//! least squares on the weights.

use super::packing::{BitPlanePacked, PackedPlane, PackedWeights};
use super::BcqConfig;
use crate::tensor::Matrix;

pub fn quantize(w: &Matrix, cfg: BcqConfig) -> (Matrix, PackedWeights) {
    let (d_out, d_in) = w.shape();
    let g = cfg.group_size;
    let k = cfg.bits as usize;
    let ng = d_in.div_ceil(g);

    // signs[i] ∈ {−1,+1}, stored dense during optimization.
    let mut signs: Vec<Matrix> = (0..k).map(|_| Matrix::zeros(d_out, d_in)).collect();
    let mut scales: Vec<Matrix> = (0..k).map(|_| Matrix::zeros(d_out, ng)).collect();

    let mut resid = vec![0.0f32; g];
    for r in 0..d_out {
        for grp in 0..ng {
            let c0 = grp * g;
            let c1 = (c0 + g).min(d_in);
            let gw = c1 - c0;
            let wrow = &w.row(r)[c0..c1];

            // --- greedy residual init ---
            resid[..gw].copy_from_slice(wrow);
            for i in 0..k {
                let a = resid[..gw].iter().map(|v| v.abs() as f64).sum::<f64>() / gw as f64;
                scales[i].set(r, grp, a as f32);
                for j in 0..gw {
                    let s = if resid[j] >= 0.0 { 1.0f32 } else { -1.0 };
                    signs[i].set(r, c0 + j, s);
                    resid[j] -= a as f32 * s;
                }
            }

            // --- alternating refinement ---
            for _ in 0..cfg.alt_iters {
                // (1) given signs, least-squares scales: solve Gᵀ a = Gᵀ w
                // where G[:,i] = signs_i. k ≤ 4 ⇒ tiny normal equations.
                let mut gtg = vec![0.0f64; k * k];
                let mut gtw = vec![0.0f64; k];
                for j in 0..gw {
                    for i in 0..k {
                        let si = signs[i].get(r, c0 + j) as f64;
                        gtw[i] += si * wrow[j] as f64;
                        for l in i..k {
                            gtg[i * k + l] += si * signs[l].get(r, c0 + j) as f64;
                        }
                    }
                }
                // symmetric fill + tiny ridge
                for i in 0..k {
                    for l in 0..i {
                        gtg[i * k + l] = gtg[l * k + i];
                    }
                    gtg[i * k + i] += 1e-8;
                }
                if let Some(a) = solve_small(&gtg, &gtw, k) {
                    for i in 0..k {
                        scales[i].set(r, grp, a[i] as f32);
                    }
                }
                // (2) given scales, update signs plane-by-plane greedily.
                for i in 0..k {
                    let ai = scales[i].get(r, grp);
                    if ai == 0.0 {
                        continue;
                    }
                    for j in 0..gw {
                        // residual excluding plane i
                        let mut rj = wrow[j];
                        for l in 0..k {
                            if l != i {
                                rj -= scales[l].get(r, grp) * signs[l].get(r, c0 + j);
                            }
                        }
                        signs[i].set(r, c0 + j, if rj * ai >= 0.0 { 1.0 } else { -1.0 });
                    }
                }
            }
        }
    }

    // Dequant + convert ±1 planes to the {0,1} bit-plane format:
    //   a·s = a·(2b−1) = −a + 2a·b  ⇒ c₀ = −Σᵢ aᵢ, cᵢ = 2aᵢ, bᵢ=(sᵢ+1)/2.
    let mut deq = Matrix::zeros(d_out, d_in);
    for r in 0..d_out {
        for j in 0..d_in {
            let grp = j / g;
            let mut v = 0.0f32;
            for i in 0..k {
                v += scales[i].get(r, grp) * signs[i].get(r, j);
            }
            deq.set(r, j, v);
        }
    }
    let planes: Vec<PackedPlane> = (0..k)
        .map(|i| {
            let b = signs[i].map(|s| if s > 0.0 { 1.0 } else { 0.0 });
            PackedPlane::pack(&b)
        })
        .collect();
    let mut coeffs: Vec<Matrix> = Vec::with_capacity(k + 1);
    let mut c0 = Matrix::zeros(d_out, ng);
    for r in 0..d_out {
        for grp in 0..ng {
            let s: f32 = (0..k).map(|i| scales[i].get(r, grp)).sum();
            c0.set(r, grp, -s);
        }
    }
    coeffs.push(c0);
    for s in &scales {
        coeffs.push(s.map(|a| 2.0 * a));
    }
    // AnyBCQ stores k scales per group (the bias is implied by the ±1
    // format), so charge k (not k+1) coefficients: adjust by using
    // coeff_bits scaled — simplest is to keep the (k+1) layout for the
    // LUT kernel but charge the storage the format actually needs.
    let packed = BitPlanePacked {
        d_out,
        d_in,
        group_size: g,
        planes,
        coeffs,
        // k fp16 scales per group charged over (k+1) stored tensors:
        // 16·k/(k+1) bits each keeps total == 16·k exactly.
        coeff_bits: 16 * k / (k + 1) + usize::from(16 * k % (k + 1) != 0),
    };
    (deq, PackedWeights::BitPlanes(packed))
}

/// Solve a tiny dense symmetric system via Gaussian elimination with
/// partial pivoting. Returns None if singular.
fn solve_small(a_in: &[f64], b_in: &[f64], n: usize) -> Option<Vec<f64>> {
    let mut a = a_in.to_vec();
    let mut b = b_in.to_vec();
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in (col + 1)..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if a[piv * n + col].abs() < 1e-12 {
            return None;
        }
        if piv != col {
            for j in 0..n {
                a.swap(col * n + j, piv * n + j);
            }
            b.swap(col, piv);
        }
        let d = a[col * n + col];
        for r in (col + 1)..n {
            let f = a[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for j in col..n {
                a[r * n + j] -= f * a[col * n + j];
            }
            b[r] -= f * b[col];
        }
    }
    // back substitution
    let mut x = vec![0.0; n];
    for r in (0..n).rev() {
        let mut s = b[r];
        for j in (r + 1)..n {
            s -= a[r * n + j] * x[j];
        }
        x[r] = s / a[r * n + r];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::test_util::rand_wx;
    use crate::quant::{quantize_linear, QuantMethod, UniformConfig};

    #[test]
    fn solve_small_correct() {
        // 2x2: [[2,1],[1,3]] x = [5, 10] → x = [1, 3]
        let x = solve_small(&[2., 1., 1., 3.], &[5., 10.], 2).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12 && (x[1] - 3.0).abs() < 1e-12);
        assert!(solve_small(&[0., 0., 0., 0.], &[1., 1.], 2).is_none());
    }

    #[test]
    fn packed_dequant_matches_dense() {
        let (w, _x) = rand_wx(41, 8, 64, 4);
        let (deq, packed) = quantize(&w, BcqConfig { bits: 2, group_size: 32, alt_iters: 4 });
        if let PackedWeights::BitPlanes(p) = &packed {
            assert!(deq.fro_dist(&p.dequant()) < 1e-4);
        } else {
            panic!("wrong variant");
        }
    }

    #[test]
    fn alternating_reduces_weight_error() {
        let (w, _x) = rand_wx(42, 16, 96, 4);
        let e0 = {
            let (d, _) = quantize(&w, BcqConfig { bits: 2, group_size: 32, alt_iters: 0 });
            d.fro_dist(&w)
        };
        let e6 = {
            let (d, _) = quantize(&w, BcqConfig { bits: 2, group_size: 32, alt_iters: 6 });
            d.fro_dist(&w)
        };
        assert!(e6 <= e0 * 1.0001, "alt {e6} > greedy {e0}");
    }

    #[test]
    fn bcq_beats_rtn_weight_error_at_2bit() {
        // BCQ's ±1 planes with LS scales are a strictly richer per-group
        // family than the 4-level uniform grid for heavy-tailed rows.
        let (w, x) = rand_wx(43, 24, 128, 32);
        let q_b = quantize_linear(
            &w,
            &x,
            QuantMethod::AnyBcq(BcqConfig { bits: 2, group_size: 32, alt_iters: 6 }),
        )
        .unwrap();
        let q_r = quantize_linear(
            &w,
            &x,
            QuantMethod::Rtn(UniformConfig { bits: 2, group_size: 32, act_order: false }),
        )
        .unwrap();
        assert!(
            q_b.stats.weight_err < q_r.stats.weight_err,
            "bcq {} !< rtn {}",
            q_b.stats.weight_err,
            q_r.stats.weight_err
        );
    }

    #[test]
    fn no_hessian_use_means_worse_output_err_than_bpdq() {
        // The paper's Table 2 ordering at 2-bit: BPDQ < AnyBCQ on quality.
        let (w, x) = rand_wx(44, 24, 128, 96);
        let e_bcq = quantize_linear(
            &w,
            &x,
            QuantMethod::AnyBcq(BcqConfig { bits: 2, group_size: 64, alt_iters: 6 }),
        )
        .unwrap()
        .stats
        .output_err;
        let e_bpdq = quantize_linear(
            &w,
            &x,
            QuantMethod::Bpdq(crate::quant::BpdqConfig {
                k: 2,
                group_size: 64,
                ..Default::default()
            }),
        )
        .unwrap()
        .stats
        .output_err;
        assert!(e_bpdq < e_bcq, "bpdq {e_bpdq} !< bcq {e_bcq}");
    }
}
