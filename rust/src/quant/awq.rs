//! AWQ (Lin et al., 2024) — activation-aware weight quantization.
//!
//! No Hessian propagation: instead, per-channel scales `s_j = a_j^α`
//! (a_j = mean activation magnitude of channel j) are grid-searched over
//! α ∈ [0,1] to minimize the output error of RTN-quantizing the scaled
//! weights. Salient (high-activation) channels get their weights
//! magnified before rounding and shrunk after, reducing their relative
//! rounding error — "outlier protection" without mixed precision.
//!
//! This matches the paper's characterization: competitive at 3–4 bits,
//! collapses at 2 bits because protecting outliers cannot compensate a
//! 4-level grid (Table 1: AWQ-W2 ppl ≈ 10⁵–10⁷).

use super::hessian::HessianState;
use super::packing::{PackedWeights, UniformPacked};
use super::rtn::{dequant_code, fit_affine, quant_code};
use super::UniformConfig;
use crate::tensor::Matrix;

/// Number of α grid points searched (AWQ reference uses 20).
const ALPHA_GRID: usize = 20;

pub fn quantize(w: &Matrix, h: &HessianState, cfg: UniformConfig) -> (Matrix, PackedWeights) {
    let (d_out, d_in) = w.shape();

    // Per-channel activation magnitude proxy: sqrt of the Hessian
    // diagonal = RMS activation per channel.
    let diag = h.diag();
    let n = h.n_samples().max(1) as f64;
    let act_rms: Vec<f64> = diag.iter().map(|&d| (d / n).sqrt().max(1e-8)).collect();

    // Grid-search α; score = Hessian-diagonal-weighted reconstruction
    // error (the AWQ paper's fast proxy for ‖(W−Ŵ)X‖²).
    let mut best: Option<(f64, Matrix, UniformPacked)> = None;
    for ai in 0..ALPHA_GRID {
        let alpha = ai as f64 / (ALPHA_GRID - 1) as f64;
        let scales: Vec<f32> = act_rms.iter().map(|&a| (a.powf(alpha)) as f32).collect();
        // Normalize so the scales have geometric mean 1 (keeps the grid
        // range stable).
        let log_mean =
            scales.iter().map(|&s| (s as f64).ln()).sum::<f64>() / d_in as f64;
        let norm = (log_mean).exp() as f32;
        let scales: Vec<f32> = scales.iter().map(|&s| s / norm).collect();

        let (deq, packed) = rtn_scaled(w, &scales, cfg);
        // weighted error
        let mut err = 0.0f64;
        for r in 0..d_out {
            let wr = w.row(r);
            let dr = deq.row(r);
            for j in 0..d_in {
                let d = (wr[j] - dr[j]) as f64;
                err += diag[j] * d * d;
            }
        }
        if best.as_ref().map_or(true, |(e, _, _)| err < *e) {
            best = Some((err, deq, packed));
        }
    }
    let (_, deq, packed) = best.unwrap();
    (deq, PackedWeights::Uniform(packed))
}

/// RTN on the column-scaled weights; dequant folds the scales back.
fn rtn_scaled(w: &Matrix, scales: &[f32], cfg: UniformConfig) -> (Matrix, UniformPacked) {
    let (d_out, d_in) = w.shape();
    let g = cfg.group_size;
    let ng = d_in.div_ceil(g);
    let mut codes = vec![0u8; d_out * d_in];
    let mut gscales = Matrix::zeros(d_out, ng);
    let mut zeros = vec![0u8; d_out * ng];
    let mut deq = Matrix::zeros(d_out, d_in);
    let mut scaled_row = vec![0.0f32; d_in];

    for r in 0..d_out {
        let wr = w.row(r);
        for j in 0..d_in {
            scaled_row[j] = wr[j] * scales[j];
        }
        for grp in 0..ng {
            let c0 = grp * g;
            let c1 = (c0 + g).min(d_in);
            let p = fit_affine(&scaled_row[c0..c1], cfg.bits);
            gscales.set(r, grp, p.scale);
            zeros[r * ng + grp] = p.zero;
            for j in c0..c1 {
                let q = quant_code(scaled_row[j], p, cfg.bits);
                codes[r * d_in + j] = q;
                // fold the AWQ channel scale back out
                deq.set(r, j, dequant_code(q, p) / scales[j]);
            }
        }
    }
    // NOTE on storage: at inference AWQ folds s_j into the *previous*
    // layer's output (LayerNorm scales), so the packed record charges the
    // same bits as plain uniform — matching the paper's identical BPW for
    // GPTQ and AWQ. The `UniformPacked::dequant` of this record returns
    // the *scaled* weights; the dense `deq` above is the source of truth
    // for evaluation.
    let packed = UniformPacked {
        d_out,
        d_in,
        group_size: g,
        bits: cfg.bits,
        codes,
        scales: gscales,
        zeros,
        inv_perm: None,
    };
    (deq, packed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::test_util::rand_wx;
    use crate::quant::{quantize_linear, QuantMethod};

    #[test]
    fn awq_beats_rtn_at_4bit_on_skewed_activations() {
        let (w, x) = rand_wx(21, 24, 128, 96);
        let cfg = UniformConfig { bits: 4, group_size: 32, act_order: false };
        let e_rtn = quantize_linear(&w, &x, QuantMethod::Rtn(cfg)).unwrap().stats.output_err;
        let e_awq = quantize_linear(&w, &x, QuantMethod::Awq(cfg)).unwrap().stats.output_err;
        assert!(e_awq < e_rtn, "awq {e_awq} !< rtn {e_rtn}");
    }

    #[test]
    fn awq_bpw_same_as_gptq() {
        let (w, x) = rand_wx(22, 4, 128, 16);
        let cfg = UniformConfig { bits: 3, group_size: 32, act_order: false };
        let a = quantize_linear(&w, &x, QuantMethod::Awq(cfg)).unwrap();
        let g = quantize_linear(&w, &x, QuantMethod::Gptq(cfg)).unwrap();
        assert_eq!(a.packed.total_bits(), g.packed.total_bits());
        assert!((a.bits_per_weight() - 3.59375).abs() < 1e-9);
    }

    #[test]
    fn awq_collapses_relative_to_gptq_at_2bit() {
        // The paper's central observation (Table 1): at 2-bit, AWQ's
        // outlier protection is not enough; GPTQ's Hessian propagation
        // wins on output error.
        let (w, x) = rand_wx(23, 32, 128, 128);
        let cfg = UniformConfig { bits: 2, group_size: 32, act_order: true };
        let e_awq = quantize_linear(&w, &x, QuantMethod::Awq(cfg)).unwrap().stats.output_err;
        let e_gptq = quantize_linear(&w, &x, QuantMethod::Gptq(cfg)).unwrap().stats.output_err;
        assert!(e_gptq < e_awq, "gptq {e_gptq} !< awq {e_awq}");
    }

    #[test]
    fn deterministic() {
        let (w, x) = rand_wx(24, 8, 64, 32);
        let cfg = UniformConfig { bits: 3, group_size: 32, act_order: false };
        let a = quantize_linear(&w, &x, QuantMethod::Awq(cfg)).unwrap();
        let b = quantize_linear(&w, &x, QuantMethod::Awq(cfg)).unwrap();
        assert_eq!(a.dequant, b.dequant);
    }
}
