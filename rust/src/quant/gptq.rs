//! GPTQ (Frantar et al., 2022) — the paper's primary optimization-based
//! baseline: fixed uniform grid + Hessian-aware column-wise error
//! propagation.
//!
//! Per column `l` (after optional `desc_act` channel reordering):
//! quantize on the group's affine grid, form the error coordinate
//! `E[:,l] = (W'[:,l] − Ŵ[:,l]) / U[l,l]` (paper Eq. 3) and propagate
//! `W'[:,l:] -= E[:,l] · U[l,l:]` (paper Eq. 4), with
//! `U = chol(H⁻¹)` upper-triangular.

use super::hessian::{HessianState, DEFAULT_HESSIAN_DAMP};
use super::packing::{PackedWeights, UniformPacked};
use super::rtn::{dequant_code, fit_affine, quant_code};
use super::UniformConfig;
use crate::tensor::Matrix;
use anyhow::Result;

/// Descending-argsort of the Hessian diagonal (GPTQ `desc_act`).
pub fn desc_act_perm(diag: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..diag.len()).collect();
    idx.sort_by(|&a, &b| diag[b].partial_cmp(&diag[a]).unwrap_or(std::cmp::Ordering::Equal));
    idx
}

/// Invert a permutation.
pub fn invert_perm(perm: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv[p] = i;
    }
    inv
}

pub fn quantize(
    w: &Matrix,
    h: &HessianState,
    cfg: UniformConfig,
) -> Result<(Matrix, PackedWeights)> {
    let (d_out, d_in) = w.shape();
    let g = cfg.group_size;
    let ng = d_in.div_ceil(g);

    // Channel reordering by Hessian saliency.
    let perm: Option<Vec<usize>> = cfg.act_order.then(|| desc_act_perm(&h.diag()));
    let u = h.factor(DEFAULT_HESSIAN_DAMP, perm.as_deref())?;
    let mut work = match &perm {
        Some(p) => w.permute_cols(p),
        None => w.clone(),
    };

    let mut codes = vec![0u8; d_out * d_in];
    let mut scales = Matrix::zeros(d_out, ng);
    let mut zeros = vec![0u8; d_out * ng];
    let mut deq = Matrix::zeros(d_out, d_in); // in permuted order
    // Per-row affine params of the current group.
    let mut params = vec![super::rtn::AffineParams { scale: 1.0, zero: 0 }; d_out];

    for l in 0..d_in {
        let grp = l / g;
        if l % g == 0 {
            // Derive the group grid from the *current working* weights —
            // the standard GPTQ implementation choice.
            let c1 = (l + g).min(d_in);
            for r in 0..d_out {
                let p = fit_affine(&work.row(r)[l..c1], cfg.bits);
                params[r] = p;
                scales.set(r, grp, p.scale);
                zeros[r * ng + grp] = p.zero;
            }
        }
        let ull = u.get(l, l);
        // Quantize column l and propagate the error to columns l+1.. .
        for r in 0..d_out {
            let wv = work.get(r, l);
            let q = quant_code(wv, params[r], cfg.bits);
            let dv = dequant_code(q, params[r]);
            codes[r * d_in + l] = q;
            deq.set(r, l, dv);
            let e = ((wv - dv) as f64 / ull) as f32;
            if e != 0.0 {
                let urow = u.row(l);
                let wrow = work.row_mut(r);
                for j in (l + 1)..d_in {
                    wrow[j] -= e * urow[j] as f32;
                }
            }
        }
    }

    // Undo the permutation for the dense dequant matrix.
    let inv = perm.as_ref().map(|p| invert_perm(p));
    let deq_orig = match &inv {
        Some(ip) => deq.permute_cols(ip),
        None => deq,
    };

    let packed = UniformPacked {
        d_out,
        d_in,
        group_size: g,
        bits: cfg.bits,
        codes,
        scales,
        zeros,
        inv_perm: inv,
    };
    Ok((deq_orig, PackedWeights::Uniform(packed)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::test_util::rand_wx;
    use crate::quant::{quantize_linear, QuantMethod};

    #[test]
    fn perm_helpers() {
        let diag = vec![1.0, 5.0, 3.0];
        let p = desc_act_perm(&diag);
        assert_eq!(p, vec![1, 2, 0]);
        let inv = invert_perm(&p);
        assert_eq!(inv, vec![2, 0, 1]);
    }

    #[test]
    fn gptq_beats_rtn_on_output_error() {
        // The whole point of Hessian-aware propagation.
        let (w, x) = rand_wx(11, 24, 128, 96);
        let cfg = UniformConfig { bits: 3, group_size: 32, act_order: true };
        let q_rtn = quantize_linear(&w, &x, QuantMethod::Rtn(cfg)).unwrap();
        let q_gptq = quantize_linear(&w, &x, QuantMethod::Gptq(cfg)).unwrap();
        assert!(
            q_gptq.stats.output_err < q_rtn.stats.output_err,
            "gptq {} !< rtn {}",
            q_gptq.stats.output_err,
            q_rtn.stats.output_err
        );
    }

    #[test]
    fn packed_dequant_matches_dense() {
        let (w, x) = rand_wx(12, 8, 64, 48);
        for act_order in [false, true] {
            let cfg = UniformConfig { bits: 2, group_size: 32, act_order };
            let q = quantize_linear(&w, &x, QuantMethod::Gptq(cfg)).unwrap();
            if let PackedWeights::Uniform(p) = &q.packed {
                assert!(
                    q.dequant.fro_dist(&p.dequant()) < 1e-5,
                    "act_order={act_order}"
                );
            } else {
                panic!("wrong variant");
            }
        }
    }

    #[test]
    fn act_order_helps_on_skewed_hessian() {
        let (w, x) = rand_wx(13, 16, 128, 96);
        let base = UniformConfig { bits: 2, group_size: 32, act_order: false };
        let ordered = UniformConfig { act_order: true, ..base };
        let e_plain = quantize_linear(&w, &x, QuantMethod::Gptq(base)).unwrap().stats.output_err;
        let e_ord = quantize_linear(&w, &x, QuantMethod::Gptq(ordered)).unwrap().stats.output_err;
        // On a strongly front-loaded Hessian (rand_wx has 1/(1+j) channel
        // scales), desc_act should not hurt much and usually helps.
        assert!(e_ord < e_plain * 1.35, "plain {e_plain} ordered {e_ord}");
    }

    #[test]
    fn bpw_matches_paper() {
        let (w, x) = rand_wx(14, 4, 128, 16);
        let q = quantize_linear(
            &w,
            &x,
            QuantMethod::Gptq(UniformConfig { bits: 2, group_size: 64, act_order: true }),
        )
        .unwrap();
        assert!((q.bits_per_weight() - 2.28125).abs() < 1e-9);
    }

    #[test]
    fn eight_bit_gptq_near_lossless() {
        let (w, x) = rand_wx(15, 8, 64, 48);
        let q = quantize_linear(
            &w,
            &x,
            QuantMethod::Gptq(UniformConfig { bits: 8, group_size: 32, act_order: false }),
        )
        .unwrap();
        // Not exactly lossless: error propagation moves working weights
        // off-grid mid-stream, but at 8 bits the residual is tiny.
        assert!(q.stats.weight_err < 1e-3 * w.fro_norm().powi(2), "{}", q.stats.weight_err);
    }
}
