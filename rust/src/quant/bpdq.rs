//! BPDQ — Bit-Plane Decomposition Quantization on a variable grid.
//!
//! The paper's method (§3), faithfully:
//!
//! 1. **Variable grid init** (§3.2): per group, 8-bit RTN → bit-plane
//!    decomposition `Z = Σ 2ⁱ Pᵢ` (Eq. 5), keep the `k` MSB planes;
//!    then the closed-form scalar-coefficient fit (Eq. 6) — a per-row
//!    weighted least squares whitened by `U_loc^{-T}`, damping α=1e-4.
//! 2. **Iteration** (§3.3), ×10 per group, retaining the iterate with the
//!    smallest group propagation error ‖E‖²_F:
//!    * *bit-plane update*: column-wise exact enumeration of all 2ᵏ bit
//!      vectors per element (Eqs. 7–8) with GPTQ-style error propagation
//!      (Eqs. 3–4) inside the group;
//!    * *coefficient refitting*: re-solve Eq. 6 with the updated planes;
//!    * *delta correction* (Eq. 9): `ΔE·U_loc = Ŵ_old − Ŵ_new`, keeping
//!      the propagation state consistent (Appendix B.3).
//! 3. After the group settles, its error propagates into the remaining
//!    columns through the global factor: `W'[:,tail] -= E·U[group,tail]`
//!    (Eq. 32).
//!
//! Channel ordering uses GAR (group-aware reordering) so that groups keep
//! their inference-time membership during scalar derivation.

use super::gar::gar_perm;
use super::gptq::invert_perm;
use super::hessian::{HessianState, DEFAULT_HESSIAN_DAMP};
use super::packing::{BitPlanePacked, PackedPlane, PackedWeights};
use super::rtn::fit_affine;
use crate::linalg::{solve_upper_transpose, wls};
use crate::tensor::{Matrix, MatrixF64};
use anyhow::Result;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BpdqConfig {
    /// Number of non-bias bit-planes (the "W2/W3/W4" in the tables).
    pub k: u8,
    pub group_size: usize,
    /// Refinement iterations per group (paper: 10 everywhere).
    pub iters: usize,
    /// WLS damping α (paper: 1e-4).
    pub damping: f64,
    /// Hessian damping (GPTQ percdamp convention).
    pub hessian_damp: f64,
    /// Use GAR channel reordering (paper: on).
    pub gar: bool,
}

impl Default for BpdqConfig {
    fn default() -> Self {
        Self {
            k: 2,
            group_size: 64,
            iters: 10,
            damping: 1e-4,
            hessian_damp: DEFAULT_HESSIAN_DAMP,
            gar: true,
        }
    }
}

/// Quantize `w` under Hessian state `h`. Returns (dequantized weights,
/// packed record), both in the ORIGINAL column order — the packed record
/// is self-contained for inference (no runtime permutation; GAR keeps
/// groups intact, see `quantize_full`).
pub fn quantize(w: &Matrix, h: &HessianState, cfg: BpdqConfig) -> Result<(Matrix, PackedWeights)> {
    let out = quantize_full(w, h, cfg)?;
    Ok((out.dequant, PackedWeights::BitPlanes(out.packed)))
}

/// Full output including internals used by tests and analysis.
pub struct BpdqOutput {
    /// Dequantized weights, original column order.
    pub dequant: Matrix,
    /// Packed record, original column order (self-contained).
    pub packed: BitPlanePacked,
    /// Propagation-error coordinates E (d_out × d_in, processing order).
    pub e_coords: Matrix,
    /// The permutation used (processing order → original channel).
    pub perm: Vec<usize>,
}

pub fn quantize_full(w: &Matrix, h: &HessianState, cfg: BpdqConfig) -> Result<BpdqOutput> {
    let (d_out, d_in) = w.shape();
    let g = cfg.group_size;
    let k = cfg.k as usize;
    assert!(k >= 1 && k <= 8, "k must be in 1..=8");
    let ng = d_in.div_ceil(g);

    let perm: Vec<usize> = if cfg.gar {
        gar_perm(&h.diag(), g)
    } else {
        (0..d_in).collect()
    };
    let u = h.factor(cfg.hessian_damp, Some(&perm))?;
    let mut work = w.permute_cols(&perm);

    let mut dequant_p = Matrix::zeros(d_out, d_in); // processing order
    let mut e_coords = Matrix::zeros(d_out, d_in);
    // planes in processing order, dense (packed at the end)
    let mut planes_dense: Vec<Matrix> = (0..k).map(|_| Matrix::zeros(d_out, d_in)).collect();
    let mut coeffs: Vec<Matrix> = (0..=k).map(|_| Matrix::zeros(d_out, ng)).collect();

    let mut scratch = GroupScratch::new(d_out, g, k);

    for grp in 0..ng {
        let s = grp * g;
        let e = (s + g).min(d_in);
        let gw = e - s;

        // Local triangular factor of this group.
        let mut u_loc = MatrixF64::zeros(gw, gw);
        for i in 0..gw {
            for j in i..gw {
                u_loc.set(i, j, u.get(s + i, s + j));
            }
        }

        // Working block at group entry — the fit target (Appendix B.1).
        let w0 = work.col_block(s, e);

        let gr = quantize_group(&w0, &u_loc, k, cfg.iters, cfg.damping, &mut scratch);

        // Record results.
        for r in 0..d_out {
            for j in 0..gw {
                dequant_p.set(r, s + j, gr.what.get(r, j));
                e_coords.set(r, s + j, gr.e.get(r, j));
                for i in 0..k {
                    planes_dense[i].set(r, s + j, if gr.bits[i].get(r, j) != 0.0 { 1.0 } else { 0.0 });
                }
            }
            for i in 0..=k {
                coeffs[i].set(r, grp, gr.coeffs.get(r, i));
            }
        }

        // Propagate the settled group's error into the tail columns
        // (Eq. 32): W'[:,tail] -= E_group · U[group, tail].
        if e < d_in {
            for r in 0..d_out {
                let erow = gr.e.row(r);
                for (jj, &ev) in erow.iter().enumerate() {
                    if ev == 0.0 {
                        continue;
                    }
                    let urow = u.row(s + jj);
                    let wrow = work.row_mut(r);
                    for t in e..d_in {
                        wrow[t] -= ev * urow[t] as f32;
                    }
                }
            }
        }
    }

    // Re-express planes and coefficients in ORIGINAL column order so the
    // packed record is self-contained (no inference-time permutation).
    // This is exactly why BPDQ uses GAR instead of desc_act: processing
    // groups coincide with original groups (within-group reorder only),
    // so un-permuting columns keeps every group contiguous and the
    // group-wise coefficients valid.
    let inv = invert_perm(&perm);
    let planes_orig: Vec<Matrix> =
        planes_dense.iter().map(|p| p.permute_cols(&inv)).collect();
    let mut coeffs_orig: Vec<Matrix> = (0..=k).map(|_| Matrix::zeros(d_out, ng)).collect();
    for proc_grp in 0..ng {
        // the original group this processing slot holds
        let orig_grp = perm[proc_grp * g] / g;
        for i in 0..=k {
            for r in 0..d_out {
                coeffs_orig[i].set(r, orig_grp, coeffs[i].get(r, proc_grp));
            }
        }
    }
    let packed = BitPlanePacked {
        d_out,
        d_in,
        group_size: g,
        planes: planes_orig.iter().map(PackedPlane::pack).collect(),
        coeffs: coeffs_orig,
        coeff_bits: 16,
    };

    Ok(BpdqOutput { dequant: dequant_p.permute_cols(&inv), packed, e_coords, perm })
}

/// Per-group scratch buffers (reused across groups — the quantizer inner
/// loop allocates nothing).
struct GroupScratch {
    /// candidate values per row: d_out × 2^k
    cand: Vec<f32>,
    /// whitened target
    b: Vec<f64>,
    col_buf: Vec<f64>,
}

impl GroupScratch {
    fn new(d_out: usize, g: usize, k: usize) -> Self {
        Self {
            cand: vec![0.0; d_out << k],
            b: vec![0.0; g],
            col_buf: vec![0.0; g],
        }
    }
}

/// Result of quantizing one group.
struct GroupResult {
    /// dequantized block (d_out × gw)
    what: Matrix,
    /// propagation error coordinates (d_out × gw)
    e: Matrix,
    /// k dense 0/1 planes (d_out × gw)
    bits: Vec<Matrix>,
    /// per-row coefficients (d_out × (k+1)), column 0 = bias
    coeffs: Matrix,
}

/// The BPDQ inner loop for one group (see module docs).
fn quantize_group(
    w0: &Matrix,
    u_loc: &MatrixF64,
    k: usize,
    iters: usize,
    damping: f64,
    scratch: &mut GroupScratch,
) -> GroupResult {
    let (d_out, gw) = w0.shape();
    let nk = 1usize << k;

    // ---- init: 8-bit RTN → MSB planes (§3.2) ----
    let mut bits: Vec<Matrix> = (0..k).map(|_| Matrix::zeros(d_out, gw)).collect();
    for r in 0..d_out {
        let row = w0.row(r);
        let p = fit_affine(row, 8);
        for (j, &wv) in row.iter().enumerate() {
            let z = super::rtn::quant_code(wv, p, 8) as u32;
            // keep the k most significant of the 8 planes:
            // B_i = P_{7-k+i}, i = 1..=k  (Eq. 5 / §3.2)
            for i in 0..k {
                let plane_idx = 7 - k + 1 + i; // P_{8-k}, …, P_7
                if (z >> plane_idx) & 1 == 1 {
                    bits[i].set(r, j, 1.0);
                }
            }
        }
    }

    // ---- closed-form coefficient fit (Eq. 6) ----
    let mut coeffs = fit_coeffs(w0, &bits, u_loc, damping, scratch);

    // State tracked across iterations.
    let mut best: Option<(f64, Matrix, Matrix, Vec<Matrix>, Matrix)> = None; // (err, what, e, bits, coeffs)

    let mut wl = Matrix::zeros(d_out, gw);
    let mut what = Matrix::zeros(d_out, gw);
    let mut e = Matrix::zeros(d_out, gw);

    for _iter in 0..iters.max(1) {
        // ---- bit-plane update: column-wise exact enumeration with error
        // propagation (Eqs. 3–4, 7–8) ----
        wl.data_mut().copy_from_slice(w0.data());
        // candidate table per row: v(b) = c0 + Σ cᵢ bᵢ  (Eq. 7)
        for r in 0..d_out {
            let crow = coeffs.row(r);
            let cand = &mut scratch.cand[r * nk..(r + 1) * nk];
            for (b, c) in cand.iter_mut().enumerate() {
                let mut v = crow[0];
                for i in 0..k {
                    if (b >> i) & 1 == 1 {
                        v += crow[i + 1];
                    }
                }
                *c = v;
            }
        }
        for j in 0..gw {
            let ujj = u_loc.get(j, j);
            for r in 0..d_out {
                let wv = wl.get(r, j);
                // argmin_b (w − v(b))²  (Eq. 8)
                let cand = &scratch.cand[r * nk..(r + 1) * nk];
                let mut best_b = 0usize;
                let mut best_d = f32::INFINITY;
                for (b, &v) in cand.iter().enumerate() {
                    let d = (wv - v) * (wv - v);
                    if d < best_d {
                        best_d = d;
                        best_b = b;
                    }
                }
                let v = cand[best_b];
                what.set(r, j, v);
                for i in 0..k {
                    bits[i].set(r, j, ((best_b >> i) & 1) as f32);
                }
                // error coordinate + in-group propagation (Eqs. 3–4)
                let ev = ((wv - v) as f64 / ujj) as f32;
                e.set(r, j, ev);
                if ev != 0.0 && j + 1 < gw {
                    let urow = u_loc.row(j);
                    let wrow = wl.row_mut(r);
                    for t in (j + 1)..gw {
                        wrow[t] -= ev * urow[t] as f32;
                    }
                }
            }
        }

        // ---- coefficient refitting (Eq. 6 with updated planes) ----
        let what_old = what.clone();
        coeffs = fit_coeffs(w0, &bits, u_loc, damping, scratch);
        // Ŵ_new = B·c with the refit coefficients.
        for r in 0..d_out {
            let crow = coeffs.row(r);
            for j in 0..gw {
                let mut v = crow[0];
                for i in 0..k {
                    if bits[i].get(r, j) != 0.0 {
                        v += crow[i + 1];
                    }
                }
                what.set(r, j, v);
            }
        }

        // ---- delta correction (Eq. 9): ΔE·U_loc = Ŵ_old − Ŵ_new ----
        // Per row: solve x·U_loc = d  ⇔  U_locᵀ xᵀ = dᵀ (forward subst).
        for r in 0..d_out {
            let d: Vec<f64> = (0..gw)
                .map(|j| (what_old.get(r, j) - what.get(r, j)) as f64)
                .collect();
            let dx = solve_upper_transpose(u_loc, &d).expect("u_loc nonsingular");
            let erow = e.row_mut(r);
            for j in 0..gw {
                erow[j] += dx[j] as f32;
            }
        }

        // ---- retain the best iterate by ‖E‖²_F (§3.3) ----
        let err = e.fro_norm().powi(2);
        if best.as_ref().map_or(true, |(be, ..)| err < *be) {
            best = Some((err, what.clone(), e.clone(), bits.clone(), coeffs.clone()));
        }
    }

    let (_, what, e, bits, coeffs) = best.unwrap();
    GroupResult { what, e, bits, coeffs }
}

/// Solve Eq. 6 for every row: c_r = argmin ‖U_loc^{-T}(B_r c − w_r)‖² + α‖c‖².
fn fit_coeffs(
    w0: &Matrix,
    bits: &[Matrix],
    u_loc: &MatrixF64,
    damping: f64,
    scratch: &mut GroupScratch,
) -> Matrix {
    let (d_out, gw) = w0.shape();
    let k = bits.len();
    let mut coeffs = Matrix::zeros(d_out, k + 1);

    // Exact-shape design matrix for this group (no per-row clone; see
    // EXPERIMENTS.md §Perf).
    let mut a = MatrixF64::zeros(gw, k + 1);
    // The ones column is row-independent: whiten it once per group.
    let ones_white =
        solve_upper_transpose(u_loc, &vec![1.0; gw]).expect("u_loc nonsingular");
    for j in 0..gw {
        a.set(j, 0, ones_white[j]);
    }

    // Whiten the plane columns per row: A[:,c] = U_loc^{-T} B[:,c].
    for r in 0..d_out {
        for col in 1..=k {
            for j in 0..gw {
                scratch.col_buf[j] = if bits[col - 1].get(r, j) != 0.0 { 1.0 } else { 0.0 };
            }
            let white = solve_upper_transpose(u_loc, &scratch.col_buf[..gw])
                .expect("u_loc nonsingular");
            for j in 0..gw {
                a.set(j, col, white[j]);
            }
        }
        for j in 0..gw {
            scratch.b[j] = w0.get(r, j) as f64;
        }
        let bw = solve_upper_transpose(u_loc, &scratch.b[..gw]).expect("u_loc nonsingular");

        // WLS over the (gw × k+1) whitened system.
        let c = wls(&a, &bw, damping).expect("wls solvable with damping");
        for (i, &ci) in c.iter().enumerate() {
            coeffs.set(r, i, ci as f32);
        }
    }
    coeffs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::test_util::rand_wx;
    use crate::quant::{quantize_linear, QuantMethod, UniformConfig};
    use crate::tensor::matmul_f64;

    fn cfg(k: u8, g: usize) -> BpdqConfig {
        BpdqConfig { k, group_size: g, iters: 10, ..Default::default() }
    }

    #[test]
    fn dequant_matches_packed() {
        // The packed record is self-contained in ORIGINAL column order.
        let (w, x) = rand_wx(31, 8, 64, 48);
        let h = HessianState::from_activations(&x);
        let out = quantize_full(&w, &h, cfg(2, 32)).unwrap();
        assert!(out.dequant.fro_dist(&out.packed.dequant()) < 1e-5);
    }

    #[test]
    fn propagation_invariant_holds() {
        // Global invariant (Appendix B.2/B.3): W_perm − Ŵ_perm = E · U.
        let (w, x) = rand_wx(32, 6, 64, 48);
        let h = HessianState::from_activations(&x);
        let c = cfg(2, 16);
        let out = quantize_full(&w, &h, c).unwrap();
        let u = h.factor(c.hessian_damp, Some(&out.perm)).unwrap();
        let w_perm = w.permute_cols(&out.perm).to_f64();
        let inv = invert_perm(&out.perm);
        let what_perm = out.dequant.permute_cols(&out.perm); // back to processing order? no:
        // dequant is in original order; permuting by perm gives processing order
        let what_perm = what_perm.to_f64();
        let eu = matmul_f64(&out.e_coords.to_f64(), &u);
        for r in 0..w.rows() {
            for j in 0..w.cols() {
                let resid = w_perm.get(r, j) - what_perm.get(r, j);
                assert!(
                    (resid - eu.get(r, j)).abs() < 2e-3 * (1.0 + resid.abs()),
                    "({r},{j}): resid {resid} vs EU {}",
                    eu.get(r, j)
                );
            }
        }
        let _ = inv;
    }

    #[test]
    fn variable_grid_reproduces_uniform_grid() {
        // Proposition 1 (Eq. 13): with c1=s, c2=2s the BPDQ grid equals
        // the UINT2 grid {0,s,2s,3s} exactly.
        use crate::quant::packing::{BitPlanePacked, PackedPlane};
        let s = 0.37f32;
        let b1 = Matrix::from_vec(1, 4, vec![0., 1., 0., 1.]); // LSB of 0..3
        let b2 = Matrix::from_vec(1, 4, vec![0., 0., 1., 1.]); // MSB of 0..3
        let rec = BitPlanePacked {
            d_out: 1,
            d_in: 4,
            group_size: 4,
            planes: vec![PackedPlane::pack(&b1), PackedPlane::pack(&b2)],
            coeffs: vec![
                Matrix::from_vec(1, 1, vec![0.0]),
                Matrix::from_vec(1, 1, vec![s]),
                Matrix::from_vec(1, 1, vec![2.0 * s]),
            ],
            coeff_bits: 16,
        };
        let w = rec.dequant();
        assert_eq!(w.row(0), &[0.0, s, 2.0 * s, 3.0 * s]);
    }

    #[test]
    fn bpdq_beats_gptq_at_2bit() {
        // The headline claim (Table 1, W2 rows): variable grid + iteration
        // beats the fixed uniform grid on the output-aligned objective.
        let (w, x) = rand_wx(33, 32, 128, 128);
        let e_gptq = quantize_linear(
            &w,
            &x,
            QuantMethod::Gptq(UniformConfig { bits: 2, group_size: 64, act_order: true }),
        )
        .unwrap()
        .stats
        .output_err;
        let e_bpdq = quantize_linear(&w, &x, QuantMethod::Bpdq(cfg(2, 64)))
            .unwrap()
            .stats
            .output_err;
        assert!(e_bpdq < e_gptq, "bpdq {e_bpdq} !< gptq {e_gptq}");
    }

    #[test]
    fn more_iters_do_not_hurt() {
        // Best-iterate retention makes error monotone in iteration count.
        let (w, x) = rand_wx(34, 12, 64, 64);
        let h = HessianState::from_activations(&x);
        let mut last = f64::INFINITY;
        for iters in [1usize, 3, 10] {
            let c = BpdqConfig { iters, ..cfg(2, 32) };
            let out = quantize_full(&w, &h, c).unwrap();
            let err = out.e_coords.fro_norm().powi(2);
            assert!(err <= last * 1.0001, "iters={iters}: {err} > {last}");
            last = err;
        }
    }

    #[test]
    fn bpw_matches_paper() {
        let (w, x) = rand_wx(35, 4, 256, 16);
        for (k, g, want) in [(2u8, 64usize, 2.75f64), (2, 128, 2.375), (2, 256, 2.1875), (3, 64, 4.0)] {
            let q = quantize_linear(&w, &x, QuantMethod::Bpdq(cfg(k, g))).unwrap();
            assert!(
                (q.bits_per_weight() - want).abs() < 1e-9,
                "W{k}-G{g}: {}",
                q.bits_per_weight()
            );
        }
    }

    #[test]
    fn k4_more_accurate_than_k2() {
        let (w, x) = rand_wx(36, 16, 64, 64);
        let e2 = quantize_linear(&w, &x, QuantMethod::Bpdq(cfg(2, 32)))
            .unwrap()
            .stats
            .output_err;
        let e4 = quantize_linear(&w, &x, QuantMethod::Bpdq(cfg(4, 32)))
            .unwrap()
            .stats
            .output_err;
        assert!(e4 < e2, "k4 {e4} !< k2 {e2}");
    }

    #[test]
    fn ragged_group_ok() {
        let (w, x) = rand_wx(37, 4, 70, 32); // ragged final group
        let q = quantize_linear(&w, &x, QuantMethod::Bpdq(cfg(2, 32))).unwrap();
        assert_eq!(q.dequant.shape(), (4, 70));
        assert!(q.stats.output_err.is_finite());
    }

    #[test]
    fn gar_off_still_works() {
        let (w, x) = rand_wx(38, 8, 64, 48);
        let c = BpdqConfig { gar: false, ..cfg(2, 32) };
        let q = quantize_linear(&w, &x, QuantMethod::Bpdq(c)).unwrap();
        assert!(q.stats.output_err.is_finite());
    }

    #[test]
    fn deterministic() {
        let (w, x) = rand_wx(39, 8, 64, 48);
        let a = quantize_linear(&w, &x, QuantMethod::Bpdq(cfg(2, 32))).unwrap();
        let b = quantize_linear(&w, &x, QuantMethod::Bpdq(cfg(2, 32))).unwrap();
        assert_eq!(a.dequant, b.dequant);
    }
}
