//! VPTQ-style vector post-training quantization (Liu et al., 2024) —
//! the paper's high-fidelity / high-cost VQ baseline.
//!
//! Per layer: (1) protect the most salient columns in fp16 (outlier
//! protection, fraction `outlier_frac`); (2) split the remaining columns
//! of each row into `vdim`-dimensional sub-vectors; (3) learn a shared
//! codebook of `2^(bits·vdim)` centroids by **Hessian-diagonal-weighted
//! k-means** (many iterations — this is where the ~40× quantization cost
//! of Table 3 comes from); (4) assign with GPTQ-style column-block error
//! propagation so the assignment stays output-aligned.

use super::hessian::{HessianState, DEFAULT_HESSIAN_DAMP};
use super::packing::{PackedWeights, VqPacked};
use super::VqConfig;
use crate::tensor::Matrix;
use anyhow::Result;

pub fn quantize(w: &Matrix, h: &HessianState, cfg: VqConfig) -> Result<(Matrix, PackedWeights)> {
    let (d_out, d_in) = w.shape();
    let v = cfg.vdim;
    let n_codes = 1usize << (cfg.bits as usize * v);

    // --- outlier columns: top fraction by Hessian diagonal ---
    let diag = h.diag();
    let n_out = ((d_in as f64 * cfg.outlier_frac).ceil() as usize).min(d_in);
    let mut order: Vec<usize> = (0..d_in).collect();
    order.sort_by(|&a, &b| diag[b].partial_cmp(&diag[a]).unwrap_or(std::cmp::Ordering::Equal));
    let mut outlier_cols: Vec<usize> = order[..n_out].to_vec();
    outlier_cols.sort_unstable();
    let is_outlier: Vec<bool> = {
        let mut m = vec![false; d_in];
        for &c in &outlier_cols {
            m[c] = true;
        }
        m
    };
    let kept: Vec<usize> = (0..d_in).filter(|&c| !is_outlier[c]).collect();

    // --- collect weighted training sub-vectors ---
    // Sub-vector t of row r covers kept columns [t*v, t*v+v).
    let n_sub_per_row = kept.len().div_ceil(v);
    let mut subs: Vec<f32> = Vec::with_capacity(d_out * n_sub_per_row * v);
    let mut sub_w: Vec<f64> = Vec::with_capacity(d_out * n_sub_per_row);
    for r in 0..d_out {
        let row = w.row(r);
        for t in 0..n_sub_per_row {
            let mut wt = 0.0f64;
            for i in 0..v {
                let idx = t * v + i;
                let (val, dw) = if idx < kept.len() {
                    (row[kept[idx]], diag[kept[idx]])
                } else {
                    (0.0, 0.0) // zero-pad ragged tail
                };
                subs.push(val);
                wt += dw;
            }
            sub_w.push(wt.max(1e-12));
        }
    }
    let n_sub = sub_w.len();

    // --- weighted k-means (the expensive part) ---
    let mut codebook = init_codebook(&subs, n_sub, v, n_codes);
    let mut assign = vec![0u16; n_sub];
    for _ in 0..cfg.kmeans_iters {
        // assignment
        for t in 0..n_sub {
            let sv = &subs[t * v..(t + 1) * v];
            assign[t] = nearest_code(&codebook, sv, v) as u16;
        }
        // update (weighted means)
        let mut sums = vec![0.0f64; n_codes * v];
        let mut wsum = vec![0.0f64; n_codes];
        for t in 0..n_sub {
            let c = assign[t] as usize;
            let wt = sub_w[t];
            wsum[c] += wt;
            for i in 0..v {
                sums[c * v + i] += wt * subs[t * v + i] as f64;
            }
        }
        for c in 0..n_codes {
            if wsum[c] > 0.0 {
                for i in 0..v {
                    codebook[c * v + i] = (sums[c * v + i] / wsum[c]) as f32;
                }
            }
        }
    }

    // --- output-aligned assignment with block error propagation ---
    // Process kept columns in blocks of v (a sub-vector spans v columns);
    // after assigning a block, propagate the quantization error through
    // the global factor U like GPTQ does per column.
    let u = h.factor(DEFAULT_HESSIAN_DAMP, None)?;
    let mut work = w.clone();
    let mut deq = Matrix::zeros(d_out, d_in);
    let mut codes = vec![0u16; d_out * n_sub_per_row];

    // outlier columns: exact fp16 copy
    let mut outliers = Matrix::zeros(d_out, n_out);
    for (oi, &c) in outlier_cols.iter().enumerate() {
        for r in 0..d_out {
            let val = super::f32_to_f16_roundtrip(w.get(r, c));
            outliers.set(r, oi, val);
            deq.set(r, c, val);
        }
    }

    let mut sv = vec![0.0f32; v];
    for t in 0..n_sub_per_row {
        let cols: Vec<usize> = (0..v).filter(|&i| t * v + i < kept.len()).map(|i| kept[t * v + i]).collect();
        for r in 0..d_out {
            for (i, &c) in cols.iter().enumerate() {
                sv[i] = work.get(r, c);
            }
            for i in cols.len()..v {
                sv[i] = 0.0;
            }
            let code = nearest_code(&codebook, &sv[..v], v);
            codes[r * n_sub_per_row + t] = code as u16;
            for (i, &c) in cols.iter().enumerate() {
                let qv = codebook[code * v + i];
                deq.set(r, c, qv);
                // per-column propagation within and beyond the block
                let e = ((work.get(r, c) - qv) as f64 / u.get(c, c)) as f32;
                if e != 0.0 {
                    let urow = u.row(c);
                    let wrow = work.row_mut(r);
                    for j in (c + 1)..d_in {
                        wrow[j] -= e * urow[j] as f32;
                    }
                }
            }
        }
    }

    // charge codebook at fp16
    let codebook_m = Matrix::from_vec(n_codes, v, codebook);
    let packed = VqPacked {
        d_out,
        d_in,
        vdim: v,
        bits: cfg.bits,
        codebook: codebook_m,
        codes,
        outlier_cols,
        outliers,
    };
    Ok((deq, PackedWeights::Vq(packed)))
}

/// k-means++-style deterministic seeding: spread over the value range.
fn init_codebook(subs: &[f32], n_sub: usize, v: usize, n_codes: usize) -> Vec<f32> {
    let mut codebook = vec![0.0f32; n_codes * v];
    if n_sub == 0 {
        return codebook;
    }
    // Seed c-th centroid from the sub-vector at the c-th quantile of the
    // first-component order — deterministic and well-spread.
    let mut order: Vec<usize> = (0..n_sub).collect();
    order.sort_by(|&a, &b| {
        subs[a * v]
            .partial_cmp(&subs[b * v])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    for c in 0..n_codes {
        let t = order[(c * (n_sub - 1)) / (n_codes - 1).max(1)];
        for i in 0..v {
            codebook[c * v + i] = subs[t * v + i];
        }
    }
    codebook
}

#[inline]
fn nearest_code(codebook: &[f32], sv: &[f32], v: usize) -> usize {
    let n_codes = codebook.len() / v;
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    for c in 0..n_codes {
        let mut d = 0.0f32;
        for i in 0..v {
            let diff = sv[i] - codebook[c * v + i];
            d += diff * diff;
        }
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::test_util::rand_wx;
    use crate::quant::{quantize_linear, QuantMethod, UniformConfig};

    #[test]
    fn nearest_code_picks_argmin() {
        let cb = vec![0.0, 0.0, 1.0, 1.0, -1.0, 2.0];
        assert_eq!(nearest_code(&cb, &[0.9, 1.1], 2), 1);
        assert_eq!(nearest_code(&cb, &[-0.8, 1.9], 2), 2);
    }

    #[test]
    fn vptq_quality_beats_gptq_at_2bit() {
        // Table 2 ordering: VPTQ is the quality ceiling at 2-bit.
        let (w, x) = rand_wx(51, 24, 128, 96);
        let e_vq = quantize_linear(&w, &x, QuantMethod::Vptq(VqConfig::default()))
            .unwrap()
            .stats
            .output_err;
        let e_gptq = quantize_linear(
            &w,
            &x,
            QuantMethod::Gptq(UniformConfig { bits: 2, group_size: 64, act_order: true }),
        )
        .unwrap()
        .stats
        .output_err;
        assert!(e_vq < e_gptq, "vptq {e_vq} !< gptq {e_gptq}");
    }

    #[test]
    fn vptq_slower_than_gptq() {
        // Table 3's cost ordering: VPTQ ≫ GPTQ (the 40× in the paper).
        // At unit-test scale we only assert the direction vs GPTQ; the
        // full cost ratios are measured by the table3 bench.
        let (w, x) = rand_wx(52, 48, 128, 64);
        let t_vq = quantize_linear(
            &w,
            &x,
            QuantMethod::Vptq(VqConfig { kmeans_iters: 60, ..Default::default() }),
        )
        .unwrap()
        .stats
        .secs;
        let t_gptq = quantize_linear(
            &w,
            &x,
            QuantMethod::Gptq(UniformConfig { bits: 2, group_size: 64, act_order: true }),
        )
        .unwrap()
        .stats
        .secs;
        assert!(t_vq > t_gptq, "vptq {t_vq}s !> gptq {t_gptq}s");
    }

    #[test]
    fn outlier_columns_are_exact_fp16() {
        let (w, x) = rand_wx(53, 8, 64, 48);
        let cfg = VqConfig { outlier_frac: 0.1, ..Default::default() };
        let q = quantize_linear(&w, &x, QuantMethod::Vptq(cfg)).unwrap();
        if let PackedWeights::Vq(p) = &q.packed {
            assert!(!p.outlier_cols.is_empty());
            for (oi, &c) in p.outlier_cols.iter().enumerate() {
                for r in 0..w.rows() {
                    let want = crate::quant::f32_to_f16_roundtrip(w.get(r, c));
                    assert_eq!(q.dequant.get(r, c), want, "outlier col {c}");
                    assert_eq!(p.outliers.get(r, oi), want);
                }
            }
        } else {
            panic!("wrong variant");
        }
    }

    #[test]
    fn bpw_near_nominal() {
        let (w, x) = rand_wx(54, 16, 256, 16);
        let q = quantize_linear(
            &w,
            &x,
            QuantMethod::Vptq(VqConfig { bits: 2, vdim: 2, kmeans_iters: 5, outlier_frac: 0.005 }),
        )
        .unwrap();
        let bpw = q.bits_per_weight();
        // 2 bits/weight + codebook/outlier overhead — should be within
        // ~30% of nominal for this small layer and well under 4.
        assert!(bpw > 2.0 && bpw < 3.2, "bpw={bpw}");
    }
}
