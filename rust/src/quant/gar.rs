//! GAR — Group-Aware Reordering (Gafni et al., 2025), the channel
//! ordering BPDQ uses instead of GPTQ's `desc_act`.
//!
//! `desc_act` sorts channels globally by Hessian saliency, which scatters
//! each quantization group across the whole layer: group parameters are
//! then derived from channels that are not contiguous in the original
//! weight, and inference needs a full permutation.
//!
//! GAR preserves **group integrity**: groups keep their original channel
//! membership; only (a) the processing order *of groups* follows
//! descending group saliency, and (b) channels *within* each group are
//! ordered by descending saliency. The resulting permutation is
//! block-structured, so group-wise scalar derivation (paper Eq. 6) always
//! sees the channels that will actually share coefficients at inference.

/// Build the GAR permutation for `d_in` channels in groups of `g`, given
/// per-channel saliency (Hessian diagonal). Returns `perm` such that
/// `new_col_j = old_col_{perm[j]}`, with groups contiguous: the j-th
/// output group is an entire input group.
pub fn gar_perm(diag: &[f64], g: usize) -> Vec<usize> {
    let d_in = diag.len();
    let ng = d_in.div_ceil(g);
    // Group saliency = max of member saliencies (the channel that most
    // constrains early processing).
    let group_sal: Vec<f64> = (0..ng)
        .map(|grp| {
            let c0 = grp * g;
            let c1 = (c0 + g).min(d_in);
            diag[c0..c1].iter().cloned().fold(f64::MIN, f64::max)
        })
        .collect();
    // A ragged final group (size < g) must stay LAST in processing order
    // so processing-group boundaries keep coinciding with original-group
    // boundaries (the property packing relies on to un-permute records).
    let ragged = d_in % g != 0;
    let sortable = if ragged { ng - 1 } else { ng };
    let mut group_order: Vec<usize> = (0..sortable).collect();
    group_order.sort_by(|&a, &b| {
        group_sal[b].partial_cmp(&group_sal[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    if ragged {
        group_order.push(ng - 1);
    }

    let mut perm = Vec::with_capacity(d_in);
    for &grp in &group_order {
        let c0 = grp * g;
        let c1 = (c0 + g).min(d_in);
        let mut members: Vec<usize> = (c0..c1).collect();
        members.sort_by(|&a, &b| {
            diag[b].partial_cmp(&diag[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        perm.extend(members);
    }
    perm
}

/// Check that a permutation preserves group integrity: every output group
/// is a permutation of exactly one input group. (Used by tests and debug
/// assertions.)
pub fn preserves_groups(perm: &[usize], g: usize) -> bool {
    let d_in = perm.len();
    let ng = d_in.div_ceil(g);
    for out_grp in 0..ng {
        let c0 = out_grp * g;
        let c1 = (c0 + g).min(d_in);
        let mut src_groups: Vec<usize> = perm[c0..c1].iter().map(|&p| p / g).collect();
        src_groups.dedup();
        // Ragged tails: the last (short) input group must map to the last
        // output slot as a unit, which the construction guarantees; here
        // we only require that a full output group draws from one input
        // group.
        if src_groups.len() != 1 {
            // allow the ragged case where group sizes differ
            let src_set: std::collections::BTreeSet<usize> = src_groups.iter().copied().collect();
            if src_set.len() != 1 {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn is_a_permutation() {
        let mut rng = Rng::new(1);
        let diag: Vec<f64> = (0..96).map(|_| rng.f64() * 10.0).collect();
        let p = gar_perm(&diag, 32);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..96).collect::<Vec<_>>());
    }

    #[test]
    fn groups_stay_intact() {
        let mut rng = Rng::new(2);
        for &(d, g) in &[(128usize, 32usize), (96, 32), (64, 64), (80, 32)] {
            let diag: Vec<f64> = (0..d).map(|_| rng.f64() * 10.0).collect();
            let p = gar_perm(&diag, g);
            assert!(preserves_groups(&p, g), "d={d} g={g} perm={p:?}");
        }
    }

    #[test]
    fn most_salient_group_first() {
        // Saliency concentrated in the third group.
        let mut diag = vec![1.0; 96];
        diag[70] = 100.0;
        let p = gar_perm(&diag, 32);
        // First output channel must be channel 70.
        assert_eq!(p[0], 70);
        // And the first 32 outputs must all come from input group 2.
        assert!(p[..32].iter().all(|&c| (64..96).contains(&c)));
    }

    #[test]
    fn within_group_desc_order() {
        let diag = vec![3.0, 1.0, 2.0, 9.0, 5.0, 7.0, 6.0, 8.0];
        let p = gar_perm(&diag, 4);
        // group 1 (channels 4..8) has max 9? no — 9.0 is channel 3 in
        // group 0. group saliencies: g0 max=9 (ch3), g1 max=8 (ch7).
        assert_eq!(p[..4], [3, 0, 2, 1]); // desc within group 0
        assert_eq!(p[4..], [7, 5, 6, 4]); // desc within group 1
    }

    #[test]
    fn desc_act_violates_group_integrity_gar_does_not() {
        // Sanity contrast: global desc sort scrambles groups.
        let mut rng = Rng::new(3);
        let diag: Vec<f64> = (0..128).map(|_| rng.f64()).collect();
        let desc = crate::quant::gptq::desc_act_perm(&diag);
        assert!(!preserves_groups(&desc, 32));
        assert!(preserves_groups(&gar_perm(&diag, 32), 32));
    }
}
