//! Dense linear algebra for the Hessian-induced geometry.
//!
//! Everything the paper's optimizer needs, in f64 (the Hessian of a
//! Zipf-skewed activation stream is badly conditioned; f32 Cholesky loses
//! the trailing groups):
//!
//! * [`cholesky_lower`] — `H = L Lᵀ`,
//! * [`inv_upper_factor`] — `U = chol(H⁻¹)` with `H⁻¹ = Uᵀ U`, the exact
//!   factor GPTQ/BPDQ propagate errors through (paper Eq. 3–4),
//! * triangular solves and inverses,
//! * [`wls`] — the damped weighted least-squares solver behind the
//!   scalar-coefficient fit (paper Eq. 6).

use crate::tensor::MatrixF64;
use std::fmt;

// thiserror is not in the offline vendor set; Display/Error are hand-
// rolled (same messages the derive produced).
#[derive(Debug)]
pub enum LinalgError {
    NotPositiveDefinite(usize, f64),
    SingularTriangular(usize),
    Shape(String),
}

impl fmt::Display for LinalgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LinalgError::NotPositiveDefinite(i, v) => {
                write!(f, "matrix not positive definite at pivot {i} (value {v})")
            }
            LinalgError::SingularTriangular(i) => {
                write!(f, "singular triangular factor at {i}")
            }
            LinalgError::Shape(s) => write!(f, "shape mismatch: {s}"),
        }
    }
}

impl std::error::Error for LinalgError {}

pub type Result<T> = std::result::Result<T, LinalgError>;

/// Lower-triangular Cholesky factor: `A = L Lᵀ`. `A` must be symmetric
/// positive definite (upper triangle is read as the mirror of the lower).
pub fn cholesky_lower(a: &MatrixF64) -> Result<MatrixF64> {
    let n = a.rows();
    if a.cols() != n {
        return Err(LinalgError::Shape(format!("{:?} not square", a.shape())));
    }
    let mut l = MatrixF64::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // s = A[i,j] - Σ_{k<j} L[i,k] L[j,k]
            let mut s = a.get(i, j);
            let li = l.row(i);
            let lj = l.row(j);
            for k in 0..j {
                s -= li[k] * lj[k];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return Err(LinalgError::NotPositiveDefinite(i, s));
                }
                l.set(i, j, s.sqrt());
            } else {
                l.set(i, j, s / l.get(j, j));
            }
        }
    }
    Ok(l)
}

/// Invert a lower-triangular matrix in place (forward substitution per
/// column of the identity).
pub fn invert_lower(l: &MatrixF64) -> Result<MatrixF64> {
    let n = l.rows();
    let mut inv = MatrixF64::zeros(n, n);
    for j in 0..n {
        // Solve L x = e_j.
        for i in j..n {
            let mut s = if i == j { 1.0 } else { 0.0 };
            for k in j..i {
                s -= l.get(i, k) * inv.get(k, j);
            }
            let d = l.get(i, i);
            if d == 0.0 || !d.is_finite() {
                return Err(LinalgError::SingularTriangular(i));
            }
            inv.set(i, j, s / d);
        }
    }
    Ok(inv)
}

/// Invert an upper-triangular matrix.
pub fn invert_upper(u: &MatrixF64) -> Result<MatrixF64> {
    // Uᵀ is lower; (Uᵀ)⁻¹ = (U⁻¹)ᵀ.
    Ok(invert_lower(&u.transpose())?.transpose())
}

/// The GPTQ/BPDQ propagation factor: upper-triangular `U` with
/// `H⁻¹ = Uᵀ U`, computed as `U = (Lᵀ)⁻¹` from `H = L Lᵀ`.
///
/// Derivation: `H⁻¹ = (L Lᵀ)⁻¹ = L⁻ᵀ L⁻¹ = (L⁻ᵀ)(L⁻ᵀ)ᵀ`... careful:
/// we need `Uᵀ U` with U upper. `L⁻¹` is lower, so `H⁻¹ = L⁻ᵀ L⁻¹ =
/// (L⁻¹)ᵀ (L⁻¹)` which is `UᵀU` with `U = L⁻¹`?? `L⁻¹` is *lower*
/// triangular. The standard GPTQ implementation instead uses
/// `U = cholesky(H⁻¹, upper=True)`, i.e. the upper factor `R` of
/// `H⁻¹ = RᵀR`. We compute it directly: invert H via the Cholesky of H,
/// then take the (upper) Cholesky of H⁻¹ by factoring the reversed
/// matrix — equivalently via the RQ-like identity below.
pub fn inv_upper_factor(h: &MatrixF64) -> Result<MatrixF64> {
    let n = h.rows();
    // H⁻¹ from Cholesky of H.
    let l = cholesky_lower(h)?;
    let linv = invert_lower(&l)?; // H⁻¹ = linvᵀ · linv
    let mut hinv = MatrixF64::zeros(n, n);
    // hinv = linvᵀ @ linv — accumulate with k-outer loop (linv rows).
    for k in 0..n {
        let row = linv.row(k);
        for i in 0..n {
            let v = row[i];
            if v == 0.0 {
                continue;
            }
            for j in 0..n {
                let x = hinv.get(i, j) + v * row[j];
                hinv.set(i, j, x);
            }
        }
    }
    cholesky_upper(&hinv)
}

/// Upper-triangular Cholesky: `A = Uᵀ U` (U upper). Computed row-by-row
/// from the top-left, mirroring `cholesky_lower` on the transpose order.
pub fn cholesky_upper(a: &MatrixF64) -> Result<MatrixF64> {
    let n = a.rows();
    if a.cols() != n {
        return Err(LinalgError::Shape(format!("{:?} not square", a.shape())));
    }
    let mut u = MatrixF64::zeros(n, n);
    for i in 0..n {
        // diagonal
        let mut s = a.get(i, i);
        for k in 0..i {
            let uki = u.get(k, i);
            s -= uki * uki;
        }
        if s <= 0.0 || !s.is_finite() {
            return Err(LinalgError::NotPositiveDefinite(i, s));
        }
        let uii = s.sqrt();
        u.set(i, i, uii);
        for j in (i + 1)..n {
            let mut s = a.get(i, j);
            for k in 0..i {
                s -= u.get(k, i) * u.get(k, j);
            }
            u.set(i, j, s / uii);
        }
    }
    Ok(u)
}

/// Solve `U x = b` with U upper triangular (back substitution).
pub fn solve_upper(u: &MatrixF64, b: &[f64]) -> Result<Vec<f64>> {
    let n = u.rows();
    if b.len() != n {
        return Err(LinalgError::Shape("solve_upper rhs".into()));
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        let row = u.row(i);
        for j in (i + 1)..n {
            s -= row[j] * x[j];
        }
        if row[i] == 0.0 {
            return Err(LinalgError::SingularTriangular(i));
        }
        x[i] = s / row[i];
    }
    Ok(x)
}

/// Solve `L x = b` with L lower triangular (forward substitution).
pub fn solve_lower(l: &MatrixF64, b: &[f64]) -> Result<Vec<f64>> {
    let n = l.rows();
    if b.len() != n {
        return Err(LinalgError::Shape("solve_lower rhs".into()));
    }
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        let row = l.row(i);
        for j in 0..i {
            s -= row[j] * x[j];
        }
        if row[i] == 0.0 {
            return Err(LinalgError::SingularTriangular(i));
        }
        x[i] = s / row[i];
    }
    Ok(x)
}

/// Solve `Uᵀ x = b` with U upper triangular (Uᵀ is lower ⇒ forward subst
/// reading U's columns). Used for the `U_loc^{-T} v` products in Eq. 6.
pub fn solve_upper_transpose(u: &MatrixF64, b: &[f64]) -> Result<Vec<f64>> {
    let n = u.rows();
    if b.len() != n {
        return Err(LinalgError::Shape("solve_upper_transpose rhs".into()));
    }
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for j in 0..i {
            s -= u.get(j, i) * x[j];
        }
        let d = u.get(i, i);
        if d == 0.0 {
            return Err(LinalgError::SingularTriangular(i));
        }
        x[i] = s / d;
    }
    Ok(x)
}

/// Damped weighted least squares: minimize `‖A c − b‖² + α‖c‖²` via the
/// normal equations `(AᵀA + αI) c = Aᵀ b`, solved with Cholesky.
///
/// This is exactly the solver behind the paper's Eq. 6 once the rows have
/// been pre-whitened by `U_loc^{-T}` (the caller does the whitening).
pub fn wls(a: &MatrixF64, b: &[f64], damping: f64) -> Result<Vec<f64>> {
    let (m, p) = a.shape();
    if b.len() != m {
        return Err(LinalgError::Shape("wls rhs".into()));
    }
    // Normal matrix N = AᵀA + αI (p×p, p = k+1 ≤ 9 — tiny).
    let mut n_mat = MatrixF64::zeros(p, p);
    for r in 0..m {
        let row = a.row(r);
        for i in 0..p {
            let ri = row[i];
            if ri == 0.0 {
                continue;
            }
            for j in i..p {
                let v = n_mat.get(i, j) + ri * row[j];
                n_mat.set(i, j, v);
            }
        }
    }
    for i in 0..p {
        for j in 0..i {
            n_mat.set(i, j, n_mat.get(j, i));
        }
        n_mat.set(i, i, n_mat.get(i, i) + damping);
    }
    // rhs = Aᵀ b
    let mut rhs = vec![0.0; p];
    for r in 0..m {
        let row = a.row(r);
        let br = b[r];
        if br == 0.0 {
            continue;
        }
        for i in 0..p {
            rhs[i] += row[i] * br;
        }
    }
    let l = cholesky_lower(&n_mat)?;
    let y = solve_lower(&l, &rhs)?;
    solve_upper(&l.transpose(), &y)
}

/// Symmetrize + add `alpha * mean(diag) * I` damping (the GPTQ "percdamp"
/// convention) so the Cholesky always exists.
pub fn damp_in_place(h: &mut MatrixF64, alpha: f64) {
    let n = h.rows();
    let mut diag_mean = 0.0;
    for i in 0..n {
        diag_mean += h.get(i, i);
    }
    diag_mean = (diag_mean / n as f64).max(1e-12);
    for i in 0..n {
        for j in (i + 1)..n {
            let s = 0.5 * (h.get(i, j) + h.get(j, i));
            h.set(i, j, s);
            h.set(j, i, s);
        }
        h.set(i, i, h.get(i, i) + alpha * diag_mean);
    }
    // Dead columns (channels never activated) get the damping floor too.
    for i in 0..n {
        if h.get(i, i) <= 0.0 {
            h.set(i, i, alpha * diag_mean);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::matmul_f64;

    fn rand_spd(rng: &mut Rng, n: usize) -> MatrixF64 {
        // A = G Gᵀ + n*I, G ~ N(0,1)^{n×n}
        let g = MatrixF64::from_vec(n, n, (0..n * n).map(|_| rng.normal()).collect());
        let mut a = matmul_f64(&g, &g.transpose());
        for i in 0..n {
            a.set(i, i, a.get(i, i) + n as f64);
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(1);
        for &n in &[1, 2, 5, 16, 40] {
            let a = rand_spd(&mut rng, n);
            let l = cholesky_lower(&a).unwrap();
            let rec = matmul_f64(&l, &l.transpose());
            for i in 0..n {
                for j in 0..n {
                    assert!(
                        (rec.get(i, j) - a.get(i, j)).abs() < 1e-8 * (1.0 + a.get(i, j).abs()),
                        "n={n} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn cholesky_upper_reconstructs() {
        let mut rng = Rng::new(2);
        for &n in &[1, 3, 10, 33] {
            let a = rand_spd(&mut rng, n);
            let u = cholesky_upper(&a).unwrap();
            // upper triangular?
            for i in 0..n {
                for j in 0..i {
                    assert_eq!(u.get(i, j), 0.0);
                }
            }
            let rec = matmul_f64(&u.transpose(), &u);
            for i in 0..n {
                for j in 0..n {
                    assert!((rec.get(i, j) - a.get(i, j)).abs() < 1e-8 * (1.0 + a.get(i, j).abs()));
                }
            }
        }
    }

    #[test]
    fn not_pd_detected() {
        let a = MatrixF64::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(matches!(
            cholesky_lower(&a),
            Err(LinalgError::NotPositiveDefinite(_, _))
        ));
    }

    #[test]
    fn invert_lower_correct() {
        let mut rng = Rng::new(3);
        let n = 12;
        let a = rand_spd(&mut rng, n);
        let l = cholesky_lower(&a).unwrap();
        let linv = invert_lower(&l).unwrap();
        let eye = matmul_f64(&l, &linv);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((eye.get(i, j) - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn inv_upper_factor_identity() {
        // For H = I, U should satisfy UᵀU = I with U upper ⇒ U = I.
        let n = 6;
        let mut h = MatrixF64::zeros(n, n);
        for i in 0..n {
            h.set(i, i, 1.0);
        }
        let u = inv_upper_factor(&h).unwrap();
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((u.get(i, j) - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn inv_upper_factor_satisfies_identity() {
        let mut rng = Rng::new(4);
        for &n in &[2, 8, 24] {
            let h = rand_spd(&mut rng, n);
            let u = inv_upper_factor(&h).unwrap();
            // UᵀU should equal H⁻¹  ⇔  Uᵀ U H = I
            let uu = matmul_f64(&u.transpose(), &u);
            let prod = matmul_f64(&uu, &h);
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!(
                        (prod.get(i, j) - want).abs() < 1e-6,
                        "n={n} ({i},{j}) got {}",
                        prod.get(i, j)
                    );
                }
            }
            // strictly upper triangular below diag
            for i in 0..n {
                for j in 0..i {
                    assert_eq!(u.get(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Rng::new(5);
        let n = 10;
        let a = rand_spd(&mut rng, n);
        let l = cholesky_lower(&a).unwrap();
        let u = l.transpose();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let x = solve_lower(&l, &b).unwrap();
        // check L x = b
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                s += l.get(i, j) * x[j];
            }
            assert!((s - b[i]).abs() < 1e-9);
        }
        let y = solve_upper(&u, &b).unwrap();
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                s += u.get(i, j) * y[j];
            }
            assert!((s - b[i]).abs() < 1e-9);
        }
        // Uᵀ x = b
        let z = solve_upper_transpose(&u, &b).unwrap();
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                s += u.get(j, i) * z[j];
            }
            assert!((s - b[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn wls_exact_when_overdetermined_consistent() {
        // b = A c*, damping→0 recovers c*.
        let mut rng = Rng::new(6);
        let (m, p) = (20, 3);
        let a = MatrixF64::from_vec(m, p, (0..m * p).map(|_| rng.normal()).collect());
        let cstar = [1.5, -2.0, 0.25];
        let b: Vec<f64> = (0..m)
            .map(|r| (0..p).map(|j| a.get(r, j) * cstar[j]).sum())
            .collect();
        let c = wls(&a, &b, 1e-12).unwrap();
        for j in 0..p {
            assert!((c[j] - cstar[j]).abs() < 1e-6, "{c:?}");
        }
    }

    #[test]
    fn wls_stationarity() {
        // Perturbing the WLS solution must not decrease the objective.
        let mut rng = Rng::new(7);
        let (m, p) = (30, 4);
        let a = MatrixF64::from_vec(m, p, (0..m * p).map(|_| rng.normal()).collect());
        let b: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let damping = 1e-4;
        let c = wls(&a, &b, damping).unwrap();
        let obj = |c: &[f64]| -> f64 {
            let mut s = 0.0;
            for r in 0..m {
                let pred: f64 = (0..p).map(|j| a.get(r, j) * c[j]).sum();
                s += (pred - b[r]).powi(2);
            }
            s + damping * c.iter().map(|x| x * x).sum::<f64>()
        };
        let base = obj(&c);
        for j in 0..p {
            for delta in [-1e-3, 1e-3] {
                let mut c2 = c.clone();
                c2[j] += delta;
                assert!(obj(&c2) >= base - 1e-12, "perturb {j} {delta}");
            }
        }
    }

    #[test]
    fn damping_rescues_singular() {
        let n = 5;
        let mut h = MatrixF64::zeros(n, n); // all-zero "Hessian": dead layer
        damp_in_place(&mut h, 1e-2);
        // now must factor
        assert!(cholesky_lower(&h).is_ok());
    }
}
