//! Iteration-level scheduler: one persistent decode sweep per worker.
//!
//! The scheduler owns the Orca-style continuous-batching loop the
//! module docs describe. Each iteration it:
//!
//! 1. retires sessions whose [`CancelHandle`](super::CancelHandle) was
//!    flagged (slot released **before** `Done{Cancelled}` is sent);
//! 2. admits queued requests into free batch slots (blocking on the
//!    [`SubmitQueue`] only when *nothing* is active) — prompt prefill
//!    starts on the very next sweep, joining whatever is in flight;
//! 3. gathers one token per active session (prompt prefill counts as
//!    steps — single-token engines) and hands the whole sweep to the
//!    engine's [`Stepper`];
//! 4. samples each generating session's logits via
//!    [`crate::model::sample`] (seeded per request; temp=0 ≡ argmax),
//!    emits `Token{id, logprob}` events as they are produced, and
//!    retires finished sessions immediately so their slots are free for
//!    the next iteration's admission.
//!
//! The loop is engine-agnostic: the [`Stepper`] decides whether a sweep
//! is executed as independent per-session steps (native), one fused
//! multi-session pass (LUT), or sequential AOT-executable calls (PJRT).
//! A stepper error retires every in-flight session with
//! `Done{finish_reason: Error}` — callers always observe a terminal
//! event, never a silent drop.

use super::batcher::{Pending, SubmitQueue};
use super::kv::KvArena;
use super::metrics::Metrics;
use super::prefix::PrefixCache;
use super::{FinishReason, GenEvent, Usage};
use crate::model::sample;
use crate::rng::Rng;
use anyhow::Result;
use std::time::Instant;

/// One in-flight decode session: KV state + position bookkeeping. The
/// stepping itself belongs to the [`Stepper`] so batched engines can
/// fuse a whole sweep.
pub(crate) trait Session {
    fn pos(&self) -> usize;
    fn capacity(&self) -> usize;

    /// Borrow a cached prompt prefix at admission (see
    /// [`PrefixCache::match_and_borrow`]); returns how many prompt
    /// tokens are already resident so the scheduler prefills only the
    /// suffix. Engines without prefix support keep the default miss.
    fn prefix_match(&mut self, _cache: &PrefixCache, _prompt: &[u32]) -> usize {
        0
    }

    /// Publish this session's prompt pages into the cache once the full
    /// prompt has been fed. Default: not supported, no-op.
    fn prefix_publish(&mut self, _cache: &PrefixCache, _prompt: &[u32]) {}
}

/// Executes one sweep: each session advances by exactly one token.
pub(crate) trait Stepper {
    type Sess: Session;

    /// Create a fresh session (claims a KV-arena slot where applicable;
    /// panics on arena exhaustion, like the capacity assert).
    fn make(&self) -> Self::Sess;

    /// Step session `i` with `tokens[i]`; returns next-token logits per
    /// session, in order. An `Err` poisons the whole sweep — the
    /// scheduler retires every stepped session with `FinishReason::Error`.
    fn step_batch(
        &mut self,
        sessions: &mut [&mut Self::Sess],
        tokens: &[u32],
    ) -> Result<Vec<Vec<f32>>>;
}

/// A request admitted into the sweep. Per-token latency samples are
/// buffered here and flushed to the shared [`Metrics`] in one call at
/// retirement — the decode hot loop never takes the metrics mutex per
/// token.
struct ActiveGen<S> {
    p: Pending,
    sess: S,
    prompt_left: std::vec::IntoIter<u32>,
    next_token: Option<u32>,
    n_out: usize,
    rng: Rng,
    admitted: Instant,
    first_tok: Option<Instant>,
    last_tok: Option<Instant>,
    /// Buffered inter-token gaps (µs), one per token after the first.
    itl_us: Vec<u64>,
    /// Whether this session's prompt pages were published to the prefix
    /// cache (exactly once, at prefill completion).
    published: bool,
}

/// Retire a session: release its KV slot (dropping `sess` releases the
/// arena handle) **before** the terminal event is sent — and snapshot
/// the arena into the metrics in between — so a caller observing `Done`
/// knows the slot is reusable and the metrics already reflect it.
#[allow(clippy::too_many_arguments)]
fn retire<S>(
    a: ActiveGen<S>,
    finish_reason: FinishReason,
    error: Option<String>,
    sweep: u64,
    queue: &SubmitQueue,
    metrics: Option<&Metrics>,
    arena: Option<&KvArena>,
) {
    let ActiveGen { p, sess, n_out, admitted, first_tok, itl_us, .. } = a;
    drop(sess);
    if let (Some(m), Some(ar)) = (metrics, arena) {
        m.observe_arena(ar.id(), ar.stats());
    }
    let now = Instant::now();
    let ttft_us = first_tok.map(|t| (t - p.enqueued).as_micros() as u64);
    let usage = Usage {
        prompt_tokens: p.request.prompt.len(),
        completion_tokens: n_out,
        queue_us: (admitted - p.enqueued).as_micros() as u64,
        ttft_us: ttft_us.unwrap_or(0),
        total_us: (now - p.enqueued).as_micros() as u64,
        finished_sweep: sweep,
    };
    let _ = p.events.send(GenEvent::Done { finish_reason, usage, error });
    queue.finish_one();
    if let Some(m) = metrics {
        m.record_retired(
            finish_reason,
            usage.queue_us,
            ttft_us,
            &itl_us,
            n_out,
            (now - admitted).as_micros() as u64,
        );
    }
}

fn admit<St: Stepper>(
    stepper: &St,
    p: Pending,
    cache: Option<&PrefixCache>,
) -> ActiveGen<St::Sess> {
    let rng = Rng::new(p.request.params.seed);
    let mut sess = stepper.make();
    let mut prompt_left = p.request.prompt.clone().into_iter();
    if let Some(c) = cache {
        // Prefix-cache admission: borrow the matched pages and skip the
        // resident prompt tokens — only the cache-miss suffix is
        // prefilled (this is where cache-hit TTFT collapses).
        let matched = sess.prefix_match(c, &p.request.prompt);
        if matched > 0 {
            let _ = prompt_left.nth(matched - 1);
        }
    }
    ActiveGen {
        sess,
        prompt_left,
        next_token: None,
        n_out: 0,
        rng,
        admitted: Instant::now(),
        first_tok: None,
        last_tok: None,
        itl_us: Vec::new(),
        published: false,
        p,
    }
}

/// Run the persistent scheduling loop until the queue is closed and
/// drained (graceful) or the stepper fails (every in-flight request is
/// retired with `Error` first).
///
/// Sweep contract (`bpdq lint` L3/L4): this loop must never panic or
/// block on a lock mid-sweep — a panic here strands every in-flight
/// stream without a `Done` event, and a lock would stall all sessions
/// at once. Allocation is fine (per-sweep vectors), hence `sweep`, not
/// `hot`.
// lint: sweep
pub(crate) fn run_scheduler<St: Stepper>(
    stepper: &mut St,
    queue: &SubmitQueue,
    max_batch: usize,
    metrics: Option<&Metrics>,
    arena: Option<&KvArena>,
    cache: Option<&PrefixCache>,
) -> Result<()> {
    let max_batch = max_batch.max(1);
    let mut active: Vec<ActiveGen<St::Sess>> = Vec::new();
    let mut sweep: u64 = 0;

    'serve: loop {
        // 1. Sweep-boundary cancellation: retire flagged sessions first
        // so their slots are admissible this very iteration.
        let mut keep = Vec::with_capacity(active.len());
        for a in active {
            if a.p.cancel.is_cancelled() {
                retire(a, FinishReason::Cancelled, None, sweep, queue, metrics, arena);
            } else {
                keep.push(a);
            }
        }
        active = keep;

        // 2. Admission into free slots. Block only when idle; a busy
        // sweep drains whatever is queued without waiting.
        while active.len() < max_batch {
            let next = if active.is_empty() {
                match queue.pop_blocking() {
                    Some(p) => p,
                    None => break 'serve, // closed & drained, nothing active
                }
            } else {
                match queue.try_pop() {
                    Some(p) => p,
                    None => break,
                }
            };
            if next.cancel.is_cancelled() {
                // Cancelled while still queued: terminal event, no slot.
                let queue_us = next.enqueued.elapsed().as_micros() as u64;
                next.reject(FinishReason::Cancelled, None);
                queue.finish_one();
                if let Some(m) = metrics {
                    m.record_retired(FinishReason::Cancelled, queue_us, None, &[], 0, 0);
                }
                continue;
            }
            active.push(admit(stepper, next, cache));
        }

        // 3. Gather this sweep's (session, token) pairs; sessions with
        // no token left (or no KV capacity) retire instead.
        let mut stepping: Vec<ActiveGen<St::Sess>> = Vec::with_capacity(active.len());
        let mut tokens: Vec<u32> = Vec::with_capacity(active.len());
        for mut a in active {
            let capacity_left = a.sess.capacity() - a.sess.pos();
            match a.next_token.take().or_else(|| a.prompt_left.next()) {
                Some(t) if capacity_left > 0 => {
                    tokens.push(t);
                    stepping.push(a);
                }
                // out of prompt+generation or capacity: finalize
                _ => retire(a, FinishReason::Length, None, sweep, queue, metrics, arena),
            }
        }
        if stepping.is_empty() {
            active = Vec::new();
            continue;
        }
        if let Some(m) = metrics {
            m.record_decode_sweep(stepping.len());
        }
        sweep += 1;

        // 4. One fused sweep through the engine.
        let logits_all = {
            let mut refs: Vec<&mut St::Sess> = stepping.iter_mut().map(|a| &mut a.sess).collect();
            stepper.step_batch(&mut refs, &tokens)
        };
        let logits_all = match logits_all {
            Ok(l) => l,
            Err(e) => {
                let msg = format!("{e:#}");
                for a in stepping {
                    retire(a, FinishReason::Error, Some(msg.clone()), sweep, queue, metrics, arena);
                }
                return Err(e);
            }
        };
        debug_assert_eq!(logits_all.len(), stepping.len());

        // 5. Sample, emit token events, retire finished sessions now so
        // their slots are re-admitted on the next iteration.
        let mut still = Vec::with_capacity(stepping.len());
        for (mut a, logits) in stepping.into_iter().zip(logits_all) {
            if a.prompt_left.len() != 0 {
                still.push(a); // prefill: logits discarded until the last prompt token
                continue;
            }
            if !a.published {
                // Prefill just completed: publish the prompt's pages
                // (refcount bumps only) before any generated token can
                // overwrite the page holding the last prompt position.
                if let Some(c) = cache {
                    a.sess.prefix_publish(c, &a.p.request.prompt);
                }
                a.published = true;
            }
            if a.n_out >= a.p.request.params.max_new {
                // max_new == 0: the prompt was consumed but nothing may
                // be sampled.
                retire(a, FinishReason::Length, None, sweep, queue, metrics, arena);
                continue;
            }
            let (tok, logprob) = {
                let prm = &a.p.request.params;
                sample(&logits, prm.temperature, prm.top_k, prm.top_p, &mut a.rng)
            };
            let tok = tok as u32;
            if a.p.request.params.stop_tokens.contains(&tok) {
                retire(a, FinishReason::Stop, None, sweep, queue, metrics, arena);
                continue;
            }
            // Timestamp the emission; the gap is buffered locally and
            // flushed to the metrics in one call at retirement.
            let now = Instant::now();
            if let Some(prev) = a.last_tok {
                a.itl_us.push((now - prev).as_micros() as u64);
            }
            a.first_tok.get_or_insert(now);
            a.last_tok = Some(now);
            if a.p.events.send(GenEvent::Token { id: tok, logprob }).is_err() {
                // Receiver gone — implicit cancellation; stop decoding
                // for a stream nobody is reading.
                retire(a, FinishReason::Cancelled, None, sweep, queue, metrics, arena);
                continue;
            }
            a.n_out += 1;
            if a.n_out >= a.p.request.params.max_new {
                retire(a, FinishReason::Length, None, sweep, queue, metrics, arena);
            } else {
                a.next_token = Some(tok);
                still.push(a);
            }
        }
        active = still;

        if let (Some(m), Some(ar)) = (metrics, arena) {
            m.observe_arena(ar.id(), ar.stats());
        }
        if let (Some(m), Some(c)) = (metrics, cache) {
            m.observe_prefix(c.id(), c.stats());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::{CancelHandle, GenRequest, SamplingParams};
    use std::sync::mpsc::{channel, Receiver};
    use std::thread;

    /// Deterministic engine stand-in: the next token depends only on
    /// (fed token, position), so output is independent of batching by
    /// construction and the tests isolate the *scheduler's* behavior.
    struct MockSession {
        pos: usize,
        cap: usize,
    }

    impl Session for MockSession {
        fn pos(&self) -> usize {
            self.pos
        }
        fn capacity(&self) -> usize {
            self.cap
        }
    }

    struct MockStepper {
        vocab: usize,
        cap: usize,
        fail_at_sweep: Option<usize>,
        sweeps: usize,
    }

    impl MockStepper {
        fn new(vocab: usize, cap: usize) -> Self {
            Self { vocab, cap, fail_at_sweep: None, sweeps: 0 }
        }
    }

    impl Stepper for MockStepper {
        type Sess = MockSession;

        fn make(&self) -> MockSession {
            MockSession { pos: 0, cap: self.cap }
        }

        fn step_batch(
            &mut self,
            sessions: &mut [&mut MockSession],
            tokens: &[u32],
        ) -> Result<Vec<Vec<f32>>> {
            self.sweeps += 1;
            if let Some(f) = self.fail_at_sweep {
                if self.sweeps >= f {
                    anyhow::bail!("mock sweep failure");
                }
            }
            Ok(sessions
                .iter_mut()
                .zip(tokens)
                .map(|(s, &t)| {
                    let mut logits = vec![0.0f32; self.vocab];
                    logits[((t as usize) * 7 + s.pos * 3 + 1) % self.vocab] = 1.0;
                    s.pos += 1;
                    logits
                })
                .collect())
        }
    }

    fn submit(
        q: &SubmitQueue,
        id: u64,
        prompt: Vec<u32>,
        max_new: usize,
        priority: u8,
    ) -> (Receiver<GenEvent>, CancelHandle) {
        let (tx, rx) = channel();
        let cancel = CancelHandle::new();
        q.push(Pending {
            request: GenRequest {
                id,
                prompt,
                params: SamplingParams { max_new, ..Default::default() },
                priority,
            },
            events: tx,
            cancel: cancel.clone(),
            enqueued: Instant::now(),
        });
        (rx, cancel)
    }

    /// Drain a stream: (tokens, finish_reason, usage, error).
    fn drain(rx: &Receiver<GenEvent>) -> (Vec<u32>, FinishReason, Usage, Option<String>) {
        let mut tokens = Vec::new();
        loop {
            match rx.recv().expect("stream must end with Done, not disconnect") {
                GenEvent::Token { id, .. } => tokens.push(id),
                GenEvent::Done { finish_reason, usage, error } => {
                    return (tokens, finish_reason, usage, error)
                }
            }
        }
    }

    #[test]
    fn iteration_level_scheduling_shorts_finish_while_long_decodes() {
        // One 64-token request + eight 4-token requests, max_batch 4:
        // every short request must retire at an earlier sweep than the
        // long one (which would be impossible under collect-then-run
        // batching, where the batch drains as a unit).
        let q = SubmitQueue::new();
        let (long_rx, _) = submit(&q, 0, vec![1, 2], 64, 0);
        let short_rxs: Vec<_> =
            (1..=8).map(|i| submit(&q, i, vec![i as u32], 4, 0).0).collect();
        q.close();
        let mut st = MockStepper::new(17, 4096);
        run_scheduler(&mut st, &q, 4, None, None, None).unwrap();

        let (long_toks, long_fin, long_usage, _) = drain(&long_rx);
        assert_eq!(long_toks.len(), 64);
        assert_eq!(long_fin, FinishReason::Length);
        for (i, rx) in short_rxs.iter().enumerate() {
            let (toks, fin, usage, _) = drain(rx);
            assert_eq!(toks.len(), 4, "short request {i}");
            assert_eq!(fin, FinishReason::Length);
            assert!(
                usage.finished_sweep < long_usage.finished_sweep,
                "short {i} finished at sweep {} but long at {} — not iteration-level",
                usage.finished_sweep,
                long_usage.finished_sweep
            );
        }
        assert_eq!(q.load(), 0);
    }

    #[test]
    fn mid_sweep_admission_is_token_identical_to_solo() {
        // Deterministic mid-flight join: with max_batch 2, the third
        // request can only be admitted once the second retires — while
        // the first (long) is still decoding. Its tokens must equal a
        // solo run's.
        let solo = {
            let q = SubmitQueue::new();
            let (rx, _) = submit(&q, 0, vec![5, 9], 6, 0);
            q.close();
            run_scheduler(&mut MockStepper::new(17, 4096), &q, 1, None, None, None).unwrap();
            drain(&rx).0
        };

        let q = SubmitQueue::new();
        let (long_rx, _) = submit(&q, 0, vec![1], 40, 0);
        let (early_rx, _) = submit(&q, 1, vec![2], 3, 0);
        let (joiner_rx, _) = submit(&q, 2, vec![5, 9], 6, 0);
        q.close();
        run_scheduler(&mut MockStepper::new(17, 4096), &q, 2, None, None, None).unwrap();

        let (long_toks, _, long_usage, _) = drain(&long_rx);
        let (_, _, early_usage, _) = drain(&early_rx);
        let (joined, _, joiner_usage, _) = drain(&joiner_rx);
        assert_eq!(long_toks.len(), 40);
        assert_eq!(joined, solo, "mid-sweep admission changed tokens");
        assert!(
            joiner_usage.finished_sweep > early_usage.finished_sweep,
            "joiner was admitted after the early request retired"
        );
        assert!(
            joiner_usage.finished_sweep < long_usage.finished_sweep,
            "joiner must have run inside the long request's sweep"
        );
    }

    #[test]
    fn stepper_failure_emits_done_error_everywhere() {
        let q = SubmitQueue::new();
        let (rx_a, _) = submit(&q, 0, vec![1], 32, 0);
        let (rx_b, _) = submit(&q, 1, vec![2], 32, 0);
        q.close();
        let mut st = MockStepper::new(17, 4096);
        st.fail_at_sweep = Some(4);
        let res = run_scheduler(&mut st, &q, 4, None, None, None);
        assert!(res.is_err(), "scheduler must propagate the engine error");
        for rx in [&rx_a, &rx_b] {
            let (toks, fin, _, err) = drain(rx);
            assert_eq!(fin, FinishReason::Error);
            assert!(err.unwrap().contains("mock sweep failure"));
            assert!(toks.len() < 32, "failure struck mid-generation");
        }
        assert_eq!(q.load(), 0, "failed requests still count as finished");
    }

    #[test]
    fn cancellation_mid_generation_retires_at_sweep_boundary() {
        let q = SubmitQueue::new();
        let (rx, cancel) = submit(&q, 0, vec![3], 100_000, 0);
        let q2 = q.clone();
        let h = thread::spawn(move || {
            let mut st = MockStepper::new(17, 1 << 20);
            run_scheduler(&mut st, &q2, 2, None, None, None)
        });
        // Wait until generation is demonstrably in flight…
        let first = rx.recv().unwrap();
        assert!(matches!(first, GenEvent::Token { .. }));
        // …then cancel and expect a terminal Cancelled event.
        cancel.cancel();
        let (more, fin, usage, _) = drain(&rx);
        assert_eq!(fin, FinishReason::Cancelled);
        assert!(usage.completion_tokens >= 1);
        assert!((usage.completion_tokens as u64) < 100_000);
        let _ = more;
        q.close();
        h.join().unwrap().unwrap();
        assert_eq!(q.load(), 0);
    }

    #[test]
    fn cancelled_while_queued_never_claims_a_slot() {
        let q = SubmitQueue::new();
        let (rx, cancel) = submit(&q, 0, vec![1], 8, 0);
        cancel.cancel();
        q.close();
        let mut st = MockStepper::new(17, 64);
        run_scheduler(&mut st, &q, 2, None, None, None).unwrap();
        let (toks, fin, usage, _) = drain(&rx);
        assert!(toks.is_empty());
        assert_eq!(fin, FinishReason::Cancelled);
        assert_eq!(usage.completion_tokens, 0);
        assert_eq!(st.sweeps, 0, "a queued-cancelled request must not be stepped");
    }

    #[test]
    fn priority_orders_admission() {
        // max_batch 1 serializes the sweep: completion order == admission
        // order == priority order (FIFO inside a priority level).
        let q = SubmitQueue::new();
        let (rx0, _) = submit(&q, 0, vec![1], 2, 0);
        let (rx1, _) = submit(&q, 1, vec![2], 2, 5);
        let (rx2, _) = submit(&q, 2, vec![3], 2, 1);
        q.close();
        run_scheduler(&mut MockStepper::new(17, 64), &q, 1, None, None, None).unwrap();
        let s0 = drain(&rx0).2.finished_sweep;
        let s1 = drain(&rx1).2.finished_sweep;
        let s2 = drain(&rx2).2.finished_sweep;
        assert!(s1 < s2 && s2 < s0, "expected priority order 1,2,0 — got {s1},{s2},{s0}");
    }

    #[test]
    fn dropped_receiver_cancels_decode() {
        let q = SubmitQueue::new();
        let (rx, _) = submit(&q, 0, vec![1], 10_000, 0);
        drop(rx);
        q.close();
        let mut st = MockStepper::new(17, 1 << 20);
        run_scheduler(&mut st, &q, 1, None, None, None).unwrap();
        // prompt (1) + first generated token whose send fails ⇒ ~2 sweeps,
        // nowhere near max_new.
        assert!(st.sweeps <= 3, "decode must stop for an unread stream ({} sweeps)", st.sweeps);
        assert_eq!(q.load(), 0);
    }

    #[test]
    fn max_new_zero_emits_done_only() {
        let q = SubmitQueue::new();
        let (rx, _) = submit(&q, 0, vec![1, 2, 3], 0, 0);
        q.close();
        run_scheduler(&mut MockStepper::new(17, 64), &q, 1, None, None, None).unwrap();
        let (toks, fin, usage, _) = drain(&rx);
        assert!(toks.is_empty());
        assert_eq!(fin, FinishReason::Length);
        assert_eq!(usage.prompt_tokens, 3);
        assert_eq!(usage.ttft_us, 0, "no token ⇒ no TTFT");
    }

    #[test]
    fn stop_token_finishes_without_emitting_it() {
        // Discover the greedy stream, then re-run with its 3rd token as
        // a stop token: the stream must end with Stop after 2 tokens.
        let greedy = {
            let q = SubmitQueue::new();
            let (rx, _) = submit(&q, 0, vec![4], 6, 0);
            q.close();
            run_scheduler(&mut MockStepper::new(17, 64), &q, 1, None, None, None).unwrap();
            drain(&rx).0
        };
        assert_eq!(greedy.len(), 6);
        let q = SubmitQueue::new();
        let (tx, rx) = channel();
        q.push(Pending {
            request: GenRequest {
                id: 0,
                prompt: vec![4],
                params: SamplingParams {
                    max_new: 6,
                    stop_tokens: vec![greedy[2]],
                    ..Default::default()
                },
                priority: 0,
            },
            events: tx,
            cancel: CancelHandle::new(),
            enqueued: Instant::now(),
        });
        q.close();
        run_scheduler(&mut MockStepper::new(17, 64), &q, 1, None, None, None).unwrap();
        let (toks, fin, usage, _) = drain(&rx);
        assert_eq!(toks, greedy[..2].to_vec());
        assert_eq!(fin, FinishReason::Stop);
        assert_eq!(usage.completion_tokens, 2);
    }
}
