//! Iteration-level scheduler: one persistent decode sweep per worker.
//!
//! The scheduler owns the Orca-style continuous-batching loop the
//! module docs describe. Each iteration it:
//!
//! 1. retires sessions whose [`CancelHandle`](super::CancelHandle) was
//!    flagged (slot released **before** `Done{Cancelled}` is sent);
//! 2. admits queued requests into free batch slots (blocking on the
//!    [`SubmitQueue`] only when *nothing* is active) — prompt prefill
//!    starts on the very next sweep, joining whatever is in flight;
//! 3. gathers this sweep's work under a token budget ([`ChunkPolicy`]):
//!    every decoding session claims one budget token first, then
//!    prefilling sessions fill the remainder with prompt **chunks** of
//!    up to `chunk` tokens each (Sarathi-style chunked prefill). Decode
//!    lanes and chunk-of-one prefill tails run as one fused
//!    [`Stepper::step_batch`]; multi-token chunks go through
//!    [`Stepper::step_prefill_chunk`], which stores K/V for the whole
//!    chunk in one pass and returns only the final position's logits;
//! 4. samples each generating session's logits via
//!    [`crate::model::sample`] (seeded per request; temp=0 ≡ argmax),
//!    emits `Token{id, logprob}` events as they are produced, and
//!    retires finished sessions immediately so their slots are free for
//!    the next iteration's admission.
//!
//! The loop is engine-agnostic: the [`Stepper`] decides whether a sweep
//! is executed as independent per-session steps (native), one fused
//! multi-session pass (LUT), or sequential AOT-executable calls (PJRT).
//! A stepper error retires every in-flight session with
//! `Done{finish_reason: Error}` — callers always observe a terminal
//! event, never a silent drop.

use super::batcher::{Pending, SubmitQueue};
use super::kv::KvArena;
use super::metrics::{Metrics, RetireSample};
use super::prefix::PrefixCache;
use super::{FinishReason, GenEvent, Usage};
use crate::model::sample;
use crate::rng::Rng;
use anyhow::Result;
use std::time::Instant;

/// Per-sweep chunked-prefill policy (Sarathi-style). `chunk` caps how
/// many prompt tokens one prefilling session may consume per sweep;
/// `budget` is the sweep-wide token budget shared by decode and
/// prefill, with decode claiming first (1 token per generating
/// session, unconditionally — a sampled token must be fed, and this is
/// the fairness rule that keeps prefill from starving decode). The
/// scheduler always advances at least one token per sweep, so a
/// too-small budget degrades to one-token-per-sweep prefill rather
/// than stalling. `ChunkPolicy::default()` (chunk 1, unbounded budget)
/// reproduces the legacy one-token-per-sweep prefill exactly.
#[derive(Clone, Copy, Debug)]
pub(crate) struct ChunkPolicy {
    pub chunk: usize,
    pub budget: usize,
}

impl Default for ChunkPolicy {
    fn default() -> Self {
        Self { chunk: 1, budget: usize::MAX }
    }
}

/// One in-flight decode session: KV state + position bookkeeping. The
/// stepping itself belongs to the [`Stepper`] so batched engines can
/// fuse a whole sweep.
pub(crate) trait Session {
    fn pos(&self) -> usize;
    fn capacity(&self) -> usize;

    /// Borrow a cached prompt prefix at admission (see
    /// [`PrefixCache::match_and_borrow`]); returns how many prompt
    /// tokens are already resident so the scheduler prefills only the
    /// suffix. Engines without prefix support keep the default miss.
    fn prefix_match(&mut self, _cache: &PrefixCache, _prompt: &[u32]) -> usize {
        0
    }

    /// Publish this session's prompt pages into the cache once the full
    /// prompt has been fed. Default: not supported, no-op.
    fn prefix_publish(&mut self, _cache: &PrefixCache, _prompt: &[u32]) {}
}

/// Executes one sweep: each session advances by exactly one token.
pub(crate) trait Stepper {
    type Sess: Session;

    /// Create a fresh session (claims a KV-arena slot where applicable;
    /// panics on arena exhaustion, like the capacity assert).
    fn make(&self) -> Self::Sess;

    /// Step session `i` with `tokens[i]`; returns next-token logits per
    /// session, in order. An `Err` poisons the whole sweep — the
    /// scheduler retires every stepped session with `FinishReason::Error`.
    fn step_batch(
        &mut self,
        sessions: &mut [&mut Self::Sess],
        tokens: &[u32],
    ) -> Result<Vec<Vec<f32>>>;

    /// Multi-token prefill: feed `tokens` at consecutive positions of
    /// one session, storing K/V for the whole chunk, and return only
    /// the **final** position's next-token logits (earlier positions
    /// predict known prompt tokens, so their logits are discarded).
    /// Must be token-identical to feeding the chunk through
    /// `step_batch` one token at a time — the default does exactly
    /// that, so single-token engines are correct by construction;
    /// batched engines override it with a fused chunk pass.
    fn step_prefill_chunk(&mut self, sess: &mut Self::Sess, tokens: &[u32]) -> Result<Vec<f32>> {
        let mut last = Vec::new();
        for &t in tokens {
            let mut lane = [&mut *sess];
            last = self.step_batch(&mut lane, &[t])?.pop().unwrap_or_default();
        }
        Ok(last)
    }
}

/// A request admitted into the sweep. Per-token latency samples are
/// buffered here and flushed to the shared [`Metrics`] in one call at
/// retirement — the decode hot loop never takes the metrics mutex per
/// token.
struct ActiveGen<S> {
    p: Pending,
    sess: S,
    prompt_left: std::vec::IntoIter<u32>,
    next_token: Option<u32>,
    n_out: usize,
    rng: Rng,
    admitted: Instant,
    first_tok: Option<Instant>,
    last_tok: Option<Instant>,
    /// Buffered inter-token gaps (µs), one per token after the first.
    itl_us: Vec<u64>,
    /// When the last prompt token was processed (prefill completion);
    /// `None` if the session retires mid-prefill.
    prefill_done: Option<Instant>,
    /// Prompt tokens actually fed through the engine (the cache-miss
    /// suffix when a prefix-cache hit skipped the head).
    n_prefill: usize,
    /// Whether this session's prompt pages were published to the prefix
    /// cache (exactly once, at prefill completion).
    published: bool,
}

/// Retire a session: release its KV slot (dropping `sess` releases the
/// arena handle) **before** the terminal event is sent — and snapshot
/// the arena into the metrics in between — so a caller observing `Done`
/// knows the slot is reusable and the metrics already reflect it.
#[allow(clippy::too_many_arguments)]
fn retire<S>(
    a: ActiveGen<S>,
    finish_reason: FinishReason,
    error: Option<String>,
    sweep: u64,
    queue: &SubmitQueue,
    metrics: Option<&Metrics>,
    arena: Option<&KvArena>,
) {
    let ActiveGen { p, sess, n_out, admitted, first_tok, itl_us, prefill_done, n_prefill, .. } = a;
    drop(sess);
    if let (Some(m), Some(ar)) = (metrics, arena) {
        m.observe_arena(ar.id(), ar.stats());
    }
    let now = Instant::now();
    let ttft_us = first_tok.map(|t| (t - p.enqueued).as_micros() as u64);
    let prefill_us = prefill_done.map(|t| (t - admitted).as_micros() as u64);
    let usage = Usage {
        prompt_tokens: p.request.prompt.len(),
        completion_tokens: n_out,
        queue_us: (admitted - p.enqueued).as_micros() as u64,
        prefill_us: prefill_us.unwrap_or(0),
        ttft_us: ttft_us.unwrap_or(0),
        total_us: (now - p.enqueued).as_micros() as u64,
        finished_sweep: sweep,
    };
    let _ = p.events.send(GenEvent::Done { finish_reason, usage, error });
    queue.finish_one();
    if let Some(m) = metrics {
        m.record_retired(RetireSample {
            finish: finish_reason,
            queue_us: usage.queue_us,
            ttft_us,
            prefill_us,
            prefill_tokens: n_prefill,
            itl_us: &itl_us,
            tokens: n_out,
            decode_us: (now - admitted).as_micros() as u64,
        });
    }
}

fn admit<St: Stepper>(
    stepper: &St,
    p: Pending,
    cache: Option<&PrefixCache>,
) -> ActiveGen<St::Sess> {
    let rng = Rng::new(p.request.params.seed);
    let mut sess = stepper.make();
    let mut prompt_left = p.request.prompt.clone().into_iter();
    if let Some(c) = cache {
        // Prefix-cache admission: borrow the matched pages and skip the
        // resident prompt tokens — only the cache-miss suffix is
        // prefilled (this is where cache-hit TTFT collapses).
        let matched = sess.prefix_match(c, &p.request.prompt);
        if matched > 0 {
            let _ = prompt_left.nth(matched - 1);
        }
    }
    ActiveGen {
        sess,
        prompt_left,
        next_token: None,
        n_out: 0,
        rng,
        admitted: Instant::now(),
        first_tok: None,
        last_tok: None,
        itl_us: Vec::new(),
        prefill_done: None,
        n_prefill: 0,
        published: false,
        p,
    }
}

/// What one active session does this sweep. `Single` lanes (decode
/// steps and chunk-of-one prefill tails) fuse into one `step_batch`
/// call; `Chunk` sessions run a multi-token prefill pass each and are
/// rewritten to `Logits` once executed; `Hold` sessions carry over
/// untouched (budget exhausted this sweep).
enum Plan {
    Hold,
    Single(u32),
    Chunk(Vec<u32>),
    Logits(Vec<f32>),
}

/// Run the persistent scheduling loop until the queue is closed and
/// drained (graceful) or the stepper fails (every in-flight request is
/// retired with `Error` first).
///
/// Sweep contract (`bpdq lint` L3/L4): this loop must never panic or
/// block on a lock mid-sweep — a panic here strands every in-flight
/// stream without a `Done` event, and a lock would stall all sessions
/// at once. Allocation is fine (per-sweep vectors), hence `sweep`, not
/// `hot`.
// lint: sweep
pub(crate) fn run_scheduler<St: Stepper>(
    stepper: &mut St,
    queue: &SubmitQueue,
    max_batch: usize,
    policy: ChunkPolicy,
    metrics: Option<&Metrics>,
    arena: Option<&KvArena>,
    cache: Option<&PrefixCache>,
) -> Result<()> {
    let max_batch = max_batch.max(1);
    let mut active: Vec<ActiveGen<St::Sess>> = Vec::new();
    let mut sweep: u64 = 0;

    'serve: loop {
        // 1. Sweep-boundary cancellation: retire flagged sessions first
        // so their slots are admissible this very iteration.
        let mut keep = Vec::with_capacity(active.len());
        for a in active {
            if a.p.cancel.is_cancelled() {
                retire(a, FinishReason::Cancelled, None, sweep, queue, metrics, arena);
            } else {
                keep.push(a);
            }
        }
        active = keep;

        // 2. Admission into free slots. Block only when idle; a busy
        // sweep drains whatever is queued without waiting.
        while active.len() < max_batch {
            let next = if active.is_empty() {
                match queue.pop_blocking() {
                    Some(p) => p,
                    None => break 'serve, // closed & drained, nothing active
                }
            } else {
                match queue.try_pop() {
                    Some(p) => p,
                    None => break,
                }
            };
            if next.cancel.is_cancelled() {
                // Cancelled while still queued: terminal event, no slot.
                let queue_us = next.enqueued.elapsed().as_micros() as u64;
                next.reject(FinishReason::Cancelled, None);
                queue.finish_one();
                if let Some(m) = metrics {
                    m.record_retired(RetireSample {
                        finish: FinishReason::Cancelled,
                        queue_us,
                        ttft_us: None,
                        prefill_us: None,
                        prefill_tokens: 0,
                        itl_us: &[],
                        tokens: 0,
                        decode_us: 0,
                    });
                }
                continue;
            }
            active.push(admit(stepper, next, cache));
        }

        // 3. Budgeted gather. Decode lanes claim one budget token each
        // first — a sampled token must always be fed, which is exactly
        // the rule that keeps prefill from starving decode. Sessions
        // out of prompt+generation or KV capacity retire instead.
        let mut entries: Vec<(ActiveGen<St::Sess>, Plan)> = Vec::with_capacity(active.len());
        let mut budget = policy.budget;
        let mut stepped = 0usize;
        for mut a in active {
            let capacity_left = a.sess.capacity() - a.sess.pos();
            match a.next_token.take() {
                Some(t) if capacity_left > 0 => {
                    budget = budget.saturating_sub(1);
                    stepped += 1;
                    entries.push((a, Plan::Single(t)));
                }
                Some(_) => retire(a, FinishReason::Length, None, sweep, queue, metrics, arena),
                None if capacity_left == 0 || a.prompt_left.as_slice().is_empty() => {
                    retire(a, FinishReason::Length, None, sweep, queue, metrics, arena)
                }
                None => entries.push((a, Plan::Hold)),
            }
        }
        // Prefilling sessions split what's left of the budget, in
        // admission order, at most one chunk each per sweep (the rule
        // that keeps decode from starving prefill). A session whose
        // share is zero holds its slot and retries next sweep; if
        // nothing at all claimed the budget, the first prefiller is
        // forced one token so every sweep makes progress.
        for (a, plan) in entries.iter_mut() {
            if !matches!(plan, Plan::Hold) {
                continue;
            }
            let capacity_left = a.sess.capacity() - a.sess.pos();
            let want = policy.chunk.max(1).min(a.prompt_left.len()).min(capacity_left);
            let mut take = want.min(budget);
            if take == 0 && stepped == 0 {
                take = 1;
            }
            if take == 0 {
                continue;
            }
            stepped += 1;
            budget = budget.saturating_sub(take);
            a.n_prefill += take;
            if take == 1 {
                if let Some(t) = a.prompt_left.next() {
                    *plan = Plan::Single(t);
                }
            } else {
                let chunk: Vec<u32> = a.prompt_left.by_ref().take(take).collect();
                *plan = Plan::Chunk(chunk);
            }
        }
        if entries.is_empty() {
            active = Vec::new();
            continue;
        }
        if let Some(m) = metrics {
            m.record_decode_sweep(stepped);
        }
        sweep += 1;

        // 4a. One fused pass over every single-token lane — at
        // `chunk == 1` this is exactly the legacy per-session sweep.
        let singles_res = {
            let mut refs: Vec<&mut St::Sess> = Vec::new();
            let mut tokens: Vec<u32> = Vec::new();
            for (a, plan) in entries.iter_mut() {
                if let Plan::Single(t) = plan {
                    tokens.push(*t);
                    refs.push(&mut a.sess);
                }
            }
            if tokens.is_empty() {
                Ok(Vec::new())
            } else {
                stepper.step_batch(&mut refs, &tokens)
            }
        };
        // 4b. Multi-token prefill chunks, one fused chunk pass each:
        // K/V for the whole chunk lands in one store pass and only the
        // final position's logits come back.
        let mut sweep_err = None;
        let singles_logits = match singles_res {
            Ok(l) => l,
            Err(e) => {
                sweep_err = Some(e);
                Vec::new()
            }
        };
        if sweep_err.is_none() {
            for (a, plan) in entries.iter_mut() {
                let toks = match plan {
                    Plan::Chunk(toks) => std::mem::take(toks),
                    _ => continue,
                };
                match stepper.step_prefill_chunk(&mut a.sess, &toks) {
                    Ok(l) => *plan = Plan::Logits(l),
                    Err(e) => {
                        sweep_err = Some(e);
                        break;
                    }
                }
            }
        }
        if let Some(e) = sweep_err {
            // A poisoned sweep retires *everything* in flight — held
            // sessions included — so every caller sees a terminal event.
            let msg = format!("{e:#}");
            for (a, _) in entries {
                retire(a, FinishReason::Error, Some(msg.clone()), sweep, queue, metrics, arena);
            }
            return Err(e);
        }

        // 5. Sample, emit token events, retire finished sessions now so
        // their slots are re-admitted on the next iteration.
        let mut singles_iter = singles_logits.into_iter();
        let mut still = Vec::with_capacity(entries.len());
        for (mut a, plan) in entries {
            let logits = match plan {
                Plan::Hold => {
                    still.push(a); // budget exhausted: retry next sweep
                    continue;
                }
                Plan::Single(_) => singles_iter.next().unwrap_or_default(),
                Plan::Logits(l) => l,
                Plan::Chunk(_) => Vec::new(), // unreachable: executed in 4b
            };
            if !a.prompt_left.as_slice().is_empty() {
                still.push(a); // prefill: logits discarded until the last prompt token
                continue;
            }
            if !a.published {
                // Prefill just completed: timestamp it, then publish the
                // prompt's pages (refcount bumps only) before any
                // generated token can overwrite the page holding the
                // last prompt position.
                a.prefill_done = Some(Instant::now());
                if let Some(c) = cache {
                    a.sess.prefix_publish(c, &a.p.request.prompt);
                }
                a.published = true;
            }
            if a.n_out >= a.p.request.params.max_new {
                // max_new == 0: the prompt was consumed but nothing may
                // be sampled.
                retire(a, FinishReason::Length, None, sweep, queue, metrics, arena);
                continue;
            }
            let (tok, logprob) = {
                let prm = &a.p.request.params;
                sample(&logits, prm.temperature, prm.top_k, prm.top_p, &mut a.rng)
            };
            let tok = tok as u32;
            if a.p.request.params.stop_tokens.contains(&tok) {
                retire(a, FinishReason::Stop, None, sweep, queue, metrics, arena);
                continue;
            }
            // Timestamp the emission; the gap is buffered locally and
            // flushed to the metrics in one call at retirement.
            let now = Instant::now();
            if let Some(prev) = a.last_tok {
                a.itl_us.push((now - prev).as_micros() as u64);
            }
            a.first_tok.get_or_insert(now);
            a.last_tok = Some(now);
            if a.p.events.send(GenEvent::Token { id: tok, logprob }).is_err() {
                // Receiver gone — implicit cancellation; stop decoding
                // for a stream nobody is reading.
                retire(a, FinishReason::Cancelled, None, sweep, queue, metrics, arena);
                continue;
            }
            a.n_out += 1;
            if a.n_out >= a.p.request.params.max_new {
                retire(a, FinishReason::Length, None, sweep, queue, metrics, arena);
            } else {
                a.next_token = Some(tok);
                still.push(a);
            }
        }
        active = still;

        if let (Some(m), Some(ar)) = (metrics, arena) {
            m.observe_arena(ar.id(), ar.stats());
        }
        if let (Some(m), Some(c)) = (metrics, cache) {
            m.observe_prefix(c.id(), c.stats());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::{CancelHandle, GenRequest, SamplingParams};
    use std::sync::mpsc::{channel, Receiver};
    use std::thread;

    /// Deterministic engine stand-in: the next token depends only on
    /// (fed token, position), so output is independent of batching by
    /// construction and the tests isolate the *scheduler's* behavior.
    struct MockSession {
        pos: usize,
        cap: usize,
    }

    impl Session for MockSession {
        fn pos(&self) -> usize {
            self.pos
        }
        fn capacity(&self) -> usize {
            self.cap
        }
    }

    struct MockStepper {
        vocab: usize,
        cap: usize,
        fail_at_sweep: Option<usize>,
        sweeps: usize,
    }

    impl MockStepper {
        fn new(vocab: usize, cap: usize) -> Self {
            Self { vocab, cap, fail_at_sweep: None, sweeps: 0 }
        }
    }

    impl Stepper for MockStepper {
        type Sess = MockSession;

        fn make(&self) -> MockSession {
            MockSession { pos: 0, cap: self.cap }
        }

        fn step_batch(
            &mut self,
            sessions: &mut [&mut MockSession],
            tokens: &[u32],
        ) -> Result<Vec<Vec<f32>>> {
            self.sweeps += 1;
            if let Some(f) = self.fail_at_sweep {
                if self.sweeps >= f {
                    anyhow::bail!("mock sweep failure");
                }
            }
            Ok(sessions
                .iter_mut()
                .zip(tokens)
                .map(|(s, &t)| {
                    let mut logits = vec![0.0f32; self.vocab];
                    logits[((t as usize) * 7 + s.pos * 3 + 1) % self.vocab] = 1.0;
                    s.pos += 1;
                    logits
                })
                .collect())
        }
    }

    fn submit(
        q: &SubmitQueue,
        id: u64,
        prompt: Vec<u32>,
        max_new: usize,
        priority: u8,
    ) -> (Receiver<GenEvent>, CancelHandle) {
        let (tx, rx) = channel();
        let cancel = CancelHandle::new();
        q.push(Pending {
            request: GenRequest {
                id,
                prompt,
                params: SamplingParams { max_new, ..Default::default() },
                priority,
            },
            events: tx,
            cancel: cancel.clone(),
            enqueued: Instant::now(),
        });
        (rx, cancel)
    }

    /// Drain a stream: (tokens, finish_reason, usage, error).
    fn drain(rx: &Receiver<GenEvent>) -> (Vec<u32>, FinishReason, Usage, Option<String>) {
        let mut tokens = Vec::new();
        loop {
            match rx.recv().expect("stream must end with Done, not disconnect") {
                GenEvent::Token { id, .. } => tokens.push(id),
                GenEvent::Done { finish_reason, usage, error } => {
                    return (tokens, finish_reason, usage, error)
                }
            }
        }
    }

    #[test]
    fn iteration_level_scheduling_shorts_finish_while_long_decodes() {
        // One 64-token request + eight 4-token requests, max_batch 4:
        // every short request must retire at an earlier sweep than the
        // long one (which would be impossible under collect-then-run
        // batching, where the batch drains as a unit).
        let q = SubmitQueue::new();
        let (long_rx, _) = submit(&q, 0, vec![1, 2], 64, 0);
        let short_rxs: Vec<_> =
            (1..=8).map(|i| submit(&q, i, vec![i as u32], 4, 0).0).collect();
        q.close();
        let mut st = MockStepper::new(17, 4096);
        run_scheduler(&mut st, &q, 4, ChunkPolicy::default(), None, None, None).unwrap();

        let (long_toks, long_fin, long_usage, _) = drain(&long_rx);
        assert_eq!(long_toks.len(), 64);
        assert_eq!(long_fin, FinishReason::Length);
        for (i, rx) in short_rxs.iter().enumerate() {
            let (toks, fin, usage, _) = drain(rx);
            assert_eq!(toks.len(), 4, "short request {i}");
            assert_eq!(fin, FinishReason::Length);
            assert!(
                usage.finished_sweep < long_usage.finished_sweep,
                "short {i} finished at sweep {} but long at {} — not iteration-level",
                usage.finished_sweep,
                long_usage.finished_sweep
            );
        }
        assert_eq!(q.load(), 0);
    }

    #[test]
    fn mid_sweep_admission_is_token_identical_to_solo() {
        // Deterministic mid-flight join: with max_batch 2, the third
        // request can only be admitted once the second retires — while
        // the first (long) is still decoding. Its tokens must equal a
        // solo run's.
        let solo = {
            let q = SubmitQueue::new();
            let (rx, _) = submit(&q, 0, vec![5, 9], 6, 0);
            q.close();
            run_scheduler(
                &mut MockStepper::new(17, 4096),
                &q,
                1,
                ChunkPolicy::default(),
                None,
                None,
                None,
            )
            .unwrap();
            drain(&rx).0
        };

        let q = SubmitQueue::new();
        let (long_rx, _) = submit(&q, 0, vec![1], 40, 0);
        let (early_rx, _) = submit(&q, 1, vec![2], 3, 0);
        let (joiner_rx, _) = submit(&q, 2, vec![5, 9], 6, 0);
        q.close();
        run_scheduler(
            &mut MockStepper::new(17, 4096),
            &q,
            2,
            ChunkPolicy::default(),
            None,
            None,
            None,
        )
        .unwrap();

        let (long_toks, _, long_usage, _) = drain(&long_rx);
        let (_, _, early_usage, _) = drain(&early_rx);
        let (joined, _, joiner_usage, _) = drain(&joiner_rx);
        assert_eq!(long_toks.len(), 40);
        assert_eq!(joined, solo, "mid-sweep admission changed tokens");
        assert!(
            joiner_usage.finished_sweep > early_usage.finished_sweep,
            "joiner was admitted after the early request retired"
        );
        assert!(
            joiner_usage.finished_sweep < long_usage.finished_sweep,
            "joiner must have run inside the long request's sweep"
        );
    }

    #[test]
    fn stepper_failure_emits_done_error_everywhere() {
        let q = SubmitQueue::new();
        let (rx_a, _) = submit(&q, 0, vec![1], 32, 0);
        let (rx_b, _) = submit(&q, 1, vec![2], 32, 0);
        q.close();
        let mut st = MockStepper::new(17, 4096);
        st.fail_at_sweep = Some(4);
        let res = run_scheduler(&mut st, &q, 4, ChunkPolicy::default(), None, None, None);
        assert!(res.is_err(), "scheduler must propagate the engine error");
        for rx in [&rx_a, &rx_b] {
            let (toks, fin, _, err) = drain(rx);
            assert_eq!(fin, FinishReason::Error);
            assert!(err.unwrap().contains("mock sweep failure"));
            assert!(toks.len() < 32, "failure struck mid-generation");
        }
        assert_eq!(q.load(), 0, "failed requests still count as finished");
    }

    #[test]
    fn cancellation_mid_generation_retires_at_sweep_boundary() {
        let q = SubmitQueue::new();
        let (rx, cancel) = submit(&q, 0, vec![3], 100_000, 0);
        let q2 = q.clone();
        let h = thread::spawn(move || {
            let mut st = MockStepper::new(17, 1 << 20);
            run_scheduler(&mut st, &q2, 2, ChunkPolicy::default(), None, None, None)
        });
        // Wait until generation is demonstrably in flight…
        let first = rx.recv().unwrap();
        assert!(matches!(first, GenEvent::Token { .. }));
        // …then cancel and expect a terminal Cancelled event.
        cancel.cancel();
        let (more, fin, usage, _) = drain(&rx);
        assert_eq!(fin, FinishReason::Cancelled);
        assert!(usage.completion_tokens >= 1);
        assert!((usage.completion_tokens as u64) < 100_000);
        let _ = more;
        q.close();
        h.join().unwrap().unwrap();
        assert_eq!(q.load(), 0);
    }

    #[test]
    fn cancelled_while_queued_never_claims_a_slot() {
        let q = SubmitQueue::new();
        let (rx, cancel) = submit(&q, 0, vec![1], 8, 0);
        cancel.cancel();
        q.close();
        let mut st = MockStepper::new(17, 64);
        run_scheduler(&mut st, &q, 2, ChunkPolicy::default(), None, None, None).unwrap();
        let (toks, fin, usage, _) = drain(&rx);
        assert!(toks.is_empty());
        assert_eq!(fin, FinishReason::Cancelled);
        assert_eq!(usage.completion_tokens, 0);
        assert_eq!(st.sweeps, 0, "a queued-cancelled request must not be stepped");
    }

    #[test]
    fn priority_orders_admission() {
        // max_batch 1 serializes the sweep: completion order == admission
        // order == priority order (FIFO inside a priority level).
        let q = SubmitQueue::new();
        let (rx0, _) = submit(&q, 0, vec![1], 2, 0);
        let (rx1, _) = submit(&q, 1, vec![2], 2, 5);
        let (rx2, _) = submit(&q, 2, vec![3], 2, 1);
        q.close();
        run_scheduler(
            &mut MockStepper::new(17, 64),
            &q,
            1,
            ChunkPolicy::default(),
            None,
            None,
            None,
        )
        .unwrap();
        let s0 = drain(&rx0).2.finished_sweep;
        let s1 = drain(&rx1).2.finished_sweep;
        let s2 = drain(&rx2).2.finished_sweep;
        assert!(s1 < s2 && s2 < s0, "expected priority order 1,2,0 — got {s1},{s2},{s0}");
    }

    #[test]
    fn dropped_receiver_cancels_decode() {
        let q = SubmitQueue::new();
        let (rx, _) = submit(&q, 0, vec![1], 10_000, 0);
        drop(rx);
        q.close();
        let mut st = MockStepper::new(17, 1 << 20);
        run_scheduler(&mut st, &q, 1, ChunkPolicy::default(), None, None, None).unwrap();
        // prompt (1) + first generated token whose send fails ⇒ ~2 sweeps,
        // nowhere near max_new.
        assert!(st.sweeps <= 3, "decode must stop for an unread stream ({} sweeps)", st.sweeps);
        assert_eq!(q.load(), 0);
    }

    #[test]
    fn max_new_zero_emits_done_only() {
        let q = SubmitQueue::new();
        let (rx, _) = submit(&q, 0, vec![1, 2, 3], 0, 0);
        q.close();
        run_scheduler(
            &mut MockStepper::new(17, 64),
            &q,
            1,
            ChunkPolicy::default(),
            None,
            None,
            None,
        )
        .unwrap();
        let (toks, fin, usage, _) = drain(&rx);
        assert!(toks.is_empty());
        assert_eq!(fin, FinishReason::Length);
        assert_eq!(usage.prompt_tokens, 3);
        assert_eq!(usage.ttft_us, 0, "no token ⇒ no TTFT");
    }

    #[test]
    fn stop_token_finishes_without_emitting_it() {
        // Discover the greedy stream, then re-run with its 3rd token as
        // a stop token: the stream must end with Stop after 2 tokens.
        let greedy = {
            let q = SubmitQueue::new();
            let (rx, _) = submit(&q, 0, vec![4], 6, 0);
            q.close();
            run_scheduler(
                &mut MockStepper::new(17, 64),
                &q,
                1,
                ChunkPolicy::default(),
                None,
                None,
                None,
            )
            .unwrap();
            drain(&rx).0
        };
        assert_eq!(greedy.len(), 6);
        let q = SubmitQueue::new();
        let (tx, rx) = channel();
        q.push(Pending {
            request: GenRequest {
                id: 0,
                prompt: vec![4],
                params: SamplingParams {
                    max_new: 6,
                    stop_tokens: vec![greedy[2]],
                    ..Default::default()
                },
                priority: 0,
            },
            events: tx,
            cancel: CancelHandle::new(),
            enqueued: Instant::now(),
        });
        q.close();
        run_scheduler(
            &mut MockStepper::new(17, 64),
            &q,
            1,
            ChunkPolicy::default(),
            None,
            None,
            None,
        )
        .unwrap();
        let (toks, fin, usage, _) = drain(&rx);
        assert_eq!(toks, greedy[..2].to_vec());
        assert_eq!(fin, FinishReason::Stop);
        assert_eq!(usage.completion_tokens, 2);
    }

    /// One full run at a given policy: (tokens, finish, usage).
    fn run_one(prompt: Vec<u32>, max_new: usize, policy: ChunkPolicy) -> (Vec<u32>, Usage) {
        let q = SubmitQueue::new();
        let (rx, _) = submit(&q, 0, prompt, max_new, 0);
        q.close();
        run_scheduler(&mut MockStepper::new(17, 4096), &q, 2, policy, None, None, None).unwrap();
        let (toks, fin, usage, _) = drain(&rx);
        assert_eq!(fin, FinishReason::Length);
        (toks, usage)
    }

    #[test]
    fn chunked_prefill_is_token_identical_and_saves_sweeps() {
        // The default Stepper::step_prefill_chunk replays the chunk one
        // token at a time, so this pins the *scheduler's* bookkeeping:
        // same tokens, same usage counts, strictly fewer sweeps.
        let prompt: Vec<u32> = (0..13).map(|t| t % 7).collect();
        let (base_toks, base_usage) = run_one(prompt.clone(), 5, ChunkPolicy::default());
        for chunk in [2usize, 3, 4, 16] {
            let policy = ChunkPolicy { chunk, budget: usize::MAX };
            let (toks, usage) = run_one(prompt.clone(), 5, policy);
            assert_eq!(toks, base_toks, "chunk {chunk} changed tokens");
            assert_eq!(usage.prompt_tokens, base_usage.prompt_tokens);
            assert!(
                usage.finished_sweep < base_usage.finished_sweep,
                "chunk {chunk}: {} sweeps vs {} unchunked — chunking must shorten prefill",
                usage.finished_sweep,
                base_usage.finished_sweep
            );
            assert!(usage.prefill_us <= usage.ttft_us.max(1), "prefill is part of TTFT");
        }
    }

    #[test]
    fn budget_interleaves_decode_with_chunked_prefill() {
        // A short decoder (A) running next to a long chunked prefill
        // (B) under a tight budget: A must finish at exactly the same
        // sweep as when it runs alone — decode claims the budget first,
        // so the long prompt can never stall it — while B's tokens
        // still match its solo run (interleaving is token-invisible).
        let policy = ChunkPolicy { chunk: 8, budget: 3 };
        let (a_solo, a_solo_usage) = run_one(vec![1], 20, policy);
        let (b_solo, _) = run_one((0..24).map(|t| t % 5).collect(), 2, policy);

        let q = SubmitQueue::new();
        let (a_rx, _) = submit(&q, 0, vec![1], 20, 0);
        let (b_rx, _) = submit(&q, 1, (0..24).map(|t| t % 5).collect(), 2, 0);
        q.close();
        run_scheduler(&mut MockStepper::new(17, 4096), &q, 2, policy, None, None, None).unwrap();
        let (a_toks, _, a_usage, _) = drain(&a_rx);
        let (b_toks, _, _, _) = drain(&b_rx);
        assert_eq!(a_toks, a_solo, "decode tokens changed under mixed load");
        assert_eq!(b_toks, b_solo, "prefill tokens changed under mixed load");
        assert_eq!(
            a_usage.finished_sweep, a_solo_usage.finished_sweep,
            "the long prefill delayed the decoder — budget fairness broken"
        );
    }

    #[test]
    fn zero_budget_still_makes_progress() {
        // Pathological budget 0: the progress guarantee forces one
        // prompt token per sweep, so the run completes with identical
        // tokens (it just degrades to legacy prefill).
        let prompt: Vec<u32> = (0..9).map(|t| t % 6).collect();
        let (base_toks, _) = run_one(prompt.clone(), 4, ChunkPolicy::default());
        let (toks, _) = run_one(prompt, 4, ChunkPolicy { chunk: 8, budget: 0 });
        assert_eq!(toks, base_toks);
    }

    #[test]
    fn cancel_mid_chunked_prefill_retires_without_tokens() {
        // A short request's first token proves the long prompt is still
        // mid-prefill (400 tokens at chunk 2 spans many sweeps); cancel
        // the long one then and expect Done{Cancelled} with no tokens
        // and an empty queue at drain.
        let q = SubmitQueue::new();
        let (long_rx, long_cancel) = submit(&q, 0, (0..400).map(|t| t % 7).collect(), 4, 0);
        let (short_rx, _) = submit(&q, 1, vec![2], 2, 0);
        let q2 = q.clone();
        let h = thread::spawn(move || {
            let policy = ChunkPolicy { chunk: 2, budget: 4 };
            run_scheduler(&mut MockStepper::new(17, 4096), &q2, 2, policy, None, None, None)
        });
        let first = short_rx.recv().unwrap();
        assert!(matches!(first, GenEvent::Token { .. }));
        long_cancel.cancel();
        let (toks, fin, usage, _) = drain(&long_rx);
        assert_eq!(fin, FinishReason::Cancelled);
        assert!(toks.is_empty(), "cancelled during prefill — no tokens expected");
        assert_eq!(usage.completion_tokens, 0);
        assert_eq!(usage.prefill_us, 0, "prefill never completed");
        q.close();
        h.join().unwrap().unwrap();
        assert_eq!(q.load(), 0);
    }
}
