//! KV arena — one pooled slab per model, shared by every decode
//! session, **format-generic** over how a strip is stored.
//!
//! ## Formats and layout
//!
//! The arena owns contiguous u32-word slabs carved into fixed-size
//! **slots**, one per live decode session. A slot holds the session's
//! entire KV state, laid out layer-major, then K/V, then head-major:
//!
//! ```text
//! slot ─┬─ layer 0 ─┬─ K ─┬─ kv-head 0 │ one strip │
//!       │           │     └─ kv-head 1 │ one strip │
//!       │           └─ V ─┬─ kv-head 0 │ one strip │
//!       │                 └─ …
//!       ├─ layer 1 ─ …
//!       └─ layer L-1 ─ …
//! ```
//!
//! What a **strip** (`cap` positions × `head_dim` channels of one
//! kv-head) physically is depends on the slot's [`KvFormat`]:
//!
//! * [`KvFormat::F32`] — `cap × head_dim` f32s, position-major; the
//!   seed layout, bit-identical to every pre-format-generic release:
//!
//!   ```text
//!   strip  = │ pos 0: hd f32 │ pos 1: hd f32 │ … │
//!   bytes/slot = n_layers × 2 × n_kv_heads × cap × head_dim × 4
//!   ```
//!
//! * [`KvFormat::BitPlane`]`{ bits, group }` — the BPDQ variable grid
//!   applied to the cache ([`crate::tensor::kvpack`]): `bits` packed
//!   bit-planes (bit `u·hd + j` of plane *i* = code bit of channel `j`
//!   at position `u` — when `hd < 32` one word holds a whole
//!   position-group) followed by per-(position, channel-group) f16
//!   coefficients `[c₀, c₁, …, c_bits]`, so a row dequantizes as
//!   `x̂ⱼ = c₀ + Σᵢ cᵢ·Bᵢ[j]` (paper Eq. 1):
//!
//!   ```text
//!   strip  = │ plane 0 │ … │ plane bits-1 │ f16 coeffs │
//!   words/strip = bits·⌈cap·hd/32⌉ + ⌈cap·⌈hd/group⌉·(bits+1)/2⌉
//!   bytes/slot  = n_layers × 2 × n_kv_heads × words/strip × 4
//!   ```
//!
//!   At `bits = 2, group = 32, hd = 32` a slot is **9.1× smaller**
//!   than f32 — the decode sweep streams that many fewer bytes per
//!   token, which is the point: attention kernels
//!   ([`crate::tensor::strip_dots_packed`] /
//!   [`crate::tensor::strip_axpys_packed`]) walk the plane words
//!   directly, fusing dequantization into the score/AV passes instead
//!   of materializing f32 rows.
//!
//! Quantization happens **once, at store time**: [`KvViewMut::store_k`]
//! / [`store_v`](KvViewMut::store_v) encode the freshly-computed
//! projection row into the slot (masked writes touching exactly that
//! row's bits). Reads, [`KvArena::fork`], and slot reuse all operate on
//! the packed bytes — a fork is a bytewise prefix copy with **no
//! re-quantization**, even when the fork position lands inside a shared
//! plane word.
//!
//! Layer-major first because the decode sweep visits layers outermost —
//! everything a layer's attention pass touches sits in one contiguous
//! span of the slot. Head-major inside because each head's score pass
//! is then one contiguous strip walk. Making the *slots themselves*
//! adjacent in one slab is what turns the batched serving sweep's
//! score/AV phase into a single multi-session pass per (layer, kv-head)
//! over arena-adjacent strips — in either format.
//!
//! ## Handles and safety
//!
//! aliasing: one live [`KvHandle`] per slot — every raw-pointer carve
//! in this file derives from a handle borrow, distinct slots never
//! overlap, and all offsets are hard-asserted. This header is the
//! protocol declaration `bpdq lint` rule L5 anchors to.
//!
//! [`KvHandle`] is an affine token (slot index + generation; not
//! `Clone`): at most one handle per live slot exists, handed out by
//! [`KvArena::acquire`] and consumed by [`KvArena::release`]. Shared
//! reads go through [`KvView`] (borrows the handle), exclusive writes
//! through [`KvViewMut`] (borrows it mutably). The invariants, keyed
//! by the `bpdq lint` rule that machine-checks each:
//!
//! | Rule | What it pins down here |
//! |------|------------------------|
//! | `L1` | every `unsafe` block/impl below carries a `// SAFETY:` comment naming the invariant it leans on |
//! | `L2`–`L4` | the arena is deliberately *not* hot code: locking (`inner` mutex) and the hard protocol asserts live here at the slot boundary, so the marked decode kernels ([`crate::tensor`], the engine's `fused_attention`) never allocate, panic, or lock |
//! | `L5` | raw-pointer carving (`from_raw_parts*`, `.add`) appears only inside `unsafe` blocks, under this header's protocol: one handle per live slot means distinct slots never alias; strip coordinates, store position, strip length, and fork position are **hard** asserts in every build profile |
//!
//! Handles are stamped with their arena's id and rejected by foreign
//! arenas (`check_owned`); generations catch stale handles
//! ([`KvArena::is_live`], asserted on release). The borrow checker
//! enforces per-slot aliasing discipline through the view borrows.
//!
//! ## Exhaustion and growth
//!
//! The arena starts empty and grows by whole slab segments (doubling,
//! so steady state is one or two big slabs) up to `max_slots`; beyond
//! that `acquire` returns `None` and session construction panics with
//! "KV arena exhausted" — the same loud-failure contract as the decode
//! capacity assert ("KV cache exhausted"). Freed slots are reused LIFO
//! (warmest lines first), which is also what keeps concurrently active
//! sessions in *adjacent* slots for the batched sweep.

use crate::model::Model;
use crate::tensor::{PackedGeom, PackedStrip, PackedStripMut};
use std::marker::PhantomData;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonic arena id source — lets handles be checked against the
/// arena they came from (releasing into a foreign arena would otherwise
/// mint two live handles to one slot).
static NEXT_ARENA_ID: AtomicU64 = AtomicU64::new(1);

/// How a KV strip is stored in the arena. Runtime-only (not serialized
/// into `.tlm` checkpoints): the same weights can serve under any
/// format.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KvFormat {
    /// Dense f32 rows — bit-identical to the pre-format-generic layout.
    F32,
    /// BPDQ-style packed bit-planes + per-plane f16 scalars (see the
    /// module docs and [`crate::tensor::kvpack`]).
    BitPlane {
        /// planes per channel (the paper's W-axis, applied to KV)
        bits: usize,
        /// channels per coefficient group along `head_dim`
        group: usize,
    },
}

impl KvFormat {
    /// Default coefficient-group width (channels sharing one set of
    /// per-plane scalars).
    pub const DEFAULT_GROUP: usize = 32;

    /// Bit-plane format at `bits` with the default group width.
    pub fn bit_plane(bits: usize) -> Self {
        KvFormat::BitPlane { bits, group: Self::DEFAULT_GROUP }
    }

    /// Parse a `--kv-bits` CLI value: `0` = f32, `2..=4` = bit-plane at
    /// the default group. Anything else is a loud error.
    pub fn from_kv_bits(bits: usize) -> anyhow::Result<Self> {
        match bits {
            0 => Ok(KvFormat::F32),
            2..=4 => Ok(Self::bit_plane(bits)),
            other => anyhow::bail!("--kv-bits must be 0 (f32), 2, 3, or 4 — got {other}"),
        }
    }

    pub fn is_packed(&self) -> bool {
        matches!(self, KvFormat::BitPlane { .. })
    }

    /// Short human label ("f32" / "kvq2g32") for summaries and benches.
    pub fn label(&self) -> String {
        match self {
            KvFormat::F32 => "f32".to_string(),
            KvFormat::BitPlane { bits, group } => format!("kvq{bits}g{group}"),
        }
    }
}

/// Geometry of one model's KV slots — everything the arena needs to
/// know about a model, without holding the model (no `Arc` cycle with
/// [`Model`]'s cached arena).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvGeom {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// positions per session — `Model::decode_capacity()`
    pub cap: usize,
    /// physical strip format (f32 or packed bit-planes)
    pub format: KvFormat,
}

impl KvGeom {
    pub fn of(model: &Model) -> Self {
        Self {
            n_layers: model.cfg.n_layers,
            n_kv_heads: model.cfg.n_kv_heads,
            head_dim: model.cfg.head_dim(),
            cap: model.decode_capacity(),
            format: model.cfg.kv_format,
        }
    }

    /// Packed-strip geometry, when the format is a bit-plane one.
    pub fn packed(&self) -> Option<PackedGeom> {
        match self.format {
            KvFormat::F32 => None,
            KvFormat::BitPlane { bits, group } => {
                Some(PackedGeom::new(self.cap, self.head_dim, bits, group))
            }
        }
    }

    /// u32 words per (layer, K/V, kv-head) strip under this format.
    pub fn strip_words(&self) -> usize {
        match self.packed() {
            None => self.cap * self.head_dim, // one f32 per word
            Some(pg) => pg.strip_words(),
        }
    }

    /// u32 words per arena slot.
    pub fn slot_words(&self) -> usize {
        self.n_layers * 2 * self.n_kv_heads * self.strip_words()
    }

    /// **Real packed** bytes per slot (the per-session KV footprint —
    /// `Model::kv_bytes_per_session`). Format-aware: f32 slots cost
    /// `n_layers × 2 × kv_dim × cap × 4` bytes; bit-plane slots cost
    /// the plane words + f16 coefficients actually resident.
    pub fn slot_bytes(&self) -> usize {
        self.slot_words() * 4
    }

    /// Word offset of the (layer, K=0/V=1, kv-head) strip within a
    /// slot. Hard-bounded: this offset feeds the raw-pointer slice
    /// carving in the views, so out-of-range coordinates must never
    /// reach it in any build profile.
    #[inline]
    fn strip_base(&self, layer: usize, which: usize, kvh: usize) -> usize {
        assert!(
            layer < self.n_layers && which < 2 && kvh < self.n_kv_heads,
            "KV strip coordinates out of range"
        );
        ((layer * 2 + which) * self.n_kv_heads + kvh) * self.strip_words()
    }
}

/// Affine ownership token for one arena slot. Not `Clone` — exactly one
/// handle exists per live slot, so `&mut KvHandle` is exclusive access
/// to the slot's memory and `&KvHandle` is shared read access.
pub struct KvHandle {
    slot: usize,
    generation: u64,
    arena_id: u64,
    base: *mut u32,
}

// SAFETY: sending the handle moves exclusive ownership of its slot to
// another thread — the slot region is disjoint from every other live
// handle's (arena invariant: one handle per slot), and all access goes
// through KvView/KvViewMut whose aliasing the borrow checker enforces
// via the handle borrow. The raw `base` pointer is just a pre-resolved
// address; it is never dereferenced except under those views.
unsafe impl Send for KvHandle {}
// SAFETY: `&KvHandle` grants only shared *read* access to the slot
// (KvView); concurrent shared reads of disjoint-or-identical words are
// race-free, and any mutation requires `&mut KvHandle`, which the
// borrow checker makes exclusive across threads.
unsafe impl Sync for KvHandle {}

impl KvHandle {
    pub fn slot(&self) -> usize {
        self.slot
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }
}

/// Cumulative arena counters (surfaced through `serving::metrics` into
/// the serve summary and `BENCH_decode.json`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// live sessions right now
    pub slots_in_use: usize,
    /// most sessions ever live at once
    pub high_water: usize,
    /// slots ever carved out of slabs
    pub slots_created: usize,
    /// acquisitions served from the free list (pooling hit count)
    pub reused: usize,
    /// bytes of slab currently allocated
    pub bytes_resident: usize,
    /// **real packed** bytes per slot under the arena's format (the
    /// format-aware per-session KV footprint)
    pub slot_bytes: usize,
    /// slot-to-slot prefix copies performed by `fork`
    pub fork_copies: u64,
}

struct ArenaInner {
    /// owning slab segments; boxed so the heap buffers never move when
    /// the segment list grows
    segments: Vec<Box<[u32]>>,
    /// per-slot base pointer into its segment, indexed by slot id
    bases: Vec<*mut u32>,
    /// bumped on release; a mismatch means a stale handle
    generations: Vec<u64>,
    /// LIFO free list of slot ids
    free: Vec<usize>,
    in_use: usize,
    high_water: usize,
    reused: usize,
    fork_copies: u64,
    bytes_resident: usize,
}

// SAFETY: the raw per-slot pointers are only dereferenced through
// KvView/KvViewMut under the handle discipline (never through
// ArenaInner itself); the inner bookkeeping is only touched under the
// arena mutex, and the `Box<[u32]>` segments it owns are Send.
unsafe impl Send for ArenaInner {}

/// One pooled KV slab per model. See the module docs for formats,
/// layout, and the handle/ownership contract.
pub struct KvArena {
    id: u64,
    geom: KvGeom,
    initial_slots: usize,
    max_slots: usize,
    inner: Mutex<ArenaInner>,
}

impl std::fmt::Debug for KvArena {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvArena")
            .field("geom", &self.geom)
            .field("stats", &self.stats())
            .finish()
    }
}

impl KvArena {
    /// Arena that grows without bound (by doubling segments).
    pub fn new(geom: KvGeom, initial_slots: usize) -> Self {
        Self::with_limit(geom, initial_slots, usize::MAX)
    }

    /// Arena capped at `max_slots` total; `acquire` returns `None` once
    /// every slot is live.
    pub fn with_limit(geom: KvGeom, initial_slots: usize, max_slots: usize) -> Self {
        assert!(initial_slots > 0, "arena needs at least one slot");
        Self {
            id: NEXT_ARENA_ID.fetch_add(1, Ordering::Relaxed),
            geom,
            initial_slots,
            max_slots,
            inner: Mutex::new(ArenaInner {
                segments: Vec::new(),
                bases: Vec::new(),
                generations: Vec::new(),
                free: Vec::new(),
                in_use: 0,
                high_water: 0,
                reused: 0,
                fork_copies: 0,
                bytes_resident: 0,
            }),
        }
    }

    pub fn geom(&self) -> KvGeom {
        self.geom
    }

    /// Unique id of this arena (stamped into every handle; used to key
    /// per-arena metrics and to reject foreign handles).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Total slots this arena may ever carve (`usize::MAX` = unbounded).
    pub fn max_slots(&self) -> usize {
        self.max_slots
    }

    /// A handle is only meaningful inside the arena that minted it —
    /// releasing or viewing through a foreign arena would break the
    /// one-handle-per-slot invariant the unsafe slice carving relies on.
    #[inline]
    fn check_owned(&self, h: &KvHandle) {
        assert_eq!(h.arena_id, self.id, "KV handle used with a foreign arena");
    }

    /// Carve a fresh segment (doubling growth) into the free list.
    fn grow(&self, inner: &mut ArenaInner) {
        let have = inner.bases.len();
        if have >= self.max_slots {
            return;
        }
        let want = if have == 0 { self.initial_slots } else { have };
        let add = want.min(self.max_slots - have);
        let words = self.geom.slot_words();
        let mut seg = vec![0u32; add * words].into_boxed_slice();
        let base = seg.as_mut_ptr();
        for i in 0..add {
            // SAFETY: `i < add` and the segment holds exactly
            // `add * words` words, so `base + i*words` stays inside the
            // allocation; the boxed slice is pushed onto `segments`
            // below and never moves (the box owns a stable heap
            // buffer), so the carved slot bases remain valid for the
            // arena's lifetime.
            inner.bases.push(unsafe { base.add(i * words) });
            inner.generations.push(0);
        }
        // Push in reverse so LIFO pops hand out ascending slot ids —
        // concurrently-acquired sessions land in adjacent slots.
        for i in (0..add).rev() {
            inner.free.push(have + i);
        }
        inner.bytes_resident += add * words * 4;
        inner.segments.push(seg);
    }

    /// Claim a slot. `None` only when the arena is at `max_slots` with
    /// every slot live — callers turn that into a "KV arena exhausted"
    /// panic, mirroring the decode capacity assert.
    pub fn acquire(&self) -> Option<KvHandle> {
        let mut inner = self.inner.lock().unwrap();
        let slot = match inner.free.pop() {
            Some(s) => {
                inner.reused += 1;
                s
            }
            None => {
                self.grow(&mut inner);
                inner.free.pop()?
            }
        };
        inner.in_use += 1;
        inner.high_water = inner.high_water.max(inner.in_use);
        Some(KvHandle {
            slot,
            generation: inner.generations[slot],
            arena_id: self.id,
            base: inner.bases[slot],
        })
    }

    /// Return a slot to the free list. The generation bump invalidates
    /// any (buggy, unsafe-born) copy of the handle.
    pub fn release(&self, h: KvHandle) {
        self.check_owned(&h);
        let mut inner = self.inner.lock().unwrap();
        assert_eq!(inner.generations[h.slot], h.generation, "double release / stale KV handle");
        inner.generations[h.slot] = inner.generations[h.slot].wrapping_add(1);
        inner.in_use -= 1;
        inner.free.push(h.slot);
    }

    /// Does `(slot, generation)` name a currently-live claim? Stale
    /// handles (released, possibly re-acquired by someone else) answer
    /// `false` — the reuse-after-release safety check.
    pub fn is_live(&self, slot: usize, generation: u64) -> bool {
        let inner = self.inner.lock().unwrap();
        slot < inner.generations.len()
            && inner.generations[slot] == generation
            && !inner.free.contains(&slot)
    }

    /// Word spans `(offset, len)` within one strip that hold the live
    /// prefix of `pos` positions — the fork copy list. F32 strips have
    /// one dense span; packed strips have one span per plane plus the
    /// coefficient prefix (see [`PackedGeom::prefix_spans`]).
    fn prefix_spans(&self, pos: usize) -> Vec<(usize, usize)> {
        match self.geom.packed() {
            None => {
                let n = pos * self.geom.head_dim;
                if n == 0 {
                    Vec::new()
                } else {
                    vec![(0, n)]
                }
            }
            Some(pg) => pg.prefix_spans(pos),
        }
    }

    /// Branch-point copy: claim a fresh slot and copy the live prefix
    /// of every (layer, K/V, head) strip from `src` **bytewise** —
    /// contiguous word copies inside the slab, no re-quantization, no
    /// zeroing of the never-read tails. For packed strips the copied
    /// prefix may end mid-word (a position-group shared with unwritten
    /// positions); the masked store discipline makes the stale tail
    /// bits harmless.
    pub fn fork(&self, src: &KvHandle, pos: usize) -> Option<KvHandle> {
        self.check_owned(src);
        // Hard bound: this arithmetic feeds raw-pointer copies below.
        assert!(pos <= self.geom.cap, "fork position {pos} beyond slot capacity");
        let dst = self.acquire()?;
        let spans = self.prefix_spans(pos);
        if !spans.is_empty() {
            let strip_words = self.geom.strip_words();
            for s in 0..self.geom.n_layers * 2 * self.geom.n_kv_heads {
                let base = s * strip_words;
                for &(off, n) in &spans {
                    // SAFETY: src is live (we hold &KvHandle, so no
                    // KvViewMut can exist) and dst was just acquired (no
                    // other reference); distinct slots never overlap, and
                    // every span lies inside the strip (hard-bounded by
                    // the geometry that computed it).
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            src.base.add(base + off),
                            dst.base.add(base + off),
                            n,
                        );
                    }
                }
            }
        }
        self.inner.lock().unwrap().fork_copies += 1;
        Some(dst)
    }

    /// Shared read access to a slot's strips.
    pub fn view<'a>(&'a self, h: &'a KvHandle) -> KvView<'a> {
        self.check_owned(h);
        debug_assert!(self.is_live(h.slot, h.generation), "stale KV handle");
        KvView { base: h.base, geom: self.geom, _life: PhantomData }
    }

    /// Exclusive read/write access to a slot's strips (requires the
    /// one-and-only handle mutably).
    pub fn view_mut<'a>(&'a self, h: &'a mut KvHandle) -> KvViewMut<'a> {
        self.check_owned(h);
        debug_assert!(self.is_live(h.slot, h.generation), "stale KV handle");
        KvViewMut { base: h.base, geom: self.geom, _life: PhantomData }
    }

    pub fn stats(&self) -> ArenaStats {
        let inner = self.inner.lock().unwrap();
        ArenaStats {
            slots_in_use: inner.in_use,
            high_water: inner.high_water,
            slots_created: inner.bases.len(),
            reused: inner.reused,
            bytes_resident: inner.bytes_resident,
            slot_bytes: self.geom.slot_bytes(),
            fork_copies: inner.fork_copies,
        }
    }
}

/// Shared (read-only) borrow of one slot. Lifetime-tied to both the
/// arena and the handle, so the slot can be neither released nor
/// mutated while a view is out.
pub struct KvView<'a> {
    base: *mut u32,
    geom: KvGeom,
    _life: PhantomData<&'a KvHandle>,
}

/// Strip accessors shared by [`KvView`] and [`KvViewMut`] (the mut view
/// re-exposes them so the decode step can read back what it stored
/// under one exclusive borrow).
macro_rules! impl_strip_readers {
    () => {
        /// The arena's strip format (drives kernel dispatch).
        #[inline]
        pub fn format(&self) -> KvFormat {
            self.geom.format
        }

        /// The first `len` cached K rows of `kvh` in `layer`, contiguous
        /// f32 — [`KvFormat::F32`] slots only.
        #[inline]
        pub fn k_strip(&self, layer: usize, kvh: usize, len: usize) -> &[f32] {
            self.f32_strip(layer, 0, kvh, len)
        }

        /// The first `len` cached V rows of `kvh` in `layer`, contiguous
        /// f32 — [`KvFormat::F32`] slots only.
        #[inline]
        pub fn v_strip(&self, layer: usize, kvh: usize, len: usize) -> &[f32] {
            self.f32_strip(layer, 1, kvh, len)
        }

        /// The packed K strip of `kvh` in `layer` —
        /// [`KvFormat::BitPlane`] slots only.
        #[inline]
        pub fn k_packed(&self, layer: usize, kvh: usize) -> PackedStrip<'_> {
            self.packed_strip(layer, 0, kvh)
        }

        /// The packed V strip of `kvh` in `layer` —
        /// [`KvFormat::BitPlane`] slots only.
        #[inline]
        pub fn v_packed(&self, layer: usize, kvh: usize) -> PackedStrip<'_> {
            self.packed_strip(layer, 1, kvh)
        }

        #[inline]
        fn f32_strip(&self, layer: usize, which: usize, kvh: usize, len: usize) -> &[f32] {
            assert_eq!(self.geom.format, KvFormat::F32, "f32 strip read on a packed arena");
            assert!(len <= self.geom.cap, "strip length beyond slot capacity");
            let off = self.geom.strip_base(layer, which, kvh);
            // SAFETY: within the slot (offset arithmetic hard-bounded by
            // strip_base and the capacity assert); u32 and f32 share
            // size/alignment, and shared reads are fine while the handle
            // is borrowed.
            unsafe {
                std::slice::from_raw_parts(
                    self.base.add(off) as *const f32,
                    len * self.geom.head_dim,
                )
            }
        }

        #[inline]
        fn packed_strip(&self, layer: usize, which: usize, kvh: usize) -> PackedStrip<'_> {
            let pg = self.geom.packed().expect("packed strip read on an f32 arena");
            let off = self.geom.strip_base(layer, which, kvh);
            // SAFETY: the whole strip lies inside the slot (strip_base is
            // hard-bounded and strides by strip_words).
            let words = unsafe {
                std::slice::from_raw_parts(self.base.add(off), pg.strip_words())
            };
            PackedStrip::new(pg, words)
        }
    };
}

impl KvView<'_> {
    impl_strip_readers!();
}

/// Exclusive borrow of one slot (store + read).
pub struct KvViewMut<'a> {
    base: *mut u32,
    geom: KvGeom,
    _life: PhantomData<&'a mut KvHandle>,
}

impl KvViewMut<'_> {
    impl_strip_readers!();

    /// Store one `kv_dim`-wide K projection row into the per-head
    /// strips at position `pos` — dense copy under [`KvFormat::F32`],
    /// bit-plane quantization under [`KvFormat::BitPlane`] (this is the
    /// once-per-token encode; nothing downstream re-quantizes).
    #[inline]
    pub fn store_k(&mut self, layer: usize, pos: usize, row: &[f32]) {
        self.store(layer, 0, pos, row)
    }

    /// Store one `kv_dim`-wide V projection row into the per-head
    /// strips at position `pos` (see [`KvViewMut::store_k`]).
    #[inline]
    pub fn store_v(&mut self, layer: usize, pos: usize, row: &[f32]) {
        self.store(layer, 1, pos, row)
    }

    fn store(&mut self, layer: usize, which: usize, pos: usize, row: &[f32]) {
        let hd = self.geom.head_dim;
        assert_eq!(row.len(), self.geom.n_kv_heads * hd, "KV row width != kv_dim");
        assert!(pos < self.geom.cap, "store position beyond slot capacity");
        match self.geom.packed() {
            None => {
                for kvh in 0..self.geom.n_kv_heads {
                    let off = self.geom.strip_base(layer, which, kvh) + pos * hd;
                    // SAFETY: exclusive access via the &mut handle borrow;
                    // offsets hard-bounded by the asserts above.
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            row.as_ptr().add(kvh * hd),
                            self.base.add(off) as *mut f32,
                            hd,
                        );
                    }
                }
            }
            Some(pg) => {
                for kvh in 0..self.geom.n_kv_heads {
                    let off = self.geom.strip_base(layer, which, kvh);
                    // SAFETY: exclusive access via the &mut handle borrow;
                    // the strip span is hard-bounded by strip_base, and
                    // per-head strips are disjoint.
                    let words = unsafe {
                        std::slice::from_raw_parts_mut(self.base.add(off), pg.strip_words())
                    };
                    PackedStripMut::new(pg, words)
                        .store_row(pos, &row[kvh * hd..(kvh + 1) * hd]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic_model, ModelConfig};
    use std::sync::Arc;

    fn model() -> Arc<Model> {
        Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 12,
                d_model: 8,
                n_layers: 1,
                n_heads: 1,
                n_kv_heads: 1,
                d_ff: 12,
                max_seq: 16,
                kv_format: KvFormat::F32,
            },
            1,
        ))
    }

    fn geom() -> KvGeom {
        KvGeom::of(&model())
    }

    fn packed_geom(bits: usize) -> KvGeom {
        KvGeom { format: KvFormat::bit_plane(bits), ..geom() }
    }

    #[test]
    fn slot_bytes_matches_model_formula() {
        let m = model();
        assert_eq!(KvGeom::of(&m).slot_bytes(), m.kv_bytes_per_session());
        // f32 slots keep the historical formula exactly.
        let g = KvGeom::of(&m);
        assert_eq!(g.slot_bytes(), g.n_layers * 2 * g.n_kv_heads * g.cap * g.head_dim * 4);
    }

    #[test]
    fn packed_slot_bytes_shrink_8x_at_w2() {
        // Acceptance: at bits = 2 the per-slot footprint shrinks ≥ 8×
        // vs f32 on the bench geometry (head_dim 32).
        let f32_geom = KvGeom {
            n_layers: 4,
            n_kv_heads: 4,
            head_dim: 32,
            cap: 1024,
            format: KvFormat::F32,
        };
        let q2 = KvGeom { format: KvFormat::bit_plane(2), ..f32_geom };
        assert!(
            f32_geom.slot_bytes() >= 8 * q2.slot_bytes(),
            "W2 slot must be ≥8× smaller: f32 {} vs packed {}",
            f32_geom.slot_bytes(),
            q2.slot_bytes()
        );
        // Monotone in bits, and every packed format beats f32.
        let q3 = KvGeom { format: KvFormat::bit_plane(3), ..f32_geom };
        let q4 = KvGeom { format: KvFormat::bit_plane(4), ..f32_geom };
        assert!(q2.slot_bytes() < q3.slot_bytes() && q3.slot_bytes() < q4.slot_bytes());
        assert!(q4.slot_bytes() * 3 < f32_geom.slot_bytes());
    }

    #[test]
    fn kv_bits_cli_validation() {
        assert_eq!(KvFormat::from_kv_bits(0).unwrap(), KvFormat::F32);
        assert_eq!(
            KvFormat::from_kv_bits(2).unwrap(),
            KvFormat::BitPlane { bits: 2, group: KvFormat::DEFAULT_GROUP }
        );
        assert!(KvFormat::from_kv_bits(1).is_err());
        assert!(KvFormat::from_kv_bits(5).is_err());
    }

    #[test]
    fn acquire_release_reuses_lifo() {
        let arena = KvArena::new(geom(), 4);
        let a = arena.acquire().unwrap();
        let a_slot = a.slot();
        arena.release(a);
        let b = arena.acquire().unwrap();
        assert_eq!(b.slot(), a_slot, "LIFO reuse of the warmest slot");
        let s = arena.stats();
        assert_eq!(s.reused, 1);
        assert_eq!(s.slots_in_use, 1);
        assert_eq!(s.high_water, 1);
    }

    #[test]
    fn adjacent_acquires_get_adjacent_slots() {
        let arena = KvArena::new(geom(), 4);
        let hs: Vec<KvHandle> = (0..3).map(|_| arena.acquire().unwrap()).collect();
        for (i, h) in hs.iter().enumerate() {
            assert_eq!(h.slot(), i, "batch sessions land in adjacent slots");
        }
        for h in hs {
            arena.release(h);
        }
    }

    #[test]
    fn grows_by_doubling_and_tracks_bytes() {
        let g = geom();
        let arena = KvArena::new(g, 2);
        let hs: Vec<KvHandle> = (0..5).map(|_| arena.acquire().unwrap()).collect();
        let s = arena.stats();
        // segments of 2, 2, 4 slots → 8 carved for 5 live
        assert_eq!(s.slots_created, 8);
        assert_eq!(s.slots_in_use, 5);
        assert_eq!(s.bytes_resident, 8 * g.slot_bytes());
        assert_eq!(s.slot_bytes, g.slot_bytes());
        for h in hs {
            arena.release(h);
        }
        assert_eq!(arena.stats().slots_in_use, 0);
        assert_eq!(arena.stats().high_water, 5);
    }

    #[test]
    fn exhaustion_returns_none_at_limit() {
        let arena = KvArena::with_limit(geom(), 1, 2);
        let a = arena.acquire().unwrap();
        let b = arena.acquire().unwrap();
        assert!(arena.acquire().is_none(), "arena at max_slots must refuse");
        arena.release(a);
        assert!(arena.acquire().is_some(), "released slot acquirable again");
        arena.release(b);
    }

    #[test]
    fn generation_invalidates_released_handles() {
        let arena = KvArena::new(geom(), 2);
        let a = arena.acquire().unwrap();
        let (slot, gen) = (a.slot(), a.generation());
        assert!(arena.is_live(slot, gen));
        arena.release(a);
        assert!(!arena.is_live(slot, gen), "released handle must go stale");
        // Reuse bumps the generation: the new claim is live, the old
        // (slot, gen) pair stays dead — reuse-after-release safety.
        let b = arena.acquire().unwrap();
        assert_eq!(b.slot(), slot);
        assert_ne!(b.generation(), gen);
        assert!(arena.is_live(b.slot(), b.generation()));
        assert!(!arena.is_live(slot, gen));
        arena.release(b);
    }

    #[test]
    #[should_panic(expected = "foreign arena")]
    fn foreign_handle_rejected() {
        // Releasing a handle into a different arena would mint two live
        // handles to one slot — it must fail loudly instead.
        let a = KvArena::new(geom(), 2);
        let b = KvArena::new(geom(), 2);
        let h = a.acquire().unwrap();
        b.release(h);
    }

    #[test]
    fn store_then_strip_roundtrip() {
        let m = model();
        let g = KvGeom::of(&m);
        let arena = KvArena::new(g, 2);
        let mut h = arena.acquire().unwrap();
        let row: Vec<f32> = (0..g.n_kv_heads * g.head_dim).map(|i| i as f32 + 0.5).collect();
        {
            let mut v = arena.view_mut(&mut h);
            v.store_k(0, 0, &row);
            v.store_v(0, 0, &row);
        }
        let v = arena.view(&h);
        assert_eq!(v.k_strip(0, 0, 1), &row[..g.head_dim]);
        assert_eq!(v.v_strip(0, 0, 1), &row[..g.head_dim]);
        arena.release(h);
    }

    #[test]
    fn packed_store_then_dequant_roundtrip() {
        // Arena-level pack→unpack: stored rows dequantize back within
        // one grid step, across layers, heads, K and V.
        for bits in [2usize, 3, 4] {
            let g = KvGeom {
                n_layers: 2,
                n_kv_heads: 2,
                head_dim: 8,
                cap: 8,
                format: KvFormat::BitPlane { bits, group: 8 },
            };
            let arena = KvArena::new(g, 2);
            let mut h = arena.acquire().unwrap();
            let kvd = g.n_kv_heads * g.head_dim;
            let rows: Vec<Vec<f32>> = (0..3)
                .map(|p| (0..kvd).map(|i| ((p * 31 + i * 7) % 13) as f32 * 0.21 - 1.0).collect())
                .collect();
            {
                let mut v = arena.view_mut(&mut h);
                for (p, row) in rows.iter().enumerate() {
                    for l in 0..g.n_layers {
                        v.store_k(l, p, row);
                        v.store_v(l, p, row);
                    }
                }
            }
            let v = arena.view(&h);
            let levels = ((1usize << bits) - 1) as f32;
            let mut out = vec![0.0f32; g.head_dim];
            for l in 0..g.n_layers {
                for kvh in 0..g.n_kv_heads {
                    for (p, row) in rows.iter().enumerate() {
                        let want = &row[kvh * g.head_dim..(kvh + 1) * g.head_dim];
                        let mn = want.iter().cloned().fold(f32::INFINITY, f32::min);
                        let mx = want.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                        let step = (mx - mn) / levels;
                        for (strip, which) in [(v.k_packed(l, kvh), "K"), (v.v_packed(l, kvh), "V")]
                        {
                            strip.dequant_row(p, &mut out);
                            for (j, (&a, &b)) in want.iter().zip(&out).enumerate() {
                                assert!(
                                    (a - b).abs() <= step * 1.001 + 5e-3,
                                    "bits {bits} {which} l {l} kvh {kvh} p {p} j {j}: {a} vs {b}"
                                );
                            }
                        }
                    }
                }
            }
            arena.release(h);
        }
    }

    #[test]
    #[should_panic(expected = "f32 strip read on a packed arena")]
    fn f32_read_on_packed_arena_fails_loudly() {
        let arena = KvArena::new(packed_geom(2), 1);
        let h = arena.acquire().unwrap();
        let _ = arena.view(&h).k_strip(0, 0, 1);
    }

    #[test]
    fn fork_copies_live_prefix_only() {
        let g = KvGeom {
            n_layers: 2,
            n_kv_heads: 2,
            head_dim: 4,
            cap: 8,
            format: KvFormat::F32,
        };
        let arena = KvArena::new(g, 2);
        let mut src = arena.acquire().unwrap();
        for pos in 0..3 {
            let row: Vec<f32> = (0..g.n_kv_heads * g.head_dim)
                .map(|i| (pos * 100 + i) as f32)
                .collect();
            let mut v = arena.view_mut(&mut src);
            for l in 0..g.n_layers {
                v.store_k(l, pos, &row);
                v.store_v(l, pos, &row);
            }
        }
        let dst = arena.fork(&src, 3).unwrap();
        let sv = arena.view(&src);
        let dv = arena.view(&dst);
        for l in 0..g.n_layers {
            for kvh in 0..g.n_kv_heads {
                assert_eq!(sv.k_strip(l, kvh, 3), dv.k_strip(l, kvh, 3), "l {l} kvh {kvh}");
                assert_eq!(sv.v_strip(l, kvh, 3), dv.v_strip(l, kvh, 3), "l {l} kvh {kvh}");
            }
        }
        assert_eq!(arena.stats().fork_copies, 1);
        drop((sv, dv));
        arena.release(src);
        arena.release(dst);
    }

    #[test]
    fn packed_fork_mid_group_is_bytewise_and_decodes_identically() {
        // Satellite: fork at a position *inside* a plane-word
        // position-group (head_dim 4 → 8 positions share each word).
        // The packed prefix is copied bytewise (no re-quantization);
        // after both sessions store the same continuation rows they
        // dequantize identically — and the released slot is reused with
        // a bumped generation.
        let g = KvGeom {
            n_layers: 2,
            n_kv_heads: 2,
            head_dim: 4,
            cap: 16,
            format: KvFormat::BitPlane { bits: 2, group: 4 },
        };
        let arena = KvArena::new(g, 2);
        let mut src = arena.acquire().unwrap();
        let kvd = g.n_kv_heads * g.head_dim;
        let row = |p: usize| -> Vec<f32> {
            (0..kvd).map(|i| ((p * 17 + i * 5) % 11) as f32 * 0.3 - 1.5).collect()
        };
        for p in 0..3 {
            let mut v = arena.view_mut(&mut src);
            for l in 0..g.n_layers {
                v.store_k(l, p, &row(p));
                v.store_v(l, p, &row(p));
            }
        }
        // Fork at pos 3 — mid-word for hd=4 (word holds positions 0..8).
        let mut dst = arena.fork(&src, 3).unwrap();
        // Prefix is byte-identical: dequantized rows 0..3 match exactly
        // (no re-quantization happened).
        {
            let sv = arena.view(&src);
            let dv = arena.view(&dst);
            let mut a = vec![0.0f32; g.head_dim];
            let mut b = vec![0.0f32; g.head_dim];
            for l in 0..g.n_layers {
                for kvh in 0..g.n_kv_heads {
                    for p in 0..3 {
                        sv.k_packed(l, kvh).dequant_row(p, &mut a);
                        dv.k_packed(l, kvh).dequant_row(p, &mut b);
                        assert_eq!(a, b, "K l {l} kvh {kvh} p {p}");
                        sv.v_packed(l, kvh).dequant_row(p, &mut a);
                        dv.v_packed(l, kvh).dequant_row(p, &mut b);
                        assert_eq!(a, b, "V l {l} kvh {kvh} p {p}");
                    }
                }
            }
        }
        // Both sessions continue with the same rows (3, 4): the shared
        // plane word is masked-rewritten in each slot independently and
        // the results stay identical.
        for p in 3..5 {
            for h in [&mut src, &mut dst] {
                let mut v = arena.view_mut(h);
                for l in 0..g.n_layers {
                    v.store_k(l, p, &row(p));
                    v.store_v(l, p, &row(p));
                }
            }
        }
        {
            let sv = arena.view(&src);
            let dv = arena.view(&dst);
            let mut a = vec![0.0f32; g.head_dim];
            let mut b = vec![0.0f32; g.head_dim];
            for l in 0..g.n_layers {
                for kvh in 0..g.n_kv_heads {
                    for p in 0..5 {
                        sv.k_packed(l, kvh).dequant_row(p, &mut a);
                        dv.k_packed(l, kvh).dequant_row(p, &mut b);
                        assert_eq!(a, b, "post-continue K l {l} kvh {kvh} p {p}");
                    }
                }
            }
        }
        assert_eq!(arena.stats().fork_copies, 1);
        // Generation bump + slot reuse: releasing the fork frees its
        // slot for the next acquire, under a new generation.
        let (fslot, fgen) = (dst.slot(), dst.generation());
        arena.release(dst);
        assert!(!arena.is_live(fslot, fgen), "released fork handle must go stale");
        let again = arena.acquire().unwrap();
        assert_eq!(again.slot(), fslot, "LIFO reuse of the fork's slot");
        assert_ne!(again.generation(), fgen, "reuse bumps the generation");
        arena.release(again);
        arena.release(src);
    }

    #[test]
    fn packed_dirty_slot_reuse_decodes_like_fresh() {
        // A reused (dirty) packed slot must dequantize stored rows
        // exactly like its first (zero-filled) use — masked stores
        // overwrite every bit they later read.
        let g = packed_geom(2);
        let arena = KvArena::new(g, 1);
        let kvd = g.n_kv_heads * g.head_dim;
        let row: Vec<f32> = (0..kvd).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut fresh = vec![0.0f32; g.head_dim];
        let mut reused = vec![0.0f32; g.head_dim];
        {
            let mut h = arena.acquire().unwrap();
            {
                let mut v = arena.view_mut(&mut h);
                v.store_k(0, 0, &row);
                v.store_k(0, 1, &row); // extra position → dirt beyond pos 0
            }
            arena.view(&h).k_packed(0, 0).dequant_row(0, &mut fresh);
            arena.release(h);
        }
        {
            let mut h = arena.acquire().unwrap(); // LIFO: the same dirty slot
            {
                let mut v = arena.view_mut(&mut h);
                v.store_k(0, 0, &row);
            }
            arena.view(&h).k_packed(0, 0).dequant_row(0, &mut reused);
            arena.release(h);
        }
        assert_eq!(fresh, reused);
    }

    #[test]
    fn slab_backed_decode_matches_fresh_slot() {
        // A reused (dirty) slot must decode token-identically to its
        // own first (zero-filled) use — stale rows beyond pos are never
        // read.
        let m = model();
        let mut a = m.decode_state();
        let fresh: Vec<f32> = a.step(&m, 7);
        a.step(&m, 3);
        drop(a); // slot back to the free list, dirty
        let mut b = m.decode_state(); // LIFO: the same slot
        let again = b.step(&m, 7);
        for (x, y) in fresh.iter().zip(&again) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn gqa_arena_slots_decode_and_shrink() {
        // Slots over a GQA model decode, and the per-slot KV footprint
        // shrinks by exactly n_heads/n_kv_heads.
        let mha = Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 12,
                d_model: 16,
                n_layers: 2,
                n_heads: 4,
                n_kv_heads: 4,
                d_ff: 24,
                max_seq: 16,
                kv_format: KvFormat::F32,
            },
            1,
        ));
        let gqa = Arc::new(synthetic_model(&ModelConfig { n_kv_heads: 1, ..mha.cfg }, 1));
        assert_eq!(KvGeom::of(&mha).slot_bytes(), 4 * KvGeom::of(&gqa).slot_bytes());
        let mut st = gqa.decode_state();
        let logits = st.step(&gqa, 3);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn dropping_states_returns_slots() {
        let m = model();
        {
            let _a = m.decode_state();
            let _b = m.decode_state();
            assert_eq!(m.kv_arena().stats().slots_in_use, 2);
        }
        assert_eq!(m.kv_arena().stats().slots_in_use, 0);
        assert_eq!(m.kv_arena().stats().high_water, 2);
    }

    #[test]
    #[should_panic(expected = "KV arena exhausted")]
    fn exhausted_arena_panics_like_capacity() {
        let m = model();
        m.init_kv_arena(1, 1); // one slot, hard cap
        let _a = m.decode_state();
        let _b = m.decode_state(); // no slot left → loud failure
    }

    /// One step of the handle-protocol state machine, chosen by index
    /// from the ops available in the current state (see
    /// `handle_protocol_exhaustive_interleavings`).
    #[derive(Clone, Copy, Debug)]
    enum ProtoOp {
        /// `acquire()` — may refuse (`None`) at `max_slots`.
        Acquire,
        /// `release(live[i])` — the handle becomes a *ghost*: a
        /// `(slot, generation)` pair a buggy unsafe-born copy could
        /// still be holding.
        Release(usize),
        /// `fork(&live[i], 1)` — branch-point copy; may refuse at
        /// `max_slots`.
        Fork(usize),
        /// store a row through `view_mut(&mut live[i])` and read it
        /// back through `view(&live[i])`.
        Store(usize),
    }

    fn proto_ops(n_live: usize) -> Vec<ProtoOp> {
        let mut ops = vec![ProtoOp::Acquire];
        for i in 0..n_live {
            ops.push(ProtoOp::Release(i));
            ops.push(ProtoOp::Fork(i));
            ops.push(ProtoOp::Store(i));
        }
        ops
    }

    /// Replay one choice sequence from a fresh two-slot arena, checking
    /// after every op that (a) every live handle answers `is_live`,
    /// (b) every ghost answers `!is_live` — `is_live` must catch every
    /// use-after-release, including slot reuse under a new generation.
    /// Returns the branching factor of the final state, or `None` if a
    /// choice index exceeded the ops available (prune that subtree).
    fn proto_replay(g: KvGeom, choices: &[usize]) -> Option<usize> {
        let arena = KvArena::with_limit(g, 1, 2);
        let mut live: Vec<KvHandle> = Vec::new();
        let mut ghosts: Vec<(usize, u64)> = Vec::new();
        let row: Vec<f32> = (0..g.n_kv_heads * g.head_dim).map(|i| i as f32 * 0.5 - 1.0).collect();
        for &c in choices {
            let ops = proto_ops(live.len());
            let &op = ops.get(c)?;
            match op {
                ProtoOp::Acquire => {
                    if let Some(h) = arena.acquire() {
                        live.push(h);
                    }
                }
                ProtoOp::Release(i) => {
                    let h = live.remove(i);
                    ghosts.push((h.slot(), h.generation()));
                    arena.release(h);
                }
                ProtoOp::Fork(i) => {
                    if let Some(h) = arena.fork(&live[i], 1) {
                        live.push(h);
                    }
                }
                ProtoOp::Store(i) => {
                    arena.view_mut(&mut live[i]).store_k(0, 0, &row);
                    if g.format == KvFormat::F32 {
                        assert_eq!(arena.view(&live[i]).k_strip(0, 0, 1), &row[..g.head_dim]);
                    }
                }
            }
            for h in &live {
                assert!(
                    arena.is_live(h.slot(), h.generation()),
                    "live handle ({}, {}) not live after {op:?}",
                    h.slot(),
                    h.generation()
                );
            }
            for &(s, gen) in &ghosts {
                assert!(
                    !arena.is_live(s, gen),
                    "use-after-release: ghost ({s}, {gen}) still live after {op:?}"
                );
            }
        }
        Some(proto_ops(live.len()).len())
    }

    fn proto_dfs(g: KvGeom, depth_left: usize, choices: &mut Vec<usize>, n_seqs: &mut usize) {
        let Some(branches) = proto_replay(g, choices) else { return };
        *n_seqs += 1;
        if depth_left == 0 {
            return;
        }
        for c in 0..branches {
            choices.push(c);
            proto_dfs(g, depth_left - 1, choices, n_seqs);
            choices.pop();
        }
    }

    #[test]
    fn handle_protocol_exhaustive_interleavings() {
        // Every acquire/release/fork/store interleaving up to 6 ops
        // over a two-slot f32 arena, each replayed from scratch. The
        // affine-handle protocol (one live handle per slot; generations
        // kill stale pairs) must hold at every intermediate state.
        let mut n = 0;
        proto_dfs(geom(), 6, &mut Vec::new(), &mut n);
        assert!(n > 1000, "interleaving space unexpectedly small: {n} sequences");
    }

    #[test]
    fn handle_protocol_exhaustive_interleavings_packed() {
        // Same state machine over a packed (bit-plane) arena: fork's
        // bytewise mid-word prefix copy and the masked packed stores
        // must uphold the identical protocol.
        let mut n = 0;
        proto_dfs(packed_geom(2), 5, &mut Vec::new(), &mut n);
        assert!(n > 300, "interleaving space unexpectedly small: {n} sequences");
    }
}
