//! KV-cache slab — pooled decode states.
//!
//! Each decode session needs
//!
//! ```text
//! n_layers × cap × 2 × kv_dim × 4  bytes        (K and V, f32;
//!                                                cap = Model::decode_capacity(),
//!                                                kv_dim = n_kv_heads × head_dim)
//! ```
//!
//! of KV storage — see [`crate::model::Model::kv_bytes_per_session`].
//! Under grouped-query attention (`n_kv_heads < n_heads`) this is exactly
//! `n_heads / n_kv_heads` smaller than the d_model-wide MHA cache, which
//! is the lever that lets large-batch decode fit in memory bandwidth.
//! Allocating it per request is the dominant allocator pressure in the
//! decode loop; the slab keeps a free list of reset states and hands them
//! out in LIFO order (warmest cache lines first).

use crate::model::{DecodeState, Model};
use std::sync::{Arc, Mutex};

struct SlabInner {
    free: Vec<DecodeState>,
    created: usize,
    reused: usize,
}

/// Thread-safe pool of [`DecodeState`]s for one model.
#[derive(Clone)]
pub struct KvSlab {
    model: Arc<Model>,
    inner: Arc<Mutex<SlabInner>>,
    max_pooled: usize,
}

impl KvSlab {
    pub fn new(model: Arc<Model>, max_pooled: usize) -> Self {
        Self {
            model,
            inner: Arc::new(Mutex::new(SlabInner { free: Vec::new(), created: 0, reused: 0 })),
            max_pooled,
        }
    }

    /// Acquire a reset decode state (reused if available).
    pub fn acquire(&self) -> DecodeState {
        let mut inner = self.inner.lock().unwrap();
        match inner.free.pop() {
            Some(mut st) => {
                inner.reused += 1;
                st.reset();
                st
            }
            None => {
                inner.created += 1;
                drop(inner);
                self.model.decode_state()
            }
        }
    }

    /// Return a state to the pool (dropped if the pool is full).
    pub fn release(&self, st: DecodeState) {
        let mut inner = self.inner.lock().unwrap();
        if inner.free.len() < self.max_pooled {
            inner.free.push(st);
        }
    }

    /// (created, reused, pooled-now)
    pub fn stats(&self) -> (usize, usize, usize) {
        let inner = self.inner.lock().unwrap();
        (inner.created, inner.reused, inner.free.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic_model, ModelConfig};

    fn model() -> Arc<Model> {
        Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 12,
                d_model: 8,
                n_layers: 1,
                n_heads: 1,
                n_kv_heads: 1,
                d_ff: 12,
                max_seq: 16,
            },
            1,
        ))
    }

    #[test]
    fn acquire_release_reuses() {
        let slab = KvSlab::new(model(), 4);
        let a = slab.acquire();
        slab.release(a);
        let _b = slab.acquire();
        let (created, reused, _) = slab.stats();
        assert_eq!(created, 1);
        assert_eq!(reused, 1);
    }

    #[test]
    fn released_state_is_reset() {
        let m = model();
        let slab = KvSlab::new(m.clone(), 4);
        let mut a = slab.acquire();
        a.step(&m, 3);
        a.step(&m, 5);
        assert_eq!(a.pos(), 2);
        slab.release(a);
        let b = slab.acquire();
        assert_eq!(b.pos(), 0);
    }

    #[test]
    fn pool_bounded() {
        let slab = KvSlab::new(model(), 2);
        let states: Vec<_> = (0..5).map(|_| slab.acquire()).collect();
        for s in states {
            slab.release(s);
        }
        let (_, _, pooled) = slab.stats();
        assert_eq!(pooled, 2);
    }

    #[test]
    fn gqa_slab_states_decode_and_shrink() {
        // A slab over a GQA model hands out working states, and the
        // per-session KV footprint shrinks by exactly n_heads/n_kv_heads.
        let mha = Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 12,
                d_model: 16,
                n_layers: 2,
                n_heads: 4,
                n_kv_heads: 4,
                d_ff: 24,
                max_seq: 16,
            },
            1,
        ));
        let gqa = Arc::new(synthetic_model(
            &ModelConfig { n_kv_heads: 1, ..mha.cfg },
            1,
        ));
        assert_eq!(mha.kv_bytes_per_session(), 4 * gqa.kv_bytes_per_session());
        let slab = KvSlab::new(gqa.clone(), 2);
        let mut st = slab.acquire();
        let logits = st.step(&gqa, 3);
        assert!(logits.iter().all(|v| v.is_finite()));
        slab.release(st);
    }

    #[test]
    fn reset_state_decodes_identically() {
        let m = model();
        let slab = KvSlab::new(m.clone(), 2);
        let mut a = slab.acquire();
        let fresh: Vec<f32> = a.step(&m, 7);
        a.step(&m, 3);
        slab.release(a);
        let mut b = slab.acquire(); // the same buffer, reset
        let again = b.step(&m, 7);
        for (x, y) in fresh.iter().zip(&again) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}
