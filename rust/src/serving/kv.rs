//! KV-cache slab — pooled decode states.
//!
//! Each decode session needs `n_layers × cache_len × d_model × 2` floats
//! of KV storage; allocating that per request is the dominant allocator
//! pressure in the decode loop. The slab keeps a free list of reset
//! states and hands them out in LIFO order (warmest cache lines first).

use crate::model::{DecodeState, Model};
use std::sync::{Arc, Mutex};

struct SlabInner {
    free: Vec<DecodeState>,
    created: usize,
    reused: usize,
}

/// Thread-safe pool of [`DecodeState`]s for one model.
#[derive(Clone)]
pub struct KvSlab {
    model: Arc<Model>,
    inner: Arc<Mutex<SlabInner>>,
    max_pooled: usize,
}

impl KvSlab {
    pub fn new(model: Arc<Model>, max_pooled: usize) -> Self {
        Self {
            model,
            inner: Arc::new(Mutex::new(SlabInner { free: Vec::new(), created: 0, reused: 0 })),
            max_pooled,
        }
    }

    /// Acquire a reset decode state (reused if available).
    pub fn acquire(&self) -> DecodeState {
        let mut inner = self.inner.lock().unwrap();
        match inner.free.pop() {
            Some(mut st) => {
                inner.reused += 1;
                st.reset();
                st
            }
            None => {
                inner.created += 1;
                drop(inner);
                self.model.decode_state()
            }
        }
    }

    /// Return a state to the pool (dropped if the pool is full).
    pub fn release(&self, st: DecodeState) {
        let mut inner = self.inner.lock().unwrap();
        if inner.free.len() < self.max_pooled {
            inner.free.push(st);
        }
    }

    /// (created, reused, pooled-now)
    pub fn stats(&self) -> (usize, usize, usize) {
        let inner = self.inner.lock().unwrap();
        (inner.created, inner.reused, inner.free.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic_model, ModelConfig};

    fn model() -> Arc<Model> {
        Arc::new(synthetic_model(
            &ModelConfig { vocab_size: 12, d_model: 8, n_layers: 1, n_heads: 1, d_ff: 12, max_seq: 16 },
            1,
        ))
    }

    #[test]
    fn acquire_release_reuses() {
        let slab = KvSlab::new(model(), 4);
        let a = slab.acquire();
        slab.release(a);
        let _b = slab.acquire();
        let (created, reused, _) = slab.stats();
        assert_eq!(created, 1);
        assert_eq!(reused, 1);
    }

    #[test]
    fn released_state_is_reset() {
        let m = model();
        let slab = KvSlab::new(m.clone(), 4);
        let mut a = slab.acquire();
        a.step(&m, 3);
        a.step(&m, 5);
        assert_eq!(a.pos(), 2);
        slab.release(a);
        let b = slab.acquire();
        assert_eq!(b.pos(), 0);
    }

    #[test]
    fn pool_bounded() {
        let slab = KvSlab::new(model(), 2);
        let states: Vec<_> = (0..5).map(|_| slab.acquire()).collect();
        for s in states {
            slab.release(s);
        }
        let (_, _, pooled) = slab.stats();
        assert_eq!(pooled, 2);
    }

    #[test]
    fn reset_state_decodes_identically() {
        let m = model();
        let slab = KvSlab::new(m.clone(), 2);
        let mut a = slab.acquire();
        let fresh: Vec<f32> = a.step(&m, 7);
        a.step(&m, 3);
        slab.release(a);
        let mut b = slab.acquire(); // the same buffer, reset
        let again = b.step(&m, 7);
        for (x, y) in fresh.iter().zip(&again) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}
