//! KV arena — one pooled slab per model, shared by every decode session.
//!
//! ## Layout
//!
//! The arena owns contiguous f32 slabs carved into fixed-size **slots**,
//! one per live decode session. A slot holds the session's entire KV
//! state:
//!
//! ```text
//! bytes/slot = n_layers × 2 × n_kv_heads × cap × head_dim × 4
//!              (K and V, f32; cap = Model::decode_capacity(),
//!               n_kv_heads × head_dim = kv_dim — the GQA-shrunk width)
//! ```
//!
//! laid out layer-major, then K/V, then head-major:
//!
//! ```text
//! slot ─┬─ layer 0 ─┬─ K ─┬─ kv-head 0 │cap × head_dim│  ← one strip
//!       │           │     └─ kv-head 1 │cap × head_dim│
//!       │           └─ V ─┬─ kv-head 0 │cap × head_dim│
//!       │                 └─ …
//!       ├─ layer 1 ─ …
//!       └─ layer L-1 ─ …
//! ```
//!
//! Layer-major first because the decode sweep visits layers outermost —
//! everything a layer's attention pass touches sits in one contiguous
//! span of the slot. Head-major inside because each head's score pass is
//! then one contiguous dot sweep and its AV pass a run of contiguous
//! axpys (the PR-2 `LayerKv` property, now arena-wide). Making the
//! *slots themselves* adjacent in one slab is what turns the batched
//! serving sweep's score/AV phase into a single multi-session pass per
//! (layer, kv-head) — [`crate::tensor::strip_dots`] /
//! [`crate::tensor::strip_axpys`] walk every session in a position group
//! together over arena-adjacent strips — instead of B separate strip
//! walks over B scattered heap allocations.
//!
//! ## Handles and safety
//!
//! [`KvHandle`] is an affine token (slot index + generation; not
//! `Clone`): at most one handle per live slot exists, handed out by
//! [`KvArena::acquire`] and consumed by [`KvArena::release`]. Shared
//! reads go through [`KvView`] (borrows the handle), exclusive writes
//! through [`KvViewMut`] (borrows it mutably) — the borrow checker
//! enforces per-slot aliasing discipline, and the only `unsafe` is the
//! disjoint-slot slice carving, whose bounds (strip coordinates, store
//! position, strip length, fork position) are **hard** asserts in every
//! build profile. Handles are stamped with their arena's id and
//! rejected by foreign arenas; generations catch stale handles
//! ([`KvArena::is_live`], asserted on release). [`KvArena::fork`] is a
//! slot-to-slot copy of the live
//! `pos × head_dim` prefix of every strip — the prefix-cache trick
//! behind fast multiple-choice scoring.
//!
//! ## Exhaustion and growth
//!
//! The arena starts empty and grows by whole slab segments (doubling,
//! so steady state is one or two big slabs) up to `max_slots`; beyond
//! that `acquire` returns `None` and session construction panics with
//! "KV arena exhausted" — the same loud-failure contract as the decode
//! capacity assert ("KV cache exhausted"). Freed slots are reused LIFO
//! (warmest lines first), which is also what keeps concurrently active
//! sessions in *adjacent* slots for the batched sweep.

use crate::model::Model;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonic arena id source — lets handles be checked against the
/// arena they came from (releasing into a foreign arena would otherwise
/// mint two live handles to one slot).
static NEXT_ARENA_ID: AtomicU64 = AtomicU64::new(1);

/// Geometry of one model's KV slots — everything the arena needs to
/// know about a model, without holding the model (no `Arc` cycle with
/// [`Model`]'s cached arena).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvGeom {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// positions per session — `Model::decode_capacity()`
    pub cap: usize,
}

impl KvGeom {
    pub fn of(model: &Model) -> Self {
        Self {
            n_layers: model.cfg.n_layers,
            n_kv_heads: model.cfg.n_kv_heads,
            head_dim: model.cfg.head_dim(),
            cap: model.decode_capacity(),
        }
    }

    /// f32 elements per arena slot: `n_layers × 2 × n_kv_heads × cap ×
    /// head_dim`.
    pub fn slot_elems(&self) -> usize {
        self.n_layers * 2 * self.n_kv_heads * self.cap * self.head_dim
    }

    /// Bytes per slot (the per-session KV footprint —
    /// `Model::kv_bytes_per_session`).
    pub fn slot_bytes(&self) -> usize {
        self.slot_elems() * 4
    }

    /// Element offset of the (layer, K=0/V=1, kv-head) strip within a
    /// slot. Hard-bounded: this offset feeds the raw-pointer slice
    /// carving in the views, so out-of-range coordinates must never
    /// reach it in any build profile.
    #[inline]
    fn strip_base(&self, layer: usize, which: usize, kvh: usize) -> usize {
        assert!(
            layer < self.n_layers && which < 2 && kvh < self.n_kv_heads,
            "KV strip coordinates out of range"
        );
        ((layer * 2 + which) * self.n_kv_heads + kvh) * self.cap * self.head_dim
    }
}

/// Affine ownership token for one arena slot. Not `Clone` — exactly one
/// handle exists per live slot, so `&mut KvHandle` is exclusive access
/// to the slot's memory and `&KvHandle` is shared read access.
pub struct KvHandle {
    slot: usize,
    generation: u64,
    arena_id: u64,
    base: *mut f32,
}

// Safety: a handle's slot region is disjoint from every other live
// handle's (arena invariant: one handle per slot), and all access goes
// through KvView/KvViewMut whose aliasing the borrow checker enforces
// via the handle borrow. Moving or sharing the token itself is
// therefore safe.
unsafe impl Send for KvHandle {}
unsafe impl Sync for KvHandle {}

impl KvHandle {
    pub fn slot(&self) -> usize {
        self.slot
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }
}

/// Cumulative arena counters (surfaced through `serving::metrics` into
/// the serve summary and `BENCH_decode.json`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// live sessions right now
    pub slots_in_use: usize,
    /// most sessions ever live at once
    pub high_water: usize,
    /// slots ever carved out of slabs
    pub slots_created: usize,
    /// acquisitions served from the free list (pooling hit count)
    pub reused: usize,
    /// bytes of slab currently allocated
    pub bytes_resident: usize,
    /// slot-to-slot prefix copies performed by `fork`
    pub fork_copies: u64,
}

struct ArenaInner {
    /// owning slab segments; boxed so the heap buffers never move when
    /// the segment list grows
    segments: Vec<Box<[f32]>>,
    /// per-slot base pointer into its segment, indexed by slot id
    bases: Vec<*mut f32>,
    /// bumped on release; a mismatch means a stale handle
    generations: Vec<u64>,
    /// LIFO free list of slot ids
    free: Vec<usize>,
    in_use: usize,
    high_water: usize,
    reused: usize,
    fork_copies: u64,
    bytes_resident: usize,
}

// Safety: the raw per-slot pointers are only dereferenced through
// KvView/KvViewMut under the handle discipline; the inner bookkeeping
// itself is only touched under the mutex.
unsafe impl Send for ArenaInner {}

/// One pooled KV slab per model. See the module docs for layout and the
/// handle/ownership contract.
pub struct KvArena {
    id: u64,
    geom: KvGeom,
    initial_slots: usize,
    max_slots: usize,
    inner: Mutex<ArenaInner>,
}

impl std::fmt::Debug for KvArena {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvArena")
            .field("geom", &self.geom)
            .field("stats", &self.stats())
            .finish()
    }
}

impl KvArena {
    /// Arena that grows without bound (by doubling segments).
    pub fn new(geom: KvGeom, initial_slots: usize) -> Self {
        Self::with_limit(geom, initial_slots, usize::MAX)
    }

    /// Arena capped at `max_slots` total; `acquire` returns `None` once
    /// every slot is live.
    pub fn with_limit(geom: KvGeom, initial_slots: usize, max_slots: usize) -> Self {
        assert!(initial_slots > 0, "arena needs at least one slot");
        Self {
            id: NEXT_ARENA_ID.fetch_add(1, Ordering::Relaxed),
            geom,
            initial_slots,
            max_slots,
            inner: Mutex::new(ArenaInner {
                segments: Vec::new(),
                bases: Vec::new(),
                generations: Vec::new(),
                free: Vec::new(),
                in_use: 0,
                high_water: 0,
                reused: 0,
                fork_copies: 0,
                bytes_resident: 0,
            }),
        }
    }

    pub fn geom(&self) -> KvGeom {
        self.geom
    }

    /// Unique id of this arena (stamped into every handle; used to key
    /// per-arena metrics and to reject foreign handles).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Total slots this arena may ever carve (`usize::MAX` = unbounded).
    pub fn max_slots(&self) -> usize {
        self.max_slots
    }

    /// A handle is only meaningful inside the arena that minted it —
    /// releasing or viewing through a foreign arena would break the
    /// one-handle-per-slot invariant the unsafe slice carving relies on.
    #[inline]
    fn check_owned(&self, h: &KvHandle) {
        assert_eq!(h.arena_id, self.id, "KV handle used with a foreign arena");
    }

    /// Carve a fresh segment (doubling growth) into the free list.
    fn grow(&self, inner: &mut ArenaInner) {
        let have = inner.bases.len();
        if have >= self.max_slots {
            return;
        }
        let want = if have == 0 { self.initial_slots } else { have };
        let add = want.min(self.max_slots - have);
        let elems = self.geom.slot_elems();
        let mut seg = vec![0.0f32; add * elems].into_boxed_slice();
        let base = seg.as_mut_ptr();
        for i in 0..add {
            inner.bases.push(unsafe { base.add(i * elems) });
            inner.generations.push(0);
        }
        // Push in reverse so LIFO pops hand out ascending slot ids —
        // concurrently-acquired sessions land in adjacent slots.
        for i in (0..add).rev() {
            inner.free.push(have + i);
        }
        inner.bytes_resident += add * elems * 4;
        inner.segments.push(seg);
    }

    /// Claim a slot. `None` only when the arena is at `max_slots` with
    /// every slot live — callers turn that into a "KV arena exhausted"
    /// panic, mirroring the decode capacity assert.
    pub fn acquire(&self) -> Option<KvHandle> {
        let mut inner = self.inner.lock().unwrap();
        let slot = match inner.free.pop() {
            Some(s) => {
                inner.reused += 1;
                s
            }
            None => {
                self.grow(&mut inner);
                inner.free.pop()?
            }
        };
        inner.in_use += 1;
        inner.high_water = inner.high_water.max(inner.in_use);
        Some(KvHandle {
            slot,
            generation: inner.generations[slot],
            arena_id: self.id,
            base: inner.bases[slot],
        })
    }

    /// Return a slot to the free list. The generation bump invalidates
    /// any (buggy, unsafe-born) copy of the handle.
    pub fn release(&self, h: KvHandle) {
        self.check_owned(&h);
        let mut inner = self.inner.lock().unwrap();
        assert_eq!(inner.generations[h.slot], h.generation, "double release / stale KV handle");
        inner.generations[h.slot] = inner.generations[h.slot].wrapping_add(1);
        inner.in_use -= 1;
        inner.free.push(h.slot);
    }

    /// Does `(slot, generation)` name a currently-live claim? Stale
    /// handles (released, possibly re-acquired by someone else) answer
    /// `false` — the reuse-after-release safety check.
    pub fn is_live(&self, slot: usize, generation: u64) -> bool {
        let inner = self.inner.lock().unwrap();
        slot < inner.generations.len()
            && inner.generations[slot] == generation
            && !inner.free.contains(&slot)
    }

    /// Branch-point copy: claim a fresh slot and copy the live
    /// `pos × head_dim` prefix of every (layer, K/V, head) strip from
    /// `src` — contiguous block copies inside the slab, no zeroing of
    /// the never-read tails.
    pub fn fork(&self, src: &KvHandle, pos: usize) -> Option<KvHandle> {
        self.check_owned(src);
        // Hard bound: this arithmetic feeds raw-pointer copies below.
        assert!(pos <= self.geom.cap, "fork position {pos} beyond slot capacity");
        let dst = self.acquire()?;
        let hd = self.geom.head_dim;
        let n = pos * hd;
        if n > 0 {
            let strip_elems = self.geom.cap * hd;
            for s in 0..self.geom.n_layers * 2 * self.geom.n_kv_heads {
                let off = s * strip_elems;
                // Safety: src is live (we hold &KvHandle, so no
                // KvViewMut can exist) and dst was just acquired (no
                // other reference); distinct slots never overlap.
                unsafe {
                    std::ptr::copy_nonoverlapping(src.base.add(off), dst.base.add(off), n);
                }
            }
        }
        self.inner.lock().unwrap().fork_copies += 1;
        Some(dst)
    }

    /// Shared read access to a slot's strips.
    pub fn view<'a>(&'a self, h: &'a KvHandle) -> KvView<'a> {
        self.check_owned(h);
        debug_assert!(self.is_live(h.slot, h.generation), "stale KV handle");
        KvView { base: h.base, geom: self.geom, _life: PhantomData }
    }

    /// Exclusive read/write access to a slot's strips (requires the
    /// one-and-only handle mutably).
    pub fn view_mut<'a>(&'a self, h: &'a mut KvHandle) -> KvViewMut<'a> {
        self.check_owned(h);
        debug_assert!(self.is_live(h.slot, h.generation), "stale KV handle");
        KvViewMut { base: h.base, geom: self.geom, _life: PhantomData }
    }

    pub fn stats(&self) -> ArenaStats {
        let inner = self.inner.lock().unwrap();
        ArenaStats {
            slots_in_use: inner.in_use,
            high_water: inner.high_water,
            slots_created: inner.bases.len(),
            reused: inner.reused,
            bytes_resident: inner.bytes_resident,
            fork_copies: inner.fork_copies,
        }
    }
}

/// Shared (read-only) borrow of one slot. Lifetime-tied to both the
/// arena and the handle, so the slot can be neither released nor
/// mutated while a view is out.
pub struct KvView<'a> {
    base: *mut f32,
    geom: KvGeom,
    _life: PhantomData<&'a KvHandle>,
}

impl KvView<'_> {
    /// The first `len` cached K rows of `kvh` in `layer`, contiguous.
    #[inline]
    pub fn k_strip(&self, layer: usize, kvh: usize, len: usize) -> &[f32] {
        self.strip(layer, 0, kvh, len)
    }

    /// The first `len` cached V rows of `kvh` in `layer`, contiguous.
    #[inline]
    pub fn v_strip(&self, layer: usize, kvh: usize, len: usize) -> &[f32] {
        self.strip(layer, 1, kvh, len)
    }

    #[inline]
    fn strip(&self, layer: usize, which: usize, kvh: usize, len: usize) -> &[f32] {
        assert!(len <= self.geom.cap, "strip length beyond slot capacity");
        let off = self.geom.strip_base(layer, which, kvh);
        // Safety: within the slot (offset arithmetic hard-bounded by
        // strip_base and the capacity assert); shared reads are fine
        // while the handle is borrowed shared.
        unsafe { std::slice::from_raw_parts(self.base.add(off), len * self.geom.head_dim) }
    }
}

/// Exclusive borrow of one slot (store + read).
pub struct KvViewMut<'a> {
    base: *mut f32,
    geom: KvGeom,
    _life: PhantomData<&'a mut KvHandle>,
}

impl KvViewMut<'_> {
    /// Scatter one `kv_dim`-wide K projection row into the per-head
    /// strips at position `pos`.
    #[inline]
    pub fn store_k(&mut self, layer: usize, pos: usize, row: &[f32]) {
        self.store(layer, 0, pos, row)
    }

    /// Scatter one `kv_dim`-wide V projection row into the per-head
    /// strips at position `pos`.
    #[inline]
    pub fn store_v(&mut self, layer: usize, pos: usize, row: &[f32]) {
        self.store(layer, 1, pos, row)
    }

    #[inline]
    fn store(&mut self, layer: usize, which: usize, pos: usize, row: &[f32]) {
        let hd = self.geom.head_dim;
        assert_eq!(row.len(), self.geom.n_kv_heads * hd, "KV row width != kv_dim");
        assert!(pos < self.geom.cap, "store position beyond slot capacity");
        for kvh in 0..self.geom.n_kv_heads {
            let off = self.geom.strip_base(layer, which, kvh) + pos * hd;
            // Safety: exclusive access via the &mut handle borrow;
            // offsets hard-bounded by the asserts above.
            unsafe {
                std::ptr::copy_nonoverlapping(row.as_ptr().add(kvh * hd), self.base.add(off), hd);
            }
        }
    }

    #[inline]
    pub fn k_strip(&self, layer: usize, kvh: usize, len: usize) -> &[f32] {
        self.strip(layer, 0, kvh, len)
    }

    #[inline]
    pub fn v_strip(&self, layer: usize, kvh: usize, len: usize) -> &[f32] {
        self.strip(layer, 1, kvh, len)
    }

    #[inline]
    fn strip(&self, layer: usize, which: usize, kvh: usize, len: usize) -> &[f32] {
        assert!(len <= self.geom.cap, "strip length beyond slot capacity");
        let off = self.geom.strip_base(layer, which, kvh);
        // Safety: as in KvView::strip, but under the exclusive borrow.
        unsafe { std::slice::from_raw_parts(self.base.add(off), len * self.geom.head_dim) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic_model, ModelConfig};
    use std::sync::Arc;

    fn model() -> Arc<Model> {
        Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 12,
                d_model: 8,
                n_layers: 1,
                n_heads: 1,
                n_kv_heads: 1,
                d_ff: 12,
                max_seq: 16,
            },
            1,
        ))
    }

    fn geom() -> KvGeom {
        KvGeom::of(&model())
    }

    #[test]
    fn slot_bytes_matches_model_formula() {
        let m = model();
        assert_eq!(KvGeom::of(&m).slot_bytes(), m.kv_bytes_per_session());
    }

    #[test]
    fn acquire_release_reuses_lifo() {
        let arena = KvArena::new(geom(), 4);
        let a = arena.acquire().unwrap();
        let a_slot = a.slot();
        arena.release(a);
        let b = arena.acquire().unwrap();
        assert_eq!(b.slot(), a_slot, "LIFO reuse of the warmest slot");
        let s = arena.stats();
        assert_eq!(s.reused, 1);
        assert_eq!(s.slots_in_use, 1);
        assert_eq!(s.high_water, 1);
    }

    #[test]
    fn adjacent_acquires_get_adjacent_slots() {
        let arena = KvArena::new(geom(), 4);
        let hs: Vec<KvHandle> = (0..3).map(|_| arena.acquire().unwrap()).collect();
        for (i, h) in hs.iter().enumerate() {
            assert_eq!(h.slot(), i, "batch sessions land in adjacent slots");
        }
        for h in hs {
            arena.release(h);
        }
    }

    #[test]
    fn grows_by_doubling_and_tracks_bytes() {
        let g = geom();
        let arena = KvArena::new(g, 2);
        let hs: Vec<KvHandle> = (0..5).map(|_| arena.acquire().unwrap()).collect();
        let s = arena.stats();
        // segments of 2, 2, 4 slots → 8 carved for 5 live
        assert_eq!(s.slots_created, 8);
        assert_eq!(s.slots_in_use, 5);
        assert_eq!(s.bytes_resident, 8 * g.slot_bytes());
        for h in hs {
            arena.release(h);
        }
        assert_eq!(arena.stats().slots_in_use, 0);
        assert_eq!(arena.stats().high_water, 5);
    }

    #[test]
    fn exhaustion_returns_none_at_limit() {
        let arena = KvArena::with_limit(geom(), 1, 2);
        let a = arena.acquire().unwrap();
        let b = arena.acquire().unwrap();
        assert!(arena.acquire().is_none(), "arena at max_slots must refuse");
        arena.release(a);
        assert!(arena.acquire().is_some(), "released slot acquirable again");
        arena.release(b);
    }

    #[test]
    fn generation_invalidates_released_handles() {
        let arena = KvArena::new(geom(), 2);
        let a = arena.acquire().unwrap();
        let (slot, gen) = (a.slot(), a.generation());
        assert!(arena.is_live(slot, gen));
        arena.release(a);
        assert!(!arena.is_live(slot, gen), "released handle must go stale");
        // Reuse bumps the generation: the new claim is live, the old
        // (slot, gen) pair stays dead — reuse-after-release safety.
        let b = arena.acquire().unwrap();
        assert_eq!(b.slot(), slot);
        assert_ne!(b.generation(), gen);
        assert!(arena.is_live(b.slot(), b.generation()));
        assert!(!arena.is_live(slot, gen));
        arena.release(b);
    }

    #[test]
    #[should_panic(expected = "foreign arena")]
    fn foreign_handle_rejected() {
        // Releasing a handle into a different arena would mint two live
        // handles to one slot — it must fail loudly instead.
        let a = KvArena::new(geom(), 2);
        let b = KvArena::new(geom(), 2);
        let h = a.acquire().unwrap();
        b.release(h);
    }

    #[test]
    fn store_then_strip_roundtrip() {
        let m = model();
        let g = KvGeom::of(&m);
        let arena = KvArena::new(g, 2);
        let mut h = arena.acquire().unwrap();
        let row: Vec<f32> = (0..g.n_kv_heads * g.head_dim).map(|i| i as f32 + 0.5).collect();
        {
            let mut v = arena.view_mut(&mut h);
            v.store_k(0, 0, &row);
            v.store_v(0, 0, &row);
        }
        let v = arena.view(&h);
        assert_eq!(v.k_strip(0, 0, 1), &row[..g.head_dim]);
        assert_eq!(v.v_strip(0, 0, 1), &row[..g.head_dim]);
        arena.release(h);
    }

    #[test]
    fn fork_copies_live_prefix_only() {
        let g = KvGeom { n_layers: 2, n_kv_heads: 2, head_dim: 4, cap: 8 };
        let arena = KvArena::new(g, 2);
        let mut src = arena.acquire().unwrap();
        for pos in 0..3 {
            let row: Vec<f32> = (0..g.n_kv_heads * g.head_dim)
                .map(|i| (pos * 100 + i) as f32)
                .collect();
            let mut v = arena.view_mut(&mut src);
            for l in 0..g.n_layers {
                v.store_k(l, pos, &row);
                v.store_v(l, pos, &row);
            }
        }
        let dst = arena.fork(&src, 3).unwrap();
        let sv = arena.view(&src);
        let dv = arena.view(&dst);
        for l in 0..g.n_layers {
            for kvh in 0..g.n_kv_heads {
                assert_eq!(sv.k_strip(l, kvh, 3), dv.k_strip(l, kvh, 3), "l {l} kvh {kvh}");
                assert_eq!(sv.v_strip(l, kvh, 3), dv.v_strip(l, kvh, 3), "l {l} kvh {kvh}");
            }
        }
        assert_eq!(arena.stats().fork_copies, 1);
        drop((sv, dv));
        arena.release(src);
        arena.release(dst);
    }

    #[test]
    fn slab_backed_decode_matches_fresh_slot() {
        // A reused (dirty) slot must decode token-identically to its
        // own first (zero-filled) use — stale rows beyond pos are never
        // read.
        let m = model();
        let mut a = m.decode_state();
        let fresh: Vec<f32> = a.step(&m, 7);
        a.step(&m, 3);
        drop(a); // slot back to the free list, dirty
        let mut b = m.decode_state(); // LIFO: the same slot
        let again = b.step(&m, 7);
        for (x, y) in fresh.iter().zip(&again) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn gqa_arena_slots_decode_and_shrink() {
        // Slots over a GQA model decode, and the per-slot KV footprint
        // shrinks by exactly n_heads/n_kv_heads.
        let mha = Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 12,
                d_model: 16,
                n_layers: 2,
                n_heads: 4,
                n_kv_heads: 4,
                d_ff: 24,
                max_seq: 16,
            },
            1,
        ));
        let gqa = Arc::new(synthetic_model(&ModelConfig { n_kv_heads: 1, ..mha.cfg }, 1));
        assert_eq!(KvGeom::of(&mha).slot_bytes(), 4 * KvGeom::of(&gqa).slot_bytes());
        let mut st = gqa.decode_state();
        let logits = st.step(&gqa, 3);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn dropping_states_returns_slots() {
        let m = model();
        {
            let _a = m.decode_state();
            let _b = m.decode_state();
            assert_eq!(m.kv_arena().stats().slots_in_use, 2);
        }
        assert_eq!(m.kv_arena().stats().slots_in_use, 0);
        assert_eq!(m.kv_arena().stats().high_water, 2);
    }

    #[test]
    #[should_panic(expected = "KV arena exhausted")]
    fn exhausted_arena_panics_like_capacity() {
        let m = model();
        m.init_kv_arena(1, 1); // one slot, hard cap
        let _a = m.decode_state();
        let _b = m.decode_state(); // no slot left → loud failure
    }
}
