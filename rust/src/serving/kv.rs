//! Paged, pooled KV-cache arena — one page pool per model, shared by
//! every decode session, with refcounted copy-on-write pages.
//!
//! ## Memory model (paged layout)
//!
//! The unit of allocation is a **page**: a self-contained mini-strip of
//! `page_positions` (`pp`) decode positions for one (layer, K/V,
//! kv-head) **strip**. A session's cache is a *page table* — an
//! `n_strips × n_pages` array of `Option<PageRef>` — not a contiguous
//! slot:
//!
//! ```text
//! session handle ── table[strip s · n_pages + page p] ──► PageRef { id, gen, base, shared }
//!                                                                 │
//! arena page pool:  [ page 0 │ page 1 │ … ]  ◄────────────────────┘  (rc, gen per page)
//!
//! strip index  s = layer·(2·n_kv_heads) + which·n_kv_heads + kv_head
//! page index   p = position / pp        (u = position % pp inside the page)
//! ```
//!
//! Per-format page layout (`page_words` u32 words each):
//!
//! * [`KvFormat::F32`] — `pp × head_dim` f32s, position-major;
//!   word-aligned by construction.
//! * [`KvFormat::BitPlane`]`{bits, group}` — one packed strip of `pp`
//!   positions ([`crate::tensor::PackedGeom::for_page`]): `bits` planes
//!   of `⌈pp·hd/32⌉` words, then `pp × ⌈hd/group⌉ × (bits+1)` f16
//!   coefficients two-per-word. Pages therefore align to plane-word
//!   *and* coefficient-span boundaries — a page dequantizes in
//!   isolation, so KV quantization and paging compose: sharing or
//!   copying a page never re-quantizes, the variable-grid encoding
//!   travels with the page bytes.
//!
//! Every page of a slot has the same `page_words`, so
//! `slot_bytes = n_strips × n_pages × page_words × 4`; with the default
//! `pp = 32` (and `pp | cap`, which holds for every `max_seq × 4`
//! capacity) this is byte-identical to the pre-paging monolithic slot.
//!
//! ## Refcount lifecycle and copy-on-write
//!
//! Pages are refcounted. Holders are (a) session page tables
//! ([`KvHandle`]) and (b) prefix-cache radix nodes
//! ([`crate::serving::prefix`]):
//!
//! * **alloc** — first store into a (strip, page): rc 0 → 1, the
//!   storing handle owns it (`shared == false`). Dirty reused memory is
//!   fine: f32 rows are fully overwritten and packed stores are masked
//!   read-modify-writes that never read bits they didn't store.
//! * **share** — [`KvArena::fork`] / [`KvArena::export_prefix`] /
//!   [`KvArena::import_prefix`]: rc += 1 and every table entry
//!   referencing the page flips to `shared == true`. `fork()` is a pure
//!   refcount bump over the live prefix — no byte copy.
//! * **copy-on-write** — store into a `shared` page: if rc == 1 the
//!   holder is the sole owner again and reclaims the page in place
//!   (flips `shared` off, no copy); otherwise a fresh page is
//!   allocated, the page copied **bytewise** (no re-quantization), the
//!   old ref dropped, and `cow_copies` counts it.
//! * **release** — handle drop / cache eviction: rc -= 1; at 0 the
//!   page's generation bumps and it returns to the LIFO free list.
//!   [`KvArena::page_is_live`] answers `false` for the old generation
//!   forever — a freed page can never be resurrected.
//!
//! ## Growth, pressure, exhaustion
//!
//! The pool grows by whole-slot page batches (doubling, like the old
//! slab), so `bytes_resident` stays a multiple of `slot_bytes`.
//! [`KvArena::with_limit`] caps live *sessions* at `max_slots`
//! (`acquire`/`fork` return `None` there — admission control) and page
//! growth at `max_slots` slots' worth. When a store needs a page, the
//! free list is empty, and growth is capped, the arena calls the
//! registered **reclaimer** ([`KvArena::set_reclaimer`] — the prefix
//! cache's LRU leaf evictor) with no arena lock held; if nothing can be
//! freed it panics `"KV arena exhausted"`, the same loud-failure
//! contract as before.
//!
//! ## Handles and safety
//!
//! aliasing: one writable owner per page — a page is written only
//! through a table entry with `shared == false`, at most one such entry
//! exists across all live handles (ownership transfers only through
//! COW, which mints a fresh page), and `shared` pages are read-only
//! everywhere, so shared `&[u32]` reads never coexist with a `&mut`
//! carve. Every raw-pointer carve derives from a `PageRef.base` whose
//! page this handle holds a refcount on; distinct page ids map to
//! disjoint `page_words` spans inside segments that never move or
//! free; and all strip/page/position coordinates are hard-asserted at
//! the boundary. This header is the protocol declaration `bpdq lint`
//! rule L5 anchors to.
//!
//! [`KvHandle`] is an affine token (not `Clone`): shared reads go
//! through [`KvView`] (borrows it), exclusive stores through
//! [`KvViewMut`] (borrows it mutably), and the borrow checker enforces
//! per-handle aliasing discipline. The invariants, keyed by the
//! `bpdq lint` rule that machine-checks each:
//!
//! | Rule | What it pins down here |
//! |------|------------------------|
//! | `L1` | every `unsafe` block/impl below carries a `// SAFETY:` comment naming the invariant it leans on |
//! | `L2`–`L4` | the arena is deliberately *not* hot code: locks and hard protocol asserts live here at the page boundary (alloc / COW / share / release), so the steady-state store fast path (owned page) and the marked decode kernels never allocate, panic, or lock |
//! | `L5` | raw-pointer carving (`from_raw_parts*`, `.add`, `copy_nonoverlapping`) appears only inside `unsafe` blocks, under this header's protocol: one writable owner per page, refcount-held liveness, disjoint page spans |
//!
//! Handles are stamped with their arena's id and rejected by foreign
//! arenas (`check_owned`); per-page generations catch stale references
//! (asserted on every release and import).

use crate::model::Model;
use crate::tensor::{PackedGeom, PackedStrip, PackedStripMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonic arena id source — lets handles be checked against the
/// arena they came from (a foreign release would corrupt refcounts).
static NEXT_ARENA_ID: AtomicU64 = AtomicU64::new(1);

/// How a KV strip is stored in the arena. Runtime-only (not serialized
/// into `.tlm` checkpoints): the same weights can serve under any
/// format.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KvFormat {
    /// Dense f32 rows — bit-identical to the pre-format-generic layout.
    F32,
    /// BPDQ-style packed bit-planes + per-plane f16 scalars (see the
    /// module docs and [`crate::tensor::kvpack`]).
    BitPlane {
        /// planes per channel (the paper's W-axis, applied to KV)
        bits: usize,
        /// channels per coefficient group along `head_dim`
        group: usize,
    },
}

impl KvFormat {
    /// Default coefficient-group width (channels sharing one set of
    /// per-plane scalars).
    pub const DEFAULT_GROUP: usize = 32;

    /// Bit-plane format at `bits` with the default group width.
    pub fn bit_plane(bits: usize) -> Self {
        KvFormat::BitPlane { bits, group: Self::DEFAULT_GROUP }
    }

    /// Parse a `--kv-bits` CLI value: `0` = f32, `2..=4` = bit-plane at
    /// the default group. Anything else is a loud error.
    pub fn from_kv_bits(bits: usize) -> anyhow::Result<Self> {
        match bits {
            0 => Ok(KvFormat::F32),
            2..=4 => Ok(Self::bit_plane(bits)),
            other => anyhow::bail!("--kv-bits must be 0 (f32), 2, 3, or 4 — got {other}"),
        }
    }

    pub fn is_packed(&self) -> bool {
        matches!(self, KvFormat::BitPlane { .. })
    }

    /// Short human label ("f32" / "kvq2g32") for summaries and benches.
    pub fn label(&self) -> String {
        match self {
            KvFormat::F32 => "f32".to_string(),
            KvFormat::BitPlane { bits, group } => format!("kvq{bits}g{group}"),
        }
    }
}

/// Geometry of one model's KV: strip grid, capacity, page size, and
/// storage format — everything the arena needs without holding the
/// model (no `Arc` cycle with [`Model`]'s cached arena).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvGeom {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// positions per session — `Model::decode_capacity()`
    pub cap: usize,
    /// positions per page (`pp`); clamped to `1..=cap` at construction
    pub page_positions: usize,
    /// physical strip format (f32 or packed bit-planes)
    pub format: KvFormat,
}

impl KvGeom {
    pub fn of(model: &Model) -> Self {
        let cap = model.decode_capacity();
        Self {
            n_layers: model.cfg.n_layers,
            n_kv_heads: model.cfg.n_kv_heads,
            head_dim: model.cfg.head_dim(),
            cap,
            page_positions: model.kv_page.clamp(1, cap),
            format: model.cfg.kv_format,
        }
    }

    /// Packed geometry of ONE PAGE (a `page_positions`-long strip);
    /// `None` under [`KvFormat::F32`].
    pub fn packed_page(&self) -> Option<PackedGeom> {
        match self.format {
            KvFormat::F32 => None,
            KvFormat::BitPlane { bits, group } => {
                Some(PackedGeom::for_page(self.page_positions, self.head_dim, bits, group))
            }
        }
    }

    /// Pages per strip: `⌈cap / pp⌉`.
    #[inline]
    pub fn n_pages(&self) -> usize {
        self.cap.div_ceil(self.page_positions)
    }

    /// Strips per session: `n_layers × {K,V} × n_kv_heads`.
    #[inline]
    pub fn n_strips(&self) -> usize {
        self.n_layers * 2 * self.n_kv_heads
    }

    /// u32 words per page (uniform across the slot).
    pub fn page_words(&self) -> usize {
        match self.packed_page() {
            None => self.page_positions * self.head_dim, // one f32 per word
            Some(pg) => pg.strip_words(),
        }
    }

    /// Bytes per page — the sharing/eviction granularity.
    #[inline]
    pub fn page_bytes(&self) -> usize {
        self.page_words() * 4
    }

    /// Pages one session needs at full capacity.
    #[inline]
    pub fn pages_per_slot(&self) -> usize {
        self.n_strips() * self.n_pages()
    }

    /// **Real packed** bytes of one full session's KV (the per-session
    /// footprint — `Model::kv_bytes_per_session`). "Slot" is kept for
    /// continuity with the pre-paging arena; a session only *resides*
    /// this much once it has stored into every page, and shared pages
    /// are counted once pool-wide, not per session.
    pub fn slot_bytes(&self) -> usize {
        self.pages_per_slot() * self.page_bytes()
    }

    /// Flat strip index within a page table. Hard-bounded: this feeds
    /// the raw-pointer carving in the views, so out-of-range
    /// coordinates must never reach it in any build profile.
    #[inline]
    fn strip_index(&self, layer: usize, which: usize, kvh: usize) -> usize {
        assert!(
            layer < self.n_layers && which < 2 && kvh < self.n_kv_heads,
            "KV strip coordinates out of range"
        );
        (layer * 2 + which) * self.n_kv_heads + kvh
    }
}

/// One page-table entry: which pool page backs (strip, page-index),
/// plus the sharing bit that drives COW.
#[derive(Clone, Copy)]
struct PageRef {
    id: u32,
    gen: u64,
    base: *mut u32,
    /// `true` ⇒ another holder may reference this page: read-only until
    /// reclaimed in place (rc back to 1) or copied on write.
    shared: bool,
}

/// Affine handle to one session's KV pages. Not `Clone` — `&mut
/// KvHandle` is exclusive write access to its owned pages and
/// `&KvHandle` is shared read access; sharing goes through
/// [`KvArena::fork`] or the prefix-cache lending API, which bump
/// refcounts and flip entries to `shared`.
pub struct KvHandle {
    arena_id: u64,
    n_pages: usize,
    table: Box<[Option<PageRef>]>,
}

// SAFETY: sending the handle moves its page table to another thread —
// the arena's refcounts keep every referenced page alive, `shared`
// pages are never written through any handle, and non-shared pages are
// written only through `&mut` access to THIS handle (aliasing header),
// so no aliased writes can arise from the move. The raw base pointers
// are pre-resolved addresses, only dereferenced under the views.
unsafe impl Send for KvHandle {}
// SAFETY: `&KvHandle` grants only shared *read* access to referenced
// pages (KvView); concurrent shared reads are race-free, and mutation
// requires `&mut KvHandle`, which the borrow checker makes exclusive.
unsafe impl Sync for KvHandle {}

impl KvHandle {
    /// Pages currently referenced by this handle (lazily grown: 0 after
    /// `acquire`, one per touched (strip, page) after stores).
    pub fn page_count(&self) -> usize {
        self.table.iter().flatten().count()
    }

    /// Referenced pages flagged shared (lent to / borrowed from the
    /// prefix cache or a fork).
    pub fn shared_page_count(&self) -> usize {
        self.table.iter().flatten().filter(|p| p.shared).count()
    }

    /// `(id, generation)` of every referenced page, table order — the
    /// observable the resurrection/leak tests key on.
    pub fn page_ids(&self) -> Vec<(u32, u64)> {
        self.table.iter().flatten().map(|p| (p.id, p.gen)).collect()
    }
}

/// Point-in-time arena counters (surfaced through `serving::metrics`
/// into the serve summary and `BENCH_decode.json`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// live sessions right now
    pub slots_in_use: usize,
    /// most sessions ever live at once
    pub high_water: usize,
    /// cumulative session admissions (acquires + forks)
    pub slots_created: usize,
    /// page allocations served from the free list (pooling hit count)
    pub reused: usize,
    /// bytes of slab currently backing the page pool
    pub bytes_resident: usize,
    /// **real packed** bytes per full session under the arena's format
    pub slot_bytes: usize,
    /// `fork()` operations — refcount bumps now, not byte copies (the
    /// copies divergence later pays are `cow_copies`)
    pub fork_copies: u64,
    /// copy-on-write page copies (first divergent store into a page
    /// that still had other holders)
    pub cow_copies: u64,
    /// pages with rc ≥ 1
    pub pages_in_use: usize,
    /// pages with rc ≥ 2 (physically shared right now)
    pub pages_shared: usize,
    /// most pages ever live at once
    pub pages_high_water: usize,
    /// bytes per page (the sharing/eviction granularity)
    pub page_bytes: usize,
}

struct ArenaInner {
    /// owning slab segments; boxed so the heap buffers never move when
    /// the segment list grows — page base pointers stay valid forever
    segments: Vec<Box<[u32]>>,
    /// per-page base pointer into its segment, indexed by page id
    bases: Vec<*mut u32>,
    /// per-page refcount (0 = on the free list)
    rc: Vec<u32>,
    /// per-page generation, bumped when the page is freed; a mismatch
    /// means a stale reference
    gen: Vec<u64>,
    /// LIFO free list of page ids (warmest lines first)
    free: Vec<u32>,
    sessions: usize,
    session_high_water: usize,
    sessions_created: usize,
    reused: usize,
    fork_ops: u64,
    cow_copies: u64,
    bytes_resident: usize,
    pages_in_use: usize,
    pages_high_water: usize,
}

// SAFETY: the raw per-page pointers are only dereferenced through
// KvView/KvViewMut under the handle discipline (never through
// ArenaInner itself); the bookkeeping is only touched under the arena
// mutex, and the `Box<[u32]>` segments it owns are Send.
unsafe impl Send for ArenaInner {}

impl ArenaInner {
    /// Carve `add_slots` slots' worth of fresh pages into the free
    /// list. Pushed in reverse so LIFO pops hand out ascending ids —
    /// a batch-filled session lands in adjacent pages.
    fn grow(&mut self, geom: &KvGeom, add_slots: usize) {
        let pw = geom.page_words();
        let count = add_slots * geom.pages_per_slot();
        let mut seg = vec![0u32; count * pw].into_boxed_slice();
        let base = seg.as_mut_ptr();
        let first = self.bases.len() as u32;
        for i in 0..count {
            // SAFETY: `i < count` and the segment holds exactly
            // `count * pw` words, so the offset stays inside the fresh
            // allocation; the boxed slice is pushed onto `segments`
            // below and never dropped or moved, so the carved page
            // bases remain valid for the arena's lifetime.
            self.bases.push(unsafe { base.add(i * pw) });
            self.rc.push(0);
            self.gen.push(1);
        }
        for id in (first..first + count as u32).rev() {
            self.free.push(id);
        }
        self.bytes_resident += count * pw * 4;
        self.segments.push(seg);
    }

    /// Pop a free page (rc 0 → 1), growing within the slot cap. `None`
    /// when the free list is empty and growth is exhausted — the caller
    /// escalates to the reclaimer.
    fn try_alloc(&mut self, geom: &KvGeom, initial_slots: usize, max_slots: usize) -> Option<u32> {
        if self.free.is_empty() {
            let pps = geom.pages_per_slot();
            let have = self.bases.len() / pps;
            let want = if have == 0 {
                initial_slots.min(max_slots)
            } else {
                have.min(max_slots.saturating_sub(have)) // doubling, capped
            };
            if want == 0 {
                return None;
            }
            self.grow(geom, want);
        }
        let id = self.free.pop()?;
        let i = id as usize;
        assert_eq!(self.rc[i], 0, "free KV page with live refcount");
        self.rc[i] = 1;
        self.reused += usize::from(self.gen[i] > 1); // gen 1 = first life
        self.pages_in_use += 1;
        self.pages_high_water = self.pages_high_water.max(self.pages_in_use);
        Some(id)
    }

    /// Drop one reference; at rc 0 the generation bumps and the page
    /// returns to the free list. Returns whether the page was freed.
    fn release_ref(&mut self, id: u32, gen: u64) -> bool {
        let i = id as usize;
        assert!(self.gen[i] == gen && self.rc[i] > 0, "double release / stale KV page ref");
        self.rc[i] -= 1;
        if self.rc[i] == 0 {
            self.gen[i] += 1;
            self.free.push(id);
            self.pages_in_use -= 1;
            true
        } else {
            false
        }
    }
}

/// Reclaim hook: asked to free at least N pages, returns how many it
/// actually freed. Registered by the prefix cache's LRU evictor.
type Reclaimer = Box<dyn Fn(usize) -> usize + Send + Sync>;

/// One pooled, paged KV arena per model. See the module docs for the
/// layout, refcount lifecycle, and ownership contract.
pub struct KvArena {
    id: u64,
    geom: KvGeom,
    initial_slots: usize,
    max_slots: usize,
    reclaim: Mutex<Option<Reclaimer>>,
    inner: Mutex<ArenaInner>,
}

impl std::fmt::Debug for KvArena {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvArena")
            .field("geom", &self.geom)
            .field("stats", &self.stats())
            .finish()
    }
}

impl KvArena {
    /// Arena whose page pool grows without bound (by doubling).
    pub fn new(geom: KvGeom, initial_slots: usize) -> Self {
        Self::with_limit(geom, initial_slots, usize::MAX)
    }

    /// Arena capped at `max_slots` concurrent sessions and `max_slots`
    /// slots' worth of pages; `acquire`/`fork` return `None` at the
    /// session cap, page pressure beyond the pool cap escalates to the
    /// reclaimer and then panics "KV arena exhausted".
    pub fn with_limit(geom: KvGeom, initial_slots: usize, max_slots: usize) -> Self {
        assert!(initial_slots > 0, "arena needs at least one slot");
        Self {
            id: NEXT_ARENA_ID.fetch_add(1, Ordering::Relaxed),
            geom,
            initial_slots,
            max_slots,
            reclaim: Mutex::new(None),
            inner: Mutex::new(ArenaInner {
                segments: Vec::new(),
                bases: Vec::new(),
                rc: Vec::new(),
                gen: Vec::new(),
                free: Vec::new(),
                sessions: 0,
                session_high_water: 0,
                sessions_created: 0,
                reused: 0,
                fork_ops: 0,
                cow_copies: 0,
                bytes_resident: 0,
                pages_in_use: 0,
                pages_high_water: 0,
            }),
        }
    }

    pub fn geom(&self) -> KvGeom {
        self.geom
    }

    /// Unique id of this arena (used to key per-arena metrics and to
    /// reject foreign handles).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Session cap (`usize::MAX` = unbounded).
    pub fn max_slots(&self) -> usize {
        self.max_slots
    }

    /// Register the under-pressure page reclaimer (the prefix cache's
    /// LRU leaf evictor). Invoked with **no** arena lock held, so it
    /// may re-enter through [`Self::release_page_refs`].
    pub fn set_reclaimer(&self, f: impl Fn(usize) -> usize + Send + Sync + 'static) {
        *self.reclaim.lock().unwrap() = Some(Box::new(f));
    }

    /// A handle is only meaningful inside the arena that minted it —
    /// foreign refcount traffic would corrupt the page pool.
    #[inline]
    fn check_owned(&self, h: &KvHandle) {
        assert_eq!(h.arena_id, self.id, "KV handle used with a foreign arena");
    }

    /// Admit a new session with an empty page table. Pages are
    /// allocated lazily at first store per (strip, page); `None` once
    /// `max_slots` sessions are live — callers turn that into the
    /// "KV arena exhausted" panic, mirroring the capacity assert.
    pub fn acquire(&self) -> Option<KvHandle> {
        let mut inner = self.inner.lock().unwrap();
        if inner.sessions >= self.max_slots {
            return None;
        }
        inner.sessions += 1;
        inner.sessions_created += 1;
        inner.session_high_water = inner.session_high_water.max(inner.sessions);
        Some(KvHandle {
            arena_id: self.id,
            n_pages: self.geom.n_pages(),
            table: vec![None; self.geom.pages_per_slot()].into_boxed_slice(),
        })
    }

    /// Allocate one page, escalating to the reclaimer under pressure.
    /// Panics "KV arena exhausted" when nothing can be freed.
    fn alloc_page(&self) -> (u32, u64, *mut u32) {
        loop {
            {
                let mut inner = self.inner.lock().unwrap();
                if let Some(id) = inner.try_alloc(&self.geom, self.initial_slots, self.max_slots) {
                    let i = id as usize;
                    return (id, inner.gen[i], inner.bases[i]);
                }
            }
            // Pressure path: the arena lock is NOT held here — the
            // reclaimer (prefix-cache eviction) re-enters through
            // release_page_refs.
            let freed = match &*self.reclaim.lock().unwrap() {
                Some(f) => f(self.geom.pages_per_slot()),
                None => 0,
            };
            if freed == 0 {
                panic!("KV arena exhausted");
            }
        }
    }

    /// Copy-on-write resolution for a `shared` table entry: reclaim in
    /// place when this handle is the sole remaining holder (no copy),
    /// else copy the page **bytewise** into a fresh one — packed pages
    /// are position-contiguous words, so no re-quantization happens.
    fn cow(&self, pr: &mut PageRef) -> *mut u32 {
        {
            let inner = self.inner.lock().unwrap();
            let i = pr.id as usize;
            debug_assert_eq!(inner.gen[i], pr.gen, "COW of a stale page ref");
            if inner.rc[i] == 1 {
                // Sole holder: no concurrent rc increment is possible
                // (sharing a page requires an existing ref, and ours is
                // the only one), so the flip is race-free.
                drop(inner);
                pr.shared = false;
                return pr.base;
            }
        }
        let (id, gen, base) = self.alloc_page();
        // SAFETY: the source page is alive (this handle holds one of
        // its ≥ 2 refs) and read-only (shared ⇒ nobody writes it); the
        // destination is a fresh page referenced by nothing else; and
        // distinct page ids map to disjoint `page_words` spans, so the
        // ranges cannot overlap.
        unsafe {
            std::ptr::copy_nonoverlapping(pr.base as *const u32, base, self.geom.page_words());
        }
        let mut inner = self.inner.lock().unwrap();
        inner.cow_copies += 1;
        inner.release_ref(pr.id, pr.gen);
        *pr = PageRef { id, gen, base, shared: false };
        base
    }

    /// Branch-point share: a new session referencing `src`'s pages
    /// covering positions `0..pos` — a pure refcount bump (both sides'
    /// entries flip to `shared`; the first divergent store pays one
    /// page COW). `None` at the session cap.
    pub fn fork(&self, src: &mut KvHandle, pos: usize) -> Option<KvHandle> {
        self.check_owned(src);
        assert!(pos <= self.geom.cap, "fork position {pos} beyond slot capacity");
        let mut inner = self.inner.lock().unwrap();
        if inner.sessions >= self.max_slots {
            return None;
        }
        inner.sessions += 1;
        inner.sessions_created += 1;
        inner.session_high_water = inner.session_high_water.max(inner.sessions);
        inner.fork_ops += 1;
        let np = self.geom.n_pages();
        let need = pos.div_ceil(self.geom.page_positions);
        let mut table = vec![None; src.table.len()].into_boxed_slice();
        for s in 0..self.geom.n_strips() {
            for p in 0..need {
                let idx = s * np + p;
                if let Some(pr) = &mut src.table[idx] {
                    inner.rc[pr.id as usize] += 1;
                    pr.shared = true;
                    table[idx] = Some(PageRef { shared: true, ..*pr });
                }
            }
        }
        Some(KvHandle { arena_id: self.id, n_pages: np, table })
    }

    /// Retire a session: drop one ref per referenced page (freeing the
    /// ones that hit rc 0, with a generation bump) and release the
    /// session slot.
    pub fn release(&self, h: KvHandle) {
        self.check_owned(&h);
        let mut inner = self.inner.lock().unwrap();
        for pr in h.table.iter().flatten() {
            inner.release_ref(pr.id, pr.gen);
        }
        assert!(inner.sessions > 0, "double session release");
        inner.sessions -= 1;
    }

    /// Does `(id, gen)` name a currently-live page generation? Freed
    /// generations answer `false` forever — the resurrection check.
    pub fn page_is_live(&self, id: u32, gen: u64) -> bool {
        let inner = self.inner.lock().unwrap();
        let i = id as usize;
        i < inner.rc.len() && inner.gen[i] == gen && inner.rc[i] > 0
    }

    /// Current refcount of page `(id, gen)`, 0 for freed generations.
    /// The prefix cache's evictor compares this against its own per-page
    /// ref tally to tell cache-internal sharing (evicting cascades and
    /// eventually frees) from session borrows (evicting frees nothing
    /// and only destroys future hits).
    pub fn page_refs(&self, id: u32, gen: u64) -> usize {
        let inner = self.inner.lock().unwrap();
        let i = id as usize;
        if i < inner.rc.len() && inner.gen[i] == gen {
            inner.rc[i]
        } else {
            0
        }
    }

    /// Lend the pages covering `h`'s positions `0..pos` to an external
    /// holder (a prefix-cache node): rc += 1 per page, the handle's
    /// entries flip to `shared`, and the returned strip-major
    /// `(id, gen)` list — `n_strips × ⌈pos/pp⌉` entries — is the
    /// holder's receipt (drop it with [`Self::release_page_refs`]).
    /// Every covered page must have been stored (the donor prefilled
    /// through `pos`).
    pub fn export_prefix(&self, h: &mut KvHandle, pos: usize) -> Vec<(u32, u64)> {
        self.check_owned(h);
        assert!(pos <= self.geom.cap, "export position beyond slot capacity");
        let mut inner = self.inner.lock().unwrap();
        let np = self.geom.n_pages();
        let need = pos.div_ceil(self.geom.page_positions);
        let mut out = Vec::with_capacity(self.geom.n_strips() * need);
        for s in 0..self.geom.n_strips() {
            for p in 0..need {
                let pr = h.table[s * np + p].as_mut().expect("export of an unstored KV page");
                inner.rc[pr.id as usize] += 1;
                pr.shared = true;
                out.push((pr.id, pr.gen));
            }
        }
        out
    }

    /// Borrow cached pages into a fresh handle: positions `0..pos` of
    /// every strip resolve to `pages` (an [`Self::export_prefix`]-shaped
    /// list), rc += 1 per page, entries marked `shared` — the first
    /// divergent store COWs. Panics on a freed generation: the cache
    /// must only lend refs it still holds.
    pub fn import_prefix(&self, h: &mut KvHandle, pages: &[(u32, u64)], pos: usize) {
        self.check_owned(h);
        assert!(pos <= self.geom.cap, "import position beyond slot capacity");
        let np = self.geom.n_pages();
        let need = pos.div_ceil(self.geom.page_positions);
        assert_eq!(pages.len(), self.geom.n_strips() * need, "borrowed page list shape");
        let mut inner = self.inner.lock().unwrap();
        let mut it = pages.iter();
        for s in 0..self.geom.n_strips() {
            for p in 0..need {
                let &(id, gen) = it.next().expect("length checked above");
                let i = id as usize;
                assert!(inner.gen[i] == gen && inner.rc[i] > 0, "import of a freed KV page");
                inner.rc[i] += 1;
                debug_assert!(h.table[s * np + p].is_none(), "import over a populated entry");
                h.table[s * np + p] =
                    Some(PageRef { id, gen, base: inner.bases[i], shared: true });
            }
        }
    }

    /// rc += 1 on each listed page — a cache node cloning part of
    /// another node's coverage (radix split). All refs must be live.
    pub fn page_ref_inc(&self, pages: &[(u32, u64)]) {
        let mut inner = self.inner.lock().unwrap();
        for &(id, gen) in pages {
            let i = id as usize;
            assert!(inner.gen[i] == gen && inner.rc[i] > 0, "ref-inc of a freed KV page");
            inner.rc[i] += 1;
        }
    }

    /// Drop external refs (cache node release / eviction); returns how
    /// many pages hit rc 0 and went back to the free list.
    pub fn release_page_refs(&self, pages: &[(u32, u64)]) -> usize {
        let mut inner = self.inner.lock().unwrap();
        pages.iter().filter(|&&(id, gen)| inner.release_ref(id, gen)).count()
    }

    /// Shared read access to a session's pages.
    pub fn view<'a>(&'a self, h: &'a KvHandle) -> KvView<'a> {
        self.check_owned(h);
        KvView { geom: self.geom, handle: h }
    }

    /// Exclusive store access (with COW resolution through the arena).
    pub fn view_mut<'a>(&'a self, h: &'a mut KvHandle) -> KvViewMut<'a> {
        self.check_owned(h);
        KvViewMut { arena: self, geom: self.geom, handle: h }
    }

    pub fn stats(&self) -> ArenaStats {
        let inner = self.inner.lock().unwrap();
        ArenaStats {
            slots_in_use: inner.sessions,
            high_water: inner.session_high_water,
            slots_created: inner.sessions_created,
            reused: inner.reused,
            bytes_resident: inner.bytes_resident,
            slot_bytes: self.geom.slot_bytes(),
            fork_copies: inner.fork_ops,
            cow_copies: inner.cow_copies,
            pages_in_use: inner.pages_in_use,
            pages_shared: inner.rc.iter().filter(|&&rc| rc >= 2).count(),
            pages_high_water: inner.pages_high_water,
            page_bytes: self.geom.page_bytes(),
        }
    }
}

/// Per-page read accessors shared by [`KvView`] and [`KvViewMut`] (the
/// mut view re-exposes them so the decode step can read back what it
/// stored under one exclusive borrow).
macro_rules! impl_page_readers {
    () => {
        /// The arena's strip format (drives kernel dispatch).
        #[inline]
        pub fn format(&self) -> KvFormat {
            self.geom.format
        }

        #[inline]
        fn page_ref(&self, layer: usize, which: usize, kvh: usize, page: usize) -> &PageRef {
            assert!(page < self.geom.n_pages(), "KV page index out of range");
            let idx = self.geom.strip_index(layer, which, kvh) * self.handle.n_pages + page;
            self.handle.table[idx].as_ref().expect("KV page read before first store")
        }

        /// Page `page` of the K strip of `kvh` in `layer`: the page's
        /// `pp × head_dim` f32s — [`KvFormat::F32`] arenas only.
        #[inline]
        pub fn k_page(&self, layer: usize, kvh: usize, page: usize) -> &[f32] {
            self.f32_page(layer, 0, kvh, page)
        }

        /// Page `page` of the V strip (see [`Self::k_page`]).
        #[inline]
        pub fn v_page(&self, layer: usize, kvh: usize, page: usize) -> &[f32] {
            self.f32_page(layer, 1, kvh, page)
        }

        /// Packed page `page` of the K strip — one self-contained
        /// `pp`-position strip, [`KvFormat::BitPlane`] arenas only.
        #[inline]
        pub fn k_page_packed(&self, layer: usize, kvh: usize, page: usize) -> PackedStrip<'_> {
            self.packed_page(layer, 0, kvh, page)
        }

        /// Packed page `page` of the V strip.
        #[inline]
        pub fn v_page_packed(&self, layer: usize, kvh: usize, page: usize) -> PackedStrip<'_> {
            self.packed_page(layer, 1, kvh, page)
        }

        #[inline]
        fn f32_page(&self, layer: usize, which: usize, kvh: usize, page: usize) -> &[f32] {
            assert_eq!(self.geom.format, KvFormat::F32, "f32 strip read on a packed arena");
            let pr = self.page_ref(layer, which, kvh, page);
            // SAFETY: the page is alive for this borrow (the handle
            // holds a refcount on it, and the handle is borrowed by
            // this view); u32 and f32 share size/alignment; and no
            // `&mut` can coexist — shared pages are never written,
            // owned pages only through `&mut` access to the same handle
            // this borrow freezes (aliasing header).
            unsafe {
                std::slice::from_raw_parts(pr.base as *const f32, self.geom.page_words())
            }
        }

        #[inline]
        fn packed_page(&self, layer: usize, which: usize, kvh: usize, page: usize) -> PackedStrip<'_> {
            let pg = self.geom.packed_page().expect("packed strip read on an f32 arena");
            let pr = self.page_ref(layer, which, kvh, page);
            // SAFETY: as in `f32_page` — refcount-held liveness,
            // disjoint page spans, no coexisting `&mut` per the
            // aliasing header; the slice is exactly the page span.
            let words =
                unsafe { std::slice::from_raw_parts(pr.base as *const u32, pg.strip_words()) };
            PackedStrip::new(pg, words)
        }
    };
}

/// Shared (read-only) borrow of one session's pages. Lifetime-tied to
/// both the arena and the handle, so no page can be released or
/// mutated out from under a reader.
pub struct KvView<'a> {
    geom: KvGeom,
    handle: &'a KvHandle,
}

impl KvView<'_> {
    impl_page_readers!();
}

/// Exclusive borrow of one session's pages (store + read). Stores
/// resolve ownership per page: owned → lock-free in-place write,
/// missing → allocate, shared → copy-on-write through the arena.
pub struct KvViewMut<'a> {
    arena: &'a KvArena,
    geom: KvGeom,
    handle: &'a mut KvHandle,
}

impl KvViewMut<'_> {
    impl_page_readers!();

    /// Writable base of (strip, page): the fast path — an entry this
    /// handle already owns — touches no lock.
    fn ensure_owned(&mut self, strip: usize, page: usize) -> *mut u32 {
        let arena = self.arena;
        let idx = strip * self.handle.n_pages + page;
        match &mut self.handle.table[idx] {
            Some(pr) if !pr.shared => pr.base,
            Some(pr) => arena.cow(pr),
            slot @ None => {
                let (id, gen, base) = arena.alloc_page();
                *slot = Some(PageRef { id, gen, base, shared: false });
                base
            }
        }
    }

    /// Store one `kv_dim`-wide K projection row at position `pos` —
    /// dense copy under [`KvFormat::F32`], bit-plane quantization under
    /// [`KvFormat::BitPlane`] (the once-per-token encode; nothing
    /// downstream re-quantizes).
    #[inline]
    pub fn store_k(&mut self, layer: usize, pos: usize, row: &[f32]) {
        self.store(layer, 0, pos, row)
    }

    /// Store one `kv_dim`-wide V projection row at position `pos` (see
    /// [`KvViewMut::store_k`]).
    #[inline]
    pub fn store_v(&mut self, layer: usize, pos: usize, row: &[f32]) {
        self.store(layer, 1, pos, row)
    }

    fn store(&mut self, layer: usize, which: usize, pos: usize, row: &[f32]) {
        let g = self.geom;
        let hd = g.head_dim;
        assert_eq!(row.len(), g.n_kv_heads * hd, "KV row width != kv_dim");
        assert!(pos < g.cap, "store position beyond slot capacity");
        let (page, u) = (pos / g.page_positions, pos % g.page_positions);
        for kvh in 0..g.n_kv_heads {
            let strip = g.strip_index(layer, which, kvh);
            let base = self.ensure_owned(strip, page);
            let head = &row[kvh * hd..(kvh + 1) * hd];
            match g.packed_page() {
                None => {
                    // SAFETY: `base` is a live page this handle owns
                    // non-shared (ensure_owned), written only through
                    // this `&mut` borrow (aliasing header); `u < pp` so
                    // the row span stays inside the page's pp·hd words.
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            head.as_ptr(),
                            (base as *mut f32).add(u * hd),
                            hd,
                        );
                    }
                }
                Some(pg) => {
                    // SAFETY: same ownership/liveness argument; the
                    // slice is exactly the page's strip_words span.
                    let words =
                        unsafe { std::slice::from_raw_parts_mut(base, pg.strip_words()) };
                    PackedStripMut::new(pg, words).store_row(u, head);
                }
            }
        }
    }

    /// Store `n` consecutive K projection rows starting at position
    /// `pos0` (`rows` is the flat `n × kv_dim` slab, position-major) —
    /// the chunked-prefill bulk store. Byte-identical end state to `n`
    /// [`KvViewMut::store_k`] calls: the per-row encode keeps no
    /// cross-position state, so only the *bookkeeping* is amortized —
    /// one ownership resolution (and one packed view) per touched page
    /// instead of one per position.
    #[inline]
    pub fn store_k_run(&mut self, layer: usize, pos0: usize, rows: &[f32]) {
        self.store_run(layer, 0, pos0, rows)
    }

    /// Store `n` consecutive V projection rows (see
    /// [`KvViewMut::store_k_run`]).
    #[inline]
    pub fn store_v_run(&mut self, layer: usize, pos0: usize, rows: &[f32]) {
        self.store_run(layer, 1, pos0, rows)
    }

    fn store_run(&mut self, layer: usize, which: usize, pos0: usize, rows: &[f32]) {
        let g = self.geom;
        let kvd = g.n_kv_heads * g.head_dim;
        assert_eq!(rows.len() % kvd, 0, "KV run width != n × kv_dim");
        let n = rows.len() / kvd;
        if n == 0 {
            return;
        }
        assert!(pos0 + n <= g.cap, "store run beyond slot capacity");
        for kvh in 0..g.n_kv_heads {
            let strip = g.strip_index(layer, which, kvh);
            self.store_strip_run(strip, kvh, pos0, rows, n);
        }
    }

    /// One strip's page-segment walk for [`KvViewMut::store_run`]: the
    /// run `[pos0, pos0+n)` is split at page boundaries, and each
    /// touched page resolves ownership (COW/alloc) and constructs its
    /// write view **once**, however many positions land on it.
    // lint: hot
    fn store_strip_run(&mut self, strip: usize, kvh: usize, pos0: usize, rows: &[f32], n: usize) {
        let g = self.geom;
        let (hd, pp) = (g.head_dim, g.page_positions);
        let kvd = g.n_kv_heads * hd;
        let mut i = 0usize;
        while i < n {
            let pos = pos0 + i;
            let (page, u0) = (pos / pp, pos % pp);
            let seg = (pp - u0).min(n - i);
            let base = self.ensure_owned(strip, page);
            match g.packed_page() {
                None => {
                    for j in 0..seg {
                        let head = &rows[(i + j) * kvd + kvh * hd..][..hd];
                        // SAFETY: `base` is a live page this handle owns
                        // non-shared (ensure_owned), written only through
                        // this `&mut` borrow (aliasing header);
                        // `u0 + seg ≤ pp` keeps every row span inside
                        // the page's pp·hd words.
                        unsafe {
                            std::ptr::copy_nonoverlapping(
                                head.as_ptr(),
                                (base as *mut f32).add((u0 + j) * hd),
                                hd,
                            );
                        }
                    }
                }
                Some(pg) => {
                    // SAFETY: same ownership/liveness argument as the
                    // single store; the slice is exactly the page span.
                    let words =
                        unsafe { std::slice::from_raw_parts_mut(base, pg.strip_words()) };
                    PackedStripMut::new(pg, words).store_rows(
                        u0,
                        rows[i * kvd..(i + seg) * kvd]
                            .chunks_exact(kvd)
                            .map(|r| &r[kvh * hd..(kvh + 1) * hd]),
                    );
                }
            }
            i += seg;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic_model, ModelConfig};
    use std::sync::Arc;

    fn model() -> Arc<Model> {
        Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 12,
                d_model: 8,
                n_layers: 1,
                n_heads: 1,
                n_kv_heads: 1,
                d_ff: 12,
                max_seq: 16,
                kv_format: KvFormat::F32,
            },
            1,
        ))
    }

    fn geom() -> KvGeom {
        KvGeom::of(&model())
    }

    /// Tiny multi-page geometry: pp = 2, cap = 8, one (layer, kv-head)
    /// pair → 2 strips × 4 pages = 8 pages per slot.
    fn paged_geom(format: KvFormat) -> KvGeom {
        KvGeom {
            n_layers: 1,
            n_kv_heads: 1,
            head_dim: 8,
            cap: 8,
            page_positions: 2,
            format,
        }
    }

    fn row(seed: usize, hd: usize) -> Vec<f32> {
        (0..hd).map(|j| ((seed * 7 + j * 3) % 13) as f32 * 0.25 - 1.0).collect()
    }

    #[test]
    fn slot_bytes_matches_model_formula() {
        let m = model();
        let g = KvGeom::of(&m);
        assert_eq!(g.page_positions, 32, "default kv_page");
        assert_eq!(g.n_pages(), 2);
        assert_eq!(g.slot_bytes(), m.kv_bytes_per_session());
        // Paged f32 slots keep the historical formula exactly.
        assert_eq!(g.slot_bytes(), g.n_layers * 2 * g.n_kv_heads * g.cap * g.head_dim * 4);
    }

    #[test]
    fn packed_slot_bytes_shrink_8x_at_w2() {
        // Acceptance: at bits = 2 the per-slot footprint shrinks ≥ 8×
        // vs f32 on the bench geometry (head_dim 32) — paging must not
        // cost bytes.
        let f32_geom = KvGeom {
            n_layers: 4,
            n_kv_heads: 4,
            head_dim: 32,
            cap: 1024,
            page_positions: 32,
            format: KvFormat::F32,
        };
        let q2 = KvGeom { format: KvFormat::bit_plane(2), ..f32_geom };
        assert!(
            f32_geom.slot_bytes() >= 8 * q2.slot_bytes(),
            "W2 slot must be ≥8× smaller: f32 {} vs packed {}",
            f32_geom.slot_bytes(),
            q2.slot_bytes()
        );
        // Pages are independent packed strips of pp positions; at the
        // default pp the paged slot is byte-identical to the monolithic
        // packed strip layout.
        let mono = PackedGeom::new(1024, 32, 2, 32).strip_words();
        assert_eq!(q2.n_pages() * q2.page_words(), mono);
        // Monotone in bits, and every packed format beats f32.
        let q3 = KvGeom { format: KvFormat::bit_plane(3), ..f32_geom };
        let q4 = KvGeom { format: KvFormat::bit_plane(4), ..f32_geom };
        assert!(q2.slot_bytes() < q3.slot_bytes() && q3.slot_bytes() < q4.slot_bytes());
        assert!(q4.slot_bytes() * 3 < f32_geom.slot_bytes());
    }

    #[test]
    fn kv_bits_cli_validation() {
        assert_eq!(KvFormat::from_kv_bits(0).unwrap(), KvFormat::F32);
        assert_eq!(
            KvFormat::from_kv_bits(2).unwrap(),
            KvFormat::BitPlane { bits: 2, group: KvFormat::DEFAULT_GROUP }
        );
        assert!(KvFormat::from_kv_bits(1).is_err());
        assert!(KvFormat::from_kv_bits(5).is_err());
    }

    #[test]
    fn lazy_pages_and_lifo_reuse() {
        let arena = KvArena::new(paged_geom(KvFormat::F32), 2);
        let mut h = arena.acquire().unwrap();
        assert_eq!(h.page_count(), 0, "acquire allocates no pages");
        arena.view_mut(&mut h).store_k(0, 0, &row(1, 8));
        assert_eq!(h.page_count(), 1);
        arena.view_mut(&mut h).store_k(0, 1, &row(2, 8));
        assert_eq!(h.page_count(), 1, "positions 0 and 1 share a pp=2 page");
        arena.view_mut(&mut h).store_k(0, 2, &row(3, 8));
        assert_eq!(h.page_count(), 2);
        let ids = h.page_ids();
        arena.release(h);
        // LIFO: the next session's first page reuses a freed one.
        let mut h2 = arena.acquire().unwrap();
        arena.view_mut(&mut h2).store_k(0, 0, &row(4, 8));
        let reused_id = h2.page_ids()[0].0;
        assert!(ids.iter().any(|&(id, _)| id == reused_id), "freed page not reused");
        assert!(arena.stats().reused >= 1);
        arena.release(h2);
    }

    #[test]
    fn grows_in_whole_slot_units_and_tracks_bytes() {
        let g = paged_geom(KvFormat::F32);
        let arena = KvArena::new(g, 2);
        assert_eq!(arena.stats().bytes_resident, 0, "no slab before first store");
        let mut hs: Vec<KvHandle> = (0..5).map(|_| arena.acquire().unwrap()).collect();
        for (i, h) in hs.iter_mut().enumerate() {
            for pos in 0..g.cap {
                arena.view_mut(h).store_k(0, pos, &row(i + pos, 8));
                arena.view_mut(h).store_v(0, pos, &row(i + pos + 1, 8));
            }
        }
        let st = arena.stats();
        assert_eq!(st.slots_in_use, 5);
        assert_eq!(st.high_water, 5);
        assert_eq!(st.pages_in_use, 5 * g.pages_per_slot());
        // Segments of 2, 2, 4 slots' pages → 8 slots resident for 5
        // full sessions; growth stays whole-slot so the modulus holds.
        assert_eq!(st.bytes_resident, 8 * g.slot_bytes());
        assert_eq!(st.bytes_resident % st.slot_bytes, 0);
        assert_eq!(st.slot_bytes, g.slot_bytes());
        for h in hs.drain(..) {
            arena.release(h);
        }
        assert_eq!(arena.stats().pages_in_use, 0);
        assert_eq!(arena.stats().slots_in_use, 0);
    }

    #[test]
    fn exhaustion_returns_none_at_limit() {
        let arena = KvArena::with_limit(geom(), 1, 2);
        let a = arena.acquire().unwrap();
        let b = arena.acquire().unwrap();
        assert!(arena.acquire().is_none(), "arena at max_slots must refuse");
        arena.release(a);
        assert!(arena.acquire().is_some(), "released session acquirable again");
        arena.release(b);
    }

    #[test]
    #[should_panic(expected = "KV arena exhausted")]
    fn page_pool_exhaustion_panics() {
        // 1-session cap = 1 slot of pages. Fill the session, lend every
        // page to a (never-evicting) cache, then diverge: the first COW
        // needs a page the pool cannot provide.
        let g = paged_geom(KvFormat::F32);
        let arena = KvArena::with_limit(g, 1, 1);
        let mut h = arena.acquire().unwrap();
        for pos in 0..g.cap {
            arena.view_mut(&mut h).store_k(0, pos, &row(pos, 8));
            arena.view_mut(&mut h).store_v(0, pos, &row(pos, 8));
        }
        let _cached = arena.export_prefix(&mut h, g.cap);
        arena.view_mut(&mut h).store_k(0, 0, &row(99, 8));
    }

    #[test]
    fn generation_invalidates_freed_pages() {
        let g = paged_geom(KvFormat::F32);
        let arena = KvArena::new(g, 1);
        let mut h = arena.acquire().unwrap();
        arena.view_mut(&mut h).store_k(0, 0, &row(1, 8));
        let (id, gen) = h.page_ids()[0];
        assert!(arena.page_is_live(id, gen));
        arena.release(h);
        assert!(!arena.page_is_live(id, gen), "freed generation must go stale");
        // Reuse bumps the generation: the new life is live, the old
        // (id, gen) pair stays dead — resurrection safety.
        let mut h2 = arena.acquire().unwrap();
        arena.view_mut(&mut h2).store_k(0, 0, &row(2, 8));
        let (id2, gen2) = h2.page_ids()[0];
        assert_eq!(id2, id, "LIFO hands the freed page back");
        assert_ne!(gen2, gen, "reuse must bump the generation");
        assert!(arena.page_is_live(id2, gen2));
        assert!(!arena.page_is_live(id, gen));
        arena.release(h2);
    }

    #[test]
    #[should_panic(expected = "foreign arena")]
    fn foreign_handle_rejected() {
        // Refcount traffic against a foreign arena would corrupt both
        // pools — it must fail loudly instead.
        let a = KvArena::new(geom(), 2);
        let b = KvArena::new(geom(), 2);
        let h = a.acquire().unwrap();
        b.release(h);
    }

    #[test]
    fn store_then_page_read_roundtrip() {
        let g = paged_geom(KvFormat::F32);
        let arena = KvArena::new(g, 1);
        let mut h = arena.acquire().unwrap();
        for pos in 0..5 {
            arena.view_mut(&mut h).store_k(0, pos, &row(pos, 8));
            arena.view_mut(&mut h).store_v(0, pos, &row(pos + 9, 8));
        }
        let v = arena.view(&h);
        for pos in 0..5 {
            let (pg, u) = (pos / g.page_positions, pos % g.page_positions);
            assert_eq!(&v.k_page(0, 0, pg)[u * 8..(u + 1) * 8], &row(pos, 8)[..], "K pos {pos}");
            assert_eq!(
                &v.v_page(0, 0, pg)[u * 8..(u + 1) * 8],
                &row(pos + 9, 8)[..],
                "V pos {pos}"
            );
        }
        arena.release(h);
    }

    #[test]
    fn store_run_matches_sequential_stores_bytewise() {
        // The chunked-prefill bulk store must leave every touched page
        // byte-for-byte identical to per-position stores — f32 and
        // packed, runs starting mid-page and crossing page boundaries,
        // multi-head rows.
        for format in [KvFormat::F32, KvFormat::BitPlane { bits: 2, group: 8 }] {
            let g = KvGeom {
                n_layers: 1,
                n_kv_heads: 2,
                head_dim: 8,
                cap: 8,
                page_positions: 2,
                format,
            };
            let arena = KvArena::new(g, 2);
            let kvd = g.n_kv_heads * g.head_dim;
            // 5 rows at positions 1..6: page 0 partial, pages 1–2 full.
            let rows: Vec<f32> =
                (0..5 * kvd).map(|i| ((i * 7) % 13) as f32 * 0.25 - 1.0).collect();
            let mut ha = arena.acquire().unwrap();
            let mut hb = arena.acquire().unwrap();
            {
                let mut va = arena.view_mut(&mut ha);
                for (j, r) in rows.chunks_exact(kvd).enumerate() {
                    va.store_k(0, 1 + j, r);
                    va.store_v(0, 1 + j, r);
                }
            }
            {
                let mut vb = arena.view_mut(&mut hb);
                vb.store_k_run(0, 1, &rows);
                vb.store_v_run(0, 1, &rows);
            }
            let (va, vb) = (arena.view(&ha), arena.view(&hb));
            for kvh in 0..g.n_kv_heads {
                match format {
                    KvFormat::F32 => {
                        // Fully-stored pages compare whole; the partial
                        // page compares only its stored row (position 0
                        // was never written — dirty words there are
                        // unspecified by design).
                        for pg in [1usize, 2] {
                            let (ka, kb) = (va.k_page(0, kvh, pg), vb.k_page(0, kvh, pg));
                            assert_eq!(ka, kb, "{format:?}");
                            let (pa, pb) = (va.v_page(0, kvh, pg), vb.v_page(0, kvh, pg));
                            assert_eq!(pa, pb, "{format:?}");
                        }
                        assert_eq!(
                            &va.k_page(0, kvh, 0)[8..16],
                            &vb.k_page(0, kvh, 0)[8..16],
                            "{format:?} partial page"
                        );
                    }
                    KvFormat::BitPlane { .. } => {
                        for pg in [1usize, 2] {
                            assert_eq!(
                                va.k_page_packed(0, kvh, pg).words,
                                vb.k_page_packed(0, kvh, pg).words,
                                "{format:?} K page {pg}"
                            );
                            assert_eq!(
                                va.v_page_packed(0, kvh, pg).words,
                                vb.v_page_packed(0, kvh, pg).words,
                                "{format:?} V page {pg}"
                            );
                        }
                        let mut a = vec![0.0f32; 8];
                        let mut b = vec![0.0f32; 8];
                        va.k_page_packed(0, kvh, 0).dequant_row(1, &mut a);
                        vb.k_page_packed(0, kvh, 0).dequant_row(1, &mut b);
                        assert_eq!(a, b, "{format:?} partial page");
                    }
                }
            }
            drop((va, vb));
            arena.release(ha);
            arena.release(hb);
        }
    }

    #[test]
    fn packed_store_then_dequant_roundtrip() {
        // Arena-level pack→unpack across pages: stored rows dequantize
        // back within one grid step, across layers, heads, K and V.
        for bits in [2usize, 3, 4] {
            let g = KvGeom {
                n_layers: 2,
                n_kv_heads: 2,
                head_dim: 8,
                cap: 8,
                page_positions: 2,
                format: KvFormat::BitPlane { bits, group: 8 },
            };
            let arena = KvArena::new(g, 2);
            let mut h = arena.acquire().unwrap();
            let kvd = g.n_kv_heads * g.head_dim;
            let rows: Vec<Vec<f32>> = (0..3)
                .map(|p| (0..kvd).map(|i| ((p * 31 + i * 7) % 13) as f32 * 0.21 - 1.0).collect())
                .collect();
            {
                let mut v = arena.view_mut(&mut h);
                for (p, row) in rows.iter().enumerate() {
                    for l in 0..g.n_layers {
                        v.store_k(l, p, row);
                        v.store_v(l, p, row);
                    }
                }
            }
            let v = arena.view(&h);
            let levels = ((1usize << bits) - 1) as f32;
            let mut out = vec![0.0f32; g.head_dim];
            for l in 0..g.n_layers {
                for kvh in 0..g.n_kv_heads {
                    for (p, row) in rows.iter().enumerate() {
                        let want = &row[kvh * g.head_dim..(kvh + 1) * g.head_dim];
                        let mn = want.iter().cloned().fold(f32::INFINITY, f32::min);
                        let mx = want.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                        let step = (mx - mn) / levels;
                        let (pg, u) = (p / g.page_positions, p % g.page_positions);
                        for (strip, which) in [
                            (v.k_page_packed(l, kvh, pg), "K"),
                            (v.v_page_packed(l, kvh, pg), "V"),
                        ] {
                            strip.dequant_row(u, &mut out);
                            for (j, (&a, &b)) in want.iter().zip(&out).enumerate() {
                                assert!(
                                    (a - b).abs() <= step * 1.001 + 5e-3,
                                    "bits {bits} {which} l {l} kvh {kvh} p {p} j {j}: {a} vs {b}"
                                );
                            }
                        }
                    }
                }
            }
            arena.release(h);
        }
    }

    #[test]
    #[should_panic(expected = "f32 strip read on a packed arena")]
    fn f32_read_on_packed_arena_fails_loudly() {
        let g = paged_geom(KvFormat::bit_plane(2));
        let arena = KvArena::new(g, 1);
        let mut h = arena.acquire().unwrap();
        arena.view_mut(&mut h).store_k(0, 0, &row(0, 8));
        let _ = arena.view(&h).k_page(0, 0, 0);
    }

    #[test]
    fn fork_shares_pages_without_copy() {
        let g = paged_geom(KvFormat::F32);
        let arena = KvArena::new(g, 1);
        let mut src = arena.acquire().unwrap();
        for pos in 0..4 {
            arena.view_mut(&mut src).store_k(0, pos, &row(pos, 8));
            arena.view_mut(&mut src).store_v(0, pos, &row(pos, 8));
        }
        let before = arena.stats().pages_in_use;
        let dst = arena.fork(&mut src, 4).unwrap();
        let st = arena.stats();
        assert_eq!(st.pages_in_use, before, "fork must not allocate pages");
        assert_eq!(st.fork_copies, 1);
        assert_eq!(st.cow_copies, 0);
        assert_eq!(st.pages_shared, before, "every live page now shared");
        assert_eq!(dst.page_ids(), src.page_ids(), "same physical pages");
        assert_eq!(src.shared_page_count(), src.page_count());
        // Reads see identical bytes through both handles.
        let (sv, dv) = (arena.view(&src), arena.view(&dst));
        for pg in 0..2 {
            assert_eq!(sv.k_page(0, 0, pg), dv.k_page(0, 0, pg), "page {pg}");
            assert_eq!(sv.v_page(0, 0, pg), dv.v_page(0, 0, pg), "page {pg}");
        }
        arena.release(dst);
        arena.release(src);
        assert_eq!(arena.stats().pages_in_use, 0);
    }

    #[test]
    fn cow_on_divergent_store_and_in_place_reclaim() {
        let g = paged_geom(KvFormat::F32);
        let arena = KvArena::new(g, 1);
        let mut src = arena.acquire().unwrap();
        for pos in 0..2 {
            arena.view_mut(&mut src).store_k(0, pos, &row(pos, 8));
            arena.view_mut(&mut src).store_v(0, pos, &row(pos, 8));
        }
        let mut dst = arena.fork(&mut src, 2).unwrap();
        // Divergent store through src: the page is still referenced by
        // dst, so src pays one bytewise page copy; dst sees nothing.
        let dst_k_before = arena.view(&dst).k_page(0, 0, 0).to_vec();
        arena.view_mut(&mut src).store_k(0, 0, &row(42, 8));
        let st = arena.stats();
        assert_eq!(st.cow_copies, 1, "first divergent store pays one page copy");
        assert_eq!(arena.view(&dst).k_page(0, 0, 0), &dst_k_before[..], "COW left sharer intact");
        assert_eq!(&arena.view(&src).k_page(0, 0, 0)[..8], &row(42, 8)[..]);
        assert_eq!(
            &arena.view(&src).k_page(0, 0, 0)[8..16],
            &row(1, 8)[..],
            "COW copied the untouched neighbour position bytewise"
        );
        // Release the sharer: remaining shared pages reclaim in place
        // on the next store (rc back to 1 ⇒ no copy).
        arena.release(dst);
        let cows = arena.stats().cow_copies;
        arena.view_mut(&mut src).store_v(0, 0, &row(43, 8));
        assert_eq!(arena.stats().cow_copies, cows, "sole owner reclaims without copying");
        arena.release(src);
    }

    #[test]
    fn packed_fork_cow_mid_group_decodes_identically() {
        // hd = 4 ⇒ several positions share one plane word; pp = 4 keeps
        // a whole position-group in one page. Fork mid-word, diverge,
        // and check the sharer's rows survive COW bit-exactly — the
        // copy is bytewise, no re-quantization.
        let g = KvGeom {
            n_layers: 1,
            n_kv_heads: 1,
            head_dim: 4,
            cap: 8,
            page_positions: 4,
            format: KvFormat::BitPlane { bits: 2, group: 4 },
        };
        let arena = KvArena::new(g, 1);
        let mut src = arena.acquire().unwrap();
        for pos in 0..3 {
            arena.view_mut(&mut src).store_k(0, pos, &row(pos, 4));
            arena.view_mut(&mut src).store_v(0, pos, &row(pos, 4));
        }
        let mut dst = arena.fork(&mut src, 3).unwrap();
        let mut a = vec![0.0f32; 4];
        let mut b = vec![0.0f32; 4];
        // Parent stores position 3 — same plane word as 0..3 → COW.
        arena.view_mut(&mut src).store_k(0, 3, &row(33, 4));
        assert_eq!(arena.stats().cow_copies, 1);
        for pos in 0..3 {
            arena.view(&dst).k_page_packed(0, 0, 0).dequant_row(pos, &mut a);
            arena.view(&src).k_page_packed(0, 0, 0).dequant_row(pos, &mut b);
            assert_eq!(a, b, "shared prefix diverged at pos {pos}");
        }
        // The sharer continues independently — masked stores land on
        // its own (reclaimed-in-place) copy.
        arena.view_mut(&mut dst).store_k(0, 3, &row(77, 4));
        arena.view(&src).k_page_packed(0, 0, 0).dequant_row(3, &mut a);
        arena.view(&dst).k_page_packed(0, 0, 0).dequant_row(3, &mut b);
        assert_ne!(a, b, "divergent tails must not alias");
        arena.release(dst);
        arena.release(src);
    }

    #[test]
    fn packed_dirty_page_reuse_decodes_like_fresh() {
        // A reused (dirty) packed page must dequantize stored rows
        // exactly like its first (zero-filled) life — masked stores
        // overwrite every bit they later read.
        let g = KvGeom {
            page_positions: 2,
            format: KvFormat::BitPlane { bits: 2, group: 8 },
            ..paged_geom(KvFormat::F32)
        };
        let arena = KvArena::new(g, 1);
        let mut fresh = vec![0.0f32; 8];
        let mut reused = vec![0.0f32; 8];
        {
            let mut h = arena.acquire().unwrap();
            for pos in 0..g.cap {
                arena.view_mut(&mut h).store_k(0, pos, &row(pos + 5, 8));
            }
            arena.view(&h).k_page_packed(0, 0, 0).dequant_row(0, &mut fresh);
            arena.release(h); // pages back to the free list, dirty
        }
        {
            let mut h = arena.acquire().unwrap();
            arena.view_mut(&mut h).store_k(0, 0, &row(5, 8)); // dirty page
            arena.view(&h).k_page_packed(0, 0, 0).dequant_row(0, &mut reused);
            arena.release(h);
        }
        assert_eq!(fresh, reused, "dirty page reuse changed a stored row");
    }

    #[test]
    fn export_import_borrow_roundtrip() {
        let g = paged_geom(KvFormat::F32);
        let arena = KvArena::new(g, 1);
        let mut donor = arena.acquire().unwrap();
        for pos in 0..4 {
            arena.view_mut(&mut donor).store_k(0, pos, &row(pos, 8));
            arena.view_mut(&mut donor).store_v(0, pos, &row(pos, 8));
        }
        let cached = arena.export_prefix(&mut donor, 4);
        assert_eq!(cached.len(), g.n_strips() * 2, "2 pages per strip at pp=2, pos 4");
        assert_eq!(donor.shared_page_count(), donor.page_count());
        // Donor dies; the cache refs keep every page alive.
        arena.release(donor);
        assert!(cached.iter().all(|&(id, gen)| arena.page_is_live(id, gen)));
        // A fresh session borrows them read-only.
        let mut borrower = arena.acquire().unwrap();
        arena.import_prefix(&mut borrower, &cached, 4);
        assert_eq!(borrower.page_count(), cached.len());
        assert_eq!(&arena.view(&borrower).k_page(0, 0, 1)[..8], &row(2, 8)[..]);
        // Divergence at pos 2 COWs; the cached page is untouched.
        arena.view_mut(&mut borrower).store_k(0, 2, &row(99, 8));
        assert_eq!(arena.stats().cow_copies, 1);
        arena.release(borrower);
        // Cache eviction: pages free exactly once, generations die.
        let freed = arena.release_page_refs(&cached);
        assert_eq!(freed, cached.len());
        assert!(cached.iter().all(|&(id, gen)| !arena.page_is_live(id, gen)));
        assert_eq!(arena.stats().pages_in_use, 0);
    }

    #[test]
    fn reclaimer_frees_pages_under_pressure() {
        let g = paged_geom(KvFormat::F32);
        let arena = Arc::new(KvArena::with_limit(g, 1, 1));
        let mut donor = arena.acquire().unwrap();
        for pos in 0..g.cap {
            arena.view_mut(&mut donor).store_k(0, pos, &row(pos, 8));
            arena.view_mut(&mut donor).store_v(0, pos, &row(pos, 8));
        }
        // The whole 1-slot pool is cache-held after the donor dies.
        let cached = Arc::new(Mutex::new(Some(arena.export_prefix(&mut donor, g.cap))));
        arena.release(donor);
        let (a2, c2) = (arena.clone(), cached.clone());
        arena.set_reclaimer(move |_need| match c2.lock().unwrap().take() {
            Some(pages) => a2.release_page_refs(&pages),
            None => 0,
        });
        // A new session's store needs a page only eviction can supply.
        let mut h = arena.acquire().unwrap();
        arena.view_mut(&mut h).store_k(0, 0, &row(1, 8));
        assert!(cached.lock().unwrap().is_none(), "reclaimer must have run");
        arena.release(h);
        assert_eq!(arena.stats().pages_in_use, 0);
    }

    #[test]
    fn slab_backed_decode_matches_fresh_slot() {
        // A reused (dirty) session must decode token-identically to its
        // own first (zero-filled) use — stale data is never read.
        let m = model();
        let mut a = m.decode_state();
        let fresh: Vec<f32> = a.step(&m, 7);
        a.step(&m, 3);
        drop(a); // pages back to the free list, dirty
        let mut b = m.decode_state();
        let again = b.step(&m, 7);
        for (x, y) in fresh.iter().zip(&again) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn gqa_arena_slots_decode_and_shrink() {
        // Slots over a GQA model decode, and the per-slot KV footprint
        // shrinks by exactly n_heads/n_kv_heads.
        let mha = Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 12,
                d_model: 16,
                n_layers: 2,
                n_heads: 4,
                n_kv_heads: 4,
                d_ff: 24,
                max_seq: 16,
                kv_format: KvFormat::F32,
            },
            1,
        ));
        let gqa = Arc::new(synthetic_model(&ModelConfig { n_kv_heads: 1, ..mha.cfg }, 1));
        assert_eq!(KvGeom::of(&mha).slot_bytes(), 4 * KvGeom::of(&gqa).slot_bytes());
        let mut st = gqa.decode_state();
        let logits = st.step(&gqa, 3);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn dropping_states_returns_slots() {
        let m = model();
        {
            let _a = m.decode_state();
            let _b = m.decode_state();
            assert_eq!(m.kv_arena().stats().slots_in_use, 2);
        }
        assert_eq!(m.kv_arena().stats().slots_in_use, 0);
        assert_eq!(m.kv_arena().stats().high_water, 2);
    }

    #[test]
    #[should_panic(expected = "KV arena exhausted")]
    fn exhausted_arena_panics_like_capacity() {
        let m = model();
        m.init_kv_arena(1, 1); // one session, hard cap
        let _a = m.decode_state();
        let _b = m.decode_state(); // no session slot left → loud failure
    }

    /// One step of the page-protocol state machine, chosen by index
    /// from the ops available in the current state (see
    /// `page_protocol_exhaustive_interleavings`).
    #[derive(Clone, Copy, Debug)]
    enum ProtoOp {
        /// `acquire()` — may refuse (`None`) at the session cap.
        Acquire,
        /// `release(live[i])` — every page ref dropped; freed pages
        /// become *ghosts*: `(id, gen)` pairs that must stay dead.
        Release(usize),
        /// `fork(&mut live[i], 2)` — refcount-bump share of page 0 of
        /// each populated strip; may refuse at the session cap.
        Fork(usize),
        /// store a K row at the position — allocates the page on first
        /// touch, COWs (or reclaims in place) a shared page.
        Store(usize, usize),
        /// cache-style external refs on all of `live[i]`'s pages
        /// (`page_ref_inc`) — models a prefix-cache node taking them.
        Borrow(usize),
        /// drop every cache-held ref (`release_page_refs`) — models LRU
        /// eviction; newly freed pages become ghosts.
        Evict,
    }

    fn proto_ops(live: &[KvHandle], n_cache: usize) -> Vec<ProtoOp> {
        let mut ops = vec![ProtoOp::Acquire];
        for (i, h) in live.iter().enumerate() {
            ops.push(ProtoOp::Release(i));
            ops.push(ProtoOp::Fork(i));
            ops.push(ProtoOp::Store(i, 0));
            ops.push(ProtoOp::Store(i, 2));
            if h.page_count() > 0 {
                ops.push(ProtoOp::Borrow(i));
            }
        }
        if n_cache > 0 {
            ops.push(ProtoOp::Evict);
        }
        ops
    }

    /// Replay one choice sequence from a fresh two-session arena,
    /// checking after every op that (a) every page a live handle
    /// references is live, (b) every ghost stays dead (generation
    /// check — no freed page resurrects), (c) session accounting
    /// matches; then drain everything and check for page leaks.
    /// Returns the branching factor of the final state, or `None` if a
    /// choice index exceeded the available ops (prune that subtree).
    fn proto_replay(g: KvGeom, choices: &[usize]) -> Option<usize> {
        let arena = KvArena::with_limit(g, 1, 2);
        let mut live: Vec<KvHandle> = Vec::new();
        let mut cache: Vec<(u32, u64)> = Vec::new();
        let mut ghosts: Vec<(u32, u64)> = Vec::new();
        let row: Vec<f32> =
            (0..g.n_kv_heads * g.head_dim).map(|i| i as f32 * 0.5 - 1.0).collect();
        for &c in choices {
            let ops = proto_ops(&live, cache.len());
            let &op = ops.get(c)?;
            match op {
                ProtoOp::Acquire => {
                    if let Some(h) = arena.acquire() {
                        live.push(h);
                    }
                }
                ProtoOp::Release(i) => {
                    let h = live.remove(i);
                    let ids = h.page_ids();
                    arena.release(h);
                    ghosts.extend(ids.into_iter().filter(|&(id, gen)| !arena.page_is_live(id, gen)));
                }
                ProtoOp::Fork(i) => {
                    if let Some(h) = arena.fork(&mut live[i], 2) {
                        live.push(h);
                    }
                }
                ProtoOp::Store(i, pos) => {
                    arena.view_mut(&mut live[i]).store_k(0, pos, &row);
                }
                ProtoOp::Borrow(i) => {
                    let ids = live[i].page_ids();
                    arena.page_ref_inc(&ids);
                    cache.extend(ids);
                }
                ProtoOp::Evict => {
                    let refs = std::mem::take(&mut cache);
                    arena.release_page_refs(&refs);
                    ghosts.extend(refs.into_iter().filter(|&(id, gen)| !arena.page_is_live(id, gen)));
                }
            }
            for h in &live {
                for (id, gen) in h.page_ids() {
                    assert!(
                        arena.page_is_live(id, gen),
                        "live handle references dead page ({id}, {gen}) after {op:?}"
                    );
                }
            }
            for &(id, gen) in &ghosts {
                assert!(
                    !arena.page_is_live(id, gen),
                    "freed page ({id}, {gen}) resurrected after {op:?}"
                );
            }
            assert_eq!(arena.stats().slots_in_use, live.len(), "session drift after {op:?}");
        }
        let branches = proto_ops(&live, cache.len()).len();
        // Drain + leak check: releasing everything empties the pool.
        arena.release_page_refs(&cache);
        for h in live.drain(..) {
            arena.release(h);
        }
        assert_eq!(arena.stats().pages_in_use, 0, "page leak after drain");
        assert_eq!(arena.stats().slots_in_use, 0);
        Some(branches)
    }

    fn proto_dfs(g: KvGeom, depth_left: usize, choices: &mut Vec<usize>, n_seqs: &mut usize) {
        let Some(branches) = proto_replay(g, choices) else { return };
        *n_seqs += 1;
        if depth_left == 0 {
            return;
        }
        for c in 0..branches {
            choices.push(c);
            proto_dfs(g, depth_left - 1, choices, n_seqs);
            choices.pop();
        }
    }

    /// Tiny proto geometry: 2 strips × 2 pages (pp = 2, cap = 4), so a
    /// depth-5 sequence can allocate at most 5 pages against a pool cap
    /// of 8 — exhaustion can't fire spuriously mid-protocol.
    fn proto_geom(format: KvFormat) -> KvGeom {
        KvGeom {
            n_layers: 1,
            n_kv_heads: 1,
            head_dim: 4,
            cap: 4,
            page_positions: 2,
            format,
        }
    }

    #[test]
    fn page_protocol_exhaustive_interleavings() {
        // Every acquire/release/fork/store/borrow/evict interleaving up
        // to 5 ops over a two-session paged arena, each replayed from
        // scratch. The page protocol (refcount-held liveness, COW on
        // shared stores, generation-killed ghosts, no leaks at drain)
        // must hold at every intermediate state.
        let mut n = 0;
        proto_dfs(proto_geom(KvFormat::F32), 5, &mut Vec::new(), &mut n);
        assert!(n > 1000, "interleaving space unexpectedly small: {n} sequences");
    }

    #[test]
    fn page_protocol_exhaustive_interleavings_packed() {
        // Same state machine over a packed arena: bytewise page COW of
        // mid-word prefixes and masked packed stores must uphold the
        // identical protocol.
        let mut n = 0;
        proto_dfs(proto_geom(KvFormat::BitPlane { bits: 2, group: 4 }), 4, &mut Vec::new(), &mut n);
        assert!(n > 300, "interleaving space unexpectedly small: {n} sequences");
    }
}
