//! Serving metrics: queueing delay, time-to-first-token, per-token
//! decode latency, throughput, decode-sweep batch occupancy, and KV
//! arena occupancy — the quantities behind Table 3's latency column and
//! the serving example's report.

use crate::io::json::JsonWriter;

use super::kv::ArenaStats;
use super::Response;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

#[derive(Default)]
struct Inner {
    queue_us: Vec<u64>,
    first_token_us: Vec<u64>,
    total_us: Vec<u64>,
    tokens: usize,
    batch_sizes: Vec<usize>,
    // Fused-sweep occupancy (recorded by the engines): one entry of work
    // per sweep, `batch` tokens advanced per sweep.
    decode_sweeps: u64,
    decode_sweep_tokens: u64,
    max_decode_batch: usize,
    // Latest KV-arena snapshot **per arena** (keyed by `KvArena::id`).
    // Workers may serve distinct models (distinct arenas); the summary
    // sums across arenas so fleet KV memory is reported, not one
    // arena's share. Each snapshot is internally monotone (the arena
    // itself owns the counters), so latest-wins per key is exact.
    arenas: HashMap<u64, ArenaStats>,
    started: Option<Instant>,
    finished: Option<Instant>,
}

#[derive(Clone)]
pub struct Metrics {
    inner: Arc<Mutex<Inner>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Clone, Debug)]
pub struct LatencySummary {
    pub completed: usize,
    pub tokens: usize,
    pub p50_first_us: u64,
    pub p95_first_us: u64,
    pub p50_queue_us: u64,
    /// mean number of requests per engine batch (router-level batching)
    pub mean_batch: f64,
    /// number of fused decode sweeps executed by the engines
    pub decode_sweeps: u64,
    /// mean sessions advanced per sweep (engine-level batching — the
    /// lever the batched LUT-GEMM amortizes the weight fetch over)
    pub mean_decode_batch: f64,
    /// largest single fused sweep observed
    pub max_decode_batch: usize,
    pub us_per_token: f64,
    pub tokens_per_sec: f64,
    /// KV arena slots live at the last engine observation
    pub arena_slots_in_use: usize,
    /// most KV arena slots ever live at once
    pub arena_high_water: usize,
    /// bytes of pooled KV slab currently allocated
    pub arena_bytes_resident: usize,
    /// slot-to-slot prefix copies performed by `fork`
    pub arena_fork_copies: u64,
}

impl LatencySummary {
    /// Compact JSON object. Every field is a plain JSON number — the
    /// summary is constructed so non-finite values cannot appear (see
    /// `tokens_per_sec` handling in [`Metrics::summary`]).
    pub fn to_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_object()
            .key("completed")
            .int(self.completed as i64)
            .key("tokens")
            .int(self.tokens as i64)
            .key("p50_first_us")
            .int(self.p50_first_us as i64)
            .key("p95_first_us")
            .int(self.p95_first_us as i64)
            .key("p50_queue_us")
            .int(self.p50_queue_us as i64)
            .key("mean_batch")
            .number(self.mean_batch)
            .key("decode_sweeps")
            .int(self.decode_sweeps as i64)
            .key("mean_decode_batch")
            .number(self.mean_decode_batch)
            .key("max_decode_batch")
            .int(self.max_decode_batch as i64)
            .key("us_per_token")
            .number(self.us_per_token)
            .key("tokens_per_sec")
            .number(self.tokens_per_sec)
            .key("arena_slots_in_use")
            .int(self.arena_slots_in_use as i64)
            .key("arena_high_water")
            .int(self.arena_high_water as i64)
            .key("arena_bytes_resident")
            .int(self.arena_bytes_resident as i64)
            .key("arena_fork_copies")
            .int(self.arena_fork_copies as i64)
            .end_object();
        w.finish()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self { inner: Arc::new(Mutex::new(Inner::default())) }
    }

    pub fn record(&self, r: &Response, queue_us: u64, batch_size: usize) {
        let mut m = self.inner.lock().unwrap();
        let now = Instant::now();
        m.started.get_or_insert(now);
        m.finished = Some(now);
        m.queue_us.push(queue_us);
        m.first_token_us.push(r.first_token_us);
        m.total_us.push(r.total_us);
        m.tokens += r.tokens.len();
        m.batch_sizes.push(batch_size);
    }

    /// Record one fused decode sweep advancing `batch` sessions by one
    /// token each (called by the engines when a metrics handle is
    /// attached).
    pub fn record_decode_sweep(&self, batch: usize) {
        let mut m = self.inner.lock().unwrap();
        m.decode_sweeps += 1;
        m.decode_sweep_tokens += batch as u64;
        m.max_decode_batch = m.max_decode_batch.max(batch);
    }

    /// Record a KV-arena snapshot (called by the engines after each
    /// batch), keyed by the arena's id. Snapshots from one arena are
    /// internally monotone, so the latest one replaces the previous;
    /// distinct arenas (workers over distinct models) are kept apart
    /// and summed at summary time.
    pub fn observe_arena(&self, arena_id: u64, s: ArenaStats) {
        let mut m = self.inner.lock().unwrap();
        m.arenas.insert(arena_id, s);
    }

    pub fn summary(&self) -> LatencySummary {
        let m = self.inner.lock().unwrap();
        let pct = |xs: &[u64], p: f64| -> u64 {
            if xs.is_empty() {
                return 0;
            }
            let mut s = xs.to_vec();
            s.sort_unstable();
            s[((s.len() as f64 * p) as usize).min(s.len() - 1)]
        };
        let total_decode_us: u64 = m.total_us.iter().sum();
        let wall = match (m.started, m.finished) {
            (Some(a), Some(b)) if b > a => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        LatencySummary {
            completed: m.total_us.len(),
            tokens: m.tokens,
            p50_first_us: pct(&m.first_token_us, 0.5),
            p95_first_us: pct(&m.first_token_us, 0.95),
            p50_queue_us: pct(&m.queue_us, 0.5),
            mean_batch: if m.batch_sizes.is_empty() {
                0.0
            } else {
                m.batch_sizes.iter().sum::<usize>() as f64 / m.batch_sizes.len() as f64
            },
            decode_sweeps: m.decode_sweeps,
            mean_decode_batch: if m.decode_sweeps == 0 {
                0.0
            } else {
                m.decode_sweep_tokens as f64 / m.decode_sweeps as f64
            },
            max_decode_batch: m.max_decode_batch,
            us_per_token: if m.tokens == 0 {
                0.0
            } else {
                total_decode_us as f64 / m.tokens as f64
            },
            // A zero wall clock (all completions in one Instant tick, or
            // a single completion) must NOT produce f64::INFINITY: inf is
            // unrepresentable in JSON and corrupted the bench reports.
            tokens_per_sec: if wall > 0.0 { m.tokens as f64 / wall } else { 0.0 },
            // Fleet totals: summed over every observed arena (distinct
            // models on distinct workers each have their own slab).
            arena_slots_in_use: m.arenas.values().map(|a| a.slots_in_use).sum(),
            arena_high_water: m.arenas.values().map(|a| a.high_water).sum(),
            arena_bytes_resident: m.arenas.values().map(|a| a.bytes_resident).sum(),
            arena_fork_copies: m.arenas.values().map(|a| a.fork_copies).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(tokens: usize, first: u64, total: u64) -> Response {
        Response { id: 0, tokens: vec![1; tokens], first_token_us: first, total_us: total }
    }

    #[test]
    fn summary_percentiles() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.record(&resp(2, i * 10, i * 20), i, 4);
        }
        let s = m.summary();
        assert_eq!(s.completed, 100);
        assert_eq!(s.tokens, 200);
        assert!(s.p50_first_us >= 490 && s.p50_first_us <= 520, "{}", s.p50_first_us);
        assert!(s.p95_first_us >= 940, "{}", s.p95_first_us);
        assert!((s.mean_batch - 4.0).abs() < 1e-9);
        assert!(s.us_per_token > 0.0);
    }

    #[test]
    fn empty_summary_is_zeroes() {
        let s = Metrics::new().summary();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p50_first_us, 0);
        assert_eq!(s.decode_sweeps, 0);
        assert_eq!(s.mean_decode_batch, 0.0);
    }

    #[test]
    fn zero_wall_time_is_finite() {
        // A single recorded response gives started == finished, i.e. a
        // zero wall clock. Regression: this used to report
        // tokens_per_sec = f64::INFINITY, which is unrepresentable in
        // JSON and corrupted bench reports.
        let m = Metrics::new();
        m.record(&resp(5, 10, 50), 1, 1);
        let s = m.summary();
        assert!(s.tokens_per_sec.is_finite(), "tokens_per_sec must be finite");
        assert_eq!(s.tokens_per_sec, 0.0);
    }

    #[test]
    fn summary_is_json_serializable() {
        let m = Metrics::new();
        m.record(&resp(3, 10, 30), 1, 2);
        m.record_decode_sweep(2);
        let s = m.summary();
        let json = s.to_json();
        // All values must be bare JSON numbers: no inf/nan (the JSON
        // writer stringifies non-finite values, which downstream report
        // tooling rejects).
        assert!(!json.contains("inf"), "{json}");
        assert!(!json.contains("NaN"), "{json}");
        assert!(json.starts_with('{') && json.ends_with('}'), "{json}");
        for key in [
            "tokens_per_sec",
            "mean_decode_batch",
            "decode_sweeps",
            "us_per_token",
            "arena_high_water",
            "arena_bytes_resident",
            "arena_fork_copies",
        ] {
            assert!(json.contains(&format!("\"{key}\":")), "missing {key} in {json}");
        }
        // No quoted values: every field in LatencySummary is numeric.
        assert_eq!(json.matches('"').count(), 2 * 15, "non-numeric value leaked into {json}");
    }

    #[test]
    fn arena_observations_latest_per_arena_summed_across() {
        let m = Metrics::new();
        let snap = |in_use, hw, bytes, forks| ArenaStats {
            slots_in_use: in_use,
            high_water: hw,
            slots_created: hw,
            reused: 0,
            bytes_resident: bytes,
            fork_copies: forks,
        };
        // Two snapshots of the same arena: the later (monotone) one
        // replaces the earlier.
        m.observe_arena(1, snap(3, 3, 4096, 1));
        m.observe_arena(1, snap(0, 3, 4096, 2));
        // A second arena (another worker's model): summed, not maxed —
        // fleet KV memory is the total across slabs.
        m.observe_arena(2, snap(1, 2, 1024, 0));
        let s = m.summary();
        assert_eq!(s.arena_slots_in_use, 1);
        assert_eq!(s.arena_high_water, 5);
        assert_eq!(s.arena_bytes_resident, 5120);
        assert_eq!(s.arena_fork_copies, 2);
    }

    #[test]
    fn decode_sweep_occupancy() {
        let m = Metrics::new();
        m.record_decode_sweep(4);
        m.record_decode_sweep(4);
        m.record_decode_sweep(1);
        let s = m.summary();
        assert_eq!(s.decode_sweeps, 3);
        assert!((s.mean_decode_batch - 3.0).abs() < 1e-9);
        assert_eq!(s.max_decode_batch, 4);
    }
}
