//! Serving metrics: queueing delay, time-to-first-token, per-token
//! decode latency, throughput — the quantities behind Table 3's latency
//! column and the serving example's report.

use super::Response;
use std::sync::{Arc, Mutex};
use std::time::Instant;

#[derive(Default)]
struct Inner {
    queue_us: Vec<u64>,
    first_token_us: Vec<u64>,
    total_us: Vec<u64>,
    tokens: usize,
    batch_sizes: Vec<usize>,
    started: Option<Instant>,
    finished: Option<Instant>,
}

#[derive(Clone)]
pub struct Metrics {
    inner: Arc<Mutex<Inner>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Clone, Debug)]
pub struct LatencySummary {
    pub completed: usize,
    pub tokens: usize,
    pub p50_first_us: u64,
    pub p95_first_us: u64,
    pub p50_queue_us: u64,
    pub mean_batch: f64,
    pub us_per_token: f64,
    pub tokens_per_sec: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self { inner: Arc::new(Mutex::new(Inner::default())) }
    }

    pub fn record(&self, r: &Response, queue_us: u64, batch_size: usize) {
        let mut m = self.inner.lock().unwrap();
        let now = Instant::now();
        m.started.get_or_insert(now);
        m.finished = Some(now);
        m.queue_us.push(queue_us);
        m.first_token_us.push(r.first_token_us);
        m.total_us.push(r.total_us);
        m.tokens += r.tokens.len();
        m.batch_sizes.push(batch_size);
    }

    pub fn summary(&self) -> LatencySummary {
        let m = self.inner.lock().unwrap();
        let pct = |xs: &[u64], p: f64| -> u64 {
            if xs.is_empty() {
                return 0;
            }
            let mut s = xs.to_vec();
            s.sort_unstable();
            s[((s.len() as f64 * p) as usize).min(s.len() - 1)]
        };
        let total_decode_us: u64 = m.total_us.iter().sum();
        let wall = match (m.started, m.finished) {
            (Some(a), Some(b)) if b > a => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        LatencySummary {
            completed: m.total_us.len(),
            tokens: m.tokens,
            p50_first_us: pct(&m.first_token_us, 0.5),
            p95_first_us: pct(&m.first_token_us, 0.95),
            p50_queue_us: pct(&m.queue_us, 0.5),
            mean_batch: if m.batch_sizes.is_empty() {
                0.0
            } else {
                m.batch_sizes.iter().sum::<usize>() as f64 / m.batch_sizes.len() as f64
            },
            us_per_token: if m.tokens == 0 {
                0.0
            } else {
                total_decode_us as f64 / m.tokens as f64
            },
            tokens_per_sec: if wall > 0.0 { m.tokens as f64 / wall } else { f64::INFINITY },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(tokens: usize, first: u64, total: u64) -> Response {
        Response { id: 0, tokens: vec![1; tokens], first_token_us: first, total_us: total }
    }

    #[test]
    fn summary_percentiles() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.record(&resp(2, i * 10, i * 20), i, 4);
        }
        let s = m.summary();
        assert_eq!(s.completed, 100);
        assert_eq!(s.tokens, 200);
        assert!(s.p50_first_us >= 490 && s.p50_first_us <= 520, "{}", s.p50_first_us);
        assert!(s.p95_first_us >= 940, "{}", s.p95_first_us);
        assert!((s.mean_batch - 4.0).abs() < 1e-9);
        assert!(s.us_per_token > 0.0);
    }

    #[test]
    fn empty_summary_is_zeroes() {
        let s = Metrics::new().summary();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p50_first_us, 0);
    }
}
