//! Serving metrics: queueing delay, **real** time-to-first-token
//! (measured when the first `Token` event is emitted, not at batch
//! completion), inter-token latency, per-token decode latency,
//! throughput, decode-sweep batch occupancy, and KV arena occupancy —
//! the quantities behind Table 3's latency column and the serving
//! example's report.
//!
//! The scheduler buffers per-token samples (TTFT, inter-token gaps)
//! inside its own request state and flushes them here in **one**
//! `record_retired` call when the request retires — the decode hot
//! loop never takes this shared mutex per token, only per sweep
//! (`record_decode_sweep`) and per request. Summaries are live: they
//! can be read while a sweep is still in flight.

use crate::io::json::JsonWriter;

use super::kv::ArenaStats;
use super::prefix::PrefixStats;
use super::FinishReason;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Bounded latency-sample pool: grows to [`MAX_LATENCY_SAMPLES`], then
/// overwrites the oldest entries ring-buffer style — a long-lived
/// server keeps percentile memory (and the summary's sort cost)
/// constant while tracking recent traffic.
#[derive(Default)]
struct Samples {
    data: Vec<u64>,
    cursor: usize,
}

/// Per-metric sample cap; percentiles reflect the most recent window
/// once a server outlives it.
const MAX_LATENCY_SAMPLES: usize = 1 << 16;

impl Samples {
    fn push(&mut self, v: u64) {
        if self.data.len() < MAX_LATENCY_SAMPLES {
            self.data.push(v);
        } else {
            self.data[self.cursor] = v;
            self.cursor = (self.cursor + 1) % MAX_LATENCY_SAMPLES;
        }
    }
}

#[derive(Default)]
struct Inner {
    queue_us: Samples,
    /// Submission → first emitted token, per request (real TTFT).
    ttft_us: Samples,
    /// Admission → last prompt token processed (the prefill component
    /// of TTFT), per request that completed prefill.
    prefill_us: Samples,
    /// First-decode component of TTFT: what's left of it after the
    /// queue and prefill spans (sampling + the first emitting sweep).
    first_decode_us: Samples,
    /// Prompt tokens actually fed through prefill (running sum) and the
    /// prefill wall-µs they took — the measured `prefill_tokens_per_sec`
    /// admission control folds into its deadline estimate.
    prefill_tokens_total: u64,
    prefill_us_total: u64,
    /// Gap between consecutive token events of one request.
    itl_us: Samples,
    /// Total admission → retirement µs across all requests (running
    /// sum, not samples — feeds `us_per_token` exactly regardless of
    /// the sample window).
    decode_us_total: u64,
    /// Requests that ran to a normal finish (`Length` / `Stop`).
    completed: usize,
    /// Requests retired by cancellation.
    cancelled: usize,
    /// Requests retired by an engine error.
    errored: usize,
    tokens: usize,
    // Fused-sweep occupancy (recorded by the scheduler): one entry of
    // work per sweep, `batch` tokens advanced per sweep.
    decode_sweeps: u64,
    decode_sweep_tokens: u64,
    max_decode_batch: usize,
    // Latest KV-arena snapshot **per arena** (keyed by `KvArena::id`).
    // Workers may serve distinct models (distinct arenas); the summary
    // sums across arenas so fleet KV memory is reported, not one
    // arena's share. Each snapshot is internally monotone (the arena
    // itself owns the counters), so latest-wins per key is exact.
    arenas: HashMap<u64, ArenaStats>,
    // Latest prefix-cache snapshot per cache (keyed by `PrefixCache::id`),
    // same latest-wins-per-key / sum-across-keys convention as `arenas`.
    prefixes: HashMap<u64, PrefixStats>,
    // Front-door admission counters (`serve --listen`): what happened to
    // wire requests *before* (or instead of) reaching the scheduler.
    accepted: u64,
    rejected_429: u64,
    cancelled_by_disconnect: u64,
    drained: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

#[derive(Clone)]
pub struct Metrics {
    inner: Arc<Mutex<Inner>>,
}

/// Everything the scheduler buffered for one request, flushed in a
/// single [`Metrics::record_retired`] call (one lock per request).
pub struct RetireSample<'a> {
    pub finish: FinishReason,
    pub queue_us: u64,
    /// Submission → first `Token` event; `None` when no token was emitted.
    pub ttft_us: Option<u64>,
    /// Admission → last prompt token processed; `None` when the request
    /// retired mid-prefill.
    pub prefill_us: Option<u64>,
    /// Prompt tokens actually fed (the cache-miss suffix on a prefix hit).
    pub prefill_tokens: usize,
    /// Buffered inter-token gaps, one per token after the first.
    pub itl_us: &'a [u64],
    pub tokens: usize,
    /// Admission → retirement µs (feeds `us_per_token`).
    pub decode_us: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Clone, Debug)]
pub struct LatencySummary {
    /// Requests that finished normally (`Length` / `Stop`).
    pub completed: usize,
    /// Requests retired by cancellation (tokens they emitted still
    /// count in `tokens`).
    pub cancelled: usize,
    /// Requests retired by an engine error.
    pub errored: usize,
    pub tokens: usize,
    /// p50 time-to-first-token (submission → first `Token` event).
    pub p50_first_us: u64,
    /// p95 time-to-first-token — the streaming latency SLO.
    pub p95_first_us: u64,
    /// p50 inter-token latency (gap between consecutive token events).
    pub p50_itl_us: u64,
    /// p95 inter-token latency.
    pub p95_itl_us: u64,
    pub p50_queue_us: u64,
    /// p50 prefill span (admission → last prompt token processed) — the
    /// middle component of the queued / prefill / first-decode TTFT split.
    pub p50_prefill_us: u64,
    /// p95 prefill span.
    pub p95_prefill_us: u64,
    /// p50 first-decode span: TTFT minus its queue and prefill
    /// components (sampling + the sweep that emitted the first token).
    pub p50_first_decode_us: u64,
    /// p95 first-decode span.
    pub p95_first_decode_us: u64,
    /// Measured prefill throughput: prompt tokens fed per second of
    /// prefill wall time, across all retired requests (0 until a
    /// prefill completes). Admission control's deadline estimate uses
    /// this to price queued prompt tokens.
    pub prefill_tokens_per_sec: f64,
    /// number of fused decode sweeps executed by the schedulers
    pub decode_sweeps: u64,
    /// mean sessions advanced per sweep (engine-level batching — the
    /// lever the batched LUT-GEMM amortizes the weight fetch over)
    pub mean_decode_batch: f64,
    /// largest single fused sweep observed
    pub max_decode_batch: usize,
    pub us_per_token: f64,
    pub tokens_per_sec: f64,
    /// KV arena slots live at the last scheduler observation
    pub arena_slots_in_use: usize,
    /// most KV arena slots ever live at once
    pub arena_high_water: usize,
    /// bytes of pooled KV slab currently allocated
    pub arena_bytes_resident: usize,
    /// **real packed** bytes one session's KV slot occupies under its
    /// arena's format (the largest across observed arenas — per-slot
    /// footprints are per-model, so summing would be meaningless)
    pub arena_slot_bytes: usize,
    /// slot-to-slot prefix copies performed by `fork`
    pub arena_fork_copies: u64,
    /// KV pages currently referenced (by sessions and/or prefix-cache
    /// nodes) at the last observation
    pub arena_pages_in_use: usize,
    /// KV pages referenced by more than one owner (prefix-cache nodes
    /// and/or borrowing sessions) at the last observation
    pub arena_pages_shared: usize,
    /// copy-on-write page copies triggered by stores into shared pages
    pub arena_cow_copies: u64,
    /// prefix-cache admission lookups
    pub prefix_lookups: u64,
    /// admissions that borrowed a non-empty cached prefix
    pub prefix_hits: u64,
    /// prompt tokens skipped at prefill thanks to borrowed prefixes
    pub prefix_hit_tokens: u64,
    /// wire requests admitted by the front door into the scheduler
    pub accepted: u64,
    /// wire requests rejected `429` by admission control (estimated
    /// queue delay over the deadline budget)
    pub rejected_429: u64,
    /// accepted streams cancelled because their client disconnected (or
    /// stalled past the write timeout) mid-stream
    pub cancelled_by_disconnect: u64,
    /// wire requests rejected because the server was draining
    pub drained: u64,
    /// active SIMD dispatch tier label (`"scalar"` / `"avx2"` / `"neon"`)
    pub simd_tier: &'static str,
}

impl LatencySummary {
    /// Compact JSON object. Every field but `simd_tier` is a plain JSON
    /// number — the summary is constructed so non-finite values cannot
    /// appear (see `tokens_per_sec` handling in [`Metrics::summary`]).
    pub fn to_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_object()
            .key("completed")
            .int(self.completed as i64)
            .key("cancelled")
            .int(self.cancelled as i64)
            .key("errored")
            .int(self.errored as i64)
            .key("tokens")
            .int(self.tokens as i64)
            .key("p50_first_us")
            .int(self.p50_first_us as i64)
            .key("p95_first_us")
            .int(self.p95_first_us as i64)
            .key("p50_itl_us")
            .int(self.p50_itl_us as i64)
            .key("p95_itl_us")
            .int(self.p95_itl_us as i64)
            .key("p50_queue_us")
            .int(self.p50_queue_us as i64)
            .key("p50_prefill_us")
            .int(self.p50_prefill_us as i64)
            .key("p95_prefill_us")
            .int(self.p95_prefill_us as i64)
            .key("p50_first_decode_us")
            .int(self.p50_first_decode_us as i64)
            .key("p95_first_decode_us")
            .int(self.p95_first_decode_us as i64)
            .key("prefill_tokens_per_sec")
            .number(self.prefill_tokens_per_sec)
            .key("decode_sweeps")
            .int(self.decode_sweeps as i64)
            .key("mean_decode_batch")
            .number(self.mean_decode_batch)
            .key("max_decode_batch")
            .int(self.max_decode_batch as i64)
            .key("us_per_token")
            .number(self.us_per_token)
            .key("tokens_per_sec")
            .number(self.tokens_per_sec)
            .key("arena_slots_in_use")
            .int(self.arena_slots_in_use as i64)
            .key("arena_high_water")
            .int(self.arena_high_water as i64)
            .key("arena_bytes_resident")
            .int(self.arena_bytes_resident as i64)
            .key("arena_slot_bytes")
            .int(self.arena_slot_bytes as i64)
            .key("arena_fork_copies")
            .int(self.arena_fork_copies as i64)
            .key("arena_pages_in_use")
            .int(self.arena_pages_in_use as i64)
            .key("arena_pages_shared")
            .int(self.arena_pages_shared as i64)
            .key("arena_cow_copies")
            .int(self.arena_cow_copies as i64)
            .key("prefix_lookups")
            .int(self.prefix_lookups as i64)
            .key("prefix_hits")
            .int(self.prefix_hits as i64)
            .key("prefix_hit_tokens")
            .int(self.prefix_hit_tokens as i64)
            .key("accepted")
            .int(self.accepted as i64)
            .key("rejected_429")
            .int(self.rejected_429 as i64)
            .key("cancelled_by_disconnect")
            .int(self.cancelled_by_disconnect as i64)
            .key("drained")
            .int(self.drained as i64)
            .key("simd_tier")
            .string(self.simd_tier)
            .end_object();
        w.finish()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self { inner: Arc::new(Mutex::new(Inner::default())) }
    }

    /// A request retired. One call (and one lock) per request: the
    /// scheduler measured TTFT at the first token *event* and buffered
    /// the inter-token gaps as they happened, and flushes them all
    /// here. The TTFT split is derived at flush time: first-decode =
    /// TTFT − queue − prefill (saturating — the three spans are
    /// measured at slightly different instants).
    pub fn record_retired(&self, s: RetireSample<'_>) {
        let mut m = self.inner.lock().unwrap();
        let now = Instant::now();
        m.started.get_or_insert(now);
        m.finished = Some(now);
        match s.finish {
            FinishReason::Length | FinishReason::Stop => m.completed += 1,
            FinishReason::Cancelled => m.cancelled += 1,
            FinishReason::Error => m.errored += 1,
        }
        m.tokens += s.tokens;
        m.decode_us_total += s.decode_us;
        m.queue_us.push(s.queue_us);
        if let Some(t) = s.ttft_us {
            m.ttft_us.push(t);
        }
        if let Some(p) = s.prefill_us {
            m.prefill_us.push(p);
            m.prefill_tokens_total += s.prefill_tokens as u64;
            m.prefill_us_total += p;
            if let Some(t) = s.ttft_us {
                m.first_decode_us.push(t.saturating_sub(s.queue_us).saturating_sub(p));
            }
        }
        for &v in s.itl_us {
            m.itl_us.push(v);
        }
    }

    /// Record one fused decode sweep advancing `batch` sessions by one
    /// token each (called by the scheduler every iteration).
    pub fn record_decode_sweep(&self, batch: usize) {
        let mut m = self.inner.lock().unwrap();
        m.decode_sweeps += 1;
        m.decode_sweep_tokens += batch as u64;
        m.max_decode_batch = m.max_decode_batch.max(batch);
    }

    /// Record a KV-arena snapshot (called by the scheduler after each
    /// sweep), keyed by the arena's id. Snapshots from one arena are
    /// internally monotone, so the latest one replaces the previous;
    /// distinct arenas (workers over distinct models) are kept apart
    /// and summed at summary time.
    pub fn observe_arena(&self, arena_id: u64, s: ArenaStats) {
        let mut m = self.inner.lock().unwrap();
        m.arenas.insert(arena_id, s);
    }

    /// Record a prefix-cache snapshot, keyed by the cache's id — same
    /// latest-wins / sum-across-keys convention as [`Metrics::observe_arena`].
    pub fn observe_prefix(&self, cache_id: u64, s: PrefixStats) {
        let mut m = self.inner.lock().unwrap();
        m.prefixes.insert(cache_id, s);
    }

    /// Front door: a wire request passed admission and was submitted.
    pub fn record_accepted(&self) {
        self.inner.lock().unwrap().accepted += 1;
    }

    /// Front door: a wire request was rejected `429` by admission
    /// control.
    pub fn record_rejected_429(&self) {
        self.inner.lock().unwrap().rejected_429 += 1;
    }

    /// Front door: an accepted stream was cancelled because its client
    /// disconnected (write failure / stalled socket).
    pub fn record_disconnect(&self) {
        self.inner.lock().unwrap().cancelled_by_disconnect += 1;
    }

    /// Front door: a wire request was turned away because the server is
    /// draining.
    pub fn record_drained(&self) {
        self.inner.lock().unwrap().drained += 1;
    }

    /// Current p50 inter-token latency in µs over the sample window
    /// (0 with no samples yet). Cheaper than a full [`Metrics::summary`]
    /// — admission control reads this on the request path.
    pub fn itl_p50_us(&self) -> u64 {
        let m = self.inner.lock().unwrap();
        let xs = &m.itl_us.data;
        if xs.is_empty() {
            return 0;
        }
        let mut s = xs.clone();
        s.sort_unstable();
        s[(s.len() / 2).min(s.len() - 1)]
    }

    /// Measured prefill throughput (prompt tokens per second of prefill
    /// wall time), 0.0 until the first prefill completes. Like
    /// [`Metrics::itl_p50_us`] this is read on the admission path, so
    /// it stays a running-sum ratio rather than a full summary.
    pub fn prefill_tokens_per_sec(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        if m.prefill_us_total == 0 {
            return 0.0;
        }
        m.prefill_tokens_total as f64 * 1e6 / m.prefill_us_total as f64
    }

    pub fn summary(&self) -> LatencySummary {
        let m = self.inner.lock().unwrap();
        let pct = |xs: &[u64], p: f64| -> u64 {
            if xs.is_empty() {
                return 0;
            }
            let mut s = xs.to_vec();
            s.sort_unstable();
            s[((s.len() as f64 * p) as usize).min(s.len() - 1)]
        };
        let total_decode_us: u64 = m.decode_us_total;
        let wall = match (m.started, m.finished) {
            (Some(a), Some(b)) if b > a => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        LatencySummary {
            completed: m.completed,
            cancelled: m.cancelled,
            errored: m.errored,
            tokens: m.tokens,
            p50_first_us: pct(&m.ttft_us.data, 0.5),
            p95_first_us: pct(&m.ttft_us.data, 0.95),
            p50_itl_us: pct(&m.itl_us.data, 0.5),
            p95_itl_us: pct(&m.itl_us.data, 0.95),
            p50_queue_us: pct(&m.queue_us.data, 0.5),
            p50_prefill_us: pct(&m.prefill_us.data, 0.5),
            p95_prefill_us: pct(&m.prefill_us.data, 0.95),
            p50_first_decode_us: pct(&m.first_decode_us.data, 0.5),
            p95_first_decode_us: pct(&m.first_decode_us.data, 0.95),
            prefill_tokens_per_sec: if m.prefill_us_total == 0 {
                0.0
            } else {
                m.prefill_tokens_total as f64 * 1e6 / m.prefill_us_total as f64
            },
            decode_sweeps: m.decode_sweeps,
            mean_decode_batch: if m.decode_sweeps == 0 {
                0.0
            } else {
                m.decode_sweep_tokens as f64 / m.decode_sweeps as f64
            },
            max_decode_batch: m.max_decode_batch,
            us_per_token: if m.tokens == 0 {
                0.0
            } else {
                total_decode_us as f64 / m.tokens as f64
            },
            // A zero wall clock (all completions in one Instant tick, or
            // a single completion) must NOT produce f64::INFINITY: inf is
            // unrepresentable in JSON and corrupted the bench reports.
            tokens_per_sec: if wall > 0.0 { m.tokens as f64 / wall } else { 0.0 },
            // Fleet totals: summed over every observed arena (distinct
            // models on distinct workers each have their own slab).
            arena_slots_in_use: m.arenas.values().map(|a| a.slots_in_use).sum(),
            arena_high_water: m.arenas.values().map(|a| a.high_water).sum(),
            arena_bytes_resident: m.arenas.values().map(|a| a.bytes_resident).sum(),
            arena_slot_bytes: m.arenas.values().map(|a| a.slot_bytes).max().unwrap_or(0),
            arena_fork_copies: m.arenas.values().map(|a| a.fork_copies).sum(),
            arena_pages_in_use: m.arenas.values().map(|a| a.pages_in_use).sum(),
            arena_pages_shared: m.arenas.values().map(|a| a.pages_shared).sum(),
            arena_cow_copies: m.arenas.values().map(|a| a.cow_copies).sum(),
            prefix_lookups: m.prefixes.values().map(|p| p.lookups).sum(),
            prefix_hits: m.prefixes.values().map(|p| p.hits).sum(),
            prefix_hit_tokens: m.prefixes.values().map(|p| p.hit_tokens).sum(),
            accepted: m.accepted,
            rejected_429: m.rejected_429,
            cancelled_by_disconnect: m.cancelled_by_disconnect,
            drained: m.drained,
            simd_tier: crate::tensor::simd::active().label(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Positional shorthand for the common test shape; prefill is half
    /// of TTFT so the split samples populate without every test
    /// spelling out the full struct.
    fn tsample(
        finish: FinishReason,
        queue_us: u64,
        ttft_us: Option<u64>,
        itl_us: &[u64],
        tokens: usize,
        decode_us: u64,
    ) -> RetireSample<'_> {
        RetireSample {
            finish,
            queue_us,
            ttft_us,
            prefill_us: ttft_us.map(|t| t / 2),
            prefill_tokens: tokens,
            itl_us,
            tokens,
            decode_us,
        }
    }

    #[test]
    fn summary_percentiles() {
        let m = Metrics::new();
        for i in 1..=100u64 {
            m.record_retired(tsample(FinishReason::Length, i, Some(i * 10), &[i * 2], 2, i * 20));
        }
        let s = m.summary();
        assert_eq!(s.completed, 100);
        assert_eq!(s.tokens, 200);
        assert!(s.p50_first_us >= 490 && s.p50_first_us <= 520, "{}", s.p50_first_us);
        assert!(s.p95_first_us >= 940, "{}", s.p95_first_us);
        assert!(s.p50_itl_us >= 98 && s.p50_itl_us <= 104, "{}", s.p50_itl_us);
        assert!(s.p95_itl_us >= 188, "{}", s.p95_itl_us);
        assert!(s.us_per_token > 0.0);
    }

    #[test]
    fn empty_summary_is_zeroes() {
        let s = Metrics::new().summary();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p50_first_us, 0);
        assert_eq!(s.p50_itl_us, 0);
        assert_eq!(s.decode_sweeps, 0);
        assert_eq!(s.mean_decode_batch, 0.0);
    }

    #[test]
    fn zero_wall_time_is_finite() {
        // A single recorded completion gives started == finished, i.e. a
        // zero wall clock. Regression: this used to report
        // tokens_per_sec = f64::INFINITY, which is unrepresentable in
        // JSON and corrupted bench reports.
        let m = Metrics::new();
        m.record_retired(tsample(FinishReason::Length, 1, Some(10), &[], 5, 50));
        let s = m.summary();
        assert!(s.tokens_per_sec.is_finite(), "tokens_per_sec must be finite");
        assert_eq!(s.tokens_per_sec, 0.0);
    }

    #[test]
    fn summary_is_json_serializable() {
        let m = Metrics::new();
        m.record_retired(tsample(FinishReason::Length, 1, Some(10), &[5, 5], 3, 30));
        m.record_decode_sweep(2);
        let s = m.summary();
        let json = s.to_json();
        // All values must be bare JSON numbers: no inf/nan (the JSON
        // writer stringifies non-finite values, which downstream report
        // tooling rejects).
        assert!(!json.contains("inf"), "{json}");
        assert!(!json.contains("NaN"), "{json}");
        assert!(json.starts_with('{') && json.ends_with('}'), "{json}");
        for key in [
            "tokens_per_sec",
            "mean_decode_batch",
            "decode_sweeps",
            "us_per_token",
            "p95_first_us",
            "p50_itl_us",
            "p95_itl_us",
            "p50_prefill_us",
            "p95_prefill_us",
            "p50_first_decode_us",
            "p95_first_decode_us",
            "prefill_tokens_per_sec",
            "arena_high_water",
            "arena_bytes_resident",
            "arena_slot_bytes",
            "arena_fork_copies",
            "arena_pages_in_use",
            "arena_pages_shared",
            "arena_cow_copies",
            "prefix_lookups",
            "prefix_hits",
            "prefix_hit_tokens",
            "accepted",
            "rejected_429",
            "cancelled_by_disconnect",
            "drained",
            "simd_tier",
        ] {
            assert!(json.contains(&format!("\"{key}\":")), "missing {key} in {json}");
        }
        // 35 quoted keys plus the one quoted value (`simd_tier` — every
        // other field is numeric and must serialize unquoted).
        assert_eq!(json.matches('"').count(), 2 * 35 + 2, "non-numeric value leaked into {json}");
    }

    #[test]
    fn ttft_split_components_and_prefill_rate() {
        // queue 100 + prefill 300 + first-decode 100 = TTFT 500; 60
        // prompt tokens over 300µs of prefill = 200k tok/s.
        let m = Metrics::new();
        m.record_retired(RetireSample {
            finish: FinishReason::Length,
            queue_us: 100,
            ttft_us: Some(500),
            prefill_us: Some(300),
            prefill_tokens: 60,
            itl_us: &[],
            tokens: 1,
            decode_us: 400,
        });
        let s = m.summary();
        assert_eq!(s.p50_prefill_us, 300);
        assert_eq!(s.p95_prefill_us, 300);
        assert_eq!(s.p50_first_decode_us, 100);
        assert!((s.prefill_tokens_per_sec - 200_000.0).abs() < 1e-6);
        assert!((m.prefill_tokens_per_sec() - s.prefill_tokens_per_sec).abs() < 1e-9);
        // A mid-prefill retirement contributes no split samples and no
        // prefill throughput.
        m.record_retired(RetireSample {
            finish: FinishReason::Cancelled,
            queue_us: 1,
            ttft_us: None,
            prefill_us: None,
            prefill_tokens: 0,
            itl_us: &[],
            tokens: 0,
            decode_us: 5,
        });
        let s2 = m.summary();
        assert_eq!(s2.p95_prefill_us, 300);
        assert!((s2.prefill_tokens_per_sec - 200_000.0).abs() < 1e-6);
    }

    #[test]
    fn admission_counters_accumulate_and_serialize() {
        let m = Metrics::new();
        m.record_accepted();
        m.record_accepted();
        m.record_rejected_429();
        m.record_disconnect();
        m.record_drained();
        m.record_drained();
        let s = m.summary();
        assert_eq!(s.accepted, 2);
        assert_eq!(s.rejected_429, 1);
        assert_eq!(s.cancelled_by_disconnect, 1);
        assert_eq!(s.drained, 2);
        let json = s.to_json();
        assert!(json.contains("\"rejected_429\":1"), "{json}");
        assert!(json.contains("\"drained\":2"), "{json}");
    }

    #[test]
    fn itl_p50_accessor_matches_summary() {
        let m = Metrics::new();
        assert_eq!(m.itl_p50_us(), 0, "no samples yet");
        m.record_retired(tsample(FinishReason::Length, 1, Some(10), &[30, 10, 20], 4, 60));
        assert_eq!(m.itl_p50_us(), m.summary().p50_itl_us);
        assert_eq!(m.itl_p50_us(), 20);
    }

    #[test]
    fn ttft_and_itl_flushed_per_request() {
        // 3 tokens of one request flush one TTFT sample and two ITL
        // samples in a single record_retired call.
        let m = Metrics::new();
        m.record_retired(tsample(FinishReason::Length, 1, Some(100), &[10, 12], 3, 130));
        let s = m.summary();
        assert_eq!(s.completed, 1);
        assert_eq!(s.tokens, 3);
        assert_eq!(s.p50_first_us, 100);
        assert!(s.p50_itl_us == 10 || s.p50_itl_us == 12);
    }

    #[test]
    fn outcomes_are_split_not_lumped() {
        // Cancelled / errored retirements must not inflate `completed`;
        // their emitted tokens still count toward throughput.
        let m = Metrics::new();
        m.record_retired(tsample(FinishReason::Length, 0, Some(5), &[], 4, 40));
        m.record_retired(tsample(FinishReason::Stop, 0, Some(5), &[], 2, 20));
        m.record_retired(tsample(FinishReason::Cancelled, 0, Some(5), &[], 3, 30));
        m.record_retired(tsample(FinishReason::Error, 0, None, &[], 1, 10));
        let s = m.summary();
        assert_eq!(s.completed, 2);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.errored, 1);
        assert_eq!(s.tokens, 10);
        // No-token retirement contributes no TTFT sample.
        assert_eq!(s.p95_first_us, 5);
    }

    #[test]
    fn arena_observations_latest_per_arena_summed_across() {
        let m = Metrics::new();
        let snap = |in_use, hw, bytes, forks| ArenaStats {
            slots_in_use: in_use,
            high_water: hw,
            slots_created: hw,
            reused: 0,
            bytes_resident: bytes,
            slot_bytes: bytes / 2,
            fork_copies: forks,
            cow_copies: forks * 2,
            pages_in_use: in_use * 4,
            pages_shared: in_use,
            pages_high_water: hw * 4,
            page_bytes: bytes / 8,
        };
        // Two snapshots of the same arena: the later (monotone) one
        // replaces the earlier.
        m.observe_arena(1, snap(3, 3, 4096, 1));
        m.observe_arena(1, snap(0, 3, 4096, 2));
        // A second arena (another worker's model): summed, not maxed —
        // fleet KV memory is the total across slabs.
        m.observe_arena(2, snap(1, 2, 1024, 0));
        let s = m.summary();
        assert_eq!(s.arena_slots_in_use, 1);
        assert_eq!(s.arena_high_water, 5);
        assert_eq!(s.arena_bytes_resident, 5120);
        assert_eq!(s.arena_slot_bytes, 2048, "largest per-slot footprint across arenas");
        assert_eq!(s.arena_fork_copies, 2);
        assert_eq!(s.arena_pages_in_use, 4);
        assert_eq!(s.arena_pages_shared, 1);
        assert_eq!(s.arena_cow_copies, 4);
    }

    #[test]
    fn prefix_observations_latest_per_cache_summed_across() {
        let m = Metrics::new();
        let snap = |lookups, hits, hit_tokens| PrefixStats {
            lookups,
            hits,
            hit_tokens,
            insertions: 1,
            evictions: 0,
        };
        m.observe_prefix(1, snap(2, 1, 8));
        m.observe_prefix(1, snap(5, 3, 24)); // later snapshot replaces
        m.observe_prefix(2, snap(1, 1, 4)); // second worker's cache: summed
        let s = m.summary();
        assert_eq!(s.prefix_lookups, 6);
        assert_eq!(s.prefix_hits, 4);
        assert_eq!(s.prefix_hit_tokens, 28);
    }

    #[test]
    fn latency_samples_are_bounded() {
        // A long-lived server must not grow sample memory with total
        // tokens served: the pools cap and recycle.
        let mut pool = Samples::default();
        for i in 0..(MAX_LATENCY_SAMPLES as u64 + 10) {
            pool.push(i);
        }
        assert_eq!(pool.data.len(), MAX_LATENCY_SAMPLES);
        // Oldest entries were overwritten by the newest ten.
        assert_eq!(pool.data[0], MAX_LATENCY_SAMPLES as u64);
        assert_eq!(pool.data[9], MAX_LATENCY_SAMPLES as u64 + 9);
        assert_eq!(pool.data[10], 10);
    }

    #[test]
    fn decode_sweep_occupancy() {
        let m = Metrics::new();
        m.record_decode_sweep(4);
        m.record_decode_sweep(4);
        m.record_decode_sweep(1);
        let s = m.summary();
        assert_eq!(s.decode_sweeps, 3);
        assert!((s.mean_decode_batch - 3.0).abs() < 1e-9);
        assert_eq!(s.max_decode_batch, 4);
    }
}
