//! Minimal HTTP/1.1 request parsing and response writing for the front
//! door (`serve --listen`).
//!
//! This is deliberately not a general HTTP implementation: it parses
//! exactly the subset a generate/health/metrics endpoint needs — one
//! request per connection (`Connection: close` semantics), a
//! `Content-Length` body, no chunked transfer encoding (rejected `501`)
//! — and is **defensive by construction**. Every malformed, truncated,
//! or oversized input maps to a 4xx [`HttpError`]; no input may panic
//! (the connection threads run under the lint L3 discipline, and a
//! panic would tear down a connection slot without accounting). Hard
//! caps bound the request line, header block, and body so a hostile
//! peer cannot balloon memory.

use std::io::{BufRead, Write};

/// Cap on the request line (`METHOD SP TARGET SP VERSION`).
pub const MAX_REQUEST_LINE: usize = 8 * 1024;
/// Cap on the number of header fields.
pub const MAX_HEADER_COUNT: usize = 64;
/// Cap on the cumulative header-block bytes.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Cap on the declared `Content-Length` body size.
pub const MAX_BODY_BYTES: usize = 256 * 1024;

/// A request-level failure: the HTTP status to answer with plus a short
/// human-readable reason (returned as the JSON error body).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub msg: String,
}

impl HttpError {
    pub fn new(status: u16, msg: impl Into<String>) -> Self {
        Self { status, msg: msg.into() }
    }
}

/// A parsed request. Header names are lowercased at parse time; values
/// keep their bytes (trimmed of surrounding whitespace).
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub target: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup (first occurrence wins).
    pub fn header(&self, name: &str) -> Option<&str> {
        let lower = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == lower).map(|(_, v)| v.as_str())
    }
}

/// Read one line terminated by `\n` (a trailing `\r` is stripped),
/// bounded by `cap` bytes. EOF mid-line is a truncated request (400);
/// exceeding the cap maps to `over_status` (414 for the request line,
/// 431 for headers).
fn read_line<R: BufRead>(r: &mut R, cap: usize, over_status: u16) -> Result<String, HttpError> {
    let mut line: Vec<u8> = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        match r.read(&mut byte) {
            Ok(0) => return Err(HttpError::new(400, "truncated request")),
            Ok(_) => {
                if byte[0] == b'\n' {
                    break;
                }
                if line.len() >= cap {
                    return Err(HttpError::new(over_status, "line too long"));
                }
                line.push(byte[0]);
            }
            Err(e) => return Err(HttpError::new(400, format!("read failed: {e}"))),
        }
    }
    if line.last() == Some(&b'\r') {
        line.pop();
    }
    String::from_utf8(line).map_err(|_| HttpError::new(400, "non-utf8 bytes in header section"))
}

/// Parse one request from the stream. On `Err`, the caller answers with
/// the embedded status and closes — partial reads leave the connection
/// in an unknown state and this server is `Connection: close` anyway.
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Request, HttpError> {
    let request_line = read_line(r, MAX_REQUEST_LINE, 414)?;
    let parts: Vec<&str> = request_line.split_whitespace().collect();
    if parts.len() != 3 {
        return Err(HttpError::new(400, "malformed request line"));
    }
    let (method, target, version) = (parts[0].to_string(), parts[1].to_string(), parts[2]);
    if method.is_empty() || !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(HttpError::new(400, "malformed method"));
    }
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::new(400, "unsupported HTTP version"));
    }

    let mut headers: Vec<(String, String)> = Vec::new();
    let mut header_bytes = 0usize;
    loop {
        let line = read_line(r, MAX_HEADER_BYTES, 431)?;
        if line.is_empty() {
            break;
        }
        header_bytes += line.len();
        if headers.len() >= MAX_HEADER_COUNT || header_bytes > MAX_HEADER_BYTES {
            return Err(HttpError::new(431, "header block too large"));
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::new(400, "malformed header (no colon)"));
        };
        let name = name.trim();
        if name.is_empty() || name.contains(' ') {
            return Err(HttpError::new(400, "malformed header name"));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }

    let mut req = Request { method, target, headers, body: Vec::new() };
    if let Some(te) = req.header("transfer-encoding") {
        if !te.eq_ignore_ascii_case("identity") {
            return Err(HttpError::new(501, "transfer-encoding not supported"));
        }
    }
    if let Some(cl) = req.header("content-length") {
        let n: usize = cl.parse().map_err(|_| HttpError::new(400, "malformed content-length"))?;
        if n > MAX_BODY_BYTES {
            return Err(HttpError::new(413, "body too large"));
        }
        let mut body = vec![0u8; n];
        let mut filled = 0usize;
        while filled < n {
            match r.read(&mut body[filled..]) {
                Ok(0) => return Err(HttpError::new(400, "truncated body")),
                Ok(k) => filled += k,
                Err(e) => return Err(HttpError::new(400, format!("body read failed: {e}"))),
            }
        }
        req.body = body;
    }
    Ok(req)
}

/// Canonical reason phrase for the statuses this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        414 => "URI Too Long",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a complete JSON response (status line, headers, body). `extra`
/// headers ride along (e.g. `Retry-After`). Write failures bubble up so
/// the caller can account a client disconnect.
pub fn write_json<W: Write>(
    w: &mut W,
    status: u16,
    json: &str,
    extra: &[(&str, String)],
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\
         Connection: close\r\n",
        reason(status),
        json.len(),
    );
    for (k, v) in extra {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(json.as_bytes())?;
    w.flush()
}

/// Write a JSON error body `{"error": msg}` with the given status.
pub fn write_json_error<W: Write>(
    w: &mut W,
    status: u16,
    msg: &str,
    extra: &[(&str, String)],
) -> std::io::Result<()> {
    let mut jw = crate::io::json::JsonWriter::new();
    jw.begin_object().key("error").string(msg).end_object();
    write_json(w, status, &jw.finish(), extra)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &[u8]) -> Result<Request, HttpError> {
        read_request(&mut Cursor::new(raw.to_vec()))
    }

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nbody";
        let req = parse(raw).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.target, "/v1/generate");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body, b"body");
    }

    #[test]
    fn parses_get_without_body_and_bare_lf() {
        // Bare-LF line endings are tolerated (curl never sends them, but
        // hand-rolled clients do).
        let req = parse(b"GET /healthz HTTP/1.1\nHost: x\n\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.target, "/healthz");
        assert!(req.body.is_empty());
    }

    #[test]
    fn header_lookup_is_case_insensitive() {
        let req = parse(b"GET / HTTP/1.1\r\nX-Tenant:  alice \r\n\r\n").unwrap();
        assert_eq!(req.header("x-tenant"), Some("alice"));
        assert_eq!(req.header("X-TENANT"), Some("alice"));
    }

    #[test]
    fn malformed_inputs_map_to_4xx() {
        let cases: &[(&[u8], u16)] = &[
            (b"", 400),                                            // empty
            (b"GET\r\n\r\n", 400),                                 // no target
            (b"GET / HTTP/1.1 extra\r\n\r\n", 400),                // 4 tokens
            (b"get / HTTP/1.1\r\n\r\n", 400),                      // lowercase method
            (b"GET / SPDY/3\r\n\r\n", 400),                        // bad version
            (b"GET / HTTP/1.1\r\nno-colon\r\n\r\n", 400),          // colonless header
            (b"GET / HTTP/1.1\r\n: empty\r\n\r\n", 400),           // empty name
            (b"GET / HTTP/1.1\r\nContent-Length: x\r\n\r\n", 400), // bad length
            (b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nab", 400), // truncated body
            (b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n", 501),
            (b"GET / HTTP/1.1\r\nHost: x", 400),                   // truncated headers
        ];
        for (raw, want) in cases {
            let err = parse(raw).expect_err("must reject");
            assert_eq!(err.status, *want, "input {:?} -> {err:?}", String::from_utf8_lossy(raw));
        }
    }

    #[test]
    fn oversized_inputs_are_capped() {
        let long_line = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_REQUEST_LINE + 10));
        assert_eq!(parse(long_line.as_bytes()).unwrap_err().status, 414);

        let mut many_headers = String::from("GET / HTTP/1.1\r\n");
        for i in 0..(MAX_HEADER_COUNT + 5) {
            many_headers.push_str(&format!("h{i}: v\r\n"));
        }
        many_headers.push_str("\r\n");
        assert_eq!(parse(many_headers.as_bytes()).unwrap_err().status, 431);

        let fat_header =
            format!("GET / HTTP/1.1\r\nbig: {}\r\n\r\n", "x".repeat(MAX_HEADER_BYTES + 10));
        assert_eq!(parse(fat_header.as_bytes()).unwrap_err().status, 431);

        let big_body = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert_eq!(parse(big_body.as_bytes()).unwrap_err().status, 413);
    }

    #[test]
    fn write_json_emits_complete_response() {
        let mut out = Vec::new();
        write_json(&mut out, 429, r#"{"error":"overloaded"}"#, &[("Retry-After", "2".into())])
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Retry-After: 2\r\n"), "{text}");
        assert!(text.contains("Content-Length: 22\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{\"error\":\"overloaded\"}"), "{text}");
    }

    #[test]
    fn prop_arbitrary_bytes_never_panic() {
        // Satellite: the parser is total — random garbage at the socket
        // yields Ok or a 4xx/5xx HttpError, never a panic.
        crate::proptest_lite::check("http_parse_total", |rng| {
            let len = rng.below(512) as usize;
            let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            match read_request(&mut Cursor::new(bytes)) {
                Ok(_) => Ok(()),
                Err(e) if (400..=599).contains(&e.status) => Ok(()),
                Err(e) => Err(format!("non-4xx/5xx error status {}", e.status)),
            }
        });
    }

    #[test]
    fn prop_mutated_valid_requests_never_panic() {
        // Mutate/truncate a well-formed request: deeper parser states
        // than pure garbage reaches, same totality requirement.
        let base: &[u8] =
            b"POST /v1/generate HTTP/1.1\r\nHost: bpdq\r\nContent-Type: application/json\r\n\
              Content-Length: 17\r\n\r\n{\"prompt\":\"2+2=\"}";
        crate::proptest_lite::check("http_parse_mutated", |rng| {
            let mut doc = base.to_vec();
            for _ in 0..(1 + rng.below(4)) {
                let i = rng.below(doc.len() as u64) as usize;
                doc[i] = rng.below(256) as u8;
            }
            let cut = rng.below(doc.len() as u64 + 1) as usize;
            doc.truncate(cut);
            match read_request(&mut Cursor::new(doc)) {
                Ok(_) => Ok(()),
                Err(e) if (400..=599).contains(&e.status) => Ok(()),
                Err(e) => Err(format!("non-4xx/5xx error status {}", e.status)),
            }
        });
    }
}
