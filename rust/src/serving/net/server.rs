//! The HTTP/1.1 + SSE front door over [`Router::submit_with`].
//!
//! One accept loop (bounded thread-per-connection pool) serves four
//! routes — `POST /v1/generate` (SSE token stream), `GET /healthz`,
//! `GET /metrics`, `POST /admin/drain` — plus a length-prefixed
//! raw-socket fallback for dependency-free clients (first four bytes
//! `BPQ1`). See the `## Front door` section of [`crate::serving`] for
//! the wire format and drain semantics.
//!
//! Design rules:
//!
//! * **Backpressure is cancellation.** A client that disconnects or
//!   stalls past the socket write timeout fails the next frame write;
//!   the pump cancels the stream, the scheduler retires the session at
//!   the next sweep boundary, and its arena slot is released. The
//!   counter is `cancelled_by_disconnect`.
//! * **Admission control is early rejection.** With a deadline budget
//!   configured, a request whose estimated delay — queueing
//!   (`Router::queue_depth` × observed ITL p50, floored at
//!   [`ITL_FLOOR_US`]) plus its own prefill cost (`prompt_tokens` ÷
//!   the measured prefill rate, zero until traffic has measured one) —
//!   exceeds the budget is answered `429` + `Retry-After` before it
//!   ever touches a queue. Long prompts thus admit against the work
//!   they bring, not just the work already queued.
//! * **Drain is reject-new, finish-in-flight.** `POST /admin/drain`
//!   (or [`Server::drain`]) flips one flag: new generate requests get
//!   `503`, live streams run to completion, then the accept loop joins
//!   its connection threads and [`Server::join`] returns.

use super::http::{self, HttpError, Request};
use crate::data::Tokenizer;
use crate::io::json::{JsonValue, JsonWriter};
use crate::serving::{FinishReason, GenEvent, GenStream, Router, SamplingParams, Usage};
use anyhow::Result;
use std::io::{BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Admission control's lower bound on the per-token latency estimate,
/// in µs. Before any traffic has retired there are no ITL samples; a
/// floor keeps `queue depth × ITL` meaningful on a cold server instead
/// of estimating zero delay for an arbitrarily deep queue.
pub const ITL_FLOOR_US: u64 = 50;

/// Magic prefix selecting the length-prefixed raw protocol. Chosen to
/// collide with no HTTP method.
pub const RAW_MAGIC: &[u8; 4] = b"BPQ1";

#[derive(Clone)]
pub struct ServerConfig {
    /// Maximum concurrent connections; excess connects are answered
    /// `503` immediately (never queued — queueing belongs to the
    /// scheduler, where it is measurable).
    pub max_conns: usize,
    /// Admission deadline budget in µs: reject `429` when the estimated
    /// queue delay exceeds this. `None` disables admission control.
    pub deadline_budget_us: Option<u64>,
    /// SSE keep-alive interval: a comment frame is written whenever no
    /// event arrives for this long, bounding how stale a silent
    /// connection can get (and detecting dead clients).
    pub keepalive_ms: u64,
    /// Socket read/write timeout — a stalled client fails its next
    /// frame write instead of pinning a connection slot forever.
    pub io_timeout_ms: u64,
    /// `tenant → priority` map for requests that carry a `tenant` field
    /// (an explicit `priority` field wins). Unknown tenants get 0.
    pub tenant_priority: Vec<(String, u8)>,
    /// Server-side sampling defaults; request bodies override per field.
    pub default_params: SamplingParams,
    /// Model decode capacity: `len(tokens) + max_new` above this is a
    /// `400` (the scheduler would truncate at capacity otherwise).
    pub capacity: usize,
    /// Vocabulary bound for raw `tokens` bodies — out-of-range ids are
    /// a `400`, never an engine panic.
    pub vocab_size: u32,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_conns: 64,
            deadline_budget_us: None,
            keepalive_ms: 5_000,
            io_timeout_ms: 30_000,
            tenant_priority: Vec::new(),
            default_params: SamplingParams::default(),
            capacity: 256,
            vocab_size: u32::MAX,
        }
    }
}

/// Shared connection-thread context.
struct Ctx {
    router: Arc<Router>,
    tok: Arc<Tokenizer>,
    cfg: ServerConfig,
    draining: AtomicBool,
    /// Cached ITL p50 for admission (µs), refreshed every few
    /// admissions so the estimate tracks live traffic without sorting
    /// the sample window on every request.
    itl_cache_us: AtomicU64,
    /// Cached measured prefill rate (whole tokens/sec) for admission,
    /// refreshed on the same cadence as `itl_cache_us`. 0 until any
    /// request has retired with prefill timing, which zeroes the
    /// prefill term instead of guessing.
    prefill_rate_cache: AtomicU64,
    admissions: AtomicU64,
}

/// A live front door. Bind with [`Server::start`]; [`Server::join`]
/// blocks until a drain completes (there is no other clean exit — kill
/// the process for an unclean one).
pub struct Server {
    local_addr: SocketAddr,
    ctx: Arc<Ctx>,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an OS-assigned port) and
    /// start the accept loop.
    pub fn start(
        addr: &str,
        router: Arc<Router>,
        tok: Arc<Tokenizer>,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot listen on {addr}: {e}"))?;
        let local_addr = listener.local_addr()?;
        // Non-blocking accept so the loop can poll the drain flag.
        listener.set_nonblocking(true)?;
        let ctx = Arc::new(Ctx {
            router,
            tok,
            cfg,
            draining: AtomicBool::new(false),
            itl_cache_us: AtomicU64::new(0),
            prefill_rate_cache: AtomicU64::new(0),
            admissions: AtomicU64::new(0),
        });
        let ctx2 = ctx.clone();
        let accept = std::thread::spawn(move || accept_sweep(listener, ctx2));
        Ok(Server { local_addr, ctx, accept: Some(accept) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Flip to reject-new (idempotent; also reachable over the wire via
    /// `POST /admin/drain`). In-flight streams finish.
    pub fn drain(&self) {
        self.ctx.draining.store(true, Ordering::Release);
    }

    pub fn is_draining(&self) -> bool {
        self.ctx.draining.load(Ordering::Acquire)
    }

    /// Block until the drain completes: every in-flight connection has
    /// finished and the accept loop has exited.
    pub fn join(mut self) -> Result<()> {
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| anyhow::anyhow!("accept loop panicked"))?;
        }
        Ok(())
    }
}

/// The connection sweep: accept, bound the pool, dispatch connection
/// threads, and — once draining — wait for them and exit. Like the
/// scheduler sweep, a panic here would strand every client, so the
/// lint gate holds it to the no-panic/no-lock discipline.
// lint: sweep
fn accept_sweep(listener: TcpListener, ctx: Arc<Ctx>) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    loop {
        conns.retain(|h| !h.is_finished());
        if ctx.draining.load(Ordering::Acquire) && conns.is_empty() {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                if conns.len() >= ctx.cfg.max_conns {
                    reject_conn(stream);
                    continue;
                }
                let c = ctx.clone();
                conns.push(std::thread::spawn(move || handle_conn(stream, &c)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
    for h in conns {
        let _ = h.join();
    }
}

/// Answer a pool-full connect with an immediate `503` and close.
fn reject_conn(mut stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
    let _ = http::write_json_error(&mut stream, 503, "connection pool full", &[]);
}

/// Sniff the first 4 bytes without consuming: raw-protocol magic routes
/// to the frame handler, anything else is HTTP. `Ok(false)` = EOF.
fn peek_exact(stream: &TcpStream, buf: &mut [u8]) -> std::io::Result<bool> {
    let deadline = Instant::now() + Duration::from_secs(2);
    loop {
        let n = stream.peek(buf)?;
        if n == 0 {
            return Ok(false);
        }
        if n >= buf.len() || Instant::now() > deadline {
            return Ok(n >= buf.len());
        }
        std::thread::sleep(Duration::from_millis(1));
    }
}

fn handle_conn(stream: TcpStream, ctx: &Ctx) {
    let io_timeout = Duration::from_millis(ctx.cfg.io_timeout_ms.max(1));
    let _ = stream.set_read_timeout(Some(io_timeout));
    let _ = stream.set_write_timeout(Some(io_timeout));
    let _ = stream.set_nodelay(true);
    let mut magic = [0u8; 4];
    match peek_exact(&stream, &mut magic) {
        Ok(true) if magic == *RAW_MAGIC => {
            handle_raw(stream, ctx);
            return;
        }
        Ok(_) => {}
        Err(_) => return,
    }
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    match http::read_request(&mut reader) {
        Ok(req) => route(req, &mut writer, ctx),
        Err(e) => {
            let _ = http::write_json_error(&mut writer, e.status, &e.msg, &[]);
        }
    }
}

fn route(req: Request, w: &mut TcpStream, ctx: &Ctx) {
    match (req.method.as_str(), req.target.as_str()) {
        ("GET", "/healthz") => healthz(w, ctx),
        ("GET", "/metrics") => metrics_endpoint(w, ctx),
        ("POST", "/admin/drain") => {
            ctx.draining.store(true, Ordering::Release);
            let _ = http::write_json(w, 200, r#"{"status":"draining"}"#, &[]);
        }
        ("POST", "/v1/generate") => generate_http(&req, w, ctx),
        ("GET" | "POST", _) => {
            let known = ["/healthz", "/metrics", "/admin/drain", "/v1/generate"];
            let status = if known.contains(&req.target.as_str()) { 405 } else { 404 };
            let _ = http::write_json_error(w, status, http::reason(status), &[]);
        }
        _ => {
            let _ = http::write_json_error(w, 405, "method not allowed", &[]);
        }
    }
}

/// `GET /healthz`: `200 ok` when every worker is alive and the server
/// is accepting; `503 degraded` when any worker died (its error list
/// rides along so clients see the cause before they see hangs);
/// `503 draining` during a drain.
fn healthz(w: &mut TcpStream, ctx: &Ctx) {
    let errors = ctx.router.worker_errors();
    let draining = ctx.draining.load(Ordering::Acquire);
    let (status, label) = if !errors.is_empty() {
        (503, "degraded")
    } else if draining {
        (503, "draining")
    } else {
        (200, "ok")
    };
    let mut jw = JsonWriter::new();
    jw.begin_object()
        .key("status")
        .string(label)
        .key("draining")
        .bool(draining)
        .key("workers")
        .int(ctx.router.n_workers() as i64)
        .key("queue_depth")
        .int(ctx.router.queue_depth() as i64)
        .key("worker_errors")
        .begin_array();
    for e in &errors {
        jw.string(e);
    }
    jw.end_array().end_object();
    let _ = http::write_json(w, status, &jw.finish(), &[]);
}

/// `GET /metrics`: the live [`crate::serving::LatencySummary`] (arena,
/// prefix-cache, page, and admission counters included) plus the
/// instantaneous queue depth.
fn metrics_endpoint(w: &mut TcpStream, ctx: &Ctx) {
    let summary = ctx.router.metrics.summary().to_json();
    let json = format!(
        r#"{{"queue_depth":{},"draining":{},"summary":{}}}"#,
        ctx.router.queue_depth(),
        ctx.draining.load(Ordering::Acquire),
        summary,
    );
    let _ = http::write_json(w, 200, &json, &[]);
}

/// A validated generate request.
struct GenSpec {
    tokens: Vec<u32>,
    params: SamplingParams,
    priority: u8,
}

/// Admission decision for one generate request.
enum Admit {
    Ok,
    Drain,
    Reject { est_us: u64, budget_us: u64 },
}

fn admit(ctx: &Ctx, prompt_tokens: usize) -> Admit {
    if ctx.draining.load(Ordering::Acquire) {
        return Admit::Drain;
    }
    let Some(budget_us) = ctx.cfg.deadline_budget_us else { return Admit::Ok };
    // Refresh the cached ITL p50 / prefill rate every few admissions
    // (sorting the whole sample window per request would put a
    // O(n log n) pass on the admission path for no accuracy gain).
    let n = ctx.admissions.fetch_add(1, Ordering::Relaxed);
    if n % 8 == 0 {
        ctx.itl_cache_us.store(ctx.router.metrics.itl_p50_us(), Ordering::Relaxed);
        let rate = ctx.router.metrics.prefill_tokens_per_sec();
        ctx.prefill_rate_cache.store(rate as u64, Ordering::Relaxed);
    }
    let itl = ctx.itl_cache_us.load(Ordering::Relaxed).max(ITL_FLOOR_US);
    // The request's own prefill cost at the measured rate; zero while
    // the rate is unmeasured (cold server) — the queue term still
    // protects against backlog, and the first retirements teach us.
    let rate = ctx.prefill_rate_cache.load(Ordering::Relaxed);
    let prefill_us =
        if rate > 0 { (prompt_tokens as u64).saturating_mul(1_000_000) / rate } else { 0 };
    let est_us = (ctx.router.queue_depth() as u64 * itl).saturating_add(prefill_us);
    if est_us > budget_us {
        Admit::Reject { est_us, budget_us }
    } else {
        Admit::Ok
    }
}

/// Parse + validate a generate body against the server's limits.
fn parse_generate(body: &[u8], ctx: &Ctx) -> Result<GenSpec, HttpError> {
    let text =
        std::str::from_utf8(body).map_err(|_| HttpError::new(400, "body is not utf-8"))?;
    if text.trim().is_empty() {
        return Err(HttpError::new(400, "empty body (expected a JSON object)"));
    }
    let v = JsonValue::parse(text).map_err(|e| HttpError::new(400, format!("bad json: {e}")))?;
    let bad = |msg: &str| HttpError::new(400, msg);

    let tokens: Vec<u32> = if let Some(t) = v.get("tokens") {
        let arr = t.as_array().ok_or_else(|| bad("`tokens` must be an array of ids"))?;
        let mut out = Vec::with_capacity(arr.len());
        for item in arr {
            let id = item.as_u64().ok_or_else(|| bad("`tokens` ids must be integers"))?;
            if id >= ctx.cfg.vocab_size as u64 {
                return Err(bad("`tokens` id out of vocabulary range"));
            }
            out.push(id as u32);
        }
        out
    } else if let Some(p) = v.get("prompt") {
        let s = p.as_str().ok_or_else(|| bad("`prompt` must be a string"))?;
        ctx.tok.encode(s)
    } else {
        return Err(bad("body needs `prompt` (string) or `tokens` (id array)"));
    };
    if tokens.is_empty() {
        return Err(bad("empty prompt"));
    }

    let mut params = ctx.cfg.default_params.clone();
    if let Some(x) = v.get("max_new") {
        params.max_new = x.as_u64().ok_or_else(|| bad("`max_new` must be an integer"))? as usize;
    }
    if let Some(x) = v.get("temperature") {
        let t = x.as_f64().ok_or_else(|| bad("`temperature` must be a number"))?;
        if t < 0.0 {
            return Err(bad("`temperature` must be >= 0"));
        }
        params.temperature = t as f32;
    }
    if let Some(x) = v.get("top_k") {
        params.top_k = x.as_u64().ok_or_else(|| bad("`top_k` must be an integer"))? as usize;
    }
    if let Some(x) = v.get("top_p") {
        let p = x.as_f64().ok_or_else(|| bad("`top_p` must be a number"))?;
        if !(p > 0.0 && p <= 1.0) {
            return Err(bad("`top_p` must be in (0, 1]"));
        }
        params.top_p = p as f32;
    }
    if let Some(x) = v.get("seed") {
        params.seed = x.as_u64().ok_or_else(|| bad("`seed` must be an integer"))?;
    }
    if let Some(x) = v.get("stop") {
        let arr = x.as_array().ok_or_else(|| bad("`stop` must be an array of ids"))?;
        params.stop_tokens.clear();
        for item in arr {
            let id = item.as_u64().ok_or_else(|| bad("`stop` ids must be integers"))?;
            params.stop_tokens.push(id as u32);
        }
    }
    if tokens.len() + params.max_new > ctx.cfg.capacity {
        return Err(bad("prompt + max_new exceeds model capacity"));
    }

    let priority = if let Some(x) = v.get("priority") {
        let p = x.as_u64().ok_or_else(|| bad("`priority` must be an integer"))?;
        if p > u8::MAX as u64 {
            return Err(bad("`priority` must be 0..=255"));
        }
        p as u8
    } else if let Some(t) = v.get("tenant") {
        let name = t.as_str().ok_or_else(|| bad("`tenant` must be a string"))?;
        ctx.cfg
            .tenant_priority
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| *p)
            .unwrap_or(0)
    } else {
        0
    };
    Ok(GenSpec { tokens, params, priority })
}

fn finish_label(finish: FinishReason) -> &'static str {
    match finish {
        FinishReason::Length => "length",
        FinishReason::Stop => "stop",
        FinishReason::Cancelled => "cancelled",
        FinishReason::Error => "error",
    }
}

fn token_json(id: u32, logprob: f32) -> String {
    let mut w = JsonWriter::new();
    w.begin_object().key("id").int(id as i64).key("logprob").number(logprob as f64).end_object();
    w.finish()
}

fn done_json(finish: FinishReason, usage: &Usage, error: Option<&str>) -> String {
    let mut w = JsonWriter::new();
    w.begin_object()
        .key("finish_reason")
        .string(finish_label(finish))
        .key("usage")
        .begin_object()
        .key("prompt_tokens")
        .int(usage.prompt_tokens as i64)
        .key("completion_tokens")
        .int(usage.completion_tokens as i64)
        .key("queue_us")
        .int(usage.queue_us as i64)
        .key("prefill_us")
        .int(usage.prefill_us as i64)
        .key("ttft_us")
        .int(usage.ttft_us as i64)
        .key("total_us")
        .int(usage.total_us as i64)
        .end_object()
        .key("error");
    match error {
        Some(e) => w.string(e),
        None => w.null(),
    };
    w.end_object();
    w.finish()
}

/// How a stream pump ended.
#[derive(Debug, PartialEq, Eq)]
enum Pump {
    /// Terminal event delivered (whatever the finish reason).
    Done,
    /// A frame write failed: the client is gone or stalled past the
    /// socket timeout. The caller cancels the stream.
    ClientGone,
    /// The worker died without a terminal event (thread panic).
    WorkerDied,
}

/// Forward a [`GenStream`] as SSE frames. Bounded waits
/// ([`GenStream::recv_timeout`]) interleave `: keep-alive` comments and
/// surface worker death; any failed write is the client's disconnect
/// signal. Shares the scheduler sweep's no-panic discipline: a panic
/// here would leak the session until its next token send failed.
// lint: sweep
fn pump_sse<W: Write>(stream: &GenStream, w: &mut W, keepalive: Duration) -> Pump {
    loop {
        match stream.recv_timeout(keepalive) {
            Ok(GenEvent::Token { id, logprob }) => {
                let frame = format!("event: token\ndata: {}\n\n", token_json(id, logprob));
                if w.write_all(frame.as_bytes()).and_then(|_| w.flush()).is_err() {
                    return Pump::ClientGone;
                }
            }
            Ok(GenEvent::Done { finish_reason, usage, error }) => {
                let json = done_json(finish_reason, &usage, error.as_deref());
                let frame = format!("event: done\ndata: {json}\n\n");
                let _ = w.write_all(frame.as_bytes()).and_then(|_| w.flush());
                return Pump::Done;
            }
            Err(RecvTimeoutError::Timeout) => {
                if w.write_all(b": keep-alive\n\n").and_then(|_| w.flush()).is_err() {
                    return Pump::ClientGone;
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                let frame = "event: done\ndata: {\"finish_reason\":\"error\",\"usage\":null,\
                             \"error\":\"worker died mid-stream\"}\n\n";
                let _ = w.write_all(frame.as_bytes()).and_then(|_| w.flush());
                return Pump::WorkerDied;
            }
        }
    }
}

fn generate_http(req: &Request, w: &mut TcpStream, ctx: &Ctx) {
    let spec = match parse_generate(&req.body, ctx) {
        Ok(s) => s,
        Err(e) => {
            let _ = http::write_json_error(w, e.status, &e.msg, &[]);
            return;
        }
    };
    match admit(ctx, spec.tokens.len()) {
        Admit::Drain => {
            ctx.router.metrics.record_drained();
            let _ = http::write_json_error(w, 503, "draining: not accepting new requests", &[]);
        }
        Admit::Reject { est_us, budget_us } => {
            ctx.router.metrics.record_rejected_429();
            let retry_s = (est_us - budget_us).div_ceil(1_000_000).max(1);
            let mut jw = JsonWriter::new();
            jw.begin_object()
                .key("error")
                .string("overloaded: estimated queue delay exceeds deadline budget")
                .key("estimated_queue_delay_us")
                .int(est_us as i64)
                .key("deadline_budget_us")
                .int(budget_us as i64)
                .end_object();
            let extra = [("Retry-After", retry_s.to_string())];
            let _ = http::write_json(w, 429, &jw.finish(), &extra);
        }
        Admit::Ok => {
            ctx.router.metrics.record_accepted();
            let stream = ctx.router.submit_with(spec.tokens, spec.params, spec.priority);
            let head = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
                        Cache-Control: no-cache\r\nConnection: close\r\n\r\n";
            if w.write_all(head.as_bytes()).and_then(|_| w.flush()).is_err() {
                stream.cancel();
                ctx.router.metrics.record_disconnect();
                return;
            }
            match pump_sse(&stream, w, Duration::from_millis(ctx.cfg.keepalive_ms.max(1))) {
                Pump::Done | Pump::WorkerDied => {}
                Pump::ClientGone => {
                    // Cancel eagerly (dropping the stream would only
                    // cancel at the next emitted token) and account it.
                    stream.cancel();
                    ctx.router.metrics.record_disconnect();
                }
            }
        }
    }
}

// ---- length-prefixed raw fallback ---------------------------------------

/// Read one `u32-le length + payload` frame, capped like an HTTP body.
fn read_frame(r: &mut impl Read) -> Result<Vec<u8>, HttpError> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4).map_err(|_| HttpError::new(400, "truncated frame header"))?;
    let n = u32::from_le_bytes(len4) as usize;
    if n > http::MAX_BODY_BYTES {
        return Err(HttpError::new(413, "frame too large"));
    }
    let mut body = vec![0u8; n];
    r.read_exact(&mut body).map_err(|_| HttpError::new(400, "truncated frame body"))?;
    Ok(body)
}

fn write_frame(w: &mut impl Write, json: &str) -> std::io::Result<()> {
    w.write_all(&(json.len() as u32).to_le_bytes())?;
    w.write_all(json.as_bytes())?;
    w.flush()
}

fn raw_error_json(status: u16, msg: &str, retry_after_s: Option<u64>) -> String {
    let mut w = JsonWriter::new();
    w.begin_object().key("type").string("error").key("status").int(status as i64);
    w.key("error").string(msg);
    if let Some(s) = retry_after_s {
        w.key("retry_after_s").int(s as i64);
    }
    w.end_object();
    w.finish()
}

/// Forward a [`GenStream`] as raw frames (`{"type":"token",…}` /
/// `{"type":"done",…}`). Same discipline and outcomes as [`pump_sse`];
/// timeouts just re-poll (raw clients need no keep-alive comments).
// lint: sweep
fn pump_raw<W: Write>(stream: &GenStream, w: &mut W, poll: Duration) -> Pump {
    loop {
        match stream.recv_timeout(poll) {
            Ok(GenEvent::Token { id, logprob }) => {
                let json = format!("{{\"type\":\"token\",\"frame\":{}}}", token_json(id, logprob));
                if write_frame(w, &json).is_err() {
                    return Pump::ClientGone;
                }
            }
            Ok(GenEvent::Done { finish_reason, usage, error }) => {
                let json = format!(
                    "{{\"type\":\"done\",\"frame\":{}}}",
                    done_json(finish_reason, &usage, error.as_deref()),
                );
                let _ = write_frame(w, &json);
                return Pump::Done;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                let _ = write_frame(w, &raw_error_json(500, "worker died mid-stream", None));
                return Pump::WorkerDied;
            }
        }
    }
}

/// One generate request per raw connection: magic, then one request
/// frame in, token/done/error frames out.
fn handle_raw(mut stream: TcpStream, ctx: &Ctx) {
    let mut magic = [0u8; 4];
    if stream.read_exact(&mut magic).is_err() {
        return;
    }
    let body = match read_frame(&mut stream) {
        Ok(b) => b,
        Err(e) => {
            let _ = write_frame(&mut stream, &raw_error_json(e.status, &e.msg, None));
            return;
        }
    };
    let spec = match parse_generate(&body, ctx) {
        Ok(s) => s,
        Err(e) => {
            let _ = write_frame(&mut stream, &raw_error_json(e.status, &e.msg, None));
            return;
        }
    };
    match admit(ctx, spec.tokens.len()) {
        Admit::Drain => {
            ctx.router.metrics.record_drained();
            let json = raw_error_json(503, "draining: not accepting new requests", None);
            let _ = write_frame(&mut stream, &json);
        }
        Admit::Reject { est_us, budget_us } => {
            ctx.router.metrics.record_rejected_429();
            let retry_s = (est_us - budget_us).div_ceil(1_000_000).max(1);
            let json = raw_error_json(
                429,
                "overloaded: estimated queue delay exceeds deadline budget",
                Some(retry_s),
            );
            let _ = write_frame(&mut stream, &json);
        }
        Admit::Ok => {
            ctx.router.metrics.record_accepted();
            let gen = ctx.router.submit_with(spec.tokens, spec.params, spec.priority);
            let poll = Duration::from_millis(ctx.cfg.keepalive_ms.max(1));
            if pump_raw(&gen, &mut stream, poll) == Pump::ClientGone {
                gen.cancel();
                ctx.router.metrics.record_disconnect();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic_model, Model, ModelConfig};
    use crate::serving::{EngineKind, KvFormat, Router, RouterConfig, Strategy};
    use std::sync::mpsc::channel;

    fn tiny_model(max_seq: usize) -> Arc<Model> {
        Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 16,
                d_model: 16,
                n_layers: 1,
                n_heads: 2,
                n_kv_heads: 2,
                d_ff: 24,
                max_seq,
                kv_format: KvFormat::F32,
            },
            5,
        ))
    }

    fn tiny_router(max_seq: usize) -> Arc<Router> {
        let model = tiny_model(max_seq);
        let router = Router::start(
            RouterConfig {
                n_workers: 1,
                max_batch: 2,
                strategy: Strategy::LeastLoaded,
                ..Default::default()
            },
            move |_| Ok(EngineKind::Native(model.clone())),
        )
        .unwrap();
        Arc::new(router)
    }

    fn test_cfg() -> ServerConfig {
        ServerConfig { capacity: 32, vocab_size: 16, ..Default::default() }
    }

    fn start(router: Arc<Router>, cfg: ServerConfig) -> Server {
        Server::start("127.0.0.1:0", router, Arc::new(Tokenizer::new()), cfg).unwrap()
    }

    /// One-shot HTTP exchange: write `raw`, read to EOF.
    fn exchange(addr: SocketAddr, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn post(addr: SocketAddr, path: &str, body: &str) -> String {
        let raw = format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len(),
        );
        exchange(addr, &raw)
    }

    fn get(addr: SocketAddr, path: &str) -> String {
        exchange(addr, &format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
    }

    /// Pull the `data:` payloads out of an SSE response body.
    fn sse_events(text: &str) -> Vec<JsonValue> {
        text.lines()
            .filter_map(|l| l.strip_prefix("data: "))
            .map(|d| JsonValue::parse(d).expect("valid event json"))
            .collect()
    }

    #[test]
    fn http_generate_streams_tokens_identical_to_inprocess() {
        let router = tiny_router(32);
        let want = router.submit(vec![1, 2, 3], 3).collect().unwrap().tokens;
        let server = start(router.clone(), test_cfg());
        let addr = server.local_addr();

        let text = post(addr, "/v1/generate", r#"{"tokens":[1,2,3],"max_new":3}"#);
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Type: text/event-stream"), "{text}");
        let events = sse_events(&text);
        let got: Vec<u32> = events
            .iter()
            .filter_map(|e| e.get("id").and_then(JsonValue::as_u64))
            .map(|id| id as u32)
            .collect();
        assert_eq!(got, want, "wire tokens must match in-process submit_with");
        let done = events.last().expect("done event");
        assert_eq!(done.get("finish_reason").and_then(JsonValue::as_str), Some("length"));
        let usage = done.get("usage").expect("usage");
        assert_eq!(usage.get("completion_tokens").and_then(JsonValue::as_u64), Some(3));
        assert!(done.get("error").is_some_and(JsonValue::is_null));

        assert!(post(addr, "/admin/drain", "").contains("draining"));
        server.join().unwrap();
        let m = router.metrics.summary();
        assert_eq!(m.accepted, 1);
        assert_eq!(m.arena_slots_in_use, 0, "no leaked slots at drain");
        router.shutdown();
    }

    #[test]
    fn raw_fallback_streams_identical_tokens() {
        let router = tiny_router(32);
        let want = router.submit(vec![4, 5], 4).collect().unwrap().tokens;
        let server = start(router.clone(), test_cfg());

        let mut s = TcpStream::connect(server.local_addr()).unwrap();
        s.write_all(RAW_MAGIC).unwrap();
        let body = br#"{"tokens":[4,5],"max_new":4}"#;
        s.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
        s.write_all(body).unwrap();
        let mut got = Vec::new();
        loop {
            let mut len4 = [0u8; 4];
            s.read_exact(&mut len4).unwrap();
            let mut frame = vec![0u8; u32::from_le_bytes(len4) as usize];
            s.read_exact(&mut frame).unwrap();
            let v = JsonValue::parse(std::str::from_utf8(&frame).unwrap()).unwrap();
            match v.get("type").and_then(JsonValue::as_str) {
                Some("token") => {
                    let id = v.get("frame").and_then(|f| f.get("id")).and_then(JsonValue::as_u64);
                    got.push(id.unwrap() as u32);
                }
                Some("done") => break,
                other => panic!("unexpected frame type {other:?} in {v:?}"),
            }
        }
        assert_eq!(got, want, "raw-protocol tokens must match in-process submit_with");
        server.drain();
        server.join().unwrap();
        router.shutdown();
    }

    #[test]
    fn malformed_bodies_get_4xx_not_a_hung_stream() {
        let router = tiny_router(32);
        let server = start(router.clone(), test_cfg());
        let addr = server.local_addr();
        for (body, frag) in [
            ("", "empty body"),
            ("{", "bad json"),
            (r#"{"max_new":4}"#, "prompt"),
            (r#"{"tokens":[]}"#, "empty prompt"),
            (r#"{"tokens":[99],"max_new":1}"#, "vocabulary"),
            (r#"{"tokens":[1],"max_new":1000}"#, "capacity"),
            (r#"{"tokens":[1],"priority":999}"#, "priority"),
            (r#"{"tokens":"nope"}"#, "array"),
        ] {
            let text = post(addr, "/v1/generate", body);
            assert!(text.starts_with("HTTP/1.1 400 "), "body {body:?} -> {text}");
            assert!(text.contains(frag), "body {body:?} -> {text}");
        }
        // Unknown path and wrong method.
        assert!(get(addr, "/nope").starts_with("HTTP/1.1 404 "));
        assert!(get(addr, "/v1/generate").starts_with("HTTP/1.1 405 "));
        server.drain();
        server.join().unwrap();
        let m = router.metrics.summary();
        assert_eq!(m.accepted, 0, "rejected bodies must never reach the scheduler");
        router.shutdown();
    }

    #[test]
    fn healthz_flips_on_dead_worker() {
        let healthy = tiny_router(32);
        let server = start(healthy.clone(), test_cfg());
        let text = get(server.local_addr(), "/healthz");
        assert!(text.starts_with("HTTP/1.1 200 OK"), "{text}");
        assert!(text.contains(r#""status":"ok""#), "{text}");
        server.drain();
        server.join().unwrap();
        healthy.shutdown();

        let broken = Router::start(
            RouterConfig { n_workers: 1, max_batch: 2, ..Default::default() },
            |_| anyhow::bail!("synthetic init failure"),
        )
        .unwrap();
        let broken = Arc::new(broken);
        let t0 = Instant::now();
        while broken.worker_errors().is_empty() {
            assert!(t0.elapsed() < Duration::from_secs(5), "worker error never surfaced");
            std::thread::sleep(Duration::from_millis(1));
        }
        let server = start(broken.clone(), test_cfg());
        let text = get(server.local_addr(), "/healthz");
        assert!(text.starts_with("HTTP/1.1 503 "), "{text}");
        assert!(text.contains(r#""status":"degraded""#), "{text}");
        assert!(text.contains("synthetic init failure"), "{text}");
        server.drain();
        server.join().unwrap();
        broken.shutdown();
    }

    #[test]
    fn metrics_endpoint_serves_summary_json() {
        let router = tiny_router(32);
        router.submit(vec![1, 2], 2).collect().unwrap();
        let server = start(router.clone(), test_cfg());
        let text = get(server.local_addr(), "/metrics");
        assert!(text.starts_with("HTTP/1.1 200 OK"), "{text}");
        let body = text.split("\r\n\r\n").nth(1).expect("body");
        let v = JsonValue::parse(body).expect("metrics json parses");
        assert_eq!(v.get("queue_depth").and_then(JsonValue::as_u64), Some(0));
        let summary = v.get("summary").expect("summary");
        assert_eq!(summary.get("completed").and_then(JsonValue::as_u64), Some(1));
        assert!(summary.get("accepted").is_some());
        server.drain();
        server.join().unwrap();
        router.shutdown();
    }

    #[test]
    fn overload_rejects_429_with_retry_after() {
        // Budget 0: any estimated queue delay > 0 must reject. A deep
        // backlog (48 requests × 200 tokens through a single max_batch-2
        // worker) keeps the queue demonstrably non-empty for the whole
        // wire exchange, so the test never races the decode speed.
        let model = Arc::new(synthetic_model(&ModelConfig::tiny_large(16), 5));
        let router = Router::start(
            RouterConfig { n_workers: 1, max_batch: 2, ..Default::default() },
            move |_| Ok(EngineKind::Native(model.clone())),
        )
        .unwrap();
        let router = Arc::new(router);
        let cfg = ServerConfig { deadline_budget_us: Some(0), ..test_cfg() };
        let server = start(router.clone(), cfg);
        let backlog: Vec<GenStream> =
            (0..48).map(|_| router.submit(vec![1, 2, 3], 200)).collect();
        let text = post(server.local_addr(), "/v1/generate", r#"{"tokens":[1],"max_new":1}"#);
        assert!(text.starts_with("HTTP/1.1 429 "), "{text}");
        assert!(text.contains("Retry-After: "), "{text}");
        assert!(text.contains("estimated_queue_delay_us"), "{text}");
        for s in &backlog {
            s.cancel();
        }
        for s in backlog {
            while s.recv().is_some() {}
        }
        server.drain();
        server.join().unwrap();
        let m = router.metrics.summary();
        assert_eq!(m.rejected_429, 1);
        assert_eq!(m.accepted, 0, "the rejected request must never reach the scheduler");
        router.shutdown();
    }

    #[test]
    fn admission_folds_prompt_prefill_cost_into_429() {
        // Satellite: once traffic has measured a prefill rate, a long
        // prompt's own prefill time counts against the deadline budget
        // — an idle server (queue term 0) must still 429 a prompt whose
        // prefill alone busts the budget, and still admit a short one.
        use crate::serving::metrics::RetireSample;
        let router = tiny_router(32);
        // Teach the metrics a rate of 1000 tok/s: 500 prompt tokens
        // prefilled in 0.5 s.
        router.metrics.record_retired(RetireSample {
            finish: FinishReason::Length,
            queue_us: 0,
            ttft_us: Some(500_000),
            prefill_us: Some(500_000),
            prefill_tokens: 500,
            itl_us: &[],
            tokens: 1,
            decode_us: 500_000,
        });
        let cfg = ServerConfig { deadline_budget_us: Some(10_000), ..test_cfg() };
        let ctx = Ctx {
            router: router.clone(),
            tok: Arc::new(Tokenizer::new()),
            cfg,
            draining: AtomicBool::new(false),
            itl_cache_us: AtomicU64::new(0),
            prefill_rate_cache: AtomicU64::new(0),
            admissions: AtomicU64::new(0),
        };
        // 100 tokens at 1000 tok/s ≈ 100 ms ≫ the 10 ms budget.
        match admit(&ctx, 100) {
            Admit::Reject { est_us, budget_us } => {
                assert!(est_us >= 100_000, "prefill term must dominate: {est_us}");
                assert_eq!(budget_us, 10_000);
            }
            _ => panic!("long prompt must be rejected on prefill cost alone"),
        }
        // 5 tokens ≈ 5 ms < 10 ms budget: admitted.
        assert!(matches!(admit(&ctx, 5), Admit::Ok), "short prompt must admit");
        router.shutdown();
    }

    #[test]
    fn draining_rejects_new_generates_and_counts_them() {
        let router = tiny_router(32);
        let server = start(router.clone(), test_cfg());
        let addr = server.local_addr();
        assert!(post(addr, "/admin/drain", "").starts_with("HTTP/1.1 200 OK"));
        let text = post(addr, "/v1/generate", r#"{"tokens":[1],"max_new":1}"#);
        assert!(text.starts_with("HTTP/1.1 503 "), "{text}");
        assert!(text.contains("draining"), "{text}");
        let health = get(addr, "/healthz");
        assert!(health.contains(r#""status":"draining""#), "{health}");
        server.join().unwrap();
        assert_eq!(router.metrics.summary().drained, 1);
        router.shutdown();
    }

    /// Writer that accepts `budget` bytes, then fails like a closed
    /// socket — the deterministic stand-in for a slow/dead client.
    struct FailAfter {
        budget: usize,
    }

    impl Write for FailAfter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if self.budget == 0 {
                return Err(std::io::Error::from(std::io::ErrorKind::BrokenPipe));
            }
            let n = buf.len().min(self.budget);
            self.budget -= n;
            Ok(n)
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn pump_reports_client_gone_on_write_failure() {
        let (tx, rx) = channel();
        let stream = GenStream::new(1, rx, crate::serving::CancelHandle::new());
        tx.send(GenEvent::Token { id: 3, logprob: -0.1 }).unwrap();
        let mut w = FailAfter { budget: 4 };
        assert_eq!(pump_sse(&stream, &mut w, Duration::from_secs(5)), Pump::ClientGone);
        let mut w = FailAfter { budget: 0 };
        tx.send(GenEvent::Token { id: 4, logprob: -0.2 }).unwrap();
        assert_eq!(pump_raw(&stream, &mut w, Duration::from_secs(5)), Pump::ClientGone);
    }

    #[test]
    fn pump_reports_worker_death_and_emits_error_event() {
        let (tx, rx) = channel();
        let stream = GenStream::new(1, rx, crate::serving::CancelHandle::new());
        drop(tx); // worker panicked without a terminal event
        let mut out = Vec::new();
        assert_eq!(pump_sse(&stream, &mut out, Duration::from_secs(5)), Pump::WorkerDied);
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("worker died"), "{text}");
    }

    #[test]
    fn pump_interleaves_keepalive_comments() {
        let (tx, rx) = channel();
        let stream = GenStream::new(1, rx, crate::serving::CancelHandle::new());
        let mut out = Vec::new();
        let pump = std::thread::spawn(move || {
            let r = pump_sse(&stream, &mut out, Duration::from_millis(5));
            (r, out)
        });
        std::thread::sleep(Duration::from_millis(50));
        let usage = Usage::default();
        let done = GenEvent::Done { finish_reason: FinishReason::Length, usage, error: None };
        tx.send(done).unwrap();
        let (r, out) = pump.join().unwrap();
        assert_eq!(r, Pump::Done);
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains(": keep-alive"), "silent stretch must emit keep-alives: {text}");
        assert!(text.contains("event: done"), "{text}");
    }

    #[test]
    fn tenant_priority_maps_and_explicit_priority_wins() {
        let router = tiny_router(32);
        let cfg = ServerConfig {
            tenant_priority: vec![("gold".into(), 9), ("free".into(), 0)],
            ..test_cfg()
        };
        let ctx = Ctx {
            router: router.clone(),
            tok: Arc::new(Tokenizer::new()),
            cfg,
            draining: AtomicBool::new(false),
            itl_cache_us: AtomicU64::new(0),
            prefill_rate_cache: AtomicU64::new(0),
            admissions: AtomicU64::new(0),
        };
        let spec = parse_generate(br#"{"tokens":[1],"tenant":"gold"}"#, &ctx).unwrap();
        assert_eq!(spec.priority, 9);
        let spec = parse_generate(br#"{"tokens":[1],"tenant":"unknown"}"#, &ctx).unwrap();
        assert_eq!(spec.priority, 0);
        let explicit = br#"{"tokens":[1],"tenant":"free","priority":3}"#;
        let spec = parse_generate(explicit, &ctx).unwrap();
        assert_eq!(spec.priority, 3, "explicit priority beats the tenant map");
        // Sampling fields flow into params; prompt strings tokenize.
        let body = br#"{"prompt":"2+2=","max_new":4,"temperature":0.5,"seed":7,"stop":[2]}"#;
        let spec = parse_generate(body, &ctx).unwrap();
        assert!(!spec.tokens.is_empty());
        assert_eq!(spec.params.max_new, 4);
        assert_eq!(spec.params.temperature, 0.5);
        assert_eq!(spec.params.seed, 7);
        assert_eq!(spec.params.stop_tokens, vec![2]);
        router.shutdown();
    }
}
