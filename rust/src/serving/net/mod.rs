//! The network front door: a dependency-free HTTP/1.1 + SSE layer over
//! the in-process serving stack.
//!
//! [`http`] is the defensive wire parser/writer (hard caps, total — no
//! input panics); [`server`] is the accept loop, routes, admission
//! control, and stream pumps. See the `## Front door` section of
//! [`crate::serving`] for the wire contract (endpoints, SSE event
//! schema, error shapes, drain semantics).

pub mod http;
pub mod server;

pub use server::{Server, ServerConfig};
