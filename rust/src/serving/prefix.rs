//! Radix-tree prefix cache — token-prefix sharing over refcounted KV
//! pages.
//!
//! An SGLang-style radix tree over token sequences: each node's edge is
//! a token run, and each node owns **refcounted KV pages** covering its
//! full prefix (`n_strips × ⌈len/pp⌉` page refs, strip-major — exactly
//! the shape [`KvArena::export_prefix`] produces). The cache turns
//! O(sessions × prompt_len) KV into O(distinct prefixes):
//!
//! * **Admission** ([`PrefixCache::match_and_borrow`]) walks the tree
//!   along full edge matches and lends the deepest node's pages to the
//!   new session read-only ([`KvArena::import_prefix`]). The session
//!   resumes decode at the matched position — only the cache-miss
//!   suffix is prefilled, which is what collapses cache-hit TTFT.
//! * **Publication** ([`PrefixCache::insert`]) runs once per session at
//!   prefill completion: the prompt's pages are exported into a new
//!   leaf (splitting an edge mid-run when two prompts diverge inside
//!   it; the split node re-refs the shared prefix of the child's
//!   pages — a pure refcount bump, like everything here).
//! * **Divergence** costs nothing at cache level: a borrower's first
//!   store into a shared page copy-on-writes *in its own table*; the
//!   cached page is immutable for as long as any node refs it.
//! * **Eviction** ([`PrefixCache::evict`]) drops least-recently-used
//!   leaves until enough pages came free; it is registered as the
//!   arena's reclaimer ([`KvArena::set_reclaimer`]), so cache memory
//!   yields to live sessions under pressure automatically.
//!
//! Correctness leans on decode being Markovian in (KV bytes, position,
//! fed token): the donor stored these pages from the identical token
//! prefix with the deterministic store-time encoder, so a borrower's
//! continuation is **token-identical** to a cold session — at every
//! `kv_bits`, since pages are shared as bytes and never re-quantized.
//!
//! Lock order: the cache mutex is always taken **before** the arena's
//! inner mutex (every arena call here locks internally). The arena
//! invokes the reclaimer with no lock held, so eviction re-entering
//! [`KvArena::release_page_refs`] cannot deadlock.

use super::kv::{KvArena, KvHandle};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

static NEXT_CACHE_ID: AtomicU64 = AtomicU64::new(1);

/// Point-in-time cache counters (surfaced through `serving::metrics`
/// into the serve summary and the Zipf bench rows).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// admission lookups
    pub lookups: u64,
    /// lookups that borrowed a non-empty prefix
    pub hits: u64,
    /// prompt tokens served from cache (prefill work avoided)
    pub hit_tokens: u64,
    /// leaves published (distinct cached prefixes, cumulative)
    pub insertions: u64,
    /// leaves evicted under memory pressure
    pub evictions: u64,
}

struct Node {
    /// edge label: the token run from the parent to this node
    tokens: Vec<u32>,
    /// total prefix length covered by this node (sum of edges root→here)
    len: usize,
    parent: usize,
    children: Vec<usize>,
    /// refcounted page receipts covering positions `0..len`,
    /// strip-major (`n_strips × ⌈len/pp⌉`, the `export_prefix` shape)
    pages: Vec<(u32, u64)>,
    /// logical LRU clock stamp of the last borrow/publish touch
    last_use: u64,
}

struct CacheInner {
    /// slab of nodes; index 0 is the (empty, page-less) root
    nodes: Vec<Option<Node>>,
    free_nodes: Vec<usize>,
    clock: u64,
    lookups: u64,
    hits: u64,
    hit_tokens: u64,
    insertions: u64,
    evictions: u64,
}

impl CacheInner {
    fn node(&self, i: usize) -> &Node {
        self.nodes[i].as_ref().expect("dangling radix node index")
    }

    fn node_mut(&mut self, i: usize) -> &mut Node {
        self.nodes[i].as_mut().expect("dangling radix node index")
    }

    fn add_node(&mut self, n: Node) -> usize {
        match self.free_nodes.pop() {
            Some(i) => {
                self.nodes[i] = Some(n);
                i
            }
            None => {
                self.nodes.push(Some(n));
                self.nodes.len() - 1
            }
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Walk from the root along **full** edge matches only (splits
    /// happen on insert, never on lookup). Returns the deepest node and
    /// the number of prompt tokens it covers.
    fn descend(&self, prompt: &[u32]) -> (usize, usize) {
        let (mut cur, mut pos) = (0usize, 0usize);
        'walk: loop {
            for &c in &self.node(cur).children {
                let edge = &self.node(c).tokens;
                if prompt.len() - pos >= edge.len() && prompt[pos..pos + edge.len()] == edge[..] {
                    cur = c;
                    pos += edge.len();
                    continue 'walk;
                }
            }
            return (cur, pos);
        }
    }
}

/// The strip-major sublist of `pages` covering the first `need` pages
/// of each strip (a node lending or re-reffing a *prefix* of another
/// node's coverage).
fn prefix_pages(
    pages: &[(u32, u64)],
    node_pps: usize,
    need: usize,
    n_strips: usize,
) -> Vec<(u32, u64)> {
    assert!(need <= node_pps, "prefix wider than the node's coverage");
    let mut out = Vec::with_capacity(n_strips * need);
    for s in 0..n_strips {
        out.extend_from_slice(&pages[s * node_pps..s * node_pps + need]);
    }
    out
}

/// One radix prefix cache per engine, lending pages out of that
/// engine's [`KvArena`]. See the module docs.
pub struct PrefixCache {
    id: u64,
    arena: Arc<KvArena>,
    inner: Mutex<CacheInner>,
}

impl PrefixCache {
    pub fn new(arena: Arc<KvArena>) -> Self {
        let root = Node {
            tokens: Vec::new(),
            len: 0,
            parent: 0,
            children: Vec::new(),
            pages: Vec::new(),
            last_use: 0,
        };
        Self {
            id: NEXT_CACHE_ID.fetch_add(1, Ordering::Relaxed),
            arena,
            inner: Mutex::new(CacheInner {
                nodes: vec![Some(root)],
                free_nodes: Vec::new(),
                clock: 0,
                lookups: 0,
                hits: 0,
                hit_tokens: 0,
                insertions: 0,
                evictions: 0,
            }),
        }
    }

    /// Unique id (keys per-cache metrics, like `KvArena::id`).
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn arena(&self) -> &Arc<KvArena> {
        &self.arena
    }

    /// Admission-time lookup: find the deepest cached node whose prefix
    /// the prompt extends, borrow its pages into `h` read-only, and
    /// return how many prompt positions are now resident (the session
    /// resumes at that position). At most `prompt.len() - 1` — at least
    /// one prompt token must still be fed to produce first logits.
    /// Returns 0 (and imports nothing) on a miss.
    pub fn match_and_borrow(&self, prompt: &[u32], h: &mut KvHandle) -> usize {
        let mut inner = self.inner.lock().unwrap();
        inner.lookups += 1;
        if prompt.len() < 2 {
            return 0;
        }
        let (node_idx, _) = inner.descend(prompt);
        if node_idx == 0 {
            return 0;
        }
        let geom = self.arena.geom();
        let pp = geom.page_positions;
        let node_len = inner.node(node_idx).len;
        let matched = node_len.min(prompt.len() - 1);
        if matched == 0 {
            return 0;
        }
        let need = matched.div_ceil(pp);
        let lend = prefix_pages(
            &inner.node(node_idx).pages,
            node_len.div_ceil(pp),
            need,
            geom.n_strips(),
        );
        // The node holds live refs on every lent page, so the import
        // cannot observe a freed generation (cache lock held across).
        self.arena.import_prefix(h, &lend, matched);
        inner.hits += 1;
        inner.hit_tokens += matched as u64;
        let stamp = inner.tick();
        inner.node_mut(node_idx).last_use = stamp;
        matched
    }

    /// Publication at prefill completion: `h` has stored positions
    /// `0..prompt.len()` — export the prompt's pages into the tree,
    /// splitting an existing edge if the prompt diverges inside it.
    /// Idempotent for already-cached prompts (touches LRU only).
    pub fn insert(&self, prompt: &[u32], h: &mut KvHandle) {
        if prompt.len() < 2 {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let geom = self.arena.geom();
        let pp = geom.page_positions;
        let (mut at, mut pos) = inner.descend(prompt);
        if pos == prompt.len() {
            let stamp = inner.tick();
            inner.node_mut(at).last_use = stamp;
            return;
        }
        // Does some child share a partial edge prefix with the rest of
        // the prompt? (Full matches were consumed by descend.)
        let rest = &prompt[pos..];
        let partial = inner.node(at).children.iter().copied().find_map(|c| {
            let edge = &inner.node(c).tokens;
            let k = edge.iter().zip(rest).take_while(|(a, b)| a == b).count();
            (k > 0).then_some((c, k))
        });
        if let Some((child, k)) = partial {
            // Split: mid takes the shared k tokens and a refcount-bumped
            // prefix of the child's pages; the child keeps its suffix.
            let mid_len = inner.node(at).len + k;
            let mid_pages = prefix_pages(
                &inner.node(child).pages,
                inner.node(child).len.div_ceil(pp),
                mid_len.div_ceil(pp),
                geom.n_strips(),
            );
            self.arena.page_ref_inc(&mid_pages);
            let stamp = inner.tick();
            let mid = inner.add_node(Node {
                tokens: rest[..k].to_vec(),
                len: mid_len,
                parent: at,
                children: vec![child],
                pages: mid_pages,
                last_use: stamp,
            });
            let at_children = &mut inner.node_mut(at).children;
            let slot = at_children.iter().position(|&c| c == child).expect("child under parent");
            at_children[slot] = mid;
            let child_node = inner.node_mut(child);
            child_node.tokens.drain(..k);
            child_node.parent = mid;
            at = mid;
            pos += k;
            if pos == prompt.len() {
                return; // the split node covers the prompt exactly
            }
        }
        // Publish the divergent tail as a new leaf owning the prompt's
        // full page list.
        let pages = self.arena.export_prefix(h, prompt.len());
        let stamp = inner.tick();
        let leaf = inner.add_node(Node {
            tokens: prompt[pos..].to_vec(),
            len: prompt.len(),
            parent: at,
            children: Vec::new(),
            pages,
            last_use: stamp,
        });
        inner.node_mut(at).children.push(leaf);
        inner.insertions += 1;
    }

    /// LRU leaf eviction: drop least-recently-used leaves until at
    /// least `want_pages` pages returned to the free list (or no
    /// evictable leaf remains). Registered as the arena's reclaimer, so
    /// this runs exactly when a store cannot get a page any other way.
    /// Returns the number of pages actually freed.
    ///
    /// Leaves whose pages are *all* borrowed outside the cache (live
    /// sessions) are skipped, not evicted: dropping the cache's refs on
    /// them frees nothing for the allocator — `freed` would never
    /// advance and the loop would devour the whole tree, hot leaves
    /// included, while reporting 0. Pages shared only *within* the tree
    /// (a split node re-reffing its child's pages) don't pin a victim:
    /// evicting it cascades — the ancestor becomes an evictable leaf
    /// and the shared pages free on a later round. Session-pinned
    /// leaves become evictable again once their borrowers retire.
    pub fn evict(&self, want_pages: usize) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let mut freed = 0usize;
        while freed < want_pages {
            // Per-page tally of refs held by tree nodes; a page whose
            // arena refcount exceeds this is borrowed by a session.
            let mut tree_refs: HashMap<(u32, u64), usize> = HashMap::new();
            for n in inner.nodes.iter().flatten() {
                for &p in &n.pages {
                    *tree_refs.entry(p).or_insert(0) += 1;
                }
            }
            let victim = inner
                .nodes
                .iter()
                .enumerate()
                .skip(1)
                .filter_map(|(i, n)| n.as_ref().map(|n| (i, n)))
                .filter(|(_, n)| n.children.is_empty())
                .filter(|(_, n)| {
                    n.pages
                        .iter()
                        .any(|&(id, gen)| self.arena.page_refs(id, gen) == tree_refs[&(id, gen)])
                })
                .min_by_key(|(_, n)| n.last_use)
                .map(|(i, _)| i);
            let Some(i) = victim else { break };
            let node = inner.nodes[i].take().expect("victim exists");
            inner.free_nodes.push(i);
            let siblings = &mut inner.node_mut(node.parent).children;
            siblings.retain(|&c| c != i);
            // A session borrowing these pages keeps them alive through
            // its own refs; eviction only drops the cache's.
            freed += self.arena.release_page_refs(&node.pages);
            inner.evictions += 1;
        }
        freed
    }

    pub fn stats(&self) -> PrefixStats {
        let inner = self.inner.lock().unwrap();
        PrefixStats {
            lookups: inner.lookups,
            hits: inner.hits,
            hit_tokens: inner.hit_tokens,
            insertions: inner.insertions,
            evictions: inner.evictions,
        }
    }

    /// Live cached prefixes (non-root nodes) — observability only.
    pub fn node_count(&self) -> usize {
        self.inner.lock().unwrap().nodes.iter().flatten().count().saturating_sub(1)
    }
}

impl Drop for PrefixCache {
    fn drop(&mut self) {
        let inner = self.inner.get_mut().unwrap();
        for node in inner.nodes.iter().flatten() {
            self.arena.release_page_refs(&node.pages);
        }
    }
}

/// Wire `cache` in as `arena`'s under-pressure reclaimer. Holds only a
/// `Weak` — the arena must not keep its cache alive (the cache already
/// holds the arena).
pub fn register_reclaimer(arena: &KvArena, cache: &Arc<PrefixCache>) {
    let weak = Arc::downgrade(cache);
    arena.set_reclaimer(move |need| weak.upgrade().map_or(0, |c| c.evict(need)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::kv::{KvFormat, KvGeom};

    /// pp = 2, cap = 8, one (layer, kv-head) pair → 2 strips.
    fn arena(max_slots: usize) -> Arc<KvArena> {
        let g = KvGeom {
            n_layers: 1,
            n_kv_heads: 1,
            head_dim: 8,
            cap: 8,
            page_positions: 2,
            format: KvFormat::F32,
        };
        Arc::new(KvArena::with_limit(g, 1, max_slots))
    }

    fn row(seed: usize) -> Vec<f32> {
        (0..8).map(|j| ((seed * 7 + j * 3) % 13) as f32 * 0.25 - 1.0).collect()
    }

    /// Simulate a donor prefill: store K/V rows keyed by token value at
    /// every prompt position, so page bytes are a pure function of the
    /// token prefix (like a real deterministic model).
    fn prefill(a: &KvArena, h: &mut KvHandle, prompt: &[u32]) {
        for (pos, &t) in prompt.iter().enumerate() {
            a.view_mut(h).store_k(0, pos, &row(t as usize));
            a.view_mut(h).store_v(0, pos, &row(t as usize + 100));
        }
    }

    #[test]
    fn miss_then_publish_then_hit() {
        let a = arena(8);
        let cache = PrefixCache::new(a.clone());
        let prompt = [5u32, 6, 7, 8];

        // Cold: miss, prefill, publish.
        let mut donor = a.acquire().unwrap();
        assert_eq!(cache.match_and_borrow(&prompt, &mut donor), 0);
        prefill(&a, &mut donor, &prompt);
        cache.insert(&prompt, &mut donor);
        a.release(donor); // cache refs outlive the donor

        // Hit: the full prompt minus the last (must-feed) token.
        let mut hit = a.acquire().unwrap();
        let matched = cache.match_and_borrow(&prompt, &mut hit);
        assert_eq!(matched, 3, "borrow up to prompt.len() - 1");
        assert_eq!(hit.page_count(), 2 * 2, "2 pages per strip cover positions 0..3");
        assert_eq!(hit.shared_page_count(), hit.page_count(), "borrowed pages are read-only");
        // Borrowed bytes are exactly the donor's stores.
        assert_eq!(&a.view(&hit).k_page(0, 0, 0)[..8], &row(5)[..]);
        assert_eq!(&a.view(&hit).v_page(0, 0, 1)[..8], &row(7 + 100)[..]);

        // A longer prompt extending the cached prefix matches all of it.
        let mut ext = a.acquire().unwrap();
        let longer = [5u32, 6, 7, 8, 9, 10];
        assert_eq!(cache.match_and_borrow(&longer, &mut ext), 4);

        let st = cache.stats();
        assert_eq!((st.lookups, st.hits, st.insertions), (3, 2, 1));
        assert_eq!(st.hit_tokens, 3 + 4);
        a.release(hit);
        a.release(ext);
    }

    #[test]
    fn divergent_prompt_splits_the_edge() {
        let a = arena(8);
        let cache = PrefixCache::new(a.clone());
        let p1 = [1u32, 2, 3, 4];
        let p2 = [1u32, 2, 9, 9];

        let mut d1 = a.acquire().unwrap();
        prefill(&a, &mut d1, &p1);
        cache.insert(&p1, &mut d1);
        a.release(d1);

        // p2 diverges inside p1's edge → split at [1, 2]; p2 publishes
        // its own leaf. Positions 0..2 of both prompts share pages.
        let mut d2 = a.acquire().unwrap();
        let m = cache.match_and_borrow(&p2, &mut d2);
        assert_eq!(m, 0, "lookup never splits: partial edge is a miss");
        prefill(&a, &mut d2, &p2);
        cache.insert(&p2, &mut d2);
        a.release(d2);
        assert_eq!(cache.node_count(), 3, "mid + two divergent leaves");

        // Both full prompts now hit, through the split node.
        let mut h1 = a.acquire().unwrap();
        assert_eq!(cache.match_and_borrow(&p1, &mut h1), 3);
        let mut h2 = a.acquire().unwrap();
        assert_eq!(cache.match_and_borrow(&p2, &mut h2), 3);
        // And a prompt stopping exactly at the split point hits it too.
        let mut h3 = a.acquire().unwrap();
        assert_eq!(cache.match_and_borrow(&[1u32, 2, 7], &mut h3), 2);
        for h in [h1, h2, h3] {
            a.release(h);
        }
    }

    #[test]
    fn borrower_divergence_cows_not_corrupts() {
        let a = arena(8);
        let cache = PrefixCache::new(a.clone());
        let prompt = [3u32, 4, 5, 6];
        let mut donor = a.acquire().unwrap();
        prefill(&a, &mut donor, &prompt);
        cache.insert(&prompt, &mut donor);
        a.release(donor);

        let mut b = a.acquire().unwrap();
        let m = cache.match_and_borrow(&prompt, &mut b);
        assert_eq!(m, 3);
        // The borrower's continuation store at pos 3 lands in borrowed
        // page 1 → COW; cached bytes stay intact for the next hit.
        a.view_mut(&mut b).store_k(0, 3, &row(999));
        assert_eq!(a.stats().cow_copies, 1);
        a.release(b);

        let mut b2 = a.acquire().unwrap();
        cache.match_and_borrow(&prompt, &mut b2);
        assert_eq!(
            &a.view(&b2).k_page(0, 0, 1)[..8],
            &row(5)[..],
            "cached page must not see the borrower's divergence"
        );
        a.release(b2);
    }

    #[test]
    fn lru_eviction_frees_pages_and_keeps_hot_leaves() {
        let a = arena(8);
        let cache = PrefixCache::new(a.clone());
        let cold = [1u32, 2, 3, 4];
        let hot = [7u32, 8, 9, 10];
        for p in [&cold, &hot] {
            let mut d = a.acquire().unwrap();
            prefill(&a, &mut d, p);
            cache.insert(p, &mut d);
            a.release(d);
        }
        // Touch `hot` so `cold` is the LRU leaf.
        let mut t = a.acquire().unwrap();
        cache.match_and_borrow(&hot, &mut t);
        a.release(t);

        let before = a.stats().pages_in_use;
        let freed = cache.evict(1);
        assert!(freed > 0);
        assert_eq!(cache.stats().evictions, 1);
        assert_eq!(a.stats().pages_in_use, before - freed);
        // The cold prefix is gone, the hot one still hits.
        let mut h = a.acquire().unwrap();
        assert_eq!(cache.match_and_borrow(&cold, &mut h), 0);
        assert_eq!(cache.match_and_borrow(&hot, &mut h), 3);
        a.release(h);
    }

    #[test]
    fn reclaimer_evicts_under_store_pressure() {
        // 1-slot pool: a cached prompt owns every page; wiring the
        // cache as reclaimer lets the next session's stores evict it
        // instead of panicking.
        let a = arena(1);
        let cache = Arc::new(PrefixCache::new(a.clone()));
        register_reclaimer(&a, &cache);
        let prompt = [1u32, 2, 3, 4, 5, 6, 7, 8];
        let mut d = a.acquire().unwrap();
        prefill(&a, &mut d, &prompt);
        cache.insert(&prompt, &mut d);
        a.release(d);
        assert!(a.stats().pages_in_use > 0, "cache holds the pool");

        let mut h = a.acquire().unwrap();
        prefill(&a, &mut h, &prompt); // needs the whole pool back
        assert!(cache.stats().evictions >= 1, "pressure must evict, not panic");
        a.release(h);
        assert_eq!(a.stats().pages_in_use, 0);
    }

    #[test]
    fn drop_releases_every_cache_ref() {
        let a = arena(8);
        let prompt = [2u32, 4, 6, 8];
        {
            let cache = PrefixCache::new(a.clone());
            let mut d = a.acquire().unwrap();
            prefill(&a, &mut d, &prompt);
            cache.insert(&prompt, &mut d);
            a.release(d);
            assert!(a.stats().pages_in_use > 0);
        }
        assert_eq!(a.stats().pages_in_use, 0, "cache drop leaked page refs");
    }

    #[test]
    fn short_prompts_never_cached() {
        let a = arena(8);
        let cache = PrefixCache::new(a.clone());
        let mut h = a.acquire().unwrap();
        assert_eq!(cache.match_and_borrow(&[5u32], &mut h), 0);
        prefill(&a, &mut h, &[5u32]);
        cache.insert(&[5u32], &mut h);
        assert_eq!(cache.node_count(), 0, "single-token prompts are not worth a node");
        a.release(h);
    }
}
