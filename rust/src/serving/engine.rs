//! Decode engines: native fp32, LUT bit-plane, and PJRT (AOT artifact).
//!
//! All three implement the same continuous-batching `generate_batch`
//! contract so the router/batcher are engine-agnostic. Sessions within a
//! batch advance one token per sweep; a [`Stepper`] decides how the sweep
//! is *executed*:
//!
//! * [`NativeStepper`] steps each session independently — dense matvecs
//!   share nothing across sessions, so the pre-refactor per-session path
//!   is kept unchanged;
//! * [`BatchedLutStep`] fuses the sweep: one multi-LUT build per linear,
//!   per-layer **batched** linears via [`crate::lut::lut_gemm`] (each
//!   row's packed plane words are gathered once for all active sessions),
//!   and a **fused attention phase**: sessions are grouped by decode
//!   position and each layer runs one group-ordered pass over head-major
//!   KV strips ([`crate::model::LayerKv`]) — contiguous dot/axpy sweeps
//!   with per-(group, head) setup shared across the group, instead of
//!   per-session strided scalar loops. Together with grouped-query
//!   attention (KV caches are
//!   `kv_dim`-wide, `n_heads / n_kv_heads` smaller than `d_model`) this
//!   amortizes both the weight fetch and the KV bandwidth across the
//!   batch — the decode-side analogue of ABQ-LLM's batched binary-matrix
//!   kernels.

use super::metrics::Metrics;
use super::{Request, Response};
use crate::lut::{lut_gemm, LutScratch};
use crate::model::{argmax, attend_head, rmsnorm, silu, DecodeState, LayerKv, Model, Rope};
use crate::quant::packing::BitPlanePacked;
use crate::runtime::{self, Runtime};
use crate::tensor::matvec;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// A model whose block linears are *packed bit-planes* — the paper's
/// deployment format. Non-linear parts (norms, embeddings, lm_head) stay
/// dense.
#[derive(Clone)]
pub struct LutModel {
    pub base: Arc<Model>,
    /// "l{layer}.{name}" → packed record for all 7 block linears.
    pub packed: Arc<HashMap<String, BitPlanePacked>>,
}

impl LutModel {
    pub fn new(base: Arc<Model>, packed: HashMap<String, BitPlanePacked>) -> Result<Self> {
        for l in 0..base.cfg.n_layers {
            for name in crate::model::BLOCK_LINEARS {
                anyhow::ensure!(
                    packed.contains_key(&format!("l{l}.{name}")),
                    "missing packed record l{l}.{name}"
                );
            }
        }
        Ok(Self { base, packed: Arc::new(packed) })
    }
}

/// Which decode path a worker runs.
#[derive(Clone)]
pub enum EngineKind {
    /// dense f32 matvecs over (dequantized or fp) weights
    Native(Arc<Model>),
    /// batched LUT-GEMM over packed bit-planes
    Lut(LutModel),
    /// PJRT execution of the AOT `decode_step.hlo.txt`
    Pjrt { model: Arc<Model>, artifact: PathBuf, cache_len: usize },
}

/// A decode engine (one per worker thread).
pub struct Engine {
    kind: EngineKind,
    runtime: Option<Runtime>,
    lut_step: Option<BatchedLutStep>,
    metrics: Option<Metrics>,
}

impl Engine {
    pub fn new(kind: EngineKind) -> Result<Self> {
        let runtime = match &kind {
            EngineKind::Pjrt { .. } => Some(Runtime::cpu()?),
            _ => None,
        };
        let lut_step = match &kind {
            EngineKind::Lut(lm) => Some(BatchedLutStep::new(lm.clone())),
            _ => None,
        };
        Ok(Self { kind, runtime, lut_step, metrics: None })
    }

    pub fn kind_name(&self) -> &'static str {
        match self.kind {
            EngineKind::Native(_) => "native",
            EngineKind::Lut(_) => "lut",
            EngineKind::Pjrt { .. } => "pjrt",
        }
    }

    /// Give the engine a metrics handle so per-sweep decode batch
    /// occupancy is recorded (the router wires this up for its workers).
    pub fn attach_metrics(&mut self, metrics: Metrics) {
        self.metrics = Some(metrics);
    }

    /// Decode a batch of requests with continuous batching: every active
    /// session advances one token per sweep, and the whole sweep runs
    /// through the engine's stepper (fused for the LUT engine).
    pub fn generate_batch(&mut self, reqs: &[Request]) -> Result<Vec<Response>> {
        let metrics = self.metrics.clone();
        match &self.kind {
            EngineKind::Native(model) => {
                let mut stepper = NativeStepper { model: model.clone() };
                generate_generic(&mut stepper, reqs, metrics.as_ref())
            }
            EngineKind::Lut(_) => {
                let stepper = self.lut_step.as_mut().context("lut stepper missing")?;
                generate_generic(stepper, reqs, metrics.as_ref())
            }
            EngineKind::Pjrt { model, artifact, cache_len } => {
                let (model, artifact, cache_len) = (model.clone(), artifact.clone(), *cache_len);
                let rt = self.runtime.as_mut().context("pjrt runtime")?;
                pjrt_generate(rt, &model, &artifact, cache_len, reqs)
            }
        }
    }
}

/// One in-flight decode session: KV state + position bookkeeping. The
/// stepping itself belongs to the [`Stepper`] so batched engines can fuse
/// a whole sweep.
trait Session {
    fn pos(&self) -> usize;
    fn capacity(&self) -> usize;
}

/// Executes one sweep: each session advances by exactly one token.
trait Stepper {
    type Sess: Session;

    fn make(&self, r: &Request) -> Self::Sess;

    /// Step session `i` with `tokens[i]`; returns next-token logits per
    /// session, in order.
    fn step_batch(&mut self, sessions: &mut [&mut Self::Sess], tokens: &[u32]) -> Vec<Vec<f32>>;
}

/// Round-robin sweeps, engine-agnostic: collect one token per active
/// session, hand the whole sweep to the stepper, then apply sampling /
/// finalization per session. Prompt prefill counts as steps too —
/// single-token engine.
fn generate_generic<St: Stepper>(
    stepper: &mut St,
    reqs: &[Request],
    metrics: Option<&Metrics>,
) -> Result<Vec<Response>> {
    struct Active<S> {
        idx: usize,
        sess: S,
        prompt_left: std::vec::IntoIter<u32>,
        next_token: Option<u32>,
        out: Vec<u32>,
        started: Instant,
        first_tok: Option<Instant>,
    }

    fn finalize<S>(done: &mut [Option<Response>], a: &Active<S>, reqs: &[Request]) {
        let total = a.started.elapsed().as_micros() as u64;
        let first = a.first_tok.map(|t| (t - a.started).as_micros() as u64).unwrap_or(total);
        done[a.idx] = Some(Response {
            id: reqs[a.idx].id,
            // `out` is exactly what was sampled — the trailing speculative
            // token (fed but never requested) is never pushed.
            tokens: a.out.clone(),
            first_token_us: first,
            total_us: total,
        });
    }

    let mut active: Vec<Active<St::Sess>> = reqs
        .iter()
        .enumerate()
        .map(|(idx, r)| Active {
            idx,
            sess: stepper.make(r),
            prompt_left: r.prompt.clone().into_iter(),
            next_token: None,
            out: Vec::new(),
            started: Instant::now(),
            first_tok: None,
        })
        .collect();
    let mut done: Vec<Option<Response>> = (0..reqs.len()).map(|_| None).collect();

    while !active.is_empty() {
        // Gather this sweep's (session, token) pairs; sessions with no
        // token left (or no KV capacity) finalize instead.
        let mut stepping: Vec<Active<St::Sess>> = Vec::with_capacity(active.len());
        let mut tokens: Vec<u32> = Vec::with_capacity(active.len());
        for mut a in active {
            let capacity_left = a.sess.capacity() - a.sess.pos();
            match a.next_token.take().or_else(|| a.prompt_left.next()) {
                Some(t) if capacity_left > 0 => {
                    tokens.push(t);
                    stepping.push(a);
                }
                // out of prompt+generation or capacity: finalize
                _ => finalize(&mut done, &a, reqs),
            }
        }
        if stepping.is_empty() {
            break;
        }
        if let Some(m) = metrics {
            m.record_decode_sweep(stepping.len());
        }

        let logits_all = {
            let mut refs: Vec<&mut St::Sess> = stepping.iter_mut().map(|a| &mut a.sess).collect();
            stepper.step_batch(&mut refs, &tokens)
        };
        debug_assert_eq!(logits_all.len(), stepping.len());

        let mut still = Vec::with_capacity(stepping.len());
        for (mut a, logits) in stepping.into_iter().zip(logits_all) {
            if a.prompt_left.len() == 0 {
                // generating
                if a.first_tok.is_none() {
                    a.first_tok = Some(Instant::now());
                }
                if a.out.len() < reqs[a.idx].max_new {
                    let next = argmax(&logits) as u32;
                    a.out.push(next);
                    a.next_token = Some(next);
                    still.push(a);
                } else {
                    finalize(&mut done, &a, reqs);
                }
            } else {
                still.push(a);
            }
        }
        active = still;
    }

    Ok(done.into_iter().map(|d| d.expect("all finalized")).collect())
}

struct NativeSession {
    state: DecodeState,
}

impl Session for NativeSession {
    fn pos(&self) -> usize {
        self.state.pos()
    }
    fn capacity(&self) -> usize {
        self.state.capacity()
    }
}

/// Independent per-session stepping — the pre-refactor decode path,
/// bypassing the fused sweep entirely (dense matvecs have no cross-
/// session work to share).
struct NativeStepper {
    model: Arc<Model>,
}

impl Stepper for NativeStepper {
    type Sess = NativeSession;

    fn make(&self, _r: &Request) -> NativeSession {
        NativeSession { state: self.model.decode_state() }
    }

    fn step_batch(&mut self, sessions: &mut [&mut NativeSession], tokens: &[u32]) -> Vec<Vec<f32>> {
        sessions.iter_mut().zip(tokens).map(|(s, &t)| s.state.step(&self.model, t)).collect()
    }
}

/// LUT decode session state: per-layer head-major KV plus position. The
/// per-step work buffers live in [`BatchedLutStep`], shared across the
/// batch. Capacity comes from [`Model::decode_capacity`] — the same
/// source as [`DecodeState`] — so the LUT and native engines truncate
/// identically and allocate identical KV memory
/// (`n_layers × cap × 2 × kv_dim × 4` bytes).
struct LutSession {
    k: Vec<LayerKv>,
    v: Vec<LayerKv>,
    pos: usize,
    cap: usize,
}

impl Session for LutSession {
    fn pos(&self) -> usize {
        self.pos
    }
    fn capacity(&self) -> usize {
        self.cap
    }
}

/// Batched LUT stepper: all active sessions advance together through one
/// fused pass per sweep — shared multi-LUT build, per-layer batched
/// linears ([`lut_gemm`]), per-session attention/KV. Per-slot buffers are
/// reused across sweeps so the warm decode loop is allocation-free (save
/// for the per-linear slice-of-refs assembly).
struct BatchedLutStep {
    lm: LutModel,
    rope: Arc<Rope>,
    cap: usize,
    scratch: LutScratch,
    // per-slot step buffers (slot = position within the current sweep)
    h: Vec<Vec<f32>>,
    normed: Vec<Vec<f32>>,
    q: Vec<Vec<f32>>,
    kx: Vec<Vec<f32>>,
    vx: Vec<Vec<f32>>,
    attn: Vec<Vec<f32>>,
    proj: Vec<Vec<f32>>,
    up: Vec<Vec<f32>>,
    gate: Vec<Vec<f32>>,
    mid: Vec<Vec<f32>>,
    down: Vec<Vec<f32>>,
    scores: Vec<f32>,
}

impl BatchedLutStep {
    fn new(lm: LutModel) -> Self {
        let cap = lm.base.decode_capacity();
        // One rope table per model, shared with every DecodeState.
        let rope = lm.base.rope();
        Self {
            lm,
            rope,
            cap,
            scratch: LutScratch::default(),
            h: Vec::new(),
            normed: Vec::new(),
            q: Vec::new(),
            kx: Vec::new(),
            vx: Vec::new(),
            attn: Vec::new(),
            proj: Vec::new(),
            up: Vec::new(),
            gate: Vec::new(),
            mid: Vec::new(),
            down: Vec::new(),
            scores: Vec::new(),
        }
    }
}

/// Grow a per-slot buffer pool to at least `nb` slots.
fn ensure_slots(bufs: &mut Vec<Vec<f32>>, nb: usize) {
    while bufs.len() < nb {
        bufs.push(Vec::new());
    }
}

/// One batched linear: `ys[b] = packed("l{l}.{name}") · xs[b]` for all
/// `b < nb`, through the fused [`lut_gemm`] kernel.
fn lin_batch(
    lm: &LutModel,
    l: usize,
    name: &str,
    xs: &[Vec<f32>],
    nb: usize,
    ys: &mut Vec<Vec<f32>>,
    scratch: &mut LutScratch,
) {
    let rec = &lm.packed[&format!("l{l}.{name}")];
    ensure_slots(ys, nb);
    let xrefs: Vec<&[f32]> = xs[..nb].iter().map(|x| x.as_slice()).collect();
    let mut yrefs: Vec<&mut [f32]> = Vec::with_capacity(nb);
    for y in ys[..nb].iter_mut() {
        y.resize(rec.d_out, 0.0);
        yrefs.push(y.as_mut_slice());
    }
    lut_gemm(rec, &xrefs, &mut yrefs, scratch);
}

impl Stepper for BatchedLutStep {
    type Sess = LutSession;

    fn make(&self, _r: &Request) -> LutSession {
        let cfg = &self.lm.base.cfg;
        let (nkv, hd) = (cfg.n_kv_heads, cfg.head_dim());
        LutSession {
            k: (0..cfg.n_layers).map(|_| LayerKv::new(nkv, self.cap, hd)).collect(),
            v: (0..cfg.n_layers).map(|_| LayerKv::new(nkv, self.cap, hd)).collect(),
            pos: 0,
            cap: self.cap,
        }
    }

    fn step_batch(&mut self, sessions: &mut [&mut LutSession], tokens: &[u32]) -> Vec<Vec<f32>> {
        let nb = sessions.len();
        debug_assert_eq!(tokens.len(), nb);
        if nb == 0 {
            return Vec::new();
        }
        // Arc clone so `model` does not borrow `self` (the per-slot
        // buffers below need disjoint &mut borrows of self's fields).
        let model = self.lm.base.clone();
        let cfg = &model.cfg;
        let (d, nh, nkv, hd) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());
        let group = cfg.kv_group();
        let scale = 1.0 / (hd as f32).sqrt();

        ensure_slots(&mut self.h, nb);
        ensure_slots(&mut self.normed, nb);
        ensure_slots(&mut self.attn, nb);
        ensure_slots(&mut self.mid, nb);

        for (b, (&tok, sess)) in tokens.iter().zip(sessions.iter()).enumerate() {
            assert!(sess.pos < sess.cap, "KV cache exhausted");
            let id = (tok as usize).min(cfg.vocab_size - 1);
            let hb = &mut self.h[b];
            hb.clear();
            hb.extend_from_slice(model.embed.row(id));
        }

        // Group sweep slots by decode position (stable within the sweep:
        // positions advance only at the end). Slots at equal positions
        // share the score-buffer length, so the per-layer attention phase
        // below runs as one uniform pass per group over the shared
        // head-major layout — not per-session control flow.
        let mut order: Vec<usize> = (0..nb).collect();
        order.sort_unstable_by_key(|&b| sessions[b].pos);
        let mut groups: Vec<(usize, std::ops::Range<usize>)> = Vec::new();
        let mut i = 0;
        while i < nb {
            let t = sessions[order[i]].pos;
            let mut j = i + 1;
            while j < nb && sessions[order[j]].pos == t {
                j += 1;
            }
            groups.push((t, i..j));
            i = j;
        }

        for l in 0..cfg.n_layers {
            let lw = &model.layers[l];

            // ---- attention (GQA: `group` q heads per kv head) ----
            for b in 0..nb {
                self.normed[b].resize(d, 0.0);
            }
            for b in 0..nb {
                rmsnorm(&self.h[b], &lw.norm1, &mut self.normed[b]);
            }
            lin_batch(&self.lm, l, "wq", &self.normed, nb, &mut self.q, &mut self.scratch);
            lin_batch(&self.lm, l, "wk", &self.normed, nb, &mut self.kx, &mut self.scratch);
            lin_batch(&self.lm, l, "wv", &self.normed, nb, &mut self.vx, &mut self.scratch);

            for (b, sess) in sessions.iter_mut().enumerate() {
                let t = sess.pos;
                for hh in 0..nh {
                    self.rope.apply(&mut self.q[b][hh * hd..(hh + 1) * hd], t);
                }
                for hh in 0..nkv {
                    self.rope.apply(&mut self.kx[b][hh * hd..(hh + 1) * hd], t);
                }
                sess.k[l].store(t, &self.kx[b]);
                sess.v[l].store(t, &self.vx[b]);

                let attnb = &mut self.attn[b];
                attnb.resize(d, 0.0);
                attnb.iter_mut().for_each(|a| *a = 0.0);
            }

            // Batched score/softmax/AV: one pass per position group with
            // heads walked *outside* the session loop, so the per-(group,
            // head) setup — score length, head offset, kv-head mapping —
            // is computed once and applied to every session in the group,
            // and each session's work is a contiguous strip sweep
            // (dot + axpy over `t+1 × hd`). Per-session KV strips stay
            // independent memory, so this is the most cross-session
            // fusion the layout admits; pooling strips into one shared
            // slab matvec is the follow-on (ROADMAP).
            for (t, range) in &groups {
                let t = *t;
                self.scores.resize(t + 1, 0.0);
                for hh in 0..nh {
                    let o0 = hh * hd;
                    let kvh = hh / group;
                    for &b in &order[range.clone()] {
                        let sess: &LutSession = &sessions[b];
                        attend_head(
                            &self.q[b][o0..o0 + hd],
                            sess.k[l].strip(kvh, t + 1),
                            sess.v[l].strip(kvh, t + 1),
                            scale,
                            &mut self.scores,
                            &mut self.attn[b][o0..o0 + hd],
                        );
                    }
                }
            }

            lin_batch(&self.lm, l, "wo", &self.attn, nb, &mut self.proj, &mut self.scratch);
            for b in 0..nb {
                for (hi, p) in self.h[b].iter_mut().zip(self.proj[b].iter()) {
                    *hi += p;
                }
            }

            // ---- MLP (SwiGLU) ----
            for b in 0..nb {
                rmsnorm(&self.h[b], &lw.norm2, &mut self.normed[b]);
            }
            lin_batch(&self.lm, l, "w1", &self.normed, nb, &mut self.up, &mut self.scratch);
            lin_batch(&self.lm, l, "w3", &self.normed, nb, &mut self.gate, &mut self.scratch);
            for b in 0..nb {
                let midb = &mut self.mid[b];
                midb.resize(self.up[b].len(), 0.0);
                for ((m, &u), &gt) in
                    midb.iter_mut().zip(self.up[b].iter()).zip(self.gate[b].iter())
                {
                    *m = u * silu(gt);
                }
            }
            lin_batch(&self.lm, l, "w2", &self.mid, nb, &mut self.down, &mut self.scratch);
            for b in 0..nb {
                for (hi, dn) in self.h[b].iter_mut().zip(self.down[b].iter()) {
                    *hi += dn;
                }
            }
        }

        let mut out = Vec::with_capacity(nb);
        for (b, sess) in sessions.iter_mut().enumerate() {
            sess.pos += 1;
            let normb = &mut self.normed[b];
            normb.resize(d, 0.0);
            rmsnorm(&self.h[b], &model.norm_f, normb);
            out.push(matvec(&model.lm_head, normb));
        }
        out
    }
}

/// PJRT path: run requests sequentially through the AOT decode-step
/// executable, threading the KV cache literals. The executable is loaded
/// (and compiled, on a cache miss) **once per batch**, not per request —
/// reloading inside the request loop made every request pay the artifact
/// parse/compile round-trip.
fn pjrt_generate(
    rt: &mut Runtime,
    model: &Model,
    artifact: &std::path::Path,
    cache_len: usize,
    reqs: &[Request],
) -> Result<Vec<Response>> {
    // The AOT decode-step artifact predates GQA and threads a full
    // d_model-wide KV cache; refuse grouped-query checkpoints rather than
    // silently mis-shaping the cache literals.
    anyhow::ensure!(
        model.cfg.n_kv_heads == model.cfg.n_heads,
        "PJRT decode artifact supports MHA only (n_kv_heads == n_heads)"
    );
    let nl = model.cfg.n_layers;
    let d = model.cfg.d_model;
    let cache_elems = nl * cache_len * d;
    let mut out = Vec::with_capacity(reqs.len());
    let exe = rt.load(artifact)?;

    for r in reqs {
        let started = Instant::now();
        let mut first_tok = None;
        let zeros = vec![0.0f32; cache_elems];
        let mut klit = runtime::literal_f32(&zeros, &[nl as i64, cache_len as i64, d as i64])?;
        let mut vlit = runtime::literal_f32(&zeros, &[nl as i64, cache_len as i64, d as i64])?;
        let mut logits: Vec<f32> = Vec::new();
        let mut pos = 0usize;
        let budget = cache_len.saturating_sub(2);
        for &t in r.prompt.iter().take(budget) {
            let res = exe.run(&[
                runtime::literal_i32(t as i32),
                runtime::literal_i32(pos as i32),
                klit,
                vlit,
            ])?;
            let mut it = res.into_iter();
            logits = runtime::to_f32_vec(&it.next().context("logits")?)?;
            klit = it.next().context("kcache")?;
            vlit = it.next().context("vcache")?;
            pos += 1;
        }
        let mut tokens = Vec::with_capacity(r.max_new);
        for _ in 0..r.max_new {
            if pos >= cache_len {
                break;
            }
            let next = argmax(&logits) as u32;
            if first_tok.is_none() {
                first_tok = Some(started.elapsed().as_micros() as u64);
            }
            tokens.push(next);
            let res = exe.run(&[
                runtime::literal_i32(next as i32),
                runtime::literal_i32(pos as i32),
                klit,
                vlit,
            ])?;
            let mut it = res.into_iter();
            logits = runtime::to_f32_vec(&it.next().context("logits")?)?;
            klit = it.next().context("kcache")?;
            vlit = it.next().context("vcache")?;
            pos += 1;
        }
        let total = started.elapsed().as_micros() as u64;
        out.push(Response {
            id: r.id,
            tokens,
            first_token_us: first_tok.unwrap_or(total),
            total_us: total,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::tlm::TlmFile;
    use crate::model::{synthetic_model, ModelConfig};
    use crate::quant::{BpdqConfig, QuantMethod};
    use std::path::Path;

    fn tiny() -> Arc<Model> {
        tiny_gqa(4)
    }

    /// 4-head tiny model with `n_kv_heads` kv heads (4 = MHA, 2 = GQA,
    /// 1 = MQA).
    fn tiny_gqa(n_kv_heads: usize) -> Arc<Model> {
        Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 20,
                d_model: 32,
                n_layers: 2,
                n_heads: 4,
                n_kv_heads,
                d_ff: 48,
                max_seq: 32,
            },
            3,
        ))
    }

    fn reqs(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                id: i as u64,
                prompt: (0..5).map(|t| ((t + i) % 20) as u32).collect(),
                max_new: 4,
            })
            .collect()
    }

    /// Quantize `model` with BPDQ and build (native-on-dequant, LUT)
    /// engines over the same weights.
    fn quantized_engine_pair(model: Arc<Model>, group_size: usize) -> (Engine, Engine) {
        let vocab = model.cfg.vocab_size;
        let calib: Vec<Vec<u32>> = (0..4)
            .map(|i| (0..20).map(|t| ((t * 3 + i) % vocab) as u32).collect())
            .collect();
        let method = QuantMethod::Bpdq(BpdqConfig {
            k: 2,
            group_size,
            iters: 2,
            gar: false,
            ..Default::default()
        });
        let qm = crate::model::pipeline::quantize_model(&model, &calib, &method).unwrap();
        let packed: HashMap<String, BitPlanePacked> = qm
            .packed
            .iter()
            .map(|(k, v)| (k.clone(), v.as_bit_planes().unwrap().clone()))
            .collect();
        let qmodel = Arc::new(qm.model.clone());
        let native = Engine::new(EngineKind::Native(qmodel.clone())).unwrap();
        let lut = Engine::new(EngineKind::Lut(LutModel::new(qmodel, packed).unwrap())).unwrap();
        (native, lut)
    }

    #[test]
    fn native_engine_batch() {
        let mut e = Engine::new(EngineKind::Native(tiny())).unwrap();
        let rs = e.generate_batch(&reqs(3)).unwrap();
        assert_eq!(rs.len(), 3);
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), 4);
            assert!(r.first_token_us <= r.total_us);
        }
    }

    #[test]
    fn batch_matches_sequential() {
        // Continuous batching must not change results.
        let model = tiny();
        let mut e = Engine::new(EngineKind::Native(model.clone())).unwrap();
        let batch = e.generate_batch(&reqs(3)).unwrap();
        for (i, r) in reqs(3).iter().enumerate() {
            let single = e.generate_batch(std::slice::from_ref(r)).unwrap();
            assert_eq!(single[0].tokens, batch[i].tokens, "request {i}");
        }
    }

    #[test]
    fn lut_engine_matches_native_on_quantized_model() {
        // Quantize with BPDQ, then: native decode over dequantized weights
        // must equal batched LUT decode over the packed records — at every
        // kv-head count (MQA / GQA / MHA).
        for n_kv in [1usize, 2, 4] {
            let (mut native, mut lut) = quantized_engine_pair(tiny_gqa(n_kv), 16);
            let rs_native = native.generate_batch(&reqs(2)).unwrap();
            let rs_lut = lut.generate_batch(&reqs(2)).unwrap();
            for (a, b) in rs_native.iter().zip(&rs_lut) {
                assert_eq!(a.tokens, b.tokens, "n_kv_heads {n_kv}");
            }
        }
    }

    #[test]
    fn gqa_batched_decode_parity_ragged_prompts() {
        // The grouped-by-position fused attention must be token-identical
        // to the native engine and to B=1 LUT decode under GQA, with
        // ragged prompts (several distinct position groups per sweep).
        for n_kv in [1usize, 2] {
            let (mut native, mut lut) = quantized_engine_pair(tiny_gqa(n_kv), 16);
            let ragged: Vec<Request> = (0..4)
                .map(|i| Request {
                    id: i as u64,
                    prompt: (0..(1 + 2 * i)).map(|t| ((t * 5 + i) % 20) as u32).collect(),
                    max_new: 3 + i,
                })
                .collect();
            let rs_native = native.generate_batch(&ragged).unwrap();
            let rs_batch = lut.generate_batch(&ragged).unwrap();
            for (i, (a, b)) in rs_native.iter().zip(&rs_batch).enumerate() {
                assert_eq!(a.tokens, b.tokens, "n_kv {n_kv} native vs lut, request {i}");
            }
            for (i, r) in ragged.iter().enumerate() {
                let single = lut.generate_batch(std::slice::from_ref(r)).unwrap();
                assert_eq!(
                    single[0].tokens, rs_batch[i].tokens,
                    "n_kv {n_kv} B=1 vs batched, request {i}"
                );
            }
        }
    }

    #[test]
    fn lut_batched_decode_parity_ragged_prompts() {
        // The fused batched sweep must be token-identical to (a) the
        // native engine and (b) the LUT engine run one request at a time,
        // including with ragged prompt lengths and max_new (sessions
        // leave the batch at different sweeps).
        let (mut native, mut lut) = quantized_engine_pair(tiny(), 16);
        let ragged: Vec<Request> = (0..4)
            .map(|i| Request {
                id: i as u64,
                prompt: (0..(1 + 2 * i)).map(|t| ((t * 5 + i) % 20) as u32).collect(),
                max_new: 3 + i,
            })
            .collect();
        let rs_native = native.generate_batch(&ragged).unwrap();
        let rs_batch = lut.generate_batch(&ragged).unwrap();
        for (i, (a, b)) in rs_native.iter().zip(&rs_batch).enumerate() {
            assert_eq!(a.tokens, b.tokens, "native vs lut, request {i}");
            assert_eq!(b.tokens.len(), ragged[i].max_new, "request {i} length");
        }
        for (i, r) in ragged.iter().enumerate() {
            let single = lut.generate_batch(std::slice::from_ref(r)).unwrap();
            assert_eq!(single[0].tokens, rs_batch[i].tokens, "B=1 vs batched, request {i}");
        }
    }

    #[test]
    fn capacity_exhaustion_parity() {
        // prompt + max_new beyond the KV capacity: both engines must
        // truncate at exactly the same point (capacity comes from the one
        // shared source, Model::decode_capacity).
        let model = Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 20,
                d_model: 32,
                n_layers: 2,
                n_heads: 2,
                n_kv_heads: 2,
                d_ff: 48,
                max_seq: 8, // decode capacity 32
            },
            5,
        ));
        assert_eq!(model.decode_capacity(), 32);
        let (mut native, mut lut) = quantized_engine_pair(model, 16);
        let req = Request {
            id: 0,
            prompt: (0..30).map(|t| (t % 20) as u32).collect(),
            max_new: 10,
        };
        let a = native.generate_batch(std::slice::from_ref(&req)).unwrap();
        let b = lut.generate_batch(std::slice::from_ref(&req)).unwrap();
        assert_eq!(a[0].tokens, b[0].tokens, "truncation point diverged");
        assert!(!a[0].tokens.is_empty(), "should have generated something");
        assert!(a[0].tokens.len() < 10, "capacity must truncate generation");
    }

    #[test]
    fn empty_prompt_generates_nothing_strange() {
        let mut e = Engine::new(EngineKind::Native(tiny())).unwrap();
        let r = Request { id: 9, prompt: vec![], max_new: 3 };
        let rs = e.generate_batch(&[r]).unwrap();
        // no prompt → no logits to sample from → zero tokens is acceptable
        assert!(rs[0].tokens.len() <= 3);
    }

    #[test]
    fn pjrt_batch_matches_single_request() {
        // PJRT engine parity across batch sizes; exercises the hoisted
        // (once-per-batch) executable load. Skips without the real PJRT
        // plugin or the AOT artifacts.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let artifact = dir.join("decode_step.hlo.txt");
        let ckpt = dir.join("tiny_small.tlm");
        if !artifact.exists() || !ckpt.exists() {
            eprintln!("[skip] pjrt artifacts missing (run `make artifacts`)");
            return;
        }
        let model = match TlmFile::load(&ckpt).and_then(|f| Model::from_tlm(&f)) {
            Ok(m) => Arc::new(m),
            Err(e) => {
                eprintln!("[skip] checkpoint unreadable: {e:#}");
                return;
            }
        };
        let kind = EngineKind::Pjrt { model, artifact, cache_len: 64 };
        let mut e = match Engine::new(kind) {
            Ok(e) => e,
            Err(err) => {
                eprintln!("[skip] PJRT plugin unavailable: {err:#}");
                return;
            }
        };
        let rs = e.generate_batch(&reqs(2)).unwrap();
        for (i, r) in reqs(2).iter().enumerate() {
            let single = e.generate_batch(std::slice::from_ref(r)).unwrap();
            assert_eq!(single[0].tokens, rs[i].tokens, "request {i}");
        }
    }
}
